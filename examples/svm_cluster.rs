//! End-to-end validation driver (EXPERIMENTS.md §E2E): train a linear
//! SVM on the rcv1-scale synthetic corpus with all four solvers on a
//! simulated 8-node × 2-core cluster, reproducing the paper's headline
//! comparison (Figure 3 / Figure 7 shape): Hybrid-DCA beats CoCoA+ on
//! wall/virtual time and scales past PassCoDe's single node.
//!
//! Run: `cargo run --release --example svm_cluster [-- <preset>]`

use hybrid_dca::config::Algorithm;
use hybrid_dca::harness;

fn main() -> anyhow::Result<()> {
    let preset = std::env::args().nth(1).unwrap_or_else(|| "rcv1-s".into());
    let (p, t) = (8usize, 2usize);
    let threshold = hybrid_dca::harness::fig3::threshold_for(&preset);

    let mut cfg = harness::paper_cfg(&preset, p, t);
    cfg.max_rounds = 80;
    cfg.gap_threshold = threshold / 10.0;
    let data = harness::load_dataset(&cfg)?;
    println!(
        "== {} : n={} d={} nnz={} λ={:.2e}, cluster {}×{} ==",
        data.name,
        data.n(),
        data.d(),
        data.x.nnz(),
        cfg.lambda,
        p,
        t
    );

    let mut traces = Vec::new();
    // Baseline (sequential, 1 core).
    {
        let mut c = cfg.clone();
        c.k_nodes = 1;
        c.r_cores = 1;
        c.s_barrier = 1;
        c.max_rounds = 200;
        let r = hybrid_dca::coordinator::run_algorithm(Algorithm::Baseline, &data, &c)?;
        traces.push(r.trace);
    }
    // CoCoA+ on p·t single-core nodes.
    {
        let mut c = cfg.clone();
        c.k_nodes = p * t;
        c.r_cores = 1;
        c.s_barrier = c.k_nodes;
        let r = hybrid_dca::coordinator::run_algorithm(Algorithm::CocoaPlus, &data, &c)?;
        traces.push(r.trace);
    }
    // PassCoDe on one p·t-core node.
    {
        let mut c = cfg.clone();
        c.k_nodes = 1;
        c.s_barrier = 1;
        c.r_cores = p * t;
        let r = hybrid_dca::coordinator::run_algorithm(Algorithm::PassCoDe, &data, &c)?;
        traces.push(r.trace);
    }
    // Hybrid-DCA (S = p, Γ = 1 — the Fig 3 setting).
    {
        let mut c = cfg.clone();
        c.s_barrier = p;
        c.gamma = 1;
        let r = hybrid_dca::coordinator::run_algorithm(Algorithm::HybridDca, &data, &c)?;
        // Report model quality from the hybrid run.
        let correct = (0..data.n())
            .filter(|&i| data.x.row(i).dot_dense(&r.v) * data.y[i] > 0.0)
            .count();
        println!(
            "Hybrid-DCA: {} rounds, {} updates, training accuracy {:.1}%",
            r.rounds,
            r.total_updates,
            100.0 * correct as f64 / data.n() as f64
        );
        traces.push(r.trace);
    }

    println!("\ntime/rounds to duality gap ≤ {threshold:.0e}:");
    harness::print_threshold_table(&traces, threshold);
    harness::save_traces("example_svm_cluster", &traces)?;

    // The paper's qualitative claims, checked programmatically:
    let get = |label: &str| traces.iter().find(|t| t.label == label).unwrap();
    let hybrid_t = get("Hybrid-DCA").virt_time_to_gap(threshold);
    let cocoa_t = get("CoCoA+").virt_time_to_gap(threshold);
    if let (Some(h), Some(c)) = (hybrid_t, cocoa_t) {
        println!(
            "\nHybrid-DCA vs CoCoA+ (virtual time): {:.1}× {}",
            c / h,
            if c > h { "faster ✓ (paper: faster)" } else { "SLOWER ✗" }
        );
    }
    Ok(())
}
