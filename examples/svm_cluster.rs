//! End-to-end validation driver (EXPERIMENTS.md §E2E): train a linear
//! SVM on the rcv1-scale synthetic corpus with all four solvers on a
//! simulated 8-node × 2-core cluster, reproducing the paper's headline
//! comparison (Figure 3 / Figure 7 shape): Hybrid-DCA beats CoCoA+ on
//! wall/virtual time and scales past PassCoDe's single node.
//!
//! Every solver runs through the `Session` builder and the
//! `SolverEngine` registry — the four engines are points in one
//! configuration space, differing only in cluster shape.
//!
//! Run: `cargo run --release --example svm_cluster [-- <preset>]`

use hybrid_dca::harness;

fn main() -> anyhow::Result<()> {
    let preset = std::env::args().nth(1).unwrap_or_else(|| "rcv1-s".into());
    let (p, t) = (8usize, 2usize);
    let threshold = hybrid_dca::harness::fig3::threshold_for(&preset);

    let base = harness::paper_session(&preset, p, t)
        .rounds(80)
        .gap_threshold(threshold / 10.0);
    let session = base.clone().build()?;
    let data = session.load_dataset()?;
    println!(
        "== {} : n={} d={} nnz={} λ={:.2e}, cluster {}×{} ==",
        data.name,
        data.n(),
        data.d(),
        data.x.nnz(),
        session.problem.lambda,
        p,
        t
    );

    let mut traces = Vec::new();
    // Baseline (sequential, 1 core).
    {
        let s = base.clone().cluster(1, 1).barrier(1).rounds(200).build()?;
        traces.push(s.run("baseline", &data)?.trace);
    }
    // CoCoA+ on p·t single-core nodes.
    {
        let s = base.clone().cluster(p * t, 1).barrier(p * t).build()?;
        traces.push(s.run("cocoa+", &data)?.trace);
    }
    // PassCoDe on one p·t-core node.
    {
        let s = base.clone().cluster(1, p * t).barrier(1).build()?;
        traces.push(s.run("passcode", &data)?.trace);
    }
    // Hybrid-DCA (S = p, Γ = 1 — the Fig 3 setting).
    {
        let s = base.clone().barrier(p).delay(1).build()?;
        let r = s.run("hybrid-dca", &data)?;
        // Report model quality from the hybrid run.
        let correct = (0..data.n())
            .filter(|&i| data.x.row(i).dot_dense(&r.v) * data.y[i] > 0.0)
            .count();
        println!(
            "Hybrid-DCA: {} rounds, {} updates, training accuracy {:.1}%",
            r.rounds,
            r.total_updates,
            100.0 * correct as f64 / data.n() as f64
        );
        traces.push(r.trace);
    }

    println!("\ntime/rounds to duality gap ≤ {threshold:.0e}:");
    harness::print_threshold_table(&traces, threshold);
    harness::save_traces("example_svm_cluster", &traces)?;

    // The paper's qualitative claims, checked programmatically:
    let get = |label: &str| traces.iter().find(|t| t.label == label).unwrap();
    let hybrid_t = get("Hybrid-DCA").virt_time_to_gap(threshold);
    let cocoa_t = get("CoCoA+").virt_time_to_gap(threshold);
    if let (Some(h), Some(c)) = (hybrid_t, cocoa_t) {
        println!(
            "\nHybrid-DCA vs CoCoA+ (virtual time): {:.1}× {}",
            c / h,
            if c > h { "faster ✓ (paper: faster)" } else { "SLOWER ✗" }
        );
    }
    Ok(())
}
