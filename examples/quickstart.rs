//! Quickstart: the smallest end-to-end run of the library.
//!
//! 1. Generate a tiny synthetic dataset.
//! 2. Train a hinge-loss SVM with Hybrid-DCA on a simulated 4-node ×
//!    2-core cluster (bounded barrier S=3, bounded delay Γ=2).
//! 3. Print the duality-gap trace and the final model quality.
//! 4. If AOT artifacts are present (`make artifacts`), run the same
//!    problem through the XLA block solver — the full L1/L2/L3 stack.
//!
//! Run: `cargo run --release --example quickstart`

use hybrid_dca::prelude::*;

fn main() -> anyhow::Result<()> {
    // 1. Data.
    let mut rng = Rng::new(42);
    let data = Preset::Tiny.generate(&mut rng);
    println!("dataset: {} (n={}, d={}, nnz={})", data.name, data.n(), data.d(), data.x.nnz());

    // 2. Configure the cluster.
    let mut cfg = ExpConfig::default();
    cfg.lambda = 1e-2;
    cfg.k_nodes = 4;
    cfg.r_cores = 2;
    cfg.s_barrier = 3; // merge as soon as 3 of 4 workers report
    cfg.gamma = 2; //     but never let anyone lag more than 2 rounds
    cfg.h_local = 256;
    cfg.max_rounds = 50;
    cfg.gap_threshold = 1e-5;

    // 3. Train.
    let report = coordinator::hybrid::run(&data, &cfg)?;
    println!("\nround    virt-time(s)        gap");
    for p in &report.trace.points {
        println!("{:>5} {:>14.6} {:>10.3e}", p.round, p.virt_secs, p.gap);
    }
    println!(
        "\nconverged in {} global rounds, {} coordinate updates, certificate gap {:.3e}",
        report.rounds,
        report.total_updates,
        report.certificate_gap(&data, &cfg)
    );

    // 4. Training accuracy of the learned model.
    let correct = (0..data.n())
        .filter(|&i| data.x.row(i).dot_dense(&report.v) * data.y[i] > 0.0)
        .count();
    println!("training accuracy: {:.1}%", 100.0 * correct as f64 / data.n() as f64);

    // 5. The XLA path (optional).
    let dir = hybrid_dca::runtime::default_artifacts_dir();
    if hybrid_dca::runtime::Runtime::available(&dir) {
        println!("\n-- XLA block solver (PJRT artifacts) --");
        let rt = hybrid_dca::runtime::Runtime::load(&dir)?;
        let mut solver = hybrid_dca::solver::xla_dense::XlaDenseSolver::new(&rt, &data, cfg.lambda)?;
        let (b, d) = solver.shape();
        println!("using block_step artifact B={b} D={d}");
        let trace = solver.solve(30, 1e-5)?;
        for p in trace.points.iter().step_by(5) {
            println!("epoch {:>3}  gap {:.3e}", p.round, p.gap);
        }
        println!("final gap through XLA: {:.3e}", trace.final_gap().unwrap());
    } else {
        println!("\n(no AOT artifacts found — run `make artifacts` to exercise the XLA path)");
    }
    Ok(())
}
