//! Quickstart: the smallest end-to-end run of the library.
//!
//! 1. Generate a tiny synthetic dataset.
//! 2. Describe the experiment with the typed `Session` builder: a
//!    hinge-loss SVM on a simulated 4-node × 2-core cluster (bounded
//!    barrier S=3, bounded delay Γ=2).
//! 3. Train through the `SolverEngine` registry, watching the
//!    duality-gap trace *live* through a streaming `Observer`.
//! 4. If AOT artifacts are present (`make artifacts` + the
//!    `xla-runtime` feature), run the same problem through the XLA
//!    block solver — the full L1/L2/L3 stack.
//!
//! Run: `cargo run --release --example quickstart`

use hybrid_dca::prelude::*;

fn main() -> anyhow::Result<()> {
    // 1. Data.
    let mut rng = Rng::new(42);
    let data = Preset::Tiny.generate(&mut rng);
    println!("dataset: {} (n={}, d={}, nnz={})", data.name, data.n(), data.d(), data.x.nnz());

    // 2. Describe the experiment. `build()` validates every paper
    // constraint (S ≤ K, Γ ≥ 1, ν ∈ (0,1], σ ≥ νS, …) and names the
    // violated one on error.
    let session = Session::builder()
        .lambda(1e-2)
        .cluster(4, 2) // K = 4 nodes × R = 2 cores
        .barrier(3) //    merge as soon as 3 of 4 workers report
        .delay(2) //      but never let anyone lag more than 2 rounds
        .local_iters(256)
        .rounds(50)
        .gap_threshold(1e-5)
        .build()?;

    // 3. Train, streaming the trace as it happens. Any engine in the
    // registry works here: "baseline", "cocoa+", "passcode", or
    // "hybrid-dca" — or one you registered yourself.
    println!("\nstreaming trace (round / virt-time / gap):");
    let mut live = hybrid_dca::session::PrintObserver::new();
    let report = session.run_observed("hybrid-dca", &data, &mut live)?;
    println!(
        "\nconverged in {} global rounds, {} coordinate updates, certificate gap {:.3e}",
        report.rounds,
        report.total_updates,
        report.certificate_gap(&data, &session.to_exp_config())
    );

    // 4. Training accuracy of the learned model.
    let correct = (0..data.n())
        .filter(|&i| data.x.row(i).dot_dense(&report.v) * data.y[i] > 0.0)
        .count();
    println!("training accuracy: {:.1}%", 100.0 * correct as f64 / data.n() as f64);

    // 5. The XLA path (optional, feature-gated).
    #[cfg(feature = "xla-runtime")]
    {
        let dir = hybrid_dca::runtime::default_artifacts_dir();
        if hybrid_dca::runtime::Runtime::available(&dir) {
            println!("\n-- XLA block solver (PJRT artifacts) --");
            let rt = hybrid_dca::runtime::Runtime::load(&dir)?;
            let mut solver = hybrid_dca::solver::xla_dense::XlaDenseSolver::new(
                &rt,
                &data,
                session.problem.lambda,
            )?;
            let (b, d) = solver.shape();
            println!("using block_step artifact B={b} D={d}");
            let trace = solver.solve(30, 1e-5)?;
            for p in trace.points.iter().step_by(5) {
                println!("epoch {:>3}  gap {:.3e}", p.round, p.gap);
            }
            println!("final gap through XLA: {:.3e}", trace.final_gap().unwrap());
        } else {
            println!("\n(no AOT artifacts found — run `make artifacts` to exercise the XLA path)");
        }
    }
    #[cfg(not(feature = "xla-runtime"))]
    println!("\n(build with --features xla-runtime to exercise the XLA path)");

    Ok(())
}
