//! The three-layer pipeline in isolation: Pallas kernel (L1) → JAX
//! model (L2) → AOT HLO artifact → Rust PJRT execution (L3 runtime).
//!
//! Loads every artifact from `artifacts/`, verifies the block-step
//! numerics against the pure-Rust oracle, solves a dense problem
//! end-to-end through XLA, and reports per-call latency and effective
//! update throughput for each (B, D) variant — the numbers behind
//! EXPERIMENTS.md §Perf (L1/L2).
//!
//! Run: `make artifacts && cargo run --release --example xla_pipeline`

use hybrid_dca::loss::Hinge;
use hybrid_dca::runtime::{default_artifacts_dir, ArtifactKind, Runtime};
use hybrid_dca::solver::block::{block_step, BlockInput};
use hybrid_dca::solver::StepParams;
use hybrid_dca::util::{measure, Rng, Stats};

fn main() -> anyhow::Result<()> {
    let dir = default_artifacts_dir();
    anyhow::ensure!(
        Runtime::available(&dir),
        "no artifacts at {} — run `make artifacts` first",
        dir.display()
    );
    let rt = Runtime::load(&dir)?;
    println!("loaded {} artifacts from {}\n", rt.names().len(), dir.display());

    let mut rng = Rng::new(99);
    println!(
        "{:<26} {:>10} {:>12} {:>14} {:>12}",
        "artifact", "max|Δ|", "p50 call", "updates/s", "agrees"
    );
    for name in rt.names() {
        let art = rt.get(name).unwrap();
        if art.meta.kind != ArtifactKind::BlockStep {
            continue;
        }
        let (b, d) = (art.meta.b, art.meta.d);
        // Random dense case.
        let x: Vec<f64> = (0..b * d)
            .map(|_| if rng.next_bool(0.4) { rng.next_gaussian() * 0.5 } else { 0.0 })
            .collect();
        let y: Vec<f64> = (0..b).map(|_| if rng.next_bool(0.5) { 1.0 } else { -1.0 }).collect();
        let alpha = vec![0.0f64; b];
        let v = vec![0.0f64; d];
        let params = StepParams { lambda: 1e-2, n: 1000, sigma: 2.0 };
        let oracle = block_step(
            &BlockInput { x: x.clone(), b, d, y: y.clone(), alpha: alpha.clone(), v: v.clone() },
            &Hinge,
            &params,
        );
        let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let yf: Vec<f32> = y.iter().map(|&v| v as f32).collect();
        let af = vec![0.0f32; b];
        let vf = vec![0.0f32; d];
        let out = rt.block_step(art, &xf, &yf, &af, &vf, params.v_scale() as f32, 2.0)?;
        let max_diff = out
            .eps
            .iter()
            .zip(&oracle.eps)
            .map(|(a, b)| (*a as f64 - b).abs())
            .fold(0.0f64, f64::max);

        // Latency.
        let samples = measure(3, 20, || {
            let _ = rt
                .block_step(art, &xf, &yf, &af, &vf, params.v_scale() as f32, 2.0)
                .unwrap();
        });
        let st = Stats::from(&samples);
        println!(
            "{:<26} {:>10.2e} {:>12} {:>14.0} {:>12}",
            name,
            max_diff,
            hybrid_dca::util::timer::fmt_duration(st.p50),
            b as f64 / st.p50,
            if max_diff < 2e-4 { "✓" } else { "✗" }
        );
    }

    // End-to-end dense solve through XLA.
    println!("\n-- dense SVM solved entirely through the XLA artifacts --");
    let data = hybrid_dca::data::synth::generate(
        &hybrid_dca::data::SynthSpec {
            name: "dense-demo".into(),
            n: 512,
            d: 384,
            nnz_per_row: 64,
            feature_skew: 0.2,
            label_noise: 0.05,
            separator_density: 0.4,
            topics: 0,
            topic_mix: 0.0,
        },
        &mut rng,
    );
    let lambda = 2.0 / data.n() as f64;
    let mut solver = hybrid_dca::solver::xla_dense::XlaDenseSolver::new(&rt, &data, lambda)?;
    let (b, d) = solver.shape();
    println!("dataset n={} d={} → artifact B={b} D={d}", data.n(), data.d());
    let trace = solver.solve(40, 1e-4)?;
    for p in trace.points.iter().step_by(8) {
        println!("epoch {:>3}  gap {:.3e}  ({:.2}s wall)", p.round, p.gap, p.wall_secs);
    }
    let final_gap = trace.final_gap().unwrap();
    println!("final gap {final_gap:.3e}");
    anyhow::ensure!(final_gap < 1e-2, "XLA solve failed to converge");
    println!("\nall layers compose ✓");
    Ok(())
}
