//! Heterogeneous-cluster scenario — the use case the paper motivates
//! in §6.3 but could not run on its homogeneous Hornet cluster: when
//! one node is much slower, the bounded barrier `S < K` lets the
//! master proceed without the straggler, and the bounded delay `Γ`
//! keeps the straggler's contribution fresh enough to converge.
//!
//! Sweeps S and Γ on a 6-node cluster where the last node is 6× slower
//! and reports time-to-gap, showing the S/Γ sweet spot.
//!
//! Run: `cargo run --release --example heterogeneous`

use hybrid_dca::harness;

fn main() -> anyhow::Result<()> {
    let preset = "rcv1-s";
    let (k, r) = (6usize, 2usize);
    let threshold = 1e-3;
    let base = harness::paper_session(preset, k, r)
        .rounds(80)
        .gap_threshold(threshold / 10.0)
        .stragglers(vec![1.0, 1.0, 1.0, 1.0, 1.0, 6.0]);
    let data = base.clone().build()?.load_dataset()?;
    println!(
        "== straggler study on {} (K={k}, R={r}, node 5 is 6× slower) ==\n",
        data.name
    );

    println!(
        "{:<16} {:>8} {:>16} {:>14}",
        "config", "rounds", "virt-time(s)", "final gap"
    );
    let mut results: Vec<(String, Option<f64>)> = Vec::new();
    for (s, gamma) in [
        (k, 1),     // synchronous: every round waits for the straggler
        (k - 1, 2), // drop one
        (k - 1, 10),
        (k / 2, 2), // aggressive barrier, tight freshness
        (k / 2, 10),
    ] {
        let session = base.clone().barrier(s).delay(gamma).build()?;
        let report = session.run("hybrid-dca", &data)?;
        let label = format!("S={s} Γ={gamma}");
        let ttt = report.trace.virt_time_to_gap(threshold);
        println!(
            "{:<16} {:>8} {:>16} {:>14.3e}",
            label,
            report
                .trace
                .rounds_to_gap(threshold)
                .map(|x| x.to_string())
                .unwrap_or_else(|| "—".into()),
            ttt.map(|x| format!("{x:.4}")).unwrap_or_else(|| "—".into()),
            report.trace.final_gap().unwrap()
        );
        results.push((label, ttt));
    }

    // The headline: bounded barrier beats full synchronization under
    // heterogeneity.
    let sync = results[0].1;
    let best_async = results[1..]
        .iter()
        .filter_map(|(l, t)| t.map(|t| (l.clone(), t)))
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    if let (Some(sync_t), Some((label, async_t))) = (sync, best_async) {
        println!(
            "\nbest async config ({label}) is {:.1}× faster than synchronous S=K \
             under a 6× straggler",
            sync_t / async_t
        );
    }
    Ok(())
}
