"""Layer-2 model (block dual step, gap tile) vs ref.py + invariants."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.kernels import ref

BS = st.sampled_from([1, 4, 8, 16])
DS = st.sampled_from([8, 64, 128, 256])


def make_case(b, d, seed):
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=(b, d)) * (rng.random((b, d)) < 0.4) * 0.5).astype(np.float32)
    y = np.where(rng.random(b) < 0.5, 1.0, -1.0).astype(np.float32)
    alpha = (rng.random(b) * y).astype(np.float32)
    v = (rng.normal(size=d) * 0.3).astype(np.float32)
    inv_ln = np.float32(0.05 + rng.random())
    sigma = np.float32(1.0 + 3.0 * rng.random())
    return x, y, alpha, v, inv_ln, sigma


@settings(max_examples=25, deadline=None)
@given(b=BS, d=DS, seed=st.integers(0, 2**31 - 1))
def test_block_step_matches_ref(b, d, seed):
    x, y, alpha, v, inv_ln, sigma = make_case(b, d, seed)
    a_ref, e_ref, dv_ref = ref.block_dual_step_ref(x, y, alpha, v, inv_ln, sigma)
    a_k, e_k, dv_k = model.block_dual_step(x, y, alpha, v, inv_ln, sigma)
    np.testing.assert_allclose(np.asarray(a_k), np.asarray(a_ref), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(e_k), np.asarray(e_ref), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(dv_k), np.asarray(dv_ref), rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(b=BS, d=DS, seed=st.integers(0, 2**31 - 1))
def test_block_step_feasibility(b, d, seed):
    """New duals stay in the hinge box: 0 ≤ α·y ≤ 1."""
    x, y, alpha, v, inv_ln, sigma = make_case(b, d, seed)
    a_new, _, _ = model.block_dual_step(x, y, alpha, v, inv_ln, sigma)
    signed = np.asarray(a_new) * y
    assert (signed >= -1e-6).all() and (signed <= 1.0 + 1e-6).all()


@settings(max_examples=25, deadline=None)
@given(b=BS, d=DS, seed=st.integers(0, 2**31 - 1))
def test_delta_v_consistency(b, d, seed):
    """Δv must equal inv_λn · εᵀX exactly (the wire contract)."""
    x, y, alpha, v, inv_ln, sigma = make_case(b, d, seed)
    _, eps, dv = model.block_dual_step(x, y, alpha, v, inv_ln, sigma)
    expect = inv_ln * (np.asarray(eps) @ x)
    np.testing.assert_allclose(np.asarray(dv), expect, rtol=1e-4, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(b=BS, d=DS, seed=st.integers(0, 2**31 - 1))
def test_gap_tile_matches_ref(b, d, seed):
    x, y, alpha, v, _, _ = make_case(b, d, seed)
    h_ref, d_ref = ref.gap_tile_ref(x, y, alpha, v)
    h_k, d_k = model.gap_tile(x, y, alpha, v)
    np.testing.assert_allclose(float(h_k), float(h_ref), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(d_k), float(d_ref), rtol=1e-5, atol=1e-5)


def test_block_step_iterates_to_fixed_point():
    """Repeated block steps (σ=1, applying Δv each round) are exact
    block coordinate ascent on the one-block dual: the steps must
    contract to a fixed point where no coordinate wants to move."""
    x, y, alpha0, v0, _, _ = make_case(8, 64, 123)
    inv_ln, sigma = np.float32(0.5), np.float32(1.0)
    alpha, v = alpha0, v0
    last = None
    for _ in range(60):
        a_new, eps, dv = model.block_dual_step(x, y, alpha, v, inv_ln, sigma)
        alpha = np.asarray(a_new)
        v = v + np.asarray(dv)
        last = float(jnp.abs(jnp.asarray(eps)).max())
    assert last < 1e-4, f"did not reach fixed point: max|eps| = {last}"


def test_zero_rows_produce_zero_steps():
    b, d = 4, 64
    x = np.zeros((b, d), np.float32)
    y = np.ones(b, np.float32)
    alpha = np.zeros(b, np.float32)
    v = np.zeros(d, np.float32)
    a_new, eps, dv = model.block_dual_step(x, y, alpha, v, np.float32(0.5), np.float32(1.0))
    assert float(jnp.abs(jnp.asarray(eps)).max()) == 0.0
    assert float(jnp.abs(jnp.asarray(dv)).max()) == 0.0
    np.testing.assert_array_equal(np.asarray(a_new), alpha)
