"""Layer-1 Pallas kernels vs pure-jnp references.

Hypothesis sweeps shapes and value distributions; every case asserts
allclose against ref.py. interpret=True keeps these runnable on CPU.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import gram_matvec as gm
from compile.kernels import matvec as mv
from compile.kernels import ref

# Shapes: B small-ish, D a multiple of the tile (tile = min(D, 128)).
BS = st.sampled_from([1, 2, 4, 8, 16, 32])
DS = st.sampled_from([8, 64, 128, 256, 384])


def make_case(b, d, seed, density=0.5, scale=1.0):
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=(b, d)) * (rng.random((b, d)) < density) * scale).astype(np.float32)
    v = (rng.normal(size=d) * 0.5).astype(np.float32)
    return x, v


@settings(max_examples=30, deadline=None)
@given(b=BS, d=DS, seed=st.integers(0, 2**31 - 1))
def test_gram_matvec_matches_ref(b, d, seed):
    x, v = make_case(b, d, seed)
    g_ref, g0_ref = ref.gram_matvec_ref(x, v)
    g_k, g0_k = gm.gram_matvec(x, v)
    np.testing.assert_allclose(np.asarray(g_k), np.asarray(g_ref), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(g0_k), np.asarray(g0_ref), rtol=1e-5, atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(b=BS, d=DS, seed=st.integers(0, 2**31 - 1))
def test_matvec_matches_ref(b, d, seed):
    x, v = make_case(b, d, seed)
    m_ref = ref.matvec_ref(x, v)
    m_k = mv.matvec(x, v)
    np.testing.assert_allclose(np.asarray(m_k), np.asarray(m_ref), rtol=1e-5, atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(b=BS, d=DS, seed=st.integers(0, 2**31 - 1))
def test_vecmat_matches_ref(b, d, seed):
    x, _ = make_case(b, d, seed)
    rng = np.random.default_rng(seed + 1)
    eps = rng.normal(size=x.shape[0]).astype(np.float32)
    u_ref = eps @ x
    u_k = mv.vecmat(eps, x)
    np.testing.assert_allclose(np.asarray(u_k), np.asarray(u_ref), rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(d=st.sampled_from([128, 256, 512]), tile=st.sampled_from([32, 64, 128]),
       seed=st.integers(0, 2**31 - 1))
def test_tiling_invariance(d, tile, seed):
    """Result must not depend on the tile width."""
    x, v = make_case(8, d, seed)
    g_a, g0_a = gm.gram_matvec(x, v, tile_d=tile)
    g_b, g0_b = gm.gram_matvec(x, v, tile_d=d)
    np.testing.assert_allclose(np.asarray(g_a), np.asarray(g_b), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(g0_a), np.asarray(g0_b), rtol=1e-5, atol=1e-5)


def test_gram_is_symmetric_psd():
    x, v = make_case(16, 128, 0)
    g, _ = gm.gram_matvec(x, v)
    g = np.asarray(g)
    np.testing.assert_allclose(g, g.T, atol=1e-5)
    eigs = np.linalg.eigvalsh(g.astype(np.float64))
    assert eigs.min() > -1e-4, f"Gram not PSD: min eig {eigs.min()}"


def test_non_divisible_tile_rejected():
    x, v = make_case(4, 100, 0)
    with pytest.raises(ValueError):
        gm.gram_matvec(x, v, tile_d=64)
    with pytest.raises(ValueError):
        mv.matvec(x, v, tile_d=64)


def test_zero_inputs():
    b, d = 8, 64
    x = np.zeros((b, d), np.float32)
    v = np.zeros(d, np.float32)
    g, g0 = gm.gram_matvec(x, v)
    assert float(jnp.abs(g).max()) == 0.0
    assert float(jnp.abs(g0).max()) == 0.0


def test_vmem_estimate_reasonable():
    # The perf model the DESIGN.md §Hardware-Adaptation table uses.
    bytes_ = gm.vmem_bytes(128, 512, tile_d=512)
    assert bytes_ < 16 * 2**20, "must fit VMEM"
    assert gm.mxu_macs(128, 512) == 128 * 128 * 512
