"""AOT pipeline: lowering produces parseable HLO text + valid manifest."""

import os

from compile import aot


def test_parse_variants():
    assert aot.parse_variants("16x64") == [(16, 64)]
    assert aot.parse_variants("16x64,32X256") == [(16, 64), (32, 256)]


def test_build_writes_artifacts(tmp_path):
    out = str(tmp_path / "artifacts")
    entries = aot.build(out, [(8, 64)])
    assert len(entries) == 2  # block_step + gap_tile
    names = {e["name"] for e in entries}
    assert names == {"block_step_b8_d64", "gap_tile_b8_d64"}
    for e in entries:
        path = os.path.join(out, e["file"])
        assert os.path.isfile(path)
        text = open(path).read()
        # HLO text essentials: module header + entry layout with the
        # expected parameter shapes.
        assert text.startswith("HloModule"), text[:80]
        assert "f32[8,64]" in text
    manifest = open(os.path.join(out, "manifest.toml")).read()
    assert "[block_step_b8_d64]" in manifest
    assert 'kind = "block_step"' in manifest
    assert "b = 8" in manifest
    assert "d = 64" in manifest


def test_hlo_has_no_custom_calls(tmp_path):
    """interpret=True must lower Pallas to plain HLO — a Mosaic
    custom-call would be unexecutable on the CPU PJRT client."""
    out = str(tmp_path / "a")
    aot.build(out, [(8, 64)])
    for f in os.listdir(out):
        if f.endswith(".hlo.txt"):
            text = open(os.path.join(out, f)).read()
            assert "custom-call" not in text, f"{f} contains a custom-call"


def test_block_step_hlo_shapes(tmp_path):
    out = str(tmp_path / "b")
    aot.build(out, [(4, 128)])
    text = open(os.path.join(out, "block_step_b4_d128.hlo.txt")).read()
    # 6 inputs (x, y, a, v, inv_lambda_n, sigma) -> 3 outputs.
    assert "f32[4,128]" in text
    assert "->(f32[4]{0}, f32[4]{0}, f32[128]{0})" in text.replace(" ", "") or True
