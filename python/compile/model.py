"""Layer-2 JAX model: the block dual-coordinate step and the objective
tile, composed from the Layer-1 Pallas kernels.

These are the functions AOT-lowered by ``aot.py`` into the HLO
artifacts the Rust coordinator executes via PJRT. Python never runs on
the solve path — only here, at build time.

Semantics are defined by ``kernels/ref.py`` (and mirrored in Rust by
``solver::block``); pytest asserts both directions.
"""

import jax
import jax.numpy as jnp
from jax import lax

from compile.kernels import gram_matvec as gm
from compile.kernels import matvec as mv
from compile.kernels.ref import hinge_step_signed


def block_dual_step(x, y, alpha, v, inv_lambda_n, sigma, *, tile_d=None):
    """Block (mini-batch locally-sequential) hinge dual step.

    Pipeline:
      1. L1 kernel: fused Gram tile ``G = X Xᵀ`` + margins ``g0 = X v``.
      2. L2 scan: exact sequential coordinate recurrence over the block
         (cheap rank-1 updates against the precomputed Gram rows).
      3. L1 kernel: ``Δv = (1/λn)·(ε @ X)``.

    Args:
      x: f32[B, D] dense coordinate tile.
      y: f32[B] labels ±1.
      alpha: f32[B] current duals.
      v: f32[D] frozen primal estimate.
      inv_lambda_n: f32 scalar, 1/(λn).
      sigma: f32 scalar, subproblem scaling σ.

    Returns:
      (alpha_new f32[B], eps f32[B], delta_v f32[D])
    """
    b = x.shape[0]
    gram, g0 = gm.gram_matvec(x, v, tile_d=tile_d)
    corr = sigma * inv_lambda_n

    # The scan needs G[j, j]; carry the row index explicitly.
    def body(eps, inputs):
        j, gram_row, g0_j, y_j, alpha_j = inputs
        m = g0_j + corr * jnp.dot(gram_row, eps)
        norm_sq = gram_row[j]
        q = sigma * norm_sq * inv_lambda_n
        a_sig = alpha_j * y_j
        a_new = hinge_step_signed(a_sig, y_j * m, q)
        e = a_new * y_j - alpha_j
        return eps.at[j].set(e), None

    eps0 = jnp.zeros_like(alpha)
    xs = (jnp.arange(b), gram, g0, y, alpha)
    eps, _ = lax.scan(body, eps0, xs)
    alpha_new = alpha + eps
    delta_v = inv_lambda_n * mv.vecmat(eps, x, tile_d=tile_d)
    return alpha_new, eps, delta_v


def gap_tile(x, y, alpha, v, *, tile_d=None):
    """Objective partial sums over a tile (hinge loss).

    Returns:
      (hinge_sum f32[], dual_sum f32[])
    """
    m = mv.matvec(x, v, tile_d=tile_d)
    hinge_sum = jnp.sum(jnp.maximum(0.0, 1.0 - y * m))
    dual_sum = jnp.sum(alpha * y)
    return hinge_sum, dual_sum


def block_step_example_args(b, d, dtype=jnp.float32):
    """ShapeDtypeStructs for AOT lowering of ``block_dual_step``."""
    return (
        jax.ShapeDtypeStruct((b, d), dtype),  # x
        jax.ShapeDtypeStruct((b,), dtype),    # y
        jax.ShapeDtypeStruct((b,), dtype),    # alpha
        jax.ShapeDtypeStruct((d,), dtype),    # v
        jax.ShapeDtypeStruct((), dtype),      # inv_lambda_n
        jax.ShapeDtypeStruct((), dtype),      # sigma
    )


def gap_tile_example_args(b, d, dtype=jnp.float32):
    """ShapeDtypeStructs for AOT lowering of ``gap_tile``."""
    return (
        jax.ShapeDtypeStruct((b, d), dtype),
        jax.ShapeDtypeStruct((b,), dtype),
        jax.ShapeDtypeStruct((b,), dtype),
        jax.ShapeDtypeStruct((d,), dtype),
    )
