"""Pure-jnp reference oracles for the Pallas kernels and the L2 model.

These are the correctness ground truth at build time: every Pallas
kernel and every composed model function is pytest-compared against the
functions here, and the Rust `solver::block` module implements the same
semantics in f64 (checked end-to-end through the PJRT runtime).

Semantics (must stay in lockstep with rust/src/solver/block.rs):

    G  = X @ X.T                      # Gram tile, [B, B]
    g0 = X @ v                        # base margins, [B]
    sequentially for j in 0..B:
        m_j   = g0[j] + sigma*inv_lambda_n * sum_l eps[l] * G[j, l]
        q_j   = sigma * G[j, j] * inv_lambda_n
        a_sig = alpha[j]*y[j]
        a_new = clip(a_sig + (1 - y[j]*m_j)/q_j, 0, 1)    # hinge step
        eps[j] = a_new*y[j] - alpha[j]
    delta_v = inv_lambda_n * (eps @ X)                    # wire scale

Rows with G[j,j] == 0 are skipped (no step possible).
"""

import jax.numpy as jnp
from jax import lax


def gram_matvec_ref(x, v):
    """G = X Xᵀ and g0 = X v."""
    return x @ x.T, x @ v


def matvec_ref(x, v):
    """Plain margins m = X v."""
    return x @ v


def hinge_step_signed(a_sig, ym, q):
    """Closed-form hinge dual step in the signed space a = alpha*y.

    Guards q == 0 (empty rows) by returning the unchanged value.
    """
    q_safe = jnp.where(q > 0.0, q, 1.0)
    a_new = jnp.clip(a_sig + (1.0 - ym) / q_safe, 0.0, 1.0)
    return jnp.where(q > 0.0, a_new, a_sig)


def block_dual_step_ref(x, y, alpha, v, inv_lambda_n, sigma):
    """Reference block dual-coordinate step (see module docstring).

    Args:
      x: [B, D] dense feature tile.
      y: [B] labels in {-1, +1}.
      alpha: [B] current dual variables.
      v: [D] frozen primal estimate.
      inv_lambda_n: scalar 1/(λn).
      sigma: scalar subproblem scaling σ.

    Returns:
      (alpha_new [B], eps [B], delta_v [D])
    """
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    alpha = jnp.asarray(alpha)
    v = jnp.asarray(v)
    b = x.shape[0]
    gram, g0 = gram_matvec_ref(x, v)
    corr = sigma * inv_lambda_n

    def body(eps, j):
        m = g0[j] + corr * jnp.dot(gram[j], eps)
        q = sigma * gram[j, j] * inv_lambda_n
        a_sig = alpha[j] * y[j]
        a_new = hinge_step_signed(a_sig, y[j] * m, q)
        e = a_new * y[j] - alpha[j]
        return eps.at[j].set(e), None

    eps, _ = lax.scan(body, jnp.zeros_like(alpha), jnp.arange(b))
    alpha_new = alpha + eps
    delta_v = inv_lambda_n * (eps @ x)
    return alpha_new, eps, delta_v


def gap_tile_ref(x, y, alpha, v):
    """Objective partial sums over a tile (hinge loss).

    Returns:
      hinge_sum = Σ_j max(0, 1 − y_j·(x_jᵀv))
      dual_sum  = Σ_j α_j·y_j
    """
    m = matvec_ref(x, v)
    hinge_sum = jnp.sum(jnp.maximum(0.0, 1.0 - y * m))
    dual_sum = jnp.sum(alpha * y)
    return hinge_sum, dual_sum
