"""Layer-1 Pallas kernel: fused Gram tile + base margins.

Computes, for a dense coordinate tile ``X ∈ f32[B, D]`` and the frozen
primal estimate ``v ∈ f32[D]``::

    G  = X @ X.T        # [B, B]
    g0 = X @ v          # [B]

in one pass over D, tiled into VMEM-sized chunks of ``TD`` features.

TPU mapping (DESIGN.md §Hardware-Adaptation): this is the MXU-shaped
heart of block SDCA. Each grid step loads one ``[B, TD]`` slab of X into
VMEM, feeds the systolic array with ``X_tile @ X_tileᵀ`` (B×TD×B MACs),
and accumulates into a ``[B, B]`` VMEM-resident accumulator; ``g0``
rides along as a fused matvec on the same slab, so X is read from HBM
exactly once. The BlockSpec index maps below express exactly the
HBM↔VMEM schedule a CUDA implementation would write with threadblocks.

Run under ``interpret=True`` everywhere in this repo: the CPU PJRT
client cannot execute Mosaic custom-calls; interpret mode lowers to
plain HLO so the AOT artifact runs on any backend.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, v_ref, g_ref, g0_ref):
    """One grid step: accumulate this D-tile's contribution."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        g0_ref[...] = jnp.zeros_like(g0_ref)

    x = x_ref[...]  # [B, TD] slab in VMEM
    v = v_ref[...]  # [TD]
    # MXU: [B, TD] @ [TD, B] accumulate in f32.
    g_ref[...] += jax.lax.dot_general(
        x, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    g0_ref[...] += x @ v


@functools.partial(jax.jit, static_argnames=("tile_d",))
def gram_matvec(x, v, *, tile_d=None):
    """Fused ``(X @ X.T, X @ v)`` via the Pallas kernel.

    Args:
      x: f32[B, D] dense tile; D must be divisible by ``tile_d``.
      v: f32[D].
      tile_d: feature-tile width (default: min(D, 128)).

    Returns:
      (G f32[B, B], g0 f32[B])
    """
    b, d = x.shape
    if tile_d is None:
        tile_d = min(d, 128)
    if d % tile_d != 0:
        raise ValueError(f"D={d} not divisible by tile_d={tile_d}")
    grid = (d // tile_d,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, tile_d), lambda i: (0, i)),
            pl.BlockSpec((tile_d,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((b, b), lambda i: (0, 0)),
            pl.BlockSpec((b,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, b), x.dtype),
            jax.ShapeDtypeStruct((b,), x.dtype),
        ],
        interpret=True,
    )(x, v)


def vmem_bytes(b, d, tile_d=None, dtype_bytes=4):
    """Estimated VMEM working set of one grid step (perf model input).

    X slab [B, TD] + v tile [TD] + accumulators G [B, B] and g0 [B].
    """
    if tile_d is None:
        tile_d = min(d, 128)
    return dtype_bytes * (b * tile_d + tile_d + b * b + b)


def mxu_macs(b, d):
    """Total MXU multiply-accumulates for the Gram product (perf model)."""
    return b * b * d
