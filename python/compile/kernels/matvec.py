"""Layer-1 Pallas kernels: plain matvec and vector-matrix products.

* ``matvec(x, v)``      — margins ``m = X @ v``        (gap tiles)
* ``vecmat(eps, x)``    — update  ``u = eps @ X``      (Δv assembly)

Both tile over D the same way as ``gram_matvec``; ``vecmat``
accumulates nothing across steps (each D-tile owns its output slice),
so its BlockSpec writes a different output block per grid step —
the streaming-store pattern.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matvec_kernel(x_ref, v_ref, m_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        m_ref[...] = jnp.zeros_like(m_ref)

    m_ref[...] += x_ref[...] @ v_ref[...]


@functools.partial(jax.jit, static_argnames=("tile_d",))
def matvec(x, v, *, tile_d=None):
    """``m = X @ v`` with D-tiled accumulation."""
    b, d = x.shape
    if tile_d is None:
        tile_d = min(d, 128)
    if d % tile_d != 0:
        raise ValueError(f"D={d} not divisible by tile_d={tile_d}")
    return pl.pallas_call(
        _matvec_kernel,
        grid=(d // tile_d,),
        in_specs=[
            pl.BlockSpec((b, tile_d), lambda i: (0, i)),
            pl.BlockSpec((tile_d,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((b,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((b,), x.dtype),
        interpret=True,
    )(x, v)


def _vecmat_kernel(e_ref, x_ref, u_ref):
    # Each grid step writes its own [TD] output slice: no accumulation.
    e = e_ref[...]  # [B]
    x = x_ref[...]  # [B, TD]
    u_ref[...] = e @ x


@functools.partial(jax.jit, static_argnames=("tile_d",))
def vecmat(eps, x, *, tile_d=None):
    """``u = eps @ X`` with per-tile streaming stores."""
    b, d = x.shape
    if eps.shape != (b,):
        raise ValueError(f"eps shape {eps.shape} != ({b},)")
    if tile_d is None:
        tile_d = min(d, 128)
    if d % tile_d != 0:
        raise ValueError(f"D={d} not divisible by tile_d={tile_d}")
    return pl.pallas_call(
        _vecmat_kernel,
        grid=(d // tile_d,),
        in_specs=[
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((b, tile_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((tile_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), x.dtype),
        interpret=True,
    )(eps, x)
