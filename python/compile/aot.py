"""AOT lowering: JAX (L2) + Pallas (L1) → HLO text artifacts + manifest.

Run once at build time (``make artifacts``); the Rust runtime compiles
the HLO on its PJRT CPU client and executes it on the solve path.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids that the `xla`
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly.

The manifest is a TOML-subset file read by ``rust/src/runtime``::

    [block_step_b16_d64]
    file = "block_step_b16_d64.hlo.txt"
    kind = "block_step"
    b = 16
    d = 64
    dtype = "f32"

Usage: ``python -m compile.aot --out-dir ../artifacts [--variants ...]``
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model

# (B, D) shape variants exported by default. B and D stay MXU/VMEM
# friendly (multiples of 8 / 128-divisible where it matters); D must be
# divisible by the kernel tile (min(D, 128)).
DEFAULT_VARIANTS = [(16, 64), (32, 256), (64, 512)]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_block_step(b: int, d: int) -> str:
    fn = lambda x, y, a, v, s, g: model.block_dual_step(x, y, a, v, s, g)
    lowered = jax.jit(fn).lower(*model.block_step_example_args(b, d))
    return to_hlo_text(lowered)


def lower_gap_tile(b: int, d: int) -> str:
    fn = lambda x, y, a, v: model.gap_tile(x, y, a, v)
    lowered = jax.jit(fn).lower(*model.gap_tile_example_args(b, d))
    return to_hlo_text(lowered)


def build(out_dir: str, variants) -> list:
    """Lower every variant; write HLO files + manifest. Returns entries."""
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for b, d in variants:
        for kind, lower in (("block_step", lower_block_step), ("gap_tile", lower_gap_tile)):
            name = f"{kind}_b{b}_d{d}"
            fname = f"{name}.hlo.txt"
            text = lower(b, d)
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            entries.append({"name": name, "file": fname, "kind": kind, "b": b, "d": d})
            print(f"  wrote {fname} ({len(text)} chars)")
    manifest = "".join(
        f'[{e["name"]}]\n'
        f'file = "{e["file"]}"\n'
        f'kind = "{e["kind"]}"\n'
        f'b = {e["b"]}\n'
        f'd = {e["d"]}\n'
        f'dtype = "f32"\n\n'
        for e in entries
    )
    with open(os.path.join(out_dir, "manifest.toml"), "w") as f:
        f.write(manifest)
    print(f"  wrote manifest.toml ({len(entries)} artifacts)")
    return entries


def parse_variants(spec: str):
    """Parse '16x64,32x256' into [(16, 64), (32, 256)]."""
    out = []
    for part in spec.split(","):
        b_s, d_s = part.lower().split("x")
        out.append((int(b_s), int(d_s)))
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact output directory")
    ap.add_argument(
        "--variants",
        default=",".join(f"{b}x{d}" for b, d in DEFAULT_VARIANTS),
        help="comma-separated BxD shape variants",
    )
    args = ap.parse_args()
    variants = parse_variants(args.variants)
    print(f"lowering {len(variants)} variants to {args.out_dir}")
    build(args.out_dir, variants)


if __name__ == "__main__":
    main()
