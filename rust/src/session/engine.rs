//! The pluggable solver-engine surface: an object-safe [`SolverEngine`]
//! trait plus a process-wide registry, so new coordination schemes (a
//! delayed-gradient variant, importance sampling, …) plug in without
//! touching any dispatcher.
//!
//! The paper's four solvers (Baseline, CoCoA+, PassCoDe, Hybrid-DCA)
//! are pre-registered; [`engine`] resolves them by canonical name or
//! any [`Algorithm`] alias (`"cocoa"`, `"hybrid"`, …).

use std::collections::BTreeMap;
use std::sync::{Arc, OnceLock, RwLock};

use crate::config::{Algorithm, ExpConfig};
use crate::coordinator::RunReport;
use crate::data::Dataset;

use super::observer::{Observer, ObserverHandle};

/// Everything an engine needs besides the dataset: the validated
/// (flattened) experiment config and the caller's streaming observer.
///
/// `cfg` is the engine-facing view of a [`Session`](super::Session) —
/// engines consume the flattened form so the coordinator internals stay
/// agnostic of the typed builder layer.
pub struct RunCtx<'a> {
    pub cfg: &'a ExpConfig,
    pub observer: ObserverHandle<'a>,
    /// Shard row spans (`[start, end)` tiling `0..n` in disk order)
    /// when the dataset came from a packed store. Multi-node engines
    /// partition on these boundaries (node `k` owns whole shards via
    /// [`Partition::from_shards`](crate::data::Partition::from_shards))
    /// instead of re-slicing `0..n` themselves; `None` means in-memory
    /// data and the configured [`Strategy`](crate::data::Strategy).
    pub shards: Option<Vec<(usize, usize)>>,
}

impl<'a> RunCtx<'a> {
    pub fn new(cfg: &'a ExpConfig, obs: &'a mut dyn Observer) -> Self {
        Self { cfg, observer: ObserverHandle::new(obs), shards: None }
    }

    /// A context that observes nothing (the deprecated-shim path).
    pub fn silent(cfg: &'a ExpConfig) -> Self {
        Self { cfg, observer: ObserverHandle::silent(), shards: None }
    }

    /// Attach shard spans from a [`ShardedDataset`](crate::store::ShardedDataset).
    pub fn with_shards(mut self, spans: Vec<(usize, usize)>) -> Self {
        self.shards = Some(spans);
        self
    }
}

/// An object-safe solver engine: one coordination scheme end to end.
///
/// Implementations must be stateless across runs (`&self`) and safe to
/// share between threads; per-run state belongs in the run itself.
pub trait SolverEngine: Send + Sync {
    /// Canonical registry name (lowercase by convention).
    fn name(&self) -> &str;

    /// Run to completion (gap threshold, round budget, or observer
    /// break) and return the final report.
    fn run(&self, data: &Dataset, ctx: &RunCtx<'_>) -> anyhow::Result<RunReport>;

    /// Run against a [`DataSource`](super::DataSource). The default
    /// materializes sharded sources flat and delegates to
    /// [`run`](Self::run) — correct for any engine, and the honest
    /// contract for single-node algorithms that need every row
    /// resident anyway. Multi-node engines override this to stream
    /// per-node slabs and evaluate over shards without ever assembling
    /// the full dataset.
    fn run_source(
        &self,
        source: &super::DataSource,
        ctx: &RunCtx<'_>,
    ) -> anyhow::Result<RunReport> {
        let data = source.as_dataset()?;
        self.run(&data, ctx)
    }
}

type Registry = RwLock<BTreeMap<String, Arc<dyn SolverEngine>>>;

static REGISTRY: OnceLock<Registry> = OnceLock::new();

fn registry() -> &'static Registry {
    REGISTRY.get_or_init(|| {
        let mut m: BTreeMap<String, Arc<dyn SolverEngine>> = BTreeMap::new();
        let builtins: [Arc<dyn SolverEngine>; 4] = [
            Arc::new(BaselineEngine),
            Arc::new(CocoaPlusEngine),
            Arc::new(PassCoDeEngine),
            Arc::new(HybridDcaEngine),
        ];
        for e in builtins {
            m.insert(e.name().to_string(), e);
        }
        RwLock::new(m)
    })
}

/// Register (or replace) an engine under its canonical name. Returns
/// the engine previously registered under that name, if any.
pub fn register_engine(engine: Arc<dyn SolverEngine>) -> Option<Arc<dyn SolverEngine>> {
    let key = engine.name().to_ascii_lowercase();
    registry().write().expect("engine registry poisoned").insert(key, engine)
}

/// Look up an engine by canonical name or [`Algorithm`] alias
/// (case-insensitive): `"hybrid"`, `"hybrid-dca"`, `"cocoa"`, …
pub fn engine(name: &str) -> Option<Arc<dyn SolverEngine>> {
    let reg = registry().read().expect("engine registry poisoned");
    let key = name.to_ascii_lowercase();
    if let Some(e) = reg.get(&key) {
        return Some(Arc::clone(e));
    }
    // Fall back to the legacy enum's aliases for the builtins.
    let canonical = Algorithm::parse(name)?;
    reg.get(canonical_name(canonical)).map(Arc::clone)
}

/// Resolve an engine or fail with the list of registered names.
pub fn resolve(name: &str) -> anyhow::Result<Arc<dyn SolverEngine>> {
    engine(name).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown solver engine '{}' (registered: {})",
            name,
            engine_names().join(", ")
        )
    })
}

/// Names of all registered engines, sorted.
pub fn engine_names() -> Vec<String> {
    registry().read().expect("engine registry poisoned").keys().cloned().collect()
}

/// Canonical registry key for a legacy [`Algorithm`] variant.
pub fn canonical_name(algo: Algorithm) -> &'static str {
    match algo {
        Algorithm::Baseline => "baseline",
        Algorithm::CocoaPlus => "cocoa+",
        Algorithm::PassCoDe => "passcode",
        Algorithm::HybridDca => "hybrid-dca",
    }
}

// ---- the four built-in engines ----

/// Sequential DCA (Hsieh et al. 2008) — the paper's *Baseline*.
struct BaselineEngine;

impl SolverEngine for BaselineEngine {
    fn name(&self) -> &str {
        "baseline"
    }

    fn run(&self, data: &Dataset, ctx: &RunCtx<'_>) -> anyhow::Result<RunReport> {
        crate::coordinator::baseline::run_ctx(data, ctx)
    }
}

/// CoCoA+ (Ma et al. 2015): synchronous all-reduce, 1 core per node.
struct CocoaPlusEngine;

impl SolverEngine for CocoaPlusEngine {
    fn name(&self) -> &str {
        "cocoa+"
    }

    fn run(&self, data: &Dataset, ctx: &RunCtx<'_>) -> anyhow::Result<RunReport> {
        crate::coordinator::cocoa::run_ctx(data, ctx)
    }

    fn run_source(
        &self,
        source: &super::DataSource,
        ctx: &RunCtx<'_>,
    ) -> anyhow::Result<RunReport> {
        crate::coordinator::cocoa::run_source_ctx(source, ctx)
    }
}

/// PassCoDe (Hsieh et al. 2015): single node, R async cores.
struct PassCoDeEngine;

impl SolverEngine for PassCoDeEngine {
    fn name(&self) -> &str {
        "passcode"
    }

    fn run(&self, data: &Dataset, ctx: &RunCtx<'_>) -> anyhow::Result<RunReport> {
        crate::coordinator::passcode::run_ctx(data, ctx)
    }
}

/// The paper's double-asynchronous solver.
struct HybridDcaEngine;

impl SolverEngine for HybridDcaEngine {
    fn name(&self) -> &str {
        "hybrid-dca"
    }

    fn run(&self, data: &Dataset, ctx: &RunCtx<'_>) -> anyhow::Result<RunReport> {
        crate::coordinator::hybrid::run_ctx(data, ctx)
    }

    fn run_source(
        &self,
        source: &super::DataSource,
        ctx: &RunCtx<'_>,
    ) -> anyhow::Result<RunReport> {
        crate::coordinator::hybrid::run_source_ctx(source, ctx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Trace;

    #[test]
    fn builtins_registered() {
        for name in ["baseline", "cocoa+", "passcode", "hybrid-dca"] {
            assert!(engine(name).is_some(), "{name} missing");
        }
        assert!(engine_names().len() >= 4);
    }

    #[test]
    fn alias_lookup() {
        for alias in ["Hybrid-DCA", "hybrid", "cocoa", "CoCoA+", "dca", "sdca"] {
            assert!(engine(alias).is_some(), "{alias} unresolved");
        }
        assert!(engine("sgd").is_none());
        assert!(resolve("sgd").is_err());
    }

    #[test]
    fn custom_engine_plugs_in() {
        struct Echo;
        impl SolverEngine for Echo {
            fn name(&self) -> &str {
                "echo-test"
            }
            fn run(&self, data: &Dataset, _ctx: &RunCtx<'_>) -> anyhow::Result<RunReport> {
                Ok(RunReport {
                    label: "echo".into(),
                    trace: Trace::new("echo"),
                    events: Vec::new(),
                    alpha: vec![0.0; data.n()],
                    v: vec![0.0; data.d()],
                    rounds: 0,
                    vtime: 0.0,
                    total_updates: 0,
                    worker_rounds: Vec::new(),
                    net: Default::default(),
                    faults: Default::default(),
                    obs: None,
                })
            }
        }
        assert!(register_engine(Arc::new(Echo)).is_none());
        let e = resolve("echo-test").unwrap();
        let data = crate::data::synth::Preset::Tiny.generate(&mut crate::util::Rng::new(1));
        let cfg = ExpConfig::default();
        let report = e.run(&data, &RunCtx::silent(&cfg)).unwrap();
        assert_eq!(report.label, "echo");
    }
}
