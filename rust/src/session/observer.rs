//! Streaming observers: watch a run as it happens instead of waiting
//! for the final [`RunReport`](crate::coordinator::RunReport).
//!
//! Engines call back on three channels, every one of which can stop
//! the run by returning [`ControlFlow::Break`]:
//!
//! * [`Observer::on_round`] — after every global round (communication
//!   round for distributed engines, `H`-update epoch for single-node
//!   ones);
//! * [`Observer::on_merge`] — after every master merge (Algorithm 2's
//!   `v ← v + νΣΔv`; distributed engines only);
//! * [`Observer::on_eval`] — whenever objectives are evaluated (the
//!   `eval_every` cadence), with the full [`TracePoint`].
//!
//! A `Break` is honored at the next stopping point: the engine winds
//! down exactly as if the gap threshold had been reached, so the
//! returned report is complete and internally consistent.

use std::io::Write;
use std::ops::ControlFlow;
use std::sync::Mutex;

use crate::coordinator::MergeEvent;
use crate::metrics::TracePoint;

/// Per-round progress (cheap; emitted even between evaluations).
#[derive(Debug, Clone, PartialEq)]
pub struct RoundEvent {
    /// Global round just completed (1-based).
    pub round: usize,
    /// Virtual cluster time at the end of the round.
    pub vtime: f64,
    /// Cumulative coordinate updates merged so far.
    pub updates: u64,
}

/// An objective evaluation along the run.
#[derive(Debug, Clone, PartialEq)]
pub struct EvalEvent {
    /// The trace point just recorded (round, times, gap, objectives).
    pub point: TracePoint,
}

/// Streaming callback surface for a solver run.
///
/// All methods default to "keep going"; implement only what you need.
pub trait Observer {
    fn on_round(&mut self, _ev: &RoundEvent) -> ControlFlow<()> {
        ControlFlow::Continue(())
    }

    fn on_merge(&mut self, _ev: &MergeEvent) -> ControlFlow<()> {
        ControlFlow::Continue(())
    }

    fn on_eval(&mut self, _ev: &EvalEvent) -> ControlFlow<()> {
        ControlFlow::Continue(())
    }
}

/// Shared handle the engines thread through the coordinator layers.
///
/// Wraps the caller's `&mut dyn Observer` behind a `Mutex` so the
/// driver (which owns worker threads) can hold it by shared reference;
/// callbacks only ever fire from the coordinating thread.
pub struct ObserverHandle<'a> {
    inner: Mutex<Option<&'a mut dyn Observer>>,
}

impl<'a> ObserverHandle<'a> {
    pub fn new(obs: &'a mut dyn Observer) -> Self {
        Self { inner: Mutex::new(Some(obs)) }
    }

    /// A handle that observes nothing and never stops the run.
    pub fn silent() -> Self {
        Self { inner: Mutex::new(None) }
    }

    pub fn on_round(&self, ev: &RoundEvent) -> ControlFlow<()> {
        match self.inner.lock().expect("observer poisoned").as_mut() {
            Some(obs) => obs.on_round(ev),
            None => ControlFlow::Continue(()),
        }
    }

    pub fn on_merge(&self, ev: &MergeEvent) -> ControlFlow<()> {
        match self.inner.lock().expect("observer poisoned").as_mut() {
            Some(obs) => obs.on_merge(ev),
            None => ControlFlow::Continue(()),
        }
    }

    pub fn on_eval(&self, ev: &EvalEvent) -> ControlFlow<()> {
        match self.inner.lock().expect("observer poisoned").as_mut() {
            Some(obs) => obs.on_eval(ev),
            None => ControlFlow::Continue(()),
        }
    }
}

/// Observes nothing (the engines' default).
#[derive(Debug, Clone, Copy, Default)]
pub struct NullObserver;

impl Observer for NullObserver {}

/// Prints each evaluation as a table row while the solver runs —
/// the CLI's live trace.
#[derive(Debug, Default)]
pub struct PrintObserver {
    printed_header: bool,
}

impl PrintObserver {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Observer for PrintObserver {
    fn on_eval(&mut self, ev: &EvalEvent) -> ControlFlow<()> {
        if !self.printed_header {
            println!("round      wall(s)      virt(s)          gap");
            self.printed_header = true;
        }
        let p = &ev.point;
        println!(
            "{:>5} {:>12.4} {:>12.6} {:>12.4e}",
            p.round, p.wall_secs, p.virt_secs, p.gap
        );
        ControlFlow::Continue(())
    }
}

/// Streams evaluation points to a CSV sink incrementally (same schema
/// as [`Trace::csv_header`](crate::metrics::Trace::csv_header)), so a
/// long run's trace survives a crash or an early stop.
pub struct CsvStreamObserver<W: Write> {
    w: W,
    label: String,
    /// First write error, if any (the run is stopped when one occurs).
    pub error: Option<std::io::Error>,
}

impl<W: Write> CsvStreamObserver<W> {
    /// Write the header immediately; rows follow per evaluation.
    pub fn new(mut w: W, label: impl Into<String>) -> std::io::Result<Self> {
        writeln!(w, "{}", crate::metrics::Trace::csv_header())?;
        Ok(Self { w, label: label.into(), error: None })
    }

    pub fn into_inner(self) -> W {
        self.w
    }
}

impl<W: Write> Observer for CsvStreamObserver<W> {
    fn on_eval(&mut self, ev: &EvalEvent) -> ControlFlow<()> {
        let res = ev
            .point
            .write_csv_row(&mut self.w, &self.label)
            .and_then(|_| self.w.flush());
        match res {
            Ok(()) => ControlFlow::Continue(()),
            Err(e) => {
                self.error = Some(e);
                ControlFlow::Break(())
            }
        }
    }
}

/// Early-stopping conditions evaluated on the streaming channels.
#[derive(Debug, Clone, Default)]
pub struct EarlyStop {
    gap_below: Option<f64>,
    after_rounds: Option<usize>,
    after_merges: Option<usize>,
    merges_seen: usize,
}

impl EarlyStop {
    /// Stop once an evaluation reports a gap ≤ `g`.
    pub fn at_gap(g: f64) -> Self {
        Self { gap_below: Some(g), ..Self::default() }
    }

    /// Stop once `n` global rounds have completed.
    pub fn after_rounds(n: usize) -> Self {
        Self { after_rounds: Some(n), ..Self::default() }
    }

    /// Stop once `n` master merges have been observed.
    pub fn after_merges(n: usize) -> Self {
        Self { after_merges: Some(n), ..Self::default() }
    }
}

impl Observer for EarlyStop {
    fn on_round(&mut self, ev: &RoundEvent) -> ControlFlow<()> {
        match self.after_rounds {
            Some(n) if ev.round >= n => ControlFlow::Break(()),
            _ => ControlFlow::Continue(()),
        }
    }

    fn on_merge(&mut self, _ev: &MergeEvent) -> ControlFlow<()> {
        self.merges_seen += 1;
        match self.after_merges {
            Some(n) if self.merges_seen >= n => ControlFlow::Break(()),
            _ => ControlFlow::Continue(()),
        }
    }

    fn on_eval(&mut self, ev: &EvalEvent) -> ControlFlow<()> {
        match self.gap_below {
            Some(g) if ev.point.gap <= g => ControlFlow::Break(()),
            _ => ControlFlow::Continue(()),
        }
    }
}

/// Fan out to two observers; the run stops if either asks to.
pub struct Chain<A: Observer, B: Observer>(pub A, pub B);

impl<A: Observer, B: Observer> Observer for Chain<A, B> {
    fn on_round(&mut self, ev: &RoundEvent) -> ControlFlow<()> {
        let a = self.0.on_round(ev);
        let b = self.1.on_round(ev);
        if a.is_break() || b.is_break() {
            ControlFlow::Break(())
        } else {
            ControlFlow::Continue(())
        }
    }

    fn on_merge(&mut self, ev: &MergeEvent) -> ControlFlow<()> {
        let a = self.0.on_merge(ev);
        let b = self.1.on_merge(ev);
        if a.is_break() || b.is_break() {
            ControlFlow::Break(())
        } else {
            ControlFlow::Continue(())
        }
    }

    fn on_eval(&mut self, ev: &EvalEvent) -> ControlFlow<()> {
        let a = self.0.on_eval(ev);
        let b = self.1.on_eval(ev);
        if a.is_break() || b.is_break() {
            ControlFlow::Break(())
        } else {
            ControlFlow::Continue(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(round: usize, gap: f64) -> TracePoint {
        TracePoint {
            round,
            wall_secs: 0.0,
            virt_secs: round as f64,
            gap,
            primal: 1.0,
            dual: 1.0 - gap,
            updates: 10 * round as u64,
        }
    }

    #[test]
    fn early_stop_at_gap() {
        let mut obs = EarlyStop::at_gap(1e-3);
        assert!(obs.on_eval(&EvalEvent { point: point(1, 1e-2) }).is_continue());
        assert!(obs.on_eval(&EvalEvent { point: point(2, 1e-4) }).is_break());
    }

    #[test]
    fn early_stop_after_rounds() {
        let mut obs = EarlyStop::after_rounds(3);
        for r in 1..3 {
            assert!(obs
                .on_round(&RoundEvent { round: r, vtime: 0.0, updates: 0 })
                .is_continue());
        }
        assert!(obs.on_round(&RoundEvent { round: 3, vtime: 0.0, updates: 0 }).is_break());
    }

    #[test]
    fn csv_stream_writes_rows() {
        let buf: Vec<u8> = Vec::new();
        let mut obs = CsvStreamObserver::new(buf, "x").unwrap();
        assert!(obs.on_eval(&EvalEvent { point: point(0, 1.0) }).is_continue());
        let s = String::from_utf8(obs.into_inner()).unwrap();
        assert!(s.starts_with(crate::metrics::Trace::csv_header()));
        assert_eq!(s.lines().count(), 2);
        assert!(s.lines().nth(1).unwrap().starts_with("x,0,"));
    }

    #[test]
    fn silent_handle_never_breaks() {
        let h = ObserverHandle::silent();
        assert!(h.on_round(&RoundEvent { round: 1, vtime: 0.0, updates: 0 }).is_continue());
        assert!(h.on_eval(&EvalEvent { point: point(1, 0.5) }).is_continue());
    }

    #[test]
    fn chain_breaks_if_either_breaks() {
        let mut obs = Chain(NullObserver, EarlyStop::after_rounds(1));
        assert!(obs.on_round(&RoundEvent { round: 1, vtime: 0.0, updates: 0 }).is_break());
    }
}
