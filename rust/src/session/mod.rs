//! The public solver API: typed sessions, pluggable engines, streaming
//! observers.
//!
//! The paper's contribution is a *family* of coordination schemes —
//! Baseline, PassCoDe, CoCoA+, and Hybrid-DCA are points in one
//! configuration space (cluster shape × merge policy). This module
//! makes that space the API:
//!
//! * [`Session`] — a validated experiment description, decomposed into
//!   the paper's natural sub-configs ([`ProblemSpec`], [`ClusterShape`],
//!   [`LocalCfg`], [`MasterCfg`], [`RunControl`], [`SimCfg`]) and built
//!   through [`SessionBuilder`] with errors that name the violated
//!   paper constraint (S ≤ K, Γ ≥ 1, σ ≥ νS, …).
//! * [`SolverEngine`] — an object-safe trait + registry
//!   ([`register_engine`], [`engine`]) so new algorithms plug in
//!   without touching any dispatcher.
//! * [`Observer`] — streaming callbacks (`on_round` / `on_merge` /
//!   `on_eval` → [`std::ops::ControlFlow`]) threaded through the
//!   coordinator so callers can watch convergence live, log traces
//!   incrementally, and early-stop.
//!
//! ```no_run
//! use hybrid_dca::prelude::*;
//!
//! let data = Preset::Tiny.generate(&mut Rng::new(42));
//! let session = Session::builder()
//!     .lambda(1e-2)
//!     .cluster(4, 2)
//!     .barrier(3)
//!     .delay(2)
//!     .build()
//!     .unwrap();
//! let report = session.run("hybrid-dca", &data).unwrap();
//! # let _ = report;
//! ```

mod engine;
pub mod observer;

pub use engine::{
    canonical_name, engine, engine_names, register_engine, resolve, RunCtx, SolverEngine,
};
pub use observer::{
    Chain, CsvStreamObserver, EarlyStop, EvalEvent, NullObserver, Observer, ObserverHandle,
    PrintObserver, RoundEvent,
};

use crate::config::{ExpConfig, MergePolicy, SigmaPolicy};
use crate::coordinator::RunReport;
use crate::data::{Dataset, Strategy};
use crate::loss::LossKind;
use crate::transport::TransportCfg;

/// Which data the session runs on (preset name, LIBSVM path, or a
/// packed shard store) and the root RNG seed.
#[derive(Debug, Clone, PartialEq)]
pub struct DataSpec {
    /// Synthetic preset name; ignored when `path` or `store` is set.
    pub dataset: String,
    /// LIBSVM file path (overrides `dataset`).
    pub path: Option<String>,
    /// Shard-store directory (`store::pack` output; overrides both).
    pub store: Option<String>,
    pub seed: u64,
}

/// Where a session's dataset physically lives — the seam between
/// in-memory workloads (presets, LIBSVM files read whole) and the
/// out-of-core shard store. Multi-node engines partition a sharded
/// source on its shard boundaries, so node `k` trains on its own
/// packed shards in disk order.
#[derive(Debug, Clone)]
pub enum DataSource {
    InMemory(Dataset),
    Sharded(crate::store::ShardedDataset),
}

impl DataSource {
    /// Number of data points.
    pub fn n(&self) -> usize {
        match self {
            DataSource::InMemory(ds) => ds.n(),
            DataSource::Sharded(s) => s.n(),
        }
    }

    /// Feature dimension.
    pub fn d(&self) -> usize {
        match self {
            DataSource::InMemory(ds) => ds.d(),
            DataSource::Sharded(s) => s.d(),
        }
    }

    /// Total nonzeros.
    pub fn nnz(&self) -> usize {
        match self {
            DataSource::InMemory(ds) => ds.x.nnz(),
            DataSource::Sharded(s) => s.nnz(),
        }
    }

    pub fn name(&self) -> &str {
        match self {
            DataSource::InMemory(ds) => &ds.name,
            DataSource::Sharded(s) => s.name(),
        }
    }

    /// Shard row spans when sharded (the partition seam), else `None`.
    pub fn shard_spans(&self) -> Option<Vec<(usize, usize)>> {
        match self {
            DataSource::InMemory(_) => None,
            DataSource::Sharded(s) => Some(s.spans()),
        }
    }

    /// A flat [`Dataset`] view: borrowed for in-memory sources,
    /// materialized (all shards, disk order) for sharded ones.
    pub fn as_dataset(&self) -> anyhow::Result<std::borrow::Cow<'_, Dataset>> {
        match self {
            DataSource::InMemory(ds) => Ok(std::borrow::Cow::Borrowed(ds)),
            DataSource::Sharded(s) => Ok(std::borrow::Cow::Owned(s.materialize()?)),
        }
    }

    /// Consume into a flat [`Dataset`].
    pub fn into_dataset(self) -> anyhow::Result<Dataset> {
        match self {
            DataSource::InMemory(ds) => Ok(ds),
            DataSource::Sharded(s) => s.materialize(),
        }
    }
}

/// The optimization problem: loss φ and regularization λ.
#[derive(Debug, Clone, PartialEq)]
pub struct ProblemSpec {
    pub loss: LossKind,
    pub lambda: f64,
}

/// The simulated cluster: K nodes × R cores, data partition, and
/// optional per-node straggler multipliers.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterShape {
    pub k_nodes: usize,
    pub r_cores: usize,
    pub partition: Strategy,
    /// Per-node slowdown multipliers (empty = homogeneous 1.0).
    pub stragglers: Vec<f64>,
}

/// The local solver (Algorithm 1): H iterations per core per round,
/// aggregation ν, subproblem scaling σ, and the wild/atomic switch.
#[derive(Debug, Clone, PartialEq)]
pub struct LocalCfg {
    pub h_local: usize,
    pub nu: f64,
    pub sigma: SigmaPolicy,
    pub wild: bool,
}

/// The master (Algorithm 2): bounded barrier S, bounded delay Γ, and
/// the merge-order policy.
#[derive(Debug, Clone, PartialEq)]
pub struct MasterCfg {
    pub s_barrier: usize,
    pub gamma: usize,
    pub policy: MergePolicy,
}

/// Run control: round budget, stopping gap, and evaluation cadence.
#[derive(Debug, Clone, PartialEq)]
pub struct RunControl {
    pub max_rounds: usize,
    pub gap_threshold: f64,
    pub eval_every: usize,
}

/// The virtual-clock cost model (DESIGN.md §3) plus the Δv wire
/// format policy.
#[derive(Debug, Clone, PartialEq)]
pub struct SimCfg {
    pub net_latency: f64,
    pub net_per_elem: f64,
    pub cost_per_nnz: f64,
    /// Δv density threshold: send sparse when the touched-coordinate
    /// fraction is ≤ this (0 forces dense, 1 forces sparse).
    pub delta_threshold: f64,
}

/// A validated experiment description — the typed replacement for the
/// monolithic [`ExpConfig`]. Construct through [`Session::builder`];
/// every instance has passed the paper's parameter constraints.
#[derive(Debug, Clone, PartialEq)]
pub struct Session {
    pub data: DataSpec,
    pub problem: ProblemSpec,
    pub cluster: ClusterShape,
    pub local: LocalCfg,
    pub master: MasterCfg,
    pub control: RunControl,
    pub sim: SimCfg,
    /// Cross-node transport (`[transport]` table): in-process channels
    /// by default, TCP/UDS for multi-process runs.
    pub transport: TransportCfg,
}

impl Session {
    pub fn builder() -> SessionBuilder {
        SessionBuilder::default()
    }

    /// Lift a legacy flat config into a typed session. Accepts exactly
    /// what [`ExpConfig::validate`] accepts (including deliberately
    /// unsafe fixed σ, which ablations use).
    pub fn from_exp_config(cfg: &ExpConfig) -> anyhow::Result<Session> {
        let mut b = Session::builder()
            .dataset(&cfg.dataset)
            .seed(cfg.seed)
            .loss(cfg.loss)
            .lambda(cfg.lambda)
            .cluster(cfg.k_nodes, cfg.r_cores)
            .partition(cfg.partition)
            .stragglers(cfg.stragglers.clone())
            .local_iters(cfg.h_local)
            .nu(cfg.nu)
            .sigma(cfg.sigma)
            .allow_unsafe_sigma()
            .wild(cfg.wild)
            .barrier(cfg.s_barrier)
            .delay(cfg.gamma)
            .merge_policy(cfg.merge_policy)
            .rounds(cfg.max_rounds)
            .gap_threshold(cfg.gap_threshold)
            .eval_every(cfg.eval_every)
            .net_latency(cfg.net_latency)
            .net_per_elem(cfg.net_per_elem)
            .cost_per_nnz(cfg.cost_per_nnz)
            .delta_threshold(cfg.delta_threshold)
            .transport(cfg.transport.clone());
        if let Some(p) = &cfg.data_path {
            b = b.data_path(p);
        }
        if let Some(s) = &cfg.store_path {
            b = b.store_dir(s);
        }
        b.build()
    }

    /// Flatten back to the engine-facing legacy config. Round-trips:
    /// `Session::from_exp_config(&c)?.to_exp_config() == c` for any
    /// valid `c`.
    pub fn to_exp_config(&self) -> ExpConfig {
        ExpConfig {
            dataset: self.data.dataset.clone(),
            data_path: self.data.path.clone(),
            store_path: self.data.store.clone(),
            seed: self.data.seed,
            loss: self.problem.loss,
            lambda: self.problem.lambda,
            k_nodes: self.cluster.k_nodes,
            r_cores: self.cluster.r_cores,
            partition: self.cluster.partition,
            h_local: self.local.h_local,
            nu: self.local.nu,
            sigma: self.local.sigma,
            wild: self.local.wild,
            s_barrier: self.master.s_barrier,
            gamma: self.master.gamma,
            merge_policy: self.master.policy,
            max_rounds: self.control.max_rounds,
            gap_threshold: self.control.gap_threshold,
            eval_every: self.control.eval_every,
            stragglers: self.cluster.stragglers.clone(),
            net_latency: self.sim.net_latency,
            net_per_elem: self.sim.net_per_elem,
            cost_per_nnz: self.sim.cost_per_nnz,
            delta_threshold: self.sim.delta_threshold,
            transport: self.transport.clone(),
        }
    }

    /// Run an engine from the registry with no observer.
    pub fn run(&self, engine_name: &str, data: &Dataset) -> anyhow::Result<RunReport> {
        let engine = engine::resolve(engine_name)?;
        let cfg = self.to_exp_config();
        engine.run(data, &RunCtx::silent(&cfg))
    }

    /// Run an engine from the registry, streaming progress to `obs`.
    pub fn run_observed(
        &self,
        engine_name: &str,
        data: &Dataset,
        obs: &mut dyn Observer,
    ) -> anyhow::Result<RunReport> {
        let engine = engine::resolve(engine_name)?;
        let cfg = self.to_exp_config();
        engine.run(data, &RunCtx::new(&cfg, obs))
    }

    /// Run with explicit shard spans (the CLI's `--store` path uses
    /// this after materializing once): multi-node engines partition on
    /// the spans instead of re-slicing `0..n`.
    pub fn run_with_shards(
        &self,
        engine_name: &str,
        data: &Dataset,
        shards: Option<Vec<(usize, usize)>>,
        obs: &mut dyn Observer,
    ) -> anyhow::Result<RunReport> {
        let engine = engine::resolve(engine_name)?;
        let cfg = self.to_exp_config();
        let mut ctx = RunCtx::new(&cfg, obs);
        if let Some(spans) = shards {
            ctx = ctx.with_shards(spans);
        }
        engine.run(data, &ctx)
    }

    /// Run an engine against a [`DataSource`], silent. Sharded sources
    /// carry their spans into the engine so the node partition follows
    /// shard boundaries, and multi-node engines stream shards instead
    /// of materializing the dataset
    /// ([`SolverEngine::run_source`](engine::SolverEngine::run_source)).
    pub fn run_source(&self, engine_name: &str, source: &DataSource) -> anyhow::Result<RunReport> {
        let engine = engine::resolve(engine_name)?;
        let cfg = self.to_exp_config();
        let mut ctx = RunCtx::silent(&cfg);
        if let Some(spans) = source.shard_spans() {
            ctx = ctx.with_shards(spans);
        }
        engine.run_source(source, &ctx)
    }

    /// [`Self::run_source`] streaming progress to `obs`.
    pub fn run_source_observed(
        &self,
        engine_name: &str,
        source: &DataSource,
        obs: &mut dyn Observer,
    ) -> anyhow::Result<RunReport> {
        let engine = engine::resolve(engine_name)?;
        let cfg = self.to_exp_config();
        let mut ctx = RunCtx::new(&cfg, obs);
        if let Some(spans) = source.shard_spans() {
            ctx = ctx.with_shards(spans);
        }
        engine.run_source(source, &ctx)
    }

    /// Resolve the session's dataset (preset, LIBSVM file, or shard
    /// store — the latter materialized flat; use [`Self::load_source`]
    /// to keep the sharded structure).
    pub fn load_dataset(&self) -> anyhow::Result<Dataset> {
        crate::harness::load_dataset(&self.to_exp_config())
    }

    /// Resolve the session's data as a [`DataSource`]: a shard store
    /// opens lazily (manifest only), everything else loads in memory.
    pub fn load_source(&self) -> anyhow::Result<DataSource> {
        if let Some(dir) = &self.data.store {
            return Ok(DataSource::Sharded(crate::store::open(dir)?));
        }
        Ok(DataSource::InMemory(self.load_dataset()?))
    }
}

/// Builder for [`Session`] with the paper's defaults; `build()`
/// validates every constraint and names the one violated.
#[derive(Debug, Clone)]
pub struct SessionBuilder {
    data: DataSpec,
    problem: ProblemSpec,
    cluster: ClusterShape,
    local: LocalCfg,
    master: MasterCfg,
    control: RunControl,
    sim: SimCfg,
    transport: TransportCfg,
    allow_unsafe_sigma: bool,
    /// Whether `barrier()` was called; only a *default* barrier tracks
    /// the cluster size in `cluster()`.
    barrier_explicit: bool,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        let d = ExpConfig::default();
        Self {
            data: DataSpec {
                dataset: d.dataset,
                path: d.data_path,
                store: d.store_path,
                seed: d.seed,
            },
            problem: ProblemSpec { loss: d.loss, lambda: d.lambda },
            cluster: ClusterShape {
                k_nodes: d.k_nodes,
                r_cores: d.r_cores,
                partition: d.partition,
                stragglers: d.stragglers,
            },
            local: LocalCfg { h_local: d.h_local, nu: d.nu, sigma: d.sigma, wild: d.wild },
            master: MasterCfg {
                s_barrier: d.s_barrier,
                gamma: d.gamma,
                policy: d.merge_policy,
            },
            control: RunControl {
                max_rounds: d.max_rounds,
                gap_threshold: d.gap_threshold,
                eval_every: d.eval_every,
            },
            sim: SimCfg {
                net_latency: d.net_latency,
                net_per_elem: d.net_per_elem,
                cost_per_nnz: d.cost_per_nnz,
                delta_threshold: d.delta_threshold,
            },
            transport: d.transport,
            allow_unsafe_sigma: false,
            barrier_explicit: false,
        }
    }
}

impl SessionBuilder {
    // ---- data ----
    pub fn dataset(mut self, name: &str) -> Self {
        self.data.dataset = name.to_string();
        self
    }

    pub fn data_path(mut self, path: &str) -> Self {
        self.data.path = Some(path.to_string());
        self
    }

    /// Train from a packed shard store (`store::pack` output) instead
    /// of a preset or LIBSVM file.
    pub fn store_dir(mut self, dir: &str) -> Self {
        self.data.store = Some(dir.to_string());
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.data.seed = seed;
        self
    }

    // ---- problem ----
    pub fn loss(mut self, loss: LossKind) -> Self {
        self.problem.loss = loss;
        self
    }

    pub fn lambda(mut self, lambda: f64) -> Self {
        self.problem.lambda = lambda;
        self
    }

    // ---- cluster shape ----
    /// K worker nodes × R cores per node. The *default* barrier
    /// follows the cluster down (so `cluster(2, 1)` alone is valid);
    /// an explicitly set `barrier()` is never silently changed —
    /// `build()` reports the S ≤ K violation instead.
    pub fn cluster(mut self, k_nodes: usize, r_cores: usize) -> Self {
        self.cluster.k_nodes = k_nodes;
        self.cluster.r_cores = r_cores;
        if !self.barrier_explicit {
            self.master.s_barrier = self.master.s_barrier.min(k_nodes.max(1));
        }
        self
    }

    pub fn partition(mut self, strategy: Strategy) -> Self {
        self.cluster.partition = strategy;
        self
    }

    /// Per-node slowdown multipliers (one per node, each ≥ 1.0); an
    /// empty vec means a homogeneous cluster.
    pub fn stragglers(mut self, multipliers: Vec<f64>) -> Self {
        self.cluster.stragglers = multipliers;
        self
    }

    // ---- local solver (Algorithm 1) ----
    /// Local iterations per core per round (the paper's H).
    pub fn local_iters(mut self, h: usize) -> Self {
        self.local.h_local = h;
        self
    }

    /// Aggregation parameter ν ∈ (0, 1].
    pub fn nu(mut self, nu: f64) -> Self {
        self.local.nu = nu;
        self
    }

    pub fn sigma(mut self, sigma: SigmaPolicy) -> Self {
        self.local.sigma = sigma;
        self
    }

    /// Explicit σ (ablations). Values below the Eq. 5 safe region νS
    /// are rejected by `build()` unless [`Self::allow_unsafe_sigma`].
    pub fn sigma_fixed(mut self, sigma: f64) -> Self {
        self.local.sigma = SigmaPolicy::Fixed(sigma);
        self
    }

    /// Permit a fixed σ below νS (divergence ablations).
    pub fn allow_unsafe_sigma(mut self) -> Self {
        self.allow_unsafe_sigma = true;
        self
    }

    /// Racy (PassCoDe-Wild) updates instead of lock-free atomics.
    pub fn wild(mut self, wild: bool) -> Self {
        self.local.wild = wild;
        self
    }

    // ---- master (Algorithm 2) ----
    /// Bounded-barrier size S: merge as soon as S of K workers report.
    pub fn barrier(mut self, s: usize) -> Self {
        self.master.s_barrier = s;
        self.barrier_explicit = true;
        self
    }

    /// Bounded delay Γ: no worker's update may go unmerged for more
    /// than Γ global rounds.
    pub fn delay(mut self, gamma: usize) -> Self {
        self.master.gamma = gamma;
        self
    }

    pub fn merge_policy(mut self, policy: MergePolicy) -> Self {
        self.master.policy = policy;
        self
    }

    // ---- run control ----
    pub fn rounds(mut self, max_rounds: usize) -> Self {
        self.control.max_rounds = max_rounds;
        self
    }

    pub fn gap_threshold(mut self, threshold: f64) -> Self {
        self.control.gap_threshold = threshold;
        self
    }

    /// Evaluate objectives every `n` rounds (n ≥ 1).
    pub fn eval_every(mut self, n: usize) -> Self {
        self.control.eval_every = n;
        self
    }

    // ---- simulation ----
    pub fn net_latency(mut self, secs: f64) -> Self {
        self.sim.net_latency = secs;
        self
    }

    pub fn net_per_elem(mut self, secs: f64) -> Self {
        self.sim.net_per_elem = secs;
        self
    }

    pub fn cost_per_nnz(mut self, secs: f64) -> Self {
        self.sim.cost_per_nnz = secs;
        self
    }

    /// Δv wire-format density threshold in [0, 1]: workers send their
    /// round delta sparse when the touched fraction is ≤ this (0
    /// forces dense, 1 forces sparse). The merged arithmetic is
    /// identical either way; with `net_per_elem > 0` the virtual-clock
    /// schedule reflects the (smaller) sparse wire size.
    pub fn delta_threshold(mut self, threshold: f64) -> Self {
        self.sim.delta_threshold = threshold;
        self
    }

    // ---- transport ----
    /// Cross-node transport configuration (backend, addresses,
    /// timeouts) for `--distributed` runs.
    pub fn transport(mut self, transport: TransportCfg) -> Self {
        self.transport = transport;
        self
    }

    /// Validate every paper constraint and produce the session. Errors
    /// name the violated constraint and where it comes from.
    pub fn build(self) -> anyhow::Result<Session> {
        let Self {
            data,
            problem,
            cluster,
            local,
            master,
            control,
            sim,
            transport,
            allow_unsafe_sigma,
            barrier_explicit: _,
        } = self;

        anyhow::ensure!(
            !(data.path.is_some() && data.store.is_some()),
            "DataSpec: a LIBSVM path and a shard store are mutually exclusive"
        );
        anyhow::ensure!(
            problem.lambda > 0.0,
            "ProblemSpec: regularization λ must be > 0 (got {})",
            problem.lambda
        );
        anyhow::ensure!(cluster.k_nodes >= 1, "ClusterShape: K must be ≥ 1 (got 0 nodes)");
        anyhow::ensure!(cluster.r_cores >= 1, "ClusterShape: R must be ≥ 1 (got 0 cores)");
        if !cluster.stragglers.is_empty() {
            anyhow::ensure!(
                cluster.stragglers.len() == cluster.k_nodes,
                "ClusterShape: stragglers must have one multiplier per node \
                 ({} multipliers for K={} nodes)",
                cluster.stragglers.len(),
                cluster.k_nodes
            );
            anyhow::ensure!(
                cluster.stragglers.iter().all(|&s| s >= 1.0),
                "ClusterShape: straggler multipliers are slowdowns and must be ≥ 1.0"
            );
        }

        anyhow::ensure!(
            local.h_local >= 1,
            "LocalCfg: H must be ≥ 1 (Algorithm 1 runs H local iterations per core)"
        );
        anyhow::ensure!(
            local.nu > 0.0 && local.nu <= 1.0,
            "LocalCfg: aggregation ν must be in (0, 1] (Lemma 3.2, Ma et al. 2015b; got {})",
            local.nu
        );

        anyhow::ensure!(
            (1..=cluster.k_nodes).contains(&master.s_barrier),
            "MasterCfg: bounded barrier must satisfy 1 ≤ S ≤ K (Algorithm 2; got S={}, K={})",
            master.s_barrier,
            cluster.k_nodes
        );
        anyhow::ensure!(
            master.gamma >= 1,
            "MasterCfg: bounded delay must satisfy Γ ≥ 1 (Algorithm 2; got Γ=0)"
        );

        let sigma = local.sigma.value(local.nu, master.s_barrier, cluster.k_nodes);
        anyhow::ensure!(sigma > 0.0, "LocalCfg: σ must be > 0 (got σ={sigma})");
        if let SigmaPolicy::Fixed(v) = local.sigma {
            let safe = local.nu * master.s_barrier as f64;
            anyhow::ensure!(
                allow_unsafe_sigma || v >= safe,
                "LocalCfg: fixed σ={v} is below the safe region σ ≥ νS = {safe} \
                 (Eq. 5 with Lemma 3.2's choice); call allow_unsafe_sigma() \
                 if this is a deliberate divergence ablation"
            );
        }

        anyhow::ensure!(
            control.max_rounds >= 1,
            "RunControl: max_rounds must be ≥ 1 (got 0)"
        );
        anyhow::ensure!(
            control.gap_threshold > 0.0,
            "RunControl: gap_threshold must be > 0 (got {})",
            control.gap_threshold
        );
        anyhow::ensure!(
            control.eval_every >= 1,
            "RunControl: eval_every must be ≥ 1 (got 0 — the trace would never be sampled)"
        );

        anyhow::ensure!(
            sim.net_latency >= 0.0 && sim.net_per_elem >= 0.0 && sim.cost_per_nnz >= 0.0,
            "SimCfg: virtual-clock costs must be ≥ 0"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&sim.delta_threshold),
            "SimCfg: delta_threshold is a density fraction and must be in [0, 1] (got {})",
            sim.delta_threshold
        );

        let session = Session { data, problem, cluster, local, master, control, sim, transport };
        // Drift backstop: the checks above are the named-subconfig
        // versions of `ExpConfig::validate`; delegating the flattened
        // config back through it guarantees a built Session is never
        // more permissive than what the engines accept, even if a
        // constraint is later added only to `validate`.
        session.to_exp_config().validate()?;
        Ok(session)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_builder_is_valid() {
        let s = Session::builder().build().unwrap();
        assert_eq!(s.cluster.k_nodes, 4);
        assert_eq!(s.master.s_barrier, 4);
    }

    #[test]
    fn readme_builder_shape() {
        let s = Session::builder()
            .cluster(16, 8)
            .barrier(4)
            .delay(2)
            .build()
            .unwrap();
        assert_eq!(s.cluster.k_nodes, 16);
        assert_eq!(s.cluster.r_cores, 8);
        assert_eq!(s.master.s_barrier, 4);
        assert_eq!(s.master.gamma, 2);
    }

    #[test]
    fn barrier_above_k_rejected_with_named_constraint() {
        let err = Session::builder().cluster(4, 2).barrier(5).build().unwrap_err();
        assert!(err.to_string().contains("1 ≤ S ≤ K"), "{err}");
    }

    #[test]
    fn gamma_zero_rejected() {
        let err = Session::builder().delay(0).build().unwrap_err();
        assert!(err.to_string().contains("Γ ≥ 1"), "{err}");
    }

    #[test]
    fn nu_out_of_range_rejected() {
        for bad in [0.0, -0.5, 1.5] {
            let err = Session::builder().nu(bad).build().unwrap_err();
            assert!(err.to_string().contains("(0, 1]"), "{err}");
        }
    }

    #[test]
    fn unsafe_fixed_sigma_needs_opt_in() {
        // νS = 4 by default; σ = 0.25 is in the divergence region.
        let err = Session::builder().sigma_fixed(0.25).build().unwrap_err();
        assert!(err.to_string().contains("σ ≥ νS"), "{err}");
        let s = Session::builder()
            .sigma_fixed(0.25)
            .allow_unsafe_sigma()
            .build()
            .unwrap();
        assert_eq!(s.local.sigma, SigmaPolicy::Fixed(0.25));
        // Non-positive σ is rejected even with the opt-in.
        let err = Session::builder()
            .sigma_fixed(-1.0)
            .allow_unsafe_sigma()
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("σ must be > 0"), "{err}");
    }

    #[test]
    fn straggler_length_mismatch_rejected() {
        let err = Session::builder()
            .cluster(4, 1)
            .stragglers(vec![1.0, 2.0])
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("one multiplier per node"), "{err}");
    }

    #[test]
    fn straggler_below_one_rejected() {
        let err = Session::builder()
            .cluster(2, 1)
            .stragglers(vec![1.0, 0.5])
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("≥ 1.0"), "{err}");
    }

    #[test]
    fn eval_every_zero_rejected() {
        let err = Session::builder().eval_every(0).build().unwrap_err();
        assert!(err.to_string().contains("eval_every"), "{err}");
    }

    #[test]
    fn zero_rounds_rejected() {
        let err = Session::builder().rounds(0).build().unwrap_err();
        assert!(err.to_string().contains("max_rounds"), "{err}");
    }

    #[test]
    fn lambda_zero_rejected() {
        let err = Session::builder().lambda(0.0).build().unwrap_err();
        assert!(err.to_string().contains("λ"), "{err}");
    }

    #[test]
    fn delta_threshold_out_of_range_rejected() {
        for bad in [-0.1, 1.5] {
            let err = Session::builder().delta_threshold(bad).build().unwrap_err();
            assert!(err.to_string().contains("delta_threshold"), "{err}");
        }
        let s = Session::builder().delta_threshold(1.0).build().unwrap();
        assert_eq!(s.sim.delta_threshold, 1.0);
    }

    #[test]
    fn exp_config_round_trip() {
        let mut cfg = ExpConfig::default();
        cfg.dataset = "rcv1-s".into();
        cfg.lambda = 1e-3;
        cfg.delta_threshold = 0.75;
        cfg.k_nodes = 6;
        cfg.r_cores = 3;
        cfg.s_barrier = 4;
        cfg.gamma = 7;
        cfg.merge_policy = MergePolicy::NewestFirst;
        cfg.sigma = SigmaPolicy::Fixed(0.5); // unsafe: from_exp_config must accept
        cfg.stragglers = vec![1.0, 1.0, 2.0, 1.0, 4.0, 1.0];
        cfg.eval_every = 3;
        cfg.transport.backend = crate::transport::TransportBackend::Tcp;
        cfg.transport.listen = "127.0.0.1:0".into();
        cfg.transport.read_timeout_secs = 2.0;
        let session = Session::from_exp_config(&cfg).unwrap();
        assert_eq!(session.to_exp_config(), cfg);
    }

    #[test]
    fn store_dir_round_trips_and_excludes_data_path() {
        let s = Session::builder().store_dir("tiny_store").build().unwrap();
        assert_eq!(s.data.store.as_deref(), Some("tiny_store"));
        let cfg = s.to_exp_config();
        assert_eq!(cfg.store_path.as_deref(), Some("tiny_store"));
        assert_eq!(Session::from_exp_config(&cfg).unwrap(), s);
        let err = Session::builder()
            .data_path("x.svm")
            .store_dir("y_store")
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("mutually exclusive"), "{err}");
    }

    #[test]
    fn default_barrier_follows_cluster_down() {
        // No explicit barrier(): the default S adapts to a smaller K.
        let s = Session::builder().cluster(2, 1).build().unwrap();
        assert_eq!(s.master.s_barrier, 2);
    }

    #[test]
    fn explicit_barrier_is_never_silently_clamped() {
        // barrier(4) then cluster(2, 1): the S > K violation must be
        // reported, not papered over.
        let err = Session::builder().barrier(4).cluster(2, 1).build().unwrap_err();
        assert!(err.to_string().contains("1 ≤ S ≤ K"), "{err}");
    }
}
