//! Cluster simulation: virtual clock, compute/network cost model, and
//! straggler (heterogeneity) profiles.
//!
//! **Why a virtual clock** (DESIGN.md §3): the paper's speedup and
//! S/Γ experiments (Figs 4–6) measure wall time on a 16-node × 24-core
//! cluster. This machine has one physical core, so real wall-clock
//! measurements of the threaded run measure *serialization*, not the
//! cluster. Instead, every worker carries a virtual timestamp advanced
//! by a costed model of its work:
//!
//! * one coordinate update on point `i` costs
//!   `cost_per_nnz · nnz(x_i)` seconds on its core, scaled by the
//!   node's straggler multiplier;
//! * a node's round compute time is the **max over its R cores** (cores
//!   run in parallel within a node);
//! * each point-to-point message costs `net_latency`; CoCoA+'s
//!   all-reduce costs `2·net_latency·⌈log₂K + 1⌉` (tree reduction);
//! * the master's merge happens at the max timestamp of the merged
//!   updates (it had to wait for the last of them).
//!
//! The quantity this reproduces is exactly the queueing structure that
//! drives the paper's results: bounded barrier `S` ⇒ the master waits
//! for the S-th fastest worker instead of the slowest; bounded delay
//! `Γ` ⇒ slow workers cannot fall arbitrarily far behind. Real wall
//! time is *also* recorded in every trace for completeness.
//!
//! **Both transport backends bill the same virtual clock.** When the
//! cluster runs over real sockets (`transport::Socket`, `train
//! --distributed`), [`SendCost`] still prices the *simulated* network
//! exactly as in-process — that is what keeps socket runs
//! bitwise-identical to single-process runs. The *actual* bytes moved
//! on the wire are counted separately per peer by
//! [`transport::TransportStats`](crate::transport::TransportStats);
//! socket-only traffic (handshake, `Assign`, `Final` frames) appears
//! in those counters but is never charged to the virtual clock.

use crate::data::Dataset;

/// Compute/network cost model (virtual seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostModel {
    /// Seconds per nonzero touched by one coordinate update. An update
    /// reads `x_i` twice (dot + axpy); the constant absorbs that.
    pub cost_per_nnz: f64,
    /// Fixed latency per point-to-point message.
    pub net_latency: f64,
    /// Seconds per vector element transferred. The paper's messages are
    /// whole `Δv ∈ R^d` / `v ∈ R^d` vectors (§5), so bandwidth matters:
    /// for rcv1 (d = 47k) a message is ~376 KB ≈ 3 ms at 1 Gb/s, about
    /// 0.2× the round compute — the default reproduces that ratio at
    /// our scaled-down d.
    pub net_per_elem: f64,
}

impl CostModel {
    pub fn new(cost_per_nnz: f64, net_latency: f64, net_per_elem: f64) -> Self {
        Self { cost_per_nnz, net_latency, net_per_elem }
    }

    /// Virtual cost of one coordinate update on data point `i`.
    #[inline]
    pub fn update_cost(&self, nnz: usize) -> f64 {
        self.cost_per_nnz * nnz as f64
    }

    /// Cost of one point-to-point message carrying a d-vector.
    #[inline]
    pub fn msg_cost(&self, d: usize) -> f64 {
        self.msg_cost_elems(d as f64)
    }

    /// Cost of one point-to-point message carrying `elems`
    /// f64-equivalent elements — sparse Δv messages ship fewer than
    /// `d` (see [`DeltaV::wire_elems`](crate::coordinator::messages::DeltaV::wire_elems)).
    #[inline]
    pub fn msg_cost_elems(&self, elems: f64) -> f64 {
        self.net_latency + self.net_per_elem * elems
    }

    /// Cost of a synchronous all-reduce of a d-vector across `k` nodes:
    /// ring all-reduce — latency `2·⌈log₂k⌉` hops plus bandwidth
    /// `2·d·(k−1)/k` element transfers (the standard MPI model).
    pub fn allreduce_cost(&self, k: usize, d: usize) -> f64 {
        if k <= 1 {
            return 0.0;
        }
        let hops = (k as f64).log2().ceil().max(1.0);
        2.0 * hops * self.net_latency
            + 2.0 * d as f64 * self.net_per_elem * (k as f64 - 1.0) / k as f64
    }
}

impl Default for CostModel {
    fn default() -> Self {
        Self { cost_per_nnz: 1e-7, net_latency: 1e-4, net_per_elem: 1e-6 }
    }
}

/// Virtual cost of the worker → master send. CoCoA+'s synchronous
/// all-reduce charges a fixed per-round share regardless of payload;
/// Hybrid-DCA's point-to-point messages are billed by their actual
/// wire size, which is what makes the sparse Δv format pay off.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SendCost {
    /// Fixed per-message cost (all-reduce share).
    Fixed(f64),
    /// Billed by wire size through the cost model (point-to-point).
    Sized(CostModel),
}

impl SendCost {
    /// Cost of a message carrying `wire_elems` f64-equivalent elements.
    #[inline]
    pub fn cost(&self, wire_elems: f64) -> f64 {
        match self {
            SendCost::Fixed(c) => *c,
            SendCost::Sized(m) => m.msg_cost_elems(wire_elems),
        }
    }
}

/// Per-update cost lookup table for one dataset (precomputed nnz).
#[derive(Debug, Clone)]
pub struct UpdateCosts {
    costs: Vec<f64>,
}

impl UpdateCosts {
    pub fn precompute(data: &Dataset, model: &CostModel) -> Self {
        let costs = (0..data.n())
            .map(|i| model.update_cost(data.x.row(i).nnz()))
            .collect();
        Self { costs }
    }

    #[inline]
    pub fn cost(&self, i: usize) -> f64 {
        self.costs[i]
    }
}

/// Named heterogeneity profiles for the straggler experiments.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StragglerProfile {
    /// All nodes equal (the paper's Hornet cluster).
    Homogeneous,
    /// One node 4× slower (the classic straggler).
    OneSlow,
    /// Slowdowns ramp linearly from 1× to 3× across nodes.
    LinearRamp,
    /// Alternate 1× / 2× (half the fleet slow).
    HalfSlow,
}

impl StragglerProfile {
    pub fn parse(s: &str) -> Option<StragglerProfile> {
        match s.to_ascii_lowercase().as_str() {
            "homogeneous" | "none" => Some(StragglerProfile::Homogeneous),
            "one-slow" | "oneslow" => Some(StragglerProfile::OneSlow),
            "linear-ramp" | "ramp" => Some(StragglerProfile::LinearRamp),
            "half-slow" | "halfslow" => Some(StragglerProfile::HalfSlow),
            _ => None,
        }
    }

    /// Expand to per-node multipliers.
    pub fn multipliers(self, k: usize) -> Vec<f64> {
        match self {
            StragglerProfile::Homogeneous => vec![1.0; k],
            StragglerProfile::OneSlow => {
                let mut v = vec![1.0; k];
                if k > 0 {
                    v[k - 1] = 4.0;
                }
                v
            }
            StragglerProfile::LinearRamp => (0..k)
                .map(|i| {
                    if k <= 1 {
                        1.0
                    } else {
                        1.0 + 2.0 * i as f64 / (k - 1) as f64
                    }
                })
                .collect(),
            StragglerProfile::HalfSlow => {
                (0..k).map(|i| if i % 2 == 1 { 2.0 } else { 1.0 }).collect()
            }
        }
    }
}

/// Resolve config stragglers: explicit list wins, else homogeneous.
pub fn resolve_stragglers(explicit: &[f64], k: usize) -> Vec<f64> {
    if explicit.is_empty() {
        vec![1.0; k]
    } else {
        assert_eq!(explicit.len(), k, "straggler list length");
        explicit.to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::Preset;
    use crate::util::Rng;

    #[test]
    fn update_costs_scale_with_nnz() {
        let m = CostModel::new(1e-6, 1e-3, 0.0);
        assert!((m.update_cost(10) - 1e-5).abs() < 1e-18);
        assert!(m.update_cost(100) > m.update_cost(10));
    }

    #[test]
    fn msg_cost_scales_with_dimension() {
        let m = CostModel::new(0.0, 1e-4, 1e-6);
        assert!((m.msg_cost(0) - 1e-4).abs() < 1e-15);
        assert!((m.msg_cost(1000) - (1e-4 + 1e-3)).abs() < 1e-12);
    }

    #[test]
    fn send_cost_fixed_vs_sized() {
        let m = CostModel::new(0.0, 1e-4, 1e-6);
        let fixed = SendCost::Fixed(0.5);
        assert_eq!(fixed.cost(10.0), 0.5);
        assert_eq!(fixed.cost(1e6), 0.5);
        let sized = SendCost::Sized(m);
        assert!((sized.cost(1000.0) - (1e-4 + 1e-3)).abs() < 1e-12);
        assert!(sized.cost(3.0) < sized.cost(1000.0));
        // A sparse message with few touched coords is cheaper than the
        // dense d-vector under the sized model.
        assert!(sized.cost(1.5 * 20.0) < m.msg_cost(1000));
    }

    #[test]
    fn allreduce_grows_logarithmically() {
        let m = CostModel::default();
        let c2 = m.allreduce_cost(2, 100);
        let c16 = m.allreduce_cost(16, 100);
        assert!(c16 > c2);
        assert!(c16 < 8.0 * c2, "log not linear");
    }

    #[test]
    fn precomputed_costs_match() {
        let ds = Preset::Tiny.generate(&mut Rng::new(1));
        let m = CostModel::default();
        let u = UpdateCosts::precompute(&ds, &m);
        for i in (0..ds.n()).step_by(17) {
            assert_eq!(u.cost(i), m.update_cost(ds.x.row(i).nnz()));
        }
    }

    #[test]
    fn profiles() {
        assert_eq!(StragglerProfile::Homogeneous.multipliers(3), vec![1.0, 1.0, 1.0]);
        let one = StragglerProfile::OneSlow.multipliers(4);
        assert_eq!(one, vec![1.0, 1.0, 1.0, 4.0]);
        let ramp = StragglerProfile::LinearRamp.multipliers(3);
        assert_eq!(ramp, vec![1.0, 2.0, 3.0]);
        let half = StragglerProfile::HalfSlow.multipliers(4);
        assert_eq!(half, vec![1.0, 2.0, 1.0, 2.0]);
        assert_eq!(StragglerProfile::parse("ramp"), Some(StragglerProfile::LinearRamp));
        assert_eq!(StragglerProfile::parse("x"), None);
    }

    #[test]
    fn resolve_explicit_or_default() {
        assert_eq!(resolve_stragglers(&[], 3), vec![1.0; 3]);
        assert_eq!(resolve_stragglers(&[1.0, 2.0], 2), vec![1.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "straggler list length")]
    fn resolve_wrong_length_panics() {
        resolve_stragglers(&[1.0], 3);
    }
}
