//! Convergence traces: the (round, time, gap) series behind every figure.

use std::io::Write;

/// One evaluation point along a run.
#[derive(Debug, Clone, PartialEq)]
pub struct TracePoint {
    /// Global round index `t` (communication round for distributed
    /// algorithms; epoch of `H` updates for single-node ones — exactly
    /// Figure 3's x-axis convention).
    pub round: usize,
    /// Measured wall-clock seconds since the run started.
    pub wall_secs: f64,
    /// Simulated cluster seconds (virtual clock; see `sim`).
    pub virt_secs: f64,
    /// Duality gap `P(v) − D(α)`.
    pub gap: f64,
    /// Primal objective.
    pub primal: f64,
    /// Dual objective.
    pub dual: f64,
    /// Cumulative coordinate updates applied so far.
    pub updates: u64,
}

impl TracePoint {
    /// One CSV row in the [`Trace::csv_header`] schema — shared by the
    /// batch writer below and the streaming
    /// `session::CsvStreamObserver` so the two cannot drift apart.
    pub fn write_csv_row<W: Write>(&self, w: &mut W, label: &str) -> std::io::Result<()> {
        writeln!(
            w,
            "{},{},{:.6},{:.6},{:.12e},{:.12e},{:.12e},{}",
            label, self.round, self.wall_secs, self.virt_secs, self.gap, self.primal, self.dual,
            self.updates
        )
    }
}

/// A named series of trace points for one algorithm/configuration.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    pub label: String,
    pub points: Vec<TracePoint>,
}

impl Trace {
    pub fn new(label: impl Into<String>) -> Self {
        Self { label: label.into(), points: Vec::new() }
    }

    pub fn push(&mut self, p: TracePoint) {
        self.points.push(p);
    }

    /// First round index whose gap falls below `threshold`, if any.
    pub fn rounds_to_gap(&self, threshold: f64) -> Option<usize> {
        self.points.iter().find(|p| p.gap <= threshold).map(|p| p.round)
    }

    /// First virtual time at which the gap falls below `threshold`.
    pub fn virt_time_to_gap(&self, threshold: f64) -> Option<f64> {
        self.points.iter().find(|p| p.gap <= threshold).map(|p| p.virt_secs)
    }

    /// First wall time at which the gap falls below `threshold`.
    pub fn wall_time_to_gap(&self, threshold: f64) -> Option<f64> {
        self.points.iter().find(|p| p.gap <= threshold).map(|p| p.wall_secs)
    }

    /// Final (smallest achieved) gap.
    pub fn final_gap(&self) -> Option<f64> {
        self.points.last().map(|p| p.gap)
    }

    /// Best gap over the run (asynchronous algorithms are not monotone).
    pub fn best_gap(&self) -> Option<f64> {
        self.points.iter().map(|p| p.gap).fold(None, |acc, g| {
            Some(match acc {
                None => g,
                Some(b) => b.min(g),
            })
        })
    }

    pub fn csv_header() -> &'static str {
        "label,round,wall_secs,virt_secs,gap,primal,dual,updates"
    }

    pub fn write_csv<W: Write>(&self, w: &mut W) -> std::io::Result<()> {
        for p in &self.points {
            p.write_csv_row(w, &self.label)?;
        }
        Ok(())
    }
}

/// Write several traces to one CSV file (with header).
pub fn write_csv_file(path: &std::path::Path, traces: &[Trace]) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{}", Trace::csv_header())?;
    for t in traces {
        t.write_csv(&mut f)?;
    }
    f.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(round: usize, gap: f64, virt: f64) -> TracePoint {
        TracePoint {
            round,
            wall_secs: virt / 2.0,
            virt_secs: virt,
            gap,
            primal: 1.0,
            dual: 1.0 - gap,
            updates: round as u64 * 100,
        }
    }

    #[test]
    fn thresholds() {
        let mut t = Trace::new("x");
        t.push(pt(0, 1.0, 0.0));
        t.push(pt(1, 0.1, 1.0));
        t.push(pt(2, 0.01, 2.0));
        assert_eq!(t.rounds_to_gap(0.5), Some(1));
        assert_eq!(t.virt_time_to_gap(0.05), Some(2.0));
        assert_eq!(t.wall_time_to_gap(0.05), Some(1.0));
        assert_eq!(t.rounds_to_gap(1e-9), None);
        assert_eq!(t.final_gap(), Some(0.01));
    }

    #[test]
    fn best_gap_non_monotone() {
        let mut t = Trace::new("x");
        t.push(pt(0, 0.5, 0.0));
        t.push(pt(1, 0.05, 1.0));
        t.push(pt(2, 0.2, 2.0));
        assert_eq!(t.best_gap(), Some(0.05));
        assert_eq!(t.final_gap(), Some(0.2));
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Trace::new("algo");
        t.push(pt(0, 1.0, 0.0));
        let mut buf = Vec::new();
        t.write_csv(&mut buf).unwrap();
        let s = String::from_utf8(buf).unwrap();
        assert!(s.starts_with("algo,0,"));
        assert_eq!(s.lines().count(), 1);
    }

    #[test]
    fn csv_file_write() {
        let mut t = Trace::new("a");
        t.push(pt(0, 1.0, 0.0));
        let path = std::env::temp_dir().join("hybrid_dca_trace_test.csv");
        write_csv_file(&path, &[t]).unwrap();
        let content = std::fs::read_to_string(&path).unwrap();
        assert!(content.starts_with(Trace::csv_header()));
        std::fs::remove_file(&path).ok();
    }
}
