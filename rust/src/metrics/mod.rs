//! Objective evaluation and experiment traces.
//!
//! * [`Objectives`] — primal `P(w)`, dual `D(α)` and duality gap
//!   `P(w(α)) − D(α)`, the paper's convergence measure (§6: "The duality
//!   gap is measured as P(v) − D(α)").
//! * [`Evaluator`] / [`EvalSource`] — the evaluation fast path: one
//!   reusable evaluator folds the objective sums over either an
//!   in-memory [`Dataset`] or a [`ShardedDataset`] streamed shard by
//!   shard, on the persistent [`WorkPool`]. Both sources accumulate
//!   identical fixed 2048-row chunks folded in chunk order, so the
//!   result is **bitwise** independent of the thread count *and* of
//!   which source held the rows.
//! * [`TracePoint`] / [`Trace`] — the (round, wall-time, virtual-time,
//!   gap) series every figure plots, with CSV export for the bench
//!   harness.
//!
//! # Memory model
//!
//! Streamed evaluation never assembles the flat dataset: each eval
//! thread owns a contiguous range of chunks and walks its rows in
//! global order with exactly one leased shard resident, swapping
//! lazily at shard boundaries (a chunk that straddles a boundary keeps
//! its single running accumulator — splitting it would change the
//! floating-point association). Peak resident data is therefore
//! (eval threads × one shard), tracked by the store's residency gauge.

pub mod trace;

pub use trace::{Trace, TracePoint};

use crate::data::{Dataset, SparseRow};
use crate::loss::Loss;
use crate::store::sharded::{ShardLease, ShardedDataset};
use crate::util::pool::DisjointWrites;
use crate::util::{norm_sq, WorkPool};

/// Primal/dual objective values for one state `(α, v)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Objectives {
    pub primal: f64,
    pub dual: f64,
    pub gap: f64,
}

/// Fixed row-chunk size for the objective sums. Partial sums are
/// accumulated per chunk and folded in chunk order, so the result is
/// bitwise-independent of how many threads ran the chunks.
const EVAL_CHUNK: usize = 2048;

/// Minimum rows before the evaluation fans out to pool threads (below
/// this the hand-off overhead dominates the O(nnz) scan).
const EVAL_PAR_MIN_ROWS: usize = 4096;

/// Where the rows live during evaluation.
#[derive(Clone, Copy)]
pub enum EvalSource<'a> {
    /// Flat dataset; rows are indexed directly.
    InMemory(&'a Dataset),
    /// Packed shard store; rows stream through leased shards.
    Sharded(&'a ShardedDataset),
}

impl EvalSource<'_> {
    pub fn n(&self) -> usize {
        match self {
            EvalSource::InMemory(d) => d.n(),
            EvalSource::Sharded(s) => s.n(),
        }
    }

    pub fn d(&self) -> usize {
        match self {
            EvalSource::InMemory(d) => d.d(),
            EvalSource::Sharded(s) => s.d(),
        }
    }
}

/// Reusable objective evaluator: owns the chunk-partial scratch (one
/// `f64` per 2048-row chunk, reused across `on_eval` rounds instead of
/// reallocated per call) and the eval-thread policy.
///
/// Sharded evaluation panics on shard I/O/CRC failures — the store was
/// manifest-validated at open, so a failed read mid-run means the
/// store changed underneath the training job and the run is
/// unrecoverable.
pub struct Evaluator<'a> {
    source: EvalSource<'a>,
    threads_override: Option<usize>,
    partials: Vec<f64>,
}

impl<'a> Evaluator<'a> {
    pub fn new(source: EvalSource<'a>) -> Self {
        Evaluator { source, threads_override: None, partials: Vec::new() }
    }

    pub fn in_memory(data: &'a Dataset) -> Self {
        Evaluator::new(EvalSource::InMemory(data))
    }

    pub fn sharded(store: &'a ShardedDataset) -> Self {
        Evaluator::new(EvalSource::Sharded(store))
    }

    /// Pin the eval fan-out to exactly `threads` workers (tests use
    /// this to prove thread-count independence; it also overrides the
    /// small-`n` serial shortcut).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads_override = Some(threads.max(1));
        self
    }

    pub fn source(&self) -> EvalSource<'a> {
        self.source
    }

    pub fn n(&self) -> usize {
        self.source.n()
    }

    pub fn d(&self) -> usize {
        self.source.d()
    }

    fn threads_for(&self, n: usize, chunks: usize) -> usize {
        let t = match self.threads_override {
            Some(t) => t,
            None if n < EVAL_PAR_MIN_ROWS => 1,
            None => WorkPool::global().size(),
        };
        t.min(chunks).max(1)
    }

    /// Fold `Σ_i term(i, x_i, y_i)` over all rows in fixed
    /// [`EVAL_CHUNK`] chunks; chunk sums are folded in chunk order
    /// regardless of thread count or source, so sequential, parallel,
    /// in-memory and streamed runs are all bitwise identical.
    fn fold<F>(&mut self, term: F) -> f64
    where
        F: Fn(usize, SparseRow<'_>, f64) -> f64 + Sync,
    {
        let n = self.source.n();
        if n == 0 {
            return 0.0;
        }
        let chunks = n.div_ceil(EVAL_CHUNK);
        let threads = self.threads_for(n, chunks);
        self.partials.clear();
        self.partials.resize(chunks, 0.0);
        match self.source {
            EvalSource::InMemory(data) => {
                fold_in_memory(data, &mut self.partials, threads, &term)
            }
            EvalSource::Sharded(store) => {
                fold_sharded(store, &mut self.partials, threads, &term);
                // Surface the store's lease high-water mark: how many
                // shards this eval actually held resident at once.
                crate::obs::global().gauge_max(
                    crate::obs::Gauge::ResidencyPeak,
                    store.residency_peak() as u64,
                );
            }
        }
        self.partials.iter().sum()
    }

    /// `P(w) = (1/n) Σ φ(x_iᵀw; y_i) + (λ/2)‖w‖²`.
    pub fn primal(&mut self, loss: &dyn Loss, w: &[f64], lambda: f64) -> f64 {
        assert_eq!(w.len(), self.source.d());
        let n = self.source.n() as f64;
        let sum = self.fold(|_, row, y| loss.primal(row.dot_dense(w), y));
        sum / n + 0.5 * lambda * norm_sq(w)
    }

    /// `D(α) = (1/n) Σ (−φ*(−α_i)) − (λ/2)‖v‖²` where the caller
    /// supplies `v = (1/λn) X α` (possibly the *estimate* shared across
    /// nodes, exactly as the paper measures it).
    pub fn dual(&mut self, loss: &dyn Loss, alpha: &[f64], v: &[f64], lambda: f64) -> f64 {
        assert_eq!(alpha.len(), self.source.n());
        assert_eq!(v.len(), self.source.d());
        let n = self.source.n() as f64;
        let sum = self.fold(|i, _, y| loss.dual_value(alpha[i], y));
        sum / n - 0.5 * lambda * norm_sq(v)
    }

    /// [`dual`](Self::dual) at `α = 0` without materializing the zero
    /// vector (the round-0 trace point of every engine; at paper scale
    /// the zero vector alone would be n × 8 bytes).
    pub fn dual_at_zero(&mut self, loss: &dyn Loss, v: &[f64], lambda: f64) -> f64 {
        assert_eq!(v.len(), self.source.d());
        let n = self.source.n() as f64;
        let sum = self.fold(|_, _, y| loss.dual_value(0.0, y));
        sum / n - 0.5 * lambda * norm_sq(v)
    }

    /// Full objective triple at `(α, v)`.
    pub fn objectives(
        &mut self,
        loss: &dyn Loss,
        alpha: &[f64],
        v: &[f64],
        lambda: f64,
    ) -> Objectives {
        let primal = self.primal(loss, v, lambda);
        let dual = self.dual(loss, alpha, v, lambda);
        Objectives { primal, dual, gap: primal - dual }
    }

    /// Objective triple at `α = 0` (round-0 trace point).
    pub fn objectives_at_zero(&mut self, loss: &dyn Loss, v: &[f64], lambda: f64) -> Objectives {
        let primal = self.primal(loss, v, lambda);
        let dual = self.dual_at_zero(loss, v, lambda);
        Objectives { primal, dual, gap: primal - dual }
    }

    /// Recompute `v = (1/λn) X α` exactly from the dual variables,
    /// streaming shards in disk order for the sharded source — the
    /// same row order and accumulation as `CsrMatrix::matvec_t`, so
    /// both sources agree bitwise.
    pub fn exact_v(&self, alpha: &[f64], lambda: f64) -> Vec<f64> {
        match self.source {
            EvalSource::InMemory(data) => exact_v(data, alpha, lambda),
            EvalSource::Sharded(store) => {
                assert_eq!(alpha.len(), store.n());
                let mut out = vec![0.0; store.d()];
                for (s, (row_start, _)) in store.spans().into_iter().enumerate() {
                    let shard = lease_or_panic(store, s);
                    for local in 0..shard.n() {
                        let ai = alpha[row_start + local];
                        if ai == 0.0 {
                            continue;
                        }
                        let r = shard.x.row(local);
                        for (&j, &x) in r.indices.iter().zip(r.values.iter()) {
                            out[j as usize] += ai * x;
                        }
                    }
                }
                let scale = 1.0 / (lambda * store.n() as f64);
                for x in out.iter_mut() {
                    *x *= scale;
                }
                out
            }
        }
    }
}

fn fold_in_memory(
    data: &Dataset,
    partials: &mut [f64],
    threads: usize,
    term: &(dyn Fn(usize, SparseRow<'_>, f64) -> f64 + Sync),
) {
    let n = data.n();
    let chunks = partials.len();
    let chunk_sum = |c: usize| {
        let lo = c * EVAL_CHUNK;
        let hi = (lo + EVAL_CHUNK).min(n);
        let mut s = 0.0;
        for i in lo..hi {
            s += term(i, data.x.row(i), data.y[i]);
        }
        s
    };
    if threads <= 1 {
        for (c, p) in partials.iter_mut().enumerate() {
            *p = chunk_sum(c);
        }
        return;
    }
    // Dynamic chunk claiming: rows are uniform per chunk but nnz is
    // not, and any claim order yields the same bits (disjoint writes,
    // in-order fold by the caller).
    // ORDERING: the claim ticket only needs the RMW's own atomicity
    // (each index handed out once); the pool's completion barrier
    // publishes the chunk sums, so `Relaxed` suffices.
    let next = crate::util::sync::AtomicUsize::new(0);
    let sink = DisjointWrites::new(partials);
    WorkPool::global().run(threads, &|_| loop {
        let c = next.fetch_add(1, crate::util::sync::Ordering::Relaxed);
        if c >= chunks {
            break;
        }
        // SAFETY: each chunk index is claimed exactly once.
        unsafe { sink.set(c, chunk_sum(c)) };
    });
}

fn fold_sharded(
    store: &ShardedDataset,
    partials: &mut [f64],
    threads: usize,
    term: &(dyn Fn(usize, SparseRow<'_>, f64) -> f64 + Sync),
) {
    let n = store.n();
    let chunks = partials.len();
    let spans = store.spans();
    let sink = DisjointWrites::new(partials);
    if threads <= 1 {
        walk_chunk_range(store, &spans, 0, chunks, n, sink, term);
        return;
    }
    // Static contiguous chunk ranges (not dynamic claiming): each
    // worker walks ascending rows so every shard it touches loads
    // exactly once, with one lease resident at a time.
    let per = chunks.div_ceil(threads);
    WorkPool::global().run(threads, &|t| {
        let c0 = t * per;
        let c1 = (c0 + per).min(chunks);
        if c0 < c1 {
            walk_chunk_range(store, &spans, c0, c1, n, sink, term);
        }
    });
}

/// Accumulate chunks `[c0, c1)` walking global rows in order with one
/// leased shard resident. A chunk straddling a shard boundary keeps
/// its single running accumulator across the swap — splitting the sum
/// at the boundary would change the floating-point association and
/// break bitwise parity with the in-memory fold.
fn walk_chunk_range(
    store: &ShardedDataset,
    spans: &[(usize, usize)],
    c0: usize,
    c1: usize,
    n: usize,
    sink: DisjointWrites,
    term: &(dyn Fn(usize, SparseRow<'_>, f64) -> f64 + Sync),
) {
    let row0 = c0 * EVAL_CHUNK;
    let mut pos = spans.partition_point(|&(_, end)| end <= row0);
    let mut resident: Option<ShardLease> = None;
    for c in c0..c1 {
        let lo = c * EVAL_CHUNK;
        let hi = (lo + EVAL_CHUNK).min(n);
        let mut s = 0.0;
        for i in lo..hi {
            while spans[pos].1 <= i {
                pos += 1;
                resident = None; // drop before the next load: ≤ 1 resident
            }
            if resident.is_none() {
                resident = Some(lease_or_panic(store, pos));
            }
            let shard = resident.as_ref().expect("resident shard");
            let local = i - spans[pos].0;
            s += term(i, shard.x.row(local), shard.y[local]);
        }
        // SAFETY: chunk ranges are disjoint across workers.
        unsafe { sink.set(c, s) };
    }
}

fn lease_or_panic(store: &ShardedDataset, shard: usize) -> ShardLease {
    store
        .lease_shard(shard)
        .unwrap_or_else(|e| panic!("evaluation failed to stream shard {shard}: {e}"))
}

/// Evaluate `P(w)` over an in-memory dataset (row-parallel for large
/// n). Thin wrapper over [`Evaluator`]; hold an `Evaluator` to reuse
/// its scratch across calls.
pub fn primal_objective(data: &Dataset, loss: &dyn Loss, w: &[f64], lambda: f64) -> f64 {
    Evaluator::in_memory(data).primal(loss, w, lambda)
}

/// Evaluate `D(α)` over an in-memory dataset. Thin wrapper over
/// [`Evaluator`].
pub fn dual_objective(
    data: &Dataset,
    loss: &dyn Loss,
    alpha: &[f64],
    v: &[f64],
    lambda: f64,
) -> f64 {
    Evaluator::in_memory(data).dual(loss, alpha, v, lambda)
}

/// Recompute `v = (1/λn) X α` exactly from the dual variables.
pub fn exact_v(data: &Dataset, alpha: &[f64], lambda: f64) -> Vec<f64> {
    let scale = 1.0 / (lambda * data.n() as f64);
    let mut v = data.x.matvec_t(alpha);
    for x in v.iter_mut() {
        *x *= scale;
    }
    v
}

/// Full objective triple at `(α, v)`. Pass `v = exact_v(..)` for the
/// certificate gap, or the shared estimate for the paper's measured gap.
pub fn objectives(
    data: &Dataset,
    loss: &dyn Loss,
    alpha: &[f64],
    v: &[f64],
    lambda: f64,
) -> Objectives {
    Evaluator::in_memory(data).objectives(loss, alpha, v, lambda)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::Preset;
    use crate::data::Strategy;
    use crate::loss::Hinge;
    use crate::store::{pack_dataset, PackOptions};
    use crate::util::Rng;

    #[test]
    fn zero_alpha_objectives() {
        let ds = Preset::Tiny.generate(&mut Rng::new(1));
        let alpha = vec![0.0; ds.n()];
        let v = exact_v(&ds, &alpha, 1e-2);
        assert!(v.iter().all(|&x| x == 0.0));
        let o = objectives(&ds, &Hinge, &alpha, &v, 1e-2);
        // P(0) = 1 (all hinge losses = 1), D(0) = 0, gap = 1.
        assert!((o.primal - 1.0).abs() < 1e-12);
        assert_eq!(o.dual, 0.0);
        assert!((o.gap - 1.0).abs() < 1e-12);
        // The allocation-free zero path is the same computation.
        let oz = Evaluator::in_memory(&ds).objectives_at_zero(&Hinge, &v, 1e-2);
        assert_eq!(oz.primal.to_bits(), o.primal.to_bits());
        assert_eq!(oz.dual.to_bits(), o.dual.to_bits());
    }

    #[test]
    fn weak_duality_random_states() {
        // P(w(α)) ≥ D(α) for any feasible α (weak duality).
        let ds = Preset::Tiny.generate(&mut Rng::new(2));
        let mut rng = Rng::new(3);
        let lambda = 1e-2;
        for _ in 0..50 {
            let alpha: Vec<f64> =
                ds.y.iter().map(|&y| rng.next_f64() * y).collect();
            let v = exact_v(&ds, &alpha, lambda);
            let o = objectives(&ds, &Hinge, &alpha, &v, lambda);
            assert!(o.gap >= -1e-9, "gap {} < 0", o.gap);
        }
    }

    /// The chunked (possibly parallel) sum is deterministic and agrees
    /// with a plain serial accumulation: exercise n above the thread
    /// fan-out threshold and a chunk-boundary remainder.
    #[test]
    fn chunked_objectives_deterministic_and_accurate() {
        let mut rng = Rng::new(9);
        let n = super::EVAL_PAR_MIN_ROWS + 137; // > threshold, ragged tail
        let d = 40;
        let x = crate::data::CsrMatrix::random(&mut rng, n, d, 6);
        let y: Vec<f64> = (0..n).map(|_| if rng.next_bool(0.5) { 1.0 } else { -1.0 }).collect();
        let ds = crate::data::Dataset::new(x, y).with_name("par-eval");
        let w: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();

        let p1 = primal_objective(&ds, &Hinge, &w, 1e-2);
        let p2 = primal_objective(&ds, &Hinge, &w, 1e-2);
        assert_eq!(p1.to_bits(), p2.to_bits(), "evaluation not deterministic");

        let mut serial = 0.0;
        for i in 0..ds.n() {
            serial += Hinge.primal(ds.x.row(i).dot_dense(&w), ds.y[i]);
        }
        let serial = serial / ds.n() as f64 + 0.5 * 1e-2 * crate::util::norm_sq(&w);
        assert!(
            (p1 - serial).abs() <= 1e-10 * (1.0 + serial.abs()),
            "chunked {p1} vs serial {serial}"
        );

        let alpha: Vec<f64> = ds.y.iter().map(|&yy| 0.5 * yy).collect();
        let v = exact_v(&ds, &alpha, 1e-2);
        let d1 = dual_objective(&ds, &Hinge, &alpha, &v, 1e-2);
        let d2 = dual_objective(&ds, &Hinge, &alpha, &v, 1e-2);
        assert_eq!(d1.to_bits(), d2.to_bits());
    }

    /// Streamed shard evaluation is bitwise-identical to the in-memory
    /// fold, including at shard sizes that put boundaries mid-chunk.
    #[test]
    fn sharded_eval_bitwise_matches_in_memory() {
        let mut rng = Rng::new(31);
        let n = super::EVAL_PAR_MIN_ROWS + 901;
        let d = 32;
        let x = crate::data::CsrMatrix::random(&mut rng, n, d, 5);
        let y: Vec<f64> = (0..n).map(|_| if rng.next_bool(0.5) { 1.0 } else { -1.0 }).collect();
        let ds = crate::data::Dataset::new(x, y).with_name("stream-eval");
        let dir = std::env::temp_dir().join("hybrid_dca_metrics_stream");
        std::fs::remove_dir_all(&dir).ok();
        // 700-row shards: boundaries land mid-chunk (700, 1400, …
        // are not multiples of 2048), exercising the accumulator
        // hand-off across a lazy shard swap.
        let opts = PackOptions { name: "stream".into(), shard_rows: 700, ..Default::default() };
        pack_dataset(&ds, &dir, &opts, Strategy::Contiguous).unwrap();
        let store = crate::store::open(&dir).unwrap();

        let w: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        let alpha: Vec<f64> = ds.y.iter().map(|&yy| 0.25 * yy).collect();
        let v = exact_v(&ds, &alpha, 1e-2);

        let mem = Evaluator::in_memory(&ds).objectives(&Hinge, &alpha, &v, 1e-2);
        let streamed = Evaluator::sharded(&store).objectives(&Hinge, &alpha, &v, 1e-2);
        assert_eq!(mem.primal.to_bits(), streamed.primal.to_bits());
        assert_eq!(mem.dual.to_bits(), streamed.dual.to_bits());

        let pm = Evaluator::in_memory(&ds).primal(&Hinge, &w, 1e-2);
        let ps = Evaluator::sharded(&store).primal(&Hinge, &w, 1e-2);
        assert_eq!(pm.to_bits(), ps.to_bits());

        let vm = Evaluator::in_memory(&ds).exact_v(&alpha, 1e-2);
        let vs = Evaluator::sharded(&store).exact_v(&alpha, 1e-2);
        assert_eq!(
            vm.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            vs.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn exact_v_matches_definition() {
        let ds = Preset::Tiny.generate(&mut Rng::new(4));
        let mut rng = Rng::new(5);
        let alpha: Vec<f64> = (0..ds.n()).map(|_| rng.next_gaussian()).collect();
        let lambda = 0.5;
        let v = exact_v(&ds, &alpha, lambda);
        // Check one coordinate by brute force.
        let mut v0 = 0.0;
        for i in 0..ds.n() {
            let r = ds.x.row(i);
            for (&j, &x) in r.indices.iter().zip(r.values.iter()) {
                if j == 0 {
                    v0 += alpha[i] * x;
                }
            }
        }
        v0 /= lambda * ds.n() as f64;
        assert!((v[0] - v0).abs() < 1e-12);
    }
}
