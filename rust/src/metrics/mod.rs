//! Objective evaluation and experiment traces.
//!
//! * [`Objectives`] — primal `P(w)`, dual `D(α)` and duality gap
//!   `P(w(α)) − D(α)`, the paper's convergence measure (§6: "The duality
//!   gap is measured as P(v) − D(α)").
//! * [`TracePoint`] / [`Trace`] — the (round, wall-time, virtual-time,
//!   gap) series every figure plots, with CSV export for the bench
//!   harness.

pub mod trace;

pub use trace::{Trace, TracePoint};

use crate::data::Dataset;
use crate::loss::Loss;
use crate::util::norm_sq;

/// Primal/dual objective values for one state `(α, v)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Objectives {
    pub primal: f64,
    pub dual: f64,
    pub gap: f64,
}

/// Evaluate `P(w) = (1/n) Σ φ(x_iᵀw; y_i) + (λ/2)‖w‖²`.
pub fn primal_objective(data: &Dataset, loss: &dyn Loss, w: &[f64], lambda: f64) -> f64 {
    assert_eq!(w.len(), data.d());
    let n = data.n() as f64;
    let mut loss_sum = 0.0;
    for i in 0..data.n() {
        let z = data.x.row(i).dot_dense(w);
        loss_sum += loss.primal(z, data.y[i]);
    }
    loss_sum / n + 0.5 * lambda * norm_sq(w)
}

/// Evaluate `D(α) = (1/n) Σ (−φ*(−α_i)) − (λ/2)‖v‖²` where the caller
/// supplies `v = (1/λn) X α` (possibly the *estimate* shared across
/// nodes, exactly as the paper measures it).
pub fn dual_objective(data: &Dataset, loss: &dyn Loss, alpha: &[f64], v: &[f64], lambda: f64) -> f64 {
    assert_eq!(alpha.len(), data.n());
    assert_eq!(v.len(), data.d());
    let n = data.n() as f64;
    let mut sum = 0.0;
    for i in 0..data.n() {
        sum += loss.dual_value(alpha[i], data.y[i]);
    }
    sum / n - 0.5 * lambda * norm_sq(v)
}

/// Recompute `v = (1/λn) X α` exactly from the dual variables.
pub fn exact_v(data: &Dataset, alpha: &[f64], lambda: f64) -> Vec<f64> {
    let scale = 1.0 / (lambda * data.n() as f64);
    let mut v = data.x.matvec_t(alpha);
    for x in v.iter_mut() {
        *x *= scale;
    }
    v
}

/// Full objective triple at `(α, v)`. Pass `v = exact_v(..)` for the
/// certificate gap, or the shared estimate for the paper's measured gap.
pub fn objectives(
    data: &Dataset,
    loss: &dyn Loss,
    alpha: &[f64],
    v: &[f64],
    lambda: f64,
) -> Objectives {
    let primal = primal_objective(data, loss, v, lambda);
    let dual = dual_objective(data, loss, alpha, v, lambda);
    Objectives { primal, dual, gap: primal - dual }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::Preset;
    use crate::loss::Hinge;
    use crate::util::Rng;

    #[test]
    fn zero_alpha_objectives() {
        let ds = Preset::Tiny.generate(&mut Rng::new(1));
        let alpha = vec![0.0; ds.n()];
        let v = exact_v(&ds, &alpha, 1e-2);
        assert!(v.iter().all(|&x| x == 0.0));
        let o = objectives(&ds, &Hinge, &alpha, &v, 1e-2);
        // P(0) = 1 (all hinge losses = 1), D(0) = 0, gap = 1.
        assert!((o.primal - 1.0).abs() < 1e-12);
        assert_eq!(o.dual, 0.0);
        assert!((o.gap - 1.0).abs() < 1e-12);
    }

    #[test]
    fn weak_duality_random_states() {
        // P(w(α)) ≥ D(α) for any feasible α (weak duality).
        let ds = Preset::Tiny.generate(&mut Rng::new(2));
        let mut rng = Rng::new(3);
        let lambda = 1e-2;
        for _ in 0..50 {
            let alpha: Vec<f64> =
                ds.y.iter().map(|&y| rng.next_f64() * y).collect();
            let v = exact_v(&ds, &alpha, lambda);
            let o = objectives(&ds, &Hinge, &alpha, &v, lambda);
            assert!(o.gap >= -1e-9, "gap {} < 0", o.gap);
        }
    }

    #[test]
    fn exact_v_matches_definition() {
        let ds = Preset::Tiny.generate(&mut Rng::new(4));
        let mut rng = Rng::new(5);
        let alpha: Vec<f64> = (0..ds.n()).map(|_| rng.next_gaussian()).collect();
        let lambda = 0.5;
        let v = exact_v(&ds, &alpha, lambda);
        // Check one coordinate by brute force.
        let mut v0 = 0.0;
        for i in 0..ds.n() {
            let r = ds.x.row(i);
            for (&j, &x) in r.indices.iter().zip(r.values.iter()) {
                if j == 0 {
                    v0 += alpha[i] * x;
                }
            }
        }
        v0 /= lambda * ds.n() as f64;
        assert!((v[0] - v0).abs() < 1e-12);
    }
}
