//! Objective evaluation and experiment traces.
//!
//! * [`Objectives`] — primal `P(w)`, dual `D(α)` and duality gap
//!   `P(w(α)) − D(α)`, the paper's convergence measure (§6: "The duality
//!   gap is measured as P(v) − D(α)").
//! * [`TracePoint`] / [`Trace`] — the (round, wall-time, virtual-time,
//!   gap) series every figure plots, with CSV export for the bench
//!   harness.

pub mod trace;

pub use trace::{Trace, TracePoint};

use crate::data::Dataset;
use crate::loss::Loss;
use crate::util::norm_sq;

/// Primal/dual objective values for one state `(α, v)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Objectives {
    pub primal: f64,
    pub dual: f64,
    pub gap: f64,
}

/// Fixed row-chunk size for the objective sums. Partial sums are
/// accumulated per chunk and folded in chunk order, so the result is
/// bitwise-independent of how many threads ran the chunks.
const EVAL_CHUNK: usize = 2048;

/// Minimum rows before the evaluation fans out to threads (below this
/// the spawn overhead dominates the O(nnz) scan).
const EVAL_PAR_MIN_ROWS: usize = 4096;

/// Sum `body(lo..hi)` over `[0, n)` in fixed [`EVAL_CHUNK`] chunks,
/// fanning out to scoped threads for large `n` (§Perf: the duality-gap
/// evaluation gates every `eval_every` rounds while all K·R solver
/// cores sit at the barrier — it was the last serial O(n·nnz) scan).
/// Chunk sums are folded in chunk order regardless of thread count, so
/// sequential and parallel runs are bitwise identical.
fn chunked_sum<F>(n: usize, body: F) -> f64
where
    F: Fn(std::ops::Range<usize>) -> f64 + Sync,
{
    if n == 0 {
        return 0.0;
    }
    let chunks = n.div_ceil(EVAL_CHUNK);
    let mut partials = vec![0.0f64; chunks];
    let threads = if n >= EVAL_PAR_MIN_ROWS {
        std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1).min(chunks)
    } else {
        1
    };
    if threads <= 1 {
        for (c, p) in partials.iter_mut().enumerate() {
            let lo = c * EVAL_CHUNK;
            *p = body(lo..(lo + EVAL_CHUNK).min(n));
        }
    } else {
        let next = std::sync::atomic::AtomicUsize::new(0);
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(threads);
            for _ in 0..threads {
                let next = &next;
                let body = &body;
                handles.push(scope.spawn(move || {
                    let mut local: Vec<(usize, f64)> = Vec::new();
                    loop {
                        let c = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        if c >= chunks {
                            break;
                        }
                        let lo = c * EVAL_CHUNK;
                        local.push((c, body(lo..(lo + EVAL_CHUNK).min(n))));
                    }
                    local
                }));
            }
            for h in handles {
                for (c, s) in h.join().expect("eval worker panicked") {
                    partials[c] = s;
                }
            }
        });
    }
    partials.iter().sum()
}

/// Evaluate `P(w) = (1/n) Σ φ(x_iᵀw; y_i) + (λ/2)‖w‖²` (row-parallel
/// for large n; see [`chunked_sum`]).
pub fn primal_objective(data: &Dataset, loss: &dyn Loss, w: &[f64], lambda: f64) -> f64 {
    assert_eq!(w.len(), data.d());
    let n = data.n() as f64;
    let loss_sum = chunked_sum(data.n(), |range| {
        let mut s = 0.0;
        for i in range {
            let z = data.x.row(i).dot_dense(w);
            s += loss.primal(z, data.y[i]);
        }
        s
    });
    loss_sum / n + 0.5 * lambda * norm_sq(w)
}

/// Evaluate `D(α) = (1/n) Σ (−φ*(−α_i)) − (λ/2)‖v‖²` where the caller
/// supplies `v = (1/λn) X α` (possibly the *estimate* shared across
/// nodes, exactly as the paper measures it). Row-parallel like
/// [`primal_objective`].
pub fn dual_objective(
    data: &Dataset,
    loss: &dyn Loss,
    alpha: &[f64],
    v: &[f64],
    lambda: f64,
) -> f64 {
    assert_eq!(alpha.len(), data.n());
    assert_eq!(v.len(), data.d());
    let n = data.n() as f64;
    let sum = chunked_sum(data.n(), |range| {
        let mut s = 0.0;
        for i in range {
            s += loss.dual_value(alpha[i], data.y[i]);
        }
        s
    });
    sum / n - 0.5 * lambda * norm_sq(v)
}

/// Recompute `v = (1/λn) X α` exactly from the dual variables.
pub fn exact_v(data: &Dataset, alpha: &[f64], lambda: f64) -> Vec<f64> {
    let scale = 1.0 / (lambda * data.n() as f64);
    let mut v = data.x.matvec_t(alpha);
    for x in v.iter_mut() {
        *x *= scale;
    }
    v
}

/// Full objective triple at `(α, v)`. Pass `v = exact_v(..)` for the
/// certificate gap, or the shared estimate for the paper's measured gap.
pub fn objectives(
    data: &Dataset,
    loss: &dyn Loss,
    alpha: &[f64],
    v: &[f64],
    lambda: f64,
) -> Objectives {
    let primal = primal_objective(data, loss, v, lambda);
    let dual = dual_objective(data, loss, alpha, v, lambda);
    Objectives { primal, dual, gap: primal - dual }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::Preset;
    use crate::loss::Hinge;
    use crate::util::Rng;

    #[test]
    fn zero_alpha_objectives() {
        let ds = Preset::Tiny.generate(&mut Rng::new(1));
        let alpha = vec![0.0; ds.n()];
        let v = exact_v(&ds, &alpha, 1e-2);
        assert!(v.iter().all(|&x| x == 0.0));
        let o = objectives(&ds, &Hinge, &alpha, &v, 1e-2);
        // P(0) = 1 (all hinge losses = 1), D(0) = 0, gap = 1.
        assert!((o.primal - 1.0).abs() < 1e-12);
        assert_eq!(o.dual, 0.0);
        assert!((o.gap - 1.0).abs() < 1e-12);
    }

    #[test]
    fn weak_duality_random_states() {
        // P(w(α)) ≥ D(α) for any feasible α (weak duality).
        let ds = Preset::Tiny.generate(&mut Rng::new(2));
        let mut rng = Rng::new(3);
        let lambda = 1e-2;
        for _ in 0..50 {
            let alpha: Vec<f64> =
                ds.y.iter().map(|&y| rng.next_f64() * y).collect();
            let v = exact_v(&ds, &alpha, lambda);
            let o = objectives(&ds, &Hinge, &alpha, &v, lambda);
            assert!(o.gap >= -1e-9, "gap {} < 0", o.gap);
        }
    }

    /// The chunked (possibly parallel) sum is deterministic and agrees
    /// with a plain serial accumulation: exercise n above the thread
    /// fan-out threshold and a chunk-boundary remainder.
    #[test]
    fn chunked_objectives_deterministic_and_accurate() {
        let mut rng = Rng::new(9);
        let n = super::EVAL_PAR_MIN_ROWS + 137; // > threshold, ragged tail
        let d = 40;
        let x = crate::data::CsrMatrix::random(&mut rng, n, d, 6);
        let y: Vec<f64> = (0..n).map(|_| if rng.next_bool(0.5) { 1.0 } else { -1.0 }).collect();
        let ds = crate::data::Dataset::new(x, y).with_name("par-eval");
        let w: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();

        let p1 = primal_objective(&ds, &Hinge, &w, 1e-2);
        let p2 = primal_objective(&ds, &Hinge, &w, 1e-2);
        assert_eq!(p1.to_bits(), p2.to_bits(), "evaluation not deterministic");

        let mut serial = 0.0;
        for i in 0..ds.n() {
            serial += Hinge.primal(ds.x.row(i).dot_dense(&w), ds.y[i]);
        }
        let serial = serial / ds.n() as f64 + 0.5 * 1e-2 * crate::util::norm_sq(&w);
        assert!(
            (p1 - serial).abs() <= 1e-10 * (1.0 + serial.abs()),
            "chunked {p1} vs serial {serial}"
        );

        let alpha: Vec<f64> = ds.y.iter().map(|&yy| 0.5 * yy).collect();
        let v = exact_v(&ds, &alpha, 1e-2);
        let d1 = dual_objective(&ds, &Hinge, &alpha, &v, 1e-2);
        let d2 = dual_objective(&ds, &Hinge, &alpha, &v, 1e-2);
        assert_eq!(d1.to_bits(), d2.to_bits());
    }

    #[test]
    fn exact_v_matches_definition() {
        let ds = Preset::Tiny.generate(&mut Rng::new(4));
        let mut rng = Rng::new(5);
        let alpha: Vec<f64> = (0..ds.n()).map(|_| rng.next_gaussian()).collect();
        let lambda = 0.5;
        let v = exact_v(&ds, &alpha, lambda);
        // Check one coordinate by brute force.
        let mut v0 = 0.0;
        for i in 0..ds.n() {
            let r = ds.x.row(i);
            for (&j, &x) in r.indices.iter().zip(r.values.iter()) {
                if j == 0 {
                    v0 += alpha[i] * x;
                }
            }
        }
        v0 /= lambda * ds.n() as f64;
        assert!((v[0] - v0).abs() < 1e-12);
    }
}
