//! Frame-level trace decorator.
//!
//! [`ObsTransport`] wraps any [`Transport`] and drops a trace instant
//! per successful frame send/recv — peer index, frame kind, and wire
//! length ([`Frame::wire_len`], the same billing the stats counters
//! use). It is installed outermost (over the chaos decorator, when
//! present) and only when the run's `[obs]` config asks for a trace,
//! so un-traced runs never pay the extra virtual dispatch. Metrics-side
//! per-peer byte totals are *not* diffed here: they are mirrored once
//! at run end from the same [`Transport::stats`] that fills
//! `RunReport.net`, which is what lets CI assert snapshot == report.

use super::{Frame, RejoinInfo, Transport, TransportError, TransportStats};

/// Decorator recording one trace instant per frame moved.
pub struct ObsTransport {
    inner: Box<dyn Transport>,
}

impl ObsTransport {
    /// Wrap `inner`. The caller decides *whether* (tracing enabled);
    /// the wrapper itself re-checks per frame so a secondary scope
    /// widening the trace mid-run is picked up too.
    pub fn wrap(inner: Box<dyn Transport>) -> Box<dyn Transport> {
        Box::new(ObsTransport { inner })
    }
}

impl Transport for ObsTransport {
    fn send(&mut self, to: usize, frame: Frame) -> Result<(), TransportError> {
        let rec = crate::obs::global();
        // Capture kind/len before the frame moves into the inner send.
        let meta = if rec.tracing_on() {
            Some((frame.kind_name(), frame.wire_len() as u64))
        } else {
            None
        };
        self.inner.send(to, frame)?;
        if let Some((kind, bytes)) = meta {
            rec.frame_sent(to, kind, bytes);
        }
        Ok(())
    }

    fn recv(&mut self) -> Result<(usize, Frame), TransportError> {
        let (from, frame) = self.inner.recv()?;
        let rec = crate::obs::global();
        if rec.tracing_on() {
            rec.frame_recv(from, frame.kind_name(), frame.wire_len() as u64);
        }
        Ok((from, frame))
    }

    fn peers(&self) -> usize {
        self.inner.peers()
    }

    fn stats(&self) -> TransportStats {
        self.inner.stats()
    }

    fn recv_timeout(
        &mut self,
        dur: std::time::Duration,
    ) -> Result<Option<(usize, Frame)>, TransportError> {
        let got = self.inner.recv_timeout(dur)?;
        if let Some((from, frame)) = &got {
            let rec = crate::obs::global();
            if rec.tracing_on() {
                rec.frame_recv(*from, frame.kind_name(), frame.wire_len() as u64);
            }
        }
        Ok(got)
    }

    fn reconnect(&mut self, info: &RejoinInfo) -> Result<bool, TransportError> {
        self.inner.reconnect(info)
    }

    fn disconnect(&mut self, peer: usize) {
        self.inner.disconnect(peer);
    }

    fn sever(&mut self) {
        self.inner.sever();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{begin, ObsCfg};
    use crate::transport::in_process;
    use crate::util::sync::{Mutex, MutexGuard};

    /// Serialize with the other tests that toggle the global recorder.
    fn lock() -> MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn traced_frames_become_instants() {
        let _g = lock();
        let guard = begin(&ObsCfg { enabled: true, trace: true }).expect("enabled");
        let (master, mut workers) = in_process(1);
        let mut master = ObsTransport::wrap(Box::new(master));
        let mut worker = workers.pop().expect("one worker");
        let shutdown = Frame::Shutdown { vtime: 0.0, round: 0 };
        let wire_len = shutdown.wire_len();
        master.send(0, shutdown).expect("send");
        let (_, frame) = worker.recv().expect("recv");
        assert!(matches!(frame, Frame::Shutdown { .. }));
        let snap = guard.finish().expect("primary");
        let sends: Vec<_> = snap.trace.iter().filter(|e| e.name == "send").collect();
        assert_eq!(sends.len(), 1);
        assert_eq!(sends[0].cat, "net");
        let bytes = sends[0]
            .args
            .iter()
            .find(|(k, _)| *k == "bytes")
            .and_then(|(_, v)| v.as_f64())
            .expect("bytes arg");
        assert_eq!(bytes, wire_len as f64);
    }

    #[test]
    fn untraced_wrapper_is_transparent() {
        let _g = lock();
        let (master, mut workers) = in_process(1);
        let mut master = ObsTransport::wrap(Box::new(master));
        let mut worker = workers.pop().expect("one worker");
        master.send(0, Frame::Shutdown { vtime: 0.0, round: 0 }).expect("send");
        assert!(matches!(worker.recv(), Ok((_, Frame::Shutdown { .. }))));
        assert_eq!(master.peers(), 1);
        assert_eq!(master.stats().per_peer.len(), 1);
    }
}
