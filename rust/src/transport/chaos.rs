//! Deterministic fault injection as a [`Transport`] decorator.
//!
//! A [`FaultPlan`] is a seeded, scripted list of one-shot faults —
//! stall a worker, drop/duplicate/corrupt a frame, sever a link, kill
//! a worker — keyed by `(worker, local_round)`. [`ChaosTransport`]
//! wraps any backend (the in-process simulator and the socket
//! endpoints identically) and fires each fault exactly once when the
//! matching `Update` frame passes through, so a chaos run is exactly
//! as reproducible as the fault-free run it perturbs.
//!
//! Sides: `stall`/`drop`/`dup`/`sever`/`kill` act on the *worker*
//! wrapper (they perturb the worker's own send path); `corrupt` acts
//! on the *master* wrapper (it mangles a received frame before the
//! coordinator sees it, surfacing as the same [`TransportError::Wire`]
//! a real on-wire bitflip would produce). `sever` and `kill` need a
//! real link to cut, so they are socket-only (`kill` still poisons an
//! in-process endpoint; `sever` is a no-op there).
//!
//! Plan grammar (the `--chaos` flag and the `[chaos]` TOML table):
//!
//! ```text
//! kind:worker=W,round=R[,secs=X] [; ...]    e.g.
//! "stall:worker=1,round=2,secs=0.3;kill:worker=2,round=4;seed=7"
//! ```

use std::time::Duration;

use crate::util::Rng;

use super::frame::{Frame, FRAME_TRAILER_LEN};
use super::{RejoinInfo, Transport, TransportError, TransportStats, WireError, MASTER};

/// What a single scripted fault does when it fires.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Sleep the worker's send path for `secs` real seconds before the
    /// `Update` goes out (a straggler the master should *survive*, via
    /// suspicion strikes and, if it comes back in time, no fault at all).
    Stall { secs: f64 },
    /// Swallow the `Update` once; the retransmit (triggered by the
    /// master's `Nack` probe) goes through.
    Drop,
    /// Send the `Update` twice; the master's round dedup absorbs it.
    Duplicate,
    /// Master side: flip one seeded-random byte of the received
    /// frame's encoding, so the coordinator sees the identical
    /// [`TransportError::Wire`] a corrupted wire read would produce.
    Corrupt,
    /// Cut the worker's connection right before the send, exercising
    /// the reconnect-with-backoff + `Rejoin` path (socket-only).
    Sever,
    /// Cut the connection and poison the endpoint: every later call
    /// fails and `reconnect` refuses, simulating a worker process that
    /// died for good.
    Kill,
}

impl FaultKind {
    fn name(self) -> &'static str {
        match self {
            FaultKind::Stall { .. } => "stall",
            FaultKind::Drop => "drop",
            FaultKind::Duplicate => "dup",
            FaultKind::Corrupt => "corrupt",
            FaultKind::Sever => "sever",
            FaultKind::Kill => "kill",
        }
    }
}

/// One scripted fault: fire `kind` when worker `worker` reaches local
/// round `round` (0-based, matching `WorkerMsg::local_round`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Fault {
    pub kind: FaultKind,
    pub worker: usize,
    pub round: usize,
}

/// A parsed, seeded chaos script. Empty plans are free: the decorator
/// is only installed when the plan is non-empty, so fault-free runs
/// pay nothing and stay bitwise-identical to pre-chaos builds.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub faults: Vec<Fault>,
    /// Seeds the byte-position RNG for `corrupt` faults.
    pub seed: u64,
}

impl FaultPlan {
    /// Parse the `;`-separated spec grammar (see module docs). An
    /// empty/whitespace spec parses to the empty plan.
    pub fn parse(spec: &str) -> anyhow::Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for entry in spec.split(';') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            if let Some(seed) = entry.strip_prefix("seed=") {
                plan.seed = seed
                    .trim()
                    .parse()
                    .map_err(|e| anyhow::anyhow!("chaos: bad seed '{seed}': {e}"))?;
                continue;
            }
            let (kind_name, args) = entry
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("chaos: entry '{entry}' is not kind:args"))?;
            let (mut worker, mut round, mut secs) = (None, None, None);
            for kv in args.split(',') {
                let kv = kv.trim();
                let (key, value) = kv
                    .split_once('=')
                    .ok_or_else(|| anyhow::anyhow!("chaos: '{kv}' is not key=value"))?;
                match key.trim() {
                    "worker" => worker = Some(value.trim().parse::<usize>()?),
                    "round" => round = Some(value.trim().parse::<usize>()?),
                    "secs" => secs = Some(value.trim().parse::<f64>()?),
                    other => anyhow::bail!("chaos: unknown key '{other}' in '{entry}'"),
                }
            }
            let worker = worker
                .ok_or_else(|| anyhow::anyhow!("chaos: '{entry}' is missing worker="))?;
            let round =
                round.ok_or_else(|| anyhow::anyhow!("chaos: '{entry}' is missing round="))?;
            let kind = match kind_name.trim() {
                "stall" => {
                    let secs = secs
                        .ok_or_else(|| anyhow::anyhow!("chaos: stall needs secs= ('{entry}')"))?;
                    anyhow::ensure!(
                        secs.is_finite() && secs >= 0.0,
                        "chaos: stall secs must be finite and ≥ 0 (got {secs})"
                    );
                    FaultKind::Stall { secs }
                }
                "drop" => FaultKind::Drop,
                "dup" | "duplicate" => FaultKind::Duplicate,
                "corrupt" => FaultKind::Corrupt,
                "sever" => FaultKind::Sever,
                "kill" => FaultKind::Kill,
                other => anyhow::bail!(
                    "chaos: unknown fault kind '{other}' \
                     (stall|drop|dup|corrupt|sever|kill)"
                ),
            };
            if secs.is_some() && !matches!(kind, FaultKind::Stall { .. }) {
                anyhow::bail!("chaos: secs= only applies to stall ('{entry}')");
            }
            plan.faults.push(Fault { kind, worker, round });
        }
        Ok(plan)
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }
}

/// The decorator. Wraps either endpoint of any backend; `role` is
/// `Some(worker_id)` on a worker link, `None` on the master link.
pub struct ChaosTransport {
    inner: Box<dyn Transport>,
    plan: FaultPlan,
    rng: Rng,
    role: Option<usize>,
    /// One-shot latch per plan entry.
    fired: Vec<bool>,
    /// Set by a `kill` fault: the endpoint is poisoned for good.
    killed: bool,
}

impl ChaosTransport {
    pub fn wrap(inner: Box<dyn Transport>, plan: FaultPlan, role: Option<usize>) -> Self {
        let fired = vec![false; plan.faults.len()];
        let rng = Rng::new(plan.seed ^ 0xC4A05);
        Self { inner, plan, rng, role, fired, killed: false }
    }

    fn killed_err(&self) -> TransportError {
        TransportError::PeerGone {
            peer: MASTER,
            detail: "worker killed by chaos plan".to_string(),
        }
    }

    /// First unfired non-stall fault matching `(worker, round)`, with
    /// every matching stall applied (slept and latched) on the way.
    fn take_send_fault(&mut self, worker: usize, round: usize) -> Option<FaultKind> {
        let mut hit = None;
        for (i, f) in self.plan.faults.iter().enumerate() {
            if self.fired[i] || f.worker != worker || f.round != round {
                continue;
            }
            match f.kind {
                FaultKind::Stall { secs } => {
                    self.fired[i] = true;
                    std::thread::sleep(Duration::from_secs_f64(secs));
                }
                FaultKind::Corrupt => {} // master-side; not a send fault
                kind => {
                    if hit.is_none() {
                        self.fired[i] = true;
                        hit = Some(kind);
                    }
                }
            }
        }
        hit
    }

    /// Master side: replace a received `Update` that a `corrupt` fault
    /// targets with the [`TransportError::Wire`] its mangled encoding
    /// actually decodes to.
    fn filter_recv(
        &mut self,
        peer: usize,
        frame: Frame,
    ) -> Result<(usize, Frame), TransportError> {
        if self.role.is_none() {
            if let Frame::Update(m) = &frame {
                for (i, f) in self.plan.faults.iter().enumerate() {
                    if self.fired[i]
                        || !matches!(f.kind, FaultKind::Corrupt)
                        || f.worker != m.worker
                        || f.round != m.local_round
                    {
                        continue;
                    }
                    self.fired[i] = true;
                    let mut bytes = frame.encode();
                    let idx = self.rng.next_below(bytes.len() - FRAME_TRAILER_LEN);
                    bytes[idx] ^= 0xFF;
                    let err = match Frame::decode(&bytes) {
                        Err(e) => e,
                        // Unreachable (the CRC covers every non-trailer
                        // byte), but stay panic-free regardless.
                        Ok(_) => WireError::BadCrc { expected: 0, got: 0 },
                    };
                    return Err(TransportError::Wire { peer, err });
                }
            }
        }
        Ok((peer, frame))
    }
}

impl Transport for ChaosTransport {
    fn send(&mut self, to: usize, frame: Frame) -> Result<(), TransportError> {
        if self.killed {
            return Err(self.killed_err());
        }
        let me = match self.role {
            Some(me) => me,
            None => return self.inner.send(to, frame),
        };
        let round = match &frame {
            Frame::Update(m) => m.local_round,
            _ => return self.inner.send(to, frame),
        };
        match self.take_send_fault(me, round) {
            None => self.inner.send(to, frame),
            Some(FaultKind::Drop) => Ok(()),
            Some(FaultKind::Duplicate) => {
                self.inner.send(to, frame.clone())?;
                self.inner.send(to, frame)
            }
            Some(FaultKind::Sever) => {
                self.inner.sever();
                self.inner.send(to, frame)
            }
            Some(FaultKind::Kill) => {
                self.killed = true;
                self.inner.sever();
                Err(self.killed_err())
            }
            // Stall and Corrupt never come back from take_send_fault.
            Some(_) => self.inner.send(to, frame),
        }
    }

    fn recv(&mut self) -> Result<(usize, Frame), TransportError> {
        if self.killed {
            return Err(self.killed_err());
        }
        let (peer, frame) = self.inner.recv()?;
        self.filter_recv(peer, frame)
    }

    fn recv_timeout(
        &mut self,
        dur: Duration,
    ) -> Result<Option<(usize, Frame)>, TransportError> {
        if self.killed {
            return Err(self.killed_err());
        }
        match self.inner.recv_timeout(dur)? {
            None => Ok(None),
            Some((peer, frame)) => self.filter_recv(peer, frame).map(Some),
        }
    }

    fn reconnect(&mut self, info: &RejoinInfo) -> Result<bool, TransportError> {
        if self.killed {
            return Ok(false);
        }
        self.inner.reconnect(info)
    }

    fn disconnect(&mut self, peer: usize) {
        self.inner.disconnect(peer);
    }

    fn sever(&mut self) {
        self.inner.sever();
    }

    fn peers(&self) -> usize {
        self.inner.peers()
    }

    fn stats(&self) -> TransportStats {
        self.inner.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::messages::{DeltaV, WorkerMsg};
    use crate::transport::in_process;

    fn update(worker: usize, round: usize) -> Frame {
        Frame::Update(WorkerMsg {
            worker,
            local_round: round,
            delta_v: DeltaV::Dense(vec![1.0, 2.0]),
            dual_sum: 0.5,
            arrival_vtime: 1.0,
            updates: 4,
        })
    }

    #[test]
    fn parse_full_grammar() {
        let plan = FaultPlan::parse(
            "stall:worker=1,round=2,secs=0.25; kill:worker=2,round=4; \
             dup:worker=0,round=1; seed=9",
        )
        .unwrap();
        assert_eq!(plan.seed, 9);
        assert_eq!(plan.faults.len(), 3);
        assert_eq!(plan.faults[0], Fault {
            kind: FaultKind::Stall { secs: 0.25 },
            worker: 1,
            round: 2
        });
        assert_eq!(plan.faults[1], Fault { kind: FaultKind::Kill, worker: 2, round: 4 });
        assert_eq!(plan.faults[1].kind.name(), "kill");
        assert!(FaultPlan::parse("  ").unwrap().is_empty());
    }

    #[test]
    fn parse_rejects_bad_specs() {
        assert!(FaultPlan::parse("fry:worker=0,round=1").is_err());
        assert!(FaultPlan::parse("stall:worker=0,round=1").is_err()); // no secs
        assert!(FaultPlan::parse("drop:worker=0,round=1,secs=2").is_err());
        assert!(FaultPlan::parse("drop:round=1").is_err()); // no worker
        assert!(FaultPlan::parse("drop:worker=0").is_err()); // no round
        assert!(FaultPlan::parse("seed=banana").is_err());
    }

    #[test]
    fn drop_swallows_once_then_delivers() {
        let (mut master, workers) = in_process(1);
        let plan = FaultPlan::parse("drop:worker=0,round=0").unwrap();
        let mut w: ChaosTransport = ChaosTransport::wrap(
            Box::new(workers.into_iter().next().unwrap()),
            plan,
            Some(0),
        );
        w.send(MASTER, update(0, 0)).unwrap();
        assert_eq!(master.recv_timeout(Duration::from_millis(20)).unwrap(), None);
        // The retransmit of the same round is not re-dropped.
        w.send(MASTER, update(0, 0)).unwrap();
        let (peer, got) = master.recv().unwrap();
        assert_eq!(peer, 0);
        assert_eq!(got, update(0, 0));
    }

    #[test]
    fn duplicate_sends_twice() {
        let (mut master, workers) = in_process(1);
        let plan = FaultPlan::parse("dup:worker=0,round=3").unwrap();
        let mut w = ChaosTransport::wrap(
            Box::new(workers.into_iter().next().unwrap()),
            plan,
            Some(0),
        );
        w.send(MASTER, update(0, 3)).unwrap();
        assert_eq!(master.recv().unwrap().1, update(0, 3));
        assert_eq!(master.recv().unwrap().1, update(0, 3));
    }

    #[test]
    fn corrupt_surfaces_as_wire_error_once() {
        let (master, mut workers) = in_process(1);
        let plan = FaultPlan::parse("corrupt:worker=0,round=1;seed=5").unwrap();
        let mut m = ChaosTransport::wrap(Box::new(master), plan, None);
        workers[0].send(MASTER, update(0, 1)).unwrap();
        match m.recv() {
            Err(TransportError::Wire { peer: 0, .. }) => {}
            other => panic!("expected a Wire error, got {other:?}"),
        }
        // The retransmit passes clean.
        workers[0].send(MASTER, update(0, 1)).unwrap();
        assert_eq!(m.recv().unwrap().1, update(0, 1));
    }

    #[test]
    fn kill_poisons_the_endpoint() {
        let (_master, workers) = in_process(1);
        let plan = FaultPlan::parse("kill:worker=0,round=2").unwrap();
        let mut w = ChaosTransport::wrap(
            Box::new(workers.into_iter().next().unwrap()),
            plan,
            Some(0),
        );
        w.send(MASTER, update(0, 1)).unwrap(); // untouched round
        let err = w.send(MASTER, update(0, 2)).unwrap_err();
        assert!(matches!(err, TransportError::PeerGone { .. }), "{err}");
        // Poisoned for good: later rounds fail too, and rejoin refuses.
        assert!(w.send(MASTER, update(0, 3)).is_err());
        assert!(w.recv().is_err());
        let info = RejoinInfo { worker_id: 0, last_acked_round: 1, alpha_crc: 0 };
        assert_eq!(w.reconnect(&info), Ok(false));
    }

    #[test]
    fn stall_delays_but_delivers() {
        let (mut master, workers) = in_process(1);
        let plan = FaultPlan::parse("stall:worker=0,round=0,secs=0.05").unwrap();
        let mut w = ChaosTransport::wrap(
            Box::new(workers.into_iter().next().unwrap()),
            plan,
            Some(0),
        );
        let t0 = std::time::Instant::now();
        w.send(MASTER, update(0, 0)).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(45));
        assert_eq!(master.recv().unwrap().1, update(0, 0));
    }
}
