//! The cross-node message seam: one [`Transport`] trait, two backends.
//!
//! Everything the coordinator sends between nodes — worker `Δv`
//! updates, merged `v` replies, shutdown, final reports — flows
//! through this trait as typed [`Frame`]s:
//!
//! * [`InProcessMaster`] / [`InProcessWorker`] wrap the façade's
//!   `util::sync::mailbox` channels. Frames pass by value (no encoding on
//!   the hot path) and the per-peer byte counters bill
//!   [`Frame::wire_len`], so the simulated cluster reports the same
//!   wire traffic a socket run would ship.
//! * [`SocketMaster`] / [`SocketWorker`] speak the versioned
//!   length-prefixed binary protocol of [`frame`] over TCP or
//!   Unix-domain sockets, so a master process and `K` worker processes
//!   form a real cluster (`hybrid-dca train --distributed` +
//!   `hybrid-dca node`).
//!
//! Addressing is role-relative: the master's peers are workers
//! `0..K`; a worker has exactly one peer, the master, at index
//! [`MASTER`]. The virtual clock is untouched by the backend choice —
//! `sim::SendCost` bills the *simulated* network either way, while
//! [`TransportStats`] counts the *actual* bytes moved (see README
//! "Distributed execution" for what is and isn't billed).

pub mod chaos;
pub mod frame;
mod inprocess;
pub mod obs;
mod socket;

pub use chaos::{ChaosTransport, FaultKind, FaultPlan};
pub use frame::{Frame, RejoinInfo, WireError, WIRE_MAGIC, WIRE_VERSION};
pub use inprocess::{in_process, InProcessMaster, InProcessWorker};
pub use obs::ObsTransport;
pub use socket::{SocketListener, SocketMaster, SocketWorker};

/// The worker-side peer index of the master.
pub const MASTER: usize = 0;

/// A connected endpoint exchanging typed frames with its peers.
///
/// Object-safe on purpose: the coordinator holds `&mut dyn Transport`
/// so the master/worker loops are byte-for-byte the same code in
/// simulated and multi-process runs — which is what makes the
/// distributed ≡ in-process bitwise parity hold.
pub trait Transport: Send {
    /// Send one frame to peer `to`.
    fn send(&mut self, to: usize, frame: Frame) -> Result<(), TransportError>;

    /// Block until a frame arrives from any peer.
    fn recv(&mut self) -> Result<(usize, Frame), TransportError>;

    /// Number of peers this endpoint addresses.
    fn peers(&self) -> usize;

    /// Per-peer traffic counters accumulated so far.
    fn stats(&self) -> TransportStats;

    /// Block at most `dur` for a frame: `Ok(Some)` on arrival,
    /// `Ok(None)` when the wait expires with nothing queued. The
    /// default falls back to the blocking [`Transport::recv`] — only
    /// backends with a real clock (mailbox, sockets) can tick, and the
    /// fault-tolerant master degrades to fail-fast on the rest.
    fn recv_timeout(
        &mut self,
        dur: std::time::Duration,
    ) -> Result<Option<(usize, Frame)>, TransportError> {
        let _ = dur;
        self.recv().map(Some)
    }

    /// Worker side: try to re-establish a severed link to the master
    /// and introduce ourselves with `info` as the first frame.
    /// `Ok(true)` means the link is live again; `Ok(false)` means this
    /// backend cannot reconnect (in-process channels, or retries
    /// exhausted) and the caller should treat the master as gone.
    fn reconnect(&mut self, info: &RejoinInfo) -> Result<bool, TransportError> {
        let _ = info;
        Ok(false)
    }

    /// Master side: drop the link to one peer (a worker declared
    /// dead), releasing its socket and reader without touching the
    /// other peers. No-op where there is nothing to release.
    fn disconnect(&mut self, peer: usize) {
        let _ = peer;
    }

    /// Tear down this endpoint's own link abruptly — the chaos
    /// decorator's hook for `sever`/`kill` faults. No-op in-process.
    fn sever(&mut self) {}
}

/// Steady-state transport failure. Setup failures (bind, connect,
/// accept, handshake) surface as `anyhow` errors from the backend
/// constructors with the peer address and configured timeout in the
/// message; this enum covers everything after the cluster is formed.
#[derive(Debug, Clone, PartialEq)]
pub enum TransportError {
    /// Every peer has closed its connection cleanly — no frame will
    /// ever arrive again. The master sees this when all workers exit.
    Closed,
    /// One peer's connection died (EOF, reset, or I/O error).
    PeerGone { peer: usize, detail: String },
    /// One peer is *silent* past the read timeout but its connection
    /// is still up — possibly just slow. The fault-tolerant master
    /// counts these as suspicion strikes instead of declaring death.
    PeerSilent { peer: usize, detail: String },
    /// A peer sent bytes that do not decode as a frame.
    Wire { peer: usize, err: WireError },
    /// A peer sent a well-formed frame that violates the protocol
    /// (e.g. a worker id that does not match its connection).
    Protocol { peer: usize, detail: String },
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Closed => write!(f, "all peers disconnected"),
            TransportError::PeerGone { peer, detail } => {
                write!(f, "peer {peer} gone: {detail}")
            }
            TransportError::PeerSilent { peer, detail } => {
                write!(f, "peer {peer} silent: {detail}")
            }
            TransportError::Wire { peer, err } => {
                write!(f, "bad frame from peer {peer}: {err}")
            }
            TransportError::Protocol { peer, detail } => {
                write!(f, "protocol violation from peer {peer}: {detail}")
            }
        }
    }
}

impl std::error::Error for TransportError {}

/// Per-peer traffic counters (payload = full encoded frames; socket
/// endpoints also count the 16-byte handshake and the `Assign` frame,
/// which in-process endpoints never exchange).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PeerStats {
    pub sent_bytes: u64,
    pub recv_bytes: u64,
    pub sent_frames: u64,
    pub recv_frames: u64,
}

/// Traffic counters for one endpoint, indexed by peer.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TransportStats {
    pub per_peer: Vec<PeerStats>,
}

impl TransportStats {
    pub fn new(peers: usize) -> Self {
        Self { per_peer: vec![PeerStats::default(); peers] }
    }

    pub fn sent_bytes(&self) -> u64 {
        self.per_peer.iter().map(|p| p.sent_bytes).sum()
    }

    pub fn recv_bytes(&self) -> u64 {
        self.per_peer.iter().map(|p| p.recv_bytes).sum()
    }

    pub fn sent_frames(&self) -> u64 {
        self.per_peer.iter().map(|p| p.sent_frames).sum()
    }

    pub fn recv_frames(&self) -> u64 {
        self.per_peer.iter().map(|p| p.recv_frames).sum()
    }
}

/// Which backend carries cross-node frames.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportBackend {
    /// Threads-as-nodes over channels (the simulator; default).
    InProcess,
    /// TCP sockets (`listen`/`join` are `host:port`).
    Tcp,
    /// Unix-domain sockets (`listen`/`join` are filesystem paths).
    Uds,
}

impl TransportBackend {
    pub fn parse(s: &str) -> Option<TransportBackend> {
        match s.to_ascii_lowercase().as_str() {
            "in-process" | "inprocess" | "sim" => Some(TransportBackend::InProcess),
            "tcp" => Some(TransportBackend::Tcp),
            "uds" | "unix" => Some(TransportBackend::Uds),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TransportBackend::InProcess => "in-process",
            TransportBackend::Tcp => "tcp",
            TransportBackend::Uds => "uds",
        }
    }
}

/// The `[transport]` config table: backend, addresses, and timeouts.
#[derive(Debug, Clone, PartialEq)]
pub struct TransportCfg {
    pub backend: TransportBackend,
    /// Master bind address (`host:port` for tcp, a path for uds).
    pub listen: String,
    /// Worker connect address.
    pub join: String,
    /// Worker-side connect + handshake deadline (seconds).
    pub connect_timeout_secs: f64,
    /// Master-side deadline for all `K` workers to connect (seconds).
    pub accept_timeout_secs: f64,
    /// Steady-state read timeout (seconds; 0 disables). A worker whose
    /// master dies mid-run errors out within this bound; the master
    /// uses it as its liveness-tick period.
    pub read_timeout_secs: f64,
    /// Listen backlog for the master's accept socket.
    pub accept_backlog: usize,
    /// Consecutive read-timeout strikes before the master declares a
    /// silent worker dead and shrinks the effective cluster (0 = never
    /// declare death; a silent worker then stalls the run forever, the
    /// pre-fault-tolerance behavior).
    pub suspicion_timeouts: u32,
    /// Worker-side reconnect attempts after a severed link before
    /// giving up (0 disables reconnecting entirely).
    pub reconnect_attempts: u32,
    /// First reconnect backoff delay (seconds); doubles per attempt.
    pub backoff_base_secs: f64,
    /// Backoff ceiling (seconds).
    pub backoff_max_secs: f64,
}

impl Default for TransportCfg {
    fn default() -> Self {
        Self {
            backend: TransportBackend::InProcess,
            listen: String::new(),
            join: String::new(),
            connect_timeout_secs: 10.0,
            accept_timeout_secs: 30.0,
            read_timeout_secs: 30.0,
            accept_backlog: 64,
            suspicion_timeouts: 4,
            reconnect_attempts: 5,
            backoff_base_secs: 0.2,
            backoff_max_secs: 5.0,
        }
    }
}

impl TransportCfg {
    /// Enforce the table's invariants (timeouts finite and ≥ 0, a
    /// backlog that can actually hold a cluster).
    pub fn validate(&self) -> anyhow::Result<()> {
        for (name, v) in [
            ("connect_timeout", self.connect_timeout_secs),
            ("accept_timeout", self.accept_timeout_secs),
            ("read_timeout", self.read_timeout_secs),
            ("backoff_base", self.backoff_base_secs),
            ("backoff_max", self.backoff_max_secs),
        ] {
            anyhow::ensure!(
                v.is_finite() && v >= 0.0,
                "transport.{name} must be a finite number of seconds ≥ 0 (got {v})"
            );
        }
        anyhow::ensure!(self.accept_backlog >= 1, "transport.accept_backlog must be ≥ 1");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_parse_and_name() {
        assert_eq!(TransportBackend::parse("tcp"), Some(TransportBackend::Tcp));
        assert_eq!(TransportBackend::parse("UNIX"), Some(TransportBackend::Uds));
        assert_eq!(TransportBackend::parse("sim"), Some(TransportBackend::InProcess));
        assert_eq!(TransportBackend::parse("smoke-signals"), None);
        assert_eq!(TransportBackend::Uds.name(), "uds");
    }

    #[test]
    fn cfg_validation() {
        TransportCfg::default().validate().unwrap();
        let mut c = TransportCfg::default();
        c.connect_timeout_secs = -1.0;
        assert!(c.validate().is_err());
        c = TransportCfg::default();
        c.read_timeout_secs = f64::NAN;
        assert!(c.validate().is_err());
        c = TransportCfg::default();
        c.accept_backlog = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn stats_totals() {
        let mut s = TransportStats::new(2);
        s.per_peer[0].sent_bytes = 10;
        s.per_peer[1].sent_bytes = 5;
        s.per_peer[1].recv_bytes = 7;
        s.per_peer[0].recv_frames = 2;
        assert_eq!(s.sent_bytes(), 15);
        assert_eq!(s.recv_bytes(), 7);
        assert_eq!(s.recv_frames(), 2);
    }
}
