//! Multi-process backend: the [`frame`](super::frame) protocol over
//! TCP or Unix-domain sockets.
//!
//! Topology mirrors the in-process one: the master binds a listener
//! ([`SocketListener::bind`]) and accepts exactly `K` workers
//! ([`SocketListener::accept_cluster`]); each worker dials in
//! ([`SocketWorker::connect`]). Worker ids are assigned in accept
//! order — the master's `Assign` frame then binds each id to its shard
//! range and RNG stream, so accept order carries no semantic weight.
//!
//! The master runs one reader thread per worker feeding a single
//! readiness queue, which is what lets `master.rs`'s bounded-barrier
//! gather block on *real socket readiness* exactly as it blocked on
//! channel readiness. Setup failures (bind/connect/accept/handshake)
//! return `anyhow` errors naming the peer address and the configured
//! timeout; steady-state failures surface as typed
//! [`TransportError`]s.

use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::util::sync::{mailbox, AtomicBool, AtomicU64, Ordering, Receiver, Sender};
use crate::util::Rng;

// ORDERING: the per-peer byte/frame counters are monotonic statistics
// read for reporting only (never for synchronization decisions), so
// all accesses are `Relaxed`; the reader-thread joins in `Drop` give
// snapshots taken after shutdown exact totals. The rejoin-acceptor
// stop flag is likewise `Relaxed`: it is a latched shutdown request
// polled in a sleep loop, ordering nothing.

use anyhow::Context;

use super::frame::{
    arr, decode_ack, decode_hello, encode_ack, encode_hello, Frame, RejoinInfo, WireError, ACK_OK,
    ACK_VERSION_MISMATCH, FRAME_HEADER_LEN, FRAME_TRAILER_LEN, HANDSHAKE_LEN, MAX_FRAME_PAYLOAD,
    WIRE_VERSION,
};
use super::{
    PeerStats, Transport, TransportBackend, TransportCfg, TransportError, TransportStats, MASTER,
};

/// Poll interval for the nonblocking accept loop and connect retries.
const RETRY_EVERY: Duration = Duration::from_millis(25);

fn timeout_of(secs: f64) -> Option<Duration> {
    if secs > 0.0 {
        Some(Duration::from_secs_f64(secs))
    } else {
        None
    }
}

/// One connected socket, TCP or Unix-domain.
enum Stream {
    Tcp(TcpStream),
    Unix(UnixStream),
}

impl Stream {
    fn try_clone(&self) -> std::io::Result<Stream> {
        match self {
            Stream::Tcp(s) => s.try_clone().map(Stream::Tcp),
            Stream::Unix(s) => s.try_clone().map(Stream::Unix),
        }
    }

    fn set_read_timeout(&self, dur: Option<Duration>) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(dur),
            Stream::Unix(s) => s.set_read_timeout(dur),
        }
    }

    fn set_nonblocking(&self, nb: bool) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_nonblocking(nb),
            Stream::Unix(s) => s.set_nonblocking(nb),
        }
    }

    /// Half-close both directions; unblocks any reader sharing the
    /// underlying socket. Errors ignored — this is teardown.
    fn shutdown_both(&self) {
        match self {
            Stream::Tcp(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
            Stream::Unix(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            Stream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            Stream::Unix(s) => s.flush(),
        }
    }
}

/// Why a read loop stopped.
#[derive(Debug)]
enum ReadEnd {
    /// Clean EOF on a frame boundary.
    Eof,
    /// EOF in the middle of a frame.
    MidFrame,
    /// No bytes within the read timeout, *at a frame boundary* — the
    /// peer is silent, but the byte stream is still in sync, so the
    /// reader can keep listening (the master turns these into
    /// suspicion strikes instead of declaring the worker dead).
    Timeout,
    /// Some other I/O failure.
    Io(String),
    /// Bytes arrived but did not decode.
    Wire(WireError),
}

/// Fill `buf` completely. `at_boundary` marks whether EOF before the
/// first byte is a clean close (frame boundary) or a truncation. A
/// timeout after *some* bytes of a frame already arrived desyncs the
/// stream and is therefore an I/O failure, not a resumable
/// [`ReadEnd::Timeout`].
fn fill(stream: &mut Stream, buf: &mut [u8], at_boundary: bool) -> Result<(), ReadEnd> {
    let mut off = 0;
    while off < buf.len() {
        match stream.read(&mut buf[off..]) {
            Ok(0) => {
                return Err(if at_boundary && off == 0 { ReadEnd::Eof } else { ReadEnd::MidFrame })
            }
            Ok(n) => off += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                return Err(if at_boundary && off == 0 {
                    ReadEnd::Timeout
                } else {
                    ReadEnd::Io("read timed out mid-frame".to_string())
                })
            }
            Err(e) => return Err(ReadEnd::Io(e.to_string())),
        }
    }
    Ok(())
}

/// Read one complete frame: header first (its length prefix is
/// sanity-capped before any allocation), then payload + CRC, then the
/// full validated decode.
fn read_frame(stream: &mut Stream) -> Result<Frame, ReadEnd> {
    let mut header = [0u8; FRAME_HEADER_LEN];
    fill(stream, &mut header, true)?;
    let payload_len = u64::from_le_bytes(
        arr(&header[12..20], "header.payload_len").map_err(ReadEnd::Wire)?,
    );
    if payload_len > MAX_FRAME_PAYLOAD {
        return Err(ReadEnd::Wire(WireError::Oversized { len: payload_len }));
    }
    let total = FRAME_HEADER_LEN + payload_len as usize + FRAME_TRAILER_LEN;
    let mut buf = vec![0u8; total];
    buf[..FRAME_HEADER_LEN].copy_from_slice(&header);
    fill(stream, &mut buf[FRAME_HEADER_LEN..], false)?;
    Frame::decode(&buf).map_err(ReadEnd::Wire)
}

/// Encode + write one frame; returns the bytes shipped.
fn write_frame(stream: &mut Stream, frame: &Frame) -> std::io::Result<u64> {
    let bytes = frame.encode();
    stream.write_all(&bytes)?;
    stream.flush()?;
    Ok(bytes.len() as u64)
}

/// Per-peer counters shared with the master's reader threads.
#[derive(Default)]
struct AtomicPeerStats {
    sent_bytes: AtomicU64,
    recv_bytes: AtomicU64,
    sent_frames: AtomicU64,
    recv_frames: AtomicU64,
}

impl AtomicPeerStats {
    fn snapshot(&self) -> PeerStats {
        PeerStats {
            sent_bytes: self.sent_bytes.load(Ordering::Relaxed),
            recv_bytes: self.recv_bytes.load(Ordering::Relaxed),
            sent_frames: self.sent_frames.load(Ordering::Relaxed),
            recv_frames: self.recv_frames.load(Ordering::Relaxed),
        }
    }
}

enum ListenerInner {
    Tcp(TcpListener),
    Unix(UnixListener),
}

/// The master's bound-but-not-yet-formed cluster endpoint.
pub struct SocketListener {
    inner: ListenerInner,
    desc: String,
    accept_timeout_secs: f64,
    read_timeout_secs: f64,
}

impl SocketListener {
    /// Bind the master's listen address (`cfg.listen`): `host:port`
    /// for tcp (port 0 picks a free port), a filesystem path for uds
    /// (a stale socket file is replaced).
    pub fn bind(cfg: &TransportCfg) -> anyhow::Result<SocketListener> {
        anyhow::ensure!(!cfg.listen.is_empty(), "transport.listen is empty: nowhere to bind");
        let (inner, desc) = match cfg.backend {
            TransportBackend::Tcp => {
                let l = TcpListener::bind(&cfg.listen)
                    .with_context(|| format!("binding tcp listener on {}", cfg.listen))?;
                let desc = l
                    .local_addr()
                    .map(|a| a.to_string())
                    .unwrap_or_else(|_| cfg.listen.clone());
                (ListenerInner::Tcp(l), desc)
            }
            TransportBackend::Uds => {
                let _ = std::fs::remove_file(&cfg.listen);
                let l = UnixListener::bind(&cfg.listen)
                    .with_context(|| format!("binding unix socket at {}", cfg.listen))?;
                (ListenerInner::Unix(l), cfg.listen.clone())
            }
            TransportBackend::InProcess => {
                anyhow::bail!("the in-process backend has no listener; use transport tcp or uds")
            }
        };
        Ok(SocketListener {
            inner,
            desc,
            accept_timeout_secs: cfg.accept_timeout_secs,
            read_timeout_secs: cfg.read_timeout_secs,
        })
    }

    /// The actual bound address — for tcp this resolves a port-0 bind
    /// to the assigned port.
    pub fn local_desc(&self) -> &str {
        &self.desc
    }

    /// Accept and handshake exactly `k` workers, then start the
    /// per-peer reader threads. Worker ids are assigned in accept
    /// order. Fails (naming the listen address, the configured
    /// timeout, and the partial count) if the cluster does not form in
    /// time.
    pub fn accept_cluster(self, k: usize) -> anyhow::Result<SocketMaster> {
        self.accept_cluster_version(k, WIRE_VERSION)
    }

    fn accept_cluster_version(self, k: usize, version: u32) -> anyhow::Result<SocketMaster> {
        anyhow::ensure!(k > 0, "a cluster needs at least one worker");
        match &self.inner {
            ListenerInner::Tcp(l) => l.set_nonblocking(true),
            ListenerInner::Unix(l) => l.set_nonblocking(true),
        }
        .context("setting listener nonblocking")?;
        let deadline = timeout_of(self.accept_timeout_secs).map(|d| Instant::now() + d);
        let mut streams: Vec<Stream> = Vec::with_capacity(k);
        while streams.len() < k {
            let accepted = match &self.inner {
                ListenerInner::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
                ListenerInner::Unix(l) => l.accept().map(|(s, _)| Stream::Unix(s)),
            };
            match accepted {
                Ok(stream) => {
                    let id = streams.len();
                    handshake_accepted(
                        &stream,
                        &format!("worker {id}"),
                        version,
                        &self.desc,
                        self.accept_timeout_secs,
                        self.read_timeout_secs,
                    )?;
                    streams.push(stream);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    if let Some(dl) = deadline {
                        if Instant::now() >= dl {
                            anyhow::bail!(
                                "timed out after {:.1}s waiting for {k} workers on {} \
                                 ({} of {k} connected)",
                                self.accept_timeout_secs,
                                self.desc,
                                streams.len(),
                            );
                        }
                    }
                    std::thread::sleep(RETRY_EVERY);
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => {
                    return Err(anyhow::Error::new(e)
                        .context(format!("accepting a worker on {}", self.desc)))
                }
            }
        }

        // Cluster formed: reader thread + shared counters per peer.
        let stats: Vec<Arc<AtomicPeerStats>> =
            (0..k).map(|_| Arc::new(AtomicPeerStats::default())).collect();
        let (tx_ev, rx_ev) = mailbox::<Event>();
        let mut writers = Vec::with_capacity(k);
        let mut threads = Vec::with_capacity(k);
        for (peer, stream) in streams.into_iter().enumerate() {
            stream
                .set_read_timeout(timeout_of(self.read_timeout_secs))
                .with_context(|| format!("setting read timeout for worker {peer}"))?;
            let reader = stream
                .try_clone()
                .with_context(|| format!("cloning worker {peer}'s stream for reads"))?;
            threads.push(spawn_reader(peer, 0, reader, tx_ev.clone(), Arc::clone(&stats[peer])));
            writers.push(Some(stream));
        }
        // The listener stays alive for the rest of the run so a severed
        // worker can dial back in and introduce itself with `Rejoin`.
        let stop = Arc::new(AtomicBool::new(false));
        let acceptor = spawn_rejoin_acceptor(
            self.inner,
            self.desc,
            version,
            self.accept_timeout_secs,
            self.read_timeout_secs,
            tx_ev.clone(),
            Arc::clone(&stop),
        );
        Ok(SocketMaster {
            writers,
            rx: rx_ev,
            tx: tx_ev,
            stats,
            threads,
            acceptor: Some(acceptor),
            stop,
            gen: vec![0; k],
            read_timeout_secs: self.read_timeout_secs,
        })
    }
}

/// Server side of the magic + version handshake. A mismatching worker
/// is told our version (so *its* error reports both) and refused here
/// with an error reporting both too. Free function so the rejoin
/// acceptor can handshake after the `SocketListener` has been consumed.
fn handshake_accepted(
    stream: &Stream,
    who: &str,
    version: u32,
    desc: &str,
    accept_timeout_secs: f64,
    read_timeout_secs: f64,
) -> anyhow::Result<()> {
    stream.set_nonblocking(false).context("unsetting nonblocking on accepted stream")?;
    if let Stream::Tcp(s) = stream {
        s.set_nodelay(true).context("setting TCP_NODELAY")?;
    }
    let handshake_timeout =
        timeout_of(accept_timeout_secs).or_else(|| timeout_of(read_timeout_secs));
    stream.set_read_timeout(handshake_timeout).context("setting handshake read timeout")?;
    let mut hello = [0u8; HANDSHAKE_LEN];
    let mut s = stream.try_clone().context("cloning stream for handshake")?;
    fill(&mut s, &mut hello, true).map_err(|end| {
        anyhow::anyhow!("{who} on {desc} sent no hello: {}", describe_end(&end))
    })?;
    let theirs =
        decode_hello(&hello).with_context(|| format!("bad hello from {who} on {desc}"))?;
    if theirs != version {
        let _ = s.write_all(&encode_ack(version, ACK_VERSION_MISMATCH));
        let _ = s.flush();
        stream.shutdown_both();
        anyhow::bail!(
            "{who} on {desc}: protocol version mismatch: \
             master speaks v{version}, worker speaks v{theirs}",
        );
    }
    s.write_all(&encode_ack(version, ACK_OK))
        .and_then(|_| s.flush())
        .with_context(|| format!("acking {who} on {desc}"))?;
    Ok(())
}

/// What flows from the reader / rejoin-acceptor threads to the
/// [`SocketMaster`]'s single readiness queue.
enum Event {
    /// A frame (or read failure) from worker `peer`'s reader thread of
    /// generation `gen`. Events from a stale generation — the orphaned
    /// reader of a stream that a rejoin has since replaced — are
    /// silently dropped on receipt.
    Frame { peer: usize, gen: u64, res: Result<Frame, ReadEnd> },
    /// A fresh connection handshook and introduced itself with a
    /// `Rejoin` frame; `SocketMaster` swaps it in for the peer it names.
    Rejoined { stream: Stream, info: RejoinInfo },
}

/// One per-peer reader: decode frames off the socket and feed the
/// master's readiness queue. A boundary read timeout leaves the byte
/// stream in sync, so it is *reported and survived* — the master turns
/// it into a suspicion strike while the reader keeps listening. Every
/// other failure ends the reader (a rejoin spawns a successor under a
/// new generation).
fn spawn_reader(
    peer: usize,
    gen: u64,
    mut reader: Stream,
    tx: Sender<Event>,
    st: Arc<AtomicPeerStats>,
) -> JoinHandle<()> {
    std::thread::spawn(move || loop {
        match read_frame(&mut reader) {
            Ok(frame) => {
                st.recv_bytes.fetch_add(frame.wire_len() as u64, Ordering::Relaxed);
                st.recv_frames.fetch_add(1, Ordering::Relaxed);
                if tx.send(Event::Frame { peer, gen, res: Ok(frame) }).is_err() {
                    return;
                }
            }
            Err(end) => {
                let resumable = matches!(end, ReadEnd::Timeout);
                if tx.send(Event::Frame { peer, gen, res: Err(end) }).is_err() || !resumable {
                    return;
                }
            }
        }
    })
}

/// The post-formation accept loop: any connection arriving after the
/// cluster formed must handshake and open with a `Rejoin` frame, or it
/// is turned away. Runs until the master drops (stop flag) or the
/// listener dies.
fn spawn_rejoin_acceptor(
    listener: ListenerInner,
    desc: String,
    version: u32,
    accept_timeout_secs: f64,
    read_timeout_secs: f64,
    tx: Sender<Event>,
    stop: Arc<AtomicBool>,
) -> JoinHandle<()> {
    std::thread::spawn(move || loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        let accepted = match &listener {
            ListenerInner::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
            ListenerInner::Unix(l) => l.accept().map(|(s, _)| Stream::Unix(s)),
        };
        match accepted {
            Ok(stream) => {
                if handshake_accepted(
                    &stream,
                    "a rejoining worker",
                    version,
                    &desc,
                    accept_timeout_secs,
                    read_timeout_secs,
                )
                .is_err()
                {
                    stream.shutdown_both();
                    continue;
                }
                let Ok(mut reader) = stream.try_clone() else {
                    stream.shutdown_both();
                    continue;
                };
                match read_frame(&mut reader) {
                    Ok(Frame::Rejoin(info)) => {
                        if stream.set_read_timeout(timeout_of(read_timeout_secs)).is_err() {
                            stream.shutdown_both();
                            continue;
                        }
                        if tx.send(Event::Rejoined { stream, info }).is_err() {
                            return;
                        }
                    }
                    _ => stream.shutdown_both(),
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => std::thread::sleep(RETRY_EVERY),
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => return,
        }
    })
}

fn describe_end(end: &ReadEnd) -> String {
    match end {
        ReadEnd::Eof => "connection closed".to_string(),
        ReadEnd::MidFrame => "connection closed mid-frame".to_string(),
        ReadEnd::Timeout => "read timed out".to_string(),
        ReadEnd::Io(e) => e.clone(),
        ReadEnd::Wire(e) => e.to_string(),
    }
}

/// Master endpoint of a formed socket cluster.
///
/// `writers[p]` is `None` once peer `p` has been
/// [`disconnect`](Transport::disconnect)ed; `gen[p]` counts reader
/// generations so a replaced reader's queued events are ignored after a
/// rejoin swaps the underlying stream.
pub struct SocketMaster {
    writers: Vec<Option<Stream>>,
    rx: Receiver<Event>,
    /// Kept alive to hand to replacement reader threads on rejoin.
    /// (Because the master holds a sender, `rx` never reports `Closed`
    /// on its own — peer liveness is tracked per-peer upstairs.)
    tx: Sender<Event>,
    stats: Vec<Arc<AtomicPeerStats>>,
    threads: Vec<JoinHandle<()>>,
    acceptor: Option<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    gen: Vec<u64>,
    read_timeout_secs: f64,
}

impl SocketMaster {
    fn end_to_error(&self, peer: usize, end: ReadEnd) -> TransportError {
        match end {
            ReadEnd::Wire(err) => TransportError::Wire { peer, err },
            ReadEnd::Timeout => TransportError::PeerSilent {
                peer,
                detail: format!(
                    "worker {peer} silent past the {:.1}s read timeout (connection still up)",
                    self.read_timeout_secs
                ),
            },
            other => TransportError::PeerGone { peer, detail: describe_end(&other) },
        }
    }

    /// Swap in a rejoined worker's fresh connection: bump the reader
    /// generation (orphaning the old reader's queued events), bill the
    /// `Rejoin` frame, start a replacement reader, and replace the
    /// writer. `false` for a `worker_id` outside the cluster (the
    /// stream is dropped on the floor).
    fn install_rejoin(&mut self, stream: Stream, info: RejoinInfo) -> bool {
        let peer = info.worker_id;
        if peer >= self.writers.len() {
            stream.shutdown_both();
            return false;
        }
        let Ok(reader) = stream.try_clone() else {
            stream.shutdown_both();
            return false;
        };
        self.gen[peer] += 1;
        self.stats[peer]
            .recv_bytes
            .fetch_add(Frame::Rejoin(info).wire_len() as u64, Ordering::Relaxed);
        self.stats[peer].recv_frames.fetch_add(1, Ordering::Relaxed);
        self.threads.push(spawn_reader(
            peer,
            self.gen[peer],
            reader,
            self.tx.clone(),
            Arc::clone(&self.stats[peer]),
        ));
        if let Some(old) = self.writers[peer].replace(stream) {
            old.shutdown_both();
        }
        true
    }

    /// Translate one queued event; `None` means "stale, keep waiting".
    fn step(&mut self, ev: Event) -> Option<Result<(usize, Frame), TransportError>> {
        match ev {
            Event::Frame { peer, gen, res } => {
                if gen != self.gen[peer] {
                    return None; // orphaned reader of a replaced stream
                }
                Some(match res {
                    Ok(frame) => Ok((peer, frame)),
                    Err(end) => Err(self.end_to_error(peer, end)),
                })
            }
            Event::Rejoined { stream, info } => {
                if self.install_rejoin(stream, info) {
                    Some(Ok((info.worker_id, Frame::Rejoin(info))))
                } else {
                    None
                }
            }
        }
    }
}

impl Transport for SocketMaster {
    fn send(&mut self, to: usize, frame: Frame) -> Result<(), TransportError> {
        assert!(to < self.writers.len(), "master send to unknown peer {to}");
        let Some(stream) = self.writers[to].as_mut() else {
            return Err(TransportError::PeerGone {
                peer: to,
                detail: "worker disconnected (no live link)".to_string(),
            });
        };
        match write_frame(stream, &frame) {
            Ok(bytes) => {
                self.stats[to].sent_bytes.fetch_add(bytes, Ordering::Relaxed);
                self.stats[to].sent_frames.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err(e) => Err(TransportError::PeerGone {
                peer: to,
                detail: format!("send of {} frame failed: {e}", frame.kind_name()),
            }),
        }
    }

    fn recv(&mut self) -> Result<(usize, Frame), TransportError> {
        loop {
            match self.rx.recv() {
                Ok(ev) => {
                    if let Some(out) = self.step(ev) {
                        return out;
                    }
                }
                Err(_) => return Err(TransportError::Closed),
            }
        }
    }

    fn recv_timeout(
        &mut self,
        dur: std::time::Duration,
    ) -> Result<Option<(usize, Frame)>, TransportError> {
        let deadline = Instant::now() + dur;
        loop {
            let Some(left) = deadline.checked_duration_since(Instant::now()) else {
                return Ok(None);
            };
            match self.rx.recv_timeout(left) {
                Ok(Some(ev)) => {
                    if let Some(out) = self.step(ev) {
                        return out.map(Some);
                    }
                }
                Ok(None) => return Ok(None),
                Err(_) => return Err(TransportError::Closed),
            }
        }
    }

    fn disconnect(&mut self, peer: usize) {
        if peer >= self.writers.len() {
            return;
        }
        // Orphan the peer's reader first so the EOF report caused by
        // this very shutdown is not mistaken for fresh news.
        self.gen[peer] += 1;
        if let Some(stream) = self.writers[peer].take() {
            stream.shutdown_both();
        }
    }

    fn peers(&self) -> usize {
        self.writers.len()
    }

    fn stats(&self) -> TransportStats {
        TransportStats { per_peer: self.stats.iter().map(|s| s.snapshot()).collect() }
    }
}

impl Drop for SocketMaster {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        for w in self.writers.iter().flatten() {
            w.shutdown_both();
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
    }
}

/// One dial attempt at `addr`, no retry and no handshake.
fn dial_once(cfg: &TransportCfg, addr: &str) -> std::io::Result<Stream> {
    match cfg.backend {
        TransportBackend::Tcp => TcpStream::connect(addr).map(Stream::Tcp),
        TransportBackend::Uds => UnixStream::connect(addr).map(Stream::Unix),
        TransportBackend::InProcess => Err(std::io::Error::new(
            ErrorKind::Unsupported,
            "the in-process backend has no socket; use transport tcp or uds",
        )),
    }
}

/// Client side of the magic + version handshake; returns the stream
/// configured with its steady-state read timeout.
fn handshake_with_master(
    mut stream: Stream,
    addr: &str,
    version: u32,
    cfg: &TransportCfg,
) -> anyhow::Result<Stream> {
    if let Stream::Tcp(s) = &stream {
        s.set_nodelay(true).context("setting TCP_NODELAY")?;
    }
    // Handshake under the connect deadline, then steady-state timeout.
    let handshake_timeout =
        timeout_of(cfg.connect_timeout_secs).or_else(|| timeout_of(cfg.read_timeout_secs));
    stream.set_read_timeout(handshake_timeout).context("setting handshake read timeout")?;
    stream
        .write_all(&encode_hello(version))
        .and_then(|_| stream.flush())
        .with_context(|| format!("sending hello to master at {addr}"))?;
    let mut ack = [0u8; HANDSHAKE_LEN];
    fill(&mut stream, &mut ack, true).map_err(|end| {
        anyhow::anyhow!(
            "no handshake ack from master at {addr} within {:.1}s: {}",
            cfg.connect_timeout_secs,
            describe_end(&end),
        )
    })?;
    decode_ack(&ack, version).with_context(|| format!("handshake with master at {addr}"))?;
    stream
        .set_read_timeout(timeout_of(cfg.read_timeout_secs))
        .context("setting read timeout")?;
    Ok(stream)
}

/// Worker endpoint: one connection to the master. Keeps its
/// [`TransportCfg`] so a severed link can be redialed
/// ([`Transport::reconnect`]) with the configured backoff schedule.
pub struct SocketWorker {
    stream: Stream,
    addr: String,
    stats: TransportStats,
    cfg: TransportCfg,
}

impl SocketWorker {
    /// Dial the master at `cfg.join` and handshake. Connection refusal
    /// is retried until `connect_timeout_secs` (workers may start
    /// before the master listens); the timeout error names the address
    /// and the configured bound. A zero timeout *disables* the deadline
    /// (retry until the master appears), consistent with the
    /// 0-disables rule of the accept/read timeouts.
    pub fn connect(cfg: &TransportCfg) -> anyhow::Result<SocketWorker> {
        Self::connect_version(cfg, WIRE_VERSION)
    }

    fn connect_version(cfg: &TransportCfg, version: u32) -> anyhow::Result<SocketWorker> {
        let addr = cfg.join.clone();
        anyhow::ensure!(!addr.is_empty(), "transport.join is empty: no master address");
        anyhow::ensure!(
            cfg.backend != TransportBackend::InProcess,
            "the in-process backend has no socket; use transport tcp or uds"
        );
        let deadline = timeout_of(cfg.connect_timeout_secs).map(|d| Instant::now() + d);
        let stream = loop {
            match dial_once(cfg, &addr) {
                Ok(s) => break s,
                // Refused / not-yet-bound are retried: the master may
                // simply not be listening yet.
                Err(e)
                    if matches!(e.kind(), ErrorKind::ConnectionRefused | ErrorKind::NotFound) =>
                {
                    if let Some(dl) = deadline {
                        if Instant::now() >= dl {
                            anyhow::bail!(
                                "could not connect to master at {addr} within {:.1}s: {e}",
                                cfg.connect_timeout_secs,
                            );
                        }
                    }
                    std::thread::sleep(RETRY_EVERY);
                }
                Err(e) => {
                    return Err(
                        anyhow::Error::new(e).context(format!("connecting to master at {addr}"))
                    )
                }
            }
        };
        let stream = handshake_with_master(stream, &addr, version, cfg)?;

        let mut stats = TransportStats::new(1);
        stats.per_peer[MASTER].sent_bytes = HANDSHAKE_LEN as u64;
        stats.per_peer[MASTER].recv_bytes = HANDSHAKE_LEN as u64;
        Ok(SocketWorker { stream, addr, stats, cfg: cfg.clone() })
    }

    /// The master's address, for error messages.
    pub fn master_addr(&self) -> &str {
        &self.addr
    }
}

impl Transport for SocketWorker {
    fn send(&mut self, to: usize, frame: Frame) -> Result<(), TransportError> {
        assert_eq!(to, MASTER, "a worker's only peer is the master");
        match write_frame(&mut self.stream, &frame) {
            Ok(bytes) => {
                self.stats.per_peer[MASTER].sent_bytes += bytes;
                self.stats.per_peer[MASTER].sent_frames += 1;
                Ok(())
            }
            Err(e) => Err(TransportError::PeerGone {
                peer: MASTER,
                detail: format!("master at {} disconnected: {e}", self.addr),
            }),
        }
    }

    fn recv(&mut self) -> Result<(usize, Frame), TransportError> {
        match read_frame(&mut self.stream) {
            Ok(frame) => {
                self.stats.per_peer[MASTER].recv_bytes += frame.wire_len() as u64;
                self.stats.per_peer[MASTER].recv_frames += 1;
                Ok((MASTER, frame))
            }
            Err(ReadEnd::Wire(err)) => Err(TransportError::Wire { peer: MASTER, err }),
            Err(ReadEnd::Timeout) => Err(TransportError::PeerSilent {
                peer: MASTER,
                detail: format!(
                    "master at {} silent past the {:.1}s read timeout (connection still up)",
                    self.addr, self.cfg.read_timeout_secs
                ),
            }),
            Err(end) => Err(TransportError::PeerGone {
                peer: MASTER,
                detail: format!("master at {} disconnected: {}", self.addr, describe_end(&end)),
            }),
        }
    }

    fn reconnect(&mut self, info: &RejoinInfo) -> Result<bool, TransportError> {
        if self.cfg.reconnect_attempts == 0 {
            return Ok(false);
        }
        self.stream.shutdown_both();
        // Deterministic jitter: ±25% around the exponential schedule,
        // seeded per worker so a severed cluster's redial herd spreads
        // out while reruns stay reproducible.
        let mut rng = Rng::new(0x9E37_79B9_7F4A_7C15 ^ info.worker_id as u64);
        for attempt in 0..self.cfg.reconnect_attempts {
            let exp = self.cfg.backoff_base_secs * f64::from(1u32 << attempt.min(20));
            let delay = exp.min(self.cfg.backoff_max_secs) * (0.75 + 0.5 * rng.next_f64());
            if delay > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(delay));
            }
            let Ok(stream) = dial_once(&self.cfg, &self.addr) else {
                continue;
            };
            let Ok(mut stream) = handshake_with_master(stream, &self.addr, WIRE_VERSION, &self.cfg)
            else {
                continue;
            };
            // Introduce ourselves: the master's rejoin acceptor demands
            // a Rejoin as the opening frame before readmitting a link.
            match write_frame(&mut stream, &Frame::Rejoin(*info)) {
                Ok(bytes) => {
                    let p = &mut self.stats.per_peer[MASTER];
                    p.sent_bytes += HANDSHAKE_LEN as u64 + bytes;
                    p.recv_bytes += HANDSHAKE_LEN as u64;
                    p.sent_frames += 1;
                    self.stream = stream;
                    return Ok(true);
                }
                Err(_) => continue,
            }
        }
        Ok(false)
    }

    fn sever(&mut self) {
        self.stream.shutdown_both();
    }

    fn peers(&self) -> usize {
        1
    }

    fn stats(&self) -> TransportStats {
        self.stats.clone()
    }
}

impl Drop for SocketWorker {
    fn drop(&mut self) {
        self.stream.shutdown_both();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::messages::{DeltaV, WorkerMsg};

    fn tcp_cfg(listen: &str, join: &str) -> TransportCfg {
        TransportCfg {
            backend: TransportBackend::Tcp,
            listen: listen.to_string(),
            join: join.to_string(),
            connect_timeout_secs: 5.0,
            accept_timeout_secs: 5.0,
            read_timeout_secs: 5.0,
            accept_backlog: 8,
            ..TransportCfg::default()
        }
    }

    fn update_frame() -> Frame {
        Frame::Update(WorkerMsg {
            worker: 0,
            local_round: 0,
            delta_v: DeltaV::Sparse { dim: 8, indices: vec![1, 5], values: vec![0.5, -2.0] },
            dual_sum: 0.25,
            arrival_vtime: 1.5,
            updates: 10,
        })
    }

    #[test]
    fn tcp_round_trip_and_stats() {
        let listener = SocketListener::bind(&tcp_cfg("127.0.0.1:0", "")).unwrap();
        let addr = listener.local_desc().to_string();
        let worker = std::thread::spawn(move || {
            let mut w = SocketWorker::connect(&tcp_cfg("", &addr)).unwrap();
            w.send(MASTER, update_frame()).unwrap();
            let (from, reply) = w.recv().unwrap();
            assert_eq!(from, MASTER);
            assert_eq!(reply, Frame::Shutdown { vtime: 2.0, round: 1 });
            w.stats()
        });
        let mut m = listener.accept_cluster(1).unwrap();
        let (peer, frame) = m.recv().unwrap();
        assert_eq!(peer, 0);
        assert_eq!(frame, update_frame());
        m.send(0, Frame::Shutdown { vtime: 2.0, round: 1 }).unwrap();
        let wstats = worker.join().unwrap();

        let sent = update_frame().wire_len() as u64;
        let hs = HANDSHAKE_LEN as u64;
        assert_eq!(wstats.sent_bytes(), hs + sent);
        assert_eq!(m.stats().per_peer[0].recv_bytes, sent);
        assert_eq!(m.stats().per_peer[0].sent_frames, 1);
    }

    #[test]
    fn uds_round_trip() {
        let path = std::env::temp_dir().join(format!("hdca-uds-test-{}", std::process::id()));
        let path = path.to_string_lossy().to_string();
        let mut cfg = tcp_cfg(&path, &path);
        cfg.backend = TransportBackend::Uds;
        let listener = SocketListener::bind(&cfg).unwrap();
        let wcfg = cfg.clone();
        let worker = std::thread::spawn(move || {
            let mut w = SocketWorker::connect(&wcfg).unwrap();
            let (_, got) = w.recv().unwrap();
            assert_eq!(got, Frame::Shutdown { vtime: 0.5, round: 9 });
        });
        let mut m = listener.accept_cluster(1).unwrap();
        m.send(0, Frame::Shutdown { vtime: 0.5, round: 9 }).unwrap();
        worker.join().unwrap();
        drop(m);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn version_mismatch_reports_both_versions() {
        let listener = SocketListener::bind(&tcp_cfg("127.0.0.1:0", "")).unwrap();
        let addr = listener.local_desc().to_string();
        let worker = std::thread::spawn(move || {
            SocketWorker::connect_version(&tcp_cfg("", &addr), WIRE_VERSION + 1)
        });
        let master_err = listener.accept_cluster(1).unwrap_err().to_string();
        assert!(master_err.contains("version mismatch"), "{master_err}");
        assert!(
            master_err.contains(&format!("v{WIRE_VERSION}"))
                && master_err.contains(&format!("v{}", WIRE_VERSION + 1)),
            "{master_err}"
        );
        let worker_err = format!("{:#}", worker.join().unwrap().unwrap_err());
        assert!(
            worker_err.contains(&format!("v{WIRE_VERSION}"))
                && worker_err.contains(&format!("v{}", WIRE_VERSION + 1)),
            "{worker_err}"
        );
    }

    #[test]
    fn connect_refused_names_peer_and_timeout() {
        // Bind then drop to get a port with (very likely) no listener.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let mut cfg = tcp_cfg("", &addr);
        cfg.connect_timeout_secs = 0.3;
        let err = SocketWorker::connect(&cfg).unwrap_err().to_string();
        assert!(err.contains(&addr), "{err}");
        assert!(err.contains("0.3"), "{err}");
    }

    #[test]
    fn accept_timeout_names_listener_and_timeout() {
        let mut cfg = tcp_cfg("127.0.0.1:0", "");
        cfg.accept_timeout_secs = 0.3;
        let listener = SocketListener::bind(&cfg).unwrap();
        let desc = listener.local_desc().to_string();
        let err = listener.accept_cluster(2).unwrap_err().to_string();
        assert!(err.contains(&desc), "{err}");
        assert!(err.contains("0.3"), "{err}");
        assert!(err.contains("0 of 2"), "{err}");
    }

    /// The graceful-shutdown satellite's failure half: a killed master
    /// must surface as a clear "master disconnected" on the worker
    /// within the read timeout — here immediately, via EOF on a real
    /// socket pair.
    #[test]
    fn killed_master_is_reported_as_disconnect() {
        let listener = SocketListener::bind(&tcp_cfg("127.0.0.1:0", "")).unwrap();
        let addr = listener.local_desc().to_string();
        let worker = std::thread::spawn(move || {
            let mut w = SocketWorker::connect(&tcp_cfg("", &addr)).unwrap();
            w.recv()
        });
        let m = listener.accept_cluster(1).unwrap();
        drop(m); // "kill" the master: sockets shut down
        let err = worker.join().unwrap().unwrap_err();
        match err {
            TransportError::PeerGone { peer, detail } => {
                assert_eq!(peer, MASTER);
                assert!(detail.contains("disconnected"), "{detail}");
            }
            other => panic!("expected PeerGone, got {other:?}"),
        }
    }

    /// Satellite fix: a zero connect timeout means "no deadline" —
    /// retry until the master appears — consistent with the 0-disables
    /// rule of the read/accept timeouts, not "single attempt".
    #[test]
    fn zero_connect_timeout_retries_until_master_appears() {
        let path = std::env::temp_dir().join(format!("hdca-late-{}", std::process::id()));
        let path_s = path.to_string_lossy().to_string();
        let _ = std::fs::remove_file(&path_s);
        let mut cfg = tcp_cfg(&path_s, &path_s);
        cfg.backend = TransportBackend::Uds;
        cfg.connect_timeout_secs = 0.0;
        let wcfg = cfg.clone();
        let worker = std::thread::spawn(move || {
            let mut w = SocketWorker::connect(&wcfg).unwrap();
            let (_, got) = w.recv().unwrap();
            assert_eq!(got, Frame::Shutdown { vtime: 1.0, round: 1 });
        });
        // Bind only after the worker has (very likely) already dialed
        // and been refused at least once.
        std::thread::sleep(Duration::from_millis(120));
        let listener = SocketListener::bind(&cfg).unwrap();
        let mut m = listener.accept_cluster(1).unwrap();
        m.send(0, Frame::Shutdown { vtime: 1.0, round: 1 }).unwrap();
        worker.join().unwrap();
        drop(m);
        let _ = std::fs::remove_file(&path_s);
    }

    /// A silent worker surfaces as `PeerSilent` (strike material), not
    /// `PeerGone`, and the reader keeps listening: the same connection
    /// still delivers frames afterwards.
    #[test]
    fn silent_worker_is_suspect_not_dead() {
        let mut lcfg = tcp_cfg("127.0.0.1:0", "");
        lcfg.read_timeout_secs = 0.15;
        let listener = SocketListener::bind(&lcfg).unwrap();
        let addr = listener.local_desc().to_string();
        let worker = std::thread::spawn(move || {
            let mut w = SocketWorker::connect(&tcp_cfg("", &addr)).unwrap();
            std::thread::sleep(Duration::from_millis(500));
            w.send(MASTER, update_frame()).unwrap();
        });
        let mut m = listener.accept_cluster(1).unwrap();
        let err = m.recv().unwrap_err();
        assert!(matches!(err, TransportError::PeerSilent { peer: 0, .. }), "{err:?}");
        let frame = loop {
            match m.recv() {
                Ok((0, f)) => break f,
                Err(TransportError::PeerSilent { .. }) => {}
                other => panic!("unexpected {other:?}"),
            }
        };
        assert_eq!(frame, update_frame());
        worker.join().unwrap();
    }

    /// The liveness tick: `recv_timeout` expires with `Ok(None)` when
    /// nothing is queued, without disturbing the link.
    #[test]
    fn master_recv_timeout_expires_with_none() {
        let listener = SocketListener::bind(&tcp_cfg("127.0.0.1:0", "")).unwrap();
        let addr = listener.local_desc().to_string();
        let worker = std::thread::spawn(move || {
            let mut w = SocketWorker::connect(&tcp_cfg("", &addr)).unwrap();
            w.recv()
        });
        let mut m = listener.accept_cluster(1).unwrap();
        assert_eq!(m.recv_timeout(Duration::from_millis(50)).unwrap(), None);
        m.send(0, Frame::Shutdown { vtime: 0.0, round: 0 }).unwrap();
        let (_, got) = worker.join().unwrap().unwrap();
        assert_eq!(got, Frame::Shutdown { vtime: 0.0, round: 0 });
    }

    /// A severed worker dials back in with `Rejoin`; the master swaps
    /// the link live and frames flow again on the new connection.
    #[test]
    fn severed_worker_rejoins_and_resumes() {
        let listener = SocketListener::bind(&tcp_cfg("127.0.0.1:0", "")).unwrap();
        let addr = listener.local_desc().to_string();
        let info = RejoinInfo { worker_id: 0, last_acked_round: 3, alpha_crc: 0xDEAD_BEEF };
        let worker = std::thread::spawn(move || {
            let mut cfg = tcp_cfg("", &addr);
            cfg.backoff_base_secs = 0.01;
            cfg.backoff_max_secs = 0.05;
            let mut w = SocketWorker::connect(&cfg).unwrap();
            w.send(MASTER, update_frame()).unwrap();
            w.sever();
            assert!(w.reconnect(&info).unwrap(), "reconnect gave up");
            w.send(MASTER, update_frame()).unwrap();
            let (_, reply) = w.recv().unwrap();
            assert_eq!(reply, Frame::Shutdown { vtime: 9.0, round: 9 });
        });
        let mut m = listener.accept_cluster(1).unwrap();
        let (peer, first) = m.recv().unwrap();
        assert_eq!((peer, first), (0, update_frame()));
        // The severed link may report PeerGone before the fresh
        // connection's Rejoin arrives; both orders are fine.
        let rejoin = loop {
            match m.recv() {
                Ok((0, Frame::Rejoin(got))) => break got,
                Ok(other) => panic!("unexpected frame {other:?}"),
                Err(TransportError::PeerGone { .. } | TransportError::PeerSilent { .. }) => {}
                Err(e) => panic!("unexpected transport error {e}"),
            }
        };
        assert_eq!(rejoin, info);
        let (_, second) = m.recv().unwrap();
        assert_eq!(second, update_frame());
        m.send(0, Frame::Shutdown { vtime: 9.0, round: 9 }).unwrap();
        worker.join().unwrap();
    }

    /// `disconnect` releases one peer: subsequent sends to it fail fast
    /// and its worker observes EOF.
    #[test]
    fn disconnected_peer_fails_fast_on_send() {
        let listener = SocketListener::bind(&tcp_cfg("127.0.0.1:0", "")).unwrap();
        let addr = listener.local_desc().to_string();
        let worker = std::thread::spawn(move || {
            let mut w = SocketWorker::connect(&tcp_cfg("", &addr)).unwrap();
            w.recv()
        });
        let mut m = listener.accept_cluster(1).unwrap();
        m.disconnect(0);
        let err = m.send(0, Frame::Shutdown { vtime: 0.0, round: 0 }).unwrap_err();
        assert!(matches!(err, TransportError::PeerGone { peer: 0, .. }), "{err:?}");
        let werr = worker.join().unwrap().unwrap_err();
        assert!(matches!(werr, TransportError::PeerGone { peer: MASTER, .. }), "{werr:?}");
    }
}
