//! Multi-process backend: the [`frame`](super::frame) protocol over
//! TCP or Unix-domain sockets.
//!
//! Topology mirrors the in-process one: the master binds a listener
//! ([`SocketListener::bind`]) and accepts exactly `K` workers
//! ([`SocketListener::accept_cluster`]); each worker dials in
//! ([`SocketWorker::connect`]). Worker ids are assigned in accept
//! order — the master's `Assign` frame then binds each id to its shard
//! range and RNG stream, so accept order carries no semantic weight.
//!
//! The master runs one reader thread per worker feeding a single
//! readiness queue, which is what lets `master.rs`'s bounded-barrier
//! gather block on *real socket readiness* exactly as it blocked on
//! channel readiness. Setup failures (bind/connect/accept/handshake)
//! return `anyhow` errors naming the peer address and the configured
//! timeout; steady-state failures surface as typed
//! [`TransportError`]s.

use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::util::sync::{mailbox, AtomicU64, Ordering, Receiver};

// ORDERING: the per-peer byte/frame counters are monotonic statistics
// read for reporting only (never for synchronization decisions), so
// all accesses are `Relaxed`; the reader-thread joins in `Drop` give
// snapshots taken after shutdown exact totals.

use anyhow::Context;

use super::frame::{
    arr, decode_ack, decode_hello, encode_ack, encode_hello, Frame, WireError, ACK_OK,
    ACK_VERSION_MISMATCH, FRAME_HEADER_LEN, FRAME_TRAILER_LEN, HANDSHAKE_LEN, MAX_FRAME_PAYLOAD,
    WIRE_VERSION,
};
use super::{
    PeerStats, Transport, TransportBackend, TransportCfg, TransportError, TransportStats, MASTER,
};

/// Poll interval for the nonblocking accept loop and connect retries.
const RETRY_EVERY: Duration = Duration::from_millis(25);

fn timeout_of(secs: f64) -> Option<Duration> {
    if secs > 0.0 {
        Some(Duration::from_secs_f64(secs))
    } else {
        None
    }
}

/// One connected socket, TCP or Unix-domain.
enum Stream {
    Tcp(TcpStream),
    Unix(UnixStream),
}

impl Stream {
    fn try_clone(&self) -> std::io::Result<Stream> {
        match self {
            Stream::Tcp(s) => s.try_clone().map(Stream::Tcp),
            Stream::Unix(s) => s.try_clone().map(Stream::Unix),
        }
    }

    fn set_read_timeout(&self, dur: Option<Duration>) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(dur),
            Stream::Unix(s) => s.set_read_timeout(dur),
        }
    }

    fn set_nonblocking(&self, nb: bool) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_nonblocking(nb),
            Stream::Unix(s) => s.set_nonblocking(nb),
        }
    }

    /// Half-close both directions; unblocks any reader sharing the
    /// underlying socket. Errors ignored — this is teardown.
    fn shutdown_both(&self) {
        match self {
            Stream::Tcp(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
            Stream::Unix(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            Stream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            Stream::Unix(s) => s.flush(),
        }
    }
}

/// Why a read loop stopped.
#[derive(Debug)]
enum ReadEnd {
    /// Clean EOF on a frame boundary.
    Eof,
    /// EOF in the middle of a frame.
    MidFrame,
    /// No bytes within the read timeout.
    Timeout,
    /// Some other I/O failure.
    Io(String),
    /// Bytes arrived but did not decode.
    Wire(WireError),
}

/// Fill `buf` completely. `at_boundary` marks whether EOF before the
/// first byte is a clean close (frame boundary) or a truncation.
fn fill(stream: &mut Stream, buf: &mut [u8], at_boundary: bool) -> Result<(), ReadEnd> {
    let mut off = 0;
    while off < buf.len() {
        match stream.read(&mut buf[off..]) {
            Ok(0) => {
                return Err(if at_boundary && off == 0 { ReadEnd::Eof } else { ReadEnd::MidFrame })
            }
            Ok(n) => off += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                return Err(ReadEnd::Timeout)
            }
            Err(e) => return Err(ReadEnd::Io(e.to_string())),
        }
    }
    Ok(())
}

/// Read one complete frame: header first (its length prefix is
/// sanity-capped before any allocation), then payload + CRC, then the
/// full validated decode.
fn read_frame(stream: &mut Stream) -> Result<Frame, ReadEnd> {
    let mut header = [0u8; FRAME_HEADER_LEN];
    fill(stream, &mut header, true)?;
    let payload_len = u64::from_le_bytes(
        arr(&header[12..20], "header.payload_len").map_err(ReadEnd::Wire)?,
    );
    if payload_len > MAX_FRAME_PAYLOAD {
        return Err(ReadEnd::Wire(WireError::Oversized { len: payload_len }));
    }
    let total = FRAME_HEADER_LEN + payload_len as usize + FRAME_TRAILER_LEN;
    let mut buf = vec![0u8; total];
    buf[..FRAME_HEADER_LEN].copy_from_slice(&header);
    fill(stream, &mut buf[FRAME_HEADER_LEN..], false)?;
    Frame::decode(&buf).map_err(ReadEnd::Wire)
}

/// Encode + write one frame; returns the bytes shipped.
fn write_frame(stream: &mut Stream, frame: &Frame) -> std::io::Result<u64> {
    let bytes = frame.encode();
    stream.write_all(&bytes)?;
    stream.flush()?;
    Ok(bytes.len() as u64)
}

/// Per-peer counters shared with the master's reader threads.
#[derive(Default)]
struct AtomicPeerStats {
    sent_bytes: AtomicU64,
    recv_bytes: AtomicU64,
    sent_frames: AtomicU64,
    recv_frames: AtomicU64,
}

impl AtomicPeerStats {
    fn snapshot(&self) -> PeerStats {
        PeerStats {
            sent_bytes: self.sent_bytes.load(Ordering::Relaxed),
            recv_bytes: self.recv_bytes.load(Ordering::Relaxed),
            sent_frames: self.sent_frames.load(Ordering::Relaxed),
            recv_frames: self.recv_frames.load(Ordering::Relaxed),
        }
    }
}

enum ListenerInner {
    Tcp(TcpListener),
    Unix(UnixListener),
}

/// The master's bound-but-not-yet-formed cluster endpoint.
pub struct SocketListener {
    inner: ListenerInner,
    desc: String,
    accept_timeout_secs: f64,
    read_timeout_secs: f64,
}

impl SocketListener {
    /// Bind the master's listen address (`cfg.listen`): `host:port`
    /// for tcp (port 0 picks a free port), a filesystem path for uds
    /// (a stale socket file is replaced).
    pub fn bind(cfg: &TransportCfg) -> anyhow::Result<SocketListener> {
        anyhow::ensure!(!cfg.listen.is_empty(), "transport.listen is empty: nowhere to bind");
        let (inner, desc) = match cfg.backend {
            TransportBackend::Tcp => {
                let l = TcpListener::bind(&cfg.listen)
                    .with_context(|| format!("binding tcp listener on {}", cfg.listen))?;
                let desc = l
                    .local_addr()
                    .map(|a| a.to_string())
                    .unwrap_or_else(|_| cfg.listen.clone());
                (ListenerInner::Tcp(l), desc)
            }
            TransportBackend::Uds => {
                let _ = std::fs::remove_file(&cfg.listen);
                let l = UnixListener::bind(&cfg.listen)
                    .with_context(|| format!("binding unix socket at {}", cfg.listen))?;
                (ListenerInner::Unix(l), cfg.listen.clone())
            }
            TransportBackend::InProcess => {
                anyhow::bail!("the in-process backend has no listener; use transport tcp or uds")
            }
        };
        Ok(SocketListener {
            inner,
            desc,
            accept_timeout_secs: cfg.accept_timeout_secs,
            read_timeout_secs: cfg.read_timeout_secs,
        })
    }

    /// The actual bound address — for tcp this resolves a port-0 bind
    /// to the assigned port.
    pub fn local_desc(&self) -> &str {
        &self.desc
    }

    /// Accept and handshake exactly `k` workers, then start the
    /// per-peer reader threads. Worker ids are assigned in accept
    /// order. Fails (naming the listen address, the configured
    /// timeout, and the partial count) if the cluster does not form in
    /// time.
    pub fn accept_cluster(self, k: usize) -> anyhow::Result<SocketMaster> {
        self.accept_cluster_version(k, WIRE_VERSION)
    }

    fn accept_cluster_version(self, k: usize, version: u32) -> anyhow::Result<SocketMaster> {
        anyhow::ensure!(k > 0, "a cluster needs at least one worker");
        match &self.inner {
            ListenerInner::Tcp(l) => l.set_nonblocking(true),
            ListenerInner::Unix(l) => l.set_nonblocking(true),
        }
        .context("setting listener nonblocking")?;
        let deadline = timeout_of(self.accept_timeout_secs).map(|d| Instant::now() + d);
        let mut streams: Vec<Stream> = Vec::with_capacity(k);
        while streams.len() < k {
            let accepted = match &self.inner {
                ListenerInner::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
                ListenerInner::Unix(l) => l.accept().map(|(s, _)| Stream::Unix(s)),
            };
            match accepted {
                Ok(stream) => {
                    let id = streams.len();
                    self.handshake_accepted(&stream, id, version)?;
                    streams.push(stream);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    if let Some(dl) = deadline {
                        if Instant::now() >= dl {
                            anyhow::bail!(
                                "timed out after {:.1}s waiting for {k} workers on {} \
                                 ({} of {k} connected)",
                                self.accept_timeout_secs,
                                self.desc,
                                streams.len(),
                            );
                        }
                    }
                    std::thread::sleep(RETRY_EVERY);
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => {
                    return Err(anyhow::Error::new(e)
                        .context(format!("accepting a worker on {}", self.desc)))
                }
            }
        }

        // Cluster formed: reader thread + shared counters per peer.
        let stats: Vec<Arc<AtomicPeerStats>> =
            (0..k).map(|_| Arc::new(AtomicPeerStats::default())).collect();
        let (tx_ev, rx_ev) = mailbox::<(usize, Result<Frame, ReadEnd>)>();
        let mut writers = Vec::with_capacity(k);
        let mut threads = Vec::with_capacity(k);
        for (peer, stream) in streams.into_iter().enumerate() {
            stream
                .set_read_timeout(timeout_of(self.read_timeout_secs))
                .with_context(|| format!("setting read timeout for worker {peer}"))?;
            let reader = stream
                .try_clone()
                .with_context(|| format!("cloning worker {peer}'s stream for reads"))?;
            let tx = tx_ev.clone();
            let st = Arc::clone(&stats[peer]);
            threads.push(std::thread::spawn(move || {
                let mut reader = reader;
                loop {
                    match read_frame(&mut reader) {
                        Ok(frame) => {
                            st.recv_bytes.fetch_add(frame.wire_len() as u64, Ordering::Relaxed);
                            st.recv_frames.fetch_add(1, Ordering::Relaxed);
                            if tx.send((peer, Ok(frame))).is_err() {
                                return;
                            }
                        }
                        Err(end) => {
                            let _ = tx.send((peer, Err(end)));
                            return;
                        }
                    }
                }
            }));
            writers.push(stream);
        }
        drop(tx_ev);
        Ok(SocketMaster {
            writers,
            rx: rx_ev,
            stats,
            threads,
            read_timeout_secs: self.read_timeout_secs,
        })
    }

    /// Server side of the magic + version handshake. A mismatching
    /// worker is told our version (so *its* error reports both) and
    /// refused here with an error reporting both too.
    fn handshake_accepted(&self, stream: &Stream, id: usize, version: u32) -> anyhow::Result<()> {
        stream.set_nonblocking(false).context("unsetting nonblocking on accepted stream")?;
        if let Stream::Tcp(s) = stream {
            s.set_nodelay(true).context("setting TCP_NODELAY")?;
        }
        let handshake_timeout =
            timeout_of(self.accept_timeout_secs).or_else(|| timeout_of(self.read_timeout_secs));
        stream.set_read_timeout(handshake_timeout).context("setting handshake read timeout")?;
        let mut hello = [0u8; HANDSHAKE_LEN];
        let mut s = stream.try_clone().context("cloning stream for handshake")?;
        fill(&mut s, &mut hello, true).map_err(|end| {
            anyhow::anyhow!("worker {id} on {} sent no hello: {}", self.desc, describe_end(&end))
        })?;
        let theirs = decode_hello(&hello)
            .with_context(|| format!("bad hello from worker {id} on {}", self.desc))?;
        if theirs != version {
            let _ = s.write_all(&encode_ack(version, ACK_VERSION_MISMATCH));
            let _ = s.flush();
            stream.shutdown_both();
            anyhow::bail!(
                "worker {id} on {}: protocol version mismatch: \
                 master speaks v{version}, worker speaks v{theirs}",
                self.desc,
            );
        }
        s.write_all(&encode_ack(version, ACK_OK))
            .and_then(|_| s.flush())
            .with_context(|| format!("acking worker {id} on {}", self.desc))?;
        Ok(())
    }
}

fn describe_end(end: &ReadEnd) -> String {
    match end {
        ReadEnd::Eof => "connection closed".to_string(),
        ReadEnd::MidFrame => "connection closed mid-frame".to_string(),
        ReadEnd::Timeout => "read timed out".to_string(),
        ReadEnd::Io(e) => e.clone(),
        ReadEnd::Wire(e) => e.to_string(),
    }
}

/// Master endpoint of a formed socket cluster.
pub struct SocketMaster {
    writers: Vec<Stream>,
    rx: Receiver<(usize, Result<Frame, ReadEnd>)>,
    stats: Vec<Arc<AtomicPeerStats>>,
    threads: Vec<JoinHandle<()>>,
    read_timeout_secs: f64,
}

impl SocketMaster {
    fn end_to_error(&self, peer: usize, end: ReadEnd) -> TransportError {
        match end {
            ReadEnd::Wire(err) => TransportError::Wire { peer, err },
            ReadEnd::Timeout => TransportError::PeerGone {
                peer,
                detail: format!(
                    "worker silent past the {:.1}s read timeout",
                    self.read_timeout_secs
                ),
            },
            other => TransportError::PeerGone { peer, detail: describe_end(&other) },
        }
    }
}

impl Transport for SocketMaster {
    fn send(&mut self, to: usize, frame: Frame) -> Result<(), TransportError> {
        assert!(to < self.writers.len(), "master send to unknown peer {to}");
        match write_frame(&mut self.writers[to], &frame) {
            Ok(bytes) => {
                self.stats[to].sent_bytes.fetch_add(bytes, Ordering::Relaxed);
                self.stats[to].sent_frames.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err(e) => Err(TransportError::PeerGone {
                peer: to,
                detail: format!("send of {} frame failed: {e}", frame.kind_name()),
            }),
        }
    }

    fn recv(&mut self) -> Result<(usize, Frame), TransportError> {
        match self.rx.recv() {
            Ok((peer, Ok(frame))) => Ok((peer, frame)),
            Ok((peer, Err(end))) => Err(self.end_to_error(peer, end)),
            Err(_) => Err(TransportError::Closed),
        }
    }

    fn peers(&self) -> usize {
        self.writers.len()
    }

    fn stats(&self) -> TransportStats {
        TransportStats { per_peer: self.stats.iter().map(|s| s.snapshot()).collect() }
    }
}

impl Drop for SocketMaster {
    fn drop(&mut self) {
        for w in &self.writers {
            w.shutdown_both();
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Worker endpoint: one connection to the master.
pub struct SocketWorker {
    stream: Stream,
    addr: String,
    stats: TransportStats,
    read_timeout_secs: f64,
}

impl SocketWorker {
    /// Dial the master at `cfg.join` and handshake. Connection refusal
    /// is retried until `connect_timeout_secs` (workers may start
    /// before the master listens); the timeout error names the address
    /// and the configured bound.
    pub fn connect(cfg: &TransportCfg) -> anyhow::Result<SocketWorker> {
        Self::connect_version(cfg, WIRE_VERSION)
    }

    fn connect_version(cfg: &TransportCfg, version: u32) -> anyhow::Result<SocketWorker> {
        let addr = cfg.join.clone();
        anyhow::ensure!(!addr.is_empty(), "transport.join is empty: no master address");
        let deadline = timeout_of(cfg.connect_timeout_secs).map(|d| Instant::now() + d);
        let stream = loop {
            let attempt = match cfg.backend {
                TransportBackend::Tcp => TcpStream::connect(&addr).map(Stream::Tcp),
                TransportBackend::Uds => UnixStream::connect(&addr).map(Stream::Unix),
                TransportBackend::InProcess => {
                    anyhow::bail!("the in-process backend has no socket; use transport tcp or uds")
                }
            };
            match attempt {
                Ok(s) => break s,
                // Refused / not-yet-bound are retried: the master may
                // simply not be listening yet.
                Err(e)
                    if matches!(e.kind(), ErrorKind::ConnectionRefused | ErrorKind::NotFound) =>
                {
                    let expired = match deadline {
                        Some(dl) => Instant::now() >= dl,
                        None => true, // zero timeout: single attempt
                    };
                    if expired {
                        anyhow::bail!(
                            "could not connect to master at {addr} within {:.1}s: {e}",
                            cfg.connect_timeout_secs,
                        );
                    }
                    std::thread::sleep(RETRY_EVERY);
                }
                Err(e) => {
                    return Err(
                        anyhow::Error::new(e).context(format!("connecting to master at {addr}"))
                    )
                }
            }
        };
        if let Stream::Tcp(s) = &stream {
            s.set_nodelay(true).context("setting TCP_NODELAY")?;
        }

        // Handshake under the connect deadline, then steady-state
        // timeout.
        let handshake_timeout =
            timeout_of(cfg.connect_timeout_secs).or_else(|| timeout_of(cfg.read_timeout_secs));
        stream.set_read_timeout(handshake_timeout).context("setting handshake read timeout")?;
        let mut stream = stream;
        stream
            .write_all(&encode_hello(version))
            .and_then(|_| stream.flush())
            .with_context(|| format!("sending hello to master at {addr}"))?;
        let mut ack = [0u8; HANDSHAKE_LEN];
        fill(&mut stream, &mut ack, true).map_err(|end| {
            anyhow::anyhow!(
                "no handshake ack from master at {addr} within {:.1}s: {}",
                cfg.connect_timeout_secs,
                describe_end(&end),
            )
        })?;
        decode_ack(&ack, version).with_context(|| format!("handshake with master at {addr}"))?;
        stream
            .set_read_timeout(timeout_of(cfg.read_timeout_secs))
            .context("setting read timeout")?;

        let mut stats = TransportStats::new(1);
        stats.per_peer[MASTER].sent_bytes = HANDSHAKE_LEN as u64;
        stats.per_peer[MASTER].recv_bytes = HANDSHAKE_LEN as u64;
        Ok(SocketWorker { stream, addr, stats, read_timeout_secs: cfg.read_timeout_secs })
    }

    /// The master's address, for error messages.
    pub fn master_addr(&self) -> &str {
        &self.addr
    }
}

impl Transport for SocketWorker {
    fn send(&mut self, to: usize, frame: Frame) -> Result<(), TransportError> {
        assert_eq!(to, MASTER, "a worker's only peer is the master");
        match write_frame(&mut self.stream, &frame) {
            Ok(bytes) => {
                self.stats.per_peer[MASTER].sent_bytes += bytes;
                self.stats.per_peer[MASTER].sent_frames += 1;
                Ok(())
            }
            Err(e) => Err(TransportError::PeerGone {
                peer: MASTER,
                detail: format!("master at {} disconnected: {e}", self.addr),
            }),
        }
    }

    fn recv(&mut self) -> Result<(usize, Frame), TransportError> {
        match read_frame(&mut self.stream) {
            Ok(frame) => {
                self.stats.per_peer[MASTER].recv_bytes += frame.wire_len() as u64;
                self.stats.per_peer[MASTER].recv_frames += 1;
                Ok((MASTER, frame))
            }
            Err(ReadEnd::Wire(err)) => Err(TransportError::Wire { peer: MASTER, err }),
            Err(ReadEnd::Timeout) => Err(TransportError::PeerGone {
                peer: MASTER,
                detail: format!(
                    "master at {} silent past the {:.1}s read timeout",
                    self.addr, self.read_timeout_secs
                ),
            }),
            Err(end) => Err(TransportError::PeerGone {
                peer: MASTER,
                detail: format!("master at {} disconnected: {}", self.addr, describe_end(&end)),
            }),
        }
    }

    fn peers(&self) -> usize {
        1
    }

    fn stats(&self) -> TransportStats {
        self.stats.clone()
    }
}

impl Drop for SocketWorker {
    fn drop(&mut self) {
        self.stream.shutdown_both();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::messages::{DeltaV, WorkerMsg};

    fn tcp_cfg(listen: &str, join: &str) -> TransportCfg {
        TransportCfg {
            backend: TransportBackend::Tcp,
            listen: listen.to_string(),
            join: join.to_string(),
            connect_timeout_secs: 5.0,
            accept_timeout_secs: 5.0,
            read_timeout_secs: 5.0,
            accept_backlog: 8,
        }
    }

    fn update_frame() -> Frame {
        Frame::Update(WorkerMsg {
            worker: 0,
            local_round: 0,
            delta_v: DeltaV::Sparse { dim: 8, indices: vec![1, 5], values: vec![0.5, -2.0] },
            dual_sum: 0.25,
            arrival_vtime: 1.5,
            updates: 10,
        })
    }

    #[test]
    fn tcp_round_trip_and_stats() {
        let listener = SocketListener::bind(&tcp_cfg("127.0.0.1:0", "")).unwrap();
        let addr = listener.local_desc().to_string();
        let worker = std::thread::spawn(move || {
            let mut w = SocketWorker::connect(&tcp_cfg("", &addr)).unwrap();
            w.send(MASTER, update_frame()).unwrap();
            let (from, reply) = w.recv().unwrap();
            assert_eq!(from, MASTER);
            assert_eq!(reply, Frame::Shutdown { vtime: 2.0, round: 1 });
            w.stats()
        });
        let mut m = listener.accept_cluster(1).unwrap();
        let (peer, frame) = m.recv().unwrap();
        assert_eq!(peer, 0);
        assert_eq!(frame, update_frame());
        m.send(0, Frame::Shutdown { vtime: 2.0, round: 1 }).unwrap();
        let wstats = worker.join().unwrap();

        let sent = update_frame().wire_len() as u64;
        let hs = HANDSHAKE_LEN as u64;
        assert_eq!(wstats.sent_bytes(), hs + sent);
        assert_eq!(m.stats().per_peer[0].recv_bytes, sent);
        assert_eq!(m.stats().per_peer[0].sent_frames, 1);
    }

    #[test]
    fn uds_round_trip() {
        let path = std::env::temp_dir().join(format!("hdca-uds-test-{}", std::process::id()));
        let path = path.to_string_lossy().to_string();
        let mut cfg = tcp_cfg(&path, &path);
        cfg.backend = TransportBackend::Uds;
        let listener = SocketListener::bind(&cfg).unwrap();
        let wcfg = cfg.clone();
        let worker = std::thread::spawn(move || {
            let mut w = SocketWorker::connect(&wcfg).unwrap();
            let (_, got) = w.recv().unwrap();
            assert_eq!(got, Frame::Shutdown { vtime: 0.5, round: 9 });
        });
        let mut m = listener.accept_cluster(1).unwrap();
        m.send(0, Frame::Shutdown { vtime: 0.5, round: 9 }).unwrap();
        worker.join().unwrap();
        drop(m);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn version_mismatch_reports_both_versions() {
        let listener = SocketListener::bind(&tcp_cfg("127.0.0.1:0", "")).unwrap();
        let addr = listener.local_desc().to_string();
        let worker = std::thread::spawn(move || {
            SocketWorker::connect_version(&tcp_cfg("", &addr), WIRE_VERSION + 1)
        });
        let master_err = listener.accept_cluster(1).unwrap_err().to_string();
        assert!(master_err.contains("version mismatch"), "{master_err}");
        assert!(
            master_err.contains(&format!("v{WIRE_VERSION}"))
                && master_err.contains(&format!("v{}", WIRE_VERSION + 1)),
            "{master_err}"
        );
        let worker_err = format!("{:#}", worker.join().unwrap().unwrap_err());
        assert!(
            worker_err.contains(&format!("v{WIRE_VERSION}"))
                && worker_err.contains(&format!("v{}", WIRE_VERSION + 1)),
            "{worker_err}"
        );
    }

    #[test]
    fn connect_refused_names_peer_and_timeout() {
        // Bind then drop to get a port with (very likely) no listener.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let mut cfg = tcp_cfg("", &addr);
        cfg.connect_timeout_secs = 0.3;
        let err = SocketWorker::connect(&cfg).unwrap_err().to_string();
        assert!(err.contains(&addr), "{err}");
        assert!(err.contains("0.3"), "{err}");
    }

    #[test]
    fn accept_timeout_names_listener_and_timeout() {
        let mut cfg = tcp_cfg("127.0.0.1:0", "");
        cfg.accept_timeout_secs = 0.3;
        let listener = SocketListener::bind(&cfg).unwrap();
        let desc = listener.local_desc().to_string();
        let err = listener.accept_cluster(2).unwrap_err().to_string();
        assert!(err.contains(&desc), "{err}");
        assert!(err.contains("0.3"), "{err}");
        assert!(err.contains("0 of 2"), "{err}");
    }

    /// The graceful-shutdown satellite's failure half: a killed master
    /// must surface as a clear "master disconnected" on the worker
    /// within the read timeout — here immediately, via EOF on a real
    /// socket pair.
    #[test]
    fn killed_master_is_reported_as_disconnect() {
        let listener = SocketListener::bind(&tcp_cfg("127.0.0.1:0", "")).unwrap();
        let addr = listener.local_desc().to_string();
        let worker = std::thread::spawn(move || {
            let mut w = SocketWorker::connect(&tcp_cfg("", &addr)).unwrap();
            w.recv()
        });
        let m = listener.accept_cluster(1).unwrap();
        drop(m); // "kill" the master: sockets shut down
        let err = worker.join().unwrap().unwrap_err();
        match err {
            TransportError::PeerGone { peer, detail } => {
                assert_eq!(peer, MASTER);
                assert!(detail.contains("disconnected"), "{detail}");
            }
            other => panic!("expected PeerGone, got {other:?}"),
        }
    }
}
