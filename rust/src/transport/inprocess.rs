//! Threads-as-nodes backend: typed frames over the façade
//! [`mailbox`](crate::util::sync::mailbox) channel, exactly the
//! topology the coordinator used before the transport seam existed.
//! Frames move by value — nothing is encoded — but the byte counters
//! bill [`Frame::wire_len`], so a simulated run reports the same
//! per-peer wire traffic its socket twin would ship.
//!
//! The master's merge mailbox used to be `std::sync::mpsc`; it now
//! rides on `util::sync::mailbox` (Mutex + Condvar under the lint-
//! enforced façade) with identical disconnect semantics, so the
//! handoff protocol is small enough to model-check exhaustively
//! (`tests/loom_mailbox.rs`).

use crate::util::sync::{mailbox, Receiver, Sender};

use super::frame::Frame;
use super::{Transport, TransportError, TransportStats, MASTER};

/// Master endpoint: one shared inbound channel, one outbound channel
/// per worker.
pub struct InProcessMaster {
    rx: Receiver<(usize, Frame)>,
    txs: Vec<Sender<Frame>>,
    stats: TransportStats,
}

/// Worker endpoint: its single peer is the master.
pub struct InProcessWorker {
    id: usize,
    tx: Sender<(usize, Frame)>,
    rx: Receiver<Frame>,
    stats: TransportStats,
}

/// Wire up a `K`-worker in-process cluster. The master holds no clone
/// of the inbound sender, so its `recv` reports [`TransportError::Closed`]
/// exactly when every worker endpoint has been dropped — the same
/// disconnect semantics the raw channels had.
pub fn in_process(k: usize) -> (InProcessMaster, Vec<InProcessWorker>) {
    let (tx_up, rx_up) = mailbox::<(usize, Frame)>();
    let mut txs = Vec::with_capacity(k);
    let mut workers = Vec::with_capacity(k);
    for id in 0..k {
        let (tx_down, rx_down) = mailbox::<Frame>();
        txs.push(tx_down);
        workers.push(InProcessWorker {
            id,
            tx: tx_up.clone(),
            rx: rx_down,
            stats: TransportStats::new(1),
        });
    }
    drop(tx_up);
    let master = InProcessMaster { rx: rx_up, txs, stats: TransportStats::new(k) };
    (master, workers)
}

impl Transport for InProcessMaster {
    fn send(&mut self, to: usize, frame: Frame) -> Result<(), TransportError> {
        assert!(to < self.txs.len(), "master send to unknown peer {to}");
        let bytes = frame.wire_len() as u64;
        match self.txs[to].send(frame) {
            Ok(()) => {
                self.stats.per_peer[to].sent_bytes += bytes;
                self.stats.per_peer[to].sent_frames += 1;
                Ok(())
            }
            Err(_) => Err(TransportError::PeerGone {
                peer: to,
                detail: "worker endpoint dropped".to_string(),
            }),
        }
    }

    fn recv(&mut self) -> Result<(usize, Frame), TransportError> {
        match self.rx.recv() {
            Ok((from, frame)) => {
                self.stats.per_peer[from].recv_bytes += frame.wire_len() as u64;
                self.stats.per_peer[from].recv_frames += 1;
                Ok((from, frame))
            }
            Err(_) => Err(TransportError::Closed),
        }
    }

    fn recv_timeout(
        &mut self,
        dur: std::time::Duration,
    ) -> Result<Option<(usize, Frame)>, TransportError> {
        match self.rx.recv_timeout(dur) {
            Ok(Some((from, frame))) => {
                self.stats.per_peer[from].recv_bytes += frame.wire_len() as u64;
                self.stats.per_peer[from].recv_frames += 1;
                Ok(Some((from, frame)))
            }
            Ok(None) => Ok(None),
            Err(_) => Err(TransportError::Closed),
        }
    }

    fn peers(&self) -> usize {
        self.txs.len()
    }

    fn stats(&self) -> TransportStats {
        self.stats.clone()
    }
}

impl Transport for InProcessWorker {
    fn send(&mut self, to: usize, frame: Frame) -> Result<(), TransportError> {
        assert_eq!(to, MASTER, "a worker's only peer is the master");
        let bytes = frame.wire_len() as u64;
        match self.tx.send((self.id, frame)) {
            Ok(()) => {
                self.stats.per_peer[MASTER].sent_bytes += bytes;
                self.stats.per_peer[MASTER].sent_frames += 1;
                Ok(())
            }
            Err(_) => Err(TransportError::PeerGone {
                peer: MASTER,
                detail: "master disconnected".to_string(),
            }),
        }
    }

    fn recv(&mut self) -> Result<(usize, Frame), TransportError> {
        match self.rx.recv() {
            Ok(frame) => {
                self.stats.per_peer[MASTER].recv_bytes += frame.wire_len() as u64;
                self.stats.per_peer[MASTER].recv_frames += 1;
                Ok((MASTER, frame))
            }
            Err(_) => Err(TransportError::PeerGone {
                peer: MASTER,
                detail: "master disconnected".to_string(),
            }),
        }
    }

    fn recv_timeout(
        &mut self,
        dur: std::time::Duration,
    ) -> Result<Option<(usize, Frame)>, TransportError> {
        match self.rx.recv_timeout(dur) {
            Ok(Some(frame)) => {
                self.stats.per_peer[MASTER].recv_bytes += frame.wire_len() as u64;
                self.stats.per_peer[MASTER].recv_frames += 1;
                Ok(Some((MASTER, frame)))
            }
            Ok(None) => Ok(None),
            Err(_) => Err(TransportError::PeerGone {
                peer: MASTER,
                detail: "master disconnected".to_string(),
            }),
        }
    }

    fn peers(&self) -> usize {
        1
    }

    fn stats(&self) -> TransportStats {
        self.stats.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_flow_and_bytes_are_billed() {
        let (mut master, mut workers) = in_process(2);
        let f = Frame::Shutdown { vtime: 1.0, round: 3 };
        let len = f.wire_len() as u64;

        workers[1].send(MASTER, f.clone()).unwrap();
        let (from, got) = master.recv().unwrap();
        assert_eq!(from, 1);
        assert_eq!(got, f);
        assert_eq!(master.stats().per_peer[1].recv_bytes, len);
        assert_eq!(master.stats().per_peer[0].recv_bytes, 0);
        assert_eq!(workers[1].stats().sent_bytes(), len);

        master.send(0, f.clone()).unwrap();
        let (from, got) = workers[0].recv().unwrap();
        assert_eq!((from, got), (MASTER, f));
        assert_eq!(master.stats().per_peer[0].sent_frames, 1);
        assert_eq!(workers[0].stats().recv_bytes(), len);
    }

    #[test]
    fn master_sees_closed_when_all_workers_drop() {
        let (mut master, workers) = in_process(2);
        drop(workers);
        assert_eq!(master.recv(), Err(TransportError::Closed));
    }

    #[test]
    fn worker_sees_master_gone() {
        let (master, mut workers) = in_process(1);
        drop(master);
        let err = workers[0].recv().unwrap_err();
        assert!(matches!(err, TransportError::PeerGone { peer: MASTER, .. }));
        let err = workers[0].send(MASTER, Frame::Shutdown { vtime: 0.0, round: 0 }).unwrap_err();
        assert!(matches!(err, TransportError::PeerGone { peer: MASTER, .. }));
    }
}
