//! The versioned binary wire protocol: handshake + length-prefixed
//! CRC-checked frames (the `store::format` encoding idioms applied to
//! the coordinator's messages).
//!
//! ```text
//! handshake   worker → master   magic b"HDCAWIRE" | version u32 | reserved u32
//!             master → worker   magic b"HDCAWIRE" | version u32 | status  u32
//!
//! frame       header (20 B)     kind u32 | round u64 | payload_len u64
//!             payload           kind-specific, little-endian (below)
//!             trailer (4 B)     crc32 u32 over header + payload
//! ```
//!
//! Payloads (all integers little-endian, floats as IEEE-754 bits — the
//! decode is bitwise, including negative zero and non-finite values):
//!
//! ```text
//! Update   worker u32 | local_round u64 | updates u64 | dual_sum f64
//!          | arrival_vtime f64 | Δv
//! Merged   global_round u64 | arrival_vtime f64 | len u64 | v f64×len
//! Shutdown round u64 | vtime f64
//! Final    worker u32 | local_rounds u64 | updates u64 | vtime f64
//!          | len u64 | (row u64, α f64)×len
//! Assign   worker u32 | k u32 | n u64 | d u64 | rng u64×4
//!          | allreduce u8 | json_len u64 | config json (UTF-8)
//! Rejoin   worker u32 | last_acked_round u64 | alpha_crc u32
//! Nack     round u64
//!
//! Δv       tag u8 (0 = dense, 1 = sparse)
//!   dense  dim u64 | values f64×dim
//!   sparse dim u64 | nnz u64 | indices u32×nnz | values f64×nnz
//! ```
//!
//! A sparse `Δv` frame therefore ships `O(touched)` bytes on the real
//! wire — the same 1.5-elems-per-entry ratio the virtual cost model
//! bills via [`DeltaV::wire_elems`].

use crate::coordinator::messages::{DeltaV, MasterReply, WorkerFinal, WorkerMsg};
use crate::store::format::crc32;

/// Protocol magic, first bytes of every handshake.
pub const WIRE_MAGIC: [u8; 8] = *b"HDCAWIRE";
/// Current protocol version.
pub const WIRE_VERSION: u32 = 1;
/// Handshake hello/ack length (both directions).
pub const HANDSHAKE_LEN: usize = 16;
/// Frame header length: kind u32 + round u64 + payload_len u64.
pub const FRAME_HEADER_LEN: usize = 20;
/// Frame trailer length: crc32 u32.
pub const FRAME_TRAILER_LEN: usize = 4;
/// Sanity cap on a frame's payload, so a corrupt length prefix can
/// never drive an allocation (the same guard the shard decoder uses).
pub const MAX_FRAME_PAYLOAD: u64 = 1 << 32;

/// Handshake ack status: accepted.
pub const ACK_OK: u32 = 0;
/// Handshake ack status: protocol version mismatch (the ack's version
/// field carries the master's version so both sides can be reported).
pub const ACK_VERSION_MISMATCH: u32 = 1;

const KIND_UPDATE: u32 = 1;
const KIND_MERGED: u32 = 2;
const KIND_SHUTDOWN: u32 = 3;
const KIND_FINAL: u32 = 4;
const KIND_ASSIGN: u32 = 5;
const KIND_REJOIN: u32 = 6;
const KIND_NACK: u32 = 7;

/// Resumable-reconnect handshake, worker → master, sent as the first
/// frame on a *replacement* connection: identifies the worker, names
/// the last global round whose `Merged` reply it committed, and
/// carries a CRC-32 over its committed local α so the master can log
/// (and tests can assert) that the dual state survived the outage
/// bitwise-intact.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RejoinInfo {
    /// The rejoining worker's id (its original accept-order index).
    pub worker_id: usize,
    /// Last global round whose merged `v` this worker committed.
    pub last_acked_round: usize,
    /// CRC-32 over the worker's committed α (f64 little-endian bytes,
    /// shard order).
    pub alpha_crc: u32,
}

/// Startup assignment, master → worker, sent once after the handshake:
/// everything a worker process needs to reproduce its in-process
/// twin's behavior bit for bit.
#[derive(Debug, Clone, PartialEq)]
pub struct Assignment {
    /// This worker's id `k` (also its accept-order peer index).
    pub worker_id: usize,
    /// Cluster size `K`.
    pub k_nodes: usize,
    /// Global row count of the shard store (cross-checked against the
    /// worker's own copy).
    pub n: usize,
    /// Global feature dimension.
    pub d: usize,
    /// The worker's forked xoshiro256** stream, forked by the master
    /// in worker-id order exactly as the in-process driver forks them.
    pub rng_state: [u64; 4],
    /// Use the all-reduce send-cost model (CoCoA+) instead of sized
    /// point-to-point (Hybrid-DCA).
    pub allreduce: bool,
    /// The full experiment config as `util::json` text.
    pub config_json: String,
}

/// One typed message on the wire.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Worker → master: one round's accumulated update.
    Update(WorkerMsg),
    /// Master → worker: the merged global `v` (never a terminate —
    /// termination is its own frame kind on the wire).
    Merged(MasterReply),
    /// Master → worker: stop after this round and report your final
    /// state. Carries the stop-time virtual clock and global round.
    Shutdown { vtime: f64, round: usize },
    /// Worker → master: final committed state, sent after `Shutdown`.
    Final(WorkerFinal),
    /// Master → worker: startup assignment.
    Assign(Assignment),
    /// Worker → master: resumable reconnect after a severed link.
    Rejoin(RejoinInfo),
    /// Either direction: "your last frame never arrived intact —
    /// retransmit it". `round` names the receiver's last good round,
    /// purely for log context; the ARQ is stop-and-wait, so each side
    /// holds at most one unacknowledged frame to resend.
    Nack { round: usize },
}

/// A named wire-level decode failure. Every single-byte corruption of
/// an encoded frame maps to one of these (`tests/prop_transport.rs`
/// flips each byte and checks).
#[derive(Debug, Clone, PartialEq)]
pub enum WireError {
    /// Handshake bytes did not start with `HDCAWIRE`.
    BadMagic { got: [u8; 8] },
    /// Peers speak different protocol versions (both reported).
    VersionMismatch { ours: u32, theirs: u32 },
    /// Handshake rejected with an unrecognized status code.
    HandshakeRejected { code: u32 },
    /// Frame length prefix disagrees with the bytes on hand.
    BadLength { expected: usize, got: usize },
    /// Length prefix exceeds [`MAX_FRAME_PAYLOAD`].
    Oversized { len: u64 },
    /// CRC-32 over header + payload does not match the trailer.
    BadCrc { expected: u32, got: u32 },
    /// Unknown frame kind tag.
    UnknownKind { kind: u32 },
    /// Ran out of bytes while parsing the named field.
    Truncated { field: &'static str },
    /// A structurally invalid payload value.
    BadPayload { field: &'static str, detail: String },
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::BadMagic { got } => {
                write!(f, "bad handshake magic {:?} (expected {:?})", got, WIRE_MAGIC)
            }
            WireError::VersionMismatch { ours, theirs } => write!(
                f,
                "protocol version mismatch: we speak v{ours}, peer speaks v{theirs}"
            ),
            WireError::HandshakeRejected { code } => {
                write!(f, "handshake rejected with unknown status {code}")
            }
            WireError::BadLength { expected, got } => write!(
                f,
                "frame length mismatch: length prefix implies {expected} bytes, got {got}"
            ),
            WireError::Oversized { len } => write!(
                f,
                "frame payload length {len} exceeds the {MAX_FRAME_PAYLOAD}-byte sanity cap"
            ),
            WireError::BadCrc { expected, got } => write!(
                f,
                "frame CRC mismatch: computed {expected:#010x}, stored {got:#010x}"
            ),
            WireError::UnknownKind { kind } => write!(f, "unknown frame kind {kind}"),
            WireError::Truncated { field } => {
                write!(f, "frame truncated while reading {field}")
            }
            WireError::BadPayload { field, detail } => {
                write!(f, "bad frame payload at {field}: {detail}")
            }
        }
    }
}

impl std::error::Error for WireError {}

// ---- encoding helpers ----

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Slice→array conversion with the panic made impossible by type: the
/// lengths are proven by the callers' `take`/bounds checks, but the
/// decode path is panic-free *by construction* (the xtask lint bans
/// `unwrap`/`expect` here), so a length surprise surfaces as a named
/// [`WireError::Truncated`] instead of tearing the process down on a
/// hostile or corrupt peer.
pub(crate) fn arr<const N: usize>(
    bytes: &[u8],
    field: &'static str,
) -> Result<[u8; N], WireError> {
    <[u8; N]>::try_from(bytes).map_err(|_| WireError::Truncated { field })
}

/// Bounds-checked little-endian reader over a byte slice.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, len: usize, field: &'static str) -> Result<&'a [u8], WireError> {
        let end = self
            .pos
            .checked_add(len)
            .filter(|&e| e <= self.buf.len())
            .ok_or(WireError::Truncated { field })?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self, field: &'static str) -> Result<u8, WireError> {
        Ok(self.take(1, field)?[0])
    }

    fn u32(&mut self, field: &'static str) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(arr(self.take(4, field)?, field)?))
    }

    fn u64(&mut self, field: &'static str) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(arr(self.take(8, field)?, field)?))
    }

    fn f64(&mut self, field: &'static str) -> Result<f64, WireError> {
        Ok(f64::from_le_bytes(arr(self.take(8, field)?, field)?))
    }

    /// A length field that must fit both `usize` and the bytes left
    /// (given `elem_bytes` per element) — a corrupt inner length can
    /// never drive an allocation past the buffer it came from.
    fn len_field(&mut self, elem_bytes: usize, field: &'static str) -> Result<usize, WireError> {
        let raw = self.u64(field)?;
        let remaining = (self.buf.len() - self.pos) as u64;
        if elem_bytes as u64 > 0 && raw > remaining / elem_bytes.max(1) as u64 {
            return Err(WireError::Truncated { field });
        }
        Ok(raw as usize)
    }

    fn done(&self, field: &'static str) -> Result<(), WireError> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(WireError::BadPayload {
                field,
                detail: format!("{} trailing bytes after payload", self.buf.len() - self.pos),
            })
        }
    }
}

fn encode_delta_v(out: &mut Vec<u8>, dv: &DeltaV) {
    match dv {
        DeltaV::Dense(values) => {
            out.push(0);
            put_u64(out, values.len() as u64);
            for &x in values {
                put_f64(out, x);
            }
        }
        DeltaV::Sparse { dim, indices, values } => {
            debug_assert_eq!(indices.len(), values.len());
            out.push(1);
            put_u64(out, *dim as u64);
            put_u64(out, indices.len() as u64);
            for &j in indices {
                put_u32(out, j);
            }
            for &x in values {
                put_f64(out, x);
            }
        }
    }
}

fn delta_v_wire_len(dv: &DeltaV) -> usize {
    match dv {
        DeltaV::Dense(values) => 1 + 8 + 8 * values.len(),
        DeltaV::Sparse { indices, .. } => 1 + 8 + 8 + 12 * indices.len(),
    }
}

fn decode_delta_v(c: &mut Cursor<'_>) -> Result<DeltaV, WireError> {
    match c.u8("delta_v.tag")? {
        0 => {
            let dim = c.len_field(8, "delta_v.dim")?;
            let mut values = Vec::with_capacity(dim);
            for _ in 0..dim {
                values.push(c.f64("delta_v.values")?);
            }
            Ok(DeltaV::Dense(values))
        }
        1 => {
            let dim = c.u64("delta_v.dim")? as usize;
            let nnz = c.len_field(12, "delta_v.nnz")?;
            let mut indices = Vec::with_capacity(nnz);
            for _ in 0..nnz {
                let j = c.u32("delta_v.indices")?;
                if j as usize >= dim {
                    return Err(WireError::BadPayload {
                        field: "delta_v.indices",
                        detail: format!("index {j} out of range for dim {dim}"),
                    });
                }
                indices.push(j);
            }
            let mut values = Vec::with_capacity(nnz);
            for _ in 0..nnz {
                values.push(c.f64("delta_v.values")?);
            }
            Ok(DeltaV::Sparse { dim, indices, values })
        }
        t => Err(WireError::BadPayload {
            field: "delta_v.tag",
            detail: format!("unknown representation tag {t}"),
        }),
    }
}

impl Frame {
    /// Wire kind tag.
    pub fn kind(&self) -> u32 {
        match self {
            Frame::Update(_) => KIND_UPDATE,
            Frame::Merged(_) => KIND_MERGED,
            Frame::Shutdown { .. } => KIND_SHUTDOWN,
            Frame::Final(_) => KIND_FINAL,
            Frame::Assign(_) => KIND_ASSIGN,
            Frame::Rejoin(_) => KIND_REJOIN,
            Frame::Nack { .. } => KIND_NACK,
        }
    }

    /// Human name of the kind (error messages).
    pub fn kind_name(&self) -> &'static str {
        match self {
            Frame::Update(_) => "Update",
            Frame::Merged(_) => "Merged",
            Frame::Shutdown { .. } => "Shutdown",
            Frame::Final(_) => "Final",
            Frame::Assign(_) => "Assign",
            Frame::Rejoin(_) => "Rejoin",
            Frame::Nack { .. } => "Nack",
        }
    }

    /// The round number mirrored into the frame header (on-wire
    /// debuggability; the decoder cross-checks it against the payload).
    pub fn header_round(&self) -> u64 {
        match self {
            Frame::Update(m) => m.local_round as u64,
            Frame::Merged(r) => r.global_round as u64,
            Frame::Shutdown { round, .. } => *round as u64,
            Frame::Final(f) => f.local_rounds as u64,
            Frame::Assign(_) => 0,
            Frame::Rejoin(r) => r.last_acked_round as u64,
            Frame::Nack { round } => *round as u64,
        }
    }

    fn payload_len(&self) -> usize {
        match self {
            Frame::Update(m) => 4 + 8 + 8 + 8 + 8 + delta_v_wire_len(&m.delta_v),
            Frame::Merged(r) => 8 + 8 + 8 + 8 * r.v.len(),
            Frame::Shutdown { .. } => 8 + 8,
            Frame::Final(f) => 4 + 8 + 8 + 8 + 8 + 16 * f.alpha.len(),
            Frame::Assign(a) => 4 + 4 + 8 + 8 + 32 + 1 + 8 + a.config_json.len(),
            Frame::Rejoin(_) => 4 + 8 + 4,
            Frame::Nack { .. } => 8,
        }
    }

    /// Exact encoded size, header and trailer included — computed
    /// without serializing, so the in-process backend can bill byte
    /// counters at zero encoding cost (pinned equal to
    /// `encode().len()` by the property tests).
    pub fn wire_len(&self) -> usize {
        FRAME_HEADER_LEN + self.payload_len() + FRAME_TRAILER_LEN
    }

    /// Encode as header + payload + CRC-32 trailer.
    pub fn encode(&self) -> Vec<u8> {
        let payload_len = self.payload_len();
        let mut out = Vec::with_capacity(FRAME_HEADER_LEN + payload_len + FRAME_TRAILER_LEN);
        put_u32(&mut out, self.kind());
        put_u64(&mut out, self.header_round());
        put_u64(&mut out, payload_len as u64);
        match self {
            Frame::Update(m) => {
                put_u32(&mut out, m.worker as u32);
                put_u64(&mut out, m.local_round as u64);
                put_u64(&mut out, m.updates);
                put_f64(&mut out, m.dual_sum);
                put_f64(&mut out, m.arrival_vtime);
                encode_delta_v(&mut out, &m.delta_v);
            }
            Frame::Merged(r) => {
                debug_assert!(!r.terminate, "terminate travels as Frame::Shutdown");
                put_u64(&mut out, r.global_round as u64);
                put_f64(&mut out, r.arrival_vtime);
                put_u64(&mut out, r.v.len() as u64);
                for &x in &r.v {
                    put_f64(&mut out, x);
                }
            }
            Frame::Shutdown { vtime, round } => {
                put_u64(&mut out, *round as u64);
                put_f64(&mut out, *vtime);
            }
            Frame::Final(f) => {
                put_u32(&mut out, f.worker_id as u32);
                put_u64(&mut out, f.local_rounds as u64);
                put_u64(&mut out, f.updates);
                put_f64(&mut out, f.vtime);
                put_u64(&mut out, f.alpha.len() as u64);
                for &(i, a) in &f.alpha {
                    put_u64(&mut out, i as u64);
                    put_f64(&mut out, a);
                }
            }
            Frame::Assign(a) => {
                put_u32(&mut out, a.worker_id as u32);
                put_u32(&mut out, a.k_nodes as u32);
                put_u64(&mut out, a.n as u64);
                put_u64(&mut out, a.d as u64);
                for &s in &a.rng_state {
                    put_u64(&mut out, s);
                }
                out.push(a.allreduce as u8);
                put_u64(&mut out, a.config_json.len() as u64);
                out.extend_from_slice(a.config_json.as_bytes());
            }
            Frame::Rejoin(r) => {
                put_u32(&mut out, r.worker_id as u32);
                put_u64(&mut out, r.last_acked_round as u64);
                put_u32(&mut out, r.alpha_crc);
            }
            Frame::Nack { round } => {
                put_u64(&mut out, *round as u64);
            }
        }
        debug_assert_eq!(out.len(), FRAME_HEADER_LEN + payload_len);
        let crc = crc32(&out);
        put_u32(&mut out, crc);
        out
    }

    /// Decode a complete encoded frame. Checks, in order: overall
    /// length consistency, the CRC, the kind tag, then the payload
    /// structure — so any corruption is rejected with a named
    /// [`WireError`] before a single payload value is trusted.
    pub fn decode(buf: &[u8]) -> Result<Frame, WireError> {
        if buf.len() < FRAME_HEADER_LEN + FRAME_TRAILER_LEN {
            return Err(WireError::Truncated { field: "frame header" });
        }
        let mut hdr = Cursor::new(&buf[..FRAME_HEADER_LEN]);
        let kind = hdr.u32("header.kind")?;
        let round = hdr.u64("header.round")?;
        let payload_len = hdr.u64("header.payload_len")?;
        if payload_len > MAX_FRAME_PAYLOAD {
            return Err(WireError::Oversized { len: payload_len });
        }
        let expected = FRAME_HEADER_LEN + payload_len as usize + FRAME_TRAILER_LEN;
        if expected != buf.len() {
            return Err(WireError::BadLength { expected, got: buf.len() });
        }
        // In bounds: the early-return above guarantees
        // buf.len() ≥ FRAME_HEADER_LEN + FRAME_TRAILER_LEN.
        let body = &buf[..buf.len() - FRAME_TRAILER_LEN];
        let stored =
            u32::from_le_bytes(arr(&buf[buf.len() - FRAME_TRAILER_LEN..], "crc trailer")?);
        let computed = crc32(body);
        if stored != computed {
            return Err(WireError::BadCrc { expected: computed, got: stored });
        }
        let mut c = Cursor::new(&body[FRAME_HEADER_LEN..]);
        let frame = match kind {
            KIND_UPDATE => {
                let worker = c.u32("update.worker")? as usize;
                let local_round = c.u64("update.local_round")? as usize;
                let updates = c.u64("update.updates")?;
                let dual_sum = c.f64("update.dual_sum")?;
                let arrival_vtime = c.f64("update.arrival_vtime")?;
                let delta_v = decode_delta_v(&mut c)?;
                Frame::Update(WorkerMsg {
                    worker,
                    local_round,
                    delta_v,
                    dual_sum,
                    arrival_vtime,
                    updates,
                })
            }
            KIND_MERGED => {
                let global_round = c.u64("merged.global_round")? as usize;
                let arrival_vtime = c.f64("merged.arrival_vtime")?;
                let len = c.len_field(8, "merged.v.len")?;
                let mut v = Vec::with_capacity(len);
                for _ in 0..len {
                    v.push(c.f64("merged.v")?);
                }
                Frame::Merged(MasterReply { v, arrival_vtime, global_round, terminate: false })
            }
            KIND_SHUTDOWN => {
                let r = c.u64("shutdown.round")? as usize;
                let vtime = c.f64("shutdown.vtime")?;
                Frame::Shutdown { vtime, round: r }
            }
            KIND_FINAL => {
                let worker_id = c.u32("final.worker")? as usize;
                let local_rounds = c.u64("final.local_rounds")? as usize;
                let updates = c.u64("final.updates")?;
                let vtime = c.f64("final.vtime")?;
                let len = c.len_field(16, "final.alpha.len")?;
                let mut alpha = Vec::with_capacity(len);
                for _ in 0..len {
                    let i = c.u64("final.alpha.row")? as usize;
                    let a = c.f64("final.alpha.value")?;
                    alpha.push((i, a));
                }
                Frame::Final(WorkerFinal { worker_id, alpha, local_rounds, updates, vtime })
            }
            KIND_ASSIGN => {
                let worker_id = c.u32("assign.worker")? as usize;
                let k_nodes = c.u32("assign.k")? as usize;
                let n = c.u64("assign.n")? as usize;
                let d = c.u64("assign.d")? as usize;
                let mut rng_state = [0u64; 4];
                for s in rng_state.iter_mut() {
                    *s = c.u64("assign.rng")?;
                }
                let allreduce = match c.u8("assign.allreduce")? {
                    0 => false,
                    1 => true,
                    b => {
                        return Err(WireError::BadPayload {
                            field: "assign.allreduce",
                            detail: format!("expected 0 or 1, got {b}"),
                        })
                    }
                };
                let json_len = c.len_field(1, "assign.json_len")?;
                let raw = c.take(json_len, "assign.config_json")?;
                let config_json = std::str::from_utf8(raw)
                    .map_err(|e| WireError::BadPayload {
                        field: "assign.config_json",
                        detail: format!("invalid UTF-8: {e}"),
                    })?
                    .to_string();
                Frame::Assign(Assignment {
                    worker_id,
                    k_nodes,
                    n,
                    d,
                    rng_state,
                    allreduce,
                    config_json,
                })
            }
            KIND_REJOIN => {
                let worker_id = c.u32("rejoin.worker")? as usize;
                let last_acked_round = c.u64("rejoin.last_acked_round")? as usize;
                let alpha_crc = c.u32("rejoin.alpha_crc")?;
                Frame::Rejoin(RejoinInfo { worker_id, last_acked_round, alpha_crc })
            }
            KIND_NACK => {
                let r = c.u64("nack.round")? as usize;
                Frame::Nack { round: r }
            }
            other => return Err(WireError::UnknownKind { kind: other }),
        };
        c.done("payload")?;
        if frame.header_round() != round {
            return Err(WireError::BadPayload {
                field: "header.round",
                detail: format!(
                    "header round {round} disagrees with payload round {}",
                    frame.header_round()
                ),
            });
        }
        Ok(frame)
    }
}

// ---- handshake ----

/// Worker → master hello.
pub fn encode_hello(version: u32) -> [u8; HANDSHAKE_LEN] {
    let mut out = [0u8; HANDSHAKE_LEN];
    out[..8].copy_from_slice(&WIRE_MAGIC);
    out[8..12].copy_from_slice(&version.to_le_bytes());
    out
}

/// Parse a hello; returns the client's protocol version. The *server*
/// decides on mismatch so its ack can carry both versions.
pub fn decode_hello(buf: &[u8; HANDSHAKE_LEN]) -> Result<u32, WireError> {
    if buf[..8] != WIRE_MAGIC {
        return Err(WireError::BadMagic { got: arr(&buf[..8], "hello.magic")? });
    }
    Ok(u32::from_le_bytes(arr(&buf[8..12], "hello.version")?))
}

/// Master → worker ack. `version` is the *master's* version; status is
/// [`ACK_OK`] or [`ACK_VERSION_MISMATCH`].
pub fn encode_ack(version: u32, status: u32) -> [u8; HANDSHAKE_LEN] {
    let mut out = [0u8; HANDSHAKE_LEN];
    out[..8].copy_from_slice(&WIRE_MAGIC);
    out[8..12].copy_from_slice(&version.to_le_bytes());
    out[12..16].copy_from_slice(&status.to_le_bytes());
    out
}

/// Parse an ack on the worker side; `ours` is the version we sent, so
/// a mismatch error reports both.
pub fn decode_ack(buf: &[u8; HANDSHAKE_LEN], ours: u32) -> Result<u32, WireError> {
    if buf[..8] != WIRE_MAGIC {
        return Err(WireError::BadMagic { got: arr(&buf[..8], "ack.magic")? });
    }
    let theirs = u32::from_le_bytes(arr(&buf[8..12], "ack.version")?);
    let status = u32::from_le_bytes(arr(&buf[12..16], "ack.status")?);
    match status {
        ACK_OK => Ok(theirs),
        ACK_VERSION_MISMATCH => Err(WireError::VersionMismatch { ours, theirs }),
        code => Err(WireError::HandshakeRejected { code }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handshake_round_trip() {
        let hello = encode_hello(WIRE_VERSION);
        assert_eq!(decode_hello(&hello).unwrap(), WIRE_VERSION);
        let ack = encode_ack(WIRE_VERSION, ACK_OK);
        assert_eq!(decode_ack(&ack, WIRE_VERSION).unwrap(), WIRE_VERSION);
    }

    #[test]
    fn handshake_version_mismatch_reports_both() {
        let ack = encode_ack(3, ACK_VERSION_MISMATCH);
        let err = decode_ack(&ack, 7).unwrap_err();
        assert_eq!(err, WireError::VersionMismatch { ours: 7, theirs: 3 });
        let msg = err.to_string();
        assert!(msg.contains('7') && msg.contains('3'), "{msg}");
    }

    #[test]
    fn handshake_bad_magic() {
        let mut hello = encode_hello(WIRE_VERSION);
        hello[0] ^= 0xFF;
        assert!(matches!(decode_hello(&hello), Err(WireError::BadMagic { .. })));
        let mut ack = encode_ack(WIRE_VERSION, ACK_OK);
        ack[3] ^= 0x01;
        assert!(matches!(decode_ack(&ack, WIRE_VERSION), Err(WireError::BadMagic { .. })));
    }

    #[test]
    fn unknown_ack_status_rejected() {
        let ack = encode_ack(WIRE_VERSION, 9);
        assert_eq!(
            decode_ack(&ack, WIRE_VERSION),
            Err(WireError::HandshakeRejected { code: 9 })
        );
    }

    #[test]
    fn shutdown_round_trip() {
        let f = Frame::Shutdown { vtime: 12.375, round: 42 };
        let bytes = f.encode();
        assert_eq!(bytes.len(), f.wire_len());
        assert_eq!(Frame::decode(&bytes).unwrap(), f);
    }

    #[test]
    fn rejoin_and_nack_round_trip() {
        let r = Frame::Rejoin(RejoinInfo {
            worker_id: 3,
            last_acked_round: 17,
            alpha_crc: 0xDEADBEEF,
        });
        let bytes = r.encode();
        assert_eq!(bytes.len(), r.wire_len());
        assert_eq!(Frame::decode(&bytes).unwrap(), r);

        let n = Frame::Nack { round: 9 };
        let bytes = n.encode();
        assert_eq!(bytes.len(), n.wire_len());
        assert_eq!(Frame::decode(&bytes).unwrap(), n);
    }

    #[test]
    fn oversized_length_prefix_rejected_without_allocating() {
        let mut bytes = Frame::Shutdown { vtime: 0.0, round: 0 }.encode();
        // Corrupt the payload_len field to a huge value.
        bytes[12..20].copy_from_slice(&(u64::MAX).to_le_bytes());
        assert!(matches!(Frame::decode(&bytes), Err(WireError::Oversized { .. })));
    }
}
