//! Typed experiment configuration.
//!
//! Configs load from a TOML-subset file (`config::toml`), then CLI flags
//! override individual fields. [`ExpConfig::validate`] enforces the
//! paper's parameter constraints (e.g. `S ≤ K`, `Γ ≥ 1`, `ν ∈ (0,1]`,
//! σ ≥ νS — Eq. 5 with the safe choice of Lemma 3.2 in Ma et al. 2015b).

pub mod toml;

use crate::data::partition::Strategy;
use crate::loss::LossKind;
use crate::obs::ObsCfg;
use crate::transport::{FaultPlan, TransportBackend, TransportCfg};
use crate::util::json::Json;
use toml::Document;

/// Merge-order policy for the master's bounded-barrier pick (paper:
/// oldest first; ablation: newest first). Lives in the config layer so
/// both [`ExpConfig`] and the session builder can carry it; the
/// coordinator re-exports it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MergePolicy {
    OldestFirst,
    NewestFirst,
}

impl MergePolicy {
    pub fn parse(s: &str) -> Option<MergePolicy> {
        match s.to_ascii_lowercase().as_str() {
            "oldest" | "oldest-first" | "oldestfirst" => Some(MergePolicy::OldestFirst),
            "newest" | "newest-first" | "newestfirst" => Some(MergePolicy::NewestFirst),
            _ => None,
        }
    }
}

/// How the subproblem scaling parameter σ is chosen.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SigmaPolicy {
    /// σ = ν·S — the paper's safe choice for Hybrid-DCA.
    NuS,
    /// σ = ν·K — CoCoA+'s choice (all-reduce over K workers).
    NuK,
    /// Explicit value (ablations).
    Fixed(f64),
}

impl SigmaPolicy {
    pub fn value(self, nu: f64, s: usize, k: usize) -> f64 {
        match self {
            SigmaPolicy::NuS => nu * s as f64,
            SigmaPolicy::NuK => nu * k as f64,
            SigmaPolicy::Fixed(v) => v,
        }
    }

    /// Parse a policy name or explicit value. A fixed σ must be a
    /// positive finite number — σ ≤ 0 breaks the subproblem curvature
    /// `q = σ‖x‖²/(λn)` (Eq. 5), so it is rejected here at parse time
    /// rather than deferred to [`ExpConfig::validate`].
    pub fn parse(s: &str) -> Option<SigmaPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "nus" | "s" | "auto" => Some(SigmaPolicy::NuS),
            "nuk" | "k" => Some(SigmaPolicy::NuK),
            other => other
                .parse::<f64>()
                .ok()
                .filter(|v| v.is_finite() && *v > 0.0)
                .map(SigmaPolicy::Fixed),
        }
    }
}

/// Which algorithm to run (Figure 3's four solvers).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    /// Sequential DCA (Hsieh et al. 2008) — the paper's *Baseline*.
    Baseline,
    /// CoCoA+ (Ma et al. 2015): synchronous all-reduce, 1 core per node.
    CocoaPlus,
    /// PassCoDe (Hsieh et al. 2015): single node, R async cores.
    PassCoDe,
    /// This paper.
    HybridDca,
}

impl Algorithm {
    pub fn parse(s: &str) -> Option<Algorithm> {
        match s.to_ascii_lowercase().as_str() {
            "baseline" | "dca" | "sdca" => Some(Algorithm::Baseline),
            "cocoa+" | "cocoa" | "cocoaplus" => Some(Algorithm::CocoaPlus),
            "passcode" => Some(Algorithm::PassCoDe),
            "hybrid" | "hybrid-dca" | "hybriddca" => Some(Algorithm::HybridDca),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Algorithm::Baseline => "Baseline",
            Algorithm::CocoaPlus => "CoCoA+",
            Algorithm::PassCoDe => "PassCoDe",
            Algorithm::HybridDca => "Hybrid-DCA",
        }
    }
}

/// Full experiment configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ExpConfig {
    // Dataset
    /// Synthetic preset name, or a LIBSVM path when `data_path` is set.
    pub dataset: String,
    pub data_path: Option<String>,
    /// Shard-store directory (`store::pack` output). Mutually exclusive
    /// with `data_path`; when set, the dataset loads from packed shards
    /// and multi-node engines partition on shard boundaries.
    pub store_path: Option<String>,
    pub seed: u64,

    // Problem
    pub loss: LossKind,
    pub lambda: f64,

    // Cluster shape (paper: K nodes × R cores)
    pub k_nodes: usize,
    pub r_cores: usize,
    pub partition: Strategy,

    // Local solver (Algorithm 1)
    /// Local iterations per round *per core* (paper's H).
    pub h_local: usize,
    pub nu: f64,
    pub sigma: SigmaPolicy,
    /// Use racy "wild" atomic updates (PassCoDe-Wild ablation).
    pub wild: bool,

    // Master (Algorithm 2)
    /// Bounded-barrier size S (≤ K).
    pub s_barrier: usize,
    /// Bounded-delay Γ (≥ 1).
    pub gamma: usize,
    /// Merge-order policy (paper: oldest first).
    pub merge_policy: MergePolicy,

    // Run control
    pub max_rounds: usize,
    pub gap_threshold: f64,
    /// Evaluate objectives every this many rounds.
    pub eval_every: usize,

    // Simulation (virtual clock)
    /// Per-worker slowdown multipliers (empty = homogeneous 1.0).
    pub stragglers: Vec<f64>,
    /// Simulated fixed network latency per message (seconds, virtual).
    pub net_latency: f64,
    /// Simulated per-element transfer cost for d-vector messages.
    pub net_per_elem: f64,
    /// Simulated per-nnz compute cost (seconds, virtual).
    pub cost_per_nnz: f64,
    /// Δv wire-format density threshold: a worker sends its round
    /// delta as sparse (indices, values) pairs when the fraction of
    /// touched coordinates is ≤ this, dense otherwise. 0 forces dense,
    /// 1 forces sparse. The merged arithmetic is representation-blind;
    /// the simulated message cost reflects the actual wire size, so
    /// with `net_per_elem > 0` the virtual-clock schedule (arrival
    /// order, merge picks) may differ between settings. Exact trace
    /// equivalence holds when message cost is size-independent
    /// (`net_per_elem = 0`).
    pub delta_threshold: f64,

    // Distributed execution (`[transport]` table)
    /// Cross-node transport: in-process channels (default, simulated
    /// cluster) or TCP / Unix-domain sockets for `train --distributed`.
    pub transport: TransportCfg,

    // Fault injection (`[chaos]` table / `--chaos` flag)
    /// Scripted fault plan in the [`FaultPlan::parse`] grammar
    /// (`kind:worker=W,round=R[,secs=X];...`); empty = no faults (the
    /// chaos decorator is not even installed).
    pub chaos_plan: String,
    /// Seed for the chaos plan's randomness (corrupt byte positions).
    /// A `seed=` entry inside `chaos_plan` overrides it.
    pub chaos_seed: u64,

    // Observability (`[obs]` table / `--metrics-out` / `--trace-out`)
    /// Run-scoped metrics registry and timeline tracer. Off by default;
    /// never affects solver arithmetic or `--dump` output.
    pub obs: ObsCfg,
}

impl Default for ExpConfig {
    fn default() -> Self {
        Self {
            dataset: "tiny".into(),
            data_path: None,
            store_path: None,
            seed: 42,
            loss: LossKind::Hinge,
            lambda: 1e-4,
            k_nodes: 4,
            r_cores: 2,
            partition: Strategy::Shuffled,
            h_local: 512,
            nu: 1.0,
            sigma: SigmaPolicy::NuS,
            wild: false,
            s_barrier: 4,
            gamma: 1,
            merge_policy: MergePolicy::OldestFirst,
            max_rounds: 100,
            gap_threshold: 1e-6,
            eval_every: 1,
            stragglers: Vec::new(),
            // Defaults keep the paper's compute-vs-communication regime:
            // an rcv1-s round (H=512 × ~73 nnz) costs ≈ 3.7 ms of compute
            // per core vs 0.1 ms per message, matching the paper's
            // H-balances-communication design point (§1).
            net_latency: 1e-4,
            net_per_elem: 1e-6,
            cost_per_nnz: 1e-7,
            // Sparse wire format costs 1.5 elems per touched coord, so
            // it wins below density 2/3; 0.5 keeps headroom.
            delta_threshold: 0.5,
            transport: TransportCfg::default(),
            chaos_plan: String::new(),
            chaos_seed: 0,
            obs: ObsCfg::default(),
        }
    }
}

impl ExpConfig {
    /// The effective σ for Hybrid-DCA under this config.
    pub fn sigma_value(&self) -> f64 {
        self.sigma.value(self.nu, self.s_barrier, self.k_nodes)
    }

    /// The effective parsed chaos plan. `chaos_seed` seeds it by
    /// default; a `seed=` entry inside the spec wins because the parser
    /// applies entries left to right.
    pub fn chaos(&self) -> anyhow::Result<FaultPlan> {
        FaultPlan::parse(&format!("seed={};{}", self.chaos_seed, self.chaos_plan))
    }

    /// Enforce parameter constraints.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            !(self.data_path.is_some() && self.store_path.is_some()),
            "data_path and store_path are mutually exclusive (a LIBSVM file vs a \
             packed shard store)"
        );
        anyhow::ensure!(self.lambda > 0.0, "lambda must be > 0 (got {})", self.lambda);
        anyhow::ensure!(self.k_nodes >= 1, "k_nodes must be ≥ 1");
        anyhow::ensure!(self.r_cores >= 1, "r_cores must be ≥ 1");
        anyhow::ensure!(
            (1..=self.k_nodes).contains(&self.s_barrier),
            "S must satisfy 1 ≤ S ≤ K (S={}, K={})",
            self.s_barrier,
            self.k_nodes
        );
        anyhow::ensure!(self.gamma >= 1, "Γ must be ≥ 1");
        anyhow::ensure!(
            self.nu > 0.0 && self.nu <= 1.0,
            "ν must be in (0, 1] (got {})",
            self.nu
        );
        // Eq. (5): σ ≥ ν·S is the safe region; warn-level enforcement —
        // smaller σ is allowed only via explicit Fixed (ablations study
        // divergence), never via the named policies.
        let sigma = self.sigma_value();
        anyhow::ensure!(sigma > 0.0, "σ must be > 0 (got {sigma})");
        anyhow::ensure!(self.h_local >= 1, "H must be ≥ 1");
        anyhow::ensure!(self.max_rounds >= 1, "max_rounds must be ≥ 1");
        anyhow::ensure!(self.eval_every >= 1, "eval_every must be ≥ 1");
        anyhow::ensure!(self.gap_threshold > 0.0, "gap_threshold must be > 0");
        if !self.stragglers.is_empty() {
            anyhow::ensure!(
                self.stragglers.len() == self.k_nodes,
                "stragglers must have one entry per node ({} != {})",
                self.stragglers.len(),
                self.k_nodes
            );
            anyhow::ensure!(
                self.stragglers.iter().all(|&s| s >= 1.0),
                "straggler multipliers must be ≥ 1.0"
            );
        }
        anyhow::ensure!(
            self.net_latency >= 0.0 && self.cost_per_nnz >= 0.0 && self.net_per_elem >= 0.0,
            "negative costs"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.delta_threshold),
            "delta_threshold must be in [0, 1] (got {})",
            self.delta_threshold
        );
        self.transport.validate()?;
        let plan = self
            .chaos()
            .map_err(|e| anyhow::anyhow!("chaos_plan: {e}"))?;
        for f in &plan.faults {
            anyhow::ensure!(
                f.worker < self.k_nodes,
                "chaos_plan targets worker {} but K = {}",
                f.worker,
                self.k_nodes
            );
        }
        Ok(())
    }

    /// Apply values from a parsed TOML document. Unknown keys error so
    /// typos are caught.
    pub fn apply_document(&mut self, doc: &Document) -> anyhow::Result<()> {
        for (table, kv) in &doc.tables {
            for (key, val) in kv {
                let dotted = if table.is_empty() {
                    key.clone()
                } else {
                    format!("{table}.{key}")
                };
                self.apply_kv(&dotted, val)
                    .map_err(|e| anyhow::anyhow!("config key '{dotted}': {e}"))?;
            }
        }
        Ok(())
    }

    fn apply_kv(&mut self, dotted: &str, val: &toml::Value) -> anyhow::Result<()> {
        use toml::Value;
        let need_f64 =
            || val.as_float().ok_or_else(|| anyhow::anyhow!("expected number, got {val:?}"));
        let need_usize = || {
            val.as_usize()
                .ok_or_else(|| anyhow::anyhow!("expected non-negative int, got {val:?}"))
        };
        let need_str =
            || val.as_str().ok_or_else(|| anyhow::anyhow!("expected string, got {val:?}"));
        match dotted {
            "dataset" | "data.dataset" => self.dataset = need_str()?.to_string(),
            "data.path" | "data_path" => self.data_path = Some(need_str()?.to_string()),
            "data.store" | "store_path" => self.store_path = Some(need_str()?.to_string()),
            "seed" | "data.seed" => {
                self.seed = val
                    .as_int()
                    .ok_or_else(|| anyhow::anyhow!("expected int"))? as u64
            }
            "loss" | "problem.loss" => {
                let s = need_str()?;
                self.loss = LossKind::parse(s)
                    .ok_or_else(|| anyhow::anyhow!("unknown loss '{s}'"))?
            }
            "lambda" | "problem.lambda" => self.lambda = need_f64()?,
            "cluster.k" | "k_nodes" => self.k_nodes = need_usize()?,
            "cluster.r" | "r_cores" => self.r_cores = need_usize()?,
            "cluster.partition" | "partition" => {
                self.partition = Strategy::parse(need_str()?)
                    .ok_or_else(|| anyhow::anyhow!("unknown partition strategy"))?
            }
            "solver.h" | "h_local" => self.h_local = need_usize()?,
            "solver.nu" | "nu" => self.nu = need_f64()?,
            "solver.sigma" | "sigma" => {
                self.sigma = match val {
                    Value::Str(s) => SigmaPolicy::parse(s).ok_or_else(|| {
                        anyhow::anyhow!(
                            "sigma must be 'auto' (νS), 'k' (νK), or a positive number; got '{s}'"
                        )
                    })?,
                    _ => {
                        let v = need_f64()?;
                        anyhow::ensure!(
                            v.is_finite() && v > 0.0,
                            "fixed σ must be a positive finite number (got {v})"
                        );
                        SigmaPolicy::Fixed(v)
                    }
                }
            }
            "solver.wild" | "wild" => {
                self.wild = val.as_bool().ok_or_else(|| anyhow::anyhow!("expected bool"))?
            }
            "master.s" | "s_barrier" => self.s_barrier = need_usize()?,
            "master.gamma" | "gamma" => self.gamma = need_usize()?,
            "master.policy" | "merge_policy" => {
                let s = need_str()?;
                self.merge_policy = MergePolicy::parse(s)
                    .ok_or_else(|| anyhow::anyhow!("unknown merge policy '{s}'"))?
            }
            "run.max-rounds" | "run.max_rounds" | "max_rounds" => self.max_rounds = need_usize()?,
            "run.gap-threshold" | "run.gap_threshold" | "gap_threshold" => {
                self.gap_threshold = need_f64()?
            }
            "run.eval-every" | "run.eval_every" | "eval_every" => self.eval_every = need_usize()?,
            "sim.stragglers" | "stragglers" => {
                let arr = val.as_array().ok_or_else(|| anyhow::anyhow!("expected array"))?;
                self.stragglers = arr
                    .iter()
                    .map(|v| v.as_float().ok_or_else(|| anyhow::anyhow!("expected numbers")))
                    .collect::<anyhow::Result<Vec<f64>>>()?;
            }
            "sim.net-latency" | "sim.net_latency" | "net_latency" => self.net_latency = need_f64()?,
            "sim.net-per-elem" | "sim.net_per_elem" | "net_per_elem" => {
                self.net_per_elem = need_f64()?
            }
            "sim.cost-per-nnz" | "sim.cost_per_nnz" | "cost_per_nnz" => {
                self.cost_per_nnz = need_f64()?
            }
            "sim.delta-threshold" | "sim.delta_threshold" | "delta_threshold" => {
                self.delta_threshold = need_f64()?
            }
            "transport.backend" => {
                let s = need_str()?;
                self.transport.backend = TransportBackend::parse(s)
                    .ok_or_else(|| anyhow::anyhow!("unknown transport backend '{s}'"))?
            }
            "transport.listen" => self.transport.listen = need_str()?.to_string(),
            "transport.join" => self.transport.join = need_str()?.to_string(),
            "transport.connect-timeout" | "transport.connect_timeout" => {
                self.transport.connect_timeout_secs = need_f64()?
            }
            "transport.accept-timeout" | "transport.accept_timeout" => {
                self.transport.accept_timeout_secs = need_f64()?
            }
            "transport.read-timeout" | "transport.read_timeout" => {
                self.transport.read_timeout_secs = need_f64()?
            }
            "transport.accept-backlog" | "transport.accept_backlog" => {
                self.transport.accept_backlog = need_usize()?
            }
            "transport.suspicion-timeouts" | "transport.suspicion_timeouts" => {
                self.transport.suspicion_timeouts = need_usize()? as u32
            }
            "transport.reconnect-attempts" | "transport.reconnect_attempts" => {
                self.transport.reconnect_attempts = need_usize()? as u32
            }
            "transport.backoff-base" | "transport.backoff_base" => {
                self.transport.backoff_base_secs = need_f64()?
            }
            "transport.backoff-max" | "transport.backoff_max" => {
                self.transport.backoff_max_secs = need_f64()?
            }
            "obs.enabled" | "obs_enabled" => {
                self.obs.enabled =
                    val.as_bool().ok_or_else(|| anyhow::anyhow!("expected bool"))?
            }
            "obs.trace" | "obs_trace" => {
                self.obs.trace = val.as_bool().ok_or_else(|| anyhow::anyhow!("expected bool"))?
            }
            "chaos.plan" | "chaos_plan" => self.chaos_plan = need_str()?.to_string(),
            "chaos.seed" | "chaos_seed" => {
                self.chaos_seed = val
                    .as_int()
                    .ok_or_else(|| anyhow::anyhow!("expected int"))? as u64
            }
            other => anyhow::bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    /// Load from a TOML file, applying defaults first.
    pub fn from_file(path: &str) -> anyhow::Result<ExpConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read config {path}: {e}"))?;
        let doc = toml::parse(&text)?;
        let mut cfg = ExpConfig::default();
        cfg.apply_document(&doc)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Serialize every field to JSON — the wire form a distributed
    /// master ships in its `Assign` frame so worker processes run the
    /// exact effective config. `f64`s print shortest-round-trip, so
    /// [`Self::from_json`] recovers identical bits; the `u64` seed
    /// travels as a string (a JSON number is an `f64` and would lose
    /// precision above 2⁵³).
    pub fn to_json(&self) -> Json {
        let opt = |o: &Option<String>| match o {
            Some(s) => Json::Str(s.clone()),
            None => Json::Null,
        };
        let loss = match self.loss {
            LossKind::Hinge => "hinge",
            LossKind::SquaredHinge => "squared_hinge",
            LossKind::Logistic => "logistic",
        };
        let sigma = match self.sigma {
            SigmaPolicy::NuS => "nus".to_string(),
            SigmaPolicy::NuK => "nuk".to_string(),
            SigmaPolicy::Fixed(v) => format!("{v}"),
        };
        let policy = match self.merge_policy {
            MergePolicy::OldestFirst => "oldest-first",
            MergePolicy::NewestFirst => "newest-first",
        };
        let t = &self.transport;
        Json::Obj(vec![
            ("dataset".into(), Json::Str(self.dataset.clone())),
            ("data_path".into(), opt(&self.data_path)),
            ("store_path".into(), opt(&self.store_path)),
            ("seed".into(), Json::Str(self.seed.to_string())),
            ("loss".into(), Json::Str(loss.into())),
            ("lambda".into(), Json::Num(self.lambda)),
            ("k_nodes".into(), Json::Num(self.k_nodes as f64)),
            ("r_cores".into(), Json::Num(self.r_cores as f64)),
            ("partition".into(), Json::Str(self.partition.name().into())),
            ("h_local".into(), Json::Num(self.h_local as f64)),
            ("nu".into(), Json::Num(self.nu)),
            ("sigma".into(), Json::Str(sigma)),
            ("wild".into(), Json::Bool(self.wild)),
            ("s_barrier".into(), Json::Num(self.s_barrier as f64)),
            ("gamma".into(), Json::Num(self.gamma as f64)),
            ("merge_policy".into(), Json::Str(policy.into())),
            ("max_rounds".into(), Json::Num(self.max_rounds as f64)),
            ("gap_threshold".into(), Json::Num(self.gap_threshold)),
            ("eval_every".into(), Json::Num(self.eval_every as f64)),
            (
                "stragglers".into(),
                Json::Arr(self.stragglers.iter().map(|&s| Json::Num(s)).collect()),
            ),
            ("net_latency".into(), Json::Num(self.net_latency)),
            ("net_per_elem".into(), Json::Num(self.net_per_elem)),
            ("cost_per_nnz".into(), Json::Num(self.cost_per_nnz)),
            ("delta_threshold".into(), Json::Num(self.delta_threshold)),
            (
                "transport".into(),
                Json::Obj(vec![
                    ("backend".into(), Json::Str(t.backend.name().into())),
                    ("listen".into(), Json::Str(t.listen.clone())),
                    ("join".into(), Json::Str(t.join.clone())),
                    ("connect_timeout_secs".into(), Json::Num(t.connect_timeout_secs)),
                    ("accept_timeout_secs".into(), Json::Num(t.accept_timeout_secs)),
                    ("read_timeout_secs".into(), Json::Num(t.read_timeout_secs)),
                    ("accept_backlog".into(), Json::Num(t.accept_backlog as f64)),
                    ("suspicion_timeouts".into(), Json::Num(f64::from(t.suspicion_timeouts))),
                    ("reconnect_attempts".into(), Json::Num(f64::from(t.reconnect_attempts))),
                    ("backoff_base_secs".into(), Json::Num(t.backoff_base_secs)),
                    ("backoff_max_secs".into(), Json::Num(t.backoff_max_secs)),
                ]),
            ),
            ("chaos_plan".into(), Json::Str(self.chaos_plan.clone())),
            ("chaos_seed".into(), Json::Str(self.chaos_seed.to_string())),
            (
                "obs".into(),
                Json::Obj(vec![
                    ("enabled".into(), Json::Bool(self.obs.enabled)),
                    ("trace".into(), Json::Bool(self.obs.trace)),
                ]),
            ),
        ])
    }

    /// Rebuild a config from [`Self::to_json`] output. Every field is
    /// required — a missing key means the two ends disagree about the
    /// config schema and the run must not start.
    pub fn from_json(text: &str) -> anyhow::Result<ExpConfig> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("config json: {e}"))?;
        let num = |o: &Json, key: &str| {
            o.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("config json: missing number '{key}'"))
        };
        let string = |o: &Json, key: &str| match o.get(key) {
            Some(Json::Str(s)) => Ok(s.clone()),
            _ => Err(anyhow::anyhow!("config json: missing string '{key}'")),
        };
        let flag = |o: &Json, key: &str| match o.get(key) {
            Some(Json::Bool(b)) => Ok(*b),
            _ => Err(anyhow::anyhow!("config json: missing bool '{key}'")),
        };
        let opt = |o: &Json, key: &str| match o.get(key) {
            Some(Json::Str(s)) => Some(s.clone()),
            _ => None,
        };

        let mut cfg = ExpConfig::default();
        cfg.dataset = string(&j, "dataset")?;
        cfg.data_path = opt(&j, "data_path");
        cfg.store_path = opt(&j, "store_path");
        let seed = string(&j, "seed")?;
        cfg.seed = seed
            .parse::<u64>()
            .map_err(|e| anyhow::anyhow!("config json: bad seed '{seed}': {e}"))?;
        let loss = string(&j, "loss")?;
        cfg.loss = LossKind::parse(&loss)
            .ok_or_else(|| anyhow::anyhow!("config json: unknown loss '{loss}'"))?;
        cfg.lambda = num(&j, "lambda")?;
        cfg.k_nodes = num(&j, "k_nodes")? as usize;
        cfg.r_cores = num(&j, "r_cores")? as usize;
        let part = string(&j, "partition")?;
        cfg.partition = Strategy::parse(&part)
            .ok_or_else(|| anyhow::anyhow!("config json: unknown partition '{part}'"))?;
        cfg.h_local = num(&j, "h_local")? as usize;
        cfg.nu = num(&j, "nu")?;
        let sigma = string(&j, "sigma")?;
        cfg.sigma = SigmaPolicy::parse(&sigma)
            .ok_or_else(|| anyhow::anyhow!("config json: bad sigma '{sigma}'"))?;
        cfg.wild = flag(&j, "wild")?;
        cfg.s_barrier = num(&j, "s_barrier")? as usize;
        cfg.gamma = num(&j, "gamma")? as usize;
        let policy = string(&j, "merge_policy")?;
        cfg.merge_policy = MergePolicy::parse(&policy)
            .ok_or_else(|| anyhow::anyhow!("config json: unknown merge policy '{policy}'"))?;
        cfg.max_rounds = num(&j, "max_rounds")? as usize;
        cfg.gap_threshold = num(&j, "gap_threshold")?;
        cfg.eval_every = num(&j, "eval_every")? as usize;
        cfg.stragglers = j
            .get("stragglers")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("config json: missing array 'stragglers'"))?
            .iter()
            .map(|v| {
                v.as_f64()
                    .ok_or_else(|| anyhow::anyhow!("config json: non-numeric straggler"))
            })
            .collect::<anyhow::Result<Vec<f64>>>()?;
        cfg.net_latency = num(&j, "net_latency")?;
        cfg.net_per_elem = num(&j, "net_per_elem")?;
        cfg.cost_per_nnz = num(&j, "cost_per_nnz")?;
        cfg.delta_threshold = num(&j, "delta_threshold")?;
        let t = j
            .get("transport")
            .ok_or_else(|| anyhow::anyhow!("config json: missing object 'transport'"))?;
        let backend = string(t, "backend")?;
        cfg.transport = TransportCfg {
            backend: TransportBackend::parse(&backend).ok_or_else(|| {
                anyhow::anyhow!("config json: unknown transport backend '{backend}'")
            })?,
            listen: string(t, "listen")?,
            join: string(t, "join")?,
            connect_timeout_secs: num(t, "connect_timeout_secs")?,
            accept_timeout_secs: num(t, "accept_timeout_secs")?,
            read_timeout_secs: num(t, "read_timeout_secs")?,
            accept_backlog: num(t, "accept_backlog")? as usize,
            suspicion_timeouts: num(t, "suspicion_timeouts")? as u32,
            reconnect_attempts: num(t, "reconnect_attempts")? as u32,
            backoff_base_secs: num(t, "backoff_base_secs")?,
            backoff_max_secs: num(t, "backoff_max_secs")?,
        };
        cfg.chaos_plan = string(&j, "chaos_plan")?;
        let chaos_seed = string(&j, "chaos_seed")?;
        cfg.chaos_seed = chaos_seed
            .parse::<u64>()
            .map_err(|e| anyhow::anyhow!("config json: bad chaos_seed '{chaos_seed}': {e}"))?;
        let o = j
            .get("obs")
            .ok_or_else(|| anyhow::anyhow!("config json: missing object 'obs'"))?;
        cfg.obs = ObsCfg { enabled: flag(o, "enabled")?, trace: flag(o, "trace")? };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        ExpConfig::default().validate().unwrap();
    }

    #[test]
    fn sigma_policies() {
        assert_eq!(SigmaPolicy::NuS.value(1.0, 4, 8), 4.0);
        assert_eq!(SigmaPolicy::NuK.value(0.5, 4, 8), 4.0);
        assert_eq!(SigmaPolicy::Fixed(2.5).value(1.0, 4, 8), 2.5);
        assert_eq!(SigmaPolicy::parse("s"), Some(SigmaPolicy::NuS));
        assert_eq!(SigmaPolicy::parse("K"), Some(SigmaPolicy::NuK));
        assert_eq!(SigmaPolicy::parse("3.5"), Some(SigmaPolicy::Fixed(3.5)));
        assert_eq!(SigmaPolicy::parse("bogus"), None);
        // Non-positive / non-finite fixed σ rejected at parse time.
        assert_eq!(SigmaPolicy::parse("0"), None);
        assert_eq!(SigmaPolicy::parse("-2.5"), None);
        assert_eq!(SigmaPolicy::parse("nan"), None);
        assert_eq!(SigmaPolicy::parse("inf"), None);
    }

    #[test]
    fn merge_policy_parse() {
        assert_eq!(MergePolicy::parse("oldest-first"), Some(MergePolicy::OldestFirst));
        assert_eq!(MergePolicy::parse("Newest"), Some(MergePolicy::NewestFirst));
        assert_eq!(MergePolicy::parse("fifo"), None);
    }

    #[test]
    fn non_positive_sigma_rejected_in_toml() {
        let doc = toml::parse("sigma = -1.0\n").unwrap();
        let mut cfg = ExpConfig::default();
        assert!(cfg.apply_document(&doc).is_err());
        let doc = toml::parse("sigma = \"-1.0\"\n").unwrap();
        assert!(cfg.apply_document(&doc).is_err());
        // Non-finite numerics are rejected like the string path rejects
        // "inf"/"nan".
        let doc = toml::parse("sigma = inf\n").unwrap();
        assert!(cfg.apply_document(&doc).is_err());
    }

    #[test]
    fn algorithm_parse() {
        assert_eq!(Algorithm::parse("cocoa+"), Some(Algorithm::CocoaPlus));
        assert_eq!(Algorithm::parse("Hybrid-DCA"), Some(Algorithm::HybridDca));
        assert_eq!(Algorithm::parse("sgd"), None);
    }

    #[test]
    fn validation_constraints() {
        let mut c = ExpConfig::default();
        c.s_barrier = 5; // > K=4
        assert!(c.validate().is_err());
        c = ExpConfig::default();
        c.gamma = 0;
        assert!(c.validate().is_err());
        c = ExpConfig::default();
        c.nu = 1.5;
        assert!(c.validate().is_err());
        c = ExpConfig::default();
        c.lambda = 0.0;
        assert!(c.validate().is_err());
        c = ExpConfig::default();
        c.stragglers = vec![1.0, 2.0]; // wrong length for K=4
        assert!(c.validate().is_err());
        c.stragglers = vec![1.0, 2.0, 1.0, 0.5]; // < 1.0
        assert!(c.validate().is_err());
    }

    #[test]
    fn apply_document_full() {
        let text = r#"
dataset = "rcv1-s"
seed = 7
lambda = 1e-4
loss = "hinge"

[cluster]
k = 8
r = 4
partition = "striped"

[solver]
h = 1000
nu = 0.5
sigma = "k"
wild = true

[master]
s = 6
gamma = 10
policy = "newest-first"

[run]
max_rounds = 50
gap_threshold = 1e-5
eval_every = 2

[sim]
stragglers = [1.0, 1.0, 1.0, 1.0, 2.0, 1.0, 1.0, 4.0]
net_latency = 0.01
cost_per_nnz = 1e-7
"#;
        let doc = toml::parse(text).unwrap();
        let mut cfg = ExpConfig::default();
        cfg.apply_document(&doc).unwrap();
        cfg.validate().unwrap();
        assert_eq!(cfg.dataset, "rcv1-s");
        assert_eq!(cfg.k_nodes, 8);
        assert_eq!(cfg.r_cores, 4);
        assert_eq!(cfg.partition, Strategy::Striped);
        assert_eq!(cfg.h_local, 1000);
        assert_eq!(cfg.sigma, SigmaPolicy::NuK);
        assert!(cfg.wild);
        assert_eq!(cfg.s_barrier, 6);
        assert_eq!(cfg.gamma, 10);
        assert_eq!(cfg.merge_policy, MergePolicy::NewestFirst);
        assert_eq!(cfg.stragglers.len(), 8);
        assert_eq!(cfg.sigma_value(), 0.5 * 8.0);
    }

    #[test]
    fn delta_threshold_validated_and_parsed() {
        let mut c = ExpConfig::default();
        c.delta_threshold = 1.5;
        assert!(c.validate().is_err());
        c.delta_threshold = -0.1;
        assert!(c.validate().is_err());
        c.delta_threshold = 1.0;
        c.validate().unwrap();

        let doc = toml::parse("[sim]\ndelta_threshold = 0.25\n").unwrap();
        let mut cfg = ExpConfig::default();
        cfg.apply_document(&doc).unwrap();
        assert_eq!(cfg.delta_threshold, 0.25);
    }

    #[test]
    fn store_path_parsed_and_exclusive() {
        let doc = toml::parse("[data]\nstore = \"tiny_store\"\n").unwrap();
        let mut cfg = ExpConfig::default();
        cfg.apply_document(&doc).unwrap();
        assert_eq!(cfg.store_path.as_deref(), Some("tiny_store"));
        cfg.validate().unwrap();
        // A LIBSVM path and a shard store at once is ambiguous.
        cfg.data_path = Some("x.svm".into());
        let err = cfg.validate().unwrap_err();
        assert!(err.to_string().contains("mutually exclusive"), "{err}");
    }

    #[test]
    fn transport_table_parsed() {
        let text = r#"
[transport]
backend = "tcp"
listen = "127.0.0.1:7070"
join = "127.0.0.1:7070"
connect_timeout = 2.5
accept_timeout = 5.0
read_timeout = 1.5
accept_backlog = 8
suspicion_timeouts = 3
reconnect_attempts = 7
backoff_base = 0.1
backoff_max = 2.0
"#;
        let doc = toml::parse(text).unwrap();
        let mut cfg = ExpConfig::default();
        cfg.apply_document(&doc).unwrap();
        cfg.validate().unwrap();
        assert_eq!(cfg.transport.backend, TransportBackend::Tcp);
        assert_eq!(cfg.transport.listen, "127.0.0.1:7070");
        assert_eq!(cfg.transport.connect_timeout_secs, 2.5);
        assert_eq!(cfg.transport.accept_backlog, 8);
        assert_eq!(cfg.transport.suspicion_timeouts, 3);
        assert_eq!(cfg.transport.reconnect_attempts, 7);
        assert_eq!(cfg.transport.backoff_base_secs, 0.1);
        assert_eq!(cfg.transport.backoff_max_secs, 2.0);

        let doc = toml::parse("[transport]\nbackend = \"carrier-pigeon\"\n").unwrap();
        assert!(cfg.apply_document(&doc).is_err());
    }

    #[test]
    fn chaos_table_parsed_and_validated() {
        let doc = toml::parse(
            "[chaos]\nplan = \"stall:worker=1,round=2,secs=0.1;kill:worker=2,round=4\"\nseed = 9\n",
        )
        .unwrap();
        let mut cfg = ExpConfig::default();
        cfg.apply_document(&doc).unwrap();
        cfg.validate().unwrap();
        let plan = cfg.chaos().unwrap();
        assert_eq!(plan.faults.len(), 2);
        assert_eq!(plan.seed, 9);
        // An in-spec seed= beats chaos_seed (entries apply left to right).
        cfg.chaos_plan = "drop:worker=0,round=1;seed=3".into();
        assert_eq!(cfg.chaos().unwrap().seed, 3);
        // Faults must target real workers (K = 4 by default).
        cfg.chaos_plan = "kill:worker=9,round=1".into();
        let err = cfg.validate().unwrap_err();
        assert!(err.to_string().contains("worker 9"), "{err}");
        // A malformed plan is a config error, not a runtime surprise.
        cfg.chaos_plan = "fry:worker=0,round=1".into();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn json_round_trip_is_exact() {
        let mut cfg = ExpConfig::default();
        cfg.dataset = "rcv1-s".into();
        cfg.store_path = Some("tiny_store".into());
        cfg.seed = u64::MAX - 7; // would lose bits as a JSON number
        cfg.loss = LossKind::Logistic;
        cfg.lambda = 1e-4 / 3.0; // not exactly representable in decimal
        cfg.k_nodes = 8;
        cfg.s_barrier = 6;
        cfg.partition = Strategy::Striped;
        cfg.sigma = SigmaPolicy::Fixed(6.25);
        cfg.merge_policy = MergePolicy::NewestFirst;
        cfg.wild = true;
        cfg.stragglers = vec![1.0; 8];
        cfg.stragglers[3] = 2.0 + f64::EPSILON;
        cfg.transport.backend = TransportBackend::Uds;
        cfg.transport.listen = "/tmp/hdca.sock".into();
        cfg.transport.join = "/tmp/hdca.sock".into();
        cfg.transport.read_timeout_secs = 0.75;
        cfg.transport.suspicion_timeouts = 2;
        cfg.transport.reconnect_attempts = 9;
        cfg.transport.backoff_base_secs = 0.05;
        cfg.transport.backoff_max_secs = 1.0 / 3.0; // not exact in decimal
        cfg.chaos_plan = "stall:worker=1,round=2,secs=0.25".into();
        cfg.chaos_seed = u64::MAX - 11;
        cfg.obs = ObsCfg { enabled: true, trace: true };
        let back = ExpConfig::from_json(&cfg.to_json().to_pretty()).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn json_missing_field_is_an_error() {
        let j = ExpConfig::default().to_json();
        let pruned = match j {
            Json::Obj(kvs) => {
                Json::Obj(kvs.into_iter().filter(|(k, _)| k != "gap_threshold").collect())
            }
            _ => unreachable!(),
        };
        let err = ExpConfig::from_json(&pruned.to_pretty()).unwrap_err();
        assert!(err.to_string().contains("gap_threshold"), "{err}");
    }

    #[test]
    fn unknown_key_errors() {
        let doc = toml::parse("bogus_key = 1\n").unwrap();
        let mut cfg = ExpConfig::default();
        assert!(cfg.apply_document(&doc).is_err());
    }
}
