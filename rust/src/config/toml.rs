//! A TOML-subset parser (no `serde`/`toml` crates offline).
//!
//! Supported grammar — everything the experiment configs need:
//!
//! ```toml
//! # comment
//! key = 3              # integer
//! key = 3.5            # float (also 1e-4)
//! key = "string"
//! key = true
//! key = [1, 2, 3]      # homogeneous scalar arrays
//! [section]            # tables, one level deep
//! key = ...
//! ```
//!
//! Values are exposed through a dynamically-typed [`Value`]; the typed
//! schema layer (`config::schema`) does the validation and defaulting.

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Str(String),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_int().and_then(|i| usize::try_from(i).ok())
    }

    /// Floats accept integer literals too (`lambda = 1` is fine).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// Parsed document: `table -> key -> value`. Root-level keys live under
/// the empty-string table.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Document {
    pub tables: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Document {
    pub fn get(&self, table: &str, key: &str) -> Option<&Value> {
        self.tables.get(table).and_then(|t| t.get(key))
    }

    /// Look up `"table.key"` or root `"key"`.
    pub fn lookup(&self, dotted: &str) -> Option<&Value> {
        match dotted.split_once('.') {
            Some((t, k)) => self.get(t, k),
            None => self.get("", dotted),
        }
    }
}

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> anyhow::Result<Document> {
    let mut doc = Document::default();
    let mut current = String::new();
    doc.tables.entry(current.clone()).or_default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| anyhow::anyhow!("line {}: unterminated table header", lineno + 1))?
                .trim();
            anyhow::ensure!(
                !name.is_empty()
                    && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-'),
                "line {}: bad table name '{name}'",
                lineno + 1
            );
            current = name.to_string();
            doc.tables.entry(current.clone()).or_default();
            continue;
        }
        let (key, val) = line
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("line {}: expected 'key = value'", lineno + 1))?;
        let key = key.trim();
        anyhow::ensure!(
            !key.is_empty()
                && key.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-'),
            "line {}: bad key '{key}'",
            lineno + 1
        );
        let value = parse_value(val.trim())
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        let table = doc.tables.get_mut(&current).unwrap();
        anyhow::ensure!(
            table.insert(key.to_string(), value).is_none(),
            "line {}: duplicate key '{key}'",
            lineno + 1
        );
    }
    Ok(doc)
}

/// Strip a `#` comment that is not inside a string literal.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> anyhow::Result<Value> {
    anyhow::ensure!(!s.is_empty(), "empty value");
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| anyhow::anyhow!("unterminated string"))?;
        anyhow::ensure!(!inner.contains('"'), "embedded quote in string");
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| anyhow::anyhow!("unterminated array"))?
            .trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let mut items = Vec::new();
        for part in inner.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue; // trailing comma
            }
            let v = parse_value(part)?;
            anyhow::ensure!(!matches!(v, Value::Array(_)), "nested arrays unsupported");
            items.push(v);
        }
        return Ok(Value::Array(items));
    }
    // Number: prefer int if it parses and has no float syntax.
    let looks_float = s.contains('.') || s.contains('e') || s.contains('E');
    if !looks_float {
        if let Ok(i) = s.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    anyhow::bail!("cannot parse value '{s}'")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        let doc = parse("a = 1\nb = 2.5\nc = \"hi\"\nd = true\ne = 1e-4\n").unwrap();
        assert_eq!(doc.get("", "a"), Some(&Value::Int(1)));
        assert_eq!(doc.get("", "b"), Some(&Value::Float(2.5)));
        assert_eq!(doc.get("", "c"), Some(&Value::Str("hi".into())));
        assert_eq!(doc.get("", "d"), Some(&Value::Bool(true)));
        assert_eq!(doc.get("", "e"), Some(&Value::Float(1e-4)));
    }

    #[test]
    fn parse_tables_and_lookup() {
        let doc = parse("x = 1\n[solver]\nh = 100\n[cluster]\nk = 4\n").unwrap();
        assert_eq!(doc.lookup("x"), Some(&Value::Int(1)));
        assert_eq!(doc.lookup("solver.h"), Some(&Value::Int(100)));
        assert_eq!(doc.lookup("cluster.k"), Some(&Value::Int(4)));
        assert_eq!(doc.lookup("cluster.missing"), None);
    }

    #[test]
    fn parse_arrays() {
        let doc = parse("s = [2, 4, 8]\nmixed = [1, 2.5]\nempty = []\ntrail = [1, 2,]\n").unwrap();
        let s = doc.get("", "s").unwrap().as_array().unwrap();
        assert_eq!(s.len(), 3);
        assert_eq!(s[2].as_int(), Some(8));
        assert_eq!(doc.get("", "empty").unwrap().as_array().unwrap().len(), 0);
        assert_eq!(doc.get("", "trail").unwrap().as_array().unwrap().len(), 2);
    }

    #[test]
    fn comments_and_blank_lines() {
        let doc = parse("# top\na = 1 # trailing\n\nb = \"x # not comment\"\n").unwrap();
        assert_eq!(doc.get("", "a"), Some(&Value::Int(1)));
        assert_eq!(doc.get("", "b"), Some(&Value::Str("x # not comment".into())));
    }

    #[test]
    fn errors() {
        assert!(parse("a 1").is_err());
        assert!(parse("a = ").is_err());
        assert!(parse("a = \"x").is_err());
        assert!(parse("[t\na=1").is_err());
        assert!(parse("a = 1\na = 2").is_err());
        assert!(parse("a = [[1]]").is_err());
        assert!(parse("bad key = 1").is_err());
    }

    #[test]
    fn value_coercions() {
        assert_eq!(Value::Int(3).as_float(), Some(3.0));
        assert_eq!(Value::Int(3).as_usize(), Some(3));
        assert_eq!(Value::Int(-1).as_usize(), None);
        assert_eq!(Value::Float(2.5).as_int(), None);
        assert_eq!(Value::Str("s".into()).as_str(), Some("s"));
        assert_eq!(Value::Bool(true).as_bool(), Some(true));
    }
}
