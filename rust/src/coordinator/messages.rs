//! Message types exchanged between workers and the master.
//!
//! In the paper these travel over MPI between nodes; here they travel
//! over `util::sync::mailbox` channels between threads (or sockets,
//! see `transport`). The payload shapes
//! are identical to the paper's: workers send `Δv ∈ R^d`, the master
//! replies with the merged `v ∈ R^d` (§5 counts exactly these 2S
//! transmissions per round). The one refinement is the *wire format*
//! of Δv: when a round touched few coordinates (short rounds on very
//! sparse data — the rcv1/kddb regime), shipping the dense `R^d`
//! vector wastes O(d) per message, so [`DeltaV`] carries either form
//! behind one enum and both sides treat them identically.

/// One round's accumulated `Δv`, dense or sparse. The two
/// representations are numerically interchangeable — the sparse form
/// lists exactly the touched coordinates, and every untouched dense
/// entry is 0.0 — so merge results are identical under either
/// (`tests/prop_kernels.rs` pins this).
#[derive(Debug, Clone, PartialEq)]
pub enum DeltaV {
    /// Full `R^d` vector.
    Dense(Vec<f64>),
    /// Touched coordinates only; `indices` ascending, same length as
    /// `values`.
    Sparse { dim: usize, indices: Vec<u32>, values: Vec<f64> },
}

impl DeltaV {
    /// Feature dimension `d` of the underlying vector.
    pub fn dim(&self) -> usize {
        match self {
            DeltaV::Dense(dv) => dv.len(),
            DeltaV::Sparse { dim, .. } => *dim,
        }
    }

    /// Stored entries: `d` for dense, touched count for sparse.
    pub fn nnz(&self) -> usize {
        match self {
            DeltaV::Dense(dv) => dv.len(),
            DeltaV::Sparse { indices, .. } => indices.len(),
        }
    }

    pub fn is_sparse(&self) -> bool {
        matches!(self, DeltaV::Sparse { .. })
    }

    /// `out += scale · Δv` — the master's merge step, one add per
    /// coordinate under either representation.
    pub fn add_scaled_into(&self, out: &mut [f64], scale: f64) {
        match self {
            DeltaV::Dense(dv) => crate::util::axpy(out, scale, dv),
            DeltaV::Sparse { dim, indices, values } => {
                assert_eq!(out.len(), *dim, "merge target dimension");
                for (&j, &x) in indices.iter().zip(values.iter()) {
                    out[j as usize] += scale * x;
                }
            }
        }
    }

    /// Materialize as a dense vector (tests / cold paths).
    pub fn to_dense(&self) -> Vec<f64> {
        match self {
            DeltaV::Dense(dv) => dv.clone(),
            DeltaV::Sparse { dim, indices, values } => {
                let mut out = vec![0.0; *dim];
                for (&j, &x) in indices.iter().zip(values.iter()) {
                    out[j as usize] = x;
                }
                out
            }
        }
    }

    /// f64-equivalent elements on the wire, for the virtual network
    /// cost model: a dense message ships `d` values; a sparse one
    /// ships a u32 index (half an f64) plus an f64 value per entry.
    pub fn wire_elems(&self) -> f64 {
        match self {
            DeltaV::Dense(dv) => dv.len() as f64,
            DeltaV::Sparse { indices, .. } => 1.5 * indices.len() as f64,
        }
    }
}

/// Worker → master: one round's accumulated update.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerMsg {
    /// Worker (node) id `k`.
    pub worker: usize,
    /// The worker's local round counter (monotone per worker).
    pub local_round: usize,
    /// `Δv = v − v_old` accumulated over the round (Algorithm 1 line
    /// 10), dense or sparse by the density threshold.
    pub delta_v: DeltaV,
    /// `Σ_{i∈I_k} −φ*(−α_i)` over the worker's *committed* α — lets the
    /// master assemble `D(α)` without a synchronous gather (the paper
    /// defers gap computation for the same reason, §6.1).
    pub dual_sum: f64,
    /// Virtual time at which this message arrives at the master
    /// (send time + network latency).
    pub arrival_vtime: f64,
    /// Coordinate updates performed in this round (≤ R·H; empty-row
    /// draws excluded).
    pub updates: u64,
}

/// Master → worker: the merged global state (or termination).
#[derive(Debug, Clone, PartialEq)]
pub struct MasterReply {
    /// Merged `v^{(t+1)}` (empty when `terminate`).
    pub v: Vec<f64>,
    /// Virtual time at which this reply arrives at the worker.
    pub arrival_vtime: f64,
    /// Global round that produced this `v`.
    pub global_round: usize,
    /// Stop now.
    pub terminate: bool,
}

impl MasterReply {
    pub fn terminate_now(vtime: f64, round: usize) -> Self {
        MasterReply { v: Vec::new(), arrival_vtime: vtime, global_round: round, terminate: true }
    }
}

/// Worker → master: final committed state, reported after shutdown.
/// (In-process runs return it through the thread join as well; socket
/// runs ship it as a `Final` frame so the master process can assemble
/// the global α.)
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerFinal {
    pub worker_id: usize,
    /// Committed α values with their global row ids.
    pub alpha: Vec<(usize, f64)>,
    /// Rounds completed locally.
    pub local_rounds: usize,
    /// Total coordinate updates performed.
    pub updates: u64,
    /// Final local virtual time.
    pub vtime: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn terminate_reply() {
        let r = MasterReply::terminate_now(1.5, 7);
        assert!(r.terminate);
        assert!(r.v.is_empty());
        assert_eq!(r.global_round, 7);
        assert_eq!(r.arrival_vtime, 1.5);
    }

    #[test]
    fn delta_v_representations_merge_identically() {
        let dense = DeltaV::Dense(vec![0.0, 2.0, 0.0, -1.5]);
        let sparse = DeltaV::Sparse { dim: 4, indices: vec![1, 3], values: vec![2.0, -1.5] };
        assert_eq!(dense.dim(), 4);
        assert_eq!(sparse.dim(), 4);
        assert_eq!(sparse.nnz(), 2);
        assert!(sparse.is_sparse() && !dense.is_sparse());
        assert_eq!(sparse.to_dense(), vec![0.0, 2.0, 0.0, -1.5]);

        let mut a = vec![1.0, 1.0, 1.0, 1.0];
        let mut b = a.clone();
        dense.add_scaled_into(&mut a, 0.5);
        sparse.add_scaled_into(&mut b, 0.5);
        assert_eq!(a, b);
        assert_eq!(a, vec![1.0, 2.0, 1.0, 0.25]);
    }

    #[test]
    fn wire_elems_counts_sparse_payload() {
        let dense = DeltaV::Dense(vec![0.0; 100]);
        assert_eq!(dense.wire_elems(), 100.0);
        let sparse = DeltaV::Sparse { dim: 100, indices: vec![5, 9], values: vec![1.0, 2.0] };
        assert_eq!(sparse.wire_elems(), 3.0); // 2 × (u32 + f64) = 2 × 1.5
    }
}
