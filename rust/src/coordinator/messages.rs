//! Message types exchanged between workers and the master.
//!
//! In the paper these travel over MPI between nodes; here they travel
//! over `std::sync::mpsc` channels between threads. The payload shapes
//! are identical to the paper's: workers send `Δv ∈ R^d`, the master
//! replies with the merged `v ∈ R^d` (§5 counts exactly these 2S
//! transmissions per round).

/// Worker → master: one round's accumulated update.
#[derive(Debug, Clone)]
pub struct WorkerMsg {
    /// Worker (node) id `k`.
    pub worker: usize,
    /// The worker's local round counter (monotone per worker).
    pub local_round: usize,
    /// `Δv = v − v_old` accumulated over the round (Algorithm 1 line 10).
    pub delta_v: Vec<f64>,
    /// `Σ_{i∈I_k} −φ*(−α_i)` over the worker's *committed* α — lets the
    /// master assemble `D(α)` without a synchronous gather (the paper
    /// defers gap computation for the same reason, §6.1).
    pub dual_sum: f64,
    /// Virtual time at which this message arrives at the master
    /// (send time + network latency).
    pub arrival_vtime: f64,
    /// Coordinate updates performed in this round (R·H).
    pub updates: u64,
}

/// Master → worker: the merged global state (or termination).
#[derive(Debug, Clone)]
pub struct MasterReply {
    /// Merged `v^{(t+1)}` (empty when `terminate`).
    pub v: Vec<f64>,
    /// Virtual time at which this reply arrives at the worker.
    pub arrival_vtime: f64,
    /// Global round that produced this `v`.
    pub global_round: usize,
    /// Stop now.
    pub terminate: bool,
}

impl MasterReply {
    pub fn terminate_now(vtime: f64, round: usize) -> Self {
        MasterReply { v: Vec::new(), arrival_vtime: vtime, global_round: round, terminate: true }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn terminate_reply() {
        let r = MasterReply::terminate_now(1.5, 7);
        assert!(r.terminate);
        assert!(r.v.is_empty());
        assert_eq!(r.global_round, 7);
        assert_eq!(r.arrival_vtime, 1.5);
    }
}
