//! The worker node — Algorithm 1: run `R` asynchronous core-threads for
//! `H` iterations each, send `Δv` to the master, wait for the merged
//! `v`, commit `α ← α + ν·δ`, repeat.
//!
//! ## Fault tolerance (the worker's half)
//!
//! The round loop is a stop-and-wait ARQ endpoint: the current round's
//! `Update` frame is held un-consumed until a reply acknowledges it, so
//! a `Nack` from the master (or a reconnect) can retransmit it.
//! Duplicate `Merged` replies are skipped by global round, master
//! silence past the read timeout is answered with a `Nack` probe, and
//! a dead connection goes through [`Transport::reconnect`] — jittered
//! exponential backoff plus a [`Rejoin`](Frame::Rejoin) handshake
//! carrying a CRC of the committed α — before the worker gives up and
//! errors out. A fault-free run takes none of these paths.

use crate::data::Dataset;
use crate::loss::Loss;
use crate::sim::{SendCost, UpdateCosts};
use crate::solver::local::{LocalSolver, DUAL_RESYNC_EVERY};
use crate::solver::StepParams;
use crate::store::format::crc32;
use crate::transport::{Frame, RejoinInfo, Transport, TransportError, MASTER};
use crate::util::Rng;

use super::messages::{DeltaV, WorkerFinal, WorkerMsg};

/// Worker configuration.
#[derive(Debug, Clone)]
pub struct WorkerCfg {
    pub worker_id: usize,
    pub h_local: usize,
    pub nu: f64,
    pub sigma: f64,
    pub lambda: f64,
    pub wild: bool,
    /// Virtual-clock slowdown multiplier for this node (≥ 1).
    pub straggler: f64,
    /// Virtual cost model of the send (worker → master message).
    pub send_cost: SendCost,
    /// Δv density threshold: the round delta goes out sparse when the
    /// touched-coordinate fraction is ≤ this (0 forces dense, 1 forces
    /// sparse). The merged arithmetic is identical either way; the
    /// simulated send cost tracks the actual wire size.
    pub delta_threshold: f64,
    /// Global number of rows `n` — the dual is 1/n-scaled globally
    /// (paper Eq. 4) even when `data` is this node's slab of a shard
    /// store rather than the full dataset.
    pub n_global: usize,
    /// Global row id of `data`'s first row: 0 when `data` is the full
    /// dataset, the node's slab offset when it was streamed from
    /// shards. Only used to report final α under global ids.
    pub row_base: usize,
}

/// CRC-32 over the committed α (f64 little-endian bytes, shard order)
/// — the integrity token a `Rejoin` frame carries so a resumed run can
/// prove the worker's state survived the reconnect bitwise.
fn committed_alpha_crc(solver: &LocalSolver) -> u32 {
    let mut bytes = Vec::with_capacity(solver.n_local() * 8);
    for shard in &solver.shards {
        for &a in &shard.alpha_start {
            bytes.extend_from_slice(&a.to_le_bytes());
        }
    }
    crc32(&bytes)
}

/// The resumable-handshake token for this worker right now. Only built
/// on the reconnect path — the α CRC is an O(n_k) scan.
fn rejoin_info(cfg: &WorkerCfg, solver: &LocalSolver, last_acked_round: usize) -> RejoinInfo {
    RejoinInfo {
        worker_id: cfg.worker_id,
        last_acked_round,
        alpha_crc: committed_alpha_crc(solver),
    }
}

/// Run one worker until the master's `Shutdown` frame.
///
/// `cells` are this node's per-core index shards (`I_{k,r}`);
/// `norms`/`costs` are per-row tables covering exactly `data`'s rows.
/// All master traffic flows through `link` (its single peer is
/// [`MASTER`]). On shutdown the final committed state is both sent to
/// the master as a `Final` frame and returned. A vanished master is an
/// error (socket workers exit non-zero with its address in the
/// message), not a silent break.
pub fn run_worker(
    cfg: &WorkerCfg,
    cells: Vec<Vec<usize>>,
    data: &Dataset,
    loss: &dyn Loss,
    norms: &[f64],
    costs: &UpdateCosts,
    link: &mut dyn Transport,
    mut rng: Rng,
) -> anyhow::Result<WorkerFinal> {
    let params = StepParams { lambda: cfg.lambda, n: cfg.n_global, sigma: cfg.sigma };
    let mut solver = LocalSolver::new(cells, data.d(), params, cfg.wild, &mut rng);
    // Dirty-coordinate tracking replaces the O(d) snapshot + diff per
    // round: Δv is read at the touched coordinates only.
    solver.enable_delta_tracking();
    // Incremental dual tracking replaces the O(n_k) dual rescan per
    // round: the sums ride along with each update.
    solver.enable_dual_tracking(data, loss);
    let mut commits = 0usize;
    // Mirror of the v each round starts from (v_old, Algorithm 1 line
    // 3) — refreshed from the master's replies, never re-snapshotted.
    let mut v_prev = vec![0.0f64; data.d()];
    let d = data.d();
    let mut vtime = 0.0f64;
    let mut local_rounds = 0usize;
    let mut total_updates = 0u64;
    // Highest master global round committed — the duplicate filter of
    // the stop-and-wait protocol (real rounds are 1-based, 0 = none).
    let mut last_global_round = 0usize;

    loop {
        // R cores × H iterations (lines 4–9). The obs span brackets the
        // physical compute only — one record per round, never per
        // update, so the hot loop stays untouched.
        let round_t0 = crate::obs::global().timer();
        let stats = solver.run_round(data, loss, norms, costs, cfg.h_local);
        total_updates += stats.updates;
        vtime += cfg.straggler * stats.node_secs();
        crate::obs::global().worker_round(cfg.worker_id, local_rounds, stats.updates, round_t0);

        // Commit α ← α + ν·δ (line 12).
        //
        // Note on ordering: the paper commits after receiving the merged
        // v, but δ is fixed once the round ends, so committing before
        // the send lets us attach this round's dual sum to the message.
        solver.commit(cfg.nu);
        commits += 1;
        // ν = 1 commits take the live α bitwise, so the tracked sums
        // stay exact and only the periodic drift guard rescans; a
        // ν ≠ 1 commit moves α off the tracked value and needs the
        // exact O(n_k) re-accumulation (the old per-round cost).
        if cfg.nu != 1.0 || commits % DUAL_RESYNC_EVERY == 0 {
            solver.resync_dual(data, loss);
        }
        let dual_sum = solver.dual_sum();

        // Δv = (v − v_old)/σ (line 10) at the touched support: the live
        // v accumulated the round's updates at σ·(1/λn) (see
        // `solver::local`); the wire format is the paper's
        // Δv = (1/λn)·X·δ. Both representations carry the same values —
        // the threshold only picks the cheaper wire format.
        let touched = solver.take_touched();
        let inv_sigma = 1.0 / cfg.sigma;
        // Threshold 0 must force dense even on a zero-touch round
        // (0 ≤ 0·d would otherwise pick sparse and skew a forced-dense
        // cost baseline); threshold 1 always passes the fraction test.
        let use_sparse = cfg.delta_threshold > 0.0
            && (touched.len() as f64) <= cfg.delta_threshold * d as f64;
        let delta_v = if use_sparse {
            let values: Vec<f64> = touched
                .iter()
                .map(|&j| (solver.v.load(j as usize) - v_prev[j as usize]) * inv_sigma)
                .collect();
            DeltaV::Sparse { dim: d, indices: touched, values }
        } else {
            let mut dense = vec![0.0f64; d];
            for &j in &touched {
                let j = j as usize;
                dense[j] = (solver.v.load(j) - v_prev[j]) * inv_sigma;
            }
            DeltaV::Dense(dense)
        };

        let send_cost = cfg.send_cost.cost(delta_v.wire_elems());
        let msg = WorkerMsg {
            worker: cfg.worker_id,
            local_round: local_rounds,
            delta_v,
            dual_sum,
            arrival_vtime: vtime + send_cost,
            updates: stats.updates,
        };
        // Held until a reply acknowledges it: Nack-triggered and
        // rejoin-triggered retransmits resend this exact frame.
        let update = Frame::Update(msg);
        if let Err(e) = link.send(MASTER, update.clone()) {
            let recovered = matches!(e, TransportError::PeerGone { .. })
                && matches!(
                    link.reconnect(&rejoin_info(cfg, &solver, last_global_round)),
                    Ok(true)
                )
                && link.send(MASTER, update.clone()).is_ok();
            if !recovered {
                anyhow::bail!("sending round {local_rounds} update: {e}");
            }
        }

        // Wait for the merged v (line 11) or the shutdown broadcast.
        let mut done = false;
        loop {
            match link.recv() {
                Ok((_, Frame::Merged(reply))) => {
                    if reply.global_round <= last_global_round {
                        // Stop-and-wait duplicate (a stale retransmit
                        // of a reply we already committed) — skip it.
                        continue;
                    }
                    last_global_round = reply.global_round;
                    vtime = reply.arrival_vtime.max(vtime);
                    solver.v.copy_from(&reply.v);
                    v_prev.copy_from_slice(&reply.v);
                    local_rounds += 1;
                    break;
                }
                Ok((_, Frame::Shutdown { vtime: stop_vtime, .. })) => {
                    vtime = vtime.max(stop_vtime);
                    local_rounds += 1;
                    done = true;
                    break;
                }
                Ok((_, Frame::Nack { .. })) => {
                    // "Resend your last frame": our update never made
                    // it intact. A send failure here surfaces on the
                    // next recv as a connection error, which the arms
                    // below recover or report.
                    let _ = link.send(MASTER, update.clone());
                }
                Ok((_, frame)) => {
                    anyhow::bail!(
                        "unexpected {} frame from the master in round {local_rounds}",
                        frame.kind_name()
                    );
                }
                Err(TransportError::PeerSilent { .. }) => {
                    // The master is quiet past the read timeout while
                    // the link is up. Probe it: if our update was lost
                    // the Nack triggers the retransmit pair; if the
                    // reply was lost we get it resent; if the barrier
                    // is just slow, the probes deduplicate to nothing.
                    let _ = link.send(MASTER, Frame::Nack { round: last_global_round });
                }
                Err(e @ TransportError::PeerGone { .. }) => {
                    // Dead connection. Try the backoff + Rejoin path,
                    // then retransmit the unacknowledged update; give
                    // up with the original error when the transport
                    // can't reconnect (in-process, exhausted retries,
                    // or killed by the chaos plan).
                    if matches!(
                        link.reconnect(&rejoin_info(cfg, &solver, last_global_round)),
                        Ok(true)
                    ) {
                        let _ = link.send(MASTER, update.clone());
                        continue;
                    }
                    return Err(anyhow::Error::new(e)
                        .context(format!("waiting for the merged v in round {local_rounds}")));
                }
                Err(e) => {
                    return Err(anyhow::Error::new(e)
                        .context(format!("waiting for the merged v in round {local_rounds}")));
                }
            }
        }
        if done {
            break;
        }
    }

    // Collect committed α for the final report, under global row ids.
    let mut alpha = Vec::with_capacity(solver.n_local());
    for shard in &solver.shards {
        for (j, &i) in shard.idx.iter().enumerate() {
            alpha.push((cfg.row_base + i, shard.alpha_start[j]));
        }
    }
    let fin = WorkerFinal {
        worker_id: cfg.worker_id,
        alpha,
        local_rounds,
        updates: total_updates,
        vtime,
    };
    let report = Frame::Final(fin.clone());
    if let Err(e) = link.send(MASTER, report.clone()) {
        let recovered = matches!(e, TransportError::PeerGone { .. })
            && matches!(
                link.reconnect(&rejoin_info(cfg, &solver, last_global_round)),
                Ok(true)
            )
            && link.send(MASTER, report).is_ok();
        if !recovered {
            anyhow::bail!("reporting final state: {e}");
        }
    }
    Ok(fin)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::messages::MasterReply;
    use crate::data::synth::Preset;
    use crate::loss::Hinge;
    use crate::sim::CostModel;
    use crate::transport::in_process;

    /// A single worker against a scripted "master" that echoes the
    /// worker's own updates back (K = 1 semantics) and shuts down after
    /// 3 rounds.
    #[test]
    fn worker_round_trip_and_terminate() {
        let ds = Preset::Tiny.generate(&mut Rng::new(1));
        let norms = ds.x.row_norms_sq();
        let costs = UpdateCosts::precompute(&ds, &CostModel::default());
        let cells = {
            let mut rng = Rng::new(2);
            crate::data::Partition::build(ds.n(), 1, 2, crate::data::Strategy::Contiguous, &mut rng)
                .parts[0]
                .clone()
        };
        let (mut ml, mut wls) = in_process(1);
        let mut wl = wls.pop().unwrap();
        let cfg = WorkerCfg {
            worker_id: 0,
            h_local: 100,
            nu: 1.0,
            sigma: 1.0,
            lambda: 1e-2,
            wild: false,
            straggler: 1.0,
            send_cost: SendCost::Fixed(1e-3),
            delta_threshold: 0.5,
            n_global: ds.n(),
            row_base: 0,
        };
        let master = std::thread::spawn(move || {
            let mut v = Vec::new();
            let mut vt = 0.0;
            for round in 0..3 {
                let (from, frame) = ml.recv().unwrap();
                assert_eq!(from, 0);
                let msg = match frame {
                    Frame::Update(m) => m,
                    other => panic!("expected Update, got {}", other.kind_name()),
                };
                assert_eq!(msg.worker, 0);
                assert_eq!(msg.local_round, round);
                assert_eq!(msg.updates, 200); // R=2 × H=100
                assert!(msg.arrival_vtime > vt);
                vt = msg.arrival_vtime;
                if v.is_empty() {
                    v = vec![0.0; msg.delta_v.dim()];
                }
                msg.delta_v.add_scaled_into(&mut v, 1.0);
                ml.send(
                    0,
                    Frame::Merged(MasterReply {
                        v: v.clone(),
                        arrival_vtime: vt + 1e-3,
                        global_round: round + 1,
                        terminate: false,
                    }),
                )
                .unwrap();
            }
            let (_, frame) = ml.recv().unwrap();
            let vt = match frame {
                Frame::Update(m) => m.arrival_vtime,
                other => panic!("expected Update, got {}", other.kind_name()),
            };
            ml.send(0, Frame::Shutdown { vtime: vt, round: 4 }).unwrap();
            // The worker reports its final state before exiting.
            let (_, frame) = ml.recv().unwrap();
            assert!(matches!(frame, Frame::Final(_)));
        });
        let ds_ref = &ds;
        let fin =
            run_worker(&cfg, cells, ds_ref, &Hinge, &norms, &costs, &mut wl, Rng::new(3)).unwrap();
        master.join().unwrap();
        assert_eq!(fin.local_rounds, 4);
        assert_eq!(fin.updates, 4 * 200);
        assert_eq!(fin.alpha.len(), ds.n());
        assert!(fin.vtime > 0.0);
        // Dual made progress: some α moved.
        assert!(fin.alpha.iter().any(|&(_, a)| a != 0.0));
    }

    /// `delta_threshold = 1` forces the sparse wire format; the values
    /// must equal the dense reconstruction of the same round.
    #[test]
    fn forced_sparse_delta_carries_the_round() {
        let ds = Preset::Tiny.generate(&mut Rng::new(5));
        let norms = ds.x.row_norms_sq();
        let costs = UpdateCosts::precompute(&ds, &CostModel::default());
        let cells = {
            let mut rng = Rng::new(6);
            crate::data::Partition::build(ds.n(), 1, 1, crate::data::Strategy::Contiguous, &mut rng)
                .parts[0]
                .clone()
        };
        let (mut ml, mut wls) = in_process(1);
        let mut wl = wls.pop().unwrap();
        let cfg = WorkerCfg {
            worker_id: 0,
            h_local: 40,
            nu: 1.0,
            sigma: 1.0,
            lambda: 1e-2,
            wild: false,
            straggler: 1.0,
            send_cost: SendCost::Sized(CostModel::default()),
            delta_threshold: 1.0, // always sparse
            n_global: ds.n(),
            row_base: 0,
        };
        let master = std::thread::spawn(move || {
            let (_, frame) = ml.recv().unwrap();
            let msg = match frame {
                Frame::Update(m) => m,
                other => panic!("expected Update, got {}", other.kind_name()),
            };
            assert!(msg.delta_v.is_sparse());
            assert!(msg.delta_v.nnz() > 0);
            assert!(msg.delta_v.nnz() <= msg.delta_v.dim());
            // Sparse values reconstruct v exactly (first round: v_old=0,
            // ν=1 ⇒ Δv = live v).
            let dense = msg.delta_v.to_dense();
            ml.send(0, Frame::Shutdown { vtime: msg.arrival_vtime, round: 1 }).unwrap();
            let (_, frame) = ml.recv().unwrap();
            assert!(matches!(frame, Frame::Final(_)));
            dense
        });
        let fin =
            run_worker(&cfg, cells, &ds, &Hinge, &norms, &costs, &mut wl, Rng::new(7)).unwrap();
        let dense = master.join().unwrap();
        // Rebuild v from the committed α and compare.
        let mut alpha = vec![0.0; ds.n()];
        for (i, a) in &fin.alpha {
            alpha[*i] = *a;
        }
        let v_exact = crate::metrics::exact_v(&ds, &alpha, 1e-2);
        for (j, (a, b)) in dense.iter().zip(&v_exact).enumerate() {
            assert!((a - b).abs() < 1e-9, "Δv[{j}]: {a} vs {b}");
        }
    }

    /// The graceful-shutdown satellite's in-process half: a worker
    /// whose master vanishes mid-round errors out with "master
    /// disconnected" instead of hanging or silently succeeding.
    #[test]
    fn vanished_master_is_an_error() {
        let ds = Preset::Tiny.generate(&mut Rng::new(9));
        let norms = ds.x.row_norms_sq();
        let costs = UpdateCosts::precompute(&ds, &CostModel::default());
        let cells = {
            let mut rng = Rng::new(10);
            crate::data::Partition::build(ds.n(), 1, 1, crate::data::Strategy::Contiguous, &mut rng)
                .parts[0]
                .clone()
        };
        let (ml, mut wls) = in_process(1);
        let mut wl = wls.pop().unwrap();
        drop(ml);
        let cfg = WorkerCfg {
            worker_id: 0,
            h_local: 10,
            nu: 1.0,
            sigma: 1.0,
            lambda: 1e-2,
            wild: false,
            straggler: 1.0,
            send_cost: SendCost::Fixed(0.0),
            delta_threshold: 0.5,
            n_global: ds.n(),
            row_base: 0,
        };
        let err = run_worker(&cfg, cells, &ds, &Hinge, &norms, &costs, &mut wl, Rng::new(11))
            .unwrap_err();
        assert!(format!("{err:#}").contains("master disconnected"), "{err:#}");
    }
}
