//! PassCoDe (Hsieh et al. 2015) — the single-node shared-memory
//! baseline: `R` asynchronous cores with lock-free atomic updates to a
//! shared `v`, no inter-node communication at all (the `K = 1` corner
//! of the paper's Fig. 1b).
//!
//! Unlike the hybrid worker, PassCoDe solves the *true* dual (σ = 1,
//! ν = 1, no perturbation) continuously; "rounds" are purely
//! measurement epochs of `R·H` updates.

use crate::config::ExpConfig;
use crate::data::{Dataset, Partition};
use crate::metrics::{Evaluator, Trace, TracePoint};
use crate::session::observer::{EvalEvent, RoundEvent};
use crate::session::RunCtx;
use crate::sim::{CostModel, UpdateCosts};
use crate::solver::local::{LocalSolver, DUAL_RESYNC_EVERY};
use crate::solver::StepParams;
use crate::util::{norm_sq, Rng, Stopwatch};

use super::RunReport;

/// Run PassCoDe with `cfg.r_cores` cores on the whole dataset.
pub fn run(data: &Dataset, cfg: &ExpConfig) -> anyhow::Result<RunReport> {
    run_ctx(data, &RunCtx::silent(cfg))
}

/// Engine entry point: run with the context's config and observer.
pub fn run_ctx(data: &Dataset, ctx: &RunCtx<'_>) -> anyhow::Result<RunReport> {
    let cfg = ctx.cfg;
    cfg.validate()?;
    let obs_guard = crate::obs::begin(&cfg.obs);
    let rec = crate::obs::global();
    let loss = cfg.loss.build();
    let mut rng = Rng::new(cfg.seed);
    let partition = Partition::build(data.n(), 1, cfg.r_cores, cfg.partition, &mut rng);
    partition.validate(data.n()).expect("partition invariant");

    let params = StepParams { lambda: cfg.lambda, n: data.n(), sigma: 1.0 };
    let mut solver =
        LocalSolver::new(partition.parts[0].clone(), data.d(), params, cfg.wild, &mut rng);
    // α is core-disjoint even in wild mode, so the tracked dual sums
    // are exact w.r.t. the committed α; only `v` is racy.
    solver.enable_dual_tracking(data, &*loss);
    let norms = data.x.row_norms_sq();
    let cost_model = CostModel::new(cfg.cost_per_nnz, cfg.net_latency, cfg.net_per_elem);
    let costs = UpdateCosts::precompute(data, &cost_model);

    let label = if cfg.wild { "PassCoDe-Wild" } else { "PassCoDe" };
    let mut trace = Trace::new(label);
    let sw = Stopwatch::start();
    let mut vtime = 0.0;
    let mut total_updates = 0u64;
    let mut alpha = vec![0.0; data.n()];
    let n = data.n() as f64;
    // Eval scratch hoisted out of the round loop: the evaluator's chunk
    // partials and the v snapshot buffer are reused every `on_eval`
    // instead of reallocated.
    let mut eval = Evaluator::in_memory(data);
    let mut v_buf = vec![0.0f64; data.d()];

    let o0 = eval.objectives_at_zero(&*loss, &v_buf, cfg.lambda);
    let p0 = TracePoint {
        round: 0,
        wall_secs: 0.0,
        virt_secs: 0.0,
        gap: o0.gap,
        primal: o0.primal,
        dual: o0.dual,
        updates: 0,
    };
    trace.push(p0.clone());
    let initial_stop = ctx.observer.on_eval(&EvalEvent { point: p0 }).is_break();

    let mut rounds = 0;
    let mut commits = 0usize;
    for t in 1..=cfg.max_rounds {
        if initial_stop {
            break;
        }
        let stats = solver.run_round(data, &*loss, &norms, &costs, cfg.h_local);
        rec.master_round(stats.updates);
        solver.commit(1.0); // ν = 1: α_cur is the truth
        commits += 1;
        // ν = 1 keeps the tracked dual exact; the periodic rescan only
        // cancels incremental rounding drift.
        if commits % DUAL_RESYNC_EVERY == 0 {
            solver.resync_dual(data, &*loss);
        }
        total_updates += stats.updates;
        vtime += stats.node_secs();
        rounds = t;
        let mut stop = ctx
            .observer
            .on_round(&RoundEvent { round: t, vtime, updates: total_updates })
            .is_break();
        if t % cfg.eval_every == 0 || t == cfg.max_rounds || stop {
            let eval_t0 = rec.timer();
            solver.v.snapshot_into(&mut v_buf);
            // One primal pass; the dual rides on the tracked sums.
            let primal = eval.primal(&*loss, &v_buf, cfg.lambda);
            let dual = solver.dual_sum() / n - 0.5 * cfg.lambda * norm_sq(&v_buf);
            let gap = primal - dual;
            rec.eval(t, eval_t0);
            let point = TracePoint {
                round: t,
                wall_secs: sw.elapsed_secs(),
                virt_secs: vtime,
                gap,
                primal,
                dual,
                updates: total_updates,
            };
            trace.push(point.clone());
            if ctx.observer.on_eval(&EvalEvent { point }).is_break() {
                stop = true;
            }
            if gap <= cfg.gap_threshold {
                stop = true;
            }
        }
        if stop {
            break;
        }
    }

    solver.scatter_alpha(&mut alpha);
    let v = solver.v.snapshot();
    Ok(RunReport {
        label: label.into(),
        trace,
        events: Vec::new(),
        alpha,
        v,
        rounds,
        vtime,
        total_updates,
        worker_rounds: vec![rounds],
        net: Default::default(),
        faults: Default::default(),
        obs: obs_guard.and_then(|g| g.finish()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::Preset;

    fn cfg(r: usize) -> ExpConfig {
        let mut cfg = ExpConfig::default();
        cfg.lambda = 1e-2;
        cfg.k_nodes = 1;
        cfg.s_barrier = 1;
        cfg.r_cores = r;
        cfg.h_local = 200;
        cfg.max_rounds = 60;
        cfg.gap_threshold = 1e-4;
        cfg
    }

    #[test]
    fn passcode_converges_multi_core() {
        let data = Preset::Tiny.generate(&mut Rng::new(1));
        let report = run(&data, &cfg(4)).unwrap();
        assert!(report.trace.final_gap().unwrap() <= 1e-4, "{:?}", report.trace.final_gap());
    }

    #[test]
    fn passcode_single_core_equals_sdca_family() {
        // R = 1 PassCoDe is sequential SDCA over a restricted sampling
        // order; it must converge to the same optimum (gap → 0) even if
        // trajectories differ.
        let data = Preset::Tiny.generate(&mut Rng::new(2));
        let report = run(&data, &cfg(1)).unwrap();
        assert!(report.trace.final_gap().unwrap() <= 1e-4);
    }

    #[test]
    fn wild_variant_labels_and_runs() {
        let data = Preset::Tiny.generate(&mut Rng::new(3));
        let mut c = cfg(4);
        c.wild = true;
        c.max_rounds = 20;
        c.gap_threshold = 1e-9;
        let report = run(&data, &c).unwrap();
        assert_eq!(report.label, "PassCoDe-Wild");
        assert!(report.trace.final_gap().unwrap() < 1.0);
    }

    #[test]
    fn virtual_time_uses_max_core_parallelism() {
        // With R cores the virtual time per round is ~1/R of the serial
        // cost (max of per-core sums, each ~H·cost).
        let data = Preset::Tiny.generate(&mut Rng::new(4));
        let mut c1 = cfg(1);
        c1.max_rounds = 4;
        c1.gap_threshold = 1e-12;
        let mut c4 = cfg(4);
        c4.max_rounds = 4;
        c4.gap_threshold = 1e-12;
        let r1 = run(&data, &c1).unwrap();
        let r4 = run(&data, &c4).unwrap();
        // Same rounds, same H ⇒ r4 does 4× the updates but in similar
        // virtual time per round; per-update virtual throughput ≥ 2×.
        let thr1 = r1.total_updates as f64 / r1.vtime;
        let thr4 = r4.total_updates as f64 / r4.vtime;
        assert!(thr4 > 2.0 * thr1, "throughput {thr4} vs {thr1}");
    }
}
