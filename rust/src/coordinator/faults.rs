//! Fault accounting for the degradation-tolerant master.
//!
//! The master's gather loop ([`super::master`]) survives silent,
//! severed, and killed workers: silence accumulates suspicion strikes,
//! a struck-out worker is declared dead and the effective cluster
//! shrinks (`K_live`), and a worker that dials back in with a `Rejoin`
//! frame is readmitted. Everything it does on that path is recorded
//! here — per-peer counters plus an ordered event log — and lands in
//! [`RunReport::faults`](super::RunReport), so a degraded run *says*
//! it degraded instead of silently certifying a smaller cluster.
//!
//! Fault-free runs leave the log empty (`FaultLog::default()`), which
//! keeps the bitwise in-process ≡ distributed parity checks meaningful:
//! the `--dump` state excludes this section exactly as it excludes the
//! wire-traffic counters.

/// One notable liveness decision, in the order the master took them.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultEvent {
    /// Virtual time when the master logged the event.
    pub vtime: f64,
    /// Global merge round the master was gathering at the time.
    pub round: usize,
    /// The worker concerned.
    pub peer: usize,
    /// Human-readable description ("declared dead after 4 strikes",
    /// "rejoined with last_acked_round=7", ...).
    pub what: String,
}

/// Per-worker fault counters.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PeerFaults {
    /// Suspicion strikes: read timeouts / silent ticks charged to this
    /// worker (resets on any frame from it, so this counts the total
    /// charged over the run, not the final streak).
    pub stalls: u64,
    /// Duplicate updates deduplicated and replies resent (stop-and-wait
    /// retransmissions in either direction).
    pub retransmits: u64,
    /// Successful `Rejoin` handshakes after a severed connection.
    pub rejoins: u64,
    /// Times this worker was declared dead (can exceed 1 if it
    /// rejoined and died again).
    pub declared_dead: u64,
    /// Last global round whose merged reply this worker acknowledged —
    /// by sending its next update or its `Rejoin` frame. Diagnostic
    /// context for "how far behind was it when it went silent".
    pub last_acked_round: usize,
}

/// The run's complete fault record: per-peer counters, the ordered
/// event log, and the surviving cluster size.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultLog {
    pub per_peer: Vec<PeerFaults>,
    pub events: Vec<FaultEvent>,
    /// Workers still considered live when the run finished. Equals the
    /// configured `K` unless someone was declared dead and never came
    /// back; the bounded barrier keeps running as long as
    /// `S ≤ k_live`.
    pub k_live: usize,
}

impl Default for FaultLog {
    fn default() -> Self {
        FaultLog { per_peer: Vec::new(), events: Vec::new(), k_live: 0 }
    }
}

impl FaultLog {
    /// An empty log sized for `k` workers, all presumed live.
    pub fn new(k: usize) -> Self {
        FaultLog { per_peer: vec![PeerFaults::default(); k], events: Vec::new(), k_live: k }
    }

    /// True iff nothing fault-related happened: no strikes, no
    /// retransmissions, no deaths, no rejoins.
    pub fn is_clean(&self) -> bool {
        self.events.is_empty()
            && self.per_peer.iter().all(|p| {
                p.stalls == 0 && p.retransmits == 0 && p.rejoins == 0 && p.declared_dead == 0
            })
    }

    /// Append one event to the ordered log (mirrored into the obs
    /// trace as a `fault_log` instant when tracing is on).
    pub fn log(&mut self, vtime: f64, round: usize, peer: usize, what: impl Into<String>) {
        let what = what.into();
        crate::obs::global().fault_log(vtime, round, peer, &what);
        self.events.push(FaultEvent { vtime, round, peer, what });
    }

    /// Total workers declared dead over the whole run.
    pub fn total_deaths(&self) -> u64 {
        self.per_peer.iter().map(|p| p.declared_dead).sum()
    }

    /// Total successful rejoins over the whole run.
    pub fn total_rejoins(&self) -> u64 {
        self.per_peer.iter().map(|p| p.rejoins).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_until_something_happens() {
        let mut log = FaultLog::new(3);
        assert!(log.is_clean());
        assert_eq!(log.k_live, 3);
        log.per_peer[1].stalls += 1;
        assert!(!log.is_clean());

        let mut log = FaultLog::new(2);
        log.log(1.5, 3, 0, "declared dead after 4 strikes");
        assert!(!log.is_clean());
        assert_eq!(log.events[0].peer, 0);
        assert_eq!(log.events[0].round, 3);
    }

    #[test]
    fn totals_sum_over_peers() {
        let mut log = FaultLog::new(3);
        log.per_peer[0].declared_dead = 1;
        log.per_peer[2].declared_dead = 1;
        log.per_peer[2].rejoins = 2;
        assert_eq!(log.total_deaths(), 2);
        assert_eq!(log.total_rejoins(), 2);
    }
}
