//! End-to-end Hybrid-DCA orchestration: build the partition, spawn the
//! `K` worker threads (each of which spawns `R` core threads per
//! round), run the master in the calling thread, and assemble the
//! final report.

use crate::config::ExpConfig;
use crate::data::{Dataset, Partition};
use crate::loss::Loss;
use crate::metrics::Evaluator;
use crate::session::observer::ObserverHandle;
use crate::session::{DataSource, RunCtx};
use crate::sim::{resolve_stragglers, CostModel, SendCost, UpdateCosts};
use crate::store::ShardedDataset;
use crate::transport::{in_process, ChaosTransport, Frame, Transport};
use crate::util::Rng;

use super::master::{run_master, MasterCfg, MasterOutcome, MergePolicy};
use super::worker::{run_worker, WorkerCfg};
use super::RunReport;

/// Options that differ between Hybrid-DCA and the CoCoA+ wrapper.
#[derive(Debug, Clone)]
pub struct ProtocolOpts {
    /// Label for traces.
    pub label: String,
    /// Use the all-reduce communication cost model (CoCoA+) instead of
    /// point-to-point (Hybrid-DCA).
    pub sync_allreduce: bool,
    /// Merge-order policy (ablation).
    pub policy: MergePolicy,
    /// Shard row spans when the data came from a packed store: the
    /// node partition is built with
    /// [`Partition::from_shards`](crate::data::Partition::from_shards)
    /// (node `k` owns whole shards in disk order, `cfg.partition` is
    /// not consulted and the seed stream is untouched) instead of
    /// [`Partition::build`].
    pub shards: Option<Vec<(usize, usize)>>,
}

impl Default for ProtocolOpts {
    fn default() -> Self {
        Self {
            label: "Hybrid-DCA".into(),
            sync_allreduce: false,
            policy: MergePolicy::OldestFirst,
            shards: None,
        }
    }
}

/// Run Hybrid-DCA with the default protocol options (the merge policy
/// comes from `cfg.merge_policy`).
pub fn run(data: &Dataset, cfg: &ExpConfig) -> anyhow::Result<RunReport> {
    let opts = ProtocolOpts { policy: cfg.merge_policy, ..ProtocolOpts::default() };
    run_with(data, cfg, &opts)
}

/// Engine entry point: run with the context's config, observer, and
/// (for store-backed data) shard spans.
pub fn run_ctx(data: &Dataset, ctx: &RunCtx<'_>) -> anyhow::Result<RunReport> {
    let opts = ProtocolOpts {
        policy: ctx.cfg.merge_policy,
        shards: ctx.shards.clone(),
        ..ProtocolOpts::default()
    };
    run_with_obs(data, ctx.cfg, &opts, &ctx.observer)
}

/// Run the double-asynchronous protocol with explicit options.
pub fn run_with(
    data: &Dataset,
    cfg: &ExpConfig,
    opts: &ProtocolOpts,
) -> anyhow::Result<RunReport> {
    run_with_obs(data, cfg, opts, &ObserverHandle::silent())
}

/// Engine entry point for a [`DataSource`]: in-memory sources run the
/// flat path; sharded sources stream per-node slabs and evaluate over
/// shards, never materializing the whole dataset.
pub fn run_source_ctx(source: &DataSource, ctx: &RunCtx<'_>) -> anyhow::Result<RunReport> {
    let opts = ProtocolOpts {
        policy: ctx.cfg.merge_policy,
        shards: ctx.shards.clone(),
        ..ProtocolOpts::default()
    };
    run_source_with_obs(source, ctx.cfg, &opts, &ctx.observer)
}

/// Run against a [`DataSource`] with explicit options.
pub fn run_source_with_obs(
    source: &DataSource,
    cfg: &ExpConfig,
    opts: &ProtocolOpts,
    obs: &ObserverHandle<'_>,
) -> anyhow::Result<RunReport> {
    match source {
        DataSource::InMemory(data) => run_with_obs(data, cfg, opts, obs),
        DataSource::Sharded(store) => run_streamed_obs(store, cfg, opts, obs),
    }
}

/// Run with explicit options, streaming events to `obs`.
pub fn run_with_obs(
    data: &Dataset,
    cfg: &ExpConfig,
    opts: &ProtocolOpts,
    obs: &ObserverHandle<'_>,
) -> anyhow::Result<RunReport> {
    cfg.validate()?;
    data.validate()?;
    let loss = cfg.loss.build();
    let k = cfg.k_nodes;
    let mut rng = Rng::new(cfg.seed);
    // Store-backed data partitions on shard boundaries (I_k = node k's
    // packed shards, in disk order — no rng consumed, matching what a
    // Contiguous build leaves in the stream); in-memory data is sliced
    // by the configured strategy. Spans come from the caller when the
    // session already opened the store, else from `cfg.store_path`'s
    // manifest — so every entry point (typed session, deprecated shim,
    // harness) partitions a store-backed config identically.
    let spans = match &opts.shards {
        Some(s) => Some(s.clone()),
        None => cfg
            .store_path
            .as_deref()
            .map(|dir| {
                crate::store::Manifest::load(std::path::Path::new(dir)).map(|m| m.spans())
            })
            .transpose()?,
    };
    let partition = match &spans {
        Some(spans) => Partition::from_shards(data.n(), spans, k, cfg.r_cores)?,
        None => Partition::build(data.n(), k, cfg.r_cores, cfg.partition, &mut rng),
    };
    partition.validate(data.n()).expect("partition invariant");

    let cost_model = CostModel::new(cfg.cost_per_nnz, cfg.net_latency, cfg.net_per_elem);
    let costs = UpdateCosts::precompute(data, &cost_model);
    let norms = data.x.row_norms_sq();
    // Every node reads the full dataset through shared tables; final α
    // ids are already global (`row_base` 0).
    let nodes: Vec<NodePlan<'_>> = partition
        .parts
        .iter()
        .cloned()
        .map(|cells| NodePlan { cells, data, norms: &norms, costs: &costs, row_base: 0 })
        .collect();
    let mut eval = Evaluator::in_memory(data);
    drive(cfg, opts, obs, &mut eval, &*loss, nodes, rng, cost_model)
}

/// Run the protocol out of core: node `k` trains on a flat slab of its
/// own shard range (streamed in, one shard resident during assembly)
/// and the master's objective evaluations stream shards through the
/// [`Evaluator`] — the full dataset is never assembled in memory. The
/// per-node tables (`norms`, `costs`) and the per-row arithmetic are
/// identical to the in-memory path, so final α/v and every traced
/// objective are bitwise-identical to a run on the materialized data.
pub fn run_streamed_obs(
    store: &ShardedDataset,
    cfg: &ExpConfig,
    opts: &ProtocolOpts,
    obs: &ObserverHandle<'_>,
) -> anyhow::Result<RunReport> {
    cfg.validate()?;
    let loss = cfg.loss.build();
    let k = cfg.k_nodes;
    // The shard-aware partition never consults the strategy, so the
    // seed stream matches the in-memory store-backed path (no draw).
    let rng = Rng::new(cfg.seed);
    let spans = match &opts.shards {
        Some(s) => s.clone(),
        None => store.spans(),
    };
    let partition = Partition::from_shards(store.n(), &spans, k, cfg.r_cores)?;
    partition.validate(store.n()).expect("partition invariant");

    let cost_model = CostModel::new(cfg.cost_per_nnz, cfg.net_latency, cfg.net_per_elem);

    // Per-node slabs: each node's contiguous shard range, with its own
    // norm/cost tables. Both tables are per-row quantities, so the
    // slab-local values equal the global ones row for row.
    let mut slabs = Vec::with_capacity(k);
    for w in 0..k {
        slabs.push(build_node_slab(store, &partition, w, &cost_model)?);
    }
    let nodes: Vec<NodePlan<'_>> = slabs
        .iter()
        .map(|slab| NodePlan {
            cells: slab.cells.clone(),
            data: &slab.data,
            norms: &slab.norms,
            costs: &slab.costs,
            row_base: slab.base,
        })
        .collect();
    let mut eval = Evaluator::sharded(store);
    drive(cfg, opts, obs, &mut eval, &*loss, nodes, rng, cost_model)
}

/// One node's streamed training slab: its contiguous shard range
/// materialized as a flat dataset, per-row tables, and slab-local
/// cells. Shared by the in-process streamed path and the distributed
/// worker process — a `--distributed` worker materializes exactly this
/// (its own shard range and nothing else), which is what keeps the two
/// paths bitwise-identical.
pub(crate) struct NodeSlab {
    pub data: Dataset,
    pub norms: Vec<f64>,
    pub costs: UpdateCosts,
    /// Global row id of the slab's first row.
    pub base: usize,
    /// Per-core cells in slab-local row ids.
    pub cells: Vec<Vec<usize>>,
}

/// Build node `w`'s [`NodeSlab`] from a shard store (cells carry
/// global row ids in `partition`; the worker indexes its slab).
pub(crate) fn build_node_slab(
    store: &ShardedDataset,
    partition: &Partition,
    w: usize,
    cost_model: &CostModel,
) -> anyhow::Result<NodeSlab> {
    let rows = partition.node_indices(w);
    let (lo, hi) = (rows[0], rows[rows.len() - 1] + 1);
    let data = store.materialize_range(lo, hi)?;
    data.validate()?;
    let norms = data.x.row_norms_sq();
    let costs = UpdateCosts::precompute(&data, cost_model);
    let cells = partition.parts[w]
        .iter()
        .map(|cell| cell.iter().map(|&i| i - lo).collect())
        .collect();
    Ok(NodeSlab { data, norms, costs, base: lo, cells })
}

/// One worker node's view of the data for a run: the rows it trains on
/// (`data` — the full dataset or a streamed slab of it), its per-core
/// cells in `data`-local row ids, and the per-row tables the local
/// solver reads.
struct NodePlan<'a> {
    cells: Vec<Vec<usize>>,
    data: &'a Dataset,
    norms: &'a [f64],
    costs: &'a UpdateCosts,
    row_base: usize,
}

/// Virtual communication model: point-to-point for Hybrid (billed by
/// the actual wire size, so sparse Δv messages are cheaper), tree
/// all-reduce for CoCoA+ (§5: 2S vs 2K transmissions; tree depth for
/// the sync collective; the collective always moves dense vectors).
/// Returns `(send_cost, merge_cost, reply_latency)`.
pub(crate) fn comm_profile(
    cost_model: &CostModel,
    allreduce: bool,
    k: usize,
    d: usize,
) -> (SendCost, f64, f64) {
    if allreduce {
        let ar = cost_model.allreduce_cost(k, d);
        (SendCost::Fixed(ar / 2.0), 0.0, ar / 2.0)
    } else {
        (SendCost::Sized(*cost_model), 0.0, cost_model.msg_cost(d))
    }
}

/// Master configuration derived from the experiment config alone —
/// shared by [`drive`] and the distributed master so both build the
/// same protocol constants.
pub(crate) fn plan_master_cfg(
    cfg: &ExpConfig,
    k: usize,
    d: usize,
    policy: MergePolicy,
    allreduce: bool,
) -> MasterCfg {
    let cost_model = CostModel::new(cfg.cost_per_nnz, cfg.net_latency, cfg.net_per_elem);
    let (_, merge_cost, reply_latency) = comm_profile(&cost_model, allreduce, k, d);
    MasterCfg {
        k_nodes: k,
        s_barrier: cfg.s_barrier,
        gamma: cfg.gamma,
        nu: cfg.nu,
        lambda: cfg.lambda,
        max_rounds: cfg.max_rounds,
        gap_threshold: cfg.gap_threshold,
        eval_every: cfg.eval_every,
        policy,
        merge_cost,
        reply_latency,
        // Fault tolerance: the liveness tick mirrors the transport's
        // read timeout (0 = the pre-fault-tolerance blocking gather).
        tick_secs: cfg.transport.read_timeout_secs,
        suspicion_timeouts: cfg.transport.suspicion_timeouts,
    }
}

/// Worker `w`'s configuration derived from the experiment config alone
/// — shared by [`drive`] and the distributed worker process, so a
/// socket worker reproduces its in-process twin's behavior exactly.
pub(crate) fn plan_worker_cfg(
    cfg: &ExpConfig,
    w: usize,
    k: usize,
    d: usize,
    n_global: usize,
    row_base: usize,
    allreduce: bool,
) -> WorkerCfg {
    let cost_model = CostModel::new(cfg.cost_per_nnz, cfg.net_latency, cfg.net_per_elem);
    let (send_cost, _, _) = comm_profile(&cost_model, allreduce, k, d);
    let stragglers = resolve_stragglers(&cfg.stragglers, k);
    WorkerCfg {
        worker_id: w,
        h_local: cfg.h_local,
        nu: cfg.nu,
        sigma: cfg.sigma_value(),
        lambda: cfg.lambda,
        wild: cfg.wild,
        straggler: stragglers[w],
        send_cost,
        delta_threshold: cfg.delta_threshold,
        n_global,
        row_base,
    }
}

/// The protocol core shared by the in-memory and streamed paths: spawn
/// one worker thread per [`NodePlan`], run the master (Algorithm 2) in
/// the calling thread against `eval` over the in-process transport,
/// and assemble the report. `rng` must be positioned after any
/// partition draws so worker forks match across paths (the distributed
/// master forks the same streams in the same order).
#[allow(clippy::too_many_arguments)]
fn drive(
    cfg: &ExpConfig,
    opts: &ProtocolOpts,
    obs: &ObserverHandle<'_>,
    eval: &mut Evaluator<'_>,
    loss: &dyn Loss,
    nodes: Vec<NodePlan<'_>>,
    mut rng: Rng,
    _cost_model: CostModel,
) -> anyhow::Result<RunReport> {
    let k = nodes.len();
    let n = eval.n();
    let d = eval.d();

    let master_cfg = plan_master_cfg(cfg, k, d, opts.policy, opts.sync_allreduce);
    let chaos = cfg.chaos()?;
    // Observability scope: opened before the worker threads spawn so
    // their round records land in this run's registry. `None` (the
    // default) costs nothing anywhere below.
    let obs_guard = crate::obs::begin(&cfg.obs);
    let (master_link, worker_links) = in_process(k);
    // Chaos decorates both ends only when the plan is non-empty, so
    // fault-free runs pay nothing and stay bitwise-identical.
    let mut master_link: Box<dyn Transport> = Box::new(master_link);
    if !chaos.is_empty() {
        master_link = Box::new(ChaosTransport::wrap(master_link, chaos.clone(), None));
    }
    // Frame tracing decorates the master end only (it sees both
    // directions); installed outermost so chaos-injected retransmits
    // show up as the extra frames they are.
    if cfg.obs.enabled && cfg.obs.trace {
        master_link = crate::transport::ObsTransport::wrap(master_link);
    }
    let worker_links: Vec<Box<dyn Transport>> = worker_links
        .into_iter()
        .enumerate()
        .map(|(w, l)| {
            let boxed: Box<dyn Transport> = Box::new(l);
            if chaos.is_empty() {
                boxed
            } else {
                Box::new(ChaosTransport::wrap(boxed, chaos.clone(), Some(w)))
            }
        })
        .collect();

    // Fork one RNG stream per worker up front (deterministic).
    let worker_rngs: Vec<Rng> = (0..k).map(|_| rng.fork()).collect();

    let mut outcome = None;
    let mut worker_results = Vec::with_capacity(k);

    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(k);
        let mut links = worker_links;
        for (w, (plan, wrng)) in nodes.into_iter().zip(worker_rngs.into_iter()).enumerate() {
            let wcfg = plan_worker_cfg(cfg, w, k, d, n, plan.row_base, opts.sync_allreduce);
            let mut link = links.remove(0);
            handles.push(scope.spawn(move || {
                run_worker(
                    &wcfg, plan.cells, plan.data, loss, plan.norms, plan.costs, &mut *link, wrng,
                )
            }));
        }

        outcome = Some(run_master(&master_cfg, &mut *master_link, eval, loss, &opts.label, obs));

        // Release any declared-dead straggler still parked in its recv:
        // an in-process worker the master gave up on can wake from a
        // stall after the shutdown drain already ended, and nothing
        // else would ever unblock it (the join below would hang).
        if let Some(Ok(oc)) = &outcome {
            for (w, p) in oc.faults.per_peer.iter().enumerate() {
                if p.declared_dead > 0 {
                    let _ = master_link
                        .send(w, Frame::Shutdown { vtime: oc.vtime, round: oc.rounds });
                }
            }
        }

        for h in handles {
            worker_results.push(h.join().expect("worker thread panicked"));
        }
    });

    let outcome = outcome.expect("master ran")?;
    let MasterOutcome { v, trace, events, rounds, vtime, finals, faults } = outcome;
    for (w, r) in worker_results.into_iter().enumerate() {
        if let Err(e) = r {
            // A declared-dead worker erroring out (killed link, master
            // unreachable) is the expected other half of the master's
            // graceful degradation; any live worker's error is real.
            let dead = faults.per_peer.get(w).is_some_and(|p| p.declared_dead > 0);
            if !dead {
                return Err(e);
            }
        }
    }
    // Assemble the final global α from the workers' committed values
    // (workers report global row ids via their `row_base`) — taken
    // from the master's collected Final frames, exactly as the
    // distributed master assembles them.
    let mut alpha = vec![0.0; n];
    let mut total_updates = 0u64;
    let mut worker_rounds = Vec::with_capacity(k);
    for (w, fin) in finals.into_iter().enumerate() {
        let Some(fin) = fin else {
            let dead = faults.per_peer.get(w).is_some_and(|p| p.declared_dead > 0);
            anyhow::ensure!(dead, "worker {w} exited without reporting final state");
            // Declared dead without a final report: its α rows stay 0.
            // The certificate gap recomputes v exactly from this α, so
            // the result is still certified — just looser.
            worker_rounds.push(0);
            continue;
        };
        for (i, a) in &fin.alpha {
            alpha[*i] = *a;
        }
        total_updates += fin.updates;
        worker_rounds.push(fin.local_rounds);
    }

    // The metrics snapshot mirrors the same final per-peer stats that
    // fill `RunReport.net` — CI asserts the two agree byte for byte.
    let net = master_link.stats();
    let rec = crate::obs::global();
    rec.set_net(&net);
    rec.gauge_set(crate::obs::Gauge::KLive, faults.k_live as u64);
    let obs_snapshot = obs_guard.and_then(|g| g.finish());

    Ok(RunReport {
        label: opts.label.clone(),
        trace,
        events,
        alpha,
        v,
        rounds,
        vtime,
        total_updates,
        worker_rounds,
        net,
        faults,
        obs: obs_snapshot,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::Preset;

    fn base_cfg() -> ExpConfig {
        let mut cfg = ExpConfig::default();
        cfg.dataset = "tiny".into();
        cfg.lambda = 1e-2;
        cfg.k_nodes = 3;
        cfg.r_cores = 2;
        cfg.s_barrier = 2;
        cfg.gamma = 3;
        cfg.h_local = 200;
        cfg.max_rounds = 60;
        cfg.gap_threshold = 1e-4;
        cfg
    }

    #[test]
    fn hybrid_converges_on_tiny() {
        let data = Preset::Tiny.generate(&mut Rng::new(1));
        let cfg = base_cfg();
        let report = run(&data, &cfg).unwrap();
        let gap = report.trace.final_gap().unwrap();
        assert!(gap <= 1e-4, "gap {gap} after {} rounds", report.rounds);
        assert!(report.total_updates > 0);
        assert_eq!(report.worker_rounds.len(), 3);
    }

    #[test]
    fn merge_events_respect_barrier() {
        let data = Preset::Tiny.generate(&mut Rng::new(2));
        let cfg = base_cfg();
        let report = run(&data, &cfg).unwrap();
        for ev in &report.events {
            assert_eq!(ev.merged.len(), 2, "barrier size S");
            let workers: std::collections::HashSet<_> =
                ev.merged.iter().map(|(w, _)| w).collect();
            assert_eq!(workers.len(), 2, "distinct workers per merge");
        }
    }

    #[test]
    fn every_update_merged_exactly_once() {
        let data = Preset::Tiny.generate(&mut Rng::new(3));
        let cfg = base_cfg();
        let report = run(&data, &cfg).unwrap();
        let mut seen = std::collections::HashSet::new();
        for ev in &report.events {
            for &(w, lr) in &ev.merged {
                assert!(seen.insert((w, lr)), "update ({w},{lr}) merged twice");
            }
        }
    }

    #[test]
    fn sync_special_case_s_equals_k() {
        // S = K, Γ = 1 ⇒ synchronous all-reduce (CoCoA+ structure):
        // every merge contains all K workers.
        let data = Preset::Tiny.generate(&mut Rng::new(4));
        let mut cfg = base_cfg();
        cfg.s_barrier = cfg.k_nodes;
        cfg.gamma = 1;
        let report = run(&data, &cfg).unwrap();
        for ev in &report.events {
            assert_eq!(ev.merged.len(), cfg.k_nodes);
        }
    }

    #[test]
    fn final_v_consistent_with_final_alpha_when_nu1_s_eq_k() {
        // With ν=1 and S=K (no update ever dropped or pending at the
        // end), the master's v must equal (1/λn)·X·α_final.
        let data = Preset::Tiny.generate(&mut Rng::new(5));
        let mut cfg = base_cfg();
        cfg.s_barrier = cfg.k_nodes;
        cfg.gamma = 1;
        cfg.max_rounds = 10;
        cfg.gap_threshold = 1e-12; // force max_rounds exit
        let report = run(&data, &cfg).unwrap();
        let v_exact = crate::metrics::exact_v(&data, &report.alpha, cfg.lambda);
        for (a, b) in report.v.iter().zip(&v_exact) {
            assert!((a - b).abs() < 1e-9, "v mismatch {a} vs {b}");
        }
    }

    #[test]
    fn virtual_time_monotone() {
        let data = Preset::Tiny.generate(&mut Rng::new(6));
        let report = run(&data, &base_cfg()).unwrap();
        let mut prev = -1.0;
        for ev in &report.events {
            assert!(ev.vtime >= prev);
            prev = ev.vtime;
        }
        for w in report.trace.points.windows(2) {
            assert!(w[1].virt_secs >= w[0].virt_secs);
        }
    }

    #[test]
    fn straggler_slows_virtual_clock() {
        let data = Preset::Tiny.generate(&mut Rng::new(7));
        let mut cfg = base_cfg();
        cfg.max_rounds = 12;
        cfg.gap_threshold = 1e-12;
        cfg.s_barrier = cfg.k_nodes; // sync: must wait for the straggler
        cfg.gamma = 1;
        let fast = run(&data, &cfg).unwrap();
        cfg.stragglers = vec![1.0, 1.0, 8.0];
        let slow = run(&data, &cfg).unwrap();
        assert!(
            slow.vtime > fast.vtime * 2.0,
            "straggler vtime {} vs {}",
            slow.vtime,
            fast.vtime
        );
    }
}
