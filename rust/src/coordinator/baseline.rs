//! The *Baseline* of Figure 3: sequential (single-core) stochastic DCA
//! (Hsieh et al. 2008), measured in rounds of `H` updates.

use crate::config::ExpConfig;
use crate::data::Dataset;
use crate::metrics::{Evaluator, Trace, TracePoint};
use crate::session::observer::{EvalEvent, RoundEvent};
use crate::session::RunCtx;
use crate::sim::CostModel;
use crate::solver::local::DUAL_RESYNC_EVERY;
use crate::solver::sdca::Sdca;
use crate::util::{norm_sq, Rng, Stopwatch};

use super::RunReport;

/// Run sequential DCA for up to `max_rounds` rounds of `H` updates.
pub fn run(data: &Dataset, cfg: &ExpConfig) -> anyhow::Result<RunReport> {
    run_ctx(data, &RunCtx::silent(cfg))
}

/// Engine entry point: run with the context's config and observer.
pub fn run_ctx(data: &Dataset, ctx: &RunCtx<'_>) -> anyhow::Result<RunReport> {
    let cfg = ctx.cfg;
    cfg.validate()?;
    let obs_guard = crate::obs::begin(&cfg.obs);
    let rec = crate::obs::global();
    let loss = cfg.loss.build();
    let cost_model = CostModel::new(cfg.cost_per_nnz, cfg.net_latency, cfg.net_per_elem);
    let mut solver = Sdca::new(data, cfg.lambda, Rng::new(cfg.seed), &cost_model);
    // The dual rides along with every coordinate step; eval rounds do
    // one primal pass and no O(n) dual rescan.
    solver.enable_dual_tracking(&*loss);
    let mut trace = Trace::new("Baseline");
    let sw = Stopwatch::start();
    let n = data.n() as f64;
    // Eval scratch hoisted out of the round loop (chunk partials are
    // reused every eval instead of reallocated).
    let mut eval = Evaluator::in_memory(data);

    let o0 = solver.objectives_tracked(&*loss);
    let p0 = TracePoint {
        round: 0,
        wall_secs: 0.0,
        virt_secs: 0.0,
        gap: o0.gap,
        primal: o0.primal,
        dual: o0.dual,
        updates: 0,
    };
    trace.push(p0.clone());
    let initial_stop = ctx.observer.on_eval(&EvalEvent { point: p0 }).is_break();

    let mut rounds = 0;
    for t in 1..=cfg.max_rounds {
        if initial_stop {
            break;
        }
        let updates_before = solver.updates;
        solver.run_round(&*loss, cfg.h_local);
        rec.master_round(solver.updates - updates_before);
        // Periodic exact rescan cancels incremental rounding drift.
        if t % DUAL_RESYNC_EVERY == 0 {
            solver.resync_dual(&*loss);
        }
        rounds = t;
        let mut stop = ctx
            .observer
            .on_round(&RoundEvent {
                round: t,
                vtime: solver.virt_secs,
                updates: solver.updates,
            })
            .is_break();
        if t % cfg.eval_every == 0 || t == cfg.max_rounds || stop {
            let eval_t0 = rec.timer();
            let primal = eval.primal(&*loss, &solver.v, cfg.lambda);
            let dual = solver.dual_sum() / n - 0.5 * cfg.lambda * norm_sq(&solver.v);
            let gap = primal - dual;
            rec.eval(t, eval_t0);
            let point = TracePoint {
                round: t,
                wall_secs: sw.elapsed_secs(),
                virt_secs: solver.virt_secs,
                gap,
                primal,
                dual,
                updates: solver.updates,
            };
            trace.push(point.clone());
            if ctx.observer.on_eval(&EvalEvent { point }).is_break() {
                stop = true;
            }
            if gap <= cfg.gap_threshold {
                stop = true;
            }
        }
        if stop {
            break;
        }
    }

    Ok(RunReport {
        label: "Baseline".into(),
        trace,
        events: Vec::new(),
        v: solver.v.clone(),
        vtime: solver.virt_secs,
        total_updates: solver.updates,
        alpha: solver.alpha,
        rounds,
        worker_rounds: vec![rounds],
        net: Default::default(),
        faults: Default::default(),
        obs: obs_guard.and_then(|g| g.finish()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::Preset;

    #[test]
    fn baseline_converges() {
        let data = Preset::Tiny.generate(&mut Rng::new(1));
        let mut cfg = ExpConfig::default();
        cfg.lambda = 1e-2;
        cfg.h_local = 400;
        cfg.max_rounds = 60;
        cfg.gap_threshold = 1e-4;
        let report = run(&data, &cfg).unwrap();
        assert!(report.trace.final_gap().unwrap() <= 1e-4);
        assert!(report.events.is_empty());
    }

    #[test]
    fn baseline_updates_counted_per_round() {
        let data = Preset::Tiny.generate(&mut Rng::new(2));
        let mut cfg = ExpConfig::default();
        cfg.lambda = 1e-2;
        cfg.h_local = 50;
        cfg.max_rounds = 3;
        cfg.gap_threshold = 1e-12;
        let report = run(&data, &cfg).unwrap();
        assert_eq!(report.total_updates, 150);
        assert_eq!(report.rounds, 3);
    }
}
