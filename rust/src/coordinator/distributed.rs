//! Multi-process distributed execution: the socket-backed master and
//! worker node entry points behind `train --distributed` and
//! `hybrid-dca node`.
//!
//! The cluster forms over the [`crate::transport`] socket backend:
//!
//! 1. the master binds `transport.listen` and accepts `K` workers
//!    (accept order assigns peer ids `0..K`);
//! 2. each worker receives an `Assign` frame carrying its worker id,
//!    its pre-forked RNG stream, and the master's full effective
//!    config as JSON — so both ends provably run the same experiment;
//! 3. rounds proceed exactly as in-process: `Update` (Δv) up,
//!    `Merged` (v) down, through the same [`run_master`] /
//!    [`run_worker`] loops;
//! 4. at convergence the master broadcasts `Shutdown` and drains one
//!    `Final` (α) report per worker.
//!
//! Parity by construction: the master forks worker RNG streams in id
//! order from `Rng::new(seed)` and plans master/worker configs through
//! the same `pub(crate)` helpers the in-process driver uses; each
//! worker opens the shard store itself and materializes *only its own
//! shard range* via [`build_node_slab`]. The master's conservative
//! gather orders merges by virtual time, not socket delivery order, so
//! final α, v, and every traced objective are bitwise-identical to the
//! single-process streamed run on the same store and seed.

use anyhow::Context;

use crate::config::{Algorithm, ExpConfig};
use crate::data::Partition;
use crate::metrics::Evaluator;
use crate::session::observer::ObserverHandle;
use crate::sim::CostModel;
use crate::transport::frame::Assignment;
use crate::transport::{
    ChaosTransport, Frame, SocketListener, SocketWorker, Transport, TransportCfg,
    TransportStats,
};
use crate::util::Rng;

use super::cocoa;
use super::hybrid::{build_node_slab, plan_master_cfg, plan_worker_cfg, ProtocolOpts};
use super::master::run_master;
use super::worker::run_worker;
use super::RunReport;

/// What a worker process reports when its run ends cleanly.
#[derive(Debug)]
pub struct WorkerSummary {
    pub worker_id: usize,
    /// Local rounds completed (merged replies + the shutdown round).
    pub local_rounds: usize,
    /// Total coordinate updates across this node's cores.
    pub updates: u64,
    /// Wire traffic to/from the master (including handshake bytes).
    pub net: TransportStats,
    /// The master's address, for the exit report.
    pub master_addr: String,
    /// Observability snapshot, when `[obs]` was enabled (by the
    /// master's config or this node's own `--metrics-out`/`--trace-out`).
    pub obs: Option<crate::obs::ObsSnapshot>,
}

/// Resolve the distributed protocol for `algo`: the effective config
/// (CoCoA+ applies its synchronous overrides) and the protocol
/// options. Single-node algorithms have nothing to distribute.
fn plan_protocol(algo: Algorithm, cfg: &ExpConfig) -> anyhow::Result<(ExpConfig, ProtocolOpts)> {
    match algo {
        Algorithm::HybridDca => Ok((
            cfg.clone(),
            ProtocolOpts { policy: cfg.merge_policy, ..ProtocolOpts::default() },
        )),
        Algorithm::CocoaPlus => Ok((cocoa::sync_overrides(cfg), cocoa::sync_opts(None))),
        Algorithm::Baseline | Algorithm::PassCoDe => anyhow::bail!(
            "{} is a single-node algorithm — nothing to distribute (use plain `train`)",
            algo.name()
        ),
    }
}

/// Run the master role: bind `cfg.transport.listen`, accept the
/// cluster, and drive Algorithm 2 over it.
pub fn run_master_node(
    algo: Algorithm,
    cfg: &ExpConfig,
    obs: &ObserverHandle<'_>,
) -> anyhow::Result<RunReport> {
    let listener = SocketListener::bind(&cfg.transport)?;
    run_master_with_listener(algo, cfg, listener, obs)
}

/// [`run_master_node`] with a pre-bound listener — lets the caller
/// print (or hand to test workers) the actual address when binding
/// port 0.
pub fn run_master_with_listener(
    algo: Algorithm,
    cfg: &ExpConfig,
    listener: SocketListener,
    obs: &ObserverHandle<'_>,
) -> anyhow::Result<RunReport> {
    cfg.validate()?;
    let (cfg, opts) = plan_protocol(algo, cfg)?;
    let obs_guard = crate::obs::begin(&cfg.obs);
    let store_dir = cfg.store_path.as_deref().ok_or_else(|| {
        anyhow::anyhow!(
            "--distributed requires a packed shard store (set --store or data.store): \
             worker processes open their own shard ranges, never a flat dataset"
        )
    })?;
    let store = crate::store::open(store_dir)?;
    let k = cfg.k_nodes;
    let n = store.n();
    let d = store.d();

    // Same seed-stream discipline as the in-process streamed path:
    // the shard-aware partition consumes no draws, workers fork in id
    // order. The partition is built here only to fail fast on a store
    // that cannot support K nodes — workers rebuild it locally.
    let mut rng = Rng::new(cfg.seed);
    let spans = store.spans();
    let partition = Partition::from_shards(n, &spans, k, cfg.r_cores)?;
    partition.validate(n).expect("partition invariant");
    let worker_rngs: Vec<Rng> = (0..k).map(|_| rng.fork()).collect();

    let link = listener.accept_cluster(k)?;
    // The chaos decorator wraps the socket master exactly as it wraps
    // the in-process one (only when a plan is scripted).
    let chaos = cfg.chaos()?;
    let mut link: Box<dyn Transport> = Box::new(link);
    if !chaos.is_empty() {
        link = Box::new(ChaosTransport::wrap(link, chaos, None));
    }
    // Outermost so the timeline sees frames exactly as the master's
    // gather loop does — after any chaos-injected drops or delays.
    if cfg.obs.enabled && cfg.obs.trace {
        link = crate::transport::ObsTransport::wrap(link);
    }

    let config_json = cfg.to_json().to_pretty();
    for (w, wrng) in worker_rngs.iter().enumerate() {
        link.send(
            w,
            Frame::Assign(Assignment {
                worker_id: w,
                k_nodes: k,
                n,
                d,
                rng_state: wrng.state(),
                allreduce: opts.sync_allreduce,
                config_json: config_json.clone(),
            }),
        )
        .map_err(|e| anyhow::anyhow!("assigning worker {w}: {e}"))?;
    }

    let master_cfg = plan_master_cfg(&cfg, k, d, opts.policy, opts.sync_allreduce);
    let mut eval = Evaluator::sharded(&store);
    let loss = cfg.loss.build();
    let outcome = run_master(&master_cfg, &mut *link, &mut eval, &*loss, &opts.label, obs)?;

    let mut alpha = vec![0.0; n];
    let mut total_updates = 0u64;
    let mut worker_rounds = Vec::with_capacity(k);
    for (w, fin) in outcome.finals.into_iter().enumerate() {
        let Some(fin) = fin else {
            // A declared-dead worker owes no final report — its α rows
            // stay 0 and the certificate recomputes v exactly from the
            // assembled α, so the degraded result is still certified.
            let dead = outcome.faults.per_peer.get(w).is_some_and(|p| p.declared_dead > 0);
            anyhow::ensure!(dead, "worker {w} exited without reporting final state");
            worker_rounds.push(0);
            continue;
        };
        for (i, a) in &fin.alpha {
            alpha[*i] = *a;
        }
        total_updates += fin.updates;
        worker_rounds.push(fin.local_rounds);
    }

    // Mirror the same stats object into the metrics snapshot that the
    // report carries, so `RunReport.net` and the exported per-peer byte
    // counters agree by construction.
    let net = link.stats();
    let rec = crate::obs::global();
    rec.set_net(&net);
    rec.gauge_set(crate::obs::Gauge::KLive, outcome.faults.k_live as u64);

    Ok(RunReport {
        label: opts.label.clone(),
        trace: outcome.trace,
        events: outcome.events,
        alpha,
        v: outcome.v,
        rounds: outcome.rounds,
        vtime: outcome.vtime,
        total_updates,
        worker_rounds,
        net,
        faults: outcome.faults,
        obs: obs_guard.and_then(|g| g.finish()),
    })
}

/// Run the worker role: connect to `transport.join`, take the master's
/// assignment, open **only this node's shard range** of the store, and
/// run Algorithm 1 until the shutdown broadcast.
///
/// `store_override` replaces the store directory from the master's
/// config — for clusters whose nodes mount the store at different
/// paths. `obs_override` ORs into the obs config that rides in on the
/// master's `Assign` frame, so one node can record its own timeline
/// (`node --trace-out`) even when the master runs dark.
pub fn run_worker_node(
    transport: &TransportCfg,
    store_override: Option<&str>,
    obs_override: crate::obs::ObsCfg,
) -> anyhow::Result<WorkerSummary> {
    let mut link = SocketWorker::connect(transport)?;
    let assign = match link.recv() {
        Ok((_, Frame::Assign(a))) => a,
        Ok((_, frame)) => anyhow::bail!(
            "expected an assignment from the master, got a {} frame",
            frame.kind_name()
        ),
        Err(e) => {
            return Err(anyhow::Error::new(e).context("waiting for the master's assignment"));
        }
    };
    let cfg = ExpConfig::from_json(&assign.config_json)
        .context("parsing the master's experiment config")?;
    let obs_cfg = crate::obs::ObsCfg {
        enabled: cfg.obs.enabled || obs_override.enabled,
        trace: cfg.obs.trace || obs_override.trace,
    };
    let obs_guard = crate::obs::begin(&obs_cfg);
    let w = assign.worker_id;
    anyhow::ensure!(
        w < assign.k_nodes && assign.k_nodes == cfg.k_nodes,
        "inconsistent assignment: worker {w} of {} nodes, config says K={}",
        assign.k_nodes,
        cfg.k_nodes
    );

    let store_dir = store_override.or(cfg.store_path.as_deref()).ok_or_else(|| {
        anyhow::anyhow!("no shard store: the master's config has no store and --store was not set")
    })?;
    let store = crate::store::open(store_dir)?;
    anyhow::ensure!(
        store.n() == assign.n && store.d() == assign.d,
        "shard store {store_dir} does not match the master's dataset: \
         {}×{} here vs {}×{} at the master",
        store.n(),
        store.d(),
        assign.n,
        assign.d
    );

    let spans = store.spans();
    let partition = Partition::from_shards(store.n(), &spans, cfg.k_nodes, cfg.r_cores)?;
    let cost_model = CostModel::new(cfg.cost_per_nnz, cfg.net_latency, cfg.net_per_elem);
    let slab = build_node_slab(&store, &partition, w, &cost_model)?;
    let wcfg =
        plan_worker_cfg(&cfg, w, cfg.k_nodes, store.d(), store.n(), slab.base, assign.allreduce);
    let rng = Rng::from_state(assign.rng_state);
    let loss = cfg.loss.build();

    // This node's scripted faults ride in on the master's config, so
    // one `--chaos` flag (or `[chaos]` table) at the master perturbs
    // the whole cluster deterministically.
    let master_addr = link.master_addr().to_string();
    let chaos = cfg.chaos()?;
    let mut link: Box<dyn Transport> = Box::new(link);
    if !chaos.is_empty() {
        link = Box::new(ChaosTransport::wrap(link, chaos, Some(w)));
    }
    if obs_cfg.enabled && obs_cfg.trace {
        link = crate::transport::ObsTransport::wrap(link);
    }

    let fin = run_worker(
        &wcfg, slab.cells, &slab.data, &*loss, &slab.norms, &slab.costs, &mut *link, rng,
    )?;
    let net = link.stats();
    crate::obs::global().set_net(&net);
    Ok(WorkerSummary {
        worker_id: w,
        local_rounds: fin.local_rounds,
        updates: fin.updates,
        net,
        master_addr,
        obs: obs_guard.and_then(|g| g.finish()),
    })
}
