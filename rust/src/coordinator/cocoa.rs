//! CoCoA+ (Ma et al. 2015b) — the synchronous distributed baseline.
//!
//! Structurally it is the `S = K, Γ = 1, R = 1` special case of
//! Hybrid-DCA (paper Fig. 1b), with two differences in the constants:
//!
//! * σ = ν·K (the all-reduce aggregates K local updates, Eq. 5), and
//! * the per-round communication is a synchronous all-reduce over all
//!   K nodes (2K transmissions / tree reduction, §5) instead of 2S
//!   point-to-point messages.
//!
//! Reusing the hybrid machinery for the special case is not a shortcut
//! — it is the paper's own argument that the framework generalizes the
//! synchronous algorithms, and the integration tests verify the merge
//! pattern is exactly all-K-every-round.

use crate::config::{ExpConfig, SigmaPolicy};
use crate::data::Dataset;
use crate::session::observer::ObserverHandle;
use crate::session::{DataSource, RunCtx};

use super::hybrid::{run_source_with_obs, run_with, run_with_obs, ProtocolOpts};
use super::master::MergePolicy;
use super::RunReport;

/// Run CoCoA+ with `cfg.k_nodes` nodes (1 core each — the paper's §6.1
/// "CoCoA+ uses only 1 core per node").
pub fn run(data: &Dataset, cfg: &ExpConfig) -> anyhow::Result<RunReport> {
    run_obs(data, cfg, &ObserverHandle::silent(), None)
}

/// Engine entry point: run with the context's config, observer, and
/// (for store-backed data) shard spans.
pub fn run_ctx(data: &Dataset, ctx: &RunCtx<'_>) -> anyhow::Result<RunReport> {
    run_obs(data, ctx.cfg, &ctx.observer, ctx.shards.clone())
}

/// Engine entry point for a [`DataSource`]: sharded sources run the
/// streamed hybrid path under the synchronous special case.
pub fn run_source_ctx(source: &DataSource, ctx: &RunCtx<'_>) -> anyhow::Result<RunReport> {
    let sync_cfg = sync_overrides(ctx.cfg);
    let opts = sync_opts(ctx.shards.clone());
    run_source_with_obs(source, &sync_cfg, &opts, &ctx.observer)
}

/// The synchronous special case of the hybrid config: 1 core per node,
/// S = K, Γ = 1, σ = νK.
pub(crate) fn sync_overrides(cfg: &ExpConfig) -> ExpConfig {
    let mut sync_cfg = cfg.clone();
    sync_cfg.r_cores = 1;
    sync_cfg.s_barrier = sync_cfg.k_nodes;
    sync_cfg.gamma = 1;
    sync_cfg.sigma = SigmaPolicy::NuK;
    sync_cfg
}

pub(crate) fn sync_opts(shards: Option<Vec<(usize, usize)>>) -> ProtocolOpts {
    ProtocolOpts {
        label: "CoCoA+".into(),
        sync_allreduce: true,
        policy: MergePolicy::OldestFirst,
        shards,
    }
}

fn run_obs(
    data: &Dataset,
    cfg: &ExpConfig,
    obs: &ObserverHandle<'_>,
    shards: Option<Vec<(usize, usize)>>,
) -> anyhow::Result<RunReport> {
    run_with_obs(data, &sync_overrides(cfg), &sync_opts(shards), obs)
}

/// The paper's §6.5 variant: run CoCoA+ treating every core as a
/// distributed node (`K × R` single-core nodes).
pub fn run_cores_as_nodes(data: &Dataset, cfg: &ExpConfig) -> anyhow::Result<RunReport> {
    let mut flat_cfg = cfg.clone();
    flat_cfg.k_nodes = cfg.k_nodes * cfg.r_cores;
    flat_cfg.r_cores = 1;
    flat_cfg.s_barrier = flat_cfg.k_nodes;
    flat_cfg.gamma = 1;
    flat_cfg.sigma = SigmaPolicy::NuK;
    if !flat_cfg.stragglers.is_empty() {
        // Expand node stragglers to their cores.
        let mut expanded = Vec::with_capacity(flat_cfg.k_nodes);
        for &s in &cfg.stragglers {
            for _ in 0..cfg.r_cores {
                expanded.push(s);
            }
        }
        flat_cfg.stragglers = expanded;
    }
    let opts = ProtocolOpts {
        label: format!("CoCoA+({} cores-as-nodes)", flat_cfg.k_nodes),
        sync_allreduce: true,
        policy: MergePolicy::OldestFirst,
        shards: None,
    };
    run_with(data, &flat_cfg, &opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::Preset;
    use crate::util::Rng;

    fn cfg() -> ExpConfig {
        let mut cfg = ExpConfig::default();
        cfg.lambda = 1e-2;
        cfg.k_nodes = 4;
        cfg.r_cores = 2; // CoCoA+ must override to 1
        cfg.s_barrier = 2; // and to S=K
        cfg.h_local = 200;
        cfg.max_rounds = 120;
        cfg.gap_threshold = 1e-4;
        cfg
    }

    #[test]
    fn cocoa_merges_all_k_every_round() {
        let data = Preset::Tiny.generate(&mut Rng::new(1));
        let report = run(&data, &cfg()).unwrap();
        for ev in &report.events {
            assert_eq!(ev.merged.len(), 4);
            // Synchronous: after every merge all freshness counters are 1.
            assert!(ev.gamma_after.iter().all(|&g| g == 1));
        }
    }

    #[test]
    fn cocoa_converges() {
        let data = Preset::Tiny.generate(&mut Rng::new(2));
        let report = run(&data, &cfg()).unwrap();
        assert!(report.trace.final_gap().unwrap() <= 1e-4);
    }

    #[test]
    fn cores_as_nodes_flattens() {
        let data = Preset::Tiny.generate(&mut Rng::new(3));
        let mut c = cfg();
        c.max_rounds = 5;
        c.gap_threshold = 1e-9;
        let report = run_cores_as_nodes(&data, &c).unwrap();
        assert_eq!(report.worker_rounds.len(), 8); // 4 nodes × 2 cores
        for ev in &report.events {
            assert_eq!(ev.merged.len(), 8);
        }
    }
}
