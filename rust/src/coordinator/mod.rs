//! Layer 3: the paper's coordination contribution.
//!
//! * [`master`] — Algorithm 2 (bounded barrier `S`, bounded delay `Γ`).
//! * [`worker`] — Algorithm 1 (R async cores × H updates, Δv exchange).
//! * [`hybrid`] — the full Hybrid-DCA driver wiring K workers + master.
//! * [`cocoa`] — the CoCoA+ baseline (synchronous special case,
//!   `S = K, Γ = 1, R = 1`, all-reduce cost model, σ = νK).
//! * [`passcode`] — the PassCoDe baseline (single node, `K = 1`).
//! * [`baseline`] — sequential DCA.
//!
//! The public entry point is the [`crate::session`] layer: a typed
//! [`Session`](crate::session::Session) run through the
//! [`SolverEngine`](crate::session::SolverEngine) registry. The
//! [`run_algorithm`] enum dispatcher is kept as a deprecated shim.

pub mod baseline;
pub mod cocoa;
pub mod distributed;
pub mod faults;
pub mod hybrid;
pub mod master;
pub mod messages;
pub mod passcode;
pub mod worker;

pub use faults::{FaultEvent, FaultLog, PeerFaults};
pub use master::{MergeEvent, MergePolicy};

use crate::config::{Algorithm, ExpConfig};
use crate::data::Dataset;
use crate::metrics::Trace;
use crate::transport::TransportStats;

/// Common result of any solver run.
#[derive(Debug)]
pub struct RunReport {
    pub label: String,
    /// Convergence trace (round / wall / virtual time / gap).
    pub trace: Trace,
    /// Master merge events (empty for single-node algorithms).
    pub events: Vec<MergeEvent>,
    /// Final global dual variables.
    pub alpha: Vec<f64>,
    /// Final shared primal estimate `v`.
    pub v: Vec<f64>,
    /// Global rounds executed.
    pub rounds: usize,
    /// Final virtual time (simulated cluster seconds).
    pub vtime: f64,
    /// Total coordinate updates across all cores.
    pub total_updates: u64,
    /// Local rounds completed per worker.
    pub worker_rounds: Vec<usize>,
    /// Master-side per-peer wire traffic (actual frame bytes — billed
    /// at [`Frame::wire_len`](crate::transport::Frame::wire_len) even
    /// in-process, counted on the socket for `--distributed`). Empty
    /// for single-node algorithms.
    pub net: TransportStats,
    /// Liveness record: stalls, retransmissions, rejoins, and deaths
    /// the master logged, plus the surviving `k_live`. Empty/default
    /// for single-node algorithms and clean for undisturbed runs.
    pub faults: FaultLog,
    /// Observability snapshot (counters, gauges, histograms, trace
    /// timeline) captured when `[obs]` is enabled; `None` otherwise.
    /// Never serialized by `--dump`, so bitwise-parity checks stand.
    pub obs: Option<crate::obs::ObsSnapshot>,
}

impl RunReport {
    /// Certificate duality gap recomputed from the final α (exact v).
    pub fn certificate_gap(&self, data: &Dataset, cfg: &ExpConfig) -> f64 {
        self.certificate_gap_eval(&mut crate::metrics::Evaluator::in_memory(data), cfg)
    }

    /// [`Self::certificate_gap`] against any [`DataSource`]: sharded
    /// sources stream shards for both the exact `v` recompute and the
    /// objective sums — same bits as the in-memory certificate, without
    /// materializing the dataset.
    pub fn certificate_gap_source(
        &self,
        source: &crate::session::DataSource,
        cfg: &ExpConfig,
    ) -> f64 {
        let mut eval = match source {
            crate::session::DataSource::InMemory(ds) => crate::metrics::Evaluator::in_memory(ds),
            crate::session::DataSource::Sharded(s) => crate::metrics::Evaluator::sharded(s),
        };
        self.certificate_gap_eval(&mut eval, cfg)
    }

    fn certificate_gap_eval(
        &self,
        eval: &mut crate::metrics::Evaluator<'_>,
        cfg: &ExpConfig,
    ) -> f64 {
        let loss = cfg.loss.build();
        let v = eval.exact_v(&self.alpha, cfg.lambda);
        eval.objectives(&*loss, &self.alpha, &v, cfg.lambda).gap
    }
}

/// Dispatch an algorithm by enum (Figure 3's four solvers).
///
/// Deprecated shim kept for source compatibility: it forwards to the
/// [`SolverEngine`](crate::session::SolverEngine) registry with no
/// observer attached, which is exactly the old behavior.
#[deprecated(
    since = "0.2.0",
    note = "build a `session::Session` (or call `session::resolve(name)`) instead; \
            this shim forwards to the engine registry"
)]
pub fn run_algorithm(
    algo: Algorithm,
    data: &Dataset,
    cfg: &ExpConfig,
) -> anyhow::Result<RunReport> {
    let engine = crate::session::resolve(crate::session::canonical_name(algo))?;
    engine.run(data, &crate::session::RunCtx::silent(cfg))
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::data::synth::Preset;
    use crate::util::Rng;

    #[test]
    fn dispatch_runs_all_four() {
        let data = Preset::Tiny.generate(&mut Rng::new(1));
        let mut cfg = ExpConfig::default();
        cfg.lambda = 1e-2;
        cfg.k_nodes = 2;
        cfg.r_cores = 2;
        cfg.s_barrier = 2;
        cfg.h_local = 100;
        cfg.max_rounds = 5;
        cfg.gap_threshold = 1e-9;
        for algo in [
            Algorithm::Baseline,
            Algorithm::CocoaPlus,
            Algorithm::PassCoDe,
            Algorithm::HybridDca,
        ] {
            let report = run_algorithm(algo, &data, &cfg).unwrap();
            assert!(!report.trace.points.is_empty(), "{}", algo.name());
            assert!(report.total_updates > 0, "{}", algo.name());
            // All four make progress from the α=0 gap of ~1.
            let g = report.trace.final_gap().unwrap();
            assert!(g < 1.0, "{}: gap {g}", algo.name());
        }
    }
}
