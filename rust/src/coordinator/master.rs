//! The master — Algorithm 2 verbatim: bounded barrier `S`, bounded
//! delay `Γ`, oldest-first merge, point-to-point replies to the
//! contributing workers only.
//!
//! ```text
//! v⁽⁰⁾ ← (1/λn)Xα;  P ← ∅
//! for t ← 0, 1, …:
//!   while |P| < S or max_k Γ_k > Γ:
//!     receive Δv_k from some worker k;  P ← P ∪ {k};  Γ_k ← 1
//!   P_S ← S workers in P with oldest updates
//!   v⁽ᵗ⁺¹⁾ ← v⁽ᵗ⁾ + ν Σ_{k∈P_S} Δv_k;  P ← P \ P_S
//!   ∀k ∉ P_S: Γ_k ← Γ_k + 1
//!   broadcast v⁽ᵗ⁺¹⁾ to workers in P_S
//! ```
//!
//! ## Virtual-time semantics (conservative discrete-event simulation)
//!
//! The cluster timeline is *simulated* (DESIGN.md §3): messages carry a
//! virtual arrival time computed from the worker's costed compute and
//! the network model. To keep the simulated protocol causally exact —
//! the master must not act on a message before its virtual arrival —
//! messages are processed in **virtual-arrival order**, not OS-thread
//! delivery order. This is a conservative DES: because every worker
//! blocks after sending, the master can wait (in real time) until it
//! physically holds one message from every in-flight worker, then pop
//! arrivals from a priority queue in virtual order. A side benefit is
//! that the entire virtual timeline (merge pattern, staleness, times)
//! is deterministic given the seed, while the *intra-node* asynchrony
//! (R racing core-threads per worker) remains physically real.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use anyhow::Context;

use crate::loss::Loss;
use crate::metrics::{Evaluator, Trace, TracePoint};
use crate::session::observer::{EvalEvent, ObserverHandle, RoundEvent};
use crate::transport::{Frame, Transport, TransportError};
use crate::util::{norm_sq, Stopwatch};

use super::messages::{MasterReply, WorkerFinal, WorkerMsg};

pub use crate::config::MergePolicy;

/// Event record for one global merge — consumed by the property tests
/// (barrier size, uniqueness, staleness bounds).
#[derive(Debug, Clone, PartialEq)]
pub struct MergeEvent {
    /// Global round `t` (1-based: the round this merge produced).
    pub round: usize,
    /// `(worker, local_round)` of each merged update, in merge order.
    pub merged: Vec<(usize, usize)>,
    /// Γ_k snapshot *after* this merge (freshness counters).
    pub gamma_after: Vec<usize>,
    /// Virtual time of the merge.
    pub vtime: f64,
    /// Global rounds each merged update waited in `P` before merging.
    pub queue_wait: Vec<usize>,
}

/// Master configuration.
#[derive(Debug, Clone)]
pub struct MasterCfg {
    pub k_nodes: usize,
    pub s_barrier: usize,
    pub gamma: usize,
    pub nu: f64,
    pub lambda: f64,
    pub max_rounds: usize,
    pub gap_threshold: f64,
    pub eval_every: usize,
    pub policy: MergePolicy,
    /// Virtual master-side merge cost per round (≈0 for p2p Hybrid;
    /// the extra collective term for CoCoA+'s all-reduce).
    pub merge_cost: f64,
    /// Virtual latency of the reply (master → worker message).
    pub reply_latency: f64,
}

/// Outcome of a master run.
#[derive(Debug)]
pub struct MasterOutcome {
    pub v: Vec<f64>,
    pub trace: Trace,
    pub events: Vec<MergeEvent>,
    pub rounds: usize,
    /// Final virtual time.
    pub vtime: f64,
    /// Each worker's final report, collected during the shutdown
    /// drain. `None` only if the worker vanished before reporting
    /// (the driver decides whether that is fatal).
    pub finals: Vec<Option<WorkerFinal>>,
}

/// A message waiting in the virtual-arrival priority queue.
struct Arrival {
    vtime: f64,
    seq: u64,
    msg: WorkerMsg,
}

impl PartialEq for Arrival {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for Arrival {}
impl PartialOrd for Arrival {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Arrival {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.vtime
            .total_cmp(&other.vtime)
            .then(self.seq.cmp(&other.seq))
    }
}

/// A received (popped in virtual order), unmerged update.
struct Pending {
    msg: WorkerMsg,
    /// Global round at which it was received.
    received_at: usize,
}

/// Run Algorithm 2 until the gap threshold or `max_rounds`.
///
/// All worker traffic flows through `link` — the in-process channel
/// backend for simulated runs, a socket cluster for `--distributed`
/// (the bounded-barrier gather then blocks on real socket readiness).
/// `eval`/`loss` are used only for objective evaluation (the paper
/// computes these distributed / offline; we evaluate at the master —
/// same numbers, zero protocol impact). The evaluator may stream a
/// shard store — the master never needs the flat dataset: the dual is
/// assembled from the workers' tracked sums, and only the primal pass
/// touches rows.
///
/// At convergence/early-stop the master broadcasts `Shutdown` frames
/// and drains one `Final` report per worker into the outcome, so
/// worker processes exit cleanly rather than dying on a closed
/// socket.
///
/// `obs` streams merge/round/eval events to the caller's observer; a
/// `Break` from any callback stops the run through the normal
/// termination path.
pub fn run_master(
    cfg: &MasterCfg,
    link: &mut dyn Transport,
    eval: &mut Evaluator<'_>,
    loss: &dyn Loss,
    label: &str,
    obs: &ObserverHandle<'_>,
) -> anyhow::Result<MasterOutcome> {
    let k = cfg.k_nodes;
    assert_eq!(link.peers(), k);
    let s_eff = cfg.s_barrier.min(k);
    let n = eval.n() as f64;
    let mut v = vec![0.0; eval.d()]; // v⁽⁰⁾ = (1/λn)·X·0 = 0
    let mut gamma_k = vec![1usize; k];
    // Workers we have replied to whose next message is still in flight.
    let mut computing: Vec<bool> = vec![true; k];
    let mut computing_count = k;
    // Virtual-arrival queue of physically-received messages.
    let mut pq: BinaryHeap<Reverse<Arrival>> = BinaryHeap::new();
    let mut seq = 0u64;
    // Each worker blocks after sending ⇒ at most one pending update each.
    let mut pending: Vec<Option<Pending>> = (0..k).map(|_| None).collect();
    // Virtual-arrival (FIFO) order of workers currently in P.
    let mut arrival_order: VecDeque<usize> = VecDeque::new();
    // Latest known per-worker dual sums. Initial α = 0 gives 0 for all
    // supported losses (hinge: a=0→0; squared hinge: 0; logistic: H(0)=0).
    let mut dual_sums = vec![0.0; k];

    let mut trace = Trace::new(label);
    let mut events = Vec::new();
    let sw = Stopwatch::start();
    let mut vtime = 0.0f64;
    let mut total_updates: u64 = 0;

    // Initial point (α = 0, v = 0) — evaluated without materializing
    // the zero α vector (n × 8 bytes at paper scale).
    let o0 = eval.objectives_at_zero(loss, &v, cfg.lambda);
    let p0 = TracePoint {
        round: 0,
        wall_secs: 0.0,
        virt_secs: 0.0,
        gap: o0.gap,
        primal: o0.primal,
        dual: o0.dual,
        updates: 0,
    };
    trace.push(p0.clone());
    let initial_stop = obs.on_eval(&EvalEvent { point: p0 }).is_break();

    let mut t = 0usize;
    let mut disconnected = false;
    'rounds: while t < cfg.max_rounds && !initial_stop {
        // ---- conservative DES step 1: hold one message per in-flight
        // worker so the next virtual arrival is known exactly ----
        while computing_count > 0 {
            match link.recv() {
                Ok((peer, Frame::Update(msg))) => {
                    let w = msg.worker;
                    anyhow::ensure!(
                        w == peer && w < k,
                        "update from peer {peer} claims worker id {w}"
                    );
                    debug_assert!(computing[w], "worker {w} double-sent");
                    computing[w] = false;
                    computing_count -= 1;
                    pq.push(Reverse(Arrival { vtime: msg.arrival_vtime, seq, msg }));
                    seq += 1;
                }
                Ok((peer, frame)) => {
                    anyhow::bail!(
                        "unexpected {} frame from worker {peer} during round {t}",
                        frame.kind_name()
                    );
                }
                Err(TransportError::Closed) => {
                    disconnected = true;
                    break 'rounds;
                }
                Err(e) => {
                    return Err(anyhow::Error::new(e)
                        .context(format!("receiving worker updates in round {t}")));
                }
            }
        }

        // ---- Algorithm 2 gather: pop arrivals in virtual order until
        // |P| ≥ S and no not-yet-arrived worker is staler than Γ ----
        let stale_unarrived = |pending: &[Option<Pending>], gamma_k: &[usize]| {
            (0..k).any(|w| pending[w].is_none() && gamma_k[w] > cfg.gamma)
        };
        while arrival_order.len() < s_eff || stale_unarrived(&pending, &gamma_k) {
            let Reverse(arr) = pq.pop().expect("all K workers are in P or pq");
            vtime = vtime.max(arr.vtime);
            let w = arr.msg.worker;
            gamma_k[w] = 1;
            dual_sums[w] = arr.msg.dual_sum;
            arrival_order.push_back(w);
            pending[w] = Some(Pending { msg: arr.msg, received_at: t });
        }

        // ---- pick S workers ----
        // Priority: pending updates whose freshness counter has passed Γ
        // are merged first (§3.2: "the master makes sure that no worker
        // has a stale update older than Γ rounds"); remaining slots
        // follow the policy. NewestFirst (the ablation) skips the
        // priority pass to expose the starvation it causes.
        let mut picked: Vec<usize> = Vec::with_capacity(s_eff);
        if cfg.policy == MergePolicy::OldestFirst {
            let mut i = 0;
            while i < arrival_order.len() && picked.len() < s_eff {
                let w = arrival_order[i];
                if gamma_k[w] > cfg.gamma {
                    picked.push(w);
                    arrival_order.remove(i);
                } else {
                    i += 1;
                }
            }
        }
        while picked.len() < s_eff {
            let w = match cfg.policy {
                MergePolicy::OldestFirst => arrival_order.pop_front().unwrap(),
                MergePolicy::NewestFirst => arrival_order.pop_back().unwrap(),
            };
            picked.push(w);
        }

        // ---- merge v ← v + ν Σ Δv at the gather-complete time ----
        let mut merged_ids = Vec::with_capacity(picked.len());
        let mut queue_wait = Vec::with_capacity(picked.len());
        for &w in &picked {
            let p = pending[w].take().expect("picked worker has a pending update");
            // One add per coordinate whether the delta arrived dense or
            // sparse — representations are merge-equivalent.
            p.msg.delta_v.add_scaled_into(&mut v, cfg.nu);
            total_updates += p.msg.updates;
            merged_ids.push((w, p.msg.local_round));
            queue_wait.push(t - p.received_at);
        }
        vtime += cfg.merge_cost;

        // ---- Γ bookkeeping ----
        for w in 0..k {
            if !picked.contains(&w) {
                gamma_k[w] += 1;
            }
        }
        t += 1;

        let merge_ev = MergeEvent {
            round: t,
            merged: merged_ids,
            gamma_after: gamma_k.clone(),
            vtime,
            queue_wait,
        };
        // Stream the merge and round to the observer before deciding
        // whether to evaluate; a Break stops the run like a reached
        // gap threshold would.
        let mut observer_stop = obs.on_merge(&merge_ev).is_break();
        events.push(merge_ev);
        observer_stop |= obs
            .on_round(&RoundEvent { round: t, vtime, updates: total_updates })
            .is_break();

        // ---- evaluate + stopping decision ----
        let mut stop = t >= cfg.max_rounds || observer_stop;
        if t % cfg.eval_every == 0 || stop {
            let primal = eval.primal(loss, &v, cfg.lambda);
            let dual = dual_sums.iter().sum::<f64>() / n - 0.5 * cfg.lambda * norm_sq(&v);
            let gap = primal - dual;
            let point = TracePoint {
                round: t,
                wall_secs: sw.elapsed_secs(),
                virt_secs: vtime,
                gap,
                primal,
                dual,
                updates: total_updates,
            };
            trace.push(point.clone());
            if obs.on_eval(&EvalEvent { point }).is_break() {
                stop = true;
            }
            if gap <= cfg.gap_threshold {
                stop = true;
            }
        }

        if stop {
            // Shut down contributors, everything still queued in P, and
            // every message still sitting in the virtual queue (their
            // workers are all blocked on our reply).
            for &w in &picked {
                let _ = link.send(w, Frame::Shutdown { vtime, round: t });
            }
            for w in 0..k {
                if pending[w].take().is_some() {
                    let _ = link.send(w, Frame::Shutdown { vtime, round: t });
                }
            }
            while let Some(Reverse(arr)) = pq.pop() {
                let _ = link.send(arr.msg.worker, Frame::Shutdown { vtime, round: t });
            }
            arrival_order.clear();
            break;
        }
        // ---- broadcast merged v to contributors ----
        for &w in &picked {
            let _ = link.send(
                w,
                Frame::Merged(MasterReply {
                    v: v.clone(),
                    arrival_vtime: vtime + cfg.reply_latency,
                    global_round: t,
                    terminate: false,
                }),
            );
            computing[w] = true;
            computing_count += 1;
        }
    }

    // Shutdown drain: shut down any still-in-flight workers and
    // collect every worker's Final report.
    let mut finals: Vec<Option<WorkerFinal>> = (0..k).map(|_| None).collect();
    if !disconnected {
        for w in 0..k {
            if pending[w].take().is_some() {
                let _ = link.send(w, Frame::Shutdown { vtime, round: t });
            }
        }
        while let Some(Reverse(arr)) = pq.pop() {
            let _ = link.send(arr.msg.worker, Frame::Shutdown { vtime, round: t });
        }
        let mut reported = 0usize;
        while reported < k {
            match link.recv() {
                Ok((peer, Frame::Update(_))) => {
                    let _ = link.send(peer, Frame::Shutdown { vtime, round: t });
                }
                Ok((peer, Frame::Final(fin))) => {
                    anyhow::ensure!(
                        fin.worker_id == peer && peer < k,
                        "final report from peer {peer} claims worker id {}",
                        fin.worker_id
                    );
                    if finals[peer].replace(fin).is_none() {
                        reported += 1;
                    }
                }
                Ok((peer, frame)) => {
                    anyhow::bail!(
                        "unexpected {} frame from worker {peer} during shutdown",
                        frame.kind_name()
                    );
                }
                Err(TransportError::Closed) => break,
                // A worker's connection closing after its Final is a
                // normal exit; before it, the report is lost.
                Err(TransportError::PeerGone { peer, .. }) if finals[peer].is_some() => {}
                Err(e) => {
                    return Err(anyhow::Error::new(e).context("draining worker final reports"));
                }
            }
        }
    }

    Ok(MasterOutcome { v, trace, events, rounds: t, vtime, finals })
}
