//! The master — Algorithm 2 verbatim: bounded barrier `S`, bounded
//! delay `Γ`, oldest-first merge, point-to-point replies to the
//! contributing workers only.
//!
//! ```text
//! v⁽⁰⁾ ← (1/λn)Xα;  P ← ∅
//! for t ← 0, 1, …:
//!   while |P| < S or max_k Γ_k > Γ:
//!     receive Δv_k from some worker k;  P ← P ∪ {k};  Γ_k ← 1
//!   P_S ← S workers in P with oldest updates
//!   v⁽ᵗ⁺¹⁾ ← v⁽ᵗ⁾ + ν Σ_{k∈P_S} Δv_k;  P ← P \ P_S
//!   ∀k ∉ P_S: Γ_k ← Γ_k + 1
//!   broadcast v⁽ᵗ⁺¹⁾ to workers in P_S
//! ```
//!
//! ## Virtual-time semantics (conservative discrete-event simulation)
//!
//! The cluster timeline is *simulated* (DESIGN.md §3): messages carry a
//! virtual arrival time computed from the worker's costed compute and
//! the network model. To keep the simulated protocol causally exact —
//! the master must not act on a message before its virtual arrival —
//! messages are processed in **virtual-arrival order**, not OS-thread
//! delivery order. This is a conservative DES: because every worker
//! blocks after sending, the master can wait (in real time) until it
//! physically holds one message from every in-flight worker, then pop
//! arrivals from a priority queue in virtual order. A side benefit is
//! that the entire virtual timeline (merge pattern, staleness, times)
//! is deterministic given the seed, while the *intra-node* asynchrony
//! (R racing core-threads per worker) remains physically real.

//! ## Fault tolerance (graceful S-barrier degradation)
//!
//! The gather loop keeps a per-worker liveness record. Read-timeout
//! ticks and `PeerSilent`/`PeerGone` transport errors accumulate
//! *suspicion strikes*; a worker striking out
//! (`suspicion_timeouts` consecutive strikes) is declared dead: its
//! queued update is discarded, its link released, and the effective
//! cluster shrinks to `K_live`. The barrier keeps running as long as
//! `S ≤ K_live` and the run errors (naming the peer and its last
//! acked round) only when `K_live < S`. A worker that dials back in
//! with a `Rejoin` frame is readmitted, and lost frames are repaired
//! by a stop-and-wait retransmit protocol (`Nack` = "resend"):
//! duplicate updates are deduplicated by local round, duplicate
//! replies by global round. Undisturbed runs never tick and never
//! Nack, so the fault layer is bitwise invisible to the parity tests.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};
use std::time::Duration;

use anyhow::Context;

use crate::loss::Loss;
use crate::metrics::{Evaluator, Trace, TracePoint};
use crate::obs::FaultKind as ObsFault;
use crate::session::observer::{EvalEvent, ObserverHandle, RoundEvent};
use crate::transport::{Frame, Transport, TransportError};
use crate::util::{norm_sq, Stopwatch};

use super::faults::FaultLog;
use super::messages::{MasterReply, WorkerFinal, WorkerMsg};

pub use crate::config::MergePolicy;

/// Event record for one global merge — consumed by the property tests
/// (barrier size, uniqueness, staleness bounds).
#[derive(Debug, Clone, PartialEq)]
pub struct MergeEvent {
    /// Global round `t` (1-based: the round this merge produced).
    pub round: usize,
    /// `(worker, local_round)` of each merged update, in merge order.
    pub merged: Vec<(usize, usize)>,
    /// Γ_k snapshot *after* this merge (freshness counters).
    pub gamma_after: Vec<usize>,
    /// Virtual time of the merge.
    pub vtime: f64,
    /// Global rounds each merged update waited in `P` before merging.
    pub queue_wait: Vec<usize>,
}

/// Master configuration.
#[derive(Debug, Clone)]
pub struct MasterCfg {
    pub k_nodes: usize,
    pub s_barrier: usize,
    pub gamma: usize,
    pub nu: f64,
    pub lambda: f64,
    pub max_rounds: usize,
    pub gap_threshold: f64,
    pub eval_every: usize,
    pub policy: MergePolicy,
    /// Virtual master-side merge cost per round (≈0 for p2p Hybrid;
    /// the extra collective term for CoCoA+'s all-reduce).
    pub merge_cost: f64,
    /// Virtual latency of the reply (master → worker message).
    pub reply_latency: f64,
    /// Liveness tick (seconds of *real* silence before a suspicion
    /// strike; mirrors `transport.read_timeout_secs`). 0 disables the
    /// tick — the gather blocks forever, the pre-fault-tolerance
    /// behavior.
    pub tick_secs: f64,
    /// Consecutive strikes before a silent worker is declared dead
    /// (mirrors `transport.suspicion_timeouts`; 0 = never).
    pub suspicion_timeouts: u32,
}

/// Outcome of a master run.
#[derive(Debug)]
pub struct MasterOutcome {
    pub v: Vec<f64>,
    pub trace: Trace,
    pub events: Vec<MergeEvent>,
    pub rounds: usize,
    /// Final virtual time.
    pub vtime: f64,
    /// Each worker's final report, collected during the shutdown
    /// drain. `None` only if the worker vanished before reporting
    /// (the driver decides whether that is fatal — a declared-dead
    /// worker's missing report is expected degradation).
    pub finals: Vec<Option<WorkerFinal>>,
    /// Liveness record: stalls, retransmits, rejoins, deaths, and the
    /// surviving `k_live`. Clean for undisturbed runs.
    pub faults: FaultLog,
}

/// A message waiting in the virtual-arrival priority queue.
struct Arrival {
    vtime: f64,
    seq: u64,
    msg: WorkerMsg,
}

impl PartialEq for Arrival {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for Arrival {}
impl PartialOrd for Arrival {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Arrival {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.vtime
            .total_cmp(&other.vtime)
            .then(self.seq.cmp(&other.seq))
    }
}

/// A received (popped in virtual order), unmerged update.
struct Pending {
    msg: WorkerMsg,
    /// Global round at which it was received.
    received_at: usize,
    /// Γ_k at pop time — the *measured* staleness of this update, the
    /// quantity the configured Γ bound constrains (recorded into the
    /// obs staleness histogram when the update merges).
    staleness: usize,
}

/// Everything [`declare_dead`] mutates, bundled so the call site stays
/// readable.
struct DeclareDead<'a> {
    w: usize,
    t: usize,
    vtime: f64,
    link: &'a mut dyn Transport,
    live: &'a mut Vec<bool>,
    k_live: &'a mut usize,
    strikes: &'a mut Vec<u32>,
    computing: &'a mut Vec<bool>,
    computing_count: &'a mut usize,
    pending: &'a mut Vec<Option<Pending>>,
    arrival_order: &'a mut VecDeque<usize>,
    pq: &'a mut BinaryHeap<Reverse<Arrival>>,
    gamma_k: &'a mut Vec<usize>,
    last_update_round: &'a mut Vec<Option<usize>>,
    faults: &'a mut FaultLog,
}

/// Declare worker `w` dead: discard its queued update, release its
/// link, shrink the live cluster, and log the event. Idempotent.
fn declare_dead(d: DeclareDead<'_>) {
    let w = d.w;
    if !d.live[w] {
        return;
    }
    d.live[w] = false;
    *d.k_live -= 1;
    d.strikes[w] = 0;
    if d.computing[w] {
        d.computing[w] = false;
        *d.computing_count -= 1;
    }
    let mut purged = false;
    if d.pending[w].take().is_some() {
        d.arrival_order.retain(|&x| x != w);
        purged = true;
    }
    let held = std::mem::take(d.pq);
    let before = held.len();
    *d.pq = held.into_iter().filter(|Reverse(a)| a.msg.worker != w).collect();
    purged |= d.pq.len() < before;
    if purged {
        // The discarded update was received but never merged; roll the
        // stop-and-wait dedup filter back so that if this worker
        // rejoins, its retransmit of the same local round is accepted
        // as new instead of deduplicated into a livelock. (An update
        // that *was* merged keeps its filter entry — the retransmit
        // must then be answered with the recorded `last_reply`, never
        // merged twice.)
        d.last_update_round[w] = None;
    }
    d.gamma_k[w] = 1;
    d.link.disconnect(w);
    d.faults.per_peer[w].declared_dead += 1;
    let last = d.faults.per_peer[w].last_acked_round;
    d.faults.log(
        d.vtime,
        d.t,
        w,
        format!("declared dead (last acked round {last}); k_live now {}", *d.k_live),
    );
    crate::obs::global().fault(ObsFault::DeclaredDead, w, d.t, "suspicion strikes exhausted");
}

/// Run Algorithm 2 until the gap threshold or `max_rounds`.
///
/// All worker traffic flows through `link` — the in-process channel
/// backend for simulated runs, a socket cluster for `--distributed`
/// (the bounded-barrier gather then blocks on real socket readiness).
/// `eval`/`loss` are used only for objective evaluation (the paper
/// computes these distributed / offline; we evaluate at the master —
/// same numbers, zero protocol impact). The evaluator may stream a
/// shard store — the master never needs the flat dataset: the dual is
/// assembled from the workers' tracked sums, and only the primal pass
/// touches rows.
///
/// At convergence/early-stop the master broadcasts `Shutdown` frames
/// and drains one `Final` report per worker into the outcome, so
/// worker processes exit cleanly rather than dying on a closed
/// socket.
///
/// `obs` streams merge/round/eval events to the caller's observer; a
/// `Break` from any callback stops the run through the normal
/// termination path.
pub fn run_master(
    cfg: &MasterCfg,
    link: &mut dyn Transport,
    eval: &mut Evaluator<'_>,
    loss: &dyn Loss,
    label: &str,
    obs: &ObserverHandle<'_>,
) -> anyhow::Result<MasterOutcome> {
    let k = cfg.k_nodes;
    assert_eq!(link.peers(), k);
    let rec = crate::obs::global();
    let s_eff = cfg.s_barrier.min(k);
    let n = eval.n() as f64;
    let mut v = vec![0.0; eval.d()]; // v⁽⁰⁾ = (1/λn)·X·0 = 0
    let mut gamma_k = vec![1usize; k];
    // Workers we have replied to whose next message is still in flight.
    let mut computing: Vec<bool> = vec![true; k];
    let mut computing_count = k;
    // Virtual-arrival queue of physically-received messages.
    let mut pq: BinaryHeap<Reverse<Arrival>> = BinaryHeap::new();
    let mut seq = 0u64;
    // Each worker blocks after sending ⇒ at most one pending update each.
    let mut pending: Vec<Option<Pending>> = (0..k).map(|_| None).collect();
    // Virtual-arrival (FIFO) order of workers currently in P.
    let mut arrival_order: VecDeque<usize> = VecDeque::new();
    // Latest known per-worker dual sums. Initial α = 0 gives 0 for all
    // supported losses (hinge: a=0→0; squared hinge: 0; logistic: H(0)=0).
    let mut dual_sums = vec![0.0; k];

    // ---- liveness / retransmit state (fault tolerance) ----
    let mut faults = FaultLog::new(k);
    let mut live = vec![true; k];
    let mut k_live = k;
    let mut strikes = vec![0u32; k];
    // Highest worker-local round accepted per worker: the duplicate
    // filter of the stop-and-wait protocol.
    let mut last_update_round: Vec<Option<usize>> = vec![None; k];
    // Last reply shipped to each worker, kept for Nack-triggered and
    // duplicate-triggered retransmission.
    let mut last_reply: Vec<Option<Frame>> = (0..k).map(|_| None).collect();
    let tick = if cfg.tick_secs > 0.0 {
        Some(Duration::from_secs_f64(cfg.tick_secs))
    } else {
        None
    };

    let mut trace = Trace::new(label);
    let mut events = Vec::new();
    let sw = Stopwatch::start();
    let mut vtime = 0.0f64;
    let mut total_updates: u64 = 0;

    // Initial point (α = 0, v = 0) — evaluated without materializing
    // the zero α vector (n × 8 bytes at paper scale).
    let o0 = eval.objectives_at_zero(loss, &v, cfg.lambda);
    let p0 = TracePoint {
        round: 0,
        wall_secs: 0.0,
        virt_secs: 0.0,
        gap: o0.gap,
        primal: o0.primal,
        dual: o0.dual,
        updates: 0,
    };
    trace.push(p0.clone());
    let initial_stop = obs.on_eval(&EvalEvent { point: p0 }).is_break();

    let mut t = 0usize;
    let mut disconnected = false;
    // Final reports, collected mostly by the shutdown drain below —
    // but a released dead worker may report out mid-gather, and its α
    // is still worth keeping.
    let mut finals: Vec<Option<WorkerFinal>> = (0..k).map(|_| None).collect();
    'rounds: while t < cfg.max_rounds && !initial_stop {
        // Wall-clock span of the whole gather: physical holds plus the
        // virtual-order pops — everything the S-barrier makes us wait
        // for before the merge can run.
        let barrier_t0 = rec.timer();
        // ---- conservative DES step 1: hold one message per in-flight
        // live worker so the next virtual arrival is known exactly ----
        while computing_count > 0 {
            let got = match tick {
                Some(d) => link.recv_timeout(d),
                None => link.recv().map(Some),
            };
            match got {
                Ok(Some((peer, Frame::Update(msg)))) => {
                    let w = msg.worker;
                    anyhow::ensure!(
                        w == peer && w < k,
                        "update from peer {peer} claims worker id {w}"
                    );
                    strikes[w] = 0;
                    if !live[w] {
                        // Declared dead, surfaced without a Rejoin (an
                        // in-process stall straggler): release it so
                        // its thread can exit cleanly.
                        let _ = link.send(w, Frame::Shutdown { vtime, round: t });
                        continue;
                    }
                    if Some(msg.local_round) <= last_update_round[w] {
                        // Stop-and-wait duplicate (our reply was lost,
                        // or the worker redialed before it arrived):
                        // drop the copy, repeat the reply.
                        faults.per_peer[w].retransmits += 1;
                        rec.fault(ObsFault::Retransmit, w, t, "duplicate update, reply repeated");
                        if let Some(reply) = last_reply[w].clone() {
                            let _ = link.send(w, reply);
                        }
                        continue;
                    }
                    debug_assert!(computing[w], "worker {w} double-sent");
                    last_update_round[w] = Some(msg.local_round);
                    faults.per_peer[w].last_acked_round = msg.local_round;
                    if computing[w] {
                        computing[w] = false;
                        computing_count -= 1;
                    }
                    pq.push(Reverse(Arrival { vtime: msg.arrival_vtime, seq, msg }));
                    seq += 1;
                }
                Ok(Some((peer, Frame::Rejoin(info)))) => {
                    anyhow::ensure!(
                        info.worker_id == peer && peer < k,
                        "rejoin from peer {peer} claims worker id {}",
                        info.worker_id
                    );
                    let w = peer;
                    strikes[w] = 0;
                    faults.per_peer[w].rejoins += 1;
                    faults.per_peer[w].last_acked_round =
                        faults.per_peer[w].last_acked_round.max(info.last_acked_round);
                    if live[w] {
                        faults.log(
                            vtime,
                            t,
                            w,
                            format!(
                                "reconnected (last_acked_round={}, alpha_crc={:#010x})",
                                info.last_acked_round, info.alpha_crc
                            ),
                        );
                    } else {
                        live[w] = true;
                        k_live += 1;
                        gamma_k[w] = 1;
                        // It will resend the update we never merged.
                        computing[w] = true;
                        computing_count += 1;
                        faults.log(
                            vtime,
                            t,
                            w,
                            format!(
                                "readmitted after death (last_acked_round={}, \
                                 alpha_crc={:#010x}); k_live now {k_live}",
                                info.last_acked_round, info.alpha_crc
                            ),
                        );
                    }
                    rec.fault(ObsFault::Rejoin, w, t, "rejoin handshake accepted");
                }
                Ok(Some((peer, Frame::Nack { .. }))) if peer < k => {
                    // "Resend your last reply" — our Merged was lost.
                    faults.per_peer[peer].retransmits += 1;
                    rec.fault(ObsFault::Retransmit, peer, t, "nack, last reply resent");
                    if let Some(reply) = last_reply[peer].clone() {
                        let _ = link.send(peer, reply);
                    }
                }
                Ok(Some((peer, Frame::Final(fin)))) if peer < k && !live[peer] => {
                    // A released dead worker reporting out on its way
                    // down — the Shutdown we sent it provoked exactly
                    // this frame, and its α is still worth keeping.
                    anyhow::ensure!(
                        fin.worker_id == peer,
                        "final report from peer {peer} claims worker id {}",
                        fin.worker_id
                    );
                    finals[peer] = Some(fin);
                }
                Ok(Some((peer, frame))) => {
                    anyhow::bail!(
                        "unexpected {} frame from worker {peer} during round {t}",
                        frame.kind_name()
                    );
                }
                Ok(None) => {
                    // Liveness tick: nothing at all arrived. Strike
                    // every awaited worker and probe it — the Nack asks
                    // it to resend, repairing a dropped update.
                    for w in 0..k {
                        if live[w] && computing[w] {
                            strikes[w] += 1;
                            faults.per_peer[w].stalls += 1;
                            rec.fault(ObsFault::Stall, w, t, "silent liveness tick");
                            let _ = link.send(w, Frame::Nack { round: t });
                        }
                    }
                }
                Err(TransportError::PeerSilent { peer, .. }) if peer < k => {
                    if live[peer] && computing[peer] {
                        strikes[peer] += 1;
                        faults.per_peer[peer].stalls += 1;
                        rec.fault(ObsFault::Stall, peer, t, "peer silent past read timeout");
                        let _ = link.send(peer, Frame::Nack { round: t });
                    }
                }
                Err(TransportError::PeerGone { peer, .. }) if peer < k => {
                    // The connection died; the worker may still redial
                    // and Rejoin. Strike it and keep gathering. (For an
                    // already-dead peer this is stale news — ignore.)
                    if live[peer] {
                        strikes[peer] += 1;
                        faults.per_peer[peer].stalls += 1;
                        rec.fault(ObsFault::Stall, peer, t, "peer connection lost");
                    }
                }
                Err(TransportError::Wire { peer, .. }) if peer < k && live[peer] => {
                    // A frame arrived corrupted (CRC reject): ask for a
                    // retransmit instead of tearing the cluster down.
                    faults.per_peer[peer].retransmits += 1;
                    rec.fault(ObsFault::Retransmit, peer, t, "corrupt frame, nack sent");
                    let _ = link.send(peer, Frame::Nack { round: t });
                }
                Err(TransportError::Closed) => {
                    disconnected = true;
                    break 'rounds;
                }
                Err(e) => {
                    return Err(anyhow::Error::new(e)
                        .context(format!("receiving worker updates in round {t}")));
                }
            }

            // ---- suspicion: declare struck-out workers dead ----
            if cfg.suspicion_timeouts > 0 {
                for w in 0..k {
                    if live[w] && strikes[w] >= cfg.suspicion_timeouts {
                        declare_dead(DeclareDead {
                            w,
                            t,
                            vtime,
                            link: &mut *link,
                            live: &mut live,
                            k_live: &mut k_live,
                            strikes: &mut strikes,
                            computing: &mut computing,
                            computing_count: &mut computing_count,
                            pending: &mut pending,
                            arrival_order: &mut arrival_order,
                            pq: &mut pq,
                            gamma_k: &mut gamma_k,
                            last_update_round: &mut last_update_round,
                            faults: &mut faults,
                        });
                        anyhow::ensure!(
                            k_live >= s_eff,
                            "worker {w} declared dead after {} silent ticks \
                             (last acked round {}): only {k_live} live workers remain, \
                             cannot satisfy barrier S={s_eff}",
                            cfg.suspicion_timeouts,
                            faults.per_peer[w].last_acked_round,
                        );
                    }
                }
            }
        }

        // ---- Algorithm 2 gather: pop arrivals in virtual order until
        // |P| ≥ S and no not-yet-arrived live worker is staler than Γ ----
        let stale_unarrived =
            |pending: &[Option<Pending>], gamma_k: &[usize], live: &[bool]| {
                (0..k).any(|w| live[w] && pending[w].is_none() && gamma_k[w] > cfg.gamma)
            };
        while arrival_order.len() < s_eff || stale_unarrived(&pending, &gamma_k, &live) {
            let Reverse(arr) = pq.pop().expect("all live workers are in P or pq");
            vtime = vtime.max(arr.vtime);
            let w = arr.msg.worker;
            let staleness = gamma_k[w];
            gamma_k[w] = 1;
            dual_sums[w] = arr.msg.dual_sum;
            arrival_order.push_back(w);
            pending[w] = Some(Pending { msg: arr.msg, received_at: t, staleness });
        }
        rec.barrier_wait(t, s_eff, barrier_t0);

        // ---- pick S workers ----
        // Priority: pending updates whose freshness counter has passed Γ
        // are merged first (§3.2: "the master makes sure that no worker
        // has a stale update older than Γ rounds"); remaining slots
        // follow the policy. NewestFirst (the ablation) skips the
        // priority pass to expose the starvation it causes.
        let mut picked: Vec<usize> = Vec::with_capacity(s_eff);
        if cfg.policy == MergePolicy::OldestFirst {
            let mut i = 0;
            while i < arrival_order.len() && picked.len() < s_eff {
                let w = arrival_order[i];
                if gamma_k[w] > cfg.gamma {
                    picked.push(w);
                    arrival_order.remove(i);
                } else {
                    i += 1;
                }
            }
        }
        while picked.len() < s_eff {
            let w = match cfg.policy {
                MergePolicy::OldestFirst => arrival_order.pop_front().unwrap(),
                MergePolicy::NewestFirst => arrival_order.pop_back().unwrap(),
            };
            picked.push(w);
        }

        // ---- merge v ← v + ν Σ Δv at the gather-complete time ----
        let mut merged_ids = Vec::with_capacity(picked.len());
        let mut queue_wait = Vec::with_capacity(picked.len());
        let mut round_updates = 0u64;
        for &w in &picked {
            let p = pending[w].take().expect("picked worker has a pending update");
            // One add per coordinate whether the delta arrived dense or
            // sparse — representations are merge-equivalent.
            p.msg.delta_v.add_scaled_into(&mut v, cfg.nu);
            total_updates += p.msg.updates;
            round_updates += p.msg.updates;
            rec.merged_update(t + 1, w, p.staleness, vtime);
            merged_ids.push((w, p.msg.local_round));
            queue_wait.push(t - p.received_at);
        }
        vtime += cfg.merge_cost;

        // ---- Γ bookkeeping (dead workers carry no staleness debt) ----
        for w in 0..k {
            if live[w] && !picked.contains(&w) {
                gamma_k[w] += 1;
            }
        }
        t += 1;
        rec.master_round(round_updates);

        let merge_ev = MergeEvent {
            round: t,
            merged: merged_ids,
            gamma_after: gamma_k.clone(),
            vtime,
            queue_wait,
        };
        // Stream the merge and round to the observer before deciding
        // whether to evaluate; a Break stops the run like a reached
        // gap threshold would.
        let mut observer_stop = obs.on_merge(&merge_ev).is_break();
        events.push(merge_ev);
        observer_stop |= obs
            .on_round(&RoundEvent { round: t, vtime, updates: total_updates })
            .is_break();

        // ---- evaluate + stopping decision ----
        let mut stop = t >= cfg.max_rounds || observer_stop;
        if t % cfg.eval_every == 0 || stop {
            let eval_t0 = rec.timer();
            let primal = eval.primal(loss, &v, cfg.lambda);
            let dual = dual_sums.iter().sum::<f64>() / n - 0.5 * cfg.lambda * norm_sq(&v);
            let gap = primal - dual;
            rec.eval(t, eval_t0);
            let point = TracePoint {
                round: t,
                wall_secs: sw.elapsed_secs(),
                virt_secs: vtime,
                gap,
                primal,
                dual,
                updates: total_updates,
            };
            trace.push(point.clone());
            if obs.on_eval(&EvalEvent { point }).is_break() {
                stop = true;
            }
            if gap <= cfg.gap_threshold {
                stop = true;
            }
        }

        if stop {
            // Shut down contributors, everything still queued in P, and
            // every message still sitting in the virtual queue (their
            // workers are all blocked on our reply).
            for &w in &picked {
                let f = Frame::Shutdown { vtime, round: t };
                last_reply[w] = Some(f.clone());
                let _ = link.send(w, f);
            }
            for w in 0..k {
                if pending[w].take().is_some() {
                    let f = Frame::Shutdown { vtime, round: t };
                    last_reply[w] = Some(f.clone());
                    let _ = link.send(w, f);
                }
            }
            while let Some(Reverse(arr)) = pq.pop() {
                let w = arr.msg.worker;
                let f = Frame::Shutdown { vtime, round: t };
                last_reply[w] = Some(f.clone());
                let _ = link.send(w, f);
            }
            arrival_order.clear();
            break;
        }
        // ---- broadcast merged v to contributors ----
        for &w in &picked {
            let reply = Frame::Merged(MasterReply {
                v: v.clone(),
                arrival_vtime: vtime + cfg.reply_latency,
                global_round: t,
                terminate: false,
            });
            last_reply[w] = Some(reply.clone());
            let _ = link.send(w, reply);
            computing[w] = true;
            computing_count += 1;
        }
    }

    // Shutdown drain: shut down any still-in-flight workers and
    // collect a Final report from every worker still considered live.
    // Declared-dead workers owe us nothing (their `finals` slot stays
    // `None` — expected degradation, not an error).
    if !disconnected {
        for w in 0..k {
            if pending[w].take().is_some() {
                let f = Frame::Shutdown { vtime, round: t };
                last_reply[w] = Some(f.clone());
                let _ = link.send(w, f);
            }
        }
        while let Some(Reverse(arr)) = pq.pop() {
            let w = arr.msg.worker;
            let f = Frame::Shutdown { vtime, round: t };
            last_reply[w] = Some(f.clone());
            let _ = link.send(w, f);
        }
        let need = |finals: &[Option<WorkerFinal>], live: &[bool]| {
            live.iter().zip(finals).filter(|(l, f)| **l && f.is_none()).count()
        };
        while need(&finals, &live) > 0 {
            let got = match tick {
                Some(d) => link.recv_timeout(d),
                None => link.recv().map(Some),
            };
            match got {
                Ok(Some((peer, Frame::Update(_)))) => {
                    // A straggler that never saw the Shutdown (or a
                    // stop-and-wait retransmit of its last update).
                    let f = Frame::Shutdown { vtime, round: t };
                    if peer < k {
                        last_reply[peer] = Some(f.clone());
                    }
                    let _ = link.send(peer, f);
                }
                Ok(Some((peer, Frame::Rejoin(info)))) => {
                    anyhow::ensure!(
                        info.worker_id == peer && peer < k,
                        "rejoin from peer {peer} claims worker id {}",
                        info.worker_id
                    );
                    // Too late to rejoin the barrier — tell it to wrap
                    // up (it will answer with its Final).
                    faults.per_peer[peer].rejoins += 1;
                    rec.fault(ObsFault::Rejoin, peer, t, "rejoin during shutdown drain");
                    let f = Frame::Shutdown { vtime, round: t };
                    last_reply[peer] = Some(f.clone());
                    let _ = link.send(peer, f);
                }
                Ok(Some((peer, Frame::Nack { .. }))) if peer < k => {
                    faults.per_peer[peer].retransmits += 1;
                    rec.fault(ObsFault::Retransmit, peer, t, "nack during shutdown drain");
                    if let Some(reply) = last_reply[peer].clone() {
                        let _ = link.send(peer, reply);
                    }
                }
                Ok(Some((peer, Frame::Final(fin)))) => {
                    anyhow::ensure!(
                        fin.worker_id == peer && peer < k,
                        "final report from peer {peer} claims worker id {}",
                        fin.worker_id
                    );
                    strikes[peer] = 0;
                    finals[peer] = Some(fin);
                }
                Ok(Some((peer, frame))) => {
                    anyhow::bail!(
                        "unexpected {} frame from worker {peer} during shutdown",
                        frame.kind_name()
                    );
                }
                Ok(None) => {
                    for w in 0..k {
                        if live[w] && finals[w].is_none() {
                            strikes[w] += 1;
                            faults.per_peer[w].stalls += 1;
                            rec.fault(ObsFault::Stall, w, t, "silent during shutdown drain");
                        }
                    }
                }
                Err(TransportError::PeerSilent { peer, .. }) if peer < k => {
                    if live[peer] && finals[peer].is_none() {
                        strikes[peer] += 1;
                        faults.per_peer[peer].stalls += 1;
                        rec.fault(ObsFault::Stall, peer, t, "silent during shutdown drain");
                    }
                }
                Err(TransportError::PeerGone { peer, detail }) if peer < k => {
                    // Closing after the Final is a normal exit; before
                    // it, strike (it may redial) unless suspicion is
                    // off — then nothing would ever terminate the
                    // drain, so fail like the pre-fault-tolerance code.
                    if live[peer] && finals[peer].is_none() {
                        anyhow::ensure!(
                            cfg.suspicion_timeouts > 0,
                            "worker {peer} vanished during shutdown drain \
                             before its final report: {detail}"
                        );
                        strikes[peer] += 1;
                        faults.per_peer[peer].stalls += 1;
                        rec.fault(ObsFault::Stall, peer, t, "connection lost during drain");
                    }
                }
                Err(TransportError::Wire { peer, .. }) if peer < k => {
                    faults.per_peer[peer].retransmits += 1;
                    rec.fault(ObsFault::Retransmit, peer, t, "corrupt frame during drain");
                    let _ = link.send(peer, Frame::Nack { round: t });
                }
                Err(TransportError::Closed) => break,
                Err(e) => {
                    return Err(anyhow::Error::new(e).context("draining worker final reports"));
                }
            }

            if cfg.suspicion_timeouts > 0 {
                for w in 0..k {
                    if live[w] && finals[w].is_none() && strikes[w] >= cfg.suspicion_timeouts {
                        live[w] = false;
                        k_live -= 1;
                        strikes[w] = 0;
                        link.disconnect(w);
                        faults.per_peer[w].declared_dead += 1;
                        faults.log(
                            vtime,
                            t,
                            w,
                            format!(
                                "declared dead during shutdown drain (no final \
                                 report); k_live now {k_live}"
                            ),
                        );
                        rec.fault(ObsFault::DeclaredDead, w, t, "no final report");
                    }
                }
            }
        }
    }

    faults.k_live = k_live;
    Ok(MasterOutcome { v, trace, events, rounds: t, vtime, finals, faults })
}
