//! Sequential stochastic dual coordinate ascent — the paper's
//! *Baseline* (an implementation of DCA, Hsieh et al. 2008).
//!
//! One "round" = `H` coordinate updates (Figure 3 top row counts one
//! round of Baseline as `H` local updates), after which the caller may
//! evaluate objectives. The dual objective is non-decreasing under
//! exact steps — a property test relies on this.

use crate::data::Dataset;
use crate::loss::Loss;
use crate::sim::UpdateCosts;
use crate::solver::kernels::{self, LossKernel};
use crate::solver::{coordinate_epsilon, StepParams};
use crate::util::Rng;

/// Sequential solver state.
pub struct Sdca<'d> {
    pub data: &'d Dataset,
    pub alpha: Vec<f64>,
    /// Dense `v = (1/λn) X α`, maintained incrementally.
    pub v: Vec<f64>,
    norms: Vec<f64>,
    params: StepParams,
    rng: Rng,
    /// Cumulative coordinate updates applied.
    pub updates: u64,
    /// Cumulative virtual compute seconds.
    pub virt_secs: f64,
    costs: UpdateCosts,
    /// Running `Σ_i dual_value(α_i, y_i)`, maintained O(1) per step
    /// when enabled ([`Self::enable_dual_tracking`]) so evaluation
    /// needs no O(n) dual rescan.
    dual_cur: Option<f64>,
}

impl<'d> Sdca<'d> {
    pub fn new(
        data: &'d Dataset,
        lambda: f64,
        rng: Rng,
        cost_model: &crate::sim::CostModel,
    ) -> Self {
        // The unchecked step kernels rely on the CSR invariant
        // (feature indices < d = v.len()). `CsrMatrix` fields are pub,
        // so enforce it here — once per solver, O(nnz) like the norm
        // precompute below — instead of trusting the caller (an invalid
        // matrix would otherwise be UB, not a panic, in release).
        data.x.validate().expect("invalid CSR matrix");
        let params = StepParams { lambda, n: data.n(), sigma: 1.0 };
        Self {
            alpha: vec![0.0; data.n()],
            v: vec![0.0; data.d()],
            norms: data.x.row_norms_sq(),
            params,
            rng,
            updates: 0,
            virt_secs: 0.0,
            costs: UpdateCosts::precompute(data, cost_model),
            dual_cur: None,
            data,
        }
    }

    /// Turn on incremental dual tracking (initialized by an exact
    /// accumulation over the current α).
    pub fn enable_dual_tracking(&mut self, loss: &dyn Loss) {
        self.dual_cur = Some(0.0);
        self.resync_dual(loss);
    }

    /// Exactly re-accumulate the tracked dual sum from α, left to
    /// right — cancels incremental rounding drift
    /// ([`crate::solver::local::DUAL_RESYNC_EVERY`] cadence).
    pub fn resync_dual(&mut self, loss: &dyn Loss) {
        let mut s = 0.0;
        for (i, &a) in self.alpha.iter().enumerate() {
            s += loss.dual_value(a, self.data.y[i]);
        }
        self.dual_cur = Some(s);
    }

    /// The tracked `Σ_i dual_value(α_i, y_i)`. Panics if tracking was
    /// never enabled.
    pub fn dual_sum(&self) -> f64 {
        self.dual_cur.expect("dual tracking not enabled")
    }

    /// Apply one exact coordinate update at a random index. Generic
    /// over the loss: monomorphized callers pay no virtual call, and
    /// `&dyn Loss` still works unchanged.
    #[inline]
    pub fn step<L: Loss + ?Sized>(&mut self, loss: &L) {
        let i = self.rng.next_below(self.data.n());
        self.step_at(loss, i);
    }

    /// Apply one exact coordinate update at index `i`.
    #[inline]
    pub fn step_at<L: Loss + ?Sized>(&mut self, loss: &L, i: usize) {
        let row = self.data.x.row(i);
        // SAFETY: CSR validity (indices < d, pinned in `new`) and
        // `v.len() == d` by construction.
        let m = unsafe { kernels::sparse_dot_dense_unchecked(row.indices, row.values, &self.v) };
        let eps =
            coordinate_epsilon(loss, self.alpha[i], self.data.y[i], m, self.norms[i], &self.params);
        if eps != 0.0 {
            let a_old = self.alpha[i];
            self.alpha[i] += eps;
            if let Some(dual) = self.dual_cur.as_mut() {
                let y = self.data.y[i];
                *dual += loss.dual_value(self.alpha[i], y) - loss.dual_value(a_old, y);
            }
            let scale = eps * self.params.v_scale();
            // SAFETY: same bounds argument as the dot above.
            unsafe {
                kernels::sparse_axpy_dense_unchecked(scale, row.indices, row.values, &mut self.v)
            };
        }
        self.updates += 1;
        self.virt_secs += self.costs.cost(i);
    }

    /// Run `h` updates (one Baseline "round"). The loss is downcast
    /// once here so the whole round runs monomorphized
    /// ([`LossKernel`]; ~one virtual call per round instead of per
    /// update).
    pub fn run_round(&mut self, loss: &dyn Loss, h: usize) {
        match LossKernel::of(loss) {
            LossKernel::Hinge(l) => self.run_round_mono(&l, h),
            LossKernel::SquaredHinge(l) => self.run_round_mono(&l, h),
            LossKernel::Logistic(l) => self.run_round_mono(&l, h),
            LossKernel::Dyn(l) => self.run_round_mono(l, h),
        }
    }

    fn run_round_mono<L: Loss + ?Sized>(&mut self, loss: &L, h: usize) {
        for _ in 0..h {
            self.step(loss);
        }
    }

    /// Current objectives measured against the maintained `v`.
    pub fn objectives(&self, loss: &dyn Loss) -> crate::metrics::Objectives {
        crate::metrics::objectives(self.data, loss, &self.alpha, &self.v, self.params.lambda)
    }

    /// Objectives using the tracked dual: one primal pass, zero dual
    /// pass. Requires [`Self::enable_dual_tracking`].
    pub fn objectives_tracked(&self, loss: &dyn Loss) -> crate::metrics::Objectives {
        let lambda = self.params.lambda;
        let primal = crate::metrics::primal_objective(self.data, loss, &self.v, lambda);
        let dual = self.dual_sum() / self.params.n as f64
            - 0.5 * lambda * crate::util::norm_sq(&self.v);
        crate::metrics::Objectives { primal, dual, gap: primal - dual }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::Preset;
    use crate::loss::{Hinge, Logistic, SquaredHinge};
    use crate::metrics::exact_v;
    use crate::sim::CostModel;

    fn solver(data: &Dataset, lambda: f64) -> Sdca<'_> {
        Sdca::new(data, lambda, Rng::new(123), &CostModel::default())
    }

    #[test]
    fn dual_objective_never_decreases() {
        let ds = Preset::Tiny.generate(&mut Rng::new(1));
        let mut s = solver(&ds, 1e-2);
        let loss = Hinge;
        let mut prev = s.objectives(&loss).dual;
        for _ in 0..20 {
            s.run_round(&loss, 50);
            let d = s.objectives(&loss).dual;
            assert!(d >= prev - 1e-12, "dual decreased {prev} -> {d}");
            prev = d;
        }
    }

    #[test]
    fn v_stays_consistent_with_alpha() {
        let ds = Preset::Tiny.generate(&mut Rng::new(2));
        let mut s = solver(&ds, 1e-2);
        s.run_round(&Hinge, 500);
        let v_exact = exact_v(&ds, &s.alpha, 1e-2);
        for (a, b) in s.v.iter().zip(v_exact.iter()) {
            assert!((a - b).abs() < 1e-9, "drift {a} vs {b}");
        }
    }

    #[test]
    fn converges_on_tiny() {
        let ds = Preset::Tiny.generate(&mut Rng::new(3));
        let mut s = solver(&ds, 1e-2);
        let loss = Hinge;
        for _ in 0..100 {
            s.run_round(&loss, 200);
            if s.objectives(&loss).gap < 1e-6 {
                return;
            }
        }
        panic!("did not reach gap 1e-6: {}", s.objectives(&loss).gap);
    }

    #[test]
    fn converges_smooth_losses() {
        let ds = Preset::Tiny.generate(&mut Rng::new(4));
        for loss in [&SquaredHinge as &dyn Loss, &Logistic::default() as &dyn Loss] {
            let mut s = solver(&ds, 1e-2);
            for _ in 0..150 {
                s.run_round(loss, 200);
                if s.objectives(loss).gap < 1e-5 {
                    break;
                }
            }
            let gap = s.objectives(loss).gap;
            assert!(gap < 1e-5, "{}: gap {gap}", loss.name());
        }
    }

    #[test]
    fn tracked_dual_matches_full_recompute() {
        let ds = Preset::Tiny.generate(&mut Rng::new(7));
        let mut s = solver(&ds, 1e-2);
        let loss = Hinge;
        s.enable_dual_tracking(&loss);
        for _ in 0..10 {
            s.run_round(&loss, 200);
            let tracked = s.objectives_tracked(&loss);
            let full = s.objectives(&loss);
            assert!(
                (tracked.dual - full.dual).abs() <= 1e-9 * (1.0 + full.dual.abs()),
                "tracked dual {} drifted from {}",
                tracked.dual,
                full.dual
            );
            assert_eq!(tracked.primal.to_bits(), full.primal.to_bits());
        }
        // Post-resync the tracked sum equals the left-to-right exact
        // accumulation to the last bit.
        s.resync_dual(&loss);
        let mut exact = 0.0;
        for (i, &a) in s.alpha.iter().enumerate() {
            exact += loss.dual_value(a, ds.y[i]);
        }
        assert_eq!(s.dual_sum().to_bits(), exact.to_bits());
    }

    #[test]
    fn counters_advance() {
        let ds = Preset::Tiny.generate(&mut Rng::new(5));
        let mut s = solver(&ds, 1e-2);
        s.run_round(&Hinge, 10);
        assert_eq!(s.updates, 10);
        assert!(s.virt_secs > 0.0);
    }

    #[test]
    fn alpha_stays_feasible() {
        let ds = Preset::Tiny.generate(&mut Rng::new(6));
        let mut s = solver(&ds, 1e-3);
        let loss = Hinge;
        s.run_round(&loss, 1000);
        for (i, &a) in s.alpha.iter().enumerate() {
            assert!(loss.feasible(a, ds.y[i]), "α[{i}]={a} infeasible");
        }
    }
}
