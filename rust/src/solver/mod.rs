//! Dual coordinate ascent solvers.
//!
//! * [`sdca`] — sequential stochastic DCA (Hsieh et al. 2008), the
//!   paper's *Baseline*.
//! * [`local`] — the multi-core asynchronous local subproblem solver
//!   each worker node runs (Algorithm 1's inner loop; PassCoDe-style
//!   lock-free atomics).
//! * [`block`] — block (mini-batch locally-sequential) dual step, the
//!   Rust oracle for the L1/L2 XLA path (see DESIGN.md
//!   §Hardware-Adaptation).
//! * [`kernels`] — the monomorphized hot-path kernels and the
//!   dirty-coordinate tracker behind the sparse Δv exchange (§Perf).

pub mod block;
pub mod kernels;
pub mod local;
pub mod sdca;
#[cfg(feature = "xla-runtime")]
pub mod xla_dense;

use crate::loss::Loss;

/// Parameters of the per-coordinate subproblem step shared by all
/// solvers.
///
/// The single-variable maximization (paper Eq. 6) is
/// `argmax_ε  −φ*(−(α_i+ε)) − m·ε − (q/2)ε²` with margin `m = x_iᵀu`
/// and curvature `q = σ·‖x_i‖² / (λn)`; `σ = 1` recovers the exact
/// (unperturbed) dual used by the sequential baseline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepParams {
    pub lambda: f64,
    /// Global number of data points `n` (the dual is scaled by 1/n
    /// globally, even for local subproblems — paper Eq. 4).
    pub n: usize,
    /// Subproblem scaling σ (Eq. 5; `ν·S` for Hybrid-DCA).
    pub sigma: f64,
}

impl StepParams {
    /// Curvature `q_i` for a data point with squared norm `‖x_i‖²`.
    #[inline(always)]
    pub fn q(&self, norm_sq: f64) -> f64 {
        self.sigma * norm_sq / (self.lambda * self.n as f64)
    }

    /// Scale factor applied to `ε·x_i` when updating `v = (1/λn)Xα`.
    #[inline(always)]
    pub fn v_scale(&self) -> f64 {
        1.0 / (self.lambda * self.n as f64)
    }
}

/// One exact coordinate step against a dense `v`; returns `ε` (the
/// dual increment) and applies nothing. Shared helper for the
/// sequential paths. Generic over the loss so monomorphized callers
/// (see [`kernels::LossKernel`]) pay no virtual call; `&dyn Loss`
/// still works unchanged.
#[inline]
pub fn coordinate_epsilon<L: Loss + ?Sized>(
    loss: &L,
    alpha_i: f64,
    y_i: f64,
    margin: f64,
    norm_sq: f64,
    params: &StepParams,
) -> f64 {
    if norm_sq == 0.0 {
        return 0.0; // empty row: no step possible
    }
    let q = params.q(norm_sq);
    loss.coordinate_step(alpha_i, y_i, margin, q) - alpha_i
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::Hinge;

    #[test]
    fn q_and_scale() {
        let p = StepParams { lambda: 0.1, n: 100, sigma: 2.0 };
        assert!((p.q(1.0) - 2.0 / 10.0).abs() < 1e-15);
        assert!((p.v_scale() - 0.1).abs() < 1e-15);
    }

    #[test]
    fn epsilon_zero_for_empty_row() {
        let p = StepParams { lambda: 0.1, n: 10, sigma: 1.0 };
        assert_eq!(coordinate_epsilon(&Hinge, 0.0, 1.0, 0.0, 0.0, &p), 0.0);
    }

    #[test]
    fn epsilon_moves_toward_bound() {
        let p = StepParams { lambda: 0.1, n: 10, sigma: 1.0 };
        // margin 0 ⇒ hinge step to the cap a=1.
        let eps = coordinate_epsilon(&Hinge, 0.0, 1.0, 0.0, 1.0, &p);
        assert!(eps > 0.0 && eps <= 1.0);
    }
}
