//! The hot-path kernel layer (§Perf): monomorphized coordinate-update
//! loops, unchecked sparse linear algebra for the sequential solver,
//! and the dirty-coordinate tracker behind the sparse Δv exchange.
//!
//! Three costs dominated the old inner loop, each paid once per
//! coordinate update or once per nonzero touched:
//!
//! 1. a virtual `dyn Loss` call per update ([`LossKernel`] removes it —
//!    the loss is downcast once per round and the loop monomorphizes);
//! 2. a bounds check per nonzero on the `x_iᵀv` read and the CAS-add
//!    write (the `*_unchecked` kernels here and on
//!    [`AtomicF64Vec`](crate::util::AtomicF64Vec) remove them, justified
//!    by one bounds proof per round);
//! 3. an O(d) snapshot + diff per round to form `Δv` ([`DirtySet`]
//!    records the touched support instead, so the worker reads only the
//!    coordinates that changed).
//!
//! Every fast path is bitwise-faithful to the scalar/checked reference
//! it replaces (same operations, same order) — `tests/prop_kernels.rs`
//! pins that, and R = 1 runs stay exactly deterministic.

use crate::data::Dataset;
use crate::loss::{Hinge, Logistic, Loss, SquaredHinge};
use crate::sim::UpdateCosts;
use crate::solver::local::CoreShard;
use crate::solver::StepParams;
use crate::util::AtomicF64Vec;

/// One-time loss dispatch at round entry: downcast a `&dyn Loss` to its
/// concrete builtin type so the update loop runs fully static, falling
/// back to virtual dispatch for plugin losses.
pub enum LossKernel<'a> {
    Hinge(Hinge),
    SquaredHinge(SquaredHinge),
    Logistic(Logistic),
    Dyn(&'a dyn Loss),
}

impl<'a> LossKernel<'a> {
    pub fn of(loss: &'a dyn Loss) -> Self {
        let any = loss.as_any();
        if let Some(l) = any.downcast_ref::<Hinge>() {
            LossKernel::Hinge(*l)
        } else if let Some(l) = any.downcast_ref::<SquaredHinge>() {
            LossKernel::SquaredHinge(*l)
        } else if let Some(l) = any.downcast_ref::<Logistic>() {
            LossKernel::Logistic(*l)
        } else {
            LossKernel::Dyn(loss)
        }
    }

    /// True when the fallback (virtual-dispatch) arm was selected.
    pub fn is_dyn(&self) -> bool {
        matches!(self, LossKernel::Dyn(_))
    }
}

/// Per-core dirty-coordinate tracker: a fixed-size bitset over the
/// feature dimension recording which `v` coordinates a core touched
/// during the round — the support of its Δv contribution.
#[derive(Debug, Clone)]
pub struct DirtySet {
    words: Vec<u64>,
    dim: usize,
}

impl DirtySet {
    pub fn new(dim: usize) -> Self {
        Self { words: vec![0u64; dim.div_ceil(64)], dim }
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Mark one coordinate (checked; tests and cold paths).
    #[inline]
    pub fn mark(&mut self, j: usize) {
        assert!(j < self.dim, "coordinate {j} out of range (dim {})", self.dim);
        self.words[j >> 6] |= 1u64 << (j & 63);
    }

    /// Mark every index of a sparse row — the Δv support of one update.
    ///
    /// # Safety
    /// Every index in `idx` must be `< self.dim()`.
    #[inline]
    pub unsafe fn mark_row_unchecked(&mut self, idx: &[u32]) {
        for &j in idx {
            let j = j as usize;
            debug_assert!(j < self.dim);
            *self.words.get_unchecked_mut(j >> 6) |= 1u64 << (j & 63);
        }
    }

    /// OR another tracker of the same dimension into this one.
    pub fn union(&mut self, other: &DirtySet) {
        assert_eq!(self.dim, other.dim, "dirty-set dimension mismatch");
        for (a, &b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }

    /// Number of marked coordinates.
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Marked coordinates in ascending order.
    pub fn indices(&self) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.count());
        for (wi, &word) in self.words.iter().enumerate() {
            let mut w = word;
            while w != 0 {
                let bit = w.trailing_zeros() as usize;
                out.push(((wi << 6) | bit) as u32);
                w &= w - 1;
            }
        }
        out
    }

    pub fn clear(&mut self) {
        for w in self.words.iter_mut() {
            *w = 0;
        }
    }
}

/// Outcome of one core's round, per counter class (ISSUE 4 satellite:
/// skipped draws must not inflate updates/s).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CoreOut {
    /// Virtual compute seconds accumulated by this core.
    pub secs: f64,
    /// Coordinate updates actually applied (the subproblem was solved;
    /// the step may still be 0 at an optimum).
    pub applied: u64,
    /// Draws skipped because the sampled row is empty (`‖x_i‖² = 0`):
    /// no subproblem exists, no work was done.
    pub skipped: u64,
}

/// One core's `h` stochastic updates against the node's shared atomic
/// `v` — Algorithm 1 lines 4–9, monomorphized over the loss.
///
/// The per-element bounds checks of the old loop are replaced by one
/// proof per round (the asserts below), after which every row and
/// feature access is in range by the CSR/partition invariants.
#[allow(clippy::too_many_arguments)]
pub fn run_core<L: Loss + ?Sized>(
    shard: &mut CoreShard,
    data: &Dataset,
    loss: &L,
    norms: &[f64],
    costs: &UpdateCosts,
    v: &AtomicF64Vec,
    params: &StepParams,
    wild: bool,
    h: usize,
) -> CoreOut {
    let mut out = CoreOut { secs: 0.0, applied: 0, skipped: 0 };
    let len = shard.idx.len();
    if len == 0 {
        return out;
    }
    // One bounds proof for the whole round: every feature index is
    // < d ≤ v.len() (CSR invariant), every shard row id is < n
    // (partition invariant), and the lookup tables cover all rows.
    assert!(data.x.dim() <= v.len(), "v shorter than the feature dimension");
    assert!(shard.idx.iter().all(|&i| i < data.n()), "shard row id out of range");
    assert_eq!(norms.len(), data.n(), "norms table length");
    assert_eq!(data.y.len(), data.n(), "label table length");
    if let Some(dirty) = shard.dirty.as_ref() {
        assert!(data.x.dim() <= dirty.dim(), "dirty set shorter than the feature dimension");
    }
    // In-round updates enter the live v at σ·(1/λn): the subproblem
    // Q_k^σ penalizes the accumulated δ through (λσ/2)‖(1/λn)Xδ‖², so
    // its margin gradient is x_iᵀ(v_frozen + (σ/λn)Xδ). (The paper's
    // Algorithm 1 line 9 writes the unscaled update; solving the stated
    // subproblem — as Ma et al.'s LocalSDCA does — requires the σ
    // factor, and without it the ν-weighted merge oscillates. Δv is
    // un-scaled back to (1/λn)Xδ before sending; see the worker.)
    let v_scale = params.v_scale() * params.sigma;
    // Incremental dual tracking (§Perf, ISSUE 6): carry the shard's
    // running Σ dual_value(α_i, y_i) through the round in a register,
    // updated O(1) per applied step, so the duality gap needs no
    // O(n_k) dual rescan at eval time. `None` keeps the branch out of
    // baseline-comparable runs.
    let track_dual = shard.dual_cur.is_some();
    let mut dual_acc = shard.dual_cur.unwrap_or(0.0);
    for _ in 0..h {
        let j = shard.rng.next_below(len);
        // SAFETY: j < len, and the round-entry asserts above prove
        // every access below is in range.
        let i = unsafe { *shard.idx.get_unchecked(j) };
        let row = unsafe { data.x.row_unchecked(i) };
        let ns = unsafe { *norms.get_unchecked(i) };
        if ns == 0.0 {
            out.skipped += 1;
            continue;
        }
        // SAFETY: same round-entry bounds proof — row indices < d =
        // v.len(), i < data.n() = y.len(), j < len = alpha_cur.len().
        let m = unsafe { v.sparse_dot_unchecked(row.indices, row.values) };
        let y = unsafe { *data.y.get_unchecked(i) };
        let q = params.q(ns);
        let a_old = unsafe { *shard.alpha_cur.get_unchecked(j) };
        let a_new = loss.coordinate_step(a_old, y, m, q);
        let eps = a_new - a_old;
        if eps != 0.0 {
            shard.alpha_cur[j] = a_new;
            if track_dual {
                dual_acc += loss.dual_value(a_new, y) - loss.dual_value(a_old, y);
            }
            // SAFETY: feature indices < d ≤ v.len() and ≤ dirty.dim().
            unsafe {
                if wild {
                    v.sparse_axpy_wild_unchecked(eps * v_scale, row.indices, row.values);
                } else {
                    v.sparse_axpy_unchecked(eps * v_scale, row.indices, row.values);
                }
                if let Some(dirty) = shard.dirty.as_mut() {
                    dirty.mark_row_unchecked(row.indices);
                }
            }
        }
        out.applied += 1;
        out.secs += costs.cost(i);
    }
    if track_dual {
        shard.dual_cur = Some(dual_acc);
    }
    out
}

/// Unchecked, 4-way-unrolled sparse·dense dot — the sequential solver's
/// `x_iᵀv` read. Bitwise-identical to
/// [`SparseRow::dot_dense`](crate::data::csr::SparseRow::dot_dense)
/// (single accumulator, same add order).
///
/// # Safety
/// Every index in `idx` must be `< v.len()`, and
/// `idx.len() == vals.len()` must hold.
#[inline]
pub unsafe fn sparse_dot_dense_unchecked(idx: &[u32], vals: &[f64], v: &[f64]) -> f64 {
    debug_assert_eq!(idx.len(), vals.len());
    debug_assert!(idx.iter().all(|&j| (j as usize) < v.len()));
    let n = idx.len();
    let mut acc = 0.0;
    let mut k = 0;
    while k + 4 <= n {
        let v0 = *v.get_unchecked(*idx.get_unchecked(k) as usize);
        let v1 = *v.get_unchecked(*idx.get_unchecked(k + 1) as usize);
        let v2 = *v.get_unchecked(*idx.get_unchecked(k + 2) as usize);
        let v3 = *v.get_unchecked(*idx.get_unchecked(k + 3) as usize);
        acc += *vals.get_unchecked(k) * v0;
        acc += *vals.get_unchecked(k + 1) * v1;
        acc += *vals.get_unchecked(k + 2) * v2;
        acc += *vals.get_unchecked(k + 3) * v3;
        k += 4;
    }
    while k < n {
        acc += *vals.get_unchecked(k) * *v.get_unchecked(*idx.get_unchecked(k) as usize);
        k += 1;
    }
    acc
}

/// Unchecked, 4-way-unrolled sparse axpy into a dense vector — the
/// sequential solver's `v += (ε/λn)·x_i` write.
///
/// # Safety
/// Every index in `idx` must be `< v.len()`, and
/// `idx.len() == vals.len()` must hold.
#[inline]
pub unsafe fn sparse_axpy_dense_unchecked(a: f64, idx: &[u32], vals: &[f64], v: &mut [f64]) {
    debug_assert_eq!(idx.len(), vals.len());
    debug_assert!(idx.iter().all(|&j| (j as usize) < v.len()));
    let n = idx.len();
    let mut k = 0;
    while k + 4 <= n {
        *v.get_unchecked_mut(*idx.get_unchecked(k) as usize) += a * *vals.get_unchecked(k);
        *v.get_unchecked_mut(*idx.get_unchecked(k + 1) as usize) += a * *vals.get_unchecked(k + 1);
        *v.get_unchecked_mut(*idx.get_unchecked(k + 2) as usize) += a * *vals.get_unchecked(k + 2);
        *v.get_unchecked_mut(*idx.get_unchecked(k + 3) as usize) += a * *vals.get_unchecked(k + 3);
        k += 4;
    }
    while k < n {
        *v.get_unchecked_mut(*idx.get_unchecked(k) as usize) += a * *vals.get_unchecked(k);
        k += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn loss_kernel_downcasts_builtins() {
        assert!(matches!(LossKernel::of(&Hinge), LossKernel::Hinge(_)));
        assert!(matches!(LossKernel::of(&SquaredHinge), LossKernel::SquaredHinge(_)));
        assert!(matches!(LossKernel::of(&Logistic::default()), LossKernel::Logistic(_)));
        assert!(!LossKernel::of(&Hinge).is_dyn());
    }

    #[test]
    fn dirty_set_marks_and_collects_sorted() {
        let mut d = DirtySet::new(130);
        for j in [129usize, 0, 64, 63, 0, 65] {
            d.mark(j);
        }
        assert_eq!(d.count(), 5);
        assert_eq!(d.indices(), vec![0, 63, 64, 65, 129]);
        d.clear();
        assert_eq!(d.count(), 0);
        assert!(d.indices().is_empty());
    }

    #[test]
    fn dirty_set_union() {
        let mut a = DirtySet::new(70);
        let mut b = DirtySet::new(70);
        a.mark(1);
        b.mark(69);
        b.mark(1);
        a.union(&b);
        assert_eq!(a.indices(), vec![1, 69]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn dirty_set_mark_bounds() {
        DirtySet::new(10).mark(10);
    }

    #[test]
    fn dense_kernels_match_reference() {
        let mut rng = Rng::new(5);
        for nnz in [0usize, 1, 3, 4, 5, 8, 11, 64] {
            let dim = 100;
            let v: Vec<f64> = (0..dim).map(|_| rng.next_gaussian()).collect();
            let mut idx: Vec<u32> = Rng::new(nnz as u64 + 9)
                .sample_indices(dim, nnz)
                .into_iter()
                .map(|j| j as u32)
                .collect();
            idx.sort_unstable();
            let vals: Vec<f64> = idx.iter().map(|_| rng.next_gaussian()).collect();
            let a = rng.next_gaussian();

            let row = crate::data::csr::SparseRow { indices: &idx, values: &vals };
            let dot_ref = row.dot_dense(&v);
            // SAFETY: `idx` was sampled from 0..dim = v.len(), and
            // `vals` was built element-for-element from `idx`.
            let dot_fast = unsafe { sparse_dot_dense_unchecked(&idx, &vals, &v) };
            assert_eq!(dot_ref.to_bits(), dot_fast.to_bits(), "dot nnz={nnz}");

            let mut v_ref = v.clone();
            let mut v_fast = v.clone();
            for (&j, &x) in idx.iter().zip(&vals) {
                v_ref[j as usize] += a * x;
            }
            // SAFETY: same `idx`/`vals` bounds proof as the dot above.
            unsafe { sparse_axpy_dense_unchecked(a, &idx, &vals, &mut v_fast) };
            assert_eq!(v_ref, v_fast, "axpy nnz={nnz}");
        }
    }
}
