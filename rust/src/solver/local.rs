//! The asynchronous multi-core local subproblem solver — Algorithm 1's
//! inner loop (lines 4–9), PassCoDe-style (Hsieh et al. 2015).
//!
//! A worker node owns a data partition `I_k`, logically divided into
//! `R` disjoint shards `I_{k,r}`, one per core. During a round each
//! core performs `H` stochastic coordinate updates on its shard:
//!
//! 1. pick a random `i ∈ I_{k,r}`;
//! 2. read the margin `m = x_iᵀ v` from the node's **shared** `v`
//!    (lock-free relaxed atomic loads — reads may be staler than γ
//!    updates, Assumption 1);
//! 3. solve the 1-D perturbed subproblem (Eq. 6) for the new `α_i`
//!    (cores own their shard's α exclusively, so no synchronization);
//! 4. apply `v ← v + (1/λn) ε x_i` with lock-free CAS adds
//!    (or racy "wild" stores when configured).
//!
//! At the end of the round the worker computes `Δv = v − v_old`, sends
//! it to the master, receives the merged `v`, and commits
//! `α ← α + ν·δ` ([`LocalSolver::commit`]).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::data::Dataset;
use crate::loss::Loss;
use crate::sim::UpdateCosts;
use crate::solver::StepParams;
use crate::util::{AtomicF64Vec, Rng};

/// Per-core shard: global indices plus the core-owned dual variables.
#[derive(Debug)]
pub struct CoreShard {
    /// Global row ids owned by this core (I_{k,r}).
    pub idx: Vec<usize>,
    /// α at the start of the current round (committed values).
    pub alpha_start: Vec<f64>,
    /// Live α (= α_start + δ accumulated this round).
    pub alpha_cur: Vec<f64>,
    /// Independent RNG stream for this core.
    pub rng: Rng,
}

impl CoreShard {
    fn new(idx: Vec<usize>, rng: Rng) -> Self {
        let n = idx.len();
        Self { idx, alpha_start: vec![0.0; n], alpha_cur: vec![0.0; n], rng }
    }
}

/// Statistics from one local round.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundStats {
    /// Coordinate updates applied (= R · H).
    pub updates: u64,
    /// Virtual compute seconds per core (caller takes the max for the
    /// node's round time — cores run in parallel on a real node).
    pub core_secs: Vec<f64>,
}

impl RoundStats {
    /// Node round time = slowest core.
    pub fn node_secs(&self) -> f64 {
        self.core_secs.iter().cloned().fold(0.0, f64::max)
    }
}

/// The per-node local solver state.
pub struct LocalSolver {
    pub shards: Vec<CoreShard>,
    /// The node's shared primal estimate `v` (atomic, lock-free).
    pub v: AtomicF64Vec,
    params: StepParams,
    wild: bool,
}

impl LocalSolver {
    /// Build from per-core index cells (the node's slice of a
    /// [`Partition`](crate::data::Partition)).
    pub fn new(
        cells: Vec<Vec<usize>>,
        dim: usize,
        params: StepParams,
        wild: bool,
        rng: &mut Rng,
    ) -> Self {
        let shards = cells.into_iter().map(|idx| CoreShard::new(idx, rng.fork())).collect();
        Self { shards, v: AtomicF64Vec::zeros(dim), params, wild }
    }

    pub fn r_cores(&self) -> usize {
        self.shards.len()
    }

    /// Update σ (used when ablations change σ between phases).
    pub fn set_sigma(&mut self, sigma: f64) {
        self.params.sigma = sigma;
    }

    /// Run one round: every core performs `h` asynchronous updates.
    /// Cores run as real OS threads when `R > 1` (exercising the atomic
    /// races), or inline when `R == 1`.
    pub fn run_round(
        &mut self,
        data: &Dataset,
        loss: &dyn Loss,
        norms: &[f64],
        costs: &UpdateCosts,
        h: usize,
    ) -> RoundStats {
        let params = self.params;
        // Perf (§Perf L3): with a single core-thread there are no
        // concurrent writers, so the racy load+store path is *exact*
        // and saves the CAS (lock cmpxchg) on every touched nonzero —
        // this is the hot path of Baseline, CoCoA+, and every R=1 node.
        let wild = self.wild || self.shards.len() == 1;
        let v = &self.v;
        let updates = AtomicU64::new(0);
        let mut core_secs = vec![0.0; self.shards.len()];
        if self.shards.len() == 1 {
            let secs = run_core(
                &mut self.shards[0],
                data,
                loss,
                norms,
                costs,
                v,
                &params,
                wild,
                h,
                &updates,
            );
            core_secs[0] = secs;
        } else {
            std::thread::scope(|scope| {
                let mut handles = Vec::new();
                for shard in self.shards.iter_mut() {
                    let updates = &updates;
                    handles.push(scope.spawn(move || {
                        run_core(shard, data, loss, norms, costs, v, &params, wild, h, updates)
                    }));
                }
                for (r, hnd) in handles.into_iter().enumerate() {
                    core_secs[r] = hnd.join().expect("core thread panicked");
                }
            });
        }
        RoundStats { updates: updates.load(Ordering::Relaxed), core_secs }
    }

    /// Commit the round: `α ← α_start + ν·δ` (Algorithm 1 line 12) and
    /// reset the round baseline.
    pub fn commit(&mut self, nu: f64) {
        for shard in self.shards.iter_mut() {
            for j in 0..shard.idx.len() {
                let delta = shard.alpha_cur[j] - shard.alpha_start[j];
                let committed = shard.alpha_start[j] + nu * delta;
                shard.alpha_start[j] = committed;
                shard.alpha_cur[j] = committed;
            }
        }
    }

    /// Scatter this node's committed α into a global dense vector.
    pub fn scatter_alpha(&self, global: &mut [f64]) {
        for shard in &self.shards {
            for (j, &i) in shard.idx.iter().enumerate() {
                global[i] = shard.alpha_start[j];
            }
        }
    }

    /// Total µ-partition size (n_k).
    pub fn n_local(&self) -> usize {
        self.shards.iter().map(|s| s.idx.len()).sum()
    }
}

/// One core's H updates. Returns virtual compute seconds.
#[allow(clippy::too_many_arguments)]
fn run_core(
    shard: &mut CoreShard,
    data: &Dataset,
    loss: &dyn Loss,
    norms: &[f64],
    costs: &UpdateCosts,
    v: &AtomicF64Vec,
    params: &StepParams,
    wild: bool,
    h: usize,
    updates: &AtomicU64,
) -> f64 {
    let mut secs = 0.0;
    let len = shard.idx.len();
    if len == 0 {
        return 0.0;
    }
    // In-round updates enter the live v at σ·(1/λn): the subproblem
    // Q_k^σ penalizes the accumulated δ through (λσ/2)‖(1/λn)Xδ‖², so
    // its margin gradient is x_iᵀ(v_frozen + (σ/λn)Xδ). (The paper's
    // Algorithm 1 line 9 writes the unscaled update; solving the stated
    // subproblem — as Ma et al.'s LocalSDCA does — requires the σ
    // factor, and without it the ν-weighted merge oscillates. Δv is
    // un-scaled back to (1/λn)Xδ before sending; see the worker.)
    let v_scale = params.v_scale() * params.sigma;
    for _ in 0..h {
        let j = shard.rng.next_below(len);
        // SAFETY: partition validation guarantees idx entries < n.
        let i = unsafe { *shard.idx.get_unchecked(j) };
        let row = unsafe { data.x.row_unchecked(i) };
        let ns = unsafe { *norms.get_unchecked(i) };
        if ns == 0.0 {
            continue;
        }
        let m = v.sparse_dot(row.indices, row.values);
        let y = unsafe { *data.y.get_unchecked(i) };
        let q = params.q(ns);
        let a_old = unsafe { *shard.alpha_cur.get_unchecked(j) };
        let a_new = loss.coordinate_step(a_old, y, m, q);
        let eps = a_new - a_old;
        if eps != 0.0 {
            shard.alpha_cur[j] = a_new;
            if wild {
                v.sparse_axpy_wild(eps * v_scale, row.indices, row.values);
            } else {
                v.sparse_axpy(eps * v_scale, row.indices, row.values);
            }
        }
        secs += costs.cost(i);
    }
    updates.fetch_add(h as u64, Ordering::Relaxed);
    secs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::Preset;
    use crate::loss::Hinge;
    use crate::metrics::{dual_objective, exact_v};
    use crate::sim::CostModel;

    fn setup(r: usize) -> (Dataset, LocalSolver, Vec<f64>, UpdateCosts) {
        let ds = Preset::Tiny.generate(&mut Rng::new(1));
        let n = ds.n();
        let mut rng = Rng::new(2);
        let part = crate::data::Partition::build(n, 1, r, crate::data::Strategy::Contiguous, &mut rng);
        let params = StepParams { lambda: 1e-2, n, sigma: 1.0 };
        let solver = LocalSolver::new(part.parts[0].clone(), ds.d(), params, false, &mut rng);
        let norms = ds.x.row_norms_sq();
        let costs = UpdateCosts::precompute(&ds, &CostModel::default());
        (ds, solver, norms, costs)
    }

    #[test]
    fn single_core_round_makes_progress() {
        let (ds, mut s, norms, costs) = setup(1);
        let stats = s.run_round(&ds, &Hinge, &norms, &costs, 500);
        assert_eq!(stats.updates, 500);
        s.commit(1.0);
        let mut alpha = vec![0.0; ds.n()];
        s.scatter_alpha(&mut alpha);
        let v = exact_v(&ds, &alpha, 1e-2);
        let d = dual_objective(&ds, &Hinge, &alpha, &v, 1e-2);
        assert!(d > 0.0, "dual did not improve: {d}");
    }

    #[test]
    fn multi_core_v_consistency_after_commit_nu1() {
        // With ν = 1 the committed α must reproduce the live v exactly
        // (atomic adds lose nothing).
        let (ds, mut s, norms, costs) = setup(4);
        for _ in 0..3 {
            s.run_round(&ds, &Hinge, &norms, &costs, 200);
            s.commit(1.0);
        }
        let mut alpha = vec![0.0; ds.n()];
        s.scatter_alpha(&mut alpha);
        let v_exact = exact_v(&ds, &alpha, 1e-2);
        let v_live = s.v.snapshot();
        for (a, b) in v_live.iter().zip(v_exact.iter()) {
            assert!((a - b).abs() < 1e-9, "v drift: {a} vs {b}");
        }
    }

    #[test]
    fn commit_scales_delta_by_nu() {
        let (ds, mut s, norms, costs) = setup(1);
        s.run_round(&ds, &Hinge, &norms, &costs, 100);
        // Capture live alphas before commit.
        let live: Vec<f64> = s.shards[0].alpha_cur.clone();
        s.commit(0.5);
        for (j, &committed) in s.shards[0].alpha_start.iter().enumerate() {
            let expected = 0.5 * live[j]; // started from 0
            assert!((committed - expected).abs() < 1e-15);
        }
    }

    #[test]
    fn node_secs_is_max_core() {
        let stats = RoundStats { updates: 10, core_secs: vec![1.0, 3.0, 2.0] };
        assert_eq!(stats.node_secs(), 3.0);
    }

    #[test]
    fn wild_mode_still_converges_roughly() {
        let ds = Preset::Tiny.generate(&mut Rng::new(3));
        let n = ds.n();
        let mut rng = Rng::new(4);
        let part =
            crate::data::Partition::build(n, 1, 4, crate::data::Strategy::Contiguous, &mut rng);
        let params = StepParams { lambda: 1e-2, n, sigma: 1.0 };
        let mut s = LocalSolver::new(part.parts[0].clone(), ds.d(), params, true, &mut rng);
        let norms = ds.x.row_norms_sq();
        let costs = UpdateCosts::precompute(&ds, &CostModel::default());
        for _ in 0..5 {
            s.run_round(&ds, &Hinge, &norms, &costs, 500);
            s.commit(1.0);
            // Resync live v from committed α (wild mode drifts).
            let mut alpha = vec![0.0; n];
            s.scatter_alpha(&mut alpha);
            s.v.copy_from(&exact_v(&ds, &alpha, 1e-2));
        }
        let mut alpha = vec![0.0; n];
        s.scatter_alpha(&mut alpha);
        let v = exact_v(&ds, &alpha, 1e-2);
        let o = crate::metrics::objectives(&ds, &Hinge, &alpha, &v, 1e-2);
        assert!(o.gap < 0.5, "wild diverged: gap {}", o.gap);
    }

    #[test]
    fn n_local_counts() {
        let (_, s, _, _) = setup(3);
        assert_eq!(s.n_local(), 200);
    }
}
