//! The asynchronous multi-core local subproblem solver — Algorithm 1's
//! inner loop (lines 4–9), PassCoDe-style (Hsieh et al. 2015).
//!
//! A worker node owns a data partition `I_k`, logically divided into
//! `R` disjoint shards `I_{k,r}`, one per core. During a round each
//! core performs `H` stochastic coordinate updates on its shard:
//!
//! 1. pick a random `i ∈ I_{k,r}`;
//! 2. read the margin `m = x_iᵀ v` from the node's **shared** `v`
//!    (lock-free relaxed atomic loads — reads may be staler than γ
//!    updates, Assumption 1);
//! 3. solve the 1-D perturbed subproblem (Eq. 6) for the new `α_i`
//!    (cores own their shard's α exclusively, so no synchronization);
//! 4. apply `v ← v + (1/λn) ε x_i` with lock-free CAS adds
//!    (or racy "wild" stores when configured).
//!
//! At the end of the round the worker computes `Δv = v − v_old`, sends
//! it to the master, receives the merged `v`, and commits
//! `α ← α + ν·δ` ([`LocalSolver::commit`]).

use crate::data::Dataset;
use crate::loss::Loss;
use crate::sim::UpdateCosts;
use crate::solver::kernels::{self, CoreOut, DirtySet, LossKernel};
use crate::solver::StepParams;
use crate::util::{AtomicF64Vec, Rng};

/// Per-core shard: global indices plus the core-owned dual variables.
#[derive(Debug)]
pub struct CoreShard {
    /// Global row ids owned by this core (I_{k,r}).
    pub idx: Vec<usize>,
    /// α at the start of the current round (committed values).
    pub alpha_start: Vec<f64>,
    /// Live α (= α_start + δ accumulated this round).
    pub alpha_cur: Vec<f64>,
    /// Independent RNG stream for this core.
    pub rng: Rng,
    /// Dirty-coordinate tracker (the Δv support), enabled by
    /// [`LocalSolver::enable_delta_tracking`]. Core-owned: no
    /// synchronization on the hot path.
    pub dirty: Option<DirtySet>,
    /// Running `Σ_j dual_value(α_cur[j], y_j)` over this shard's rows,
    /// maintained O(1) per applied step by the kernel when tracking is
    /// enabled ([`LocalSolver::enable_dual_tracking`]). Core-owned.
    pub dual_cur: Option<f64>,
}

impl CoreShard {
    fn new(idx: Vec<usize>, rng: Rng) -> Self {
        let n = idx.len();
        Self {
            idx,
            alpha_start: vec![0.0; n],
            alpha_cur: vec![0.0; n],
            rng,
            dirty: None,
            dual_cur: None,
        }
    }
}

/// Commit cadence for the tracked dual's exact re-accumulation: the
/// running sums absorb one rounding error per applied step, so every
/// `DUAL_RESYNC_EVERY` commits callers recompute them from the
/// committed α (O(n_k), same cost as one pre-tracking eval scan).
pub const DUAL_RESYNC_EVERY: usize = 64;

/// Statistics from one local round.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundStats {
    /// Coordinate updates applied (≤ R · H; empty-row draws excluded).
    pub updates: u64,
    /// Draws that hit an empty row (`‖x_i‖² = 0`) and did no work.
    /// Counted separately so updates/s is not inflated (ISSUE 4).
    pub skipped: u64,
    /// Virtual compute seconds per core (caller takes the max for the
    /// node's round time — cores run in parallel on a real node).
    pub core_secs: Vec<f64>,
}

impl RoundStats {
    /// Node round time = slowest core.
    pub fn node_secs(&self) -> f64 {
        self.core_secs.iter().cloned().fold(0.0, f64::max)
    }
}

/// The per-node local solver state.
pub struct LocalSolver {
    pub shards: Vec<CoreShard>,
    /// The node's shared primal estimate `v` (atomic, lock-free).
    pub v: AtomicF64Vec,
    dim: usize,
    params: StepParams,
    wild: bool,
    /// Shape of the last dataset whose CSR invariants were verified
    /// (n, d, nnz) — the unchecked kernels' release-mode guard,
    /// amortized to one O(nnz) validation per dataset instead of per
    /// round.
    validated_shape: Option<(usize, usize, usize)>,
}

impl LocalSolver {
    /// Build from per-core index cells (the node's slice of a
    /// [`Partition`](crate::data::Partition)).
    pub fn new(
        cells: Vec<Vec<usize>>,
        dim: usize,
        params: StepParams,
        wild: bool,
        rng: &mut Rng,
    ) -> Self {
        let shards = cells.into_iter().map(|idx| CoreShard::new(idx, rng.fork())).collect();
        Self { shards, v: AtomicF64Vec::zeros(dim), dim, params, wild, validated_shape: None }
    }

    pub fn r_cores(&self) -> usize {
        self.shards.len()
    }

    /// Turn on per-core dirty-coordinate tracking so rounds record the
    /// Δv support (required before [`Self::take_touched`]).
    pub fn enable_delta_tracking(&mut self) {
        for shard in self.shards.iter_mut() {
            shard.dirty = Some(DirtySet::new(self.dim));
        }
    }

    /// Union-and-clear of all shards' touched coordinates (ascending).
    /// Panics if tracking was never enabled.
    pub fn take_touched(&mut self) -> Vec<u32> {
        let mut acc = DirtySet::new(self.dim);
        for shard in self.shards.iter_mut() {
            let dirty = shard.dirty.as_mut().expect("delta tracking not enabled");
            acc.union(dirty);
            dirty.clear();
        }
        acc.indices()
    }

    /// Update σ (used when ablations change σ between phases).
    pub fn set_sigma(&mut self, sigma: f64) {
        self.params.sigma = sigma;
    }

    /// Run one round: every core performs `h` asynchronous updates.
    /// Cores run as real OS threads when `R > 1` (exercising the atomic
    /// races), or inline when `R == 1`. The loss is downcast once here
    /// ([`LossKernel`]) so the inner loops are fully monomorphized.
    pub fn run_round(
        &mut self,
        data: &Dataset,
        loss: &dyn Loss,
        norms: &[f64],
        costs: &UpdateCosts,
        h: usize,
    ) -> RoundStats {
        // The unchecked kernels rely on CSR validity (feature indices
        // < d). `CsrMatrix` fields are pub, so an invalid matrix from
        // safe code must panic here — not corrupt memory inside the
        // kernels. One O(nnz) validation per dataset (re-run only when
        // the shape changes), amortized across all rounds.
        let shape = (data.n(), data.d(), data.x.nnz());
        if self.validated_shape != Some(shape) {
            data.x.validate().expect("invalid CSR matrix");
            self.validated_shape = Some(shape);
        }
        let params = self.params;
        // Perf (§Perf L3): with a single core-thread there are no
        // concurrent writers, so the racy load+store path is *exact*
        // and saves the CAS (lock cmpxchg) on every touched nonzero —
        // this is the hot path of Baseline, CoCoA+, and every R=1 node.
        let wild = self.wild || self.shards.len() == 1;
        let v = &self.v;
        let kernel = LossKernel::of(loss);
        let mut core_secs = vec![0.0; self.shards.len()];
        let mut updates = 0u64;
        let mut skipped = 0u64;
        if self.shards.len() == 1 {
            let shard = &mut self.shards[0];
            let out = run_core_dispatch(&kernel, shard, data, norms, costs, v, &params, wild, h);
            core_secs[0] = out.secs;
            updates = out.applied;
            skipped = out.skipped;
        } else {
            std::thread::scope(|scope| {
                let mut handles = Vec::new();
                for shard in self.shards.iter_mut() {
                    let kernel = &kernel;
                    handles.push(scope.spawn(move || {
                        run_core_dispatch(kernel, shard, data, norms, costs, v, &params, wild, h)
                    }));
                }
                for (r, hnd) in handles.into_iter().enumerate() {
                    let out = hnd.join().expect("core thread panicked");
                    core_secs[r] = out.secs;
                    updates += out.applied;
                    skipped += out.skipped;
                }
            });
        }
        RoundStats { updates, skipped, core_secs }
    }

    /// Commit the round: `α ← α_start + ν·δ` (Algorithm 1 line 12) and
    /// reset the round baseline.
    ///
    /// ν = 1 takes the live α verbatim: `start + 1·(cur − start)` can
    /// differ from `cur` by one rounding in the last place, and the
    /// bitwise identity is what keeps the tracked dual sums
    /// ([`Self::dual_sum`]) exact across full-weight commits. For
    /// ν ≠ 1 the committed α is genuinely new, so dual-tracking
    /// callers must [`Self::resync_dual`] afterwards.
    pub fn commit(&mut self, nu: f64) {
        for shard in self.shards.iter_mut() {
            if nu == 1.0 {
                shard.alpha_start.copy_from_slice(&shard.alpha_cur);
                continue;
            }
            for j in 0..shard.idx.len() {
                let delta = shard.alpha_cur[j] - shard.alpha_start[j];
                let committed = shard.alpha_start[j] + nu * delta;
                shard.alpha_start[j] = committed;
                shard.alpha_cur[j] = committed;
            }
        }
    }

    /// Turn on incremental dual tracking: each core carries its
    /// shard's `Σ dual_value(α_i, y_i)` as a running sum updated O(1)
    /// per applied step, so reading the node's dual contribution
    /// ([`Self::dual_sum`]) is O(R) instead of an O(n_k) rescan.
    pub fn enable_dual_tracking(&mut self, data: &Dataset, loss: &dyn Loss) {
        for shard in self.shards.iter_mut() {
            shard.dual_cur = Some(0.0);
        }
        self.resync_dual(data, loss);
    }

    /// Whether [`Self::enable_dual_tracking`] was called.
    pub fn dual_tracking(&self) -> bool {
        self.shards.iter().any(|s| s.dual_cur.is_some())
    }

    /// Exactly re-accumulate every shard's tracked dual sum from the
    /// committed α (left-to-right in shard index order — the reference
    /// order the 0-ULP resync property test pins). Required after a
    /// ν ≠ 1 commit and periodically ([`DUAL_RESYNC_EVERY`]) to cancel
    /// incremental rounding drift.
    pub fn resync_dual(&mut self, data: &Dataset, loss: &dyn Loss) {
        for shard in self.shards.iter_mut() {
            let mut s = 0.0;
            for (j, &i) in shard.idx.iter().enumerate() {
                s += loss.dual_value(shard.alpha_start[j], data.y[i]);
            }
            shard.dual_cur = Some(s);
        }
    }

    /// The node's tracked `Σ_i dual_value(α_i, y_i)` — per-core sums
    /// folded in shard order. Panics if tracking was never enabled.
    pub fn dual_sum(&self) -> f64 {
        self.shards.iter().map(|s| s.dual_cur.expect("dual tracking not enabled")).sum()
    }

    /// Scatter this node's committed α into a global dense vector.
    pub fn scatter_alpha(&self, global: &mut [f64]) {
        for shard in &self.shards {
            for (j, &i) in shard.idx.iter().enumerate() {
                global[i] = shard.alpha_start[j];
            }
        }
    }

    /// Total µ-partition size (n_k).
    pub fn n_local(&self) -> usize {
        self.shards.iter().map(|s| s.idx.len()).sum()
    }
}

/// Monomorphizing dispatch into [`kernels::run_core`]: each concrete
/// arm instantiates the update loop with static loss calls; plugin
/// losses keep virtual dispatch.
#[allow(clippy::too_many_arguments)]
fn run_core_dispatch(
    kernel: &LossKernel<'_>,
    shard: &mut CoreShard,
    data: &Dataset,
    norms: &[f64],
    costs: &UpdateCosts,
    v: &AtomicF64Vec,
    params: &StepParams,
    wild: bool,
    h: usize,
) -> CoreOut {
    match kernel {
        LossKernel::Hinge(l) => kernels::run_core(shard, data, l, norms, costs, v, params, wild, h),
        LossKernel::SquaredHinge(l) => {
            kernels::run_core(shard, data, l, norms, costs, v, params, wild, h)
        }
        LossKernel::Logistic(l) => {
            kernels::run_core(shard, data, l, norms, costs, v, params, wild, h)
        }
        LossKernel::Dyn(l) => kernels::run_core(shard, data, *l, norms, costs, v, params, wild, h),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::Preset;
    use crate::loss::Hinge;
    use crate::metrics::{dual_objective, exact_v};
    use crate::sim::CostModel;

    fn setup(r: usize) -> (Dataset, LocalSolver, Vec<f64>, UpdateCosts) {
        let ds = Preset::Tiny.generate(&mut Rng::new(1));
        let n = ds.n();
        let mut rng = Rng::new(2);
        let part =
            crate::data::Partition::build(n, 1, r, crate::data::Strategy::Contiguous, &mut rng);
        let params = StepParams { lambda: 1e-2, n, sigma: 1.0 };
        let solver = LocalSolver::new(part.parts[0].clone(), ds.d(), params, false, &mut rng);
        let norms = ds.x.row_norms_sq();
        let costs = UpdateCosts::precompute(&ds, &CostModel::default());
        (ds, solver, norms, costs)
    }

    #[test]
    fn single_core_round_makes_progress() {
        let (ds, mut s, norms, costs) = setup(1);
        let stats = s.run_round(&ds, &Hinge, &norms, &costs, 500);
        assert_eq!(stats.updates, 500);
        s.commit(1.0);
        let mut alpha = vec![0.0; ds.n()];
        s.scatter_alpha(&mut alpha);
        let v = exact_v(&ds, &alpha, 1e-2);
        let d = dual_objective(&ds, &Hinge, &alpha, &v, 1e-2);
        assert!(d > 0.0, "dual did not improve: {d}");
    }

    #[test]
    fn multi_core_v_consistency_after_commit_nu1() {
        // With ν = 1 the committed α must reproduce the live v exactly
        // (atomic adds lose nothing).
        let (ds, mut s, norms, costs) = setup(4);
        for _ in 0..3 {
            s.run_round(&ds, &Hinge, &norms, &costs, 200);
            s.commit(1.0);
        }
        let mut alpha = vec![0.0; ds.n()];
        s.scatter_alpha(&mut alpha);
        let v_exact = exact_v(&ds, &alpha, 1e-2);
        let v_live = s.v.snapshot();
        for (a, b) in v_live.iter().zip(v_exact.iter()) {
            assert!((a - b).abs() < 1e-9, "v drift: {a} vs {b}");
        }
    }

    #[test]
    fn commit_scales_delta_by_nu() {
        let (ds, mut s, norms, costs) = setup(1);
        s.run_round(&ds, &Hinge, &norms, &costs, 100);
        // Capture live alphas before commit.
        let live: Vec<f64> = s.shards[0].alpha_cur.clone();
        s.commit(0.5);
        for (j, &committed) in s.shards[0].alpha_start.iter().enumerate() {
            let expected = 0.5 * live[j]; // started from 0
            assert!((committed - expected).abs() < 1e-15);
        }
    }

    /// Exact reference for the tracked dual: per-shard left-to-right
    /// sums folded in shard order — the same association as
    /// `resync_dual` + `dual_sum`.
    fn exact_dual_sum(s: &LocalSolver, ds: &Dataset) -> f64 {
        let mut total = 0.0;
        for shard in &s.shards {
            let mut sh = 0.0;
            for (j, &i) in shard.idx.iter().enumerate() {
                sh += Hinge.dual_value(shard.alpha_start[j], ds.y[i]);
            }
            total += sh;
        }
        total
    }

    #[test]
    fn tracked_dual_follows_exact_and_resyncs_to_zero_ulp() {
        let (ds, mut s, norms, costs) = setup(2);
        s.enable_dual_tracking(&ds, &Hinge);
        assert!(s.dual_tracking());
        for round in 0..10 {
            s.run_round(&ds, &Hinge, &norms, &costs, 200);
            s.commit(1.0); // bitwise α take-over keeps tracking exact
            let tracked = s.dual_sum();
            let exact = exact_dual_sum(&s, &ds);
            assert!(
                (tracked - exact).abs() <= 1e-9 * (1.0 + exact.abs()),
                "round {round}: tracked {tracked} drifted from exact {exact}"
            );
        }
        s.resync_dual(&ds, &Hinge);
        let exact = exact_dual_sum(&s, &ds);
        assert_eq!(s.dual_sum().to_bits(), exact.to_bits(), "post-resync not 0 ULP");
    }

    #[test]
    fn nu_commit_requires_resync_then_agrees() {
        let (ds, mut s, norms, costs) = setup(1);
        s.enable_dual_tracking(&ds, &Hinge);
        s.run_round(&ds, &Hinge, &norms, &costs, 300);
        s.commit(0.5); // committed α ≠ live α: tracked sums are stale
        s.resync_dual(&ds, &Hinge);
        let exact = exact_dual_sum(&s, &ds);
        assert_eq!(s.dual_sum().to_bits(), exact.to_bits());
    }

    #[test]
    fn commit_nu1_takes_live_alpha_bitwise() {
        let (ds, mut s, norms, costs) = setup(2);
        s.run_round(&ds, &Hinge, &norms, &costs, 200);
        let live: Vec<Vec<f64>> = s.shards.iter().map(|sh| sh.alpha_cur.clone()).collect();
        s.commit(1.0);
        for (shard, live) in s.shards.iter().zip(&live) {
            for (a, b) in shard.alpha_start.iter().zip(live) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn node_secs_is_max_core() {
        let stats = RoundStats { updates: 10, skipped: 0, core_secs: vec![1.0, 3.0, 2.0] };
        assert_eq!(stats.node_secs(), 3.0);
    }

    #[test]
    fn empty_row_draws_counted_as_skipped_not_updates() {
        // Two rows, one empty: draws landing on the empty row must be
        // counted in `skipped`, not `updates` (ISSUE 4 satellite — the
        // old counter credited them as applied work).
        let mut b = crate::data::CsrBuilder::new(4);
        b.push_row(vec![(0, 1.0), (2, -1.0)]).unwrap();
        b.push_row(vec![]).unwrap(); // empty row: ‖x‖² = 0
        let ds = Dataset::new(b.finish(), vec![1.0, -1.0]).with_name("skiptest");
        let mut rng = Rng::new(9);
        let params = StepParams { lambda: 1e-2, n: ds.n(), sigma: 1.0 };
        let mut s = LocalSolver::new(vec![vec![0, 1]], ds.d(), params, false, &mut rng);
        let norms = ds.x.row_norms_sq();
        let costs = UpdateCosts::precompute(&ds, &CostModel::default());
        let h = 200;
        let stats = s.run_round(&ds, &Hinge, &norms, &costs, h);
        assert_eq!(stats.updates + stats.skipped, h as u64);
        assert!(stats.skipped > 0, "empty row never drawn with h={h}");
        assert!(stats.updates > 0);
    }

    #[test]
    fn dirty_tracking_covers_every_changed_coordinate() {
        let (ds, mut s, norms, costs) = setup(1);
        s.enable_delta_tracking();
        let v_before = s.v.snapshot();
        s.run_round(&ds, &Hinge, &norms, &costs, 300);
        let v_after = s.v.snapshot();
        let touched = s.take_touched();
        let touched_set: std::collections::HashSet<u32> = touched.iter().copied().collect();
        for (j, (a, b)) in v_before.iter().zip(&v_after).enumerate() {
            if a != b {
                assert!(touched_set.contains(&(j as u32)), "changed coord {j} not tracked");
            }
        }
        assert!(!touched.is_empty());
        // take_touched clears: a second call with no new work is empty.
        assert!(s.take_touched().is_empty());
    }

    #[test]
    fn wild_mode_still_converges_roughly() {
        let ds = Preset::Tiny.generate(&mut Rng::new(3));
        let n = ds.n();
        let mut rng = Rng::new(4);
        let part =
            crate::data::Partition::build(n, 1, 4, crate::data::Strategy::Contiguous, &mut rng);
        let params = StepParams { lambda: 1e-2, n, sigma: 1.0 };
        let mut s = LocalSolver::new(part.parts[0].clone(), ds.d(), params, true, &mut rng);
        let norms = ds.x.row_norms_sq();
        let costs = UpdateCosts::precompute(&ds, &CostModel::default());
        for _ in 0..5 {
            s.run_round(&ds, &Hinge, &norms, &costs, 500);
            s.commit(1.0);
            // Resync live v from committed α (wild mode drifts).
            let mut alpha = vec![0.0; n];
            s.scatter_alpha(&mut alpha);
            s.v.copy_from(&exact_v(&ds, &alpha, 1e-2));
        }
        let mut alpha = vec![0.0; n];
        s.scatter_alpha(&mut alpha);
        let v = exact_v(&ds, &alpha, 1e-2);
        let o = crate::metrics::objectives(&ds, &Hinge, &alpha, &v, 1e-2);
        assert!(o.gap < 0.5, "wild diverged: gap {}", o.gap);
    }

    #[test]
    fn n_local_counts() {
        let (_, s, _, _) = setup(3);
        assert_eq!(s.n_local(), 200);
    }
}
