//! Block (mini-batch locally-sequential) dual coordinate step — the
//! exact Rust oracle for the L1/L2 XLA path.
//!
//! Semantics (must match `python/compile/kernels/ref.py` bit-for-bit up
//! to dtype): for a block of `B` coordinates with dense feature tile
//! `X_blk ∈ R^{B×D}` and a *frozen* primal estimate `v`:
//!
//! ```text
//! G  = X_blk X_blkᵀ                  (Gram tile)
//! g0 = X_blk v                        (base margins)
//! for j in 0..B (sequentially):
//!     m_j   = g0[j] + (1/λn) Σ_l ε_l G[j,l]
//!     a_new = hinge step at (α_j, y_j, m_j, q_j)
//!     ε_j   = a_new − α_j
//! Δv = (1/λn) X_blkᵀ ε
//! ```
//!
//! This is numerically identical to `B` sequential scalar updates
//! against `v` kept live *within* the block, because the Gram row
//! supplies exactly the inner products the live `v` would have
//! accumulated. It is the TPU-idiomatic form of SDCA (DESIGN.md
//! §Hardware-Adaptation): the Gram product and the two matvecs are
//! MXU-shaped, and the scan carries the sequential dependency.

use crate::loss::Loss;
use crate::solver::StepParams;

/// Inputs to one block step, in dense row-major form.
#[derive(Debug, Clone)]
pub struct BlockInput {
    /// `B×D` row-major dense tile.
    pub x: Vec<f64>,
    pub b: usize,
    pub d: usize,
    /// Labels, length B.
    pub y: Vec<f64>,
    /// Current duals, length B.
    pub alpha: Vec<f64>,
    /// Frozen primal estimate, length D.
    pub v: Vec<f64>,
}

/// Outputs of one block step.
#[derive(Debug, Clone, PartialEq)]
pub struct BlockOutput {
    /// New duals, length B.
    pub alpha_new: Vec<f64>,
    /// Dual increments ε, length B.
    pub eps: Vec<f64>,
    /// `Δv = (1/λn) X_blkᵀ ε`, length D.
    pub delta_v: Vec<f64>,
}

/// Run the block dual step in pure Rust (f64). `loss` must be hinge-like
/// (any [`Loss`] works; the XLA kernel implements hinge).
pub fn block_step(input: &BlockInput, loss: &dyn Loss, params: &StepParams) -> BlockOutput {
    let (b, d) = (input.b, input.d);
    assert_eq!(input.x.len(), b * d);
    assert_eq!(input.y.len(), b);
    assert_eq!(input.alpha.len(), b);
    assert_eq!(input.v.len(), d);

    // Gram tile G = X Xᵀ and base margins g0 = X v.
    let mut gram = vec![0.0; b * b];
    let mut g0 = vec![0.0; b];
    for i in 0..b {
        let xi = &input.x[i * d..(i + 1) * d];
        g0[i] = xi.iter().zip(&input.v).map(|(a, c)| a * c).sum();
        for j in 0..=i {
            let xj = &input.x[j * d..(j + 1) * d];
            let g: f64 = xi.iter().zip(xj).map(|(a, c)| a * c).sum();
            gram[i * b + j] = g;
            gram[j * b + i] = g;
        }
    }

    // In-block corrections carry the σ·(1/λn) scaling, matching the
    // subproblem Q_k^σ's treatment of accumulated δ (see solver::local).
    let corr_scale = params.v_scale() * params.sigma;
    let mut eps = vec![0.0; b];
    let mut alpha_new = input.alpha.clone();
    for j in 0..b {
        let norm_sq = gram[j * b + j];
        if norm_sq == 0.0 {
            continue;
        }
        // Margin including corrections from earlier in-block updates.
        let mut m = g0[j];
        for l in 0..j {
            m += corr_scale * eps[l] * gram[j * b + l];
        }
        let q = params.q(norm_sq);
        let a_new = loss.coordinate_step(input.alpha[j], input.y[j], m, q);
        eps[j] = a_new - input.alpha[j];
        alpha_new[j] = a_new;
    }

    // Δv = (1/λn) · Xᵀ ε (wire format: unscaled by σ).
    let scale = params.v_scale();
    let mut delta_v = vec![0.0; d];
    for j in 0..b {
        let e = eps[j];
        if e == 0.0 {
            continue;
        }
        let xj = &input.x[j * d..(j + 1) * d];
        for (dv, &x) in delta_v.iter_mut().zip(xj) {
            *dv += scale * e * x;
        }
    }
    BlockOutput { alpha_new, eps, delta_v }
}

/// Reference implementation: B truly-sequential scalar updates with a
/// live dense `v` copy. Used by tests to prove [`block_step`]'s Gram
/// formulation is exact.
pub fn sequential_oracle(input: &BlockInput, loss: &dyn Loss, params: &StepParams) -> BlockOutput {
    let (b, d) = (input.b, input.d);
    let mut v = input.v.clone();
    let mut eps = vec![0.0; b];
    let mut alpha_new = input.alpha.clone();
    // Live v carries the in-round σ·(1/λn) scaling (solver::local);
    // Δv is reported in the (1/λn) wire scale.
    let corr_scale = params.v_scale() * params.sigma;
    for j in 0..b {
        let xj = &input.x[j * d..(j + 1) * d];
        let norm_sq: f64 = xj.iter().map(|x| x * x).sum();
        if norm_sq == 0.0 {
            continue;
        }
        let m: f64 = xj.iter().zip(&v).map(|(a, c)| a * c).sum();
        let q = params.q(norm_sq);
        let a_new = loss.coordinate_step(input.alpha[j], input.y[j], m, q);
        eps[j] = a_new - input.alpha[j];
        alpha_new[j] = a_new;
        for (vv, &x) in v.iter_mut().zip(xj) {
            *vv += corr_scale * eps[j] * x;
        }
    }
    let mut delta_v = vec![0.0; d];
    for (dv, (a, b_)) in delta_v.iter_mut().zip(v.iter().zip(&input.v)) {
        *dv = (a - b_) / params.sigma;
    }
    BlockOutput { alpha_new, eps, delta_v }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::Hinge;
    use crate::util::Rng;

    fn random_input(rng: &mut Rng, b: usize, d: usize) -> BlockInput {
        let x: Vec<f64> = (0..b * d)
            .map(|_| if rng.next_bool(0.4) { rng.next_gaussian() } else { 0.0 })
            .collect();
        let y: Vec<f64> = (0..b).map(|_| if rng.next_bool(0.5) { 1.0 } else { -1.0 }).collect();
        let alpha: Vec<f64> = (0..b).map(|i| rng.next_f64() * y[i]).collect();
        let v: Vec<f64> = (0..d).map(|_| rng.next_gaussian() * 0.3).collect();
        BlockInput { x, b, d, y, alpha, v }
    }

    #[test]
    fn gram_formulation_matches_sequential_oracle() {
        let mut rng = Rng::new(61);
        let params = StepParams { lambda: 1e-2, n: 500, sigma: 2.0 };
        for &(b, d) in &[(1usize, 8usize), (4, 8), (8, 16), (16, 32)] {
            let input = random_input(&mut rng, b, d);
            let a = block_step(&input, &Hinge, &params);
            let o = sequential_oracle(&input, &Hinge, &params);
            for (x, y) in a.eps.iter().zip(&o.eps) {
                assert!((x - y).abs() < 1e-10, "eps mismatch {x} vs {y} (B={b},D={d})");
            }
            for (x, y) in a.delta_v.iter().zip(&o.delta_v) {
                assert!((x - y).abs() < 1e-10, "dv mismatch {x} vs {y}");
            }
            for (x, y) in a.alpha_new.iter().zip(&o.alpha_new) {
                assert!((x - y).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn new_alphas_feasible() {
        let mut rng = Rng::new(63);
        let params = StepParams { lambda: 1e-3, n: 100, sigma: 1.0 };
        let input = random_input(&mut rng, 16, 24);
        let out = block_step(&input, &Hinge, &params);
        for (j, &a) in out.alpha_new.iter().enumerate() {
            assert!(Hinge.feasible(a, input.y[j]), "α[{j}]={a}");
        }
    }

    #[test]
    fn zero_rows_are_skipped() {
        let params = StepParams { lambda: 1e-2, n: 10, sigma: 1.0 };
        let input = BlockInput {
            x: vec![0.0; 2 * 4],
            b: 2,
            d: 4,
            y: vec![1.0, -1.0],
            alpha: vec![0.0, 0.0],
            v: vec![1.0; 4],
        };
        let out = block_step(&input, &Hinge, &params);
        assert_eq!(out.eps, vec![0.0, 0.0]);
        assert_eq!(out.delta_v, vec![0.0; 4]);
    }

    #[test]
    fn block_step_improves_dual_subobjective() {
        // The block objective Σ_j [dual(α_j) stuff] must not decrease:
        // verify via the sequential oracle's per-step monotonicity —
        // each scalar step maximizes its 1-D problem, so f(ε_j) ≥ f(0).
        let mut rng = Rng::new(65);
        let params = StepParams { lambda: 1e-2, n: 200, sigma: 1.0 };
        let input = random_input(&mut rng, 8, 12);
        let out = block_step(&input, &Hinge, &params);
        // At least one coordinate should move for a random state.
        assert!(out.eps.iter().any(|&e| e != 0.0));
    }
}
