//! The XLA block solver: dual coordinate ascent whose inner loop runs
//! entirely through the AOT-compiled PJRT artifacts (Layer 1 + Layer 2),
//! with Rust orchestrating blocks — the path a TPU deployment takes.
//!
//! Scope: dense tiles. The paper's experiments use the `rust-sparse`
//! scalar path (their datasets are extremely sparse); this solver
//! exists to (a) prove the three layers compose on the hot path, and
//! (b) serve workloads where densified tiles are profitable (d small,
//! MXU-shaped). Features are padded to the artifact's D, rows to
//! blocks of B; padding rows have `‖x‖ = 0` and are skipped inside the
//! kernel (q = 0 guard).

use crate::data::Dataset;
use crate::metrics::{Trace, TracePoint};
use crate::runtime::{Artifact, Runtime};
use crate::util::Stopwatch;

/// Solver state.
pub struct XlaDenseSolver<'rt> {
    rt: &'rt Runtime,
    step_art: &'rt Artifact,
    gap_art: &'rt Artifact,
    b: usize,
    d_art: usize,
    lambda: f64,
    /// Densified row tiles, one per block: `B × D_art` row-major f32
    /// (host copies kept for diagnostics; the solve path uses the
    /// device-resident buffers below).
    blocks: Vec<Vec<f32>>,
    /// Per-block duals (padded with zeros).
    block_alpha: Vec<Vec<f32>>,
    /// Device-resident copies of the static per-block tensors (perf:
    /// staging the B×D tile dominates small block-step calls; X and y
    /// never change, so they are uploaded once).
    x_bufs: Vec<xla::PjRtBuffer>,
    y_bufs: Vec<xla::PjRtBuffer>,
    /// Dense primal estimate (padded).
    pub v: Vec<f32>,
    n: usize,
}

impl<'rt> XlaDenseSolver<'rt> {
    /// Build from a dataset; requires `data.d() ≤` some artifact `D`.
    pub fn new(rt: &'rt Runtime, data: &Dataset, lambda: f64) -> anyhow::Result<Self> {
        // Pick the smallest (B, D) block-step artifact that fits d.
        let mut candidates: Vec<&Artifact> = rt
            .names()
            .into_iter()
            .filter_map(|n| rt.get(n))
            .filter(|a| {
                a.meta.kind == crate::runtime::ArtifactKind::BlockStep && a.meta.d >= data.d()
            })
            .collect();
        candidates.sort_by_key(|a| (a.meta.d, a.meta.b));
        let step_art = *candidates
            .first()
            .ok_or_else(|| anyhow::anyhow!("no block_step artifact with D ≥ {}", data.d()))?;
        let (b, d_art) = (step_art.meta.b, step_art.meta.d);
        let gap_art = rt
            .find_gap_tile(b, d_art)
            .ok_or_else(|| anyhow::anyhow!("no matching gap_tile artifact {b}x{d_art}"))?;

        // Densify rows into padded tiles.
        let n = data.n();
        let n_blocks = n.div_ceil(b);
        let mut blocks = Vec::with_capacity(n_blocks);
        let mut block_y = Vec::with_capacity(n_blocks);
        let mut block_alpha = Vec::with_capacity(n_blocks);
        for blk in 0..n_blocks {
            let mut tile = vec![0.0f32; b * d_art];
            let mut ys = vec![0.0f32; b];
            for r in 0..b {
                let i = blk * b + r;
                if i >= n {
                    break;
                }
                let row = data.x.row(i);
                for (&j, &x) in row.indices.iter().zip(row.values.iter()) {
                    tile[r * d_art + j as usize] = x as f32;
                }
                ys[r] = data.y[i] as f32;
            }
            blocks.push(tile);
            block_y.push(ys);
            block_alpha.push(vec![0.0f32; b]);
        }
        let mut x_bufs = Vec::with_capacity(blocks.len());
        let mut y_bufs = Vec::with_capacity(blocks.len());
        for (tile, ys) in blocks.iter().zip(&block_y) {
            x_bufs.push(rt.upload(tile, &[b, d_art])?);
            y_bufs.push(rt.upload(ys, &[b])?);
        }
        drop(block_y);
        Ok(Self {
            rt,
            step_art,
            gap_art,
            b,
            d_art,
            lambda,
            blocks,
            block_alpha,
            x_bufs,
            y_bufs,
            v: vec![0.0f32; d_art],
            n,
        })
    }

    /// Artifact shape in use.
    pub fn shape(&self) -> (usize, usize) {
        (self.b, self.d_art)
    }

    /// One epoch: a block step per tile, applying `Δv` after each
    /// (σ = 1: single-node, blocks sequential ⇒ exact block SDCA).
    pub fn run_epoch(&mut self) -> anyhow::Result<()> {
        let inv_ln = (1.0 / (self.lambda * self.n as f64)) as f32;
        for blk in 0..self.blocks.len() {
            let out = self.rt.block_step_buffered(
                self.step_art,
                &self.x_bufs[blk],
                &self.y_bufs[blk],
                &self.block_alpha[blk],
                &self.v,
                inv_ln,
                1.0,
            )?;
            self.block_alpha[blk] = out.alpha_new;
            for (vv, dv) in self.v.iter_mut().zip(&out.delta_v) {
                *vv += dv;
            }
        }
        Ok(())
    }

    /// Duality gap evaluated entirely through the gap-tile artifact.
    pub fn gap(&self) -> anyhow::Result<f64> {
        let mut hinge = 0.0f64;
        let mut dual = 0.0f64;
        for blk in 0..self.blocks.len() {
            let out = self.rt.gap_tile_buffered(
                self.gap_art,
                &self.x_bufs[blk],
                &self.y_bufs[blk],
                &self.block_alpha[blk],
                &self.v,
            )?;
            hinge += out.hinge_sum as f64;
            dual += out.dual_sum as f64;
        }
        // Padding rows contribute max(0, 1−0) = 1 to the hinge sum;
        // subtract them (they have y = 0 ⇒ hinge term = 1, dual = 0).
        let pad_rows = self.blocks.len() * self.b - self.n;
        hinge -= pad_rows as f64;
        let vnorm: f64 = self.v.iter().map(|&x| (x as f64) * (x as f64)).sum();
        let primal = hinge / self.n as f64 + 0.5 * self.lambda * vnorm;
        let dual_obj = dual / self.n as f64 - 0.5 * self.lambda * vnorm;
        Ok(primal - dual_obj)
    }

    /// Solve to a gap threshold, recording a trace.
    pub fn solve(&mut self, max_epochs: usize, threshold: f64) -> anyhow::Result<Trace> {
        let mut trace = Trace::new("XLA-block");
        let sw = Stopwatch::start();
        let g0 = self.gap()?;
        trace.push(TracePoint {
            round: 0,
            wall_secs: 0.0,
            virt_secs: 0.0,
            gap: g0,
            primal: 0.0,
            dual: 0.0,
            updates: 0,
        });
        for epoch in 1..=max_epochs {
            self.run_epoch()?;
            let gap = self.gap()?;
            trace.push(TracePoint {
                round: epoch,
                wall_secs: sw.elapsed_secs(),
                virt_secs: sw.elapsed_secs(),
                gap,
                primal: 0.0,
                dual: 0.0,
                updates: (epoch * self.n) as u64,
            });
            if gap <= threshold {
                break;
            }
        }
        Ok(trace)
    }

    /// Collected duals in dataset row order.
    pub fn alpha(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.n);
        'outer: for (blk, alphas) in self.block_alpha.iter().enumerate() {
            for (r, &a) in alphas.iter().enumerate() {
                if blk * self.b + r >= self.n {
                    break 'outer;
                }
                out.push(a as f64);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    // Runtime-dependent tests live in rust/tests/xla_roundtrip.rs and
    // rust/tests/convergence.rs (they need `make artifacts`). Pure
    // logic (padding arithmetic) is covered here.

    #[test]
    fn div_ceil_padding_math() {
        assert_eq!(10usize.div_ceil(4), 3);
        assert_eq!(16usize.div_ceil(16), 1);
        assert_eq!(17usize.div_ceil(16), 2);
    }
}
