//! Compressed sparse row (CSR) matrix over `f64` values with `u32`
//! column indices.
//!
//! Layout convention: the paper writes the data matrix `X ∈ R^{d×n}` with
//! one *column* per data point. We store the transpose — one CSR **row
//! per data point** `x_i ∈ R^d` — because every algorithm in the paper
//! accesses whole data points (`x_iᵀ v`, `v += ε x_i`) and never whole
//! features. `n = rows()`, `d = dim()`.

use crate::util::Rng;

/// Sparse dataset: CSR feature matrix plus labels.
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrix {
    /// Row pointer array, length `n + 1`.
    pub indptr: Vec<usize>,
    /// Column (feature) indices, length `nnz`, each `< dim`.
    pub indices: Vec<u32>,
    /// Nonzero values, length `nnz`.
    pub values: Vec<f64>,
    /// Number of features `d`.
    pub dim: usize,
}

/// One sparse row view.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SparseRow<'a> {
    pub indices: &'a [u32],
    pub values: &'a [f64],
}

impl<'a> SparseRow<'a> {
    #[inline]
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Dot with a dense vector.
    #[inline]
    pub fn dot_dense(&self, v: &[f64]) -> f64 {
        let mut acc = 0.0;
        for (&j, &x) in self.indices.iter().zip(self.values.iter()) {
            acc += x * v[j as usize];
        }
        acc
    }

    /// Squared norm of the row.
    #[inline]
    pub fn norm_sq(&self) -> f64 {
        self.values.iter().map(|x| x * x).sum()
    }

    /// Dot with another sparse row (both index-sorted).
    pub fn dot_sparse(&self, other: &SparseRow<'_>) -> f64 {
        let (mut i, mut j, mut acc) = (0usize, 0usize, 0.0f64);
        while i < self.indices.len() && j < other.indices.len() {
            match self.indices[i].cmp(&other.indices[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    acc += self.values[i] * other.values[j];
                    i += 1;
                    j += 1;
                }
            }
        }
        acc
    }
}

/// Sort row entries by feature index and reject duplicates — the row
/// normalization shared by [`CsrBuilder::push_row`] and the shard
/// packer's dim-deferred accumulator (`store::pack`). Both callers
/// read the max index from the sorted tail *before* dropping explicit
/// zeros, so the two ingestion paths stay bit-for-bit in lockstep.
pub fn sort_row_entries(mut entries: Vec<(u32, f64)>) -> anyhow::Result<Vec<(u32, f64)>> {
    entries.sort_unstable_by_key(|e| e.0);
    for w in entries.windows(2) {
        anyhow::ensure!(w[0].0 != w[1].0, "duplicate feature index {} in row", w[0].0);
    }
    Ok(entries)
}

/// Builder collecting rows incrementally.
#[derive(Debug, Default)]
pub struct CsrBuilder {
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f64>,
    dim: usize,
}

impl CsrBuilder {
    pub fn new(dim: usize) -> Self {
        Self { indptr: vec![0], indices: Vec::new(), values: Vec::new(), dim }
    }

    /// Push one row given (index, value) pairs; pairs are sorted and
    /// duplicate indices are rejected.
    pub fn push_row(&mut self, entries: Vec<(u32, f64)>) -> anyhow::Result<()> {
        let entries = sort_row_entries(entries)?;
        if let Some(&(max_idx, _)) = entries.last() {
            anyhow::ensure!(
                (max_idx as usize) < self.dim,
                "feature index {max_idx} out of range (dim={})",
                self.dim
            );
        }
        for (j, x) in entries {
            if x != 0.0 {
                self.indices.push(j);
                self.values.push(x);
            }
        }
        self.indptr.push(self.indices.len());
        Ok(())
    }

    pub fn finish(self) -> CsrMatrix {
        CsrMatrix {
            indptr: self.indptr,
            indices: self.indices,
            values: self.values,
            dim: self.dim,
        }
    }
}

impl CsrMatrix {
    /// Empty matrix with `dim` columns.
    pub fn empty(dim: usize) -> Self {
        CsrMatrix { indptr: vec![0], indices: vec![], values: vec![], dim }
    }

    /// Number of rows (data points `n`).
    #[inline]
    pub fn rows(&self) -> usize {
        self.indptr.len() - 1
    }

    /// Number of columns (features `d`).
    #[inline]
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Total number of stored nonzeros.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Borrow row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> SparseRow<'_> {
        let (s, e) = (self.indptr[i], self.indptr[i + 1]);
        SparseRow { indices: &self.indices[s..e], values: &self.values[s..e] }
    }

    /// Row access without bounds checks on the pointer array — the
    /// solver hot path calls this with indices proven valid by the
    /// partitioning invariants.
    ///
    /// # Safety
    /// `i < self.rows()` must hold.
    #[inline(always)]
    pub unsafe fn row_unchecked(&self, i: usize) -> SparseRow<'_> {
        let s = *self.indptr.get_unchecked(i);
        let e = *self.indptr.get_unchecked(i + 1);
        SparseRow {
            indices: self.indices.get_unchecked(s..e),
            values: self.values.get_unchecked(s..e),
        }
    }

    /// Squared norms of all rows (precomputed once per run: the
    /// closed-form coordinate step divides by `‖x_i‖²`).
    pub fn row_norms_sq(&self) -> Vec<f64> {
        (0..self.rows()).map(|i| self.row(i).norm_sq()).collect()
    }

    /// Dense matrix-vector product `X v` (rows of X dotted with v).
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.dim);
        (0..self.rows()).map(|i| self.row(i).dot_dense(v)).collect()
    }

    /// Transposed product `Xᵀ a = Σ_i a_i x_i` into a dense `R^d` vector.
    pub fn matvec_t(&self, a: &[f64]) -> Vec<f64> {
        assert_eq!(a.len(), self.rows());
        let mut out = vec![0.0; self.dim];
        for i in 0..self.rows() {
            let ai = a[i];
            if ai == 0.0 {
                continue;
            }
            let r = self.row(i);
            for (&j, &x) in r.indices.iter().zip(r.values.iter()) {
                out[j as usize] += ai * x;
            }
        }
        out
    }

    /// Extract rows `rows` as a dense row-major `B×dim_slice` tile over
    /// feature range `[col_lo, col_hi)`. Used to feed the XLA block path.
    pub fn dense_tile(&self, rows: &[usize], col_lo: usize, col_hi: usize) -> Vec<f64> {
        assert!(col_lo <= col_hi && col_hi <= self.dim);
        let w = col_hi - col_lo;
        let mut out = vec![0.0; rows.len() * w];
        for (bi, &i) in rows.iter().enumerate() {
            let r = self.row(i);
            for (&j, &x) in r.indices.iter().zip(r.values.iter()) {
                let j = j as usize;
                if j >= col_lo && j < col_hi {
                    out[bi * w + (j - col_lo)] = x;
                }
            }
        }
        out
    }

    /// Select a subset of rows into a new matrix (used for partitioning).
    pub fn select_rows(&self, rows: &[usize]) -> CsrMatrix {
        let mut b = CsrBuilder::new(self.dim);
        for &i in rows {
            let r = self.row(i);
            let entries: Vec<(u32, f64)> =
                r.indices.iter().copied().zip(r.values.iter().copied()).collect();
            b.push_row(entries).expect("rows from a valid matrix are valid");
        }
        b.finish()
    }

    /// Density = nnz / (n·d).
    pub fn density(&self) -> f64 {
        if self.rows() == 0 || self.dim == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.rows() as f64 * self.dim as f64)
    }

    /// Build a random sparse matrix (test helper; experiment workloads
    /// use `data::synth` which controls label structure too).
    pub fn random(rng: &mut Rng, n: usize, d: usize, nnz_per_row: usize) -> CsrMatrix {
        let mut b = CsrBuilder::new(d);
        for _ in 0..n {
            let k = nnz_per_row.min(d).max(1);
            let idx = rng.sample_indices(d, k);
            let entries: Vec<(u32, f64)> =
                idx.into_iter().map(|j| (j as u32, rng.next_gaussian())).collect();
            b.push_row(entries).unwrap();
        }
        b.finish()
    }

    /// Structural validation of the CSR invariants.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.indptr.is_empty(), "indptr empty");
        anyhow::ensure!(self.indptr[0] == 0, "indptr[0] != 0");
        anyhow::ensure!(
            *self.indptr.last().unwrap() == self.indices.len(),
            "indptr end mismatch"
        );
        anyhow::ensure!(self.indices.len() == self.values.len(), "index/value length");
        for w in self.indptr.windows(2) {
            anyhow::ensure!(w[0] <= w[1], "indptr not monotone");
        }
        for i in 0..self.rows() {
            let r = self.row(i);
            for w in r.indices.windows(2) {
                anyhow::ensure!(w[0] < w[1], "row {i} indices not strictly sorted");
            }
            if let Some(&last) = r.indices.last() {
                anyhow::ensure!((last as usize) < self.dim, "row {i} index out of range");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CsrMatrix {
        // [[1, 0, 2], [0, 3, 0], [4, 5, 6]]
        let mut b = CsrBuilder::new(3);
        b.push_row(vec![(0, 1.0), (2, 2.0)]).unwrap();
        b.push_row(vec![(1, 3.0)]).unwrap();
        b.push_row(vec![(2, 6.0), (0, 4.0), (1, 5.0)]).unwrap();
        b.finish()
    }

    #[test]
    fn build_and_shape() {
        let m = sample();
        assert_eq!(m.rows(), 3);
        assert_eq!(m.dim(), 3);
        assert_eq!(m.nnz(), 6);
        m.validate().unwrap();
        assert!((m.density() - 6.0 / 9.0).abs() < 1e-12);
    }

    #[test]
    fn builder_sorts_entries() {
        let m = sample();
        assert_eq!(m.row(2).indices, &[0, 1, 2]);
        assert_eq!(m.row(2).values, &[4.0, 5.0, 6.0]);
    }

    #[test]
    fn builder_rejects_bad_rows() {
        let mut b = CsrBuilder::new(3);
        assert!(b.push_row(vec![(1, 1.0), (1, 2.0)]).is_err());
        assert!(b.push_row(vec![(3, 1.0)]).is_err());
    }

    #[test]
    fn builder_drops_explicit_zeros() {
        let mut b = CsrBuilder::new(4);
        b.push_row(vec![(0, 0.0), (1, 2.0)]).unwrap();
        let m = b.finish();
        assert_eq!(m.nnz(), 1);
    }

    #[test]
    fn matvec_and_transpose() {
        let m = sample();
        let v = vec![1.0, 2.0, 3.0];
        assert_eq!(m.matvec(&v), vec![7.0, 6.0, 32.0]);
        let a = vec![1.0, 1.0, 1.0];
        assert_eq!(m.matvec_t(&a), vec![5.0, 8.0, 8.0]);
    }

    #[test]
    fn matvec_t_consistent_with_matvec() {
        // aᵀ(Xv) == (Xᵀa)ᵀv
        let mut rng = Rng::new(3);
        let m = CsrMatrix::random(&mut rng, 20, 15, 4);
        let v: Vec<f64> = (0..15).map(|_| rng.next_gaussian()).collect();
        let a: Vec<f64> = (0..20).map(|_| rng.next_gaussian()).collect();
        let lhs: f64 = m.matvec(&v).iter().zip(&a).map(|(x, y)| x * y).sum();
        let rhs: f64 = m.matvec_t(&a).iter().zip(&v).map(|(x, y)| x * y).sum();
        assert!((lhs - rhs).abs() < 1e-9 * (1.0 + lhs.abs()));
    }

    #[test]
    fn row_ops() {
        let m = sample();
        let r0 = m.row(0);
        assert_eq!(r0.nnz(), 2);
        assert_eq!(r0.norm_sq(), 5.0);
        assert_eq!(r0.dot_dense(&[1.0, 1.0, 1.0]), 3.0);
        let r2 = m.row(2);
        assert_eq!(r0.dot_sparse(&r2), 1.0 * 4.0 + 2.0 * 6.0);
    }

    #[test]
    fn unchecked_matches_checked() {
        let m = sample();
        for i in 0..m.rows() {
            let a = m.row(i);
            // SAFETY: loop bound keeps i < m.rows().
            let b = unsafe { m.row_unchecked(i) };
            assert_eq!(a, b);
        }
    }

    #[test]
    fn dense_tile_extraction() {
        let m = sample();
        let t = m.dense_tile(&[0, 2], 0, 3);
        assert_eq!(t, vec![1.0, 0.0, 2.0, 4.0, 5.0, 6.0]);
        let t2 = m.dense_tile(&[2], 1, 3);
        assert_eq!(t2, vec![5.0, 6.0]);
    }

    #[test]
    fn select_rows_subset() {
        let m = sample();
        let s = m.select_rows(&[2, 0]);
        assert_eq!(s.rows(), 2);
        assert_eq!(s.row(0).values, m.row(2).values);
        assert_eq!(s.row(1).values, m.row(0).values);
    }

    #[test]
    fn norms() {
        let m = sample();
        assert_eq!(m.row_norms_sq(), vec![5.0, 9.0, 77.0]);
    }

    #[test]
    fn random_matrix_valid() {
        let mut rng = Rng::new(1);
        let m = CsrMatrix::random(&mut rng, 50, 30, 5);
        m.validate().unwrap();
        assert_eq!(m.rows(), 50);
        assert!(m.nnz() <= 250);
    }
}
