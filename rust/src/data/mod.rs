//! Data substrate: sparse matrices, LIBSVM I/O, synthetic workload
//! generation, partitioning, and dataset statistics.

pub mod csr;
pub mod dataset;
pub mod libsvm;
pub mod partition;
pub mod stats;
pub mod synth;

pub use csr::{CsrBuilder, CsrMatrix, SparseRow};
pub use dataset::Dataset;
pub use partition::{Partition, Strategy};
pub use stats::DatasetStats;
pub use synth::{Preset, SynthSpec};
