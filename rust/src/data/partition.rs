//! Data partitioning: node-level partitions `I_k` (paper §3) and
//! core-level sub-partitions `I_{k,r}` (paper §3.1).
//!
//! The paper distributes data *equally across the K nodes* and each node
//! logically divides its partition into R disjoint subparts, one per
//! core, "exclusively used by core r" — so α updates never conflict and
//! only `v` needs atomics. These invariants (exact cover, disjointness)
//! are what the property tests in `rust/tests/prop_partition.rs` check.

use crate::util::Rng;

/// How global indices are assigned to nodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Contiguous blocks (what an MPI scatter of a file does).
    Contiguous,
    /// Round-robin striping (balances heterogeneous row costs).
    Striped,
    /// Random permutation then contiguous blocks (breaks any ordering
    /// correlation in the data file; recommended default).
    Shuffled,
}

impl Strategy {
    pub fn parse(s: &str) -> Option<Strategy> {
        match s.to_ascii_lowercase().as_str() {
            "contiguous" => Some(Strategy::Contiguous),
            "striped" => Some(Strategy::Striped),
            "shuffled" => Some(Strategy::Shuffled),
            _ => None,
        }
    }

    /// Canonical lowercase name; `Strategy::parse(s.name()) == Some(s)`.
    pub fn name(self) -> &'static str {
        match self {
            Strategy::Contiguous => "contiguous",
            Strategy::Striped => "striped",
            Strategy::Shuffled => "shuffled",
        }
    }
}

/// A two-level partition: `parts[k][r]` = global row indices owned by
/// core `r` of node `k`.
#[derive(Debug, Clone, PartialEq)]
pub struct Partition {
    pub parts: Vec<Vec<Vec<usize>>>,
}

impl Partition {
    /// Split `n` rows across `k_nodes × r_cores`.
    pub fn build(
        n: usize,
        k_nodes: usize,
        r_cores: usize,
        strategy: Strategy,
        rng: &mut Rng,
    ) -> Partition {
        assert!(k_nodes > 0 && r_cores > 0);
        assert!(
            n >= k_nodes * r_cores,
            "need at least one row per core: n={n}, K*R={}",
            k_nodes * r_cores
        );
        let order: Vec<usize> = match strategy {
            Strategy::Contiguous => (0..n).collect(),
            Strategy::Striped => {
                // Interleave: node k gets indices ≡ k (mod K), preserving
                // stripe order inside each node.
                let mut v = Vec::with_capacity(n);
                for k in 0..k_nodes {
                    for i in (k..n).step_by(k_nodes) {
                        v.push(i);
                    }
                }
                v
            }
            Strategy::Shuffled => {
                let mut v: Vec<usize> = (0..n).collect();
                rng.shuffle(&mut v);
                v
            }
        };
        // First level: equal contiguous chunks of `order` per node.
        let node_chunks = split_even(&order, k_nodes);
        // Second level: equal chunks per core.
        let parts = node_chunks
            .into_iter()
            .map(|chunk| split_even(&chunk, r_cores).into_iter().collect())
            .collect();
        Partition { parts }
    }

    /// Build a shard-aware partition: node cuts are placed on shard
    /// boundaries so every node owns whole shards, in disk order — the
    /// out-of-core contract that `I_k` never leaves the order its
    /// shards were packed in (paper §3's pre-partitioned node-local
    /// blocks, as Hydra and distributed mini-batch SDCA assume).
    ///
    /// `spans` are the store's `[start, end)` global row ranges, which
    /// must tile `0..n` contiguously. Each of the `K − 1` interior cut
    /// points is the shard boundary nearest the ideal even cut that
    /// still leaves every node at least `r_cores` rows; if no boundary
    /// qualifies (shards too coarse for K), this errors with repack
    /// advice instead of silently splitting a shard. Within a node the
    /// contiguous row range is split evenly across cores, exactly like
    /// [`Partition::build`] with [`Strategy::Contiguous`].
    pub fn from_shards(
        n: usize,
        spans: &[(usize, usize)],
        k_nodes: usize,
        r_cores: usize,
    ) -> anyhow::Result<Partition> {
        anyhow::ensure!(k_nodes > 0 && r_cores > 0, "need K ≥ 1 and R ≥ 1");
        anyhow::ensure!(!spans.is_empty(), "shard store has no shards");
        let mut expect = 0usize;
        for &(s, e) in spans {
            anyhow::ensure!(
                s == expect && e > s,
                "shard spans must tile 0..{n} contiguously (got [{s}, {e}) where \
                 start {expect} was expected)"
            );
            expect = e;
        }
        anyhow::ensure!(
            expect == n,
            "shard spans cover {expect} rows but the dataset has {n}"
        );
        anyhow::ensure!(
            n >= k_nodes * r_cores,
            "need at least one row per core: n={n}, K*R={}",
            k_nodes * r_cores
        );

        let cut_candidates: Vec<usize> = spans.iter().map(|&(_, e)| e).collect();
        // Feasibility oracle: the most ≥R-row nodes the shard suffix
        // starting at row `from` can still form (whole shards, disk
        // order). Greedy-from-the-left maximizes the count, and any
        // smaller count is reachable by merging adjacent groups — so a
        // cut at `b` is viable for step j iff max_groups(b) ≥ K − j.
        // Checking this per candidate (instead of a row-count window
        // alone) guarantees the construction never refuses a span set
        // that has a valid shard-aligned partition.
        let max_groups = |from: usize| -> usize {
            let mut groups = 0usize;
            let mut acc = 0usize;
            for &(s, e) in spans {
                if s < from {
                    continue;
                }
                acc += e - s;
                if acc >= r_cores {
                    groups += 1;
                    acc = 0;
                }
            }
            groups
        };
        anyhow::ensure!(
            max_groups(0) >= k_nodes,
            "{} shards over {n} rows cannot form {k_nodes} nodes of ≥ {r_cores} rows \
             on shard boundaries; repack with smaller shards, e.g. --shard-rows {}",
            spans.len(),
            (n / (k_nodes * 2)).max(1)
        );
        let mut node_bounds = vec![0usize; k_nodes + 1];
        node_bounds[k_nodes] = n;
        for j in 1..k_nodes {
            let prev = node_bounds[j - 1];
            // This node keeps ≥ R rows …
            let lo = prev + r_cores;
            let ideal = ((n as f64) * (j as f64) / (k_nodes as f64)).round() as i64;
            let best = cut_candidates
                .iter()
                .copied()
                // … and the suffix can still seat the remaining nodes.
                .filter(|&b| b >= lo && max_groups(b) >= k_nodes - j)
                .min_by_key(|&b| (b as i64 - ideal).abs());
            node_bounds[j] = best.ok_or_else(|| {
                anyhow::anyhow!(
                    "no viable shard boundary to cut node {j} of {k_nodes} \
                     ({} shards over {n} rows); repack with smaller shards",
                    spans.len()
                )
            })?;
        }

        let parts = (0..k_nodes)
            .map(|j| {
                let rows: Vec<usize> = (node_bounds[j]..node_bounds[j + 1]).collect();
                split_even(&rows, r_cores)
            })
            .collect();
        let p = Partition { parts };
        p.validate(n).expect("shard-aligned construction covers 0..n");
        Ok(p)
    }

    pub fn k_nodes(&self) -> usize {
        self.parts.len()
    }

    pub fn r_cores(&self) -> usize {
        self.parts.first().map_or(0, |p| p.len())
    }

    /// All indices of node `k` (flattened over cores).
    pub fn node_indices(&self, k: usize) -> Vec<usize> {
        self.parts[k].iter().flatten().copied().collect()
    }

    /// Total indices across all nodes.
    pub fn total(&self) -> usize {
        self.parts.iter().flatten().map(|c| c.len()).sum()
    }

    /// Check the exact-cover invariant: every index in `0..n` appears
    /// exactly once across all (node, core) cells.
    pub fn validate(&self, n: usize) -> anyhow::Result<()> {
        let mut seen = vec![false; n];
        for (k, node) in self.parts.iter().enumerate() {
            for (r, cell) in node.iter().enumerate() {
                anyhow::ensure!(!cell.is_empty(), "empty cell ({k},{r})");
                for &i in cell {
                    anyhow::ensure!(i < n, "index {i} out of range");
                    anyhow::ensure!(!seen[i], "index {i} assigned twice");
                    seen[i] = true;
                }
            }
        }
        anyhow::ensure!(seen.iter().all(|&s| s), "some index unassigned");
        Ok(())
    }
}

/// Split a slice into `k` nearly-equal contiguous chunks (sizes differ
/// by at most 1; earlier chunks get the remainder).
fn split_even(xs: &[usize], k: usize) -> Vec<Vec<usize>> {
    let n = xs.len();
    let base = n / k;
    let rem = n % k;
    let mut out = Vec::with_capacity(k);
    let mut pos = 0;
    for i in 0..k {
        let len = base + usize::from(i < rem);
        out.push(xs[pos..pos + len].to_vec());
        pos += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_even_sizes() {
        let xs: Vec<usize> = (0..10).collect();
        let chunks = split_even(&xs, 3);
        assert_eq!(chunks.iter().map(|c| c.len()).collect::<Vec<_>>(), vec![4, 3, 3]);
        let flat: Vec<usize> = chunks.into_iter().flatten().collect();
        assert_eq!(flat, xs);
    }

    #[test]
    fn contiguous_cover() {
        let mut rng = Rng::new(1);
        let p = Partition::build(100, 4, 3, Strategy::Contiguous, &mut rng);
        p.validate(100).unwrap();
        assert_eq!(p.k_nodes(), 4);
        assert_eq!(p.r_cores(), 3);
        assert_eq!(p.total(), 100);
        // Contiguity: node 0 holds 0..25.
        let mut n0 = p.node_indices(0);
        n0.sort_unstable();
        assert_eq!(n0, (0..25).collect::<Vec<_>>());
    }

    #[test]
    fn striped_cover_and_stripes() {
        let mut rng = Rng::new(2);
        let p = Partition::build(12, 3, 2, Strategy::Striped, &mut rng);
        p.validate(12).unwrap();
        let mut n1 = p.node_indices(1);
        n1.sort_unstable();
        assert_eq!(n1, vec![1, 4, 7, 10]);
    }

    #[test]
    fn shuffled_cover_and_differs() {
        let mut rng = Rng::new(3);
        let p = Partition::build(200, 4, 2, Strategy::Shuffled, &mut rng);
        p.validate(200).unwrap();
        let n0 = p.node_indices(0);
        assert_ne!(n0, (0..50).collect::<Vec<_>>(), "shuffle should move things");
    }

    #[test]
    fn balance_within_one() {
        let mut rng = Rng::new(4);
        let p = Partition::build(103, 4, 3, Strategy::Shuffled, &mut rng);
        let sizes: Vec<usize> =
            p.parts.iter().flatten().map(|c| c.len()).collect();
        let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        assert!(mx - mn <= 1, "sizes {sizes:?}");
    }

    #[test]
    #[should_panic(expected = "at least one row per core")]
    fn too_few_rows_panics() {
        let mut rng = Rng::new(5);
        let _ = Partition::build(5, 3, 2, Strategy::Contiguous, &mut rng);
    }

    #[test]
    fn strategy_parse() {
        assert_eq!(Strategy::parse("striped"), Some(Strategy::Striped));
        assert_eq!(Strategy::parse("SHUFFLED"), Some(Strategy::Shuffled));
        assert_eq!(Strategy::parse("x"), None);
    }

    #[test]
    fn strategy_names_roundtrip() {
        for s in [Strategy::Contiguous, Strategy::Striped, Strategy::Shuffled] {
            assert_eq!(Strategy::parse(s.name()), Some(s));
        }
    }

    fn uniform_spans(n: usize, step: usize) -> Vec<(usize, usize)> {
        (0..n).step_by(step).map(|s| (s, (s + step).min(n))).collect()
    }

    #[test]
    fn from_shards_even_boundaries_match_contiguous_build() {
        // 4 shards of 50, K = 2, R = 1: the snapped cut is exactly the
        // even cut, so the partition equals a Contiguous build.
        let spans = uniform_spans(200, 50);
        let sharded = Partition::from_shards(200, &spans, 2, 1).unwrap();
        let mut rng = Rng::new(0);
        let contiguous = Partition::build(200, 2, 1, Strategy::Contiguous, &mut rng);
        assert_eq!(sharded, contiguous);
    }

    #[test]
    fn from_shards_cuts_on_shard_boundaries() {
        // Uneven shards: every node boundary must coincide with one.
        let spans = vec![(0, 30), (30, 90), (90, 110), (110, 200)];
        let p = Partition::from_shards(200, &spans, 3, 2).unwrap();
        p.validate(200).unwrap();
        let ends: Vec<usize> = spans.iter().map(|&(_, e)| e).collect();
        for k in 0..p.k_nodes() {
            let node = p.node_indices(k);
            // Contiguous ascending disk order inside each node.
            for w in node.windows(2) {
                assert_eq!(w[1], w[0] + 1, "node {k} left disk order");
            }
            let hi = node.last().unwrap() + 1;
            assert!(
                hi == 200 || ends.contains(&hi),
                "node {k} ends at {hi}, not a shard boundary"
            );
        }
    }

    #[test]
    fn from_shards_succeeds_when_only_a_non_greedy_cut_works() {
        // Nearest-to-ideal alone would pick 34 for the first cut
        // (ideal 33), stranding the second cut with no boundary in its
        // window; the feasibility filter must steer to 31 and 63.
        let spans = vec![(0, 31), (31, 34), (34, 63), (63, 100)];
        let p = Partition::from_shards(100, &spans, 3, 30).unwrap();
        p.validate(100).unwrap();
        let sizes: Vec<usize> = (0..3).map(|k| p.node_indices(k).len()).collect();
        assert_eq!(sizes, vec![31, 32, 37]);
    }

    #[test]
    fn from_shards_too_coarse_errors_with_repack_advice() {
        // One giant shard cannot be cut for K = 2.
        let err = Partition::from_shards(100, &[(0, 100)], 2, 1).unwrap_err();
        assert!(err.to_string().contains("repack"), "{err}");
    }

    #[test]
    fn from_shards_rejects_bad_spans() {
        // Gap.
        assert!(Partition::from_shards(100, &[(0, 40), (50, 100)], 2, 1).is_err());
        // Wrong total.
        assert!(Partition::from_shards(100, &[(0, 40), (40, 90)], 2, 1).is_err());
        // Empty span.
        assert!(Partition::from_shards(100, &[(0, 0), (0, 100)], 1, 1).is_err());
        // Too few rows for K*R.
        assert!(Partition::from_shards(4, &[(0, 2), (2, 4)], 2, 4).is_err());
    }
}
