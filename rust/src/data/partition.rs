//! Data partitioning: node-level partitions `I_k` (paper §3) and
//! core-level sub-partitions `I_{k,r}` (paper §3.1).
//!
//! The paper distributes data *equally across the K nodes* and each node
//! logically divides its partition into R disjoint subparts, one per
//! core, "exclusively used by core r" — so α updates never conflict and
//! only `v` needs atomics. These invariants (exact cover, disjointness)
//! are what the property tests in `rust/tests/prop_partition.rs` check.

use crate::util::Rng;

/// How global indices are assigned to nodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Contiguous blocks (what an MPI scatter of a file does).
    Contiguous,
    /// Round-robin striping (balances heterogeneous row costs).
    Striped,
    /// Random permutation then contiguous blocks (breaks any ordering
    /// correlation in the data file; recommended default).
    Shuffled,
}

impl Strategy {
    pub fn parse(s: &str) -> Option<Strategy> {
        match s.to_ascii_lowercase().as_str() {
            "contiguous" => Some(Strategy::Contiguous),
            "striped" => Some(Strategy::Striped),
            "shuffled" => Some(Strategy::Shuffled),
            _ => None,
        }
    }
}

/// A two-level partition: `parts[k][r]` = global row indices owned by
/// core `r` of node `k`.
#[derive(Debug, Clone, PartialEq)]
pub struct Partition {
    pub parts: Vec<Vec<Vec<usize>>>,
}

impl Partition {
    /// Split `n` rows across `k_nodes × r_cores`.
    pub fn build(
        n: usize,
        k_nodes: usize,
        r_cores: usize,
        strategy: Strategy,
        rng: &mut Rng,
    ) -> Partition {
        assert!(k_nodes > 0 && r_cores > 0);
        assert!(
            n >= k_nodes * r_cores,
            "need at least one row per core: n={n}, K*R={}",
            k_nodes * r_cores
        );
        let order: Vec<usize> = match strategy {
            Strategy::Contiguous => (0..n).collect(),
            Strategy::Striped => {
                // Interleave: node k gets indices ≡ k (mod K), preserving
                // stripe order inside each node.
                let mut v = Vec::with_capacity(n);
                for k in 0..k_nodes {
                    for i in (k..n).step_by(k_nodes) {
                        v.push(i);
                    }
                }
                v
            }
            Strategy::Shuffled => {
                let mut v: Vec<usize> = (0..n).collect();
                rng.shuffle(&mut v);
                v
            }
        };
        // First level: equal contiguous chunks of `order` per node.
        let node_chunks = split_even(&order, k_nodes);
        // Second level: equal chunks per core.
        let parts = node_chunks
            .into_iter()
            .map(|chunk| split_even(&chunk, r_cores).into_iter().collect())
            .collect();
        Partition { parts }
    }

    pub fn k_nodes(&self) -> usize {
        self.parts.len()
    }

    pub fn r_cores(&self) -> usize {
        self.parts.first().map_or(0, |p| p.len())
    }

    /// All indices of node `k` (flattened over cores).
    pub fn node_indices(&self, k: usize) -> Vec<usize> {
        self.parts[k].iter().flatten().copied().collect()
    }

    /// Total indices across all nodes.
    pub fn total(&self) -> usize {
        self.parts.iter().flatten().map(|c| c.len()).sum()
    }

    /// Check the exact-cover invariant: every index in `0..n` appears
    /// exactly once across all (node, core) cells.
    pub fn validate(&self, n: usize) -> anyhow::Result<()> {
        let mut seen = vec![false; n];
        for (k, node) in self.parts.iter().enumerate() {
            for (r, cell) in node.iter().enumerate() {
                anyhow::ensure!(!cell.is_empty(), "empty cell ({k},{r})");
                for &i in cell {
                    anyhow::ensure!(i < n, "index {i} out of range");
                    anyhow::ensure!(!seen[i], "index {i} assigned twice");
                    seen[i] = true;
                }
            }
        }
        anyhow::ensure!(seen.iter().all(|&s| s), "some index unassigned");
        Ok(())
    }
}

/// Split a slice into `k` nearly-equal contiguous chunks (sizes differ
/// by at most 1; earlier chunks get the remainder).
fn split_even(xs: &[usize], k: usize) -> Vec<Vec<usize>> {
    let n = xs.len();
    let base = n / k;
    let rem = n % k;
    let mut out = Vec::with_capacity(k);
    let mut pos = 0;
    for i in 0..k {
        let len = base + usize::from(i < rem);
        out.push(xs[pos..pos + len].to_vec());
        pos += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_even_sizes() {
        let xs: Vec<usize> = (0..10).collect();
        let chunks = split_even(&xs, 3);
        assert_eq!(chunks.iter().map(|c| c.len()).collect::<Vec<_>>(), vec![4, 3, 3]);
        let flat: Vec<usize> = chunks.into_iter().flatten().collect();
        assert_eq!(flat, xs);
    }

    #[test]
    fn contiguous_cover() {
        let mut rng = Rng::new(1);
        let p = Partition::build(100, 4, 3, Strategy::Contiguous, &mut rng);
        p.validate(100).unwrap();
        assert_eq!(p.k_nodes(), 4);
        assert_eq!(p.r_cores(), 3);
        assert_eq!(p.total(), 100);
        // Contiguity: node 0 holds 0..25.
        let mut n0 = p.node_indices(0);
        n0.sort_unstable();
        assert_eq!(n0, (0..25).collect::<Vec<_>>());
    }

    #[test]
    fn striped_cover_and_stripes() {
        let mut rng = Rng::new(2);
        let p = Partition::build(12, 3, 2, Strategy::Striped, &mut rng);
        p.validate(12).unwrap();
        let mut n1 = p.node_indices(1);
        n1.sort_unstable();
        assert_eq!(n1, vec![1, 4, 7, 10]);
    }

    #[test]
    fn shuffled_cover_and_differs() {
        let mut rng = Rng::new(3);
        let p = Partition::build(200, 4, 2, Strategy::Shuffled, &mut rng);
        p.validate(200).unwrap();
        let n0 = p.node_indices(0);
        assert_ne!(n0, (0..50).collect::<Vec<_>>(), "shuffle should move things");
    }

    #[test]
    fn balance_within_one() {
        let mut rng = Rng::new(4);
        let p = Partition::build(103, 4, 3, Strategy::Shuffled, &mut rng);
        let sizes: Vec<usize> =
            p.parts.iter().flatten().map(|c| c.len()).collect();
        let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        assert!(mx - mn <= 1, "sizes {sizes:?}");
    }

    #[test]
    #[should_panic(expected = "at least one row per core")]
    fn too_few_rows_panics() {
        let mut rng = Rng::new(5);
        let _ = Partition::build(5, 3, 2, Strategy::Contiguous, &mut rng);
    }

    #[test]
    fn strategy_parse() {
        assert_eq!(Strategy::parse("striped"), Some(Strategy::Striped));
        assert_eq!(Strategy::parse("SHUFFLED"), Some(Strategy::Shuffled));
        assert_eq!(Strategy::parse("x"), None);
    }
}
