//! LIBSVM/SVMlight text format reader and writer.
//!
//! The paper's datasets (Table 1: rcv1, webspam, kddb, splicesite) are
//! distributed in this format: one line per data point,
//! `label idx:val idx:val ...` with 1-based feature indices. We cannot
//! ship the originals (up to 280 GB), but this module means any real
//! LIBSVM file drops into every binary unchanged via `--data path.svm`.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use super::csr::{CsrBuilder, CsrMatrix};
use super::dataset::Dataset;

/// One parsed LIBSVM line: the raw label (not yet mapped to ±1) plus
/// 0-based `(index, value)` entries in file order.
#[derive(Debug, Clone, PartialEq)]
pub struct ParsedRow {
    pub label: f64,
    pub entries: Vec<(u32, f64)>,
}

/// Streaming row iterator over LIBSVM text — the shared parsing core
/// behind both [`read`] (buffer everything, build one CSR) and
/// [`crate::store::pack`] (constant-memory shard conversion). Yields
/// one `Result<ParsedRow>` per data line; comments and blank lines are
/// skipped. Errors carry 1-based line numbers.
pub struct RowIter<R: BufRead> {
    lines: std::io::Lines<R>,
    lineno: usize,
}

/// Iterate parsed rows of a LIBSVM reader without materializing the
/// dataset.
pub fn rows<R: BufRead>(reader: R) -> RowIter<R> {
    RowIter { lines: reader.lines(), lineno: 0 }
}

impl<R: BufRead> Iterator for RowIter<R> {
    type Item = anyhow::Result<ParsedRow>;

    fn next(&mut self) -> Option<Self::Item> {
        loop {
            let line = match self.lines.next()? {
                Ok(l) => l,
                Err(e) => return Some(Err(e.into())),
            };
            self.lineno += 1;
            match parse_line(&line, self.lineno) {
                Ok(Some(row)) => return Some(Ok(row)),
                Ok(None) => continue, // comment / blank
                Err(e) => return Some(Err(e)),
            }
        }
    }
}

/// Parse one LIBSVM line (`lineno` is 1-based, for error messages).
/// Returns `None` for blank/comment lines. Non-finite labels and
/// values (`inf`, `NaN` — which `f64::parse` happily accepts) are
/// rejected: they would silently poison every downstream objective.
fn parse_line(raw: &str, lineno: usize) -> anyhow::Result<Option<ParsedRow>> {
    let line = raw.split('#').next().unwrap_or("").trim();
    if line.is_empty() {
        return Ok(None);
    }
    let mut parts = line.split_ascii_whitespace();
    let label_tok = parts.next().expect("non-empty line has a first token");
    let label: f64 = label_tok
        .parse()
        .map_err(|e| anyhow::anyhow!("line {lineno}: bad label '{label_tok}': {e}"))?;
    anyhow::ensure!(label.is_finite(), "line {lineno}: non-finite label '{label_tok}'");
    let mut entries = Vec::new();
    for tok in parts {
        let (idx_s, val_s) = tok
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("line {lineno}: bad pair '{tok}'"))?;
        let idx: u32 = idx_s
            .parse()
            .map_err(|e| anyhow::anyhow!("line {lineno}: bad index '{idx_s}': {e}"))?;
        anyhow::ensure!(idx >= 1, "line {lineno}: LIBSVM indices are 1-based");
        let val: f64 = val_s
            .parse()
            .map_err(|e| anyhow::anyhow!("line {lineno}: bad value '{val_s}': {e}"))?;
        anyhow::ensure!(
            val.is_finite(),
            "line {lineno}: non-finite value '{val_s}' at index {idx}"
        );
        entries.push((idx - 1, val));
    }
    Ok(Some(ParsedRow { label, entries }))
}

/// Map a raw LIBSVM label to ±1: values `> 0` → +1, `<= 0` → −1
/// (matching LIBLINEAR's binary handling of {0,1} and {−1,+1}
/// labelings).
#[inline]
pub fn map_label(label: f64) -> f64 {
    if label > 0.0 {
        1.0
    } else {
        -1.0
    }
}

/// Parse LIBSVM text from a reader into one in-memory dataset. Labels
/// are mapped to ±1 via [`map_label`].
pub fn read<R: BufRead>(reader: R, min_dim: usize) -> anyhow::Result<Dataset> {
    let mut parsed: Vec<ParsedRow> = Vec::new();
    let mut max_idx = 0u32;
    for row in rows(reader) {
        let row = row?;
        if let Some(&(idx, _)) = row.entries.iter().max_by_key(|e| e.0) {
            max_idx = max_idx.max(idx + 1);
        }
        parsed.push(row);
    }
    let dim = (max_idx as usize).max(min_dim);
    let mut b = CsrBuilder::new(dim.max(1));
    let mut labels = Vec::with_capacity(parsed.len());
    for row in parsed {
        labels.push(map_label(row.label));
        b.push_row(row.entries)?;
    }
    Ok(Dataset::new(b.finish(), labels))
}

/// Read a LIBSVM file from disk.
pub fn read_file<P: AsRef<Path>>(path: P, min_dim: usize) -> anyhow::Result<Dataset> {
    let f = std::fs::File::open(path.as_ref())
        .map_err(|e| anyhow::anyhow!("open {}: {e}", path.as_ref().display()))?;
    read(BufReader::new(f), min_dim)
}

/// Write a dataset in LIBSVM format (1-based indices).
pub fn write<W: Write>(w: &mut W, data: &Dataset) -> anyhow::Result<()> {
    let x: &CsrMatrix = &data.x;
    for i in 0..x.rows() {
        let label = data.y[i];
        write!(w, "{}", if label > 0.0 { "+1" } else { "-1" })?;
        let r = x.row(i);
        for (&j, &v) in r.indices.iter().zip(r.values.iter()) {
            write!(w, " {}:{}", j + 1, fmt_val(v))?;
        }
        writeln!(w)?;
    }
    Ok(())
}

/// Write to a file.
pub fn write_file<P: AsRef<Path>>(path: P, data: &Dataset) -> anyhow::Result<()> {
    let f = std::fs::File::create(path.as_ref())?;
    let mut w = BufWriter::new(f);
    write(&mut w, data)?;
    w.flush()?;
    Ok(())
}

fn fmt_val(v: f64) -> String {
    // Compact but lossless-enough formatting (17 sig figs round-trips f64).
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:.17e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::Rng;

    #[test]
    fn parse_basic() {
        let text = "+1 1:0.5 3:2\n-1 2:1\n";
        let ds = read(std::io::Cursor::new(text), 0).unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.d(), 3);
        assert_eq!(ds.y, vec![1.0, -1.0]);
        assert_eq!(ds.x.row(0).indices, &[0, 2]);
        assert_eq!(ds.x.row(0).values, &[0.5, 2.0]);
    }

    #[test]
    fn parse_labels_zero_one() {
        let text = "1 1:1\n0 1:1\n";
        let ds = read(std::io::Cursor::new(text), 0).unwrap();
        assert_eq!(ds.y, vec![1.0, -1.0]);
    }

    #[test]
    fn parse_skips_comments_and_blanks() {
        let text = "# header\n\n+1 1:1 # trailing\n";
        let ds = read(std::io::Cursor::new(text), 0).unwrap();
        assert_eq!(ds.n(), 1);
        assert_eq!(ds.x.nnz(), 1);
    }

    #[test]
    fn parse_errors() {
        assert!(read(std::io::Cursor::new("abc 1:1\n"), 0).is_err());
        assert!(read(std::io::Cursor::new("+1 0:1\n"), 0).is_err()); // 0-based
        assert!(read(std::io::Cursor::new("+1 1\n"), 0).is_err());
        assert!(read(std::io::Cursor::new("+1 1:x\n"), 0).is_err());
    }

    #[test]
    fn non_finite_rejected_with_line_numbers() {
        // f64::parse accepts these spellings; the reader must not.
        for bad in ["inf 1:1\n", "-inf 1:1\n", "nan 1:1\n", "NaN 1:1\n"] {
            let err = read(std::io::Cursor::new(bad), 0).unwrap_err();
            assert!(err.to_string().contains("line 1"), "{bad:?}: {err}");
            assert!(err.to_string().contains("non-finite label"), "{bad:?}: {err}");
        }
        for bad in ["+1 1:inf\n", "+1 1:nan\n", "-1 2:-inf\n"] {
            let err = read(std::io::Cursor::new(bad), 0).unwrap_err();
            assert!(err.to_string().contains("non-finite value"), "{bad:?}: {err}");
        }
        // The line number points at the offending line, not the count
        // of data rows seen so far.
        let text = "# header\n+1 1:1\n\n+1 2:nan\n";
        let err = read(std::io::Cursor::new(text), 0).unwrap_err();
        assert!(err.to_string().contains("line 4"), "{err}");
    }

    #[test]
    fn row_iter_streams_without_building() {
        let text = "# c\n+1 1:0.5 3:2\n\n-1 2:1\n";
        let parsed: Vec<ParsedRow> =
            rows(std::io::Cursor::new(text)).collect::<anyhow::Result<_>>().unwrap();
        assert_eq!(parsed.len(), 2);
        assert_eq!(parsed[0].label, 1.0);
        assert_eq!(parsed[0].entries, vec![(0, 0.5), (2, 2.0)]);
        assert_eq!(parsed[1].label, -1.0);
    }

    #[test]
    fn min_dim_respected() {
        let ds = read(std::io::Cursor::new("+1 1:1\n"), 10).unwrap();
        assert_eq!(ds.d(), 10);
    }

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(21);
        let ds = synth::Preset::Tiny.generate(&mut rng);
        let mut buf = Vec::new();
        write(&mut buf, &ds).unwrap();
        let ds2 = read(std::io::Cursor::new(buf), ds.d()).unwrap();
        assert_eq!(ds2.n(), ds.n());
        assert_eq!(ds2.d(), ds.d());
        assert_eq!(ds2.y, ds.y);
        for i in 0..ds.n() {
            let (a, b) = (ds.x.row(i), ds2.x.row(i));
            assert_eq!(a.indices, b.indices);
            for (&u, &v) in a.values.iter().zip(b.values.iter()) {
                assert!((u - v).abs() <= 1e-15 * u.abs().max(1.0));
            }
        }
    }

    #[test]
    fn file_roundtrip() {
        let mut rng = Rng::new(22);
        let ds = synth::Preset::Tiny.generate(&mut rng);
        let path = std::env::temp_dir().join("hybrid_dca_libsvm_test.svm");
        write_file(&path, &ds).unwrap();
        let ds2 = read_file(&path, ds.d()).unwrap();
        assert_eq!(ds2.n(), ds.n());
        std::fs::remove_file(&path).ok();
    }
}
