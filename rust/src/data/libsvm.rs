//! LIBSVM/SVMlight text format reader and writer.
//!
//! The paper's datasets (Table 1: rcv1, webspam, kddb, splicesite) are
//! distributed in this format: one line per data point,
//! `label idx:val idx:val ...` with 1-based feature indices. We cannot
//! ship the originals (up to 280 GB), but this module means any real
//! LIBSVM file drops into every binary unchanged via `--data path.svm`.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use super::csr::{CsrBuilder, CsrMatrix};
use super::dataset::Dataset;

/// Parse LIBSVM text from a reader. Labels are mapped to ±1: values
/// `> 0` → +1, `<= 0` → −1 (matching LIBLINEAR's binary handling of
/// {0,1} and {−1,+1} labelings).
pub fn read<R: BufRead>(reader: R, min_dim: usize) -> anyhow::Result<Dataset> {
    let mut rows: Vec<(f64, Vec<(u32, f64)>)> = Vec::new();
    let mut max_idx = 0u32;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let label_tok = parts.next().unwrap();
        let label: f64 = label_tok
            .parse()
            .map_err(|e| anyhow::anyhow!("line {}: bad label '{label_tok}': {e}", lineno + 1))?;
        let mut entries = Vec::new();
        for tok in parts {
            let (idx_s, val_s) = tok
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("line {}: bad pair '{tok}'", lineno + 1))?;
            let idx: u32 = idx_s
                .parse()
                .map_err(|e| anyhow::anyhow!("line {}: bad index '{idx_s}': {e}", lineno + 1))?;
            anyhow::ensure!(idx >= 1, "line {}: LIBSVM indices are 1-based", lineno + 1);
            let val: f64 = val_s
                .parse()
                .map_err(|e| anyhow::anyhow!("line {}: bad value '{val_s}': {e}", lineno + 1))?;
            max_idx = max_idx.max(idx);
            entries.push((idx - 1, val));
        }
        rows.push((label, entries));
    }
    let dim = (max_idx as usize).max(min_dim);
    let mut b = CsrBuilder::new(dim.max(1));
    let mut labels = Vec::with_capacity(rows.len());
    for (label, entries) in rows {
        labels.push(if label > 0.0 { 1.0 } else { -1.0 });
        b.push_row(entries)?;
    }
    Ok(Dataset::new(b.finish(), labels))
}

/// Read a LIBSVM file from disk.
pub fn read_file<P: AsRef<Path>>(path: P, min_dim: usize) -> anyhow::Result<Dataset> {
    let f = std::fs::File::open(path.as_ref())
        .map_err(|e| anyhow::anyhow!("open {}: {e}", path.as_ref().display()))?;
    read(BufReader::new(f), min_dim)
}

/// Write a dataset in LIBSVM format (1-based indices).
pub fn write<W: Write>(w: &mut W, data: &Dataset) -> anyhow::Result<()> {
    let x: &CsrMatrix = &data.x;
    for i in 0..x.rows() {
        let label = data.y[i];
        write!(w, "{}", if label > 0.0 { "+1" } else { "-1" })?;
        let r = x.row(i);
        for (&j, &v) in r.indices.iter().zip(r.values.iter()) {
            write!(w, " {}:{}", j + 1, fmt_val(v))?;
        }
        writeln!(w)?;
    }
    Ok(())
}

/// Write to a file.
pub fn write_file<P: AsRef<Path>>(path: P, data: &Dataset) -> anyhow::Result<()> {
    let f = std::fs::File::create(path.as_ref())?;
    let mut w = BufWriter::new(f);
    write(&mut w, data)?;
    w.flush()?;
    Ok(())
}

fn fmt_val(v: f64) -> String {
    // Compact but lossless-enough formatting (17 sig figs round-trips f64).
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:.17e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::Rng;

    #[test]
    fn parse_basic() {
        let text = "+1 1:0.5 3:2\n-1 2:1\n";
        let ds = read(std::io::Cursor::new(text), 0).unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.d(), 3);
        assert_eq!(ds.y, vec![1.0, -1.0]);
        assert_eq!(ds.x.row(0).indices, &[0, 2]);
        assert_eq!(ds.x.row(0).values, &[0.5, 2.0]);
    }

    #[test]
    fn parse_labels_zero_one() {
        let text = "1 1:1\n0 1:1\n";
        let ds = read(std::io::Cursor::new(text), 0).unwrap();
        assert_eq!(ds.y, vec![1.0, -1.0]);
    }

    #[test]
    fn parse_skips_comments_and_blanks() {
        let text = "# header\n\n+1 1:1 # trailing\n";
        let ds = read(std::io::Cursor::new(text), 0).unwrap();
        assert_eq!(ds.n(), 1);
        assert_eq!(ds.x.nnz(), 1);
    }

    #[test]
    fn parse_errors() {
        assert!(read(std::io::Cursor::new("abc 1:1\n"), 0).is_err());
        assert!(read(std::io::Cursor::new("+1 0:1\n"), 0).is_err()); // 0-based
        assert!(read(std::io::Cursor::new("+1 1\n"), 0).is_err());
        assert!(read(std::io::Cursor::new("+1 1:x\n"), 0).is_err());
    }

    #[test]
    fn min_dim_respected() {
        let ds = read(std::io::Cursor::new("+1 1:1\n"), 10).unwrap();
        assert_eq!(ds.d(), 10);
    }

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(21);
        let ds = synth::Preset::Tiny.generate(&mut rng);
        let mut buf = Vec::new();
        write(&mut buf, &ds).unwrap();
        let ds2 = read(std::io::Cursor::new(buf), ds.d()).unwrap();
        assert_eq!(ds2.n(), ds.n());
        assert_eq!(ds2.d(), ds.d());
        assert_eq!(ds2.y, ds.y);
        for i in 0..ds.n() {
            let (a, b) = (ds.x.row(i), ds2.x.row(i));
            assert_eq!(a.indices, b.indices);
            for (&u, &v) in a.values.iter().zip(b.values.iter()) {
                assert!((u - v).abs() <= 1e-15 * u.abs().max(1.0));
            }
        }
    }

    #[test]
    fn file_roundtrip() {
        let mut rng = Rng::new(22);
        let ds = synth::Preset::Tiny.generate(&mut rng);
        let path = std::env::temp_dir().join("hybrid_dca_libsvm_test.svm");
        write_file(&path, &ds).unwrap();
        let ds2 = read_file(&path, ds.d()).unwrap();
        assert_eq!(ds2.n(), ds.n());
        std::fs::remove_file(&path).ok();
    }
}
