//! Synthetic dataset generators standing in for the paper's LIBSVM
//! datasets (Table 1).
//!
//! Substitution rationale (DESIGN.md §3): the originals range up to
//! 280 GB (splicesite) and are not available offline. (S)DCA convergence
//! behaviour is governed by the dataset's *shape statistics* — n, d,
//! nnz/row, feature-frequency skew, label noise, margin — so each preset
//! reproduces those statistics scaled down ~1000× in nnz while keeping
//! the paper's n:d ratios and densities. The generator plants a sparse
//! ground-truth separator `w*` and labels points by `sign(x·w*)` with
//! configurable flip noise, so hinge-SVM duality-gap trajectories are
//! non-trivial (neither instantly separable nor pure noise).

use super::csr::{CsrBuilder, CsrMatrix};
use super::dataset::Dataset;
use crate::util::Rng;

/// Generator parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct SynthSpec {
    pub name: String,
    /// Number of data points.
    pub n: usize,
    /// Feature dimension.
    pub d: usize,
    /// Mean nonzeros per row.
    pub nnz_per_row: usize,
    /// Zipf skew for feature popularity (0 = uniform). Text datasets
    /// like rcv1 have heavily skewed feature frequencies.
    pub feature_skew: f64,
    /// Fraction of labels flipped after planting the separator.
    pub label_noise: f64,
    /// Density of the planted separator w*.
    pub separator_density: f64,
    /// Number of "topics" (shared sparse feature templates). Real text
    /// corpora have heavily *correlated* columns — near-duplicate
    /// documents sharing feature supports — which is exactly what slows
    /// coordinate descent (the `M` constant in the paper's Assumption
    /// 1/4). 0 disables topic structure (independent features).
    pub topics: usize,
    /// Fraction of each row's nonzeros drawn from its topic template.
    pub topic_mix: f64,
}

/// Named presets mirroring the paper's Table 1 datasets, ~1000× smaller.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Preset {
    /// Minimal smoke-test dataset.
    Tiny,
    /// rcv1: n≫d? no — n=677k, d=47k, ~73 nnz/row, skewed text features.
    RcvS,
    /// webspam: n=280k, d=16.6M (d≫n), ~3732 nnz/row.
    WebspamS,
    /// kddb: n=19.3M, d=29.9M, ~29 nnz/row, extremely sparse.
    KddbS,
    /// splicesite: n=4.6M, d=11.7M, ~3324 nnz/row, 280 GB — the "big"
    /// dataset of Fig. 7. Largest preset here.
    SplicesiteS,
}

pub const ALL_PRESETS: [Preset; 5] =
    [Preset::Tiny, Preset::RcvS, Preset::WebspamS, Preset::KddbS, Preset::SplicesiteS];

impl Preset {
    pub fn parse(s: &str) -> Option<Preset> {
        match s.to_ascii_lowercase().as_str() {
            "tiny" => Some(Preset::Tiny),
            "rcv1-s" | "rcv1" => Some(Preset::RcvS),
            "webspam-s" | "webspam" => Some(Preset::WebspamS),
            "kddb-s" | "kddb" => Some(Preset::KddbS),
            "splicesite-s" | "splicesite" => Some(Preset::SplicesiteS),
            _ => None,
        }
    }

    pub fn spec(self) -> SynthSpec {
        // Scale: paper nnz / ~1000, preserving n:d ratio and nnz/row
        // within practical bounds for the test machine.
        match self {
            Preset::Tiny => SynthSpec {
                name: "tiny".into(),
                n: 200,
                d: 50,
                nnz_per_row: 10,
                feature_skew: 0.5,
                label_noise: 0.05,
                separator_density: 0.5,
                topics: 0,
                topic_mix: 0.0,
            },
            Preset::RcvS => SynthSpec {
                name: "rcv1-s".into(),
                // paper: n=677,399 d=47,236 nnz=49.5M (73/row)
                n: 8_000,
                d: 560,
                nnz_per_row: 73,
                feature_skew: 1.0,
                label_noise: 0.20,
                separator_density: 0.3,
                topics: 40,
                topic_mix: 0.7,
            },
            Preset::WebspamS => SynthSpec {
                name: "webspam-s".into(),
                // paper: n=280,000 d=16,609,143 nnz=1.045G (3732/row)
                n: 2_000,
                d: 120_000,
                nnz_per_row: 500,
                feature_skew: 0.8,
                label_noise: 0.10,
                separator_density: 0.05,
                topics: 25,
                topic_mix: 0.7,
            },
            Preset::KddbS => SynthSpec {
                name: "kddb-s".into(),
                // paper: n=19,264,097 d=29,890,095 nnz=566M (29/row)
                n: 20_000,
                d: 31_000,
                nnz_per_row: 29,
                feature_skew: 1.1,
                label_noise: 0.20,
                separator_density: 0.1,
                topics: 60,
                topic_mix: 0.6,
            },
            Preset::SplicesiteS => SynthSpec {
                name: "splicesite-s".into(),
                // paper: n=4,627,840 d=11,725,480 nnz=15.4G (3324/row)
                n: 12_000,
                d: 30_000,
                nnz_per_row: 420,
                feature_skew: 0.6,
                label_noise: 0.12,
                separator_density: 0.05,
                topics: 50,
                topic_mix: 0.7,
            },
        }
    }

    pub fn generate(self, rng: &mut Rng) -> Dataset {
        generate(&self.spec(), rng)
    }
}

/// Sample a feature index with Zipf-like popularity skew via inverse
/// power transform of a uniform: `floor(d * u^(1/(1-s)))` clamped.
/// s=0 reduces to uniform.
#[inline]
fn skewed_index(rng: &mut Rng, d: usize, skew: f64) -> u32 {
    if skew <= 0.0 {
        return rng.next_below(d) as u32;
    }
    let u = rng.next_f64().max(1e-12);
    // Power-law rank sampling: smaller ranks exponentially more likely.
    let exponent = 1.0 / (1.0 + skew);
    let r = (d as f64 * u.powf(1.0 / exponent)).min(d as f64 - 1.0);
    r as u32
}

/// Generate a dataset from a spec.
pub fn generate(spec: &SynthSpec, rng: &mut Rng) -> Dataset {
    assert!(spec.n > 0 && spec.d > 0 && spec.nnz_per_row > 0);
    // Plant a sparse unit separator w*.
    let k_sep = ((spec.d as f64 * spec.separator_density) as usize).clamp(1, spec.d);
    let sep_idx = rng.sample_indices(spec.d, k_sep);
    let mut w_star = vec![0.0f64; spec.d];
    for &j in &sep_idx {
        w_star[j] = rng.next_gaussian();
    }
    let norm = crate::util::norm_sq(&w_star).sqrt().max(1e-12);
    for w in w_star.iter_mut() {
        *w /= norm;
    }

    // Topic templates: sparse (feature, value) lists rows sample from.
    let template_len = (spec.nnz_per_row * 2).min(spec.d).max(1);
    let topic_templates: Vec<Vec<(u32, f64)>> = (0..spec.topics)
        .map(|_| {
            let mut t = Vec::with_capacity(template_len);
            let mut seen = std::collections::HashSet::with_capacity(template_len * 2);
            while t.len() < template_len {
                let j = skewed_index(rng, spec.d, spec.feature_skew);
                if seen.insert(j) {
                    t.push((j, rng.next_gaussian()));
                }
            }
            t
        })
        .collect();

    let mut b = CsrBuilder::new(spec.d);
    let mut labels = Vec::with_capacity(spec.n);
    let mut scratch: Vec<(u32, f64)> = Vec::with_capacity(spec.nnz_per_row * 2);
    for _ in 0..spec.n {
        // Row nnz jitter ±50% keeps per-update costs heterogeneous, which
        // matters for the virtual-clock model.
        let lo = (spec.nnz_per_row / 2).max(1);
        let hi = (spec.nnz_per_row * 3 / 2).min(spec.d).max(lo);
        let k = rng.next_range(lo, hi);
        scratch.clear();
        let mut seen = std::collections::HashSet::with_capacity(k * 2);
        // Topic part: correlated supports and correlated values (the
        // value is the template's, jittered — near-duplicate rows).
        if spec.topics > 0 && spec.topic_mix > 0.0 {
            let tpl = &topic_templates[rng.next_below(spec.topics)];
            let k_topic = ((k as f64 * spec.topic_mix) as usize).min(tpl.len());
            for &(j, val) in rng
                .sample_indices(tpl.len(), k_topic)
                .into_iter()
                .map(|idx| &tpl[idx])
            {
                if seen.insert(j) {
                    scratch.push((j, val * (1.0 + 0.3 * rng.next_gaussian())));
                }
            }
        }
        while scratch.len() < k {
            let j = skewed_index(rng, spec.d, spec.feature_skew);
            if seen.insert(j) {
                scratch.push((j, rng.next_gaussian()));
            }
        }
        // Normalize rows to unit norm (standard for rcv1-style text data;
        // keeps ‖x_i‖² ≈ 1 so closed-form steps are well scaled).
        let nrm = scratch.iter().map(|(_, v)| v * v).sum::<f64>().sqrt().max(1e-12);
        for e in scratch.iter_mut() {
            e.1 /= nrm;
        }
        let margin: f64 = scratch.iter().map(|&(j, v)| v * w_star[j as usize]).sum();
        let mut label = if margin >= 0.0 { 1.0 } else { -1.0 };
        if rng.next_bool(spec.label_noise) {
            label = -label;
        }
        labels.push(label);
        b.push_row(scratch.clone()).expect("generated rows are valid");
    }
    Dataset::new(b.finish(), labels).with_name(spec.name.clone())
}

/// Convenience: generate a plain random dataset (used by tests that do
/// not care about label structure).
pub fn random_dataset(rng: &mut Rng, n: usize, d: usize, nnz_per_row: usize) -> Dataset {
    let x = CsrMatrix::random(rng, n, d, nnz_per_row);
    let y: Vec<f64> = (0..n).map(|_| if rng.next_bool(0.5) { 1.0 } else { -1.0 }).collect();
    Dataset::new(x, y).with_name("random")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_parse() {
        assert_eq!(Preset::parse("rcv1-s"), Some(Preset::RcvS));
        assert_eq!(Preset::parse("RCV1"), Some(Preset::RcvS));
        assert_eq!(Preset::parse("nope"), None);
        for p in ALL_PRESETS {
            assert!(Preset::parse(&p.spec().name).is_some());
        }
    }

    #[test]
    fn tiny_generates_valid() {
        let mut rng = Rng::new(42);
        let ds = Preset::Tiny.generate(&mut rng);
        ds.validate().unwrap();
        assert_eq!(ds.n(), 200);
        assert_eq!(ds.d(), 50);
    }

    #[test]
    fn generation_deterministic() {
        let a = Preset::Tiny.generate(&mut Rng::new(7));
        let b = Preset::Tiny.generate(&mut Rng::new(7));
        assert_eq!(a, b);
    }

    #[test]
    fn rows_unit_norm() {
        let mut rng = Rng::new(3);
        let ds = Preset::Tiny.generate(&mut rng);
        for i in 0..ds.n() {
            let ns = ds.x.row(i).norm_sq();
            assert!((ns - 1.0).abs() < 1e-9, "row {i} norm² = {ns}");
        }
    }

    #[test]
    fn labels_mostly_separable() {
        // With 5% noise the planted separator classifies ≥85% correctly,
        // so a trained SVM must beat chance. Verify via the margin of the
        // generating separator reconstruction: labels should not be 50/50
        // independent of x. Quick proxy: majority agreement between two
        // nearby rows sharing features is above chance — instead we just
        // check both classes present and noise level is sane.
        let mut rng = Rng::new(11);
        let ds = Preset::Tiny.generate(&mut rng);
        let pos = ds.y.iter().filter(|&&y| y > 0.0).count();
        assert!(pos > 10 && pos < ds.n() - 10, "degenerate labels: {pos}");
    }

    #[test]
    fn skewed_index_in_range_and_skewed() {
        let mut rng = Rng::new(5);
        let d = 1000;
        let mut low = 0usize;
        for _ in 0..10_000 {
            let j = skewed_index(&mut rng, d, 1.0) as usize;
            assert!(j < d);
            if j < d / 10 {
                low += 1;
            }
        }
        // With skew 1.0 the first decile should receive far more than 10%.
        assert!(low > 1_500, "low-decile hits = {low}");
    }

    #[test]
    fn nnz_matches_spec_roughly() {
        let mut rng = Rng::new(9);
        let spec = Preset::RcvS.spec();
        let ds = generate(&spec, &mut rng);
        let mean_nnz = ds.x.nnz() as f64 / ds.n() as f64;
        let target = spec.nnz_per_row as f64;
        assert!((mean_nnz - target).abs() < target * 0.2, "mean nnz {mean_nnz} vs {target}");
    }

    #[test]
    fn random_dataset_valid() {
        let mut rng = Rng::new(13);
        let ds = random_dataset(&mut rng, 30, 10, 3);
        ds.validate().unwrap();
    }
}
