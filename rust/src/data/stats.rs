//! Dataset statistics — regenerates the paper's Table 1 columns
//! (n, d, nnz, file size) plus extras the analysis cares about
//! (density, nnz/row distribution, label balance).

use super::dataset::Dataset;

#[derive(Debug, Clone, PartialEq)]
pub struct DatasetStats {
    pub name: String,
    pub n: usize,
    pub d: usize,
    pub nnz: usize,
    pub density: f64,
    pub nnz_per_row_mean: f64,
    pub nnz_per_row_max: usize,
    pub positive_fraction: f64,
    /// Estimated LIBSVM file size in bytes (what Table 1's last column
    /// reports): label + ~14 bytes per nnz ("idx:val ").
    pub est_file_bytes: u64,
}

impl DatasetStats {
    pub fn compute(ds: &Dataset) -> DatasetStats {
        let n = ds.n();
        let nnz = ds.x.nnz();
        let mut max_row = 0usize;
        for i in 0..n {
            max_row = max_row.max(ds.x.row(i).nnz());
        }
        let pos = ds.y.iter().filter(|&&y| y > 0.0).count();
        DatasetStats {
            name: ds.name.clone(),
            n,
            d: ds.d(),
            nnz,
            density: ds.x.density(),
            nnz_per_row_mean: if n == 0 { 0.0 } else { nnz as f64 / n as f64 },
            nnz_per_row_max: max_row,
            positive_fraction: if n == 0 { 0.0 } else { pos as f64 / n as f64 },
            est_file_bytes: (n as u64) * 3 + (nnz as u64) * 14,
        }
    }

    /// Human-readable size like Table 1's "1.2 GB".
    pub fn human_size(&self) -> String {
        let b = self.est_file_bytes as f64;
        if b >= 1e9 {
            format!("{:.1} GB", b / 1e9)
        } else if b >= 1e6 {
            format!("{:.1} MB", b / 1e6)
        } else if b >= 1e3 {
            format!("{:.1} KB", b / 1e3)
        } else {
            format!("{b:.0} B")
        }
    }

    /// One row of the Table-1-style report.
    pub fn table_row(&self) -> String {
        format!(
            "{:<14} {:>10} {:>10} {:>12} {:>10.6} {:>8.1} {:>9}",
            self.name,
            self.n,
            self.d,
            self.nnz,
            self.density,
            self.nnz_per_row_mean,
            self.human_size()
        )
    }

    pub fn table_header() -> String {
        format!(
            "{:<14} {:>10} {:>10} {:>12} {:>10} {:>8} {:>9}",
            "dataset", "n", "d", "nnz", "density", "nnz/row", "size"
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::Preset;
    use crate::util::Rng;

    #[test]
    fn stats_tiny() {
        let ds = Preset::Tiny.generate(&mut Rng::new(1));
        let s = DatasetStats::compute(&ds);
        assert_eq!(s.n, 200);
        assert_eq!(s.d, 50);
        assert!(s.nnz > 0);
        assert!((s.density - s.nnz as f64 / (200.0 * 50.0)).abs() < 1e-12);
        assert!(s.positive_fraction > 0.0 && s.positive_fraction < 1.0);
        assert!(s.nnz_per_row_max >= s.nnz_per_row_mean as usize);
    }

    #[test]
    fn human_sizes() {
        let mut s = DatasetStats::compute(&Preset::Tiny.generate(&mut Rng::new(1)));
        s.est_file_bytes = 500;
        assert_eq!(s.human_size(), "500 B");
        s.est_file_bytes = 2_500;
        assert_eq!(s.human_size(), "2.5 KB");
        s.est_file_bytes = 3_000_000;
        assert_eq!(s.human_size(), "3.0 MB");
        s.est_file_bytes = 4_200_000_000;
        assert_eq!(s.human_size(), "4.2 GB");
    }

    #[test]
    fn table_formatting() {
        let ds = Preset::Tiny.generate(&mut Rng::new(1));
        let s = DatasetStats::compute(&ds);
        let header = DatasetStats::table_header();
        let row = s.table_row();
        assert!(header.contains("dataset"));
        assert!(row.contains("tiny"));
    }
}
