//! A labelled sparse dataset: the unit every solver consumes.

use super::csr::CsrMatrix;

/// Sparse binary-classification dataset (labels in {−1, +1}).
#[derive(Debug, Clone, PartialEq)]
pub struct Dataset {
    pub x: CsrMatrix,
    pub y: Vec<f64>,
    /// Optional human-readable name (preset or file stem).
    pub name: String,
}

impl Dataset {
    pub fn new(x: CsrMatrix, y: Vec<f64>) -> Self {
        assert_eq!(x.rows(), y.len(), "labels must match rows");
        Self { x, y, name: String::new() }
    }

    pub fn with_name(mut self, name: impl Into<String>) -> Self {
        self.name = name.into();
        self
    }

    /// Number of data points.
    #[inline]
    pub fn n(&self) -> usize {
        self.x.rows()
    }

    /// Feature dimension.
    #[inline]
    pub fn d(&self) -> usize {
        self.x.dim()
    }

    /// Validate structure: CSR invariants plus ±1 labels.
    pub fn validate(&self) -> anyhow::Result<()> {
        self.x.validate()?;
        anyhow::ensure!(self.x.rows() == self.y.len(), "label count mismatch");
        for (i, &y) in self.y.iter().enumerate() {
            anyhow::ensure!(y == 1.0 || y == -1.0, "label[{i}] = {y} not ±1");
        }
        Ok(())
    }

    /// Restrict to a subset of rows.
    pub fn select(&self, rows: &[usize]) -> Dataset {
        Dataset {
            x: self.x.select_rows(rows),
            y: rows.iter().map(|&i| self.y[i]).collect(),
            name: self.name.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::csr::CsrBuilder;

    fn tiny() -> Dataset {
        let mut b = CsrBuilder::new(2);
        b.push_row(vec![(0, 1.0)]).unwrap();
        b.push_row(vec![(1, -1.0)]).unwrap();
        Dataset::new(b.finish(), vec![1.0, -1.0]).with_name("tiny")
    }

    #[test]
    fn accessors() {
        let ds = tiny();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.d(), 2);
        assert_eq!(ds.name, "tiny");
        ds.validate().unwrap();
    }

    #[test]
    fn validate_rejects_bad_labels() {
        let mut ds = tiny();
        ds.y[0] = 0.5;
        assert!(ds.validate().is_err());
    }

    #[test]
    #[should_panic(expected = "labels must match rows")]
    fn mismatched_labels_panic() {
        let mut b = CsrBuilder::new(2);
        b.push_row(vec![(0, 1.0)]).unwrap();
        let _ = Dataset::new(b.finish(), vec![1.0, -1.0]);
    }

    #[test]
    fn select_subset() {
        let ds = tiny();
        let s = ds.select(&[1]);
        assert_eq!(s.n(), 1);
        assert_eq!(s.y, vec![-1.0]);
    }
}
