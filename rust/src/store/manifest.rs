//! The store manifest: one JSON document (`manifest.json`, written and
//! parsed by `util/json` — no serde) describing the whole sharded
//! dataset: global dims, the pack-time row-order [`Strategy`], and one
//! entry per shard with its row span, sizes, CRC, and `data::stats`
//! summary. The manifest is the only file a reader must parse before
//! deciding which shards to touch — `data inspect` and shard-aware
//! partitioning work from it without opening any shard.

use std::path::{Path, PathBuf};

use crate::data::{Dataset, DatasetStats, Strategy};
use crate::util::json::Json;

/// Manifest file name inside a store directory.
pub const MANIFEST_FILE: &str = "manifest.json";
/// Format marker embedded in the JSON, so a stray JSON file is never
/// mistaken for a store.
pub const FORMAT_MARKER: &str = "hybrid-dca-shard-store";
/// Manifest schema version.
pub const MANIFEST_VERSION: u64 = 1;

/// Per-shard shape statistics (the `data::stats` columns that make
/// sense per block). Stored so `data inspect` reports Table-1-style
/// numbers without decoding a single shard.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardStats {
    pub density: f64,
    pub nnz_per_row_mean: f64,
    pub nnz_per_row_max: usize,
    pub positive_fraction: f64,
}

impl ShardStats {
    /// Compute from an in-memory shard via [`DatasetStats`].
    pub fn compute(shard: &Dataset) -> ShardStats {
        let s = DatasetStats::compute(shard);
        ShardStats {
            density: s.density,
            nnz_per_row_mean: s.nnz_per_row_mean,
            nnz_per_row_max: s.nnz_per_row_max,
            positive_fraction: s.positive_fraction,
        }
    }
}

/// One shard's manifest entry.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardEntry {
    /// File name relative to the store directory.
    pub path: String,
    /// Global row span `[row_start, row_end)`.
    pub row_start: usize,
    pub row_end: usize,
    pub nnz: usize,
    /// Encoded file size in bytes.
    pub bytes: u64,
    /// The shard file's trailing CRC-32, duplicated here so `inspect`
    /// can cross-check manifest↔file without recomputing.
    pub crc32: u32,
    pub stats: ShardStats,
}

impl ShardEntry {
    #[inline]
    pub fn rows(&self) -> usize {
        self.row_end - self.row_start
    }
}

/// The full store description.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    /// Dataset name (preset name or input file stem).
    pub name: String,
    /// Global dims: rows, features, nonzeros.
    pub n: usize,
    pub d: usize,
    pub nnz: usize,
    /// Row order the packer wrote: `Contiguous` = input order,
    /// `Shuffled` = permuted at pack time with `seed`. Shard-aware
    /// partitions always read disk order; this records what that order
    /// *means*.
    pub strategy: Strategy,
    /// Seed of the pack-time permutation (0 when `Contiguous`).
    pub seed: u64,
    pub shards: Vec<ShardEntry>,
}

fn get<'a>(j: &'a Json, key: &str) -> anyhow::Result<&'a Json> {
    j.get(key).ok_or_else(|| anyhow::anyhow!("manifest missing key '{key}'"))
}

fn get_f64(j: &Json, key: &str) -> anyhow::Result<f64> {
    get(j, key)?
        .as_f64()
        .ok_or_else(|| anyhow::anyhow!("manifest key '{key}' is not a number"))
}

fn get_usize(j: &Json, key: &str) -> anyhow::Result<usize> {
    let x = get_f64(j, key)?;
    anyhow::ensure!(
        x >= 0.0 && x.fract() == 0.0 && x <= 2f64.powi(53),
        "manifest key '{key}' = {x} is not a non-negative integer"
    );
    Ok(x as usize)
}

fn get_str<'a>(j: &'a Json, key: &str) -> anyhow::Result<&'a str> {
    get(j, key)?
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("manifest key '{key}' is not a string"))
}

impl Manifest {
    /// Serialize to the JSON document layout.
    pub fn to_json(&self) -> Json {
        let shards = self
            .shards
            .iter()
            .map(|s| {
                Json::Obj(vec![
                    ("path".into(), Json::Str(s.path.clone())),
                    ("row_start".into(), Json::Num(s.row_start as f64)),
                    ("row_end".into(), Json::Num(s.row_end as f64)),
                    ("nnz".into(), Json::Num(s.nnz as f64)),
                    ("bytes".into(), Json::Num(s.bytes as f64)),
                    ("crc32".into(), Json::Num(s.crc32 as f64)),
                    (
                        "stats".into(),
                        Json::Obj(vec![
                            ("density".into(), Json::Num(s.stats.density)),
                            (
                                "nnz_per_row_mean".into(),
                                Json::Num(s.stats.nnz_per_row_mean),
                            ),
                            (
                                "nnz_per_row_max".into(),
                                Json::Num(s.stats.nnz_per_row_max as f64),
                            ),
                            (
                                "positive_fraction".into(),
                                Json::Num(s.stats.positive_fraction),
                            ),
                        ]),
                    ),
                ])
            })
            .collect();
        Json::Obj(vec![
            ("format".into(), Json::Str(FORMAT_MARKER.into())),
            ("version".into(), Json::Num(MANIFEST_VERSION as f64)),
            ("name".into(), Json::Str(self.name.clone())),
            ("n".into(), Json::Num(self.n as f64)),
            ("d".into(), Json::Num(self.d as f64)),
            ("nnz".into(), Json::Num(self.nnz as f64)),
            ("strategy".into(), Json::Str(self.strategy.name().into())),
            ("seed".into(), Json::Num(self.seed as f64)),
            ("shards".into(), Json::Arr(shards)),
        ])
    }

    /// Parse from the JSON document layout.
    pub fn from_json(j: &Json) -> anyhow::Result<Manifest> {
        let marker = get_str(j, "format")?;
        anyhow::ensure!(
            marker == FORMAT_MARKER,
            "not a shard-store manifest (format marker '{marker}')"
        );
        let version = get_usize(j, "version")? as u64;
        anyhow::ensure!(
            version == MANIFEST_VERSION,
            "unsupported manifest version {version} (this build reads {MANIFEST_VERSION})"
        );
        let strategy_s = get_str(j, "strategy")?;
        let strategy = Strategy::parse(strategy_s)
            .ok_or_else(|| anyhow::anyhow!("unknown pack strategy '{strategy_s}'"))?;
        let shards_json = get(j, "shards")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("manifest 'shards' is not an array"))?;
        let mut shards = Vec::with_capacity(shards_json.len());
        for sj in shards_json {
            let stats_j = get(sj, "stats")?;
            shards.push(ShardEntry {
                path: get_str(sj, "path")?.to_string(),
                row_start: get_usize(sj, "row_start")?,
                row_end: get_usize(sj, "row_end")?,
                nnz: get_usize(sj, "nnz")?,
                bytes: get_usize(sj, "bytes")? as u64,
                crc32: u32::try_from(get_usize(sj, "crc32")?)
                    .map_err(|_| anyhow::anyhow!("shard crc32 out of u32 range"))?,
                stats: ShardStats {
                    density: get_f64(stats_j, "density")?,
                    nnz_per_row_mean: get_f64(stats_j, "nnz_per_row_mean")?,
                    nnz_per_row_max: get_usize(stats_j, "nnz_per_row_max")?,
                    positive_fraction: get_f64(stats_j, "positive_fraction")?,
                },
            });
        }
        let m = Manifest {
            name: get_str(j, "name")?.to_string(),
            n: get_usize(j, "n")?,
            d: get_usize(j, "d")?,
            nnz: get_usize(j, "nnz")?,
            strategy,
            seed: get_usize(j, "seed")? as u64,
            shards,
        };
        m.validate()?;
        Ok(m)
    }

    /// Structural validation: spans tile `0..n` in order, totals agree.
    pub fn validate(&self) -> anyhow::Result<()> {
        let mut expect = 0usize;
        let mut nnz = 0usize;
        for (i, s) in self.shards.iter().enumerate() {
            anyhow::ensure!(
                s.row_start == expect && s.row_end > s.row_start,
                "shard {i} spans [{}, {}) where start {expect} was expected",
                s.row_start,
                s.row_end
            );
            anyhow::ensure!(!s.path.is_empty(), "shard {i} has an empty path");
            expect = s.row_end;
            nnz += s.nnz;
        }
        anyhow::ensure!(
            expect == self.n,
            "shards cover {expect} rows, manifest says n={}",
            self.n
        );
        anyhow::ensure!(
            nnz == self.nnz,
            "shard nnz totals {nnz}, manifest says {}",
            self.nnz
        );
        anyhow::ensure!(self.d >= 1 || self.n == 0, "manifest d must be ≥ 1");
        Ok(())
    }

    /// The shards' `[start, end)` row spans in disk order — the input
    /// to [`crate::data::Partition::from_shards`].
    pub fn spans(&self) -> Vec<(usize, usize)> {
        self.shards.iter().map(|s| (s.row_start, s.row_end)).collect()
    }

    /// Path of the manifest inside `dir`.
    pub fn path_in(dir: &Path) -> PathBuf {
        dir.join(MANIFEST_FILE)
    }

    /// Write `manifest.json` into the store directory.
    pub fn save(&self, dir: &Path) -> anyhow::Result<()> {
        let path = Self::path_in(dir);
        std::fs::write(&path, self.to_json().to_pretty())
            .map_err(|e| anyhow::anyhow!("write {}: {e}", path.display()))?;
        Ok(())
    }

    /// Load and validate `manifest.json` from a store directory.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = Self::path_in(dir);
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("open shard store {}: {e}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
        Self::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Manifest {
        Manifest {
            name: "tiny".into(),
            n: 30,
            d: 7,
            nnz: 11,
            strategy: Strategy::Shuffled,
            seed: 99,
            shards: vec![
                ShardEntry {
                    path: "shard-00000.csr".into(),
                    row_start: 0,
                    row_end: 20,
                    nnz: 8,
                    bytes: 400,
                    crc32: 0xDEAD_BEEF,
                    stats: ShardStats {
                        density: 0.05,
                        nnz_per_row_mean: 0.4,
                        nnz_per_row_max: 3,
                        positive_fraction: 0.5,
                    },
                },
                ShardEntry {
                    path: "shard-00001.csr".into(),
                    row_start: 20,
                    row_end: 30,
                    nnz: 3,
                    bytes: 220,
                    crc32: 7,
                    stats: ShardStats {
                        density: 0.04,
                        nnz_per_row_mean: 0.3,
                        nnz_per_row_max: 2,
                        positive_fraction: 0.6,
                    },
                },
            ],
        }
    }

    #[test]
    fn json_roundtrip() {
        let m = sample();
        let text = m.to_json().to_pretty();
        let back = Manifest::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn spans_and_validate() {
        let m = sample();
        m.validate().unwrap();
        assert_eq!(m.spans(), vec![(0, 20), (20, 30)]);
        let mut gap = m.clone();
        gap.shards[1].row_start = 21;
        assert!(gap.validate().is_err());
        let mut short = m.clone();
        short.n = 40;
        assert!(short.validate().is_err());
        let mut bad_nnz = m;
        bad_nnz.nnz = 5;
        assert!(bad_nnz.validate().is_err());
    }

    #[test]
    fn foreign_json_rejected() {
        let j = Json::parse(r#"{"format": "something-else", "version": 1}"#).unwrap();
        let err = Manifest::from_json(&j).unwrap_err();
        assert!(err.to_string().contains("format marker"), "{err}");
        let j = Json::parse("{}").unwrap();
        assert!(Manifest::from_json(&j).is_err());
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("hybrid_dca_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let m = sample();
        m.save(&dir).unwrap();
        let back = Manifest::load(&dir).unwrap();
        assert_eq!(back, m);
        std::fs::remove_dir_all(&dir).ok();
    }
}
