//! One-pass, constant-memory conversion of LIBSVM text (or an
//! in-memory dataset) into a shard store.
//!
//! The paper's headline workload is a 280 GB LIBSVM file that "cannot
//! be accommodated on a single node" — so the converter never holds
//! more than the shard currently being filled: rows stream in through
//! [`crate::data::libsvm::rows`], accumulate in one CSR buffer, and
//! are encoded + flushed to disk the moment the row/byte budget is
//! hit. [`PackReport::peak_buffered_rows`] records the high-water mark
//! so tests can *prove* the bound instead of trusting it.

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use crate::data::csr::{sort_row_entries, CsrMatrix};
use crate::data::{libsvm, Dataset, Strategy};
use crate::util::Rng;

use super::format;
use super::manifest::{Manifest, ShardEntry, ShardStats};

/// Packing parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct PackOptions {
    /// Dataset name recorded in the manifest.
    pub name: String,
    /// Cut a shard once it holds this many rows (0 = no row budget).
    pub shard_rows: usize,
    /// Cut a shard once its encoded size reaches this many bytes
    /// (0 = no byte budget). With both budgets 0 the whole input
    /// becomes one shard.
    pub shard_bytes: u64,
    /// Only cut when the shard's row count is a multiple of this —
    /// set it to K×R so the even K-node × R-core split lands exactly
    /// on shard boundaries (the last shard is exempt). ≤ 1 disables.
    pub align: usize,
    /// Lower bound on the recorded feature dimension (like
    /// `libsvm::read`'s `min_dim`).
    pub min_dim: usize,
    /// Seed for the pack-time permutation when a shuffled row order is
    /// requested (only available via [`pack_dataset`]).
    pub seed: u64,
}

impl Default for PackOptions {
    fn default() -> Self {
        Self {
            name: "dataset".into(),
            shard_rows: 65_536,
            shard_bytes: 0,
            align: 1,
            min_dim: 0,
            seed: 0,
        }
    }
}

/// What a pack run did — sizes, throughput inputs, and the buffered
/// high-water mark that proves the constant-memory property.
#[derive(Debug, Clone, PartialEq)]
pub struct PackReport {
    pub shards: usize,
    pub rows: usize,
    pub nnz: usize,
    /// Total shard bytes written (manifest excluded).
    pub bytes_written: u64,
    /// Max rows ever resident in the pack buffer — bounded by one
    /// shard, never the file.
    pub peak_buffered_rows: usize,
}

/// Streaming accumulator for the shard being filled.
struct ShardAcc {
    row_start: usize,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f64>,
    labels: Vec<f64>,
    dim_local: usize,
}

impl ShardAcc {
    fn new(row_start: usize) -> Self {
        Self {
            row_start,
            indptr: vec![0],
            indices: Vec::new(),
            values: Vec::new(),
            labels: Vec::new(),
            dim_local: 0,
        }
    }

    fn rows(&self) -> usize {
        self.labels.len()
    }

    /// Append one row: the shared [`sort_row_entries`] normalization
    /// (sort + duplicate rejection), then the same explicit-zero drop
    /// [`crate::data::csr::CsrBuilder::push_row`] performs — minus the
    /// up-front dim bound (the global d is only known at end of input).
    fn push_row(&mut self, label: f64, entries: Vec<(u32, f64)>) -> anyhow::Result<()> {
        let entries = sort_row_entries(entries)?;
        if let Some(&(max_idx, _)) = entries.last() {
            self.dim_local = self.dim_local.max(max_idx as usize + 1);
        }
        for (j, x) in entries {
            if x != 0.0 {
                self.indices.push(j);
                self.values.push(x);
            }
        }
        self.indptr.push(self.indices.len());
        self.labels.push(label);
        Ok(())
    }

    fn encoded_len(&self) -> usize {
        format::encoded_len(self.rows(), self.indices.len())
    }

    /// Turn the buffer into an in-memory shard dataset (consumes it).
    fn into_dataset(self) -> Dataset {
        let x = CsrMatrix {
            indptr: self.indptr,
            indices: self.indices,
            values: self.values,
            dim: self.dim_local.max(1),
        };
        Dataset::new(x, self.labels)
    }
}

/// Running pack state: the open accumulator plus everything already
/// flushed.
struct PackState<'a> {
    dir: &'a Path,
    opts: &'a PackOptions,
    acc: ShardAcc,
    entries: Vec<ShardEntry>,
    dim_global: usize,
    total_nnz: usize,
    bytes_written: u64,
    peak_buffered_rows: usize,
}

impl<'a> PackState<'a> {
    fn new(dir: &'a Path, opts: &'a PackOptions) -> anyhow::Result<Self> {
        std::fs::create_dir_all(dir)
            .map_err(|e| anyhow::anyhow!("create store dir {}: {e}", dir.display()))?;
        Ok(Self {
            dir,
            opts,
            acc: ShardAcc::new(0),
            entries: Vec::new(),
            dim_global: 0,
            total_nnz: 0,
            bytes_written: 0,
            peak_buffered_rows: 0,
        })
    }

    fn push(&mut self, label: f64, entries: Vec<(u32, f64)>) -> anyhow::Result<()> {
        self.acc.push_row(label, entries).map_err(|e| {
            anyhow::anyhow!("row {}: {e}", self.acc.row_start + self.acc.rows())
        })?;
        self.peak_buffered_rows = self.peak_buffered_rows.max(self.acc.rows());
        let rows = self.acc.rows();
        let budget_hit = (self.opts.shard_rows > 0 && rows >= self.opts.shard_rows)
            || (self.opts.shard_bytes > 0
                && self.acc.encoded_len() as u64 >= self.opts.shard_bytes);
        let aligned = self.opts.align <= 1 || rows % self.opts.align == 0;
        if budget_hit && aligned {
            self.flush()?;
        }
        Ok(())
    }

    /// Encode and write the open accumulator as the next shard file.
    fn flush(&mut self) -> anyhow::Result<()> {
        let row_start = self.acc.row_start;
        let next_start = row_start + self.acc.rows();
        let acc = std::mem::replace(&mut self.acc, ShardAcc::new(next_start));
        if acc.rows() == 0 {
            return Ok(());
        }
        let shard = acc.into_dataset();
        self.dim_global = self.dim_global.max(shard.d());
        self.total_nnz += shard.x.nnz();
        let stats = ShardStats::compute(&shard);
        let bytes = format::encode_shard(&shard, row_start);
        let crc = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().expect("crc tail"));
        let file = format!("shard-{:05}.{}", self.entries.len(), format::SHARD_EXT);
        let path = self.dir.join(&file);
        let f = std::fs::File::create(&path)
            .map_err(|e| anyhow::anyhow!("create {}: {e}", path.display()))?;
        let mut w = std::io::BufWriter::new(f);
        w.write_all(&bytes)
            .and_then(|_| w.flush())
            .map_err(|e| anyhow::anyhow!("write {}: {e}", path.display()))?;
        self.bytes_written += bytes.len() as u64;
        self.entries.push(ShardEntry {
            path: file,
            row_start,
            row_end: next_start,
            nnz: shard.x.nnz(),
            bytes: bytes.len() as u64,
            crc32: crc,
            stats,
        });
        Ok(())
    }

    fn finish(mut self, strategy: Strategy) -> anyhow::Result<(Manifest, PackReport)> {
        self.flush()?;
        anyhow::ensure!(!self.entries.is_empty(), "input has no data rows to pack");
        let n = self.entries.last().expect("non-empty").row_end;
        let manifest = Manifest {
            name: self.opts.name.clone(),
            n,
            d: self.dim_global.max(self.opts.min_dim).max(1),
            nnz: self.total_nnz,
            strategy,
            seed: if strategy == Strategy::Contiguous { 0 } else { self.opts.seed },
            shards: self.entries,
        };
        manifest.validate().expect("packer emits a consistent manifest");
        manifest.save(self.dir)?;
        let report = PackReport {
            shards: manifest.shards.len(),
            rows: n,
            nnz: manifest.nnz,
            bytes_written: self.bytes_written,
            peak_buffered_rows: self.peak_buffered_rows,
        };
        Ok((manifest, report))
    }
}

/// Stream LIBSVM text from `reader` into a shard store at `dir`.
/// Constant memory: at most one shard is buffered. Rows keep their
/// input order (`Strategy::Contiguous` in the manifest) — a streaming
/// pass cannot shuffle; use [`pack_dataset`] for a shuffled pack.
pub fn pack<R: BufRead>(
    reader: R,
    dir: &Path,
    opts: &PackOptions,
) -> anyhow::Result<(Manifest, PackReport)> {
    let mut st = PackState::new(dir, opts)?;
    for row in libsvm::rows(reader) {
        let row = row?;
        st.push(libsvm::map_label(row.label), row.entries)?;
    }
    st.finish(Strategy::Contiguous)
}

/// [`pack`] reading from a LIBSVM file on disk.
pub fn pack_file(
    input: &Path,
    dir: &Path,
    opts: &PackOptions,
) -> anyhow::Result<(Manifest, PackReport)> {
    let f = std::fs::File::open(input)
        .map_err(|e| anyhow::anyhow!("open {}: {e}", input.display()))?;
    pack(BufReader::new(f), dir, opts)
}

/// Pack an in-memory dataset, optionally permuting rows first:
/// `Strategy::Contiguous` keeps input order, `Strategy::Shuffled`
/// applies a seeded permutation at pack time (so a later shard-aware
/// contiguous split *realizes* the shuffled assignment on disk).
/// `Striped` needs a node count that doesn't exist at pack time and is
/// rejected.
pub fn pack_dataset(
    ds: &Dataset,
    dir: &Path,
    opts: &PackOptions,
    strategy: Strategy,
) -> anyhow::Result<(Manifest, PackReport)> {
    let n = ds.n();
    anyhow::ensure!(n > 0, "input has no data rows to pack");
    let order: Vec<usize> = match strategy {
        Strategy::Contiguous => (0..n).collect(),
        Strategy::Shuffled => {
            let mut v: Vec<usize> = (0..n).collect();
            Rng::new(opts.seed).shuffle(&mut v);
            v
        }
        Strategy::Striped => anyhow::bail!(
            "a striped pack order needs the node count at pack time; pack contiguous \
             (or shuffled) and let the shard-aware partition place nodes"
        ),
    };
    let mut opts_eff = opts.clone();
    opts_eff.min_dim = opts.min_dim.max(ds.d());
    let mut st = PackState::new(dir, &opts_eff)?;
    for &i in &order {
        let r = ds.x.row(i);
        let entries: Vec<(u32, f64)> =
            r.indices.iter().copied().zip(r.values.iter().copied()).collect();
        st.push(ds.y[i], entries)?;
    }
    st.finish(strategy)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::Preset;
    use crate::util::Rng;

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("hybrid_dca_pack_{tag}"));
        std::fs::remove_dir_all(&d).ok();
        d
    }

    #[test]
    fn pack_streams_with_bounded_buffer() {
        let ds = Preset::Tiny.generate(&mut Rng::new(1));
        let mut text = Vec::new();
        libsvm::write(&mut text, &ds).unwrap();
        let dir = tmp_dir("bounded");
        let opts = PackOptions {
            name: "tiny".into(),
            shard_rows: 32,
            min_dim: ds.d(),
            ..PackOptions::default()
        };
        let (manifest, report) = pack(std::io::Cursor::new(text), &dir, &opts).unwrap();
        // 200 rows / 32-row budget → 7 shards; the buffer never held
        // more than one shard even though the input had 200 rows.
        assert_eq!(report.shards, 7);
        assert_eq!(report.rows, 200);
        assert!(report.peak_buffered_rows <= 32, "peak {}", report.peak_buffered_rows);
        assert_eq!(manifest.n, 200);
        assert_eq!(manifest.d, ds.d());
        assert_eq!(manifest.strategy, Strategy::Contiguous);
        assert_eq!(manifest.spans().first(), Some(&(0, 32)));
        assert_eq!(manifest.spans().last(), Some(&(192, 200)));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn byte_budget_cuts_shards() {
        let ds = Preset::Tiny.generate(&mut Rng::new(2));
        let dir = tmp_dir("bytes");
        let opts = PackOptions {
            name: "tiny".into(),
            shard_rows: 0,
            shard_bytes: 4 * 1024,
            ..PackOptions::default()
        };
        let (manifest, report) =
            pack_dataset(&ds, &dir, &opts, Strategy::Contiguous).unwrap();
        assert!(report.shards > 1, "4 KB budget should split tiny");
        for e in &manifest.shards[..manifest.shards.len() - 1] {
            // Each cut happened at the first row crossing the budget.
            assert!(e.bytes >= 4 * 1024, "shard under budget: {} bytes", e.bytes);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn alignment_respected() {
        let ds = Preset::Tiny.generate(&mut Rng::new(3));
        let dir = tmp_dir("align");
        let opts = PackOptions {
            name: "tiny".into(),
            shard_rows: 30,
            align: 8, // K×R = 8: cut only at multiples of 8
            ..PackOptions::default()
        };
        let (manifest, _) = pack_dataset(&ds, &dir, &opts, Strategy::Contiguous).unwrap();
        for e in &manifest.shards[..manifest.shards.len() - 1] {
            assert_eq!(e.rows() % 8, 0, "unaligned shard of {} rows", e.rows());
            assert!(e.rows() >= 30, "cut before the row budget");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shuffled_pack_is_a_seeded_permutation() {
        let ds = Preset::Tiny.generate(&mut Rng::new(4));
        let dir_a = tmp_dir("shuf_a");
        let dir_b = tmp_dir("shuf_b");
        let opts =
            PackOptions { name: "tiny".into(), shard_rows: 64, seed: 7, ..Default::default() };
        let (ma, _) = pack_dataset(&ds, &dir_a, &opts, Strategy::Shuffled).unwrap();
        let (mb, _) = pack_dataset(&ds, &dir_b, &opts, Strategy::Shuffled).unwrap();
        assert_eq!(ma.strategy, Strategy::Shuffled);
        assert_eq!(ma.seed, 7);
        // Same seed ⇒ identical stores (shard CRCs agree).
        let crcs = |m: &Manifest| m.shards.iter().map(|s| s.crc32).collect::<Vec<_>>();
        assert_eq!(crcs(&ma), crcs(&mb));
        // Striped is rejected at pack time.
        assert!(pack_dataset(&ds, &dir_a, &opts, Strategy::Striped).is_err());
        std::fs::remove_dir_all(&dir_a).ok();
        std::fs::remove_dir_all(&dir_b).ok();
    }

    #[test]
    fn empty_input_rejected() {
        let dir = tmp_dir("empty");
        let err = pack(
            std::io::Cursor::new("# only comments\n\n"),
            &dir,
            &PackOptions::default(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("no data rows"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn non_finite_input_rejected_while_streaming() {
        let dir = tmp_dir("nonfinite");
        let err = pack(
            std::io::Cursor::new("+1 1:1\n+1 2:inf\n"),
            &dir,
            &PackOptions::default(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
