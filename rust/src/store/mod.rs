//! Out-of-core sharded dataset store.
//!
//! The paper's headline experiment (Fig. 7) solves splicesite — a
//! 280 GB LIBSVM file that "cannot be accommodated on a single node".
//! Hydra (Richtárik & Takáč 2013) and distributed mini-batch SDCA
//! (Takáč et al. 2015) assume the data already lives pre-partitioned
//! in node-local blocks; this module makes that block a first-class
//! on-disk object:
//!
//! * [`format`] — the versioned little-endian binary shard: header
//!   (magic, version, row span, dim, nnz), raw CSR arrays, labels, and
//!   a trailing CRC-32. Hand-encoded, no serde.
//! * [`manifest`] — `manifest.json` (via `util/json`): global dims,
//!   pack-time row-order [`Strategy`](crate::data::Strategy), and per-
//!   shard spans, sizes, CRCs, and `data::stats` summaries.
//! * [`pack`] — one-pass, constant-memory streaming ingest from LIBSVM
//!   text (shares `libsvm::rows` with the in-memory reader), cutting
//!   shards on a row/byte budget with optional K×R alignment.
//! * [`sharded`] — [`ShardedDataset`]: open parses only the manifest;
//!   shards decode lazily one at a time. Its [`spans`] feed
//!   [`Partition::from_shards`](crate::data::Partition::from_shards)
//!   so node `k` trains on its own packed shards in disk order.
//!
//! [`spans`]: ShardedDataset::spans
//!
//! ```no_run
//! use hybrid_dca::store;
//!
//! let opts = store::PackOptions { name: "rcv1".into(), ..Default::default() };
//! store::pack_file("rcv1.svm".as_ref(), "rcv1_store".as_ref(), &opts)?;
//! let sharded = store::open("rcv1_store")?;
//! let node0 = sharded.load_shard(0)?; // one shard resident, not 280 GB
//! # let _ = node0; Ok::<(), anyhow::Error>(())
//! ```

pub mod format;
pub mod manifest;
pub mod pack;
pub mod sharded;

pub use format::{crc32, decode_shard, encode_shard, ShardHeader};
pub use manifest::{Manifest, ShardEntry, ShardStats, MANIFEST_FILE};
pub use pack::{pack, pack_dataset, pack_file, PackOptions, PackReport};
pub use sharded::{open, ShardLease, ShardedDataset};
