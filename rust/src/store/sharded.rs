//! Lazy reader over a packed store: open parses only the manifest;
//! shard files are read one at a time, on demand, through buffered
//! whole-file reads (`pread`-style: seekless sequential I/O of exactly
//! one shard, no mmap, no new dependencies). Peak memory for any
//! single operation is one decoded shard — except [`materialize`],
//! which deliberately assembles the full dataset for the in-process
//! engines and says so.
//!
//! [`ShardedDataset::materialize`]: ShardedDataset::materialize

use std::cell::RefCell;
use std::io::Read;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::util::sync::{AtomicUsize, Mutex, Ordering};

use crate::data::csr::CsrMatrix;
use crate::data::Dataset;
use crate::util::WorkPool;

use super::format;
use super::manifest::Manifest;

/// Shard-residency gauge: how many leased (decoded, live) shards exist
/// right now, and the high-water mark since the last reset. The
/// out-of-core evaluation contract — peak resident data ≤ eval threads
/// × one shard — is asserted against this in tests.
// ORDERING: all gauge traffic is `Relaxed` (downgraded from the
// original blanket `SeqCst`, see CHANGES.md). Correctness needs only
// per-location RMW atomicity: `current` is an exact up/down counter
// because fetch_add/fetch_sub never lose increments regardless of
// ordering, and `peak` is maintained with `fetch_max` against the
// value `current`'s own RMW returned — no cross-location ordering is
// consumed. Every assertion against the gauge happens after the
// leasing operation has quiesced (pool completion barrier or thread
// join), which supplies the happens-before for the final loads.
#[derive(Debug, Default)]
struct Residency {
    current: AtomicUsize,
    peak: AtomicUsize,
}

/// An open shard store.
#[derive(Debug, Clone)]
pub struct ShardedDataset {
    dir: PathBuf,
    manifest: Manifest,
    /// Shared across clones so every reader of this store feeds one
    /// gauge.
    residency: Arc<Residency>,
}

/// A decoded shard whose lifetime is tracked by the store's residency
/// gauge: the gauge increments when the lease is created and
/// decrements when it drops. Derefs to the decoded [`Dataset`].
#[derive(Debug)]
pub struct ShardLease {
    data: Dataset,
    residency: Arc<Residency>,
}

impl std::ops::Deref for ShardLease {
    type Target = Dataset;
    fn deref(&self) -> &Dataset {
        &self.data
    }
}

impl Drop for ShardLease {
    fn drop(&mut self) {
        self.residency.current.fetch_sub(1, Ordering::Relaxed);
    }
}

// Reusable per-thread raw-byte buffer for shard reads. Pool threads
// (`util::pool`) persist across evaluation rounds, so this scratch is
// allocated once per thread instead of once per `on_eval` call.
thread_local! {
    static SHARD_BUF: RefCell<Vec<u8>> = const { RefCell::new(Vec::new()) };
}

/// Open a store directory (parses and validates `manifest.json` only —
/// no shard is touched).
pub fn open(dir: impl AsRef<Path>) -> anyhow::Result<ShardedDataset> {
    let dir = dir.as_ref().to_path_buf();
    let manifest = Manifest::load(&dir)?;
    Ok(ShardedDataset { dir, manifest, residency: Arc::default() })
}

impl ShardedDataset {
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Dataset name from the manifest.
    pub fn name(&self) -> &str {
        &self.manifest.name
    }

    /// Global number of rows.
    pub fn n(&self) -> usize {
        self.manifest.n
    }

    /// Global feature dimension.
    pub fn d(&self) -> usize {
        self.manifest.d
    }

    /// Global nonzero count.
    pub fn nnz(&self) -> usize {
        self.manifest.nnz
    }

    pub fn num_shards(&self) -> usize {
        self.manifest.shards.len()
    }

    /// The shards' global `[start, end)` row spans in disk order.
    pub fn spans(&self) -> Vec<(usize, usize)> {
        self.manifest.spans()
    }

    /// Read and decode one shard into an in-memory [`Dataset`] whose
    /// matrix is widened to the global `d`. Memory: one shard. The raw
    /// file bytes go through a per-thread reusable buffer, so repeated
    /// loads on the same (pool) thread do not reallocate the read
    /// buffer.
    pub fn load_shard(&self, i: usize) -> anyhow::Result<Dataset> {
        SHARD_BUF.with(|buf| self.load_shard_with(i, &mut buf.borrow_mut()))
    }

    /// [`load_shard`](Self::load_shard) plus residency accounting: the
    /// returned lease keeps the store's shard-residency gauge
    /// incremented until it drops. Every path with a memory contract
    /// (streamed evaluation, slab assembly) loads through leases.
    pub fn lease_shard(&self, i: usize) -> anyhow::Result<ShardLease> {
        let cur = self.residency.current.fetch_add(1, Ordering::Relaxed) + 1;
        self.residency.peak.fetch_max(cur, Ordering::Relaxed);
        match self.load_shard(i) {
            Ok(data) => Ok(ShardLease { data, residency: Arc::clone(&self.residency) }),
            Err(e) => {
                self.residency.current.fetch_sub(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    /// Number of shard leases alive right now.
    pub fn residency_current(&self) -> usize {
        self.residency.current.load(Ordering::Relaxed)
    }

    /// High-water mark of concurrently leased shards since open (or the
    /// last [`reset_residency_peak`](Self::reset_residency_peak)).
    pub fn residency_peak(&self) -> usize {
        self.residency.peak.load(Ordering::Relaxed)
    }

    /// Reset the high-water mark (tests bracket one operation with
    /// this and [`residency_peak`](Self::residency_peak)).
    pub fn reset_residency_peak(&self) {
        self.residency.peak.store(self.residency.current.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Core of [`load_shard`](Self::load_shard) with a caller-supplied
    /// byte buffer (cleared, then reused at its grown capacity).
    fn load_shard_with(&self, i: usize, bytes: &mut Vec<u8>) -> anyhow::Result<Dataset> {
        let entry = self
            .manifest
            .shards
            .get(i)
            .ok_or_else(|| {
                anyhow::anyhow!("shard {i} out of range ({} shards)", self.num_shards())
            })?;
        let path = self.dir.join(&entry.path);
        bytes.clear();
        bytes.reserve(entry.bytes as usize);
        std::fs::File::open(&path)
            .and_then(|mut f| f.read_to_end(bytes))
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        let (header, ds) = format::decode_shard(&*bytes, self.d())
            .map_err(|e| anyhow::anyhow!("decode {}: {e}", path.display()))?;
        // Cross-check file ↔ manifest: the decoder proved the file is
        // *internally* consistent; the manifest's recorded CRC proves
        // it is the file this store was packed with (a swapped-in
        // shard from another pack is self-consistent but wrong).
        let file_crc =
            u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().expect("decoded shard"));
        anyhow::ensure!(
            file_crc == entry.crc32,
            "{}: file CRC {:08x} disagrees with manifest {:08x} (shard replaced after pack?)",
            path.display(),
            file_crc,
            entry.crc32
        );
        anyhow::ensure!(
            bytes.len() as u64 == entry.bytes,
            "{}: file is {} bytes, manifest says {}",
            path.display(),
            bytes.len(),
            entry.bytes
        );
        anyhow::ensure!(
            header.row_start == entry.row_start && header.row_end == entry.row_end,
            "{}: header rows [{}, {}) disagree with manifest [{}, {})",
            path.display(),
            header.row_start,
            header.row_end,
            entry.row_start,
            entry.row_end
        );
        anyhow::ensure!(
            header.nnz == entry.nnz,
            "{}: header nnz {} disagrees with manifest {}",
            path.display(),
            header.nnz,
            entry.nnz
        );
        Ok(ds.with_name(format!("{}[{}]", self.manifest.name, i)))
    }

    /// Decode every shard (CRC + full structural validation), fanned
    /// out across the global [`WorkPool`] — each pool thread holds at
    /// most one decoded shard, so peak memory is (pool threads × one
    /// shard). The `data inspect --verify` backend.
    pub fn verify(&self) -> anyhow::Result<()> {
        let shards = self.num_shards();
        let pool = WorkPool::global();
        let workers = pool.size().min(shards);
        if workers <= 1 {
            for i in 0..shards {
                self.check_shard(i)?;
            }
            return Ok(());
        }
        // ORDERING: work-claim ticket; RMW atomicity alone guarantees
        // each shard index is checked exactly once, and the pool's
        // completion barrier publishes the error slot — `Relaxed`.
        let next = AtomicUsize::new(0);
        // Keep only the lowest-index failure so the parallel scan
        // reports the same error a serial one would have hit first.
        let first_err: Mutex<Option<(usize, anyhow::Error)>> = Mutex::new(None);
        pool.run(workers, &|_| loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= shards {
                break;
            }
            if let Err(e) = self.check_shard(i) {
                let mut slot = first_err.lock().expect("verify error slot");
                if slot.as_ref().map_or(true, |(j, _)| i < *j) {
                    *slot = Some((i, e));
                }
            }
        });
        match first_err.into_inner().expect("verify error slot") {
            Some((_, e)) => Err(e),
            None => Ok(()),
        }
    }

    fn check_shard(&self, i: usize) -> anyhow::Result<()> {
        let ds = self.load_shard(i)?;
        let entry = &self.manifest.shards[i];
        anyhow::ensure!(
            ds.n() == entry.rows(),
            "shard {i}: decoded {} rows, manifest says {}",
            ds.n(),
            entry.rows()
        );
        Ok(())
    }

    /// Assemble the contiguous row range `[lo, hi)` as one flat slab,
    /// streaming its shards one at a time through leases (≤ 1 shard
    /// resident beyond the slab being built). The range must align to
    /// shard boundaries — shard-aware node partitions
    /// ([`Partition::from_shards`](crate::data::Partition::from_shards))
    /// produce exactly such ranges.
    pub fn materialize_range(&self, lo: usize, hi: usize) -> anyhow::Result<Dataset> {
        anyhow::ensure!(
            lo < hi && hi <= self.n(),
            "row range [{lo}, {hi}) is not a non-empty subrange of 0..{}",
            self.n()
        );
        let spans = self.spans();
        let first = spans.partition_point(|&(_, end)| end <= lo);
        anyhow::ensure!(
            first < spans.len() && spans[first].0 == lo,
            "range start {lo} is not a shard boundary"
        );
        let mut indptr = vec![0usize];
        let mut indices = Vec::new();
        let mut values = Vec::new();
        let mut labels = Vec::with_capacity(hi - lo);
        let mut s = first;
        let mut row = lo;
        while row < hi {
            anyhow::ensure!(
                s < spans.len() && spans[s].1 <= hi,
                "range end {hi} is not a shard boundary"
            );
            let shard = self.lease_shard(s)?;
            let offset = indices.len();
            for &p in &shard.x.indptr[1..] {
                indptr.push(offset + p);
            }
            indices.extend_from_slice(&shard.x.indices);
            values.extend_from_slice(&shard.x.values);
            labels.extend_from_slice(&shard.y);
            row = spans[s].1;
            s += 1;
        }
        let x = CsrMatrix { indptr, indices, values, dim: self.d().max(1) };
        Ok(Dataset::new(x, labels).with_name(format!("{}[{lo}..{hi})", self.manifest.name)))
    }

    /// Assemble the full in-memory dataset by streaming shards in disk
    /// order — the bridge to engines that still want a flat
    /// [`Dataset`]. This is the one operation whose memory is the
    /// whole dataset (plus one shard transiently).
    pub fn materialize(&self) -> anyhow::Result<Dataset> {
        let n = self.n();
        let mut indptr = Vec::with_capacity(n + 1);
        indptr.push(0usize);
        let mut indices = Vec::with_capacity(self.nnz());
        let mut values = Vec::with_capacity(self.nnz());
        let mut labels = Vec::with_capacity(n);
        for i in 0..self.num_shards() {
            let shard = self.load_shard(i)?;
            let offset = indices.len();
            for &p in &shard.x.indptr[1..] {
                indptr.push(offset + p);
            }
            indices.extend_from_slice(&shard.x.indices);
            values.extend_from_slice(&shard.x.values);
            labels.extend_from_slice(&shard.y);
        }
        let x = CsrMatrix { indptr, indices, values, dim: self.d().max(1) };
        Ok(Dataset::new(x, labels).with_name(self.manifest.name.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::Preset;
    use crate::data::Strategy;
    use crate::store::pack::{pack_dataset, PackOptions};
    use crate::util::Rng;

    fn packed_tiny(tag: &str, shard_rows: usize) -> (Dataset, PathBuf) {
        let ds = Preset::Tiny.generate(&mut Rng::new(11));
        let dir = std::env::temp_dir().join(format!("hybrid_dca_sharded_{tag}"));
        std::fs::remove_dir_all(&dir).ok();
        let opts = PackOptions { name: "tiny".into(), shard_rows, ..Default::default() };
        pack_dataset(&ds, &dir, &opts, Strategy::Contiguous).unwrap();
        (ds, dir)
    }

    #[test]
    fn open_reads_only_the_manifest() {
        let (ds, dir) = packed_tiny("open", 64);
        let store = open(&dir).unwrap();
        assert_eq!(store.n(), ds.n());
        assert_eq!(store.d(), ds.d());
        assert_eq!(store.nnz(), ds.x.nnz());
        assert_eq!(store.name(), "tiny");
        assert_eq!(store.num_shards(), 4); // 200 / 64 → 64+64+64+8
        assert_eq!(store.spans()[0], (0, 64));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_shard_is_the_row_slice() {
        let (ds, dir) = packed_tiny("slice", 64);
        let store = open(&dir).unwrap();
        let s1 = store.load_shard(1).unwrap();
        assert_eq!(s1.n(), 64);
        assert_eq!(s1.d(), ds.d());
        for (local, global) in (64..128).enumerate() {
            assert_eq!(s1.x.row(local), ds.x.row(global), "row {global}");
            assert_eq!(s1.y[local], ds.y[global]);
        }
        assert!(store.load_shard(99).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn materialize_is_bitwise_identical() {
        let (ds, dir) = packed_tiny("mat", 32);
        let store = open(&dir).unwrap();
        let back = store.materialize().unwrap();
        assert_eq!(back.x.indptr, ds.x.indptr);
        assert_eq!(back.x.indices, ds.x.indices);
        assert_eq!(back.x.values, ds.x.values);
        assert_eq!(back.y, ds.y);
        assert_eq!(back.d(), ds.d());
        assert_eq!(back.name, "tiny");
        store.verify().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn materialize_range_streams_one_shard_at_a_time() {
        let (ds, dir) = packed_tiny("range", 32);
        let store = open(&dir).unwrap();
        let spans = store.spans();
        assert!(spans.len() >= 3, "need ≥ 3 shards for a mid-store slab");
        let (lo, hi) = (spans[1].0, spans[2].1);
        store.reset_residency_peak();
        let slab = store.materialize_range(lo, hi).unwrap();
        assert_eq!(store.residency_peak(), 1, "one transient lease per shard");
        assert_eq!(store.residency_current(), 0);
        assert_eq!(slab.n(), hi - lo);
        assert_eq!(slab.d(), ds.d());
        for local in 0..slab.n() {
            let g = lo + local;
            assert_eq!(slab.x.row(local).indices, ds.x.row(g).indices);
            assert_eq!(slab.x.row(local).values, ds.x.row(g).values);
            assert_eq!(slab.y[local], ds.y[g]);
        }
        // Ranges off shard boundaries fail loudly instead of slicing a
        // shard.
        assert!(store.materialize_range(lo + 1, hi).is_err());
        assert!(store.materialize_range(lo, hi - 1).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_shard_caught_on_load() {
        let (_, dir) = packed_tiny("corrupt", 64);
        let store = open(&dir).unwrap();
        let victim = dir.join(&store.manifest().shards[2].path);
        let mut bytes = std::fs::read(&victim).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        std::fs::write(&victim, &bytes).unwrap();
        let err = store.load_shard(2).unwrap_err();
        assert!(err.to_string().contains("CRC"), "{err}");
        assert!(store.verify().is_err());
        // Untouched shards still load.
        store.load_shard(0).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_file_crc_cross_checked() {
        // A shard file that is internally valid but not the one the
        // manifest recorded (e.g. swapped in from another pack) must
        // fail the manifest↔file CRC cross-check.
        let (_, dir) = packed_tiny("crosscheck", 64);
        let mut m = Manifest::load(&dir).unwrap();
        m.shards[1].crc32 ^= 1;
        m.save(&dir).unwrap();
        let store = open(&dir).unwrap();
        let err = store.load_shard(1).unwrap_err();
        assert!(err.to_string().contains("manifest"), "{err}");
        assert!(store.verify().is_err());
        store.load_shard(0).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lease_residency_accounting() {
        let (_, dir) = packed_tiny("lease", 64);
        let store = open(&dir).unwrap();
        assert_eq!(store.residency_current(), 0);
        assert_eq!(store.residency_peak(), 0);
        {
            let a = store.lease_shard(0).unwrap();
            assert_eq!(a.n(), 64);
            assert_eq!(store.residency_current(), 1);
            let b = store.lease_shard(1).unwrap();
            assert_eq!(b.n(), 64);
            assert_eq!(store.residency_current(), 2);
            assert_eq!(store.residency_peak(), 2);
        }
        assert_eq!(store.residency_current(), 0);
        assert_eq!(store.residency_peak(), 2, "peak is a high-water mark");
        store.reset_residency_peak();
        assert_eq!(store.residency_peak(), 0);
        // A failed lease does not leak a residency slot.
        assert!(store.lease_shard(99).is_err());
        assert_eq!(store.residency_current(), 0);
        // Clones share the gauge.
        let clone = store.clone();
        let _l = clone.lease_shard(2).unwrap();
        assert_eq!(store.residency_current(), 1);
        drop(_l);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_a_clear_error() {
        let dir = std::env::temp_dir().join("hybrid_dca_sharded_nostore");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let err = open(&dir).unwrap_err();
        assert!(err.to_string().contains("manifest.json"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
