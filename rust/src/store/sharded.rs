//! Lazy reader over a packed store: open parses only the manifest;
//! shard files are read one at a time, on demand, through buffered
//! whole-file reads (`pread`-style: seekless sequential I/O of exactly
//! one shard, no mmap, no new dependencies). Peak memory for any
//! single operation is one decoded shard — except [`materialize`],
//! which deliberately assembles the full dataset for the in-process
//! engines and says so.
//!
//! [`ShardedDataset::materialize`]: ShardedDataset::materialize

use std::io::Read;
use std::path::{Path, PathBuf};

use crate::data::csr::CsrMatrix;
use crate::data::Dataset;

use super::format;
use super::manifest::Manifest;

/// An open shard store.
#[derive(Debug, Clone)]
pub struct ShardedDataset {
    dir: PathBuf,
    manifest: Manifest,
}

/// Open a store directory (parses and validates `manifest.json` only —
/// no shard is touched).
pub fn open(dir: impl AsRef<Path>) -> anyhow::Result<ShardedDataset> {
    let dir = dir.as_ref().to_path_buf();
    let manifest = Manifest::load(&dir)?;
    Ok(ShardedDataset { dir, manifest })
}

impl ShardedDataset {
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Dataset name from the manifest.
    pub fn name(&self) -> &str {
        &self.manifest.name
    }

    /// Global number of rows.
    pub fn n(&self) -> usize {
        self.manifest.n
    }

    /// Global feature dimension.
    pub fn d(&self) -> usize {
        self.manifest.d
    }

    /// Global nonzero count.
    pub fn nnz(&self) -> usize {
        self.manifest.nnz
    }

    pub fn num_shards(&self) -> usize {
        self.manifest.shards.len()
    }

    /// The shards' global `[start, end)` row spans in disk order.
    pub fn spans(&self) -> Vec<(usize, usize)> {
        self.manifest.spans()
    }

    /// Read and decode one shard into an in-memory [`Dataset`] whose
    /// matrix is widened to the global `d`. Memory: one shard.
    pub fn load_shard(&self, i: usize) -> anyhow::Result<Dataset> {
        let entry = self
            .manifest
            .shards
            .get(i)
            .ok_or_else(|| {
                anyhow::anyhow!("shard {i} out of range ({} shards)", self.num_shards())
            })?;
        let path = self.dir.join(&entry.path);
        let mut bytes = Vec::with_capacity(entry.bytes as usize);
        std::fs::File::open(&path)
            .and_then(|mut f| f.read_to_end(&mut bytes))
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        let (header, ds) = format::decode_shard(&bytes, self.d())
            .map_err(|e| anyhow::anyhow!("decode {}: {e}", path.display()))?;
        // Cross-check file ↔ manifest: the decoder proved the file is
        // *internally* consistent; the manifest's recorded CRC proves
        // it is the file this store was packed with (a swapped-in
        // shard from another pack is self-consistent but wrong).
        let file_crc =
            u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().expect("decoded shard"));
        anyhow::ensure!(
            file_crc == entry.crc32,
            "{}: file CRC {:08x} disagrees with manifest {:08x} (shard replaced after pack?)",
            path.display(),
            file_crc,
            entry.crc32
        );
        anyhow::ensure!(
            bytes.len() as u64 == entry.bytes,
            "{}: file is {} bytes, manifest says {}",
            path.display(),
            bytes.len(),
            entry.bytes
        );
        anyhow::ensure!(
            header.row_start == entry.row_start && header.row_end == entry.row_end,
            "{}: header rows [{}, {}) disagree with manifest [{}, {})",
            path.display(),
            header.row_start,
            header.row_end,
            entry.row_start,
            entry.row_end
        );
        anyhow::ensure!(
            header.nnz == entry.nnz,
            "{}: header nnz {} disagrees with manifest {}",
            path.display(),
            header.nnz,
            entry.nnz
        );
        Ok(ds.with_name(format!("{}[{}]", self.manifest.name, i)))
    }

    /// Decode every shard (CRC + full structural validation) without
    /// keeping more than one in memory. The `data inspect --verify`
    /// backend.
    pub fn verify(&self) -> anyhow::Result<()> {
        for i in 0..self.num_shards() {
            let ds = self.load_shard(i)?;
            let entry = &self.manifest.shards[i];
            anyhow::ensure!(
                ds.n() == entry.rows(),
                "shard {i}: decoded {} rows, manifest says {}",
                ds.n(),
                entry.rows()
            );
        }
        Ok(())
    }

    /// Assemble the full in-memory dataset by streaming shards in disk
    /// order — the bridge to engines that still want a flat
    /// [`Dataset`]. This is the one operation whose memory is the
    /// whole dataset (plus one shard transiently).
    pub fn materialize(&self) -> anyhow::Result<Dataset> {
        let n = self.n();
        let mut indptr = Vec::with_capacity(n + 1);
        indptr.push(0usize);
        let mut indices = Vec::with_capacity(self.nnz());
        let mut values = Vec::with_capacity(self.nnz());
        let mut labels = Vec::with_capacity(n);
        for i in 0..self.num_shards() {
            let shard = self.load_shard(i)?;
            let offset = indices.len();
            for &p in &shard.x.indptr[1..] {
                indptr.push(offset + p);
            }
            indices.extend_from_slice(&shard.x.indices);
            values.extend_from_slice(&shard.x.values);
            labels.extend_from_slice(&shard.y);
        }
        let x = CsrMatrix { indptr, indices, values, dim: self.d().max(1) };
        Ok(Dataset::new(x, labels).with_name(self.manifest.name.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::Preset;
    use crate::data::Strategy;
    use crate::store::pack::{pack_dataset, PackOptions};
    use crate::util::Rng;

    fn packed_tiny(tag: &str, shard_rows: usize) -> (Dataset, PathBuf) {
        let ds = Preset::Tiny.generate(&mut Rng::new(11));
        let dir = std::env::temp_dir().join(format!("hybrid_dca_sharded_{tag}"));
        std::fs::remove_dir_all(&dir).ok();
        let opts = PackOptions { name: "tiny".into(), shard_rows, ..Default::default() };
        pack_dataset(&ds, &dir, &opts, Strategy::Contiguous).unwrap();
        (ds, dir)
    }

    #[test]
    fn open_reads_only_the_manifest() {
        let (ds, dir) = packed_tiny("open", 64);
        let store = open(&dir).unwrap();
        assert_eq!(store.n(), ds.n());
        assert_eq!(store.d(), ds.d());
        assert_eq!(store.nnz(), ds.x.nnz());
        assert_eq!(store.name(), "tiny");
        assert_eq!(store.num_shards(), 4); // 200 / 64 → 64+64+64+8
        assert_eq!(store.spans()[0], (0, 64));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_shard_is_the_row_slice() {
        let (ds, dir) = packed_tiny("slice", 64);
        let store = open(&dir).unwrap();
        let s1 = store.load_shard(1).unwrap();
        assert_eq!(s1.n(), 64);
        assert_eq!(s1.d(), ds.d());
        for (local, global) in (64..128).enumerate() {
            assert_eq!(s1.x.row(local), ds.x.row(global), "row {global}");
            assert_eq!(s1.y[local], ds.y[global]);
        }
        assert!(store.load_shard(99).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn materialize_is_bitwise_identical() {
        let (ds, dir) = packed_tiny("mat", 32);
        let store = open(&dir).unwrap();
        let back = store.materialize().unwrap();
        assert_eq!(back.x.indptr, ds.x.indptr);
        assert_eq!(back.x.indices, ds.x.indices);
        assert_eq!(back.x.values, ds.x.values);
        assert_eq!(back.y, ds.y);
        assert_eq!(back.d(), ds.d());
        assert_eq!(back.name, "tiny");
        store.verify().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_shard_caught_on_load() {
        let (_, dir) = packed_tiny("corrupt", 64);
        let store = open(&dir).unwrap();
        let victim = dir.join(&store.manifest().shards[2].path);
        let mut bytes = std::fs::read(&victim).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        std::fs::write(&victim, &bytes).unwrap();
        let err = store.load_shard(2).unwrap_err();
        assert!(err.to_string().contains("CRC"), "{err}");
        assert!(store.verify().is_err());
        // Untouched shards still load.
        store.load_shard(0).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_file_crc_cross_checked() {
        // A shard file that is internally valid but not the one the
        // manifest recorded (e.g. swapped in from another pack) must
        // fail the manifest↔file CRC cross-check.
        let (_, dir) = packed_tiny("crosscheck", 64);
        let mut m = Manifest::load(&dir).unwrap();
        m.shards[1].crc32 ^= 1;
        m.save(&dir).unwrap();
        let store = open(&dir).unwrap();
        let err = store.load_shard(1).unwrap_err();
        assert!(err.to_string().contains("manifest"), "{err}");
        assert!(store.verify().is_err());
        store.load_shard(0).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_a_clear_error() {
        let dir = std::env::temp_dir().join("hybrid_dca_sharded_nostore");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let err = open(&dir).unwrap_err();
        assert!(err.to_string().contains("manifest.json"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
