//! The binary shard format: one node-local CSR block per file.
//!
//! Hand-encoded little-endian (the `util/json` philosophy: no serde
//! offline, and a fixed layout we can document byte-for-byte). Layout
//! of version 1:
//!
//! ```text
//! offset  size         field
//! 0       8            magic  b"HDCASHRD"
//! 8       4            version u32 (= 1)
//! 12      4            flags   u32 (reserved, 0)
//! 16      8            row_start u64   global row range [row_start,
//! 24      8            row_end   u64    row_end) in pack order
//! 32      8            dim       u64   max feature index + 1 *in this
//!                                      shard* (global d lives in the
//!                                      manifest)
//! 40      8            nnz       u64
//! 48      (n+1)×8      indptr  u64[]   shard-local, indptr[0] = 0
//! …       nnz×4        indices u32[]   strictly sorted per row
//! …       nnz×8        values  f64[]   finite
//! …       n×8          labels  f64[]   ±1
//! end−4   4            crc32   u32     IEEE CRC-32 of all preceding
//!                                      bytes
//! ```
//!
//! The decoder is paranoid: CRC first, then structural CSR invariants,
//! then the same non-finite guard `libsvm::rows` applies to text input
//! — a corrupt or hand-edited shard can never reach a solver.

use crate::data::csr::CsrMatrix;
use crate::data::Dataset;

/// File magic, start of every shard.
pub const MAGIC: [u8; 8] = *b"HDCASHRD";
/// Current format version.
pub const VERSION: u32 = 1;
/// Fixed header length in bytes (everything before `indptr`).
pub const HEADER_LEN: usize = 48;
/// Shard file extension used by the packer.
pub const SHARD_EXT: &str = "csr";

/// Decoded shard header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardHeader {
    /// Global row range `[row_start, row_end)` this shard covers.
    pub row_start: usize,
    pub row_end: usize,
    /// Max feature index + 1 observed in this shard.
    pub dim: usize,
    pub nnz: usize,
}

impl ShardHeader {
    #[inline]
    pub fn rows(&self) -> usize {
        self.row_end - self.row_start
    }
}

/// Exact encoded size of a shard with `rows` rows and `nnz` nonzeros
/// (header + arrays + trailing CRC). Used by the packer's byte budget.
pub fn encoded_len(rows: usize, nnz: usize) -> usize {
    HEADER_LEN + (rows + 1) * 8 + nnz * 4 + nnz * 8 + rows * 8 + 4
}

/// IEEE CRC-32 (the zlib/PNG polynomial), table-driven.
pub fn crc32(bytes: &[u8]) -> u32 {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, slot) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *slot = c;
        }
        t
    });
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Encode one shard: the rows of `ds` become global rows
/// `[row_start, row_start + ds.n())`. The matrix's `dim` is recorded
/// as the shard-local dim (callers pass a matrix whose `dim` is the
/// shard-local max index + 1; the global d lives in the manifest).
pub fn encode_shard(ds: &Dataset, row_start: usize) -> Vec<u8> {
    let n = ds.n();
    let nnz = ds.x.nnz();
    let mut out = Vec::with_capacity(encoded_len(n, nnz));
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&0u32.to_le_bytes()); // reserved flags
    out.extend_from_slice(&(row_start as u64).to_le_bytes());
    out.extend_from_slice(&((row_start + n) as u64).to_le_bytes());
    out.extend_from_slice(&(ds.d() as u64).to_le_bytes());
    out.extend_from_slice(&(nnz as u64).to_le_bytes());
    for &p in &ds.x.indptr {
        out.extend_from_slice(&(p as u64).to_le_bytes());
    }
    for &j in &ds.x.indices {
        out.extend_from_slice(&j.to_le_bytes());
    }
    for &v in &ds.x.values {
        out.extend_from_slice(&v.to_le_bytes());
    }
    for &y in &ds.y {
        out.extend_from_slice(&y.to_le_bytes());
    }
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

fn read_u32(b: &[u8], pos: &mut usize) -> u32 {
    let v = u32::from_le_bytes(b[*pos..*pos + 4].try_into().expect("length checked"));
    *pos += 4;
    v
}

fn read_u64(b: &[u8], pos: &mut usize) -> u64 {
    let v = u64::from_le_bytes(b[*pos..*pos + 8].try_into().expect("length checked"));
    *pos += 8;
    v
}

fn read_f64(b: &[u8], pos: &mut usize) -> f64 {
    let v = f64::from_le_bytes(b[*pos..*pos + 8].try_into().expect("length checked"));
    *pos += 8;
    v
}

/// Decode and fully validate one shard.
///
/// `global_dim` is the manifest's dataset-wide `d`; the decoded matrix
/// is widened to it (pass 0 to use the shard-local dim). Every failure
/// mode — wrong magic/version, truncation, CRC mismatch, broken CSR
/// invariants, non-finite values, non-±1 labels — is a typed error,
/// never a panic.
pub fn decode_shard(bytes: &[u8], global_dim: usize) -> anyhow::Result<(ShardHeader, Dataset)> {
    anyhow::ensure!(
        bytes.len() >= HEADER_LEN + 4,
        "shard truncated: {} bytes < minimum {}",
        bytes.len(),
        HEADER_LEN + 4
    );
    anyhow::ensure!(bytes[..8] == MAGIC, "bad shard magic (not a shard file?)");
    // CRC before anything else: all further parsing assumes intact bytes.
    let body = &bytes[..bytes.len() - 4];
    let stored_crc = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().expect("4 bytes"));
    let actual_crc = crc32(body);
    anyhow::ensure!(
        stored_crc == actual_crc,
        "shard CRC-32 mismatch: stored {stored_crc:#010x}, computed {actual_crc:#010x} \
         (file corrupt or truncated)"
    );

    let mut pos = 8usize;
    let version = read_u32(bytes, &mut pos);
    anyhow::ensure!(
        version == VERSION,
        "unsupported shard version {version} (this build reads version {VERSION})"
    );
    let _flags = read_u32(bytes, &mut pos);
    let row_start = read_u64(bytes, &mut pos);
    let row_end = read_u64(bytes, &mut pos);
    let dim = read_u64(bytes, &mut pos);
    let nnz = read_u64(bytes, &mut pos);
    anyhow::ensure!(row_end > row_start, "empty or inverted row range [{row_start}, {row_end})");
    let n = (row_end - row_start) as usize;

    // Checked size arithmetic in u64: a corrupt header must produce an
    // error, not an overflow panic or an OOM-sized allocation.
    let expect = (HEADER_LEN as u64 + 4)
        .checked_add((n as u64 + 1).checked_mul(8).unwrap_or(u64::MAX))
        .and_then(|t| t.checked_add(nnz.checked_mul(12)?))
        .and_then(|t| t.checked_add((n as u64).checked_mul(8)?))
        .ok_or_else(|| anyhow::anyhow!("shard header sizes overflow (n={n}, nnz={nnz})"))?;
    anyhow::ensure!(
        expect == bytes.len() as u64,
        "shard length mismatch: header implies {expect} bytes, file has {}",
        bytes.len()
    );
    let nnz = nnz as usize;

    let mut indptr = Vec::with_capacity(n + 1);
    for _ in 0..=n {
        indptr.push(read_u64(bytes, &mut pos) as usize);
    }
    anyhow::ensure!(indptr[0] == 0, "indptr[0] != 0");
    anyhow::ensure!(
        *indptr.last().expect("n+1 entries") == nnz,
        "indptr end {} != nnz {nnz}",
        indptr.last().expect("n+1 entries")
    );
    for w in indptr.windows(2) {
        anyhow::ensure!(w[0] <= w[1], "indptr not monotone");
    }

    let dim_eff = if global_dim == 0 {
        dim as usize
    } else {
        anyhow::ensure!(
            dim as usize <= global_dim,
            "shard-local dim {dim} exceeds manifest dim {global_dim}"
        );
        global_dim
    };

    let mut indices = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        indices.push(read_u32(bytes, &mut pos));
    }
    let mut values = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        values.push(read_f64(bytes, &mut pos));
    }
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        labels.push(read_f64(bytes, &mut pos));
    }
    debug_assert_eq!(pos, bytes.len() - 4);

    // Per-row structural checks + the same non-finite guard the LIBSVM
    // reader applies to text input.
    for i in 0..n {
        let (s, e) = (indptr[i], indptr[i + 1]);
        let row_idx = &indices[s..e];
        for w in row_idx.windows(2) {
            anyhow::ensure!(
                w[0] < w[1],
                "row {i}: indices not strictly sorted ({} then {})",
                w[0],
                w[1]
            );
        }
        if let Some(&last) = row_idx.last() {
            anyhow::ensure!(
                (last as usize) < dim_eff,
                "row {i}: index {last} out of range (dim={dim_eff})"
            );
        }
        for &v in &values[s..e] {
            anyhow::ensure!(v.is_finite(), "row {i}: non-finite value {v}");
        }
        let y = labels[i];
        anyhow::ensure!(y == 1.0 || y == -1.0, "row {i}: label {y} not ±1");
    }

    let header = ShardHeader {
        row_start: row_start as usize,
        row_end: row_end as usize,
        dim: dim as usize,
        nnz,
    };
    let x = CsrMatrix { indptr, indices, values, dim: dim_eff.max(1) };
    Ok((header, Dataset::new(x, labels)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::csr::CsrBuilder;

    fn tiny_shard() -> Dataset {
        let mut b = CsrBuilder::new(4);
        b.push_row(vec![(0, 1.0), (3, -2.5)]).unwrap();
        b.push_row(vec![(1, 0.75)]).unwrap();
        b.push_row(vec![]).unwrap();
        Dataset::new(b.finish(), vec![1.0, -1.0, 1.0])
    }

    #[test]
    fn crc32_known_vector() {
        // The canonical IEEE CRC-32 check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn encode_decode_roundtrip_bitwise() {
        let ds = tiny_shard();
        let bytes = encode_shard(&ds, 10);
        assert_eq!(bytes.len(), encoded_len(3, 3));
        let (h, back) = decode_shard(&bytes, 0).unwrap();
        assert_eq!(h, ShardHeader { row_start: 10, row_end: 13, dim: 4, nnz: 3 });
        assert_eq!(back.x, ds.x);
        assert_eq!(back.y, ds.y);
    }

    #[test]
    fn global_dim_widens() {
        let ds = tiny_shard();
        let bytes = encode_shard(&ds, 0);
        let (_, back) = decode_shard(&bytes, 100).unwrap();
        assert_eq!(back.d(), 100);
        // A global dim smaller than the shard's is a manifest/shard
        // disagreement, not something to silently truncate.
        assert!(decode_shard(&bytes, 2).is_err());
    }

    #[test]
    fn corruption_detected_by_crc() {
        let ds = tiny_shard();
        let mut bytes = encode_shard(&ds, 0);
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        let err = decode_shard(&bytes, 0).unwrap_err();
        assert!(err.to_string().contains("CRC"), "{err}");
    }

    #[test]
    fn truncation_and_magic_rejected() {
        let ds = tiny_shard();
        let bytes = encode_shard(&ds, 0);
        assert!(decode_shard(&bytes[..HEADER_LEN], 0).is_err());
        assert!(decode_shard(&bytes[..bytes.len() - 1], 0).is_err());
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(decode_shard(&bad, 0).is_err());
    }

    #[test]
    fn wrong_version_rejected() {
        let ds = tiny_shard();
        let mut bytes = encode_shard(&ds, 0);
        bytes[8] = 99; // version field
        let crc_at = bytes.len() - 4;
        let crc = crc32(&bytes[..crc_at]);
        bytes[crc_at..].copy_from_slice(&crc.to_le_bytes());
        let err = decode_shard(&bytes, 0).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
    }

    #[test]
    fn non_finite_value_rejected_even_with_valid_crc() {
        // Craft a shard whose payload smuggles a NaN value, re-seal the
        // CRC, and confirm the decoder's finite guard still fires —
        // the guard mirrors libsvm::rows on the binary path.
        let ds = tiny_shard();
        let mut bytes = encode_shard(&ds, 0);
        let values_off = HEADER_LEN + 4 * 8 + 3 * 4; // indptr (n+1=4) + indices (nnz=3)
        bytes[values_off..values_off + 8].copy_from_slice(&f64::NAN.to_le_bytes());
        let crc_at = bytes.len() - 4;
        let crc = crc32(&bytes[..crc_at]);
        bytes[crc_at..].copy_from_slice(&crc.to_le_bytes());
        let err = decode_shard(&bytes, 0).unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
    }

    #[test]
    fn bad_label_rejected() {
        let mut ds = tiny_shard();
        ds.y[1] = 0.5;
        let bytes = encode_shard(&ds, 0);
        let err = decode_shard(&bytes, 0).unwrap_err();
        assert!(err.to_string().contains("not ±1"), "{err}");
    }
}
