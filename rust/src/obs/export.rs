//! Snapshot exporters: Prometheus text exposition, metrics JSON, and
//! Chrome-trace-event JSON (all serde-free via `util::json`).
//!
//! `train --metrics-out PATH` picks the format by extension — `.json`
//! writes [`metrics_json`], anything else writes
//! [`metrics_prometheus`] — and `--trace-out PATH` always writes
//! [`trace_json`] (the format Perfetto / `chrome://tracing` load).

use super::ObsSnapshot;
use crate::util::json::Json;
use std::fmt::Write as _;

/// Metric-name prefix for the Prometheus exposition, so a scrape of a
/// mixed fleet can select this process family.
const PROM_PREFIX: &str = "hdca_";

/// The metrics snapshot as one JSON object: `counters` and `gauges`
/// are flat name→value maps in catalog order, `histograms` carry
/// cumulative `le` buckets, `net` the per-peer byte/frame totals
/// (equal to `RunReport.net` by construction).
pub fn metrics_json(snap: &ObsSnapshot) -> Json {
    let counters =
        snap.counters.iter().map(|&(n, v)| (n.to_string(), Json::Num(v as f64))).collect();
    let gauges = snap.gauges.iter().map(|&(n, v)| (n.to_string(), Json::Num(v as f64))).collect();
    let hists = snap
        .hists
        .iter()
        .map(|h| {
            Json::Obj(vec![
                ("name".into(), Json::Str(h.name.into())),
                ("count".into(), Json::Num(h.count as f64)),
                ("sum".into(), Json::Num(h.sum as f64)),
                (
                    "buckets".into(),
                    Json::Arr(
                        h.buckets
                            .iter()
                            .map(|&(le, cum)| {
                                Json::Obj(vec![
                                    ("le".into(), Json::Num(le as f64)),
                                    ("count".into(), Json::Num(cum as f64)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ])
        })
        .collect();
    let net = snap
        .net
        .iter()
        .enumerate()
        .map(|(peer, p)| {
            Json::Obj(vec![
                ("peer".into(), Json::Num(peer as f64)),
                ("sent_bytes".into(), Json::Num(p.sent_bytes as f64)),
                ("recv_bytes".into(), Json::Num(p.recv_bytes as f64)),
                ("sent_frames".into(), Json::Num(p.sent_frames as f64)),
                ("recv_frames".into(), Json::Num(p.recv_frames as f64)),
            ])
        })
        .collect();
    Json::Obj(vec![
        ("counters".into(), Json::Obj(counters)),
        ("gauges".into(), Json::Obj(gauges)),
        ("histograms".into(), Json::Arr(hists)),
        ("net".into(), Json::Arr(net)),
    ])
}

/// The metrics snapshot in Prometheus text exposition format
/// (version 0.0.4): `# TYPE` lines, `_bucket{le=...}` cumulative
/// histogram series ending in `le="+Inf"`, and per-peer net counters
/// as labeled series.
pub fn metrics_prometheus(snap: &ObsSnapshot) -> String {
    let mut out = String::new();
    for &(name, v) in &snap.counters {
        let _ = writeln!(out, "# TYPE {PROM_PREFIX}{name} counter");
        let _ = writeln!(out, "{PROM_PREFIX}{name} {v}");
    }
    for &(name, v) in &snap.gauges {
        let _ = writeln!(out, "# TYPE {PROM_PREFIX}{name} gauge");
        let _ = writeln!(out, "{PROM_PREFIX}{name} {v}");
    }
    for h in &snap.hists {
        let name = h.name;
        let _ = writeln!(out, "# TYPE {PROM_PREFIX}{name} histogram");
        for &(le, cum) in &h.buckets {
            let _ = writeln!(out, "{PROM_PREFIX}{name}_bucket{{le=\"{le}\"}} {cum}");
        }
        let _ = writeln!(out, "{PROM_PREFIX}{name}_bucket{{le=\"+Inf\"}} {}", h.count);
        let _ = writeln!(out, "{PROM_PREFIX}{name}_sum {}", h.sum);
        let _ = writeln!(out, "{PROM_PREFIX}{name}_count {}", h.count);
    }
    let net_fields: [(&str, fn(&super::PeerNet) -> u64); 4] = [
        ("net_sent_bytes", |p| p.sent_bytes),
        ("net_recv_bytes", |p| p.recv_bytes),
        ("net_sent_frames", |p| p.sent_frames),
        ("net_recv_frames", |p| p.recv_frames),
    ];
    for (which, get) in net_fields {
        if snap.net.is_empty() {
            continue;
        }
        let _ = writeln!(out, "# TYPE {PROM_PREFIX}{which} counter");
        for (peer, p) in snap.net.iter().enumerate() {
            let _ = writeln!(out, "{PROM_PREFIX}{which}{{peer=\"{peer}\"}} {}", get(p));
        }
    }
    out
}

/// The timeline as Chrome trace-event JSON (the
/// `{"traceEvents": [...]}` object form): complete spans carry
/// `ph: "X"` with `ts`/`dur` in microseconds, instants `ph: "i"` with
/// thread scope. `pid` is the recording OS process, `tid` 0 the
/// master, `tid = w + 1` worker `w`.
pub fn trace_json(snap: &ObsSnapshot) -> Json {
    let pid = std::process::id() as f64;
    let events = snap
        .trace
        .iter()
        .map(|e| {
            let mut kv = vec![
                ("name".into(), Json::Str(e.name.into())),
                ("cat".into(), Json::Str(e.cat.into())),
                ("ph".into(), Json::Str(e.ph.to_string())),
                ("ts".into(), Json::Num(e.ts_us as f64)),
            ];
            if e.ph == 'X' {
                kv.push(("dur".into(), Json::Num(e.dur_us as f64)));
            }
            if e.ph == 'i' {
                // Thread-scoped instants render as small arrows.
                kv.push(("s".into(), Json::Str("t".into())));
            }
            kv.push(("pid".into(), Json::Num(pid)));
            kv.push(("tid".into(), Json::Num(e.tid as f64)));
            kv.push((
                "args".into(),
                Json::Obj(e.args.iter().map(|(k, v)| ((*k).to_string(), v.clone())).collect()),
            ));
            Json::Obj(kv)
        })
        .collect();
    Json::Obj(vec![("traceEvents".into(), Json::Arr(events))])
}

/// Write the metrics snapshot to `path`, JSON for a `.json` extension,
/// Prometheus text otherwise.
pub fn write_metrics(path: &str, snap: &ObsSnapshot) -> anyhow::Result<()> {
    let body = if path.ends_with(".json") {
        metrics_json(snap).to_pretty()
    } else {
        metrics_prometheus(snap)
    };
    std::fs::write(path, body).map_err(|e| anyhow::anyhow!("write metrics {path}: {e}"))
}

/// Write the Chrome-trace JSON to `path`.
pub fn write_trace(path: &str, snap: &ObsSnapshot) -> anyhow::Result<()> {
    std::fs::write(path, trace_json(snap).to_pretty())
        .map_err(|e| anyhow::anyhow!("write trace {path}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{HistSnapshot, PeerNet, TraceEvent};

    fn sample() -> ObsSnapshot {
        ObsSnapshot {
            counters: vec![("rounds_total", 8), ("merges_total", 14)],
            gauges: vec![("eval_shard_residency_peak", 1)],
            hists: vec![HistSnapshot {
                name: "staleness_rounds",
                count: 14,
                sum: 19,
                buckets: vec![(1, 10), (3, 14)],
            }],
            net: vec![
                PeerNet { sent_bytes: 100, recv_bytes: 200, sent_frames: 3, recv_frames: 4 },
                PeerNet { sent_bytes: 10, recv_bytes: 20, sent_frames: 1, recv_frames: 2 },
            ],
            trace: vec![
                TraceEvent {
                    name: "worker_round",
                    cat: "compute",
                    ph: 'X',
                    ts_us: 5,
                    dur_us: 120,
                    tid: 1,
                    args: vec![("updates", Json::Num(256.0))],
                },
                TraceEvent {
                    name: "merge",
                    cat: "master",
                    ph: 'i',
                    ts_us: 130,
                    dur_us: 0,
                    tid: 0,
                    args: vec![("staleness", Json::Num(2.0))],
                },
            ],
        }
    }

    #[test]
    fn metrics_json_round_trips() {
        let j = metrics_json(&sample());
        let back = Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(back.get("counters").unwrap().get("rounds_total").unwrap().as_f64(), Some(8.0));
        let net = back.get("net").unwrap().as_arr().unwrap();
        assert_eq!(net.len(), 2);
        assert_eq!(net[1].get("recv_bytes").unwrap().as_f64(), Some(20.0));
        let h = &back.get("histograms").unwrap().as_arr().unwrap()[0];
        assert_eq!(h.get("count").unwrap().as_f64(), Some(14.0));
    }

    #[test]
    fn prometheus_exposition_shape() {
        let text = metrics_prometheus(&sample());
        assert!(text.contains("# TYPE hdca_rounds_total counter"), "{text}");
        assert!(text.contains("hdca_rounds_total 8"), "{text}");
        assert!(text.contains("hdca_eval_shard_residency_peak 1"), "{text}");
        assert!(text.contains("hdca_staleness_rounds_bucket{le=\"3\"} 14"), "{text}");
        assert!(text.contains("hdca_staleness_rounds_bucket{le=\"+Inf\"} 14"), "{text}");
        assert!(text.contains("hdca_staleness_rounds_sum 19"), "{text}");
        assert!(text.contains("hdca_net_sent_bytes{peer=\"0\"} 100"), "{text}");
        assert!(text.contains("hdca_net_recv_frames{peer=\"1\"} 2"), "{text}");
    }

    #[test]
    fn trace_json_is_chrome_shaped() {
        let j = trace_json(&sample());
        let back = Json::parse(&j.to_pretty()).unwrap();
        let events = back.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 2);
        let span = &events[0];
        assert_eq!(span.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(span.get("dur").unwrap().as_f64(), Some(120.0));
        assert_eq!(span.get("tid").unwrap().as_f64(), Some(1.0));
        assert_eq!(span.get("args").unwrap().get("updates").unwrap().as_f64(), Some(256.0));
        let inst = &events[1];
        assert_eq!(inst.get("ph").unwrap().as_str(), Some("i"));
        assert!(inst.get("dur").is_none(), "instants carry no dur");
        assert_eq!(inst.get("s").unwrap().as_str(), Some("t"));
    }
}
