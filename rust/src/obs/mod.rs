//! Process-wide observability: a run-scoped metrics registry and an
//! async-timeline tracer, off by default and costing one relaxed
//! atomic load per record call when disabled.
//!
//! ## Design
//!
//! The paper's contribution is a *schedule* — the bounded barrier `S`,
//! the staleness bound `Γ`, stragglers overlapping compute with
//! communication — and none of it is visible in a final objective
//! value. This module makes the schedule observable through one seam,
//! [`Recorder`], that every layer reports into:
//!
//! * **Metrics** — fixed-catalog counters, gauges, and log2-bucket
//!   histograms (see [`Counter`], [`Gauge`], [`HistId`]) allocated once
//!   per process and updated through the `util::sync` façade with
//!   `Relaxed` ordering. The solver side aggregates *per round*, never
//!   per coordinate update, so the 18.3M updates/s hot loop is
//!   untouched. A run's snapshot lands in `RunReport.obs`, prints as
//!   `# obs:` lines, and exports as Prometheus text or JSON
//!   (`train --metrics-out`).
//! * **Timeline trace** — Chrome-trace-event JSON
//!   (`train --trace-out`, open in Perfetto or `chrome://tracing`):
//!   spans for worker compute rounds, S-barrier waits, and eval
//!   rounds; instants for merges (tagged with the *measured* staleness
//!   Γ of each merged update), per-peer frame send/recv with byte
//!   sizes, and every chaos/fault event (stall, retransmit,
//!   declared-dead, rejoin).
//!
//! ## Lifecycle and parity
//!
//! The recorder is process-global ([`global`]) because worker threads,
//! transport decorators, and the evaluator pool all need it without
//! threading a handle through every signature. A run brackets itself
//! with [`begin`] / [`RunGuard::finish`]; the first `begin` in a
//! process (the *primary* — the master, or a `node` process's single
//! run) resets and enables the registry and its `finish` takes the
//! snapshot. Nested begins (worker threads of an in-process cluster
//! test) share the primary's registry and snapshot nothing, so a
//! same-process master + workers topology cannot deadlock or
//! double-count.
//!
//! Observability never feeds back into the solve: recording only
//! *reads* solver state, `RunReport.obs` is excluded from `--dump` by
//! construction, and with the default `ObsCfg { enabled: false }`
//! every record call is a single relaxed load — which is why all
//! bitwise-parity CI runs unchanged.

pub mod export;
pub mod report;

use crate::transport::TransportStats;
use crate::util::json::Json;
use crate::util::sync::{AtomicBool, AtomicU64, Mutex, OnceLock, Ordering};
use std::time::Instant;

/// `[obs]` config table: both knobs default off, so observability is
/// strictly opt-in (`--metrics-out` / `--trace-out` imply `enabled`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ObsCfg {
    /// Master switch for the metrics registry (and the trace, below).
    pub enabled: bool,
    /// Also record the Chrome-trace-event timeline. Implies nothing
    /// about `enabled` — a trace without metrics makes no sense, so
    /// `trace = true` only records when `enabled` is also set.
    pub trace: bool,
}

/// Monotonic counters in the fixed catalog (see README "Observability"
/// for meanings). Indexes into the recorder's counter table.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Counter {
    /// Master global rounds completed (merge events).
    Rounds,
    /// Worker updates folded into merges (≥ rounds when S > 1).
    Merges,
    /// Coordinate updates carried by merged messages (master view).
    Updates,
    /// Local rounds completed across all workers.
    WorkerRounds,
    /// Objective evaluations performed.
    Evals,
    /// Liveness-tick strikes against silent computing peers.
    FaultStalls,
    /// Nack-triggered retransmits (corrupt or lost frames).
    FaultRetransmits,
    /// Workers readmitted through the Rejoin handshake.
    FaultRejoins,
    /// Workers declared dead by the suspicion policy.
    FaultDeaths,
}

impl Counter {
    pub const ALL: [Counter; 9] = [
        Counter::Rounds,
        Counter::Merges,
        Counter::Updates,
        Counter::WorkerRounds,
        Counter::Evals,
        Counter::FaultStalls,
        Counter::FaultRetransmits,
        Counter::FaultRejoins,
        Counter::FaultDeaths,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Counter::Rounds => "rounds_total",
            Counter::Merges => "merges_total",
            Counter::Updates => "updates_total",
            Counter::WorkerRounds => "worker_rounds_total",
            Counter::Evals => "evals_total",
            Counter::FaultStalls => "fault_stalls_total",
            Counter::FaultRetransmits => "fault_retransmits_total",
            Counter::FaultRejoins => "fault_rejoins_total",
            Counter::FaultDeaths => "fault_deaths_total",
        }
    }
}

/// Gauges (last-value or high-water-mark) in the fixed catalog.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Gauge {
    /// Peak simultaneously-resident decoded shards during evaluation
    /// (the PR 6 residency gauge, surfaced from `store::sharded`).
    ResidencyPeak,
    /// Live workers at the end of the run (`K_live` after deaths and
    /// rejoins).
    KLive,
}

impl Gauge {
    pub const ALL: [Gauge; 2] = [Gauge::ResidencyPeak, Gauge::KLive];

    pub fn name(self) -> &'static str {
        match self {
            Gauge::ResidencyPeak => "eval_shard_residency_peak",
            Gauge::KLive => "k_live",
        }
    }
}

/// Log2-bucket histograms in the fixed catalog.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HistId {
    /// Measured staleness Γ of each merged update, in rounds — the
    /// distribution the configured `gamma` bound caps.
    Staleness,
    /// Wall-clock time the master spent holding the S-barrier open,
    /// per round, in microseconds.
    BarrierWaitUs,
    /// Wall-clock time per worker compute round (R cores × H
    /// iterations), in microseconds.
    WorkerRoundUs,
    /// Wall-clock time per objective evaluation, in microseconds.
    EvalUs,
}

impl HistId {
    pub const ALL: [HistId; 4] =
        [HistId::Staleness, HistId::BarrierWaitUs, HistId::WorkerRoundUs, HistId::EvalUs];

    pub fn name(self) -> &'static str {
        match self {
            HistId::Staleness => "staleness_rounds",
            HistId::BarrierWaitUs => "barrier_wait_us",
            HistId::WorkerRoundUs => "worker_round_us",
            HistId::EvalUs => "eval_us",
        }
    }
}

/// Typed fault kinds — the trace-event names the chaos tests grep for,
/// and the mapping onto the `fault_*_total` counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    Stall,
    Retransmit,
    DeclaredDead,
    Rejoin,
}

impl FaultKind {
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::Stall => "stall",
            FaultKind::Retransmit => "retransmit",
            FaultKind::DeclaredDead => "declared_dead",
            FaultKind::Rejoin => "rejoin",
        }
    }

    fn counter(self) -> Counter {
        match self {
            FaultKind::Stall => Counter::FaultStalls,
            FaultKind::Retransmit => Counter::FaultRetransmits,
            FaultKind::DeclaredDead => Counter::FaultDeaths,
            FaultKind::Rejoin => Counter::FaultRejoins,
        }
    }
}

/// Number of log2 buckets: index 0 holds exact zeros, index `i ≥ 1`
/// holds values in `[2^(i-1), 2^i − 1]`, so index 64 (values with the
/// top bit set) is the last — no clamping needed for any `u64`.
const HIST_BUCKETS: usize = 65;

/// Bucket index for `v`: 0 for 0, otherwise `64 − leading_zeros(v)`.
fn bucket_index(v: u64) -> usize {
    (64 - v.leading_zeros()) as usize
}

/// Inclusive upper bound of bucket `i` (`2^i − 1`, saturating).
fn bucket_le(i: usize) -> u64 {
    if i >= 64 {
        u64::MAX
    } else {
        (1u64 << i) - 1
    }
}

/// One log2-bucket histogram. All fields are relaxed atomics: each
/// observation is independent and the snapshot happens after every
/// recording thread has joined, so no ordering is needed beyond
/// atomicity (same argument as the residency gauge in
/// `store::sharded`).
struct Hist {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
}

impl Hist {
    fn new() -> Hist {
        Hist {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    fn observe(&self, v: u64) {
        // ORDERING: Relaxed — independent monotone accumulators read
        // only at snapshot time, after recording threads joined.
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
    }

    fn snapshot(&self, id: HistId) -> HistSnapshot {
        let raw: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let last = raw.iter().rposition(|&c| c > 0).map_or(0, |i| i + 1);
        let mut cumulative = 0u64;
        let buckets = raw[..last]
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                cumulative += c;
                (bucket_le(i), cumulative)
            })
            .collect();
        HistSnapshot {
            name: id.name(),
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            buckets,
        }
    }
}

/// Point-in-time copy of one histogram: `buckets` are
/// `(inclusive upper bound, cumulative count)` pairs, truncated after
/// the last non-empty bucket (Prometheus `le` semantics).
#[derive(Debug, Clone)]
pub struct HistSnapshot {
    pub name: &'static str,
    pub count: u64,
    pub sum: u64,
    pub buckets: Vec<(u64, u64)>,
}

impl HistSnapshot {
    /// Inclusive upper bound of the bucket containing the q-quantile
    /// (0 ≤ q ≤ 1), or `None` on an empty histogram. Log2 buckets make
    /// this a ≤ 2× over-estimate — good enough for `# obs:` lines.
    pub fn quantile_le(&self, q: f64) -> Option<u64> {
        if self.count == 0 {
            return None;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        self.buckets.iter().find(|&&(_, cum)| cum >= rank).map(|&(le, _)| le)
    }

    /// Inclusive upper bound of the highest non-empty bucket.
    pub fn max_le(&self) -> Option<u64> {
        self.buckets.last().map(|&(le, _)| le)
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

/// Per-peer transport byte/frame totals mirrored from the run's final
/// [`TransportStats`] so the exported snapshot matches `RunReport.net`
/// exactly (CI asserts this).
#[derive(Debug, Clone, Default)]
pub struct PeerNet {
    pub sent_bytes: u64,
    pub recv_bytes: u64,
    pub sent_frames: u64,
    pub recv_frames: u64,
}

/// One Chrome-trace event: a complete span (`ph = 'X'`, with a
/// duration) or an instant (`ph = 'i'`). `tid` 0 is the master /
/// single-process driver; worker `w` records as `tid = w + 1`.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub name: &'static str,
    pub cat: &'static str,
    pub ph: char,
    pub ts_us: u64,
    pub dur_us: u64,
    pub tid: u64,
    pub args: Vec<(&'static str, Json)>,
}

/// Mutex-guarded recorder state: the trace buffer and everything else
/// that is not a simple monotone counter. Trace pushes take this lock,
/// which is fine because every span/instant is per-round or per-frame,
/// never per coordinate update.
struct Inner {
    /// Concurrently-active `begin` scopes in this process (> 1 only
    /// for in-process cluster topologies, e.g. tests).
    active_runs: usize,
    /// Wall-clock zero of the current run's trace timestamps.
    epoch: Option<Instant>,
    trace: Vec<TraceEvent>,
    net: Vec<PeerNet>,
}

/// The observability seam: every layer (solver round boundaries,
/// master barrier/merge, transport frames, evaluator, chaos faults)
/// records through this one type. Obtain it via [`global`]; bracket a
/// run with [`begin`] / [`RunGuard::finish`].
pub struct Recorder {
    enabled: AtomicBool,
    tracing: AtomicBool,
    counters: Vec<AtomicU64>,
    gauges: Vec<AtomicU64>,
    hists: Vec<Hist>,
    inner: Mutex<Inner>,
}

static RECORDER: OnceLock<Recorder> = OnceLock::new();

/// The process-wide recorder (disabled until a [`begin`] enables it).
pub fn global() -> &'static Recorder {
    RECORDER.get_or_init(Recorder::new)
}

impl Recorder {
    fn new() -> Recorder {
        Recorder {
            enabled: AtomicBool::new(false),
            tracing: AtomicBool::new(false),
            counters: Counter::ALL.iter().map(|_| AtomicU64::new(0)).collect(),
            gauges: Gauge::ALL.iter().map(|_| AtomicU64::new(0)).collect(),
            hists: HistId::ALL.iter().map(|_| Hist::new()).collect(),
            inner: Mutex::new(Inner {
                active_runs: 0,
                epoch: None,
                trace: Vec::new(),
                net: Vec::new(),
            }),
        }
    }

    /// Is the registry recording? One relaxed load — the entire cost
    /// of every record call in a default (disabled) run.
    pub fn on(&self) -> bool {
        // ORDERING: Relaxed — a stale read during the begin/finish
        // transition at worst drops or keeps one observation; the
        // registry is reset under the inner lock before `enabled`
        // flips on, so no stale *data* can leak between runs.
        self.enabled.load(Ordering::Relaxed)
    }

    /// Is the timeline tracer recording?
    pub fn tracing_on(&self) -> bool {
        // ORDERING: Relaxed — same argument as `on`.
        self.tracing.load(Ordering::Relaxed)
    }

    /// Start a wall-clock measurement, or `None` when disabled — the
    /// `Some` branch is the only time `Instant::now()` is called, so
    /// disabled runs pay no clock reads.
    pub fn timer(&self) -> Option<Instant> {
        if self.on() {
            Some(Instant::now())
        } else {
            None
        }
    }

    pub fn add(&self, c: Counter, n: u64) {
        if self.on() {
            // ORDERING: Relaxed — monotone counter, snapshot-time read.
            self.counters[c as usize].fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Raise `g` to at least `v` (high-water-mark semantics).
    pub fn gauge_max(&self, g: Gauge, v: u64) {
        if self.on() {
            // ORDERING: Relaxed — independent high-water mark.
            self.gauges[g as usize].fetch_max(v, Ordering::Relaxed);
        }
    }

    /// Set `g` to `v` (last-writer-wins semantics).
    pub fn gauge_set(&self, g: Gauge, v: u64) {
        if self.on() {
            // ORDERING: Relaxed — last value wins; writers are the
            // master thread only.
            self.gauges[g as usize].store(v, Ordering::Relaxed);
        }
    }

    pub fn observe(&self, h: HistId, v: u64) {
        if self.on() {
            self.hists[h as usize].observe(v);
        }
    }

    /// Microseconds since the run epoch for timestamp `t`.
    fn ts_us(inner: &Inner, t: Instant) -> u64 {
        match inner.epoch {
            Some(epoch) => t.checked_duration_since(epoch).unwrap_or_default().as_micros() as u64,
            None => 0,
        }
    }

    /// Record a complete span from `t0` (a [`Self::timer`] token) to
    /// now. The histogram side (if any) is the caller's job — spans
    /// only exist when tracing.
    pub fn span(
        &self,
        name: &'static str,
        cat: &'static str,
        tid: u64,
        t0: Option<Instant>,
        args: Vec<(&'static str, Json)>,
    ) {
        let Some(t0) = t0 else { return };
        if !self.tracing_on() {
            return;
        }
        let dur_us = t0.elapsed().as_micros() as u64;
        let mut inner = self.inner.lock().expect("obs lock");
        let ts_us = Self::ts_us(&inner, t0);
        inner.trace.push(TraceEvent { name, cat, ph: 'X', ts_us, dur_us, tid, args });
    }

    /// Record an instant event at the current time.
    pub fn instant(
        &self,
        name: &'static str,
        cat: &'static str,
        tid: u64,
        args: Vec<(&'static str, Json)>,
    ) {
        if !self.tracing_on() {
            return;
        }
        let now = Instant::now();
        let mut inner = self.inner.lock().expect("obs lock");
        let ts_us = Self::ts_us(&inner, now);
        inner.trace.push(TraceEvent { name, cat, ph: 'i', ts_us, dur_us: 0, tid, args });
    }

    // ---- Domain-level recording (one method per instrumented site) ----

    /// One worker compute round (R cores × H iterations) finished.
    pub fn worker_round(&self, worker: usize, local_round: usize, updates: u64, t0: Option<Instant>) {
        if !self.on() {
            return;
        }
        self.add(Counter::WorkerRounds, 1);
        if let Some(t0) = t0 {
            self.observe(HistId::WorkerRoundUs, t0.elapsed().as_micros() as u64);
        }
        self.span(
            "worker_round",
            "compute",
            worker as u64 + 1,
            t0,
            vec![
                ("worker", Json::Num(worker as f64)),
                ("round", Json::Num(local_round as f64)),
                ("updates", Json::Num(updates as f64)),
            ],
        );
    }

    /// The master held the S-barrier open from `t0` until now.
    pub fn barrier_wait(&self, round: usize, merged: usize, t0: Option<Instant>) {
        if !self.on() {
            return;
        }
        if let Some(t0) = t0 {
            self.observe(HistId::BarrierWaitUs, t0.elapsed().as_micros() as u64);
        }
        self.span(
            "s_barrier_wait",
            "barrier",
            0,
            t0,
            vec![("round", Json::Num(round as f64)), ("merged", Json::Num(merged as f64))],
        );
    }

    /// One worker update was folded into a merge, with the measured
    /// staleness (`gamma_k` at pop time — the Γ the bound constrains).
    pub fn merged_update(&self, round: usize, worker: usize, staleness: usize, vtime: f64) {
        if !self.on() {
            return;
        }
        self.add(Counter::Merges, 1);
        self.observe(HistId::Staleness, staleness as u64);
        self.instant(
            "merge",
            "master",
            0,
            vec![
                ("round", Json::Num(round as f64)),
                ("worker", Json::Num(worker as f64)),
                ("staleness", Json::Num(staleness as f64)),
                ("vtime", Json::Num(vtime)),
            ],
        );
    }

    /// One master global round completed, carrying `updates` coordinate
    /// updates across its merged messages.
    pub fn master_round(&self, updates: u64) {
        self.add(Counter::Rounds, 1);
        self.add(Counter::Updates, updates);
    }

    /// One objective evaluation finished.
    pub fn eval(&self, round: usize, t0: Option<Instant>) {
        if !self.on() {
            return;
        }
        self.add(Counter::Evals, 1);
        if let Some(t0) = t0 {
            self.observe(HistId::EvalUs, t0.elapsed().as_micros() as u64);
        }
        self.span("eval", "eval", 0, t0, vec![("round", Json::Num(round as f64))]);
    }

    /// A chaos/fault event: bumps the kind's counter and drops a trace
    /// instant named after the kind (the chaos-trace test greps these).
    pub fn fault(&self, kind: FaultKind, worker: usize, round: usize, detail: &str) {
        if !self.on() {
            return;
        }
        self.add(kind.counter(), 1);
        self.instant(
            kind.name(),
            "fault",
            0,
            vec![
                ("worker", Json::Num(worker as f64)),
                ("round", Json::Num(round as f64)),
                ("detail", Json::Str(detail.to_string())),
            ],
        );
    }

    /// A free-text fault-log line (mirror of `RunReport.faults.events`).
    pub fn fault_log(&self, vtime: f64, round: usize, worker: usize, what: &str) {
        self.instant(
            "fault_log",
            "fault",
            0,
            vec![
                ("worker", Json::Num(worker as f64)),
                ("round", Json::Num(round as f64)),
                ("vtime", Json::Num(vtime)),
                ("detail", Json::Str(what.to_string())),
            ],
        );
    }

    /// A transport frame left for `peer` (`bytes` = wire length).
    pub fn frame_sent(&self, peer: usize, kind: &'static str, bytes: u64) {
        self.instant(
            "send",
            "net",
            0,
            vec![
                ("peer", Json::Num(peer as f64)),
                ("kind", Json::Str(kind.to_string())),
                ("bytes", Json::Num(bytes as f64)),
            ],
        );
    }

    /// A transport frame arrived from `peer`.
    pub fn frame_recv(&self, peer: usize, kind: &'static str, bytes: u64) {
        self.instant(
            "recv",
            "net",
            0,
            vec![
                ("peer", Json::Num(peer as f64)),
                ("kind", Json::Str(kind.to_string())),
                ("bytes", Json::Num(bytes as f64)),
            ],
        );
    }

    /// Mirror the run's final per-peer transport totals into the
    /// snapshot, so exported counters equal `RunReport.net` exactly.
    pub fn set_net(&self, stats: &TransportStats) {
        if !self.on() {
            return;
        }
        let mut inner = self.inner.lock().expect("obs lock");
        inner.net = stats
            .per_peer
            .iter()
            .map(|p| PeerNet {
                sent_bytes: p.sent_bytes,
                recv_bytes: p.recv_bytes,
                sent_frames: p.sent_frames,
                recv_frames: p.recv_frames,
            })
            .collect();
    }

    fn reset(&self, inner: &mut Inner) {
        for c in &self.counters {
            c.store(0, Ordering::Relaxed);
        }
        for g in &self.gauges {
            g.store(0, Ordering::Relaxed);
        }
        for h in &self.hists {
            h.reset();
        }
        inner.trace.clear();
        inner.net.clear();
    }

    fn snapshot(&self, inner: &mut Inner) -> ObsSnapshot {
        ObsSnapshot {
            counters: Counter::ALL
                .iter()
                .map(|&c| (c.name(), self.counters[c as usize].load(Ordering::Relaxed)))
                .collect(),
            gauges: Gauge::ALL
                .iter()
                .map(|&g| (g.name(), self.gauges[g as usize].load(Ordering::Relaxed)))
                .collect(),
            hists: HistId::ALL.iter().map(|&h| self.hists[h as usize].snapshot(h)).collect(),
            net: std::mem::take(&mut inner.net),
            trace: std::mem::take(&mut inner.trace),
        }
    }
}

/// Point-in-time copy of the whole registry, taken by the primary
/// [`RunGuard::finish`] and carried in `RunReport.obs`. Counters and
/// gauges are in catalog order.
#[derive(Debug, Default)]
pub struct ObsSnapshot {
    pub counters: Vec<(&'static str, u64)>,
    pub gauges: Vec<(&'static str, u64)>,
    pub hists: Vec<HistSnapshot>,
    pub net: Vec<PeerNet>,
    pub trace: Vec<TraceEvent>,
}

impl ObsSnapshot {
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.iter().find(|(n, _)| *n == name).map_or(0, |&(_, v)| v)
    }

    pub fn gauge(&self, name: &str) -> u64 {
        self.gauges.iter().find(|(n, _)| *n == name).map_or(0, |&(_, v)| v)
    }

    pub fn hist(&self, name: &str) -> Option<&HistSnapshot> {
        self.hists.iter().find(|h| h.name == name)
    }
}

/// Scope token from [`begin`]. The primary guard's [`finish`]
/// (first `begin` in the process) yields the run's snapshot and
/// disables the registry; secondary guards yield `None`.
///
/// [`finish`]: RunGuard::finish
#[must_use = "finish() takes the snapshot; dropping the guard discards it"]
pub struct RunGuard {
    primary: bool,
    done: bool,
}

/// Begin an observed run. `None` when `cfg.enabled` is false — the
/// caller then skips the finish/snapshot plumbing entirely.
pub fn begin(cfg: &ObsCfg) -> Option<RunGuard> {
    if !cfg.enabled {
        return None;
    }
    let rec = global();
    let mut inner = rec.inner.lock().expect("obs lock");
    let primary = inner.active_runs == 0;
    inner.active_runs += 1;
    if primary {
        rec.reset(&mut inner);
        inner.epoch = Some(Instant::now());
        // ORDERING: Relaxed — the reset above happens under the inner
        // lock before recording is observable; late recorders racing
        // the flip merely miss one observation.
        rec.enabled.store(true, Ordering::Relaxed);
        rec.tracing.store(cfg.trace, Ordering::Relaxed);
    } else if cfg.trace && !rec.tracing_on() {
        // A nested scope may widen (but never narrow) the trace.
        rec.tracing.store(true, Ordering::Relaxed);
    }
    drop(inner);
    Some(RunGuard { primary, done: false })
}

impl RunGuard {
    /// End the scope. The primary guard returns the run's snapshot and
    /// turns recording off; nested guards return `None`.
    pub fn finish(mut self) -> Option<ObsSnapshot> {
        let rec = global();
        let mut inner = rec.inner.lock().expect("obs lock");
        inner.active_runs = inner.active_runs.saturating_sub(1);
        self.done = true;
        if !self.primary {
            return None;
        }
        // ORDERING: Relaxed — see `begin`; stragglers recording after
        // this flip lose their observation, which is the documented
        // contract for nested scopes outliving the primary.
        rec.enabled.store(false, Ordering::Relaxed);
        rec.tracing.store(false, Ordering::Relaxed);
        inner.epoch = None;
        Some(rec.snapshot(&mut inner))
    }
}

impl Drop for RunGuard {
    fn drop(&mut self) {
        if self.done {
            return;
        }
        // Error-path unwind: release the scope without snapshotting.
        let rec = global();
        let mut inner = rec.inner.lock().expect("obs lock");
        inner.active_runs = inner.active_runs.saturating_sub(1);
        if self.primary {
            rec.enabled.store(false, Ordering::Relaxed);
            rec.tracing.store(false, Ordering::Relaxed);
            inner.epoch = None;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Lifecycle tests mutate the process-global recorder; serialize
    /// them so parallel `cargo test` threads cannot interleave scopes.
    fn lock() -> MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }
    use crate::util::sync::MutexGuard;

    #[test]
    fn bucket_boundaries() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        // Every power of two starts a new bucket; its predecessor ends
        // the previous one.
        for i in 1..64 {
            let p = 1u64 << i;
            assert_eq!(bucket_index(p), i + 1, "2^{i}");
            assert_eq!(bucket_index(p - 1), i, "2^{i} - 1");
        }
        assert_eq!(bucket_index(u64::MAX), 64);
        // `le` bounds are the inclusive bucket tops.
        assert_eq!(bucket_le(0), 0);
        assert_eq!(bucket_le(1), 1);
        assert_eq!(bucket_le(2), 3);
        assert_eq!(bucket_le(64), u64::MAX);
        for v in [0u64, 1, 2, 3, 4, 5, 1023, 1024, 1025, u64::MAX] {
            let i = bucket_index(v);
            assert!(v <= bucket_le(i), "{v} ≤ le({i})");
            assert!(i == 0 || v > bucket_le(i - 1), "{v} > le({})", i - 1);
        }
    }

    #[test]
    fn hist_snapshot_quantiles() {
        let h = Hist::new();
        for v in [1u64, 1, 1, 2, 4, 100] {
            h.observe(v);
        }
        let snap = h.snapshot(HistId::Staleness);
        assert_eq!(snap.count, 6);
        assert_eq!(snap.sum, 109);
        assert_eq!(snap.quantile_le(0.5), Some(1)); // rank 3 of 6 → bucket le=1
        assert_eq!(snap.max_le(), Some(127)); // 100 lands in [64, 127]
        assert!((snap.mean() - 109.0 / 6.0).abs() < 1e-12);
        // Cumulative counts are monotone and end at `count`.
        assert_eq!(snap.buckets.last().map(|&(_, c)| c), Some(6));
    }

    #[test]
    fn disabled_recorder_is_inert() {
        let _g = lock();
        let rec = global();
        assert!(!rec.on());
        rec.add(Counter::Rounds, 5);
        rec.observe(HistId::Staleness, 3);
        rec.instant("merge", "master", 0, vec![]);
        // An enabled scope starts from zero regardless.
        let guard = begin(&ObsCfg { enabled: true, trace: false }).expect("enabled");
        let snap = guard.finish().expect("primary");
        assert_eq!(snap.counter("rounds_total"), 0);
        assert!(snap.trace.is_empty());
    }

    #[test]
    fn begin_finish_snapshot_cycle() {
        let _g = lock();
        assert!(begin(&ObsCfg::default()).is_none(), "disabled config yields no guard");
        let guard = begin(&ObsCfg { enabled: true, trace: true }).expect("enabled");
        let rec = global();
        assert!(rec.on() && rec.tracing_on());
        rec.master_round(128);
        rec.merged_update(1, 0, 2, 0.5);
        rec.fault(FaultKind::Rejoin, 1, 3, "test rejoin");
        rec.gauge_max(Gauge::ResidencyPeak, 2);
        let snap = guard.finish().expect("primary snapshot");
        assert!(!rec.on(), "finish disables recording");
        assert_eq!(snap.counter("rounds_total"), 1);
        assert_eq!(snap.counter("updates_total"), 128);
        assert_eq!(snap.counter("merges_total"), 1);
        assert_eq!(snap.counter("fault_rejoins_total"), 1);
        assert_eq!(snap.gauge("eval_shard_residency_peak"), 2);
        let hist = snap.hist("staleness_rounds").expect("catalog hist");
        assert_eq!(hist.count, 1);
        let names: Vec<_> = snap.trace.iter().map(|e| e.name).collect();
        assert!(names.contains(&"merge") && names.contains(&"rejoin"), "{names:?}");
    }

    #[test]
    fn nested_scopes_share_the_primary_registry() {
        let _g = lock();
        let outer = begin(&ObsCfg { enabled: true, trace: false }).expect("outer");
        let inner = begin(&ObsCfg { enabled: true, trace: false }).expect("inner");
        global().add(Counter::WorkerRounds, 3);
        assert!(inner.finish().is_none(), "nested scope has no snapshot");
        assert!(global().on(), "primary scope still recording");
        let snap = outer.finish().expect("primary");
        assert_eq!(snap.counter("worker_rounds_total"), 3);
    }
}
