//! One formatting helper for every `# <channel>:` report printer.
//!
//! The CLI ends a run with machine-greppable stdout lines — `# transport:`,
//! `# faults:`, `# obs:` — and CI smoke steps grep them literally
//! (e.g. `grep -q "# faults: k_live=1 deaths=1"`). Routing all three
//! printers through [`kv_line`] keeps the shape in one place: a `# `
//! prefix, the channel name, a colon, an optional free-form head, then
//! space-separated `key=value` fields. Values may contain spaces
//! (`sent=12B/3 frames`); keys must not.

use super::ObsSnapshot;

/// Format one report line: `# {channel}: {head} k=v k=v`. An empty
/// `head` is skipped (no double space); an empty field list gives a
/// head-only line.
pub fn kv_line(channel: &str, head: &str, fields: &[(&str, String)]) -> String {
    let mut s = format!("# {channel}:");
    if !head.is_empty() {
        s.push(' ');
        s.push_str(head);
    }
    for (k, v) in fields {
        s.push(' ');
        s.push_str(k);
        s.push('=');
        s.push_str(v);
    }
    s
}

/// The `# obs:` summary of a run's metrics snapshot — counters first,
/// then non-empty histograms (approximate p50/max from the log2
/// buckets), then non-zero gauges, then a trace note. Keys are stable;
/// CI greps them.
pub fn obs_lines(snap: &ObsSnapshot) -> Vec<String> {
    let mut lines = Vec::new();
    lines.push(kv_line(
        "obs",
        "",
        &[
            ("rounds", snap.counter("rounds_total").to_string()),
            ("merges", snap.counter("merges_total").to_string()),
            ("updates", snap.counter("updates_total").to_string()),
            ("worker_rounds", snap.counter("worker_rounds_total").to_string()),
            ("evals", snap.counter("evals_total").to_string()),
        ],
    ));
    let faults = [
        ("stalls", snap.counter("fault_stalls_total")),
        ("retransmits", snap.counter("fault_retransmits_total")),
        ("rejoins", snap.counter("fault_rejoins_total")),
        ("deaths", snap.counter("fault_deaths_total")),
    ];
    if faults.iter().any(|&(_, v)| v > 0) {
        lines.push(kv_line(
            "obs",
            "faults",
            &faults.map(|(k, v)| (k, v.to_string())),
        ));
    }
    for h in &snap.hists {
        if h.count == 0 {
            continue;
        }
        lines.push(kv_line(
            "obs",
            h.name,
            &[
                ("count", h.count.to_string()),
                ("mean", format!("{:.1}", h.mean())),
                ("p50_le", h.quantile_le(0.5).unwrap_or(0).to_string()),
                ("max_le", h.max_le().unwrap_or(0).to_string()),
            ],
        ));
    }
    let residency = snap.gauge("eval_shard_residency_peak");
    if residency > 0 {
        lines.push(kv_line("obs", "", &[("residency_peak", residency.to_string())]));
    }
    if !snap.trace.is_empty() {
        lines.push(kv_line("obs", "", &[("trace_events", snap.trace.len().to_string())]));
    }
    lines
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::HistSnapshot;

    #[test]
    fn kv_line_shapes() {
        assert_eq!(kv_line("obs", "", &[("rounds", "8".into())]), "# obs: rounds=8");
        // Values may contain spaces — the `# transport:` per-peer form.
        assert_eq!(
            kv_line(
                "transport",
                "worker 0",
                &[("sent", "12B/3 frames".into()), ("recv", "4B/1 frames".into())]
            ),
            "# transport: worker 0 sent=12B/3 frames recv=4B/1 frames"
        );
        // Head-only lines (the fault event log).
        assert_eq!(
            kv_line("faults", "[vtime 0.100 round 2] worker 1: stalled", &[]),
            "# faults: [vtime 0.100 round 2] worker 1: stalled"
        );
    }

    #[test]
    fn obs_lines_are_stable_and_sparse() {
        let mut snap = ObsSnapshot {
            counters: vec![
                ("rounds_total", 8),
                ("merges_total", 14),
                ("updates_total", 4096),
                ("worker_rounds_total", 14),
                ("evals_total", 4),
                ("fault_stalls_total", 0),
                ("fault_retransmits_total", 0),
                ("fault_rejoins_total", 0),
                ("fault_deaths_total", 0),
            ],
            gauges: vec![("eval_shard_residency_peak", 0)],
            hists: vec![HistSnapshot {
                name: "staleness_rounds",
                count: 14,
                sum: 19,
                buckets: vec![(1, 10), (3, 14)],
            }],
            net: Vec::new(),
            trace: Vec::new(),
        };
        let lines = obs_lines(&snap);
        assert_eq!(
            lines[0],
            "# obs: rounds=8 merges=14 updates=4096 worker_rounds=14 evals=4"
        );
        assert!(lines.iter().any(|l| l.starts_with("# obs: staleness_rounds count=14")), "{lines:?}");
        // Clean run: no faults line, no residency line.
        assert!(!lines.iter().any(|l| l.contains("faults")), "{lines:?}");
        assert!(!lines.iter().any(|l| l.contains("residency")), "{lines:?}");
        // A dirty run gets both.
        for c in snap.counters.iter_mut() {
            if c.0 == "fault_rejoins_total" {
                c.1 = 1;
            }
        }
        snap.gauges = vec![("eval_shard_residency_peak", 2)];
        let lines = obs_lines(&snap);
        assert!(
            lines.iter().any(|l| l == "# obs: faults stalls=0 retransmits=0 rejoins=1 deaths=0"),
            "{lines:?}"
        );
        assert!(lines.iter().any(|l| l == "# obs: residency_peak=2"), "{lines:?}");
    }
}
