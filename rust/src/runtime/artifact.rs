//! Artifact manifest: the `manifest.toml` contract between
//! `python/compile/aot.py` (writer) and the Rust runtime (reader).
//!
//! ```toml
//! [block_step_b16_d64]
//! file = "block_step_b16_d64.hlo.txt"
//! kind = "block_step"
//! b = 16
//! d = 64
//! dtype = "f32"
//! ```

use std::path::Path;

use crate::config::toml;

/// What a module computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// Block dual-coordinate step (Gram + scan + Δv).
    BlockStep,
    /// Primal/dual objective partial sums over a tile.
    GapTile,
}

impl ArtifactKind {
    pub fn parse(s: &str) -> Option<ArtifactKind> {
        match s {
            "block_step" => Some(ArtifactKind::BlockStep),
            "gap_tile" => Some(ArtifactKind::GapTile),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            ArtifactKind::BlockStep => "block_step",
            ArtifactKind::GapTile => "gap_tile",
        }
    }
}

/// Metadata for one artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: ArtifactKind,
    /// Block size (rows per tile).
    pub b: usize,
    /// Feature dimension of the tile.
    pub d: usize,
    pub dtype: String,
}

/// The parsed manifest.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Manifest {
    pub entries: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Parse manifest text.
    pub fn parse(text: &str) -> anyhow::Result<Manifest> {
        let doc = toml::parse(text)?;
        let mut entries = Vec::new();
        for (table, kv) in &doc.tables {
            if table.is_empty() {
                anyhow::ensure!(kv.is_empty(), "manifest keys must live inside tables");
                continue;
            }
            let get_str = |key: &str| -> anyhow::Result<&str> {
                kv.get(key)
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow::anyhow!("[{table}]: missing/invalid '{key}'"))
            };
            let get_usize = |key: &str| -> anyhow::Result<usize> {
                kv.get(key)
                    .and_then(|v| v.as_usize())
                    .ok_or_else(|| anyhow::anyhow!("[{table}]: missing/invalid '{key}'"))
            };
            let kind_s = get_str("kind")?;
            let kind = ArtifactKind::parse(kind_s)
                .ok_or_else(|| anyhow::anyhow!("[{table}]: unknown kind '{kind_s}'"))?;
            entries.push(ArtifactMeta {
                name: table.clone(),
                file: get_str("file")?.to_string(),
                kind,
                b: get_usize("b")?,
                d: get_usize("d")?,
                dtype: get_str("dtype")?.to_string(),
            });
        }
        anyhow::ensure!(!entries.is_empty(), "manifest has no artifacts");
        Ok(Manifest { entries })
    }

    /// Read and parse from a file.
    pub fn read(path: &Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        Self::parse(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
[block_step_b16_d64]
file = "block_step_b16_d64.hlo.txt"
kind = "block_step"
b = 16
d = 64
dtype = "f32"

[gap_tile_b16_d64]
file = "gap_tile_b16_d64.hlo.txt"
kind = "gap_tile"
b = 16
d = 64
dtype = "f32"
"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 2);
        let bs = m.entries.iter().find(|e| e.kind == ArtifactKind::BlockStep).unwrap();
        assert_eq!(bs.b, 16);
        assert_eq!(bs.d, 64);
        assert_eq!(bs.file, "block_step_b16_d64.hlo.txt");
        assert_eq!(bs.dtype, "f32");
    }

    #[test]
    fn kind_roundtrip() {
        for k in [ArtifactKind::BlockStep, ArtifactKind::GapTile] {
            assert_eq!(ArtifactKind::parse(k.as_str()), Some(k));
        }
        assert_eq!(ArtifactKind::parse("bogus"), None);
    }

    #[test]
    fn parse_errors() {
        assert!(Manifest::parse("").is_err());
        assert!(Manifest::parse(
            "[x]\nkind = \"bogus\"\nfile = \"f\"\nb = 1\nd = 1\ndtype = \"f32\"\n"
        )
        .is_err());
        assert!(Manifest::parse("[x]\nfile = \"f\"\n").is_err());
        assert!(Manifest::parse("toplevel = 1\n").is_err());
    }
}
