//! PJRT runtime: load the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and execute them from Rust.
//!
//! Interchange format is **HLO text** (not serialized `HloModuleProto`):
//! jax ≥ 0.5 emits protos with 64-bit instruction ids which the
//! xla_extension 0.5.1 bundled with the `xla` crate rejects; the text
//! parser reassigns ids and round-trips cleanly (see
//! /opt/xla-example/README.md).
//!
//! Artifacts live in `artifacts/` next to a `manifest.toml` describing
//! each module's kind and shapes (the manifest reuses our TOML-subset
//! parser — both sides of the interchange are ours).

pub mod artifact;

pub use artifact::{ArtifactKind, ArtifactMeta, Manifest};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled, executable artifact.
pub struct Artifact {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT CPU runtime holding all compiled artifacts.
pub struct Runtime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    artifacts: HashMap<String, Artifact>,
    pub dir: PathBuf,
}

/// Outputs of one block-step execution (mirrors
/// [`crate::solver::block::BlockOutput`] in f32).
#[derive(Debug, Clone, PartialEq)]
pub struct BlockStepOut {
    pub alpha_new: Vec<f32>,
    pub eps: Vec<f32>,
    pub delta_v: Vec<f32>,
}

/// Outputs of one objective-tile execution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GapTileOut {
    /// `Σ_j max(0, 1 − y_j·(x_jᵀv))` over the tile.
    pub hinge_sum: f32,
    /// `Σ_j α_j·y_j` over the tile (hinge dual contribution).
    pub dual_sum: f32,
}

impl Runtime {
    /// Load every artifact listed in `dir/manifest.toml` and compile it
    /// on the PJRT CPU client.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::read(&dir.join("manifest.toml"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        let mut artifacts = HashMap::new();
        for meta in manifest.entries {
            let path = dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {}: {e:?}", meta.name))?;
            artifacts.insert(meta.name.clone(), Artifact { meta, exe });
        }
        Ok(Runtime { client, artifacts, dir })
    }

    /// Does an artifacts directory look loadable?
    pub fn available(dir: impl AsRef<Path>) -> bool {
        dir.as_ref().join("manifest.toml").is_file()
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.artifacts.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    pub fn get(&self, name: &str) -> Option<&Artifact> {
        self.artifacts.get(name)
    }

    /// Find the block-step artifact for a given (B, D) shape.
    pub fn find_block_step(&self, b: usize, d: usize) -> Option<&Artifact> {
        self.artifacts.values().find(|a| {
            a.meta.kind == ArtifactKind::BlockStep && a.meta.b == b && a.meta.d == d
        })
    }

    /// Find the objective-tile artifact for a given (B, D) shape.
    pub fn find_gap_tile(&self, b: usize, d: usize) -> Option<&Artifact> {
        self.artifacts.values().find(|a| {
            a.meta.kind == ArtifactKind::GapTile && a.meta.b == b && a.meta.d == d
        })
    }

    /// Execute a block dual step:
    /// inputs `x[B,D], y[B], α[B], v[D]` + scalars `1/(λn)`, `σ`.
    pub fn block_step(
        &self,
        art: &Artifact,
        x: &[f32],
        y: &[f32],
        alpha: &[f32],
        v: &[f32],
        inv_lambda_n: f32,
        sigma: f32,
    ) -> anyhow::Result<BlockStepOut> {
        let (b, d) = (art.meta.b, art.meta.d);
        anyhow::ensure!(x.len() == b * d, "x shape");
        anyhow::ensure!(y.len() == b && alpha.len() == b, "y/α shape");
        anyhow::ensure!(v.len() == d, "v shape");
        let lit_x = xla::Literal::vec1(x)
            .reshape(&[b as i64, d as i64])
            .map_err(|e| anyhow::anyhow!("reshape x: {e:?}"))?;
        let lit_y = xla::Literal::vec1(y);
        let lit_a = xla::Literal::vec1(alpha);
        let lit_v = xla::Literal::vec1(v);
        let lit_sc = xla::Literal::scalar(inv_lambda_n);
        let lit_sg = xla::Literal::scalar(sigma);
        let result = art
            .exe
            .execute::<xla::Literal>(&[lit_x, lit_y, lit_a, lit_v, lit_sc, lit_sg])
            .map_err(|e| anyhow::anyhow!("execute block_step: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e:?}"))?;
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        anyhow::ensure!(parts.len() == 3, "expected 3 outputs, got {}", parts.len());
        Ok(BlockStepOut {
            alpha_new: parts[0].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
            eps: parts[1].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
            delta_v: parts[2].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
        })
    }

    /// Execute an objective tile: inputs `x[B,D], y[B], α[B], v[D]`.
    pub fn gap_tile(
        &self,
        art: &Artifact,
        x: &[f32],
        y: &[f32],
        alpha: &[f32],
        v: &[f32],
    ) -> anyhow::Result<GapTileOut> {
        let (b, d) = (art.meta.b, art.meta.d);
        anyhow::ensure!(x.len() == b * d && y.len() == b && alpha.len() == b && v.len() == d);
        let lit_x = xla::Literal::vec1(x)
            .reshape(&[b as i64, d as i64])
            .map_err(|e| anyhow::anyhow!("reshape x: {e:?}"))?;
        let result = art
            .exe
            .execute::<xla::Literal>(&[
                lit_x,
                xla::Literal::vec1(y),
                xla::Literal::vec1(alpha),
                xla::Literal::vec1(v),
            ])
            .map_err(|e| anyhow::anyhow!("execute gap_tile: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e:?}"))?;
        let parts = result.to_tuple().map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        anyhow::ensure!(parts.len() == 2, "expected 2 outputs");
        Ok(GapTileOut {
            hinge_sum: parts[0].get_first_element::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
            dual_sum: parts[1].get_first_element::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
        })
    }
}

impl Runtime {
    /// Upload a host array to a device-resident buffer. Perf (§Perf
    /// L2/L3 boundary): the dominant cost of a small `block_step` call
    /// is host→device staging of the `B×D` tile; callers whose tiles
    /// are static across calls (the block solver's X and y) upload them
    /// once and use [`Runtime::block_step_buffered`].
    pub fn upload(&self, data: &[f32], dims: &[usize]) -> anyhow::Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("upload: {e:?}"))
    }

    /// Block step with pre-uploaded `x`/`y` buffers; only `α`, `v` and
    /// the scalars are staged per call.
    pub fn block_step_buffered(
        &self,
        art: &Artifact,
        x_buf: &xla::PjRtBuffer,
        y_buf: &xla::PjRtBuffer,
        alpha: &[f32],
        v: &[f32],
        inv_lambda_n: f32,
        sigma: f32,
    ) -> anyhow::Result<BlockStepOut> {
        let (b, d) = (art.meta.b, art.meta.d);
        anyhow::ensure!(alpha.len() == b && v.len() == d, "α/v shape");
        let a_buf = self.upload(alpha, &[b])?;
        let v_buf = self.upload(v, &[d])?;
        let sc_buf = self.upload(&[inv_lambda_n], &[])?;
        let sg_buf = self.upload(&[sigma], &[])?;
        let result = art
            .exe
            .execute_b::<&xla::PjRtBuffer>(&[x_buf, y_buf, &a_buf, &v_buf, &sc_buf, &sg_buf])
            .map_err(|e| anyhow::anyhow!("execute_b block_step: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e:?}"))?;
        let parts = result.to_tuple().map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        anyhow::ensure!(parts.len() == 3, "expected 3 outputs, got {}", parts.len());
        Ok(BlockStepOut {
            alpha_new: parts[0].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
            eps: parts[1].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
            delta_v: parts[2].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
        })
    }

    /// Gap tile with pre-uploaded `x`/`y` buffers.
    pub fn gap_tile_buffered(
        &self,
        art: &Artifact,
        x_buf: &xla::PjRtBuffer,
        y_buf: &xla::PjRtBuffer,
        alpha: &[f32],
        v: &[f32],
    ) -> anyhow::Result<GapTileOut> {
        let (b, d) = (art.meta.b, art.meta.d);
        anyhow::ensure!(alpha.len() == b && v.len() == d, "α/v shape");
        let a_buf = self.upload(alpha, &[b])?;
        let v_buf = self.upload(v, &[d])?;
        let result = art
            .exe
            .execute_b::<&xla::PjRtBuffer>(&[x_buf, y_buf, &a_buf, &v_buf])
            .map_err(|e| anyhow::anyhow!("execute_b gap_tile: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e:?}"))?;
        let parts = result.to_tuple().map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        anyhow::ensure!(parts.len() == 2, "expected 2 outputs");
        Ok(GapTileOut {
            hinge_sum: parts[0].get_first_element::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
            dual_sum: parts[1].get_first_element::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
        })
    }
}

/// Conventional artifacts directory (crate root / artifacts).
pub fn default_artifacts_dir() -> PathBuf {
    // CARGO_MANIFEST_DIR points at the crate root in tests/benches;
    // fall back to ./artifacts for installed binaries.
    if let Ok(dir) = std::env::var("HYBRID_DCA_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let manifest_dir = std::env::var("CARGO_MANIFEST_DIR").unwrap_or_else(|_| ".".into());
    Path::new(&manifest_dir).join("artifacts")
}
