//! Figure 5 — effect of the bounded-barrier size `S ∈ {2,3,4,6,8}` with
//! `Γ = 10` fixed, on `p = 8` nodes × `t = 8` cores.
//!
//! Paper finding: with `S < p/2` only a minority of workers contribute
//! per round and the gap stalls above a level; `S ≥ p/2` reaches the
//! full-barrier quality, and small S buys shorter rounds that are
//! eventually eaten by needing more rounds. We reproduce the sweep on
//! the homogeneous cluster and — as an extension the paper motivates
//! but could not run (§6.3: "useful for HPC platforms with
//! heterogeneous nodes, unlike ours") — under a straggler profile.

use crate::metrics::Trace;
use crate::sim::StragglerProfile;

use super::{paper_session, print_threshold_table, save_traces, QuickFull};

/// Run the S sweep; returns one trace per S value.
pub fn run_sweep(
    dataset: &str,
    p: usize,
    t: usize,
    s_values: &[usize],
    gamma: usize,
    max_rounds: usize,
    profile: StragglerProfile,
) -> anyhow::Result<Vec<Trace>> {
    let mut base = paper_session(dataset, p, t)
        .rounds(max_rounds)
        .delay(gamma)
        .gap_threshold(1e-7); // run the full horizon; stalls are the point
    if profile != StragglerProfile::Homogeneous {
        base = base.stragglers(profile.multipliers(p));
    }
    let data = base.clone().build()?.load_dataset()?;
    let mut traces = Vec::new();
    for &s in s_values {
        let session = base.clone().barrier(s).build()?;
        let mut tr = session.run("hybrid-dca", &data)?.trace;
        tr.label = format!("S={s}");
        traces.push(tr);
    }
    Ok(traces)
}

pub fn run_and_print(mode: QuickFull) -> anyhow::Result<()> {
    let (p, t, s_values, rounds): (usize, usize, Vec<usize>, usize) = match mode {
        QuickFull::Quick => (4, 2, vec![1, 2, 4], 30),
        QuickFull::Full => (8, 8, vec![2, 3, 4, 6, 8], 120),
    };
    println!("== Figure 5: effect of S (p={p}, t={t}, Γ=10) ==");
    let homog = run_sweep("rcv1-s", p, t, &s_values, 10, rounds, StragglerProfile::Homogeneous)?;
    println!("\nhomogeneous cluster (paper's setting):");
    print_threshold_table(&homog, super::fig3::threshold_for("rcv1-s"));

    let mut strag = run_sweep("rcv1-s", p, t, &s_values, 10, rounds, StragglerProfile::OneSlow)?;
    println!("\none-slow straggler profile (paper §6.3 motivation):");
    print_threshold_table(&strag, super::fig3::threshold_for("rcv1-s"));

    let mut all = homog;
    for tr in all.iter_mut() {
        tr.label = format!("homog/{}", tr.label);
    }
    for tr in strag.iter_mut() {
        tr.label = format!("one-slow/{}", tr.label);
    }
    all.append(&mut strag);
    save_traces("fig5_barrier_s", &all)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn s_sweep_runs_tiny() {
        let traces =
            run_sweep("tiny", 3, 2, &[1, 3], 10, 15, StragglerProfile::Homogeneous).unwrap();
        assert_eq!(traces.len(), 2);
        assert_eq!(traces[0].label, "S=1");
        // Both make progress.
        for t in &traces {
            assert!(t.final_gap().unwrap() < 1.0, "{}: {:?}", t.label, t.final_gap());
        }
    }

    #[test]
    fn straggler_bounded_barrier_is_faster_per_round() {
        // With a 4× straggler, S=1 rounds shouldn't wait for it: virtual
        // time per round must be smaller than S=K's.
        let fast = run_sweep("tiny", 3, 2, &[1], 10, 10, StragglerProfile::OneSlow).unwrap();
        let slow = run_sweep("tiny", 3, 2, &[3], 10, 10, StragglerProfile::OneSlow).unwrap();
        let vt_fast = fast[0].points.last().unwrap().virt_secs / fast[0].points.len() as f64;
        let vt_slow = slow[0].points.last().unwrap().virt_secs / slow[0].points.len() as f64;
        assert!(
            vt_fast < vt_slow,
            "S=1 per-round vtime {vt_fast} should beat S=K {vt_slow}"
        );
    }
}
