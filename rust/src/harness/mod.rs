//! Experiment harness: one driver per paper table/figure, shared by the
//! `benches/` binaries and the CLI's `bench` subcommand.
//!
//! Every driver follows the same shape: build the workload, run the
//! solver grid, print the same rows/series the paper reports, and write
//! a CSV under `results/` for plotting.

pub mod ablations;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod table1;

use crate::config::ExpConfig;
use crate::data::{synth, Dataset, Preset};
use crate::metrics::Trace;
use crate::session::{Session, SessionBuilder};
use crate::util::Rng;

/// Sweep size: `Quick` for CLI smoke / CI, `Full` for `cargo bench`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuickFull {
    Quick,
    Full,
}

impl QuickFull {
    pub fn from_env() -> Self {
        match std::env::var("HYBRID_DCA_BENCH").as_deref() {
            Ok("quick") => QuickFull::Quick,
            _ => QuickFull::Full,
        }
    }
}

/// Resolve a dataset from a config: a packed shard store if
/// `store_path` is set (materialized flat — use
/// [`crate::session::Session::load_source`] to keep shard structure),
/// a LIBSVM file if `data_path` is set, otherwise the named synthetic
/// preset.
pub fn load_dataset(cfg: &ExpConfig) -> anyhow::Result<Dataset> {
    if let Some(dir) = &cfg.store_path {
        return crate::store::open(dir)?.materialize();
    }
    if let Some(path) = &cfg.data_path {
        return crate::data::libsvm::read_file(path, 0);
    }
    let preset = Preset::parse(&cfg.dataset)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset preset '{}'", cfg.dataset))?;
    Ok(gen_preset(preset, cfg.seed))
}

/// Generate a preset with the harness' seed convention.
pub fn gen_preset(preset: Preset, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0xDA7A);
    synth::generate(&preset.spec(), &mut rng)
}

/// The paper's regularization, rescaled to our dataset sizes.
///
/// The paper uses λ = 10⁻⁴ throughout §6; what governs the coordinate
/// step size and the shape of the dual problem is the product `λ·n`
/// (the curvature is `q = σ‖x‖²/(λn)` and `v = (1/λn)Xα`). Our presets
/// shrink n ~100×–1000×, so we keep **λ·n at the paper's value** for
/// each dataset rather than copying λ verbatim — copying λ would put
/// the solver in a qualitatively different (λn ≪ 1, bang-bang) regime
/// the paper never ran.
pub fn paper_lambda(dataset: &str) -> f64 {
    // λ·n targets calibrated so each preset's convergence horizon lands
    // in the paper's regime (50–300 communication rounds to the
    // dataset's threshold; see EXPERIMENTS.md §Calibration). The
    // *ordering* of the paper's λ·n values (kddb ≫ splicesite > rcv1 >
    // webspam at λ = 1e-4) is preserved.
    let (lambda_n, n_ours) = match dataset {
        "rcv1-s" => (10.0, 8_000.0),
        "webspam-s" => (5.0, 2_000.0),
        // kddb mirrors the paper's: very slow convergence (their
        // threshold for kddb is only 1e-1).
        "kddb-s" => (0.2, 20_000.0),
        "splicesite-s" => (30.0, 12_000.0),
        // tiny and files: λ·n = 2 (a well-behaved SVM regime).
        _ => (2.0, 200.0),
    };
    lambda_n / n_ours
}

/// Standard experiment config used across the figures (paper §6:
/// λ = 10⁻⁴ (rescaled, see [`paper_lambda`]), ν = 1, σ = νS; H scaled
/// per DESIGN.md's ~1000× rule).
pub fn paper_cfg(dataset: &str, p: usize, t: usize) -> ExpConfig {
    let mut cfg = ExpConfig::default();
    cfg.dataset = dataset.to_string();
    cfg.lambda = paper_lambda(dataset);
    cfg.k_nodes = p;
    cfg.r_cores = t;
    cfg.s_barrier = p;
    cfg.gamma = 1;
    cfg.h_local = 512;
    cfg.nu = 1.0;
    cfg.max_rounds = 100;
    cfg.gap_threshold = 1e-6;
    cfg.eval_every = 1;
    cfg
}

/// The same standard setup as [`paper_cfg`], as a [`SessionBuilder`]
/// ready for per-figure overrides (`.barrier(s)`, `.delay(g)`, …).
pub fn paper_session(dataset: &str, p: usize, t: usize) -> SessionBuilder {
    Session::builder()
        .dataset(dataset)
        .lambda(paper_lambda(dataset))
        .cluster(p, t)
        .barrier(p)
        .delay(1)
        .local_iters(512)
        .nu(1.0)
        .rounds(100)
        .gap_threshold(1e-6)
        .eval_every(1)
}

/// Results directory (crate-root/results).
pub fn results_dir() -> std::path::PathBuf {
    let root = std::env::var("CARGO_MANIFEST_DIR").unwrap_or_else(|_| ".".into());
    std::path::Path::new(&root).join("results")
}

/// Write traces as `results/<name>.csv` and announce it.
pub fn save_traces(name: &str, traces: &[Trace]) -> anyhow::Result<()> {
    let path = results_dir().join(format!("{name}.csv"));
    crate::metrics::trace::write_csv_file(&path, traces)?;
    println!("# series written to {}", path.display());
    Ok(())
}

/// Pretty-print a “who reached the threshold when” summary table.
pub fn print_threshold_table(traces: &[Trace], threshold: f64) {
    println!(
        "{:<34} {:>8} {:>14} {:>14} {:>12}",
        "solver", "rounds", "virt-time(s)", "wall-time(s)", "final gap"
    );
    for t in traces {
        let rounds = t
            .rounds_to_gap(threshold)
            .map(|r| r.to_string())
            .unwrap_or_else(|| "—".into());
        let vt = t
            .virt_time_to_gap(threshold)
            .map(|v| format!("{v:.4}"))
            .unwrap_or_else(|| "—".into());
        let wt = t
            .wall_time_to_gap(threshold)
            .map(|v| format!("{v:.3}"))
            .unwrap_or_else(|| "—".into());
        let fg = t.final_gap().map(|g| format!("{g:.3e}")).unwrap_or_else(|| "—".into());
        println!("{:<34} {:>8} {:>14} {:>14} {:>12}", t.label, rounds, vt, wt, fg);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_preset() {
        let mut cfg = ExpConfig::default();
        cfg.dataset = "tiny".into();
        let ds = load_dataset(&cfg).unwrap();
        assert_eq!(ds.name, "tiny");
    }

    #[test]
    fn unknown_preset_errors() {
        let mut cfg = ExpConfig::default();
        cfg.dataset = "nope".into();
        assert!(load_dataset(&cfg).is_err());
    }

    #[test]
    fn paper_cfg_valid() {
        paper_cfg("rcv1-s", 4, 2).validate().unwrap();
    }

    #[test]
    fn paper_session_matches_paper_cfg() {
        let session = paper_session("rcv1-s", 4, 2).build().unwrap();
        assert_eq!(session.to_exp_config(), paper_cfg("rcv1-s", 4, 2));
    }

    #[test]
    fn quickfull_env() {
        // Default (env unset in tests) is Full.
        match QuickFull::from_env() {
            QuickFull::Quick | QuickFull::Full => {}
        }
    }
}
