//! Figure 7 — the big dataset (splicesite, 280 GB in the paper):
//! Hybrid-DCA (16 nodes × 8 cores) vs CoCoA+ (16 nodes), plus the
//! §6.5 variant CoCoA+ treating all 128 cores as distributed nodes.
//! PassCoDe cannot run at all: the dataset does not fit on one node.
//!
//! Paper headline: CoCoA+ takes > 300 s to reach a 10⁻⁶ duality gap on
//! 16 nodes; Hybrid-DCA takes ≈ 30 s — a ~10× gap this harness's
//! virtual-clock reproduction should land near.

use crate::metrics::Trace;

use super::{paper_session, print_threshold_table, save_traces, QuickFull};

pub struct Fig7Result {
    pub traces: Vec<Trace>,
    pub threshold: f64,
    /// Hybrid vs CoCoA+ time-to-threshold ratio (the headline ~10×).
    pub hybrid_vs_cocoa: Option<f64>,
}

pub fn run(
    dataset: &str,
    p: usize,
    t: usize,
    h: usize,
    max_rounds: usize,
    threshold: f64,
) -> anyhow::Result<Fig7Result> {
    let base = paper_session(dataset, p, t)
        .local_iters(h) // paper uses H = 10000 for Fig 7 (scaled here)
        .rounds(max_rounds)
        .gap_threshold(threshold)
        .eval_every(5);
    let data = base.clone().build()?.load_dataset()?;

    let mut traces = Vec::new();

    // CoCoA+ on p nodes.
    {
        // CoCoA+ applies p·H updates/round vs Hybrid's p·t·H; match the
        // paper (same H per node per round — CoCoA+ simply has no cores).
        let session = base.clone().cluster(p, 1).barrier(p).build()?;
        traces.push(session.run("cocoa+", &data)?.trace);
    }
    // CoCoA+ with all p·t cores as nodes (§6.5 variant).
    {
        let c = base.clone().build()?.to_exp_config();
        let mut tr = crate::coordinator::cocoa::run_cores_as_nodes(&data, &c)?.trace;
        tr.label = format!("CoCoA+({}-cores-as-nodes)", p * t);
        traces.push(tr);
    }
    // Hybrid-DCA p × t.
    {
        let session = base.clone().barrier(p).delay(1).build()?;
        traces.push(session.run("hybrid-dca", &data)?.trace);
    }

    let cocoa_t = traces[0].virt_time_to_gap(threshold);
    let hybrid_t = traces[2].virt_time_to_gap(threshold);
    let ratio = match (cocoa_t, hybrid_t) {
        (Some(c), Some(h)) if h > 0.0 => Some(c / h),
        _ => None,
    };
    Ok(Fig7Result { traces, threshold, hybrid_vs_cocoa: ratio })
}

pub fn run_and_print(mode: QuickFull) -> anyhow::Result<()> {
    let (dataset, p, t, h, rounds, threshold): (&str, usize, usize, usize, usize, f64) = match mode
    {
        QuickFull::Quick => ("rcv1-s", 4, 2, 256, 40, 1e-3),
        // H = 32 preserves the paper's local-progress ratio
        // H/n_k ≈ 3.5% per core per round (their H = 10000 on
        // n_k ≈ 289k), which is what generates the ~10× headline:
        // Hybrid's 8 cores cover 8× more of the partition per
        // equally-priced (communication-dominated) round.
        QuickFull::Full => ("splicesite-s", 16, 8, 32, 1500, 1e-6),
    };
    println!("== Figure 7: big dataset {dataset} (p={p}, t={t}, H={h}) ==");
    let res = run(dataset, p, t, h, rounds, threshold)?;
    print_threshold_table(&res.traces, res.threshold);
    match res.hybrid_vs_cocoa {
        Some(r) => println!(
            "\nHybrid-DCA is {r:.1}× faster than CoCoA+ to gap ≤ {:.0e} \
             (paper: ~10× — 30 s vs >300 s)",
            res.threshold
        ),
        None => println!("\n(one of the solvers did not reach the threshold)"),
    }
    save_traces("fig7_big", &res.traces)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_quick_shape() {
        let res = run("tiny", 2, 2, 128, 25, 5e-2).unwrap();
        assert_eq!(res.traces.len(), 3);
        // Hybrid should not be slower than CoCoA+ in virtual time when
        // it uses t× more cores.
        if let Some(r) = res.hybrid_vs_cocoa {
            assert!(r > 0.8, "hybrid/cocoa ratio {r}");
        }
    }
}
