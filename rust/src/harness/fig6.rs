//! Figure 6 — effect of the bounded-delay `Γ ∈ {1,2,3,4,10}` with
//! `S = 6` fixed, on `p = 8` nodes × `t = 8` cores.
//!
//! Paper finding: on their homogeneous cluster Γ has little effect, and
//! even with Γ = 10 the observed staleness never exceeded 4 rounds. We
//! reproduce both the sweep and the staleness measurement (our
//! [`MergeEvent`](crate::coordinator::MergeEvent) log records the Γ_k
//! counters every round), and add the heterogeneous extension where Γ
//! matters.

use crate::coordinator::RunReport;
use crate::metrics::Trace;
use crate::sim::StragglerProfile;

use super::{paper_session, print_threshold_table, save_traces, QuickFull};

/// Result of one Γ setting: trace + observed staleness statistics.
pub struct GammaResult {
    pub gamma: usize,
    pub trace: Trace,
    /// Maximum Γ_k observed at any merge.
    pub max_staleness: usize,
    /// Mean of per-round max Γ_k.
    pub mean_staleness: f64,
}

/// Observed staleness from a report's merge events.
pub fn staleness_stats(report: &RunReport) -> (usize, f64) {
    let mut max_s = 0usize;
    let mut sum = 0.0;
    let mut count = 0usize;
    for ev in &report.events {
        let m = ev.gamma_after.iter().copied().max().unwrap_or(1);
        max_s = max_s.max(m);
        sum += m as f64;
        count += 1;
    }
    (max_s, if count == 0 { 0.0 } else { sum / count as f64 })
}

/// Run the Γ sweep.
pub fn run_sweep(
    dataset: &str,
    p: usize,
    t: usize,
    s: usize,
    gamma_values: &[usize],
    max_rounds: usize,
    profile: StragglerProfile,
) -> anyhow::Result<Vec<GammaResult>> {
    let mut base = paper_session(dataset, p, t)
        .rounds(max_rounds)
        .barrier(s)
        .gap_threshold(1e-7);
    if profile != StragglerProfile::Homogeneous {
        base = base.stragglers(profile.multipliers(p));
    }
    let data = base.clone().build()?.load_dataset()?;
    let mut out = Vec::new();
    for &g in gamma_values {
        let session = base.clone().delay(g).build()?;
        let report = session.run("hybrid-dca", &data)?;
        let (max_staleness, mean_staleness) = staleness_stats(&report);
        let mut trace = report.trace;
        trace.label = format!("Γ={g}");
        out.push(GammaResult { gamma: g, trace, max_staleness, mean_staleness });
    }
    Ok(out)
}

pub fn run_and_print(mode: QuickFull) -> anyhow::Result<()> {
    let (p, t, s, gammas, rounds): (usize, usize, usize, Vec<usize>, usize) = match mode {
        QuickFull::Quick => (4, 2, 2, vec![1, 4], 30),
        QuickFull::Full => (8, 8, 6, vec![1, 2, 3, 4, 10], 120),
    };
    println!("== Figure 6: effect of Γ (p={p}, t={t}, S={s}) ==");
    for profile in [StragglerProfile::Homogeneous, StragglerProfile::OneSlow] {
        let results = run_sweep("rcv1-s", p, t, s, &gammas, rounds, profile)?;
        println!("\nprofile {profile:?}:");
        let traces: Vec<Trace> = results.iter().map(|r| r.trace.clone()).collect();
        print_threshold_table(&traces, super::fig3::threshold_for("rcv1-s"));
        println!("{:<8} {:>14} {:>16}", "Γ", "max staleness", "mean staleness");
        for r in &results {
            println!("{:<8} {:>14} {:>16.2}", r.gamma, r.max_staleness, r.mean_staleness);
        }
        let mut labeled = traces;
        for tr in labeled.iter_mut() {
            tr.label = format!("{profile:?}/{}", tr.label);
        }
        save_traces(&format!("fig6_delay_gamma_{profile:?}"), &labeled)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gamma_sweep_and_staleness_bound() {
        let results =
            run_sweep("tiny", 3, 2, 2, &[1, 3], 12, StragglerProfile::Homogeneous).unwrap();
        assert_eq!(results.len(), 2);
        for r in &results {
            // The master's wait rule keeps any unheard worker's counter
            // from passing Γ between merges, so observed staleness is at
            // most Γ + 1 (the +1 is the post-merge increment).
            assert!(
                r.max_staleness <= r.gamma + 1,
                "Γ={}: observed {}",
                r.gamma,
                r.max_staleness
            );
        }
    }
}
