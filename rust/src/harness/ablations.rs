//! Ablation studies for the design choices DESIGN.md §6 calls out:
//!
//! * **merge policy** — oldest-first (paper) vs newest-first pick of
//!   the `S` updates to merge;
//! * **locks** — lock-free CAS adds (paper/PassCoDe-Atomic) vs racy
//!   wild writes (PassCoDe-Wild); a mutex variant is approximated by
//!   `R = 1` (serialized updates have exactly a global lock's
//!   semantics without its overhead);
//! * **σ scaling** — σ = νS (paper-safe) vs νK (over-damped) vs a
//!   deliberately unsafe small σ.

use crate::config::SigmaPolicy;
use crate::coordinator::MergePolicy;
use crate::metrics::Trace;

use super::paper_session;

/// Merge-policy ablation: same config, two policies. Run under a
/// straggler — on a homogeneous cluster updates barely queue, so the
/// pick order cannot matter; with a slow node the newest-first policy
/// starves the straggler's queued updates.
pub fn merge_policy(dataset: &str, rounds: usize) -> anyhow::Result<Vec<Trace>> {
    let base = paper_session(dataset, 4, 2)
        .barrier(2)
        .delay(4)
        .rounds(rounds)
        .gap_threshold(1e-8)
        .stragglers(vec![1.0, 1.0, 1.0, 3.0]);
    let data = base.clone().build()?.load_dataset()?;
    let mut out = Vec::new();
    for (policy, name) in
        [(MergePolicy::OldestFirst, "oldest-first"), (MergePolicy::NewestFirst, "newest-first")]
    {
        let session = base.clone().merge_policy(policy).build()?;
        let mut tr = session.run("hybrid-dca", &data)?.trace;
        tr.label = format!("Hybrid-DCA/{name}");
        out.push(tr);
    }
    Ok(out)
}

/// Atomic vs wild ablation (PassCoDe-style, single node, R cores).
pub fn locks(dataset: &str, r: usize, rounds: usize) -> anyhow::Result<Vec<Trace>> {
    let base = paper_session(dataset, 1, r)
        .barrier(1)
        .rounds(rounds)
        .gap_threshold(1e-8);
    let data = base.clone().build()?.load_dataset()?;
    let mut out = Vec::new();
    for wild in [false, true] {
        let session = base.clone().wild(wild).build()?;
        out.push(session.run("passcode", &data)?.trace);
    }
    // Serialized (R=1) stands in for the mutex variant.
    let session = base.clone().cluster(1, 1).barrier(1).build()?;
    let mut tr = session.run("passcode", &data)?.trace;
    tr.label = "PassCoDe-serialized(R=1)".into();
    out.push(tr);
    Ok(out)
}

/// σ-scaling ablation.
pub fn sigma(dataset: &str, rounds: usize) -> anyhow::Result<Vec<Trace>> {
    let base = paper_session(dataset, 4, 2)
        .barrier(2)
        .delay(4)
        .rounds(rounds)
        .gap_threshold(1e-8);
    let data = base.clone().build()?.load_dataset()?;
    let mut out = Vec::new();
    for (policy, name) in [
        (SigmaPolicy::NuS, "sigma=νS(safe)"),
        (SigmaPolicy::NuK, "sigma=νK(damped)"),
        (SigmaPolicy::Fixed(0.25), "sigma=0.25(unsafe)"),
    ] {
        // The Fixed(0.25) point is deliberately below the Eq. 5 safe
        // region — that divergence is what the ablation studies.
        let session = base.clone().sigma(policy).allow_unsafe_sigma().build()?;
        let mut tr = session.run("hybrid-dca", &data)?.trace;
        tr.label = format!("Hybrid-DCA/{name}");
        out.push(tr);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_policy_both_run() {
        let traces = merge_policy("tiny", 10).unwrap();
        assert_eq!(traces.len(), 2);
        for t in &traces {
            assert!(t.final_gap().unwrap() < 1.0);
        }
    }

    #[test]
    fn locks_three_variants() {
        let traces = locks("tiny", 4, 10).unwrap();
        assert_eq!(traces.len(), 3);
        assert_eq!(traces[0].label, "PassCoDe");
        assert_eq!(traces[1].label, "PassCoDe-Wild");
        assert_eq!(traces[2].label, "PassCoDe-serialized(R=1)");
    }

    #[test]
    fn sigma_safe_beats_unsafe_eventually() {
        let traces = sigma("tiny", 25).unwrap();
        assert_eq!(traces.len(), 3);
        let safe = traces[0].best_gap().unwrap();
        // Damped converges too, just slower per round.
        let damped = traces[1].best_gap().unwrap();
        assert!(safe < 0.5 && damped < 0.9, "safe {safe}, damped {damped}");
    }
}
