//! Ablation studies for the design choices DESIGN.md §6 calls out:
//!
//! * **merge policy** — oldest-first (paper) vs newest-first pick of
//!   the `S` updates to merge;
//! * **locks** — lock-free CAS adds (paper/PassCoDe-Atomic) vs racy
//!   wild writes (PassCoDe-Wild); a mutex variant is approximated by
//!   `R = 1` (serialized updates have exactly a global lock's
//!   semantics without its overhead);
//! * **σ scaling** — σ = νS (paper-safe) vs νK (over-damped) vs a
//!   deliberately unsafe small σ.

use crate::config::{Algorithm, ExpConfig, SigmaPolicy};
use crate::coordinator::hybrid::{run_with, ProtocolOpts};
use crate::coordinator::MergePolicy;
use crate::metrics::Trace;

use super::paper_cfg;

/// Merge-policy ablation: same config, two policies. Run under a
/// straggler — on a homogeneous cluster updates barely queue, so the
/// pick order cannot matter; with a slow node the newest-first policy
/// starves the straggler's queued updates.
pub fn merge_policy(dataset: &str, rounds: usize) -> anyhow::Result<Vec<Trace>> {
    let mut cfg = paper_cfg(dataset, 4, 2);
    cfg.s_barrier = 2;
    cfg.gamma = 4;
    cfg.max_rounds = rounds;
    cfg.gap_threshold = 1e-8;
    cfg.stragglers = vec![1.0, 1.0, 1.0, 3.0];
    let data = super::load_dataset(&cfg)?;
    let mut out = Vec::new();
    for (policy, name) in
        [(MergePolicy::OldestFirst, "oldest-first"), (MergePolicy::NewestFirst, "newest-first")]
    {
        let opts = ProtocolOpts {
            label: format!("Hybrid-DCA/{name}"),
            sync_allreduce: false,
            policy,
        };
        out.push(run_with(&data, &cfg, &opts)?.trace);
    }
    Ok(out)
}

/// Atomic vs wild ablation (PassCoDe-style, single node, R cores).
pub fn locks(dataset: &str, r: usize, rounds: usize) -> anyhow::Result<Vec<Trace>> {
    let mut cfg = paper_cfg(dataset, 1, r);
    cfg.s_barrier = 1;
    cfg.max_rounds = rounds;
    cfg.gap_threshold = 1e-8;
    let data = super::load_dataset(&cfg)?;
    let mut out = Vec::new();
    for (wild, _name) in [(false, "atomic"), (true, "wild")] {
        let mut c = cfg.clone();
        c.wild = wild;
        out.push(crate::coordinator::run_algorithm(Algorithm::PassCoDe, &data, &c)?.trace);
    }
    // Serialized (R=1) stands in for the mutex variant.
    let mut c = cfg.clone();
    c.r_cores = 1;
    let mut tr = crate::coordinator::run_algorithm(Algorithm::PassCoDe, &data, &c)?.trace;
    tr.label = "PassCoDe-serialized(R=1)".into();
    out.push(tr);
    Ok(out)
}

/// σ-scaling ablation.
pub fn sigma(dataset: &str, rounds: usize) -> anyhow::Result<Vec<Trace>> {
    let mut cfg = paper_cfg(dataset, 4, 2);
    cfg.s_barrier = 2;
    cfg.gamma = 4;
    cfg.max_rounds = rounds;
    cfg.gap_threshold = 1e-8;
    let data = super::load_dataset(&cfg)?;
    let mut out = Vec::new();
    for (policy, name) in [
        (SigmaPolicy::NuS, "sigma=νS(safe)"),
        (SigmaPolicy::NuK, "sigma=νK(damped)"),
        (SigmaPolicy::Fixed(0.25), "sigma=0.25(unsafe)"),
    ] {
        let mut c: ExpConfig = cfg.clone();
        c.sigma = policy;
        let opts = ProtocolOpts {
            label: format!("Hybrid-DCA/{name}"),
            sync_allreduce: false,
            policy: MergePolicy::OldestFirst,
        };
        out.push(run_with(&data, &c, &opts)?.trace);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_policy_both_run() {
        let traces = merge_policy("tiny", 10).unwrap();
        assert_eq!(traces.len(), 2);
        for t in &traces {
            assert!(t.final_gap().unwrap() < 1.0);
        }
    }

    #[test]
    fn locks_three_variants() {
        let traces = locks("tiny", 4, 10).unwrap();
        assert_eq!(traces.len(), 3);
        assert_eq!(traces[0].label, "PassCoDe");
        assert_eq!(traces[1].label, "PassCoDe-Wild");
        assert_eq!(traces[2].label, "PassCoDe-serialized(R=1)");
    }

    #[test]
    fn sigma_safe_beats_unsafe_eventually() {
        let traces = sigma("tiny", 25).unwrap();
        assert_eq!(traces.len(), 3);
        let safe = traces[0].best_gap().unwrap();
        // Damped converges too, just slower per round.
        let damped = traces[1].best_gap().unwrap();
        assert!(safe < 0.5 && damped < 0.9, "safe {safe}, damped {damped}");
    }
}
