//! Figure 4 — speedup(p, t) of each parallel/distributed solver over
//! the sequential Baseline, measured as the ratio of time-to-threshold.
//!
//! Paper setup: thresholds 10⁻⁴/10⁻⁵/10⁻¹ per dataset; PassCoDe sweeps
//! cores on one node; CoCoA+ sweeps nodes (1 core each); Hybrid-DCA
//! sweeps p ∈ {2,4,8,16} × t ∈ {2,4,8,16,24} with p·t ≤ 144.
//! Time here is **virtual** cluster time (DESIGN.md §3: the testbed has
//! one physical core, so parallel wall-clock is meaningless; the
//! virtual clock models the paper's queueing structure).

use super::{paper_session, QuickFull};

/// One measured speedup point.
#[derive(Debug, Clone, PartialEq)]
pub struct SpeedupPoint {
    pub solver: String,
    pub p: usize,
    pub t: usize,
    /// Virtual time to reach the threshold (None = never reached).
    pub time_to_threshold: Option<f64>,
    /// Baseline virtual time / this solver's virtual time.
    pub speedup: Option<f64>,
}

/// The sweep grid.
pub struct Fig4Grid {
    pub dataset: String,
    pub threshold: f64,
    pub p_values: Vec<usize>,
    pub t_values: Vec<usize>,
    pub max_cores: usize,
    pub max_rounds: usize,
}

impl Fig4Grid {
    pub fn new(mode: QuickFull, dataset: &str) -> Self {
        match mode {
            QuickFull::Quick => Fig4Grid {
                dataset: dataset.into(),
                threshold: super::fig3::threshold_for(dataset),
                p_values: vec![2, 4],
                t_values: vec![2, 4],
                max_cores: 16,
                max_rounds: 60,
            },
            QuickFull::Full => Fig4Grid {
                dataset: dataset.into(),
                threshold: super::fig3::threshold_for(dataset),
                p_values: vec![2, 4, 8, 16],
                t_values: vec![2, 4, 8],
                max_cores: 144,
                max_rounds: 150,
            },
        }
    }
}

/// Run the whole grid. Returns (baseline time, points).
pub fn run_grid(grid: &Fig4Grid) -> anyhow::Result<(f64, Vec<SpeedupPoint>)> {
    let base = paper_session(&grid.dataset, 1, 1)
        .rounds(grid.max_rounds)
        .gap_threshold(grid.threshold);
    let data = base.clone().build()?.load_dataset()?;

    // Baseline reference. Give it proportionally more rounds: it applies
    // H updates/round where parallel solvers apply p·t·H.
    let base_time = {
        let session = base
            .clone()
            .cluster(1, 1)
            .barrier(1)
            .rounds(grid.max_rounds * grid.max_cores)
            .eval_every(8)
            .build()?;
        let tr = session.run("baseline", &data)?.trace;
        tr.virt_time_to_gap(grid.threshold)
            .ok_or_else(|| anyhow::anyhow!("baseline never reached threshold {}", grid.threshold))?
    };

    let mut points = Vec::new();

    // PassCoDe: single node, t cores (t sweep includes the larger values).
    for &t in grid.t_values.iter().chain(grid.p_values.iter()) {
        let session = base.clone().cluster(1, t).barrier(1).build()?;
        let tr = session.run("passcode", &data)?.trace;
        let ttt = tr.virt_time_to_gap(grid.threshold);
        points.push(SpeedupPoint {
            solver: "PassCoDe".into(),
            p: 1,
            t,
            time_to_threshold: ttt,
            speedup: ttt.map(|x| base_time / x),
        });
    }

    // CoCoA+: p nodes × 1 core.
    for &p in &grid.p_values {
        let session = base.clone().cluster(p, 1).barrier(p).build()?;
        let tr = session.run("cocoa+", &data)?.trace;
        let ttt = tr.virt_time_to_gap(grid.threshold);
        points.push(SpeedupPoint {
            solver: "CoCoA+".into(),
            p,
            t: 1,
            time_to_threshold: ttt,
            speedup: ttt.map(|x| base_time / x),
        });
    }

    // Hybrid-DCA: p × t grid under the core cap.
    for &p in &grid.p_values {
        for &t in &grid.t_values {
            if p * t > grid.max_cores {
                continue;
            }
            let session = base.clone().cluster(p, t).barrier(p).delay(1).build()?;
            let tr = session.run("hybrid-dca", &data)?.trace;
            let ttt = tr.virt_time_to_gap(grid.threshold);
            points.push(SpeedupPoint {
                solver: "Hybrid-DCA".into(),
                p,
                t,
                time_to_threshold: ttt,
                speedup: ttt.map(|x| base_time / x),
            });
        }
    }

    Ok((base_time, points))
}

/// Print the figure's series and write the CSV.
pub fn run_and_print(mode: QuickFull) -> anyhow::Result<()> {
    let dataset = "rcv1-s";
    let grid = Fig4Grid::new(mode, dataset);
    println!(
        "== Figure 4: speedup over Baseline on {} (threshold {:.0e}, virtual time) ==",
        grid.dataset, grid.threshold
    );
    let (base_time, points) = run_grid(&grid)?;
    println!("baseline time-to-threshold: {base_time:.4}s (virtual)\n");
    println!("{:<12} {:>4} {:>4} {:>14} {:>10}", "solver", "p", "t", "time(s)", "speedup");
    for pt in &points {
        println!(
            "{:<12} {:>4} {:>4} {:>14} {:>10}",
            pt.solver,
            pt.p,
            pt.t,
            pt.time_to_threshold.map(|x| format!("{x:.4}")).unwrap_or_else(|| "—".into()),
            pt.speedup.map(|x| format!("{x:.1}×")).unwrap_or_else(|| "—".into()),
        );
    }
    // CSV.
    let path = super::results_dir().join("fig4_speedup.csv");
    std::fs::create_dir_all(super::results_dir())?;
    let mut out = String::from("solver,p,t,time_to_threshold,speedup\n");
    for pt in &points {
        out.push_str(&format!(
            "{},{},{},{},{}\n",
            pt.solver,
            pt.p,
            pt.t,
            pt.time_to_threshold.map(|x| x.to_string()).unwrap_or_default(),
            pt.speedup.map(|x| x.to_string()).unwrap_or_default()
        ));
    }
    std::fs::write(&path, out)?;
    println!("\n# series written to {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_grid_tiny() {
        let grid = Fig4Grid {
            dataset: "tiny".into(),
            threshold: 5e-2,
            p_values: vec![2],
            t_values: vec![2],
            max_cores: 8,
            max_rounds: 40,
        };
        let (base_time, points) = run_grid(&grid).unwrap();
        assert!(base_time > 0.0);
        assert!(!points.is_empty());
        // Hybrid with 4 virtual cores should beat the 1-core baseline.
        let hybrid = points
            .iter()
            .find(|p| p.solver == "Hybrid-DCA" && p.p == 2 && p.t == 2)
            .unwrap();
        let sp = hybrid.speedup.expect("hybrid reached threshold");
        assert!(sp > 1.0, "speedup {sp}");
    }
}
