//! Table 1 — dataset statistics (n, d, nnz, size) for every preset,
//! with the paper's originals alongside for the scale-down record.

use crate::data::{synth::ALL_PRESETS, DatasetStats};

/// Paper's Table 1 rows (for the printed comparison).
pub const PAPER_TABLE1: [(&str, u64, u64, u64, &str); 4] = [
    ("rcv1", 677_399, 47_236, 49_556_258, "1.2 GB"),
    ("webspam", 280_000, 16_609_143, 1_045_051_224, "20 GB"),
    ("kddb", 19_264_097, 29_890_095, 566_345_888, "5.1 GB"),
    ("splicesite", 4_627_840, 11_725_480, 15_383_587_858, "280 GB"),
];

/// Compute stats for all presets.
pub fn compute_all(seed: u64) -> Vec<DatasetStats> {
    ALL_PRESETS
        .iter()
        .map(|p| DatasetStats::compute(&super::gen_preset(*p, seed)))
        .collect()
}

/// Regenerate and print Table 1.
pub fn run_and_print() -> anyhow::Result<()> {
    println!("== Table 1: datasets (paper originals vs synthetic presets) ==\n");
    println!("paper originals:");
    println!(
        "{:<14} {:>12} {:>12} {:>16} {:>9}",
        "dataset", "n", "d", "nnz", "size"
    );
    for (name, n, d, nnz, size) in PAPER_TABLE1 {
        println!("{name:<14} {n:>12} {d:>12} {nnz:>16} {size:>9}");
    }
    println!("\nsynthetic presets (this repo):");
    println!("{}", DatasetStats::table_header());
    let stats = compute_all(42);
    for s in &stats {
        println!("{}", s.table_row());
    }
    // Scale record: nnz ratio vs paper for matched presets.
    println!("\nscale-down factors (paper nnz / preset nnz):");
    for (paper, preset_name) in [
        ("rcv1", "rcv1-s"),
        ("webspam", "webspam-s"),
        ("kddb", "kddb-s"),
        ("splicesite", "splicesite-s"),
    ] {
        let p = PAPER_TABLE1.iter().find(|r| r.0 == paper).unwrap();
        if let Some(s) = stats.iter().find(|s| s.name == preset_name) {
            println!("  {:<12} {:>8.0}×", paper, p.3 as f64 / s.nnz as f64);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_have_stats() {
        let stats = compute_all(1);
        assert_eq!(stats.len(), ALL_PRESETS.len());
        for s in &stats {
            assert!(s.nnz > 0, "{}", s.name);
        }
    }

    #[test]
    fn presets_preserve_shape_statistics() {
        // n:d ratios within 3× of the paper's (the preserved invariant).
        let stats = compute_all(2);
        for (paper_name, preset_name) in [
            ("rcv1", "rcv1-s"),
            ("webspam", "webspam-s"),
            ("kddb", "kddb-s"),
            ("splicesite", "splicesite-s"),
        ] {
            let p = PAPER_TABLE1.iter().find(|r| r.0 == paper_name).unwrap();
            let s = stats.iter().find(|s| s.name == preset_name).unwrap();
            let paper_ratio = p.1 as f64 / p.2 as f64;
            let ours = s.n as f64 / s.d as f64;
            assert!(
                ours / paper_ratio < 3.0 && paper_ratio / ours < 3.0,
                "{preset_name}: n:d {ours:.3} vs paper {paper_ratio:.3}"
            );
        }
    }
}
