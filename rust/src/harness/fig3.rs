//! Figure 3 — duality gap vs rounds (top row) and vs time (bottom row)
//! for Baseline, CoCoA+, PassCoDe, and Hybrid-DCA on three datasets,
//! with the total worker cores `p × t` equal across the parallel
//! solvers (paper: 16; quick mode: 8).
//!
//! Paper setup: λ = 10⁻⁴, H = 40000, ν = 1, σ ∈ {K, S}; Hybrid uses
//! `S = p, Γ = 1` (synchronous global updates) for this figure.

use crate::config::Algorithm;
use crate::metrics::Trace;

use super::{paper_cfg, print_threshold_table, save_traces, QuickFull};

/// One dataset's sweep result.
pub struct Fig3Result {
    pub dataset: String,
    pub threshold: f64,
    pub traces: Vec<Trace>,
}

/// Per-dataset gap thresholds (the paper's §6.2 values:
/// 10⁻⁴ / 10⁻⁵ / 10⁻¹ for rcv1 / webspam / kddb).
pub fn threshold_for(dataset: &str) -> f64 {
    match dataset {
        "rcv1-s" => 1e-4,
        "webspam-s" => 1e-5,
        "kddb-s" => 1e-1,
        "splicesite-s" => 1e-6, // Fig 7's headline gap
        _ => 1e-4,
    }
}

/// Run the four solvers on one dataset with `p×t` worker cores.
pub fn run_dataset(dataset: &str, p: usize, t: usize, max_rounds: usize) -> anyhow::Result<Fig3Result> {
    let threshold = threshold_for(dataset);
    let mut cfg = paper_cfg(dataset, p, t);
    cfg.max_rounds = max_rounds;
    cfg.gap_threshold = threshold / 10.0; // run a bit past the threshold
    let data = super::load_dataset(&cfg)?;

    let mut traces = Vec::new();

    // Baseline: 1 core, rounds of H updates.
    {
        let mut c = cfg.clone();
        c.k_nodes = 1;
        c.r_cores = 1;
        c.s_barrier = 1;
        traces.push(crate::coordinator::run_algorithm(Algorithm::Baseline, &data, &c)?.trace);
    }
    // CoCoA+: p×t single-core nodes (equal total cores; the paper's
    // CoCoA+ rows use 1 core per node, so p·t nodes).
    {
        let mut c = cfg.clone();
        c.k_nodes = p * t;
        c.r_cores = 1;
        c.s_barrier = c.k_nodes;
        traces.push(crate::coordinator::run_algorithm(Algorithm::CocoaPlus, &data, &c)?.trace);
    }
    // PassCoDe: one node, p×t cores.
    {
        let mut c = cfg.clone();
        c.k_nodes = 1;
        c.s_barrier = 1;
        c.r_cores = p * t;
        traces.push(crate::coordinator::run_algorithm(Algorithm::PassCoDe, &data, &c)?.trace);
    }
    // Hybrid-DCA: p nodes × t cores, S = p, Γ = 1 (Fig 3 setting).
    {
        let mut c = cfg.clone();
        c.s_barrier = p;
        c.gamma = 1;
        traces.push(crate::coordinator::run_algorithm(Algorithm::HybridDca, &data, &c)?.trace);
    }

    Ok(Fig3Result { dataset: dataset.into(), threshold, traces })
}

/// Full driver: all datasets, print + CSV.
pub fn run_and_print(mode: QuickFull) -> anyhow::Result<()> {
    let (datasets, p, t, rounds): (&[&str], usize, usize, usize) = match mode {
        QuickFull::Quick => (&["rcv1-s"], 4, 2, 30),
        QuickFull::Full => (&["rcv1-s", "webspam-s", "kddb-s"], 8, 2, 250),
    };
    println!("== Figure 3: duality gap vs rounds and vs time (p×t = {}) ==", p * t);
    let mut all = Vec::new();
    for ds in datasets {
        let res = run_dataset(ds, p, t, rounds)?;
        println!("\n-- dataset {} (threshold {:.0e}) --", res.dataset, res.threshold);
        print_threshold_table(&res.traces, res.threshold);
        for mut tr in res.traces {
            tr.label = format!("{}/{}", res.dataset, tr.label);
            all.push(tr);
        }
    }
    save_traces("fig3_convergence", &all)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_quick_tiny() {
        // Smoke the full driver machinery on the tiny preset.
        let res = run_dataset("tiny", 2, 2, 10).unwrap();
        assert_eq!(res.traces.len(), 4);
        let labels: Vec<&str> = res.traces.iter().map(|t| t.label.as_str()).collect();
        assert!(labels.contains(&"Baseline"));
        assert!(labels.contains(&"CoCoA+"));
        assert!(labels.contains(&"PassCoDe"));
        assert!(labels.contains(&"Hybrid-DCA"));
        // All four make real progress from the α=0 gap of ≈1. (Relative
        // ordering is only meaningful on the full-size presets — on
        // `tiny`, n=200, the sequential baseline solves the problem in a
        // couple of epochs; the bench asserts the paper's ordering on
        // rcv1-s.)
        for t in &res.traces {
            let g = t.final_gap().unwrap();
            assert!(g < 0.1, "{}: gap {g}", t.label);
        }
    }
}
