//! Figure 3 — duality gap vs rounds (top row) and vs time (bottom row)
//! for Baseline, CoCoA+, PassCoDe, and Hybrid-DCA on three datasets,
//! with the total worker cores `p × t` equal across the parallel
//! solvers (paper: 16; quick mode: 8).
//!
//! Paper setup: λ = 10⁻⁴, H = 40000, ν = 1, σ ∈ {K, S}; Hybrid uses
//! `S = p, Γ = 1` (synchronous global updates) for this figure.

use crate::metrics::Trace;

use super::{paper_session, print_threshold_table, save_traces, QuickFull};

/// One dataset's sweep result.
pub struct Fig3Result {
    pub dataset: String,
    pub threshold: f64,
    pub traces: Vec<Trace>,
}

/// Per-dataset gap thresholds (the paper's §6.2 values:
/// 10⁻⁴ / 10⁻⁵ / 10⁻¹ for rcv1 / webspam / kddb).
pub fn threshold_for(dataset: &str) -> f64 {
    match dataset {
        "rcv1-s" => 1e-4,
        "webspam-s" => 1e-5,
        "kddb-s" => 1e-1,
        "splicesite-s" => 1e-6, // Fig 7's headline gap
        _ => 1e-4,
    }
}

/// Run the four solvers on one dataset with `p×t` worker cores.
pub fn run_dataset(
    dataset: &str,
    p: usize,
    t: usize,
    max_rounds: usize,
) -> anyhow::Result<Fig3Result> {
    let threshold = threshold_for(dataset);
    let base = paper_session(dataset, p, t)
        .rounds(max_rounds)
        .gap_threshold(threshold / 10.0); // run a bit past the threshold
    let data = base.clone().build()?.load_dataset()?;

    let mut traces = Vec::new();

    // Baseline: 1 core, rounds of H updates.
    traces.push(
        base.clone()
            .cluster(1, 1)
            .barrier(1)
            .build()?
            .run("baseline", &data)?
            .trace,
    );
    // CoCoA+: p×t single-core nodes (equal total cores; the paper's
    // CoCoA+ rows use 1 core per node, so p·t nodes).
    traces.push(
        base.clone()
            .cluster(p * t, 1)
            .barrier(p * t)
            .build()?
            .run("cocoa+", &data)?
            .trace,
    );
    // PassCoDe: one node, p×t cores.
    traces.push(
        base.clone()
            .cluster(1, p * t)
            .barrier(1)
            .build()?
            .run("passcode", &data)?
            .trace,
    );
    // Hybrid-DCA: p nodes × t cores, S = p, Γ = 1 (Fig 3 setting).
    traces.push(
        base.clone()
            .barrier(p)
            .delay(1)
            .build()?
            .run("hybrid-dca", &data)?
            .trace,
    );

    Ok(Fig3Result { dataset: dataset.into(), threshold, traces })
}

/// Full driver: all datasets, print + CSV.
pub fn run_and_print(mode: QuickFull) -> anyhow::Result<()> {
    let (datasets, p, t, rounds): (&[&str], usize, usize, usize) = match mode {
        QuickFull::Quick => (&["rcv1-s"], 4, 2, 30),
        QuickFull::Full => (&["rcv1-s", "webspam-s", "kddb-s"], 8, 2, 250),
    };
    println!("== Figure 3: duality gap vs rounds and vs time (p×t = {}) ==", p * t);
    let mut all = Vec::new();
    for ds in datasets {
        let res = run_dataset(ds, p, t, rounds)?;
        println!("\n-- dataset {} (threshold {:.0e}) --", res.dataset, res.threshold);
        print_threshold_table(&res.traces, res.threshold);
        for mut tr in res.traces {
            tr.label = format!("{}/{}", res.dataset, tr.label);
            all.push(tr);
        }
    }
    save_traces("fig3_convergence", &all)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_quick_tiny() {
        // Smoke the full driver machinery on the tiny preset.
        let res = run_dataset("tiny", 2, 2, 10).unwrap();
        assert_eq!(res.traces.len(), 4);
        let labels: Vec<&str> = res.traces.iter().map(|t| t.label.as_str()).collect();
        assert!(labels.contains(&"Baseline"));
        assert!(labels.contains(&"CoCoA+"));
        assert!(labels.contains(&"PassCoDe"));
        assert!(labels.contains(&"Hybrid-DCA"));
        // All four make real progress from the α=0 gap of ≈1. (Relative
        // ordering is only meaningful on the full-size presets — on
        // `tiny`, n=200, the sequential baseline solves the problem in a
        // couple of epochs; the bench asserts the paper's ordering on
        // rcv1-s.)
        for t in &res.traces {
            let g = t.final_gap().unwrap();
            assert!(g < 0.1, "{}: gap {g}", t.label);
        }
    }
}
