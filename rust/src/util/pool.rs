//! Persistent worker pool for evaluation and store maintenance.
//!
//! The PR 4 parallel objective evaluation spawned scoped threads on
//! every call; at `eval_every = 1` the spawn/join cost rivals the scan
//! itself on small shards. This pool parks a fixed set of threads once
//! (first use) and hands them closures through a generation counter —
//! no per-call thread creation, and pool threads keep their
//! thread-local scratch (shard read buffers, see `store::sharded`)
//! alive across evaluation rounds.
//!
//! Semantics match `std::thread::scope`: [`WorkPool::run`] blocks until
//! every worker has finished the closure, so borrowing stack data in
//! the job is sound (the lifetime erasure below is justified exactly by
//! that barrier). Worker panics are caught and re-raised on the caller.

use std::panic::{catch_unwind, AssertUnwindSafe};

use crate::util::sync::{Condvar, Mutex, MutexGuard, OnceLock};

// ORDERING: the pool protocol uses no atomics at all — every shared
// field (generation, job, remaining, panicked) lives under one façade
// `Mutex`, so the lock's release/acquire edges order job publication
// before execution and execution before the submitter's return. The
// generation handshake is model-checked in `tests/loom_pool.rs`
// (a generation never runs a job twice, panics propagate, drop-free
// termination in every interleaving).

/// Work item: a lifetime-erased `Fn(worker_index)`. Only dereferenced
/// between job publication and the last `remaining` decrement, while
/// the submitting caller is still blocked in [`WorkPool::run`].
#[derive(Clone, Copy)]
struct Job {
    ptr: *const (dyn Fn(usize) + Sync),
    /// Workers with index ≥ `workers` skip the job (they still check
    /// in, keeping the generation bookkeeping uniform).
    workers: usize,
}
// SAFETY: the raw pointer is only dereferenced by pool threads while
// the submitter is blocked in `run` (see the lifetime-erasure proof
// there); sending it across threads adds no new access.
unsafe impl Send for Job {}

struct State {
    generation: u64,
    job: Option<Job>,
    /// Pool threads that have not yet finished the current generation.
    remaining: usize,
    panicked: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here between generations.
    work_cv: Condvar,
    /// The submitter parks here until `remaining == 0`.
    done_cv: Condvar,
}

/// A fixed-size pool of parked threads. One global instance
/// ([`WorkPool::global`]) is shared by the objective evaluators and
/// the shard-store verifier; a submission mutex serializes concurrent
/// `run` calls (e.g. parallel `cargo test`).
pub struct WorkPool {
    shared: &'static Shared,
    size: usize,
    submit: Mutex<()>,
}

impl WorkPool {
    /// The process-wide pool. Created on first use; threads are
    /// detached and die with the process.
    pub fn global() -> &'static WorkPool {
        static POOL: OnceLock<WorkPool> = OnceLock::new();
        POOL.get_or_init(|| {
            // At least 4 so tests can exercise 1/2/4-way evaluation
            // fan-out regardless of the host's core count.
            let size =
                std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1).max(4);
            WorkPool::with_size(size)
        })
    }

    fn with_size(size: usize) -> WorkPool {
        let shared: &'static Shared = Box::leak(Box::new(Shared {
            state: Mutex::new(State {
                generation: 0,
                job: None,
                remaining: 0,
                panicked: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        }));
        for index in 0..size {
            std::thread::Builder::new()
                .name(format!("hdca-pool-{index}"))
                .spawn(move || worker_loop(shared, index))
                .expect("spawn pool thread");
        }
        WorkPool { shared, size, submit: Mutex::new(()) }
    }

    /// Number of threads in the pool (upper bound on `workers`).
    pub fn size(&self) -> usize {
        self.size
    }

    /// Run `job(i)` on `workers` pool threads (`i` in `0..workers`)
    /// and block until all have finished. Re-raises worker panics.
    pub fn run(&self, workers: usize, job: &(dyn Fn(usize) + Sync)) {
        let workers = workers.clamp(1, self.size);
        let _serial: MutexGuard<'_, ()> = self.submit.lock().expect("pool submit lock");
        // SAFETY: lifetime erasure. The pointer is only called by pool
        // threads before they decrement `remaining`, and we do not
        // return until `remaining == 0` (release on the state mutex /
        // acquire below orders those calls before our return), so the
        // borrow never outlives the frame that owns it.
        let erased: *const (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute(job as *const (dyn Fn(usize) + Sync)) };
        let mut state = self.shared.state.lock().expect("pool state lock");
        state.generation += 1;
        state.job = Some(Job { ptr: erased, workers });
        state.remaining = self.size;
        self.shared.work_cv.notify_all();
        while state.remaining > 0 {
            state = self.shared.done_cv.wait(state).expect("pool done wait");
        }
        state.job = None;
        let panicked = std::mem::replace(&mut state.panicked, false);
        drop(state);
        if panicked {
            panic!("worker panicked in WorkPool::run");
        }
    }
}

fn worker_loop(shared: &'static Shared, index: usize) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut state = shared.state.lock().expect("pool state lock");
            while state.generation == seen {
                state = shared.work_cv.wait(state).expect("pool work wait");
            }
            seen = state.generation;
            state.job.expect("generation advanced without a job")
        };
        if index < job.workers {
            // SAFETY: the submitter blocks in `run` until we decrement
            // `remaining` below, so the erased borrow is still live.
            let f = unsafe { &*job.ptr };
            if catch_unwind(AssertUnwindSafe(|| f(index))).is_err() {
                shared.state.lock().expect("pool state lock").panicked = true;
            }
        }
        let mut state = shared.state.lock().expect("pool state lock");
        state.remaining -= 1;
        if state.remaining == 0 {
            shared.done_cv.notify_all();
        }
    }
}

/// A `*mut f64` slice that many pool workers may write through, each at
/// indices it exclusively owns (chunk-claimed or range-partitioned).
/// The caller must guarantee disjointness; the pool's completion
/// barrier provides the happens-before for reading the results back.
#[derive(Clone, Copy)]
pub struct DisjointWrites(*mut f64);
// SAFETY: the wrapped pointer is only written through `set`, whose
// contract (caller-guaranteed index disjointness + the pool barrier)
// makes concurrent use race-free; the pointer itself is plain data.
unsafe impl Send for DisjointWrites {}
// SAFETY: as above — shared references only expose `set`, which is
// already unsafe with a disjointness contract.
unsafe impl Sync for DisjointWrites {}

impl DisjointWrites {
    pub fn new(slice: &mut [f64]) -> Self {
        DisjointWrites(slice.as_mut_ptr())
    }

    /// Write `value` at `index`.
    ///
    /// # Safety
    /// `index` is in bounds of the source slice and no other thread
    /// writes the same index during this pool job.
    #[inline]
    pub unsafe fn set(&self, index: usize, value: f64) {
        // SAFETY: forwarded contract — `index` in bounds of the source
        // slice, no concurrent writer of the same index.
        unsafe { *self.0.add(index) = value };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::sync::{AtomicUsize, Ordering};

    // ORDERING: test counters are read only after `run` returns, and
    // `run`'s completion barrier (state mutex) already orders all
    // worker writes before that return — `Relaxed` suffices.

    #[test]
    fn runs_all_workers_and_blocks_until_done() {
        let pool = WorkPool::global();
        let hits = AtomicUsize::new(0);
        pool.run(3, &|_i| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn reusable_across_many_generations() {
        let pool = WorkPool::global();
        let total = AtomicUsize::new(0);
        for _ in 0..100 {
            pool.run(2, &|i| {
                total.fetch_add(i + 1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 300);
    }

    #[test]
    fn disjoint_writes_land() {
        let pool = WorkPool::global();
        let mut out = vec![0.0f64; 8];
        let sink = DisjointWrites::new(&mut out);
        pool.run(4, &|i| {
            // SAFETY: worker i exclusively owns indices {i, i+4},
            // both < 8 = out.len().
            unsafe {
                sink.set(i, i as f64);
                sink.set(i + 4, (i + 4) as f64);
            }
        });
        assert_eq!(out, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = WorkPool::global();
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(2, &|i| {
                if i == 1 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err());
        // Pool still serves jobs afterwards.
        let hits = AtomicUsize::new(0);
        pool.run(2, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn workers_clamped_to_pool_size() {
        let pool = WorkPool::global();
        let hits = AtomicUsize::new(0);
        pool.run(pool.size() + 100, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), pool.size());
    }
}
