//! Minimal JSON value type with a parser and pretty-printer — the
//! machine-readable side of the bench harness (`BENCH_hot_loop.json`).
//! `serde` is unavailable offline, and the TOML-subset reader in
//! `config::toml` is config-shaped; this covers the full JSON value
//! grammar the bench files need (objects preserve insertion order).

/// A JSON value. Objects are ordered key/value lists so emitted files
/// diff cleanly across runs.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> anyhow::Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let value = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        anyhow::ensure!(pos == bytes.len(), "trailing characters at byte {pos}");
        Ok(value)
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        write_value(self, 0, &mut out);
        out.push('\n');
        out
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, ch: u8) -> anyhow::Result<()> {
    skip_ws(b, pos);
    anyhow::ensure!(
        *pos < b.len() && b[*pos] == ch,
        "expected '{}' at byte {pos}",
        ch as char
    );
    *pos += 1;
    Ok(())
}

fn parse_value(b: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    skip_ws(b, pos);
    anyhow::ensure!(*pos < b.len(), "unexpected end of input");
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, value: Json) -> anyhow::Result<Json> {
    anyhow::ensure!(
        b[*pos..].starts_with(lit.as_bytes()),
        "invalid literal at byte {pos}"
    );
    *pos += lit.len();
    Ok(value)
}

fn parse_num(b: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    let start = *pos;
    while *pos < b.len() && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E') {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos]).expect("ascii slice");
    let x: f64 = text
        .parse()
        .map_err(|e| anyhow::anyhow!("bad number '{text}' at byte {start}: {e}"))?;
    Ok(Json::Num(x))
}

fn parse_string(b: &[u8], pos: &mut usize) -> anyhow::Result<String> {
    anyhow::ensure!(
        *pos < b.len() && b[*pos] == b'"',
        "expected string at byte {pos}"
    );
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                anyhow::ensure!(*pos < b.len(), "unterminated escape");
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        anyhow::ensure!(*pos + 4 < b.len(), "truncated \\u escape");
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|_| anyhow::anyhow!("non-ascii \\u escape"))?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| anyhow::anyhow!("bad \\u escape '{hex}'"))?;
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    c => anyhow::bail!("unknown escape '\\{}'", c as char),
                }
                *pos += 1;
            }
            _ => {
                // Consume one UTF-8 scalar (multi-byte sequences pass
                // through unchanged).
                let rest = std::str::from_utf8(&b[*pos..])
                    .map_err(|_| anyhow::anyhow!("invalid UTF-8 in string"))?;
                let ch = rest.chars().next().expect("non-empty rest");
                out.push(ch);
                *pos += ch.len_utf8();
            }
        }
    }
    anyhow::bail!("unterminated string")
}

fn parse_arr(b: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    expect(b, pos, b'[')?;
    let mut items = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        anyhow::ensure!(*pos < b.len(), "unterminated array");
        match b[*pos] {
            b',' => *pos += 1,
            b']' => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            c => anyhow::bail!("expected ',' or ']' at byte {pos}, got '{}'", c as char),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    expect(b, pos, b'{')?;
    let mut kvs = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(kvs));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        expect(b, pos, b':')?;
        let value = parse_value(b, pos)?;
        kvs.push((key, value));
        skip_ws(b, pos);
        anyhow::ensure!(*pos < b.len(), "unterminated object");
        match b[*pos] {
            b',' => *pos += 1,
            b'}' => {
                *pos += 1;
                return Ok(Json::Obj(kvs));
            }
            c => anyhow::bail!("expected ',' or '}}' at byte {pos}, got '{}'", c as char),
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_value(v: &Json, indent: usize, out: &mut String) {
    let pad = "  ".repeat(indent);
    let pad_in = "  ".repeat(indent + 1);
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(x) => out.push_str(if *x { "true" } else { "false" }),
        Json::Num(x) => {
            if x.is_finite() {
                out.push_str(&format!("{x}"));
            } else {
                out.push_str("null"); // JSON has no NaN/inf
            }
        }
        Json::Str(s) => write_escaped(s, out),
        Json::Arr(xs) => {
            if xs.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push_str("[\n");
            for (i, x) in xs.iter().enumerate() {
                out.push_str(&pad_in);
                write_value(x, indent + 1, out);
                out.push_str(if i + 1 < xs.len() { ",\n" } else { "\n" });
            }
            out.push_str(&pad);
            out.push(']');
        }
        Json::Obj(kvs) => {
            if kvs.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push_str("{\n");
            for (i, (k, x)) in kvs.iter().enumerate() {
                out.push_str(&pad_in);
                write_escaped(k, out);
                out.push_str(": ");
                write_value(x, indent + 1, out);
                out.push_str(if i + 1 < kvs.len() { ",\n" } else { "\n" });
            }
            out.push_str(&pad);
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_a_bench_shaped_document() {
        let doc = Json::Obj(vec![
            ("bench".into(), Json::Str("hot_loop".into())),
            ("h".into(), Json::Num(20000.0)),
            (
                "runs".into(),
                Json::Arr(vec![Json::Obj(vec![
                    ("label".into(), Json::Str("pre \"quoted\"".into())),
                    ("rate".into(), Json::Num(9.8e6)),
                    ("neg".into(), Json::Num(-0.25)),
                    ("flag".into(), Json::Bool(true)),
                    ("none".into(), Json::Null),
                ])]),
            ),
        ]);
        let text = doc.to_pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(doc, back);
    }

    #[test]
    fn parses_hand_written_json() {
        let j = Json::parse(
            r#"{"a": [1, 2.5e-3, -4], "b": {"c": "x\ny"}, "empty": [], "eo": {}}"#,
        )
        .unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5e-3));
        assert_eq!(j.get("b").unwrap().get("c").unwrap().as_str(), Some("x\ny"));
        assert_eq!(j.get("empty").unwrap().as_arr().unwrap().len(), 0);
        assert!(j.get("missing").is_none());
    }

    #[test]
    fn rejects_malformed_input() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{} trailing").is_err());
        assert!(Json::parse("nope").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""a\u0041b""#).unwrap();
        assert_eq!(j.as_str(), Some("aAb"));
        let j = Json::parse("\"Δv → master\"").unwrap();
        assert_eq!(j.as_str(), Some("Δv → master"));
    }
}
