//! Concurrency façade: the single place the crate is allowed to touch
//! `std::sync::atomic` (enforced by `cargo xtask lint`).
//!
//! Every atomic, mutex, and condvar the solver/transport/store layers
//! use is imported *through this module*. That buys two things:
//!
//! 1. **Auditability.** All `Ordering` decisions funnel through call
//!    sites that the xtask lint forces to carry `// ORDERING:`
//!    justifications, and a grep for `std::sync::atomic` outside this
//!    file is a lint failure — no ordering choice can hide.
//! 2. **Model-checkability.** The `modelcheck` feature (see
//!    [`crate::util::model`]) ships an exhaustive interleaving explorer
//!    whose step-level models are transcriptions of the protocols built
//!    on these primitives (`AtomicF64Vec` CAS/wild adds, the `WorkPool`
//!    generation handshake, the [`mailbox`] handoff). Keeping the real
//!    code on one façade keeps the models honest: each `tests/loom_*.rs`
//!    model cites the façade call sites it transcribes, and the lint
//!    wall keeps those call sites enumerable.
//!
//! The re-exports are zero-cost: this module adds no wrapper types on
//! the hot path (the 18M updates/s CAS loop in `atomic_vec.rs` compiles
//! to the same code as before the façade existed).

pub use std::sync::atomic::{
    AtomicBool, AtomicU32, AtomicU64, AtomicU8, AtomicUsize, Ordering,
};
pub use std::sync::{Condvar, Mutex, MutexGuard, OnceLock};

use std::collections::VecDeque;
use std::sync::Arc;

/// Shared core of the mailbox channel (see [`mailbox`]).
struct MailboxInner<T> {
    state: Mutex<MailboxState<T>>,
    /// The receiver parks here while the queue is empty.
    ready_cv: Condvar,
}

struct MailboxState<T> {
    queue: VecDeque<T>,
    /// Live `Sender` handles. `recv` only reports disconnect once this
    /// reaches zero with an empty queue.
    senders: usize,
    /// Set by `Receiver::drop`; flips `send` into the error path.
    receiver_gone: bool,
}

/// Error from [`Receiver::recv`]: all senders dropped, queue drained.
#[derive(Debug, PartialEq, Eq)]
pub struct RecvError;

/// Error from [`Sender::send`]: the receiver was dropped. Carries the
/// unsent message back to the caller, like `std::sync::mpsc::SendError`.
#[derive(Debug, PartialEq, Eq)]
pub struct SendError<T>(pub T);

/// Sending half of a mailbox channel. Cloneable (multi-producer).
pub struct Sender<T> {
    inner: Arc<MailboxInner<T>>,
}

/// Receiving half of a mailbox channel. Single-consumer.
pub struct Receiver<T> {
    inner: Arc<MailboxInner<T>>,
}

/// Create a connected `(Sender, Receiver)` mailbox pair — a
/// multi-producer single-consumer channel built from the façade's
/// `Mutex` + `Condvar`, replacing `std::sync::mpsc` on the master's
/// merge-mailbox handoff (`transport::inprocess`) and the socket
/// demultiplexer (`transport::socket`).
///
/// Semantics match `std::sync::mpsc` where the coordinator relies on
/// them:
/// * [`Receiver::recv`] blocks until a message is queued, and returns
///   `Err(RecvError)` exactly when the queue is empty **and** every
///   [`Sender`] has been dropped.
/// * [`Sender::send`] returns `Err(SendError(t))` after the receiver is
///   dropped, handing the message back.
/// * Messages from a single sender are received in send order (FIFO
///   queue under one lock).
///
/// The protocol is small enough to model-check: `tests/loom_mailbox.rs`
/// transcribes send/recv/drop into explorer steps and exhausts every
/// 2-producer interleaving (no lost message, no stuck receiver).
pub fn mailbox<T>() -> (Sender<T>, Receiver<T>) {
    let inner = Arc::new(MailboxInner {
        state: Mutex::new(MailboxState {
            queue: VecDeque::new(),
            senders: 1,
            receiver_gone: false,
        }),
        ready_cv: Condvar::new(),
    });
    (Sender { inner: Arc::clone(&inner) }, Receiver { inner })
}

impl<T> Sender<T> {
    /// Queue `t` for the receiver. Fails (returning `t`) iff the
    /// receiver has been dropped.
    pub fn send(&self, t: T) -> Result<(), SendError<T>> {
        let mut state = self.inner.state.lock().expect("mailbox lock");
        if state.receiver_gone {
            return Err(SendError(t));
        }
        state.queue.push_back(t);
        drop(state);
        self.inner.ready_cv.notify_one();
        Ok(())
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.inner.state.lock().expect("mailbox lock").senders += 1;
        Sender { inner: Arc::clone(&self.inner) }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut state = self.inner.state.lock().expect("mailbox lock");
        state.senders -= 1;
        let last = state.senders == 0;
        drop(state);
        if last {
            // Wake a receiver parked on an empty queue so it can
            // observe the disconnect instead of sleeping forever.
            self.inner.ready_cv.notify_one();
        }
    }
}

impl<T> Receiver<T> {
    /// Block until a message arrives, or until every sender is gone.
    pub fn recv(&self) -> Result<T, RecvError> {
        let mut state = self.inner.state.lock().expect("mailbox lock");
        loop {
            if let Some(t) = state.queue.pop_front() {
                return Ok(t);
            }
            if state.senders == 0 {
                return Err(RecvError);
            }
            state = self.inner.ready_cv.wait(state).expect("mailbox wait");
        }
    }

    /// Bounded-wait variant: block at most `dur` for a message.
    /// `Ok(Some)` on a queued message, `Ok(None)` once the wait expires
    /// with the queue still empty, `Err` once disconnected+drained —
    /// the fault-tolerant master's liveness tick
    /// (`coordinator::master`) is built on this.
    pub fn recv_timeout(&self, dur: std::time::Duration) -> Result<Option<T>, RecvError> {
        let deadline = std::time::Instant::now() + dur;
        let mut state = self.inner.state.lock().expect("mailbox lock");
        loop {
            if let Some(t) = state.queue.pop_front() {
                return Ok(Some(t));
            }
            if state.senders == 0 {
                return Err(RecvError);
            }
            let now = std::time::Instant::now();
            let Some(left) = deadline.checked_duration_since(now).filter(|d| !d.is_zero())
            else {
                return Ok(None);
            };
            // Spurious wakeups and waits cut short both land back in
            // the loop, which re-derives the remaining budget from the
            // absolute deadline.
            let (guard, _timed_out) =
                self.inner.ready_cv.wait_timeout(state, left).expect("mailbox wait");
            state = guard;
        }
    }

    /// Non-blocking variant: `Ok(Some)` on a queued message, `Ok(None)`
    /// on an empty-but-connected queue, `Err` once disconnected+drained.
    pub fn try_recv(&self) -> Result<Option<T>, RecvError> {
        let mut state = self.inner.state.lock().expect("mailbox lock");
        if let Some(t) = state.queue.pop_front() {
            return Ok(Some(t));
        }
        if state.senders == 0 {
            return Err(RecvError);
        }
        Ok(None)
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        self.inner.state.lock().expect("mailbox lock").receiver_gone = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_within_one_sender() {
        let (tx, rx) = mailbox();
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        tx.send(3).unwrap();
        assert_eq!(rx.recv(), Ok(1));
        assert_eq!(rx.recv(), Ok(2));
        assert_eq!(rx.recv(), Ok(3));
    }

    #[test]
    fn recv_disconnects_only_after_drain() {
        let (tx, rx) = mailbox();
        tx.send(7).unwrap();
        drop(tx);
        assert_eq!(rx.recv(), Ok(7));
        assert_eq!(rx.recv(), Err(RecvError));
    }

    #[test]
    fn clone_keeps_channel_open() {
        let (tx, rx) = mailbox();
        let tx2 = tx.clone();
        drop(tx);
        tx2.send(9).unwrap();
        assert_eq!(rx.recv(), Ok(9));
        drop(tx2);
        assert_eq!(rx.recv(), Err(RecvError));
    }

    #[test]
    fn send_fails_after_receiver_drop() {
        let (tx, rx) = mailbox();
        drop(rx);
        assert_eq!(tx.send(5), Err(SendError(5)));
    }

    #[test]
    fn blocking_recv_wakes_on_send() {
        let (tx, rx) = mailbox();
        let h = std::thread::spawn(move || rx.recv());
        // Give the receiver a chance to park before the send.
        std::thread::sleep(std::time::Duration::from_millis(10));
        tx.send(42u32).unwrap();
        assert_eq!(h.join().unwrap(), Ok(42));
    }

    #[test]
    fn blocking_recv_wakes_on_last_sender_drop() {
        let (tx, rx) = mailbox::<u32>();
        let h = std::thread::spawn(move || rx.recv());
        std::thread::sleep(std::time::Duration::from_millis(10));
        drop(tx);
        assert_eq!(h.join().unwrap(), Err(RecvError));
    }

    #[test]
    fn try_recv_states() {
        let (tx, rx) = mailbox();
        assert_eq!(rx.try_recv(), Ok(None));
        tx.send(1).unwrap();
        assert_eq!(rx.try_recv(), Ok(Some(1)));
        drop(tx);
        assert_eq!(rx.try_recv(), Err(RecvError));
    }

    #[test]
    fn recv_timeout_states() {
        let (tx, rx) = mailbox();
        // Empty but connected: expires with None.
        assert_eq!(rx.recv_timeout(std::time::Duration::from_millis(5)), Ok(None));
        tx.send(3).unwrap();
        // Queued message: returned without waiting out the budget.
        assert_eq!(rx.recv_timeout(std::time::Duration::from_secs(60)), Ok(Some(3)));
        drop(tx);
        assert_eq!(rx.recv_timeout(std::time::Duration::from_millis(5)), Err(RecvError));
    }

    #[test]
    fn recv_timeout_wakes_on_send() {
        let (tx, rx) = mailbox();
        let h = std::thread::spawn(move || rx.recv_timeout(std::time::Duration::from_secs(60)));
        std::thread::sleep(std::time::Duration::from_millis(10));
        tx.send(11u32).unwrap();
        assert_eq!(h.join().unwrap(), Ok(Some(11)));
    }

    #[test]
    fn many_producers_lose_nothing() {
        let (tx, rx) = mailbox();
        let handles: Vec<_> = (0..4)
            .map(|p| {
                let tx = tx.clone();
                std::thread::spawn(move || {
                    for k in 0..100u64 {
                        tx.send(p * 1000 + k).unwrap();
                    }
                })
            })
            .collect();
        drop(tx);
        let mut got = Vec::new();
        while let Ok(v) = rx.recv() {
            got.push(v);
        }
        for h in handles {
            h.join().unwrap();
        }
        got.sort_unstable();
        let mut want: Vec<u64> =
            (0..4).flat_map(|p| (0..100).map(move |k| p * 1000 + k)).collect();
        want.sort_unstable();
        assert_eq!(got, want);
    }
}
