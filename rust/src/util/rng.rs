//! Deterministic pseudo-random number generation.
//!
//! The offline build has no `rand` crate, so we implement the two small,
//! well-known generators the library needs:
//!
//! * [`SplitMix64`] — used only for seeding (it is the recommended seeder
//!   for the xoshiro family and recovers well from poorly-mixed seeds).
//! * [`Xoshiro256StarStar`] — the workhorse generator used by every
//!   solver thread. 256-bit state, period 2^256 − 1, passes BigCrush.
//!
//! Every solver/worker derives an independent stream with [`Rng::fork`],
//! which applies the generator's `jump()` function (equivalent to 2^128
//! `next_u64` calls), guaranteeing non-overlapping streams across the
//! `K × R` core-threads while keeping the whole run reproducible from a
//! single root seed.

/// SplitMix64: a tiny 64-bit PRNG used to expand seeds.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — the main generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline(always)]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Rng {
    /// Build from a 64-bit seed via SplitMix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = sm.next_u64();
        }
        // An all-zero state would be a fixed point; SplitMix64 cannot
        // produce four consecutive zeros in practice, but guard anyway.
        if s.iter().all(|&x| x == 0) {
            s[0] = 0x9E3779B97F4A7C15;
        }
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[1].wrapping_mul(5), 7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, bound)` via Lemire's nearly-divisionless method.
    #[inline]
    pub fn next_below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0, "next_below(0)");
        let bound = bound as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn next_range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.next_below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller (polar form avoided: we do not need
    /// the perf, and Box–Muller has no rejection loop state).
    pub fn next_gaussian(&mut self) -> f64 {
        let u1 = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn next_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// The xoshiro256** `jump()` — advances the stream by 2^128 steps.
    /// Used to split non-overlapping sub-streams.
    pub fn jump(&mut self) {
        const JUMP: [u64; 4] = [
            0x180EC6D33CFD0ABA,
            0xD5A61266F0C9392C,
            0xA9582618E03FC9AA,
            0x39ABDC4529B1661C,
        ];
        let mut s0 = 0u64;
        let mut s1 = 0u64;
        let mut s2 = 0u64;
        let mut s3 = 0u64;
        for &jump in JUMP.iter() {
            for b in 0..64 {
                if (jump & (1u64 << b)) != 0 {
                    s0 ^= self.s[0];
                    s1 ^= self.s[1];
                    s2 ^= self.s[2];
                    s3 ^= self.s[3];
                }
                self.next_u64();
            }
        }
        self.s = [s0, s1, s2, s3];
    }

    /// Fork an independent, non-overlapping child stream and advance self.
    pub fn fork(&mut self) -> Rng {
        let child = self.clone();
        self.jump();
        child
    }

    /// Snapshot the raw 256-bit state, e.g. to ship a forked stream to
    /// another process (`transport::frame::Assignment`).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Self::state`] snapshot. An all-zero
    /// state (a xoshiro fixed point) is replaced by a seeded one so the
    /// generator can never get stuck.
    pub fn from_state(s: [u64; 4]) -> Rng {
        if s.iter().all(|&x| x == 0) {
            return Rng::new(0);
        }
        Rng { s }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (Floyd's algorithm for
    /// small k, shuffle for large k).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 >= n {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        } else {
            // Floyd's: O(k) expected.
            let mut chosen = std::collections::HashSet::with_capacity(k);
            let mut out = Vec::with_capacity(k);
            for j in (n - k)..n {
                let t = self.next_below(j + 1);
                let pick = if chosen.contains(&t) { j } else { t };
                chosen.insert(pick);
                out.push(pick);
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference values for seed 1234567 from the public-domain
        // splitmix64.c by Sebastiano Vigna.
        let mut sm = SplitMix64::new(1234567);
        let v: Vec<u64> = (0..3).map(|_| sm.next_u64()).collect();
        assert_eq!(v[0], 6457827717110365317);
        assert_eq!(v[1], 3203168211198807973);
        assert_eq!(v[2], 9817491932198370423);
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        let mut c = Rng::new(43);
        let va: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..16).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.next_below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(11);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            let x = r.next_range(3, 5);
            assert!((3..=5).contains(&x));
            lo_seen |= x == 3;
            hi_seen |= x == 5;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.next_gaussian();
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn fork_streams_disjoint_prefix() {
        let mut root = Rng::new(5);
        let mut a = root.fork();
        let mut b = root.fork();
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn state_round_trip_resumes_the_stream() {
        let mut a = Rng::new(23);
        for _ in 0..5 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_eq!(va, vb);
        // The all-zero fixed point is rejected, not propagated.
        let mut z = Rng::from_state([0; 4]);
        assert_ne!(z.next_u64(), 0);
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(19);
        for &(n, k) in &[(100usize, 5usize), (100, 90), (1, 1), (50, 50)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k, "distinct");
            assert!(s.iter().all(|&i| i < n));
        }
    }
}
