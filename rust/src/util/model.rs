//! Exhaustive interleaving explorer — a vendored, dependency-free
//! stand-in for `loom`.
//!
//! The container this repo builds in is offline, so the real `loom`
//! crate cannot be added. This module implements the part of loom the
//! ISSUE's invariants need: **exhaustive exploration of every
//! interleaving of a small set of model threads**, where each thread is
//! a deterministic state machine whose [`ModelThread::step`] performs
//! one *atomic* action on the shared state.
//!
//! How this relates to the real code:
//!
//! * The model threads in `tests/loom_*.rs` are line-by-line
//!   transcriptions of the protocols in `util/atomic_vec.rs`
//!   (CAS add / wild add), `util/pool.rs` (generation handshake), and
//!   `util/sync.rs` (`Mailbox` handoff) — each model cites the lines it
//!   transcribes. The `xtask lint` wall keeps the real code's atomics
//!   enumerable (they may only live behind the `util::sync` façade), so
//!   the transcription stays auditable.
//! * Because the explorer serializes steps, every exploration is a
//!   sequentially-consistent execution. For the single-location
//!   `Relaxed` protocols modeled here (per-cell CAS/store, one mutex)
//!   coherence order per location is all that matters, so SC
//!   exploration is faithful. Cross-location `Relaxed` reordering is
//!   *not* modeled — that is exactly the staleness the algorithm
//!   tolerates by design (paper Assumption 1, bounded delay), and the
//!   README's "Correctness & static analysis" section spells out the
//!   boundary.
//!
//! The explorer is depth-first with schedule replay: each execution
//! re-creates the model from scratch via the caller's factory, replays
//! the chosen schedule prefix, then extends it greedily, recording the
//! untried alternatives at every choice point. Deterministic models
//! make replay exact. Deadlocks (no runnable thread while some are
//! unfinished) panic with the offending schedule.

/// Result of one model-thread step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Step {
    /// The thread performed one atomic action and has more to do.
    Ran,
    /// The thread performed its final action (or had nothing to do).
    Done,
}

/// One deterministic thread of a model.
///
/// The explorer only calls [`step`](Self::step) when
/// [`ready`](Self::ready) returns `true`; a thread parked on a model
/// mutex/condvar reports not-ready instead of spinning, which keeps the
/// schedule space finite and makes deadlocks detectable.
pub trait ModelThread<S> {
    /// May this thread take a step in the current shared state?
    fn ready(&self, shared: &S) -> bool {
        let _ = shared;
        true
    }

    /// Perform exactly one atomic action. Must be deterministic given
    /// `shared` and the thread's own state.
    fn step(&mut self, shared: &mut S) -> Step;
}

/// Statistics from an exhaustive exploration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Explored {
    /// Number of complete executions (distinct schedules) explored.
    pub executions: usize,
    /// Length of the longest schedule seen.
    pub max_depth: usize,
}

/// Safety valve: a single execution longer than this panics. Real
/// models here are < 100 steps; hitting the cap means a model livelock
/// (e.g. a retry loop that the modeled protocol cannot exit).
const STEP_CAP: usize = 4096;

/// Exhaustively explore every interleaving of the model produced by
/// `factory`. `on_complete` runs at the end of each execution with the
/// final shared state — assert invariants there (it panicking fails the
/// test with the schedule visible in the backtrace via `RUST_BACKTRACE`).
///
/// Panics on deadlock: some thread unfinished, none ready.
pub fn explore<S>(
    factory: &mut dyn FnMut() -> (S, Vec<Box<dyn ModelThread<S>>>),
    on_complete: &mut dyn FnMut(&S),
) -> Explored {
    let mut prefix: Vec<usize> = Vec::new();
    // alts[d] = thread choices at depth d not yet explored.
    let mut alts: Vec<Vec<usize>> = Vec::new();
    let mut executions = 0usize;
    let mut max_depth = 0usize;

    loop {
        executions += 1;
        let (mut shared, mut threads) = factory();
        let mut done = vec![false; threads.len()];

        // Replay the committed prefix (deterministic ⇒ identical run).
        for &t in &prefix {
            debug_assert!(!done[t] && threads[t].ready(&shared));
            if threads[t].step(&mut shared) == Step::Done {
                done[t] = true;
            }
        }

        // Extend greedily, recording alternatives at each new depth.
        loop {
            let runnable: Vec<usize> = (0..threads.len())
                .filter(|&t| !done[t] && threads[t].ready(&shared))
                .collect();
            match runnable.split_first() {
                None => {
                    if done.iter().all(|&d| d) {
                        break; // execution complete
                    }
                    let stuck: Vec<usize> =
                        (0..threads.len()).filter(|&t| !done[t]).collect();
                    panic!(
                        "model deadlock: threads {stuck:?} blocked, schedule {prefix:?}"
                    );
                }
                Some((&first, rest)) => {
                    assert!(
                        prefix.len() < STEP_CAP,
                        "model livelock: schedule exceeded {STEP_CAP} steps"
                    );
                    alts.push(rest.to_vec());
                    prefix.push(first);
                    if threads[first].step(&mut shared) == Step::Done {
                        done[first] = true;
                    }
                }
            }
        }

        max_depth = max_depth.max(prefix.len());
        on_complete(&shared);

        // Backtrack to the deepest choice point with an untried branch.
        loop {
            match alts.pop() {
                None => return Explored { executions, max_depth },
                Some(mut rest) => {
                    prefix.pop();
                    if !rest.is_empty() {
                        let next = rest.remove(0);
                        prefix.push(next);
                        alts.push(rest);
                        break;
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Model lock / condvar building blocks.
//
// These let a model transcribe Mutex/Condvar protocols (WorkPool,
// Mailbox) without busy-waiting: a thread that would block reports
// not-ready via these helpers, so the explorer never schedules it and
// deadlocks surface as "no runnable thread".
// ---------------------------------------------------------------------

/// A mutex modeled as "which thread holds it". Acquisition is one
/// explorer step; contention is expressed by `ready()` gating on
/// [`ModelMutex::free`].
#[derive(Debug, Default)]
pub struct ModelMutex {
    holder: Option<usize>,
}

impl ModelMutex {
    pub fn new() -> Self {
        ModelMutex { holder: None }
    }

    /// Is the lock available (for `ready()` checks)?
    pub fn free(&self) -> bool {
        self.holder.is_none()
    }

    pub fn held_by(&self, who: usize) -> bool {
        self.holder == Some(who)
    }

    /// Take the lock. Callers gate on [`free`](Self::free) in `ready`.
    pub fn lock(&mut self, who: usize) {
        assert!(self.holder.is_none(), "thread {who} locking a held ModelMutex");
        self.holder = Some(who);
    }

    pub fn unlock(&mut self, who: usize) {
        assert_eq!(
            self.holder,
            Some(who),
            "thread {who} unlocking a ModelMutex it does not hold"
        );
        self.holder = None;
    }
}

/// A condvar modeled as a parked-thread bitmask. `wait` = park +
/// release the paired mutex (one atomic step, like the real condvar);
/// `notify_all` unparks everyone — woken threads still re-acquire the
/// mutex before their next step, exactly like `Condvar::wait` returning.
///
/// Spurious wakeups are not modeled; none of the transcribed protocols
/// distinguish them from a real wake (all re-check their predicate in a
/// loop), which the loom tests assert structurally by construction.
#[derive(Debug, Default)]
pub struct ModelCondvar {
    parked: u64,
}

impl ModelCondvar {
    pub fn new() -> Self {
        ModelCondvar { parked: 0 }
    }

    /// Park `who` and release `lock` in one step.
    pub fn wait(&mut self, who: usize, lock: &mut ModelMutex) {
        assert!(who < 64);
        self.parked |= 1 << who;
        lock.unlock(who);
    }

    /// Is `who` currently parked (i.e. not ready)?
    pub fn is_parked(&self, who: usize) -> bool {
        self.parked & (1 << who) != 0
    }

    /// Unpark every waiter (they still contend on the mutex).
    pub fn notify_all(&mut self) {
        self.parked = 0;
    }

    /// Unpark the lowest-indexed waiter. With a single possible waiter
    /// (the Mailbox receiver) this is exact; with several it picks one
    /// deterministically, which under-approximates `notify_one`'s
    /// nondeterminism — use `notify_all` for multi-waiter protocols.
    pub fn notify_one(&mut self) {
        if self.parked != 0 {
            self.parked &= self.parked - 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two threads, two independent steps each: the explorer must visit
    /// exactly C(4,2) = 6 interleavings.
    #[test]
    fn counts_interleavings_exactly() {
        struct TwoSteps {
            left: usize,
        }
        impl ModelThread<u64> for TwoSteps {
            fn step(&mut self, shared: &mut u64) -> Step {
                *shared += 1;
                self.left -= 1;
                if self.left == 0 {
                    Step::Done
                } else {
                    Step::Ran
                }
            }
        }
        let stats = explore(
            &mut || {
                (
                    0u64,
                    vec![
                        Box::new(TwoSteps { left: 2 }) as Box<dyn ModelThread<u64>>,
                        Box::new(TwoSteps { left: 2 }),
                    ],
                )
            },
            &mut |&total| assert_eq!(total, 4),
        );
        assert_eq!(stats.executions, 6);
        assert_eq!(stats.max_depth, 4);
    }

    /// A racy load-then-store increment (the "wild" shape): exploration
    /// must find both the lost-update outcome (1) and the clean one (2).
    #[test]
    fn finds_lost_update_in_racy_increment() {
        #[derive(Default)]
        struct Racy {
            seen: Option<u64>,
        }
        impl ModelThread<u64> for Racy {
            fn step(&mut self, shared: &mut u64) -> Step {
                match self.seen {
                    None => {
                        self.seen = Some(*shared); // load
                        Step::Ran
                    }
                    Some(v) => {
                        *shared = v + 1; // store
                        Step::Done
                    }
                }
            }
        }
        let mut outcomes = std::collections::BTreeSet::new();
        explore(
            &mut || {
                (
                    0u64,
                    vec![
                        Box::new(Racy::default()) as Box<dyn ModelThread<u64>>,
                        Box::new(Racy::default()),
                    ],
                )
            },
            &mut |&v| {
                outcomes.insert(v);
            },
        );
        assert_eq!(outcomes.into_iter().collect::<Vec<_>>(), vec![1, 2]);
    }

    /// A CAS retry-loop increment (the atomic-add shape): every
    /// interleaving must end at exactly 2 — no lost updates.
    #[test]
    fn cas_increment_never_loses() {
        #[derive(Default)]
        struct Cas {
            seen: Option<u64>,
        }
        impl ModelThread<u64> for Cas {
            fn step(&mut self, shared: &mut u64) -> Step {
                match self.seen {
                    None => {
                        self.seen = Some(*shared);
                        Step::Ran
                    }
                    Some(v) => {
                        if *shared == v {
                            *shared = v + 1; // CAS success
                            Step::Done
                        } else {
                            self.seen = Some(*shared); // CAS failure: reload
                            Step::Ran
                        }
                    }
                }
            }
        }
        let stats = explore(
            &mut || {
                (
                    0u64,
                    vec![
                        Box::new(Cas::default()) as Box<dyn ModelThread<u64>>,
                        Box::new(Cas::default()),
                    ],
                )
            },
            &mut |&v| assert_eq!(v, 2),
        );
        assert!(stats.executions >= 6);
    }

    /// Classic AB/BA lock ordering: the explorer must find the deadlock.
    #[test]
    fn detects_lock_order_deadlock() {
        struct Locks {
            a: ModelMutex,
            b: ModelMutex,
        }
        /// Locks `first` then `second`, then releases both.
        struct Grabber {
            order: [bool; 2], // true = lock A at that stage
            stage: usize,
        }
        impl ModelThread<Locks> for Grabber {
            fn ready(&self, s: &Locks) -> bool {
                match self.stage {
                    0 | 1 => {
                        let want_a = self.order[self.stage];
                        if want_a {
                            s.a.free()
                        } else {
                            s.b.free()
                        }
                    }
                    _ => true,
                }
            }
            fn step(&mut self, s: &mut Locks) -> Step {
                let me = self.order[0] as usize; // distinct ids: 1 and 0
                match self.stage {
                    0 | 1 => {
                        if self.order[self.stage] {
                            s.a.lock(me);
                        } else {
                            s.b.lock(me);
                        }
                        self.stage += 1;
                        Step::Ran
                    }
                    _ => {
                        s.a.unlock(me);
                        s.b.unlock(me);
                        Step::Done
                    }
                }
            }
        }
        let r = std::panic::catch_unwind(|| {
            explore(
                &mut || {
                    (
                        Locks { a: ModelMutex::new(), b: ModelMutex::new() },
                        vec![
                            Box::new(Grabber { order: [true, false], stage: 0 })
                                as Box<dyn ModelThread<Locks>>,
                            Box::new(Grabber { order: [false, true], stage: 0 }),
                        ],
                    )
                },
                &mut |_| {},
            )
        });
        let msg = *r.expect_err("AB/BA must deadlock").downcast::<String>().unwrap();
        assert!(msg.contains("model deadlock"), "unexpected panic: {msg}");
    }

    /// Park/notify round trip through the condvar helper terminates in
    /// every interleaving.
    #[test]
    fn condvar_wait_notify_terminates() {
        struct S {
            lock: ModelMutex,
            cv: ModelCondvar,
            flag: bool,
        }
        /// Waiter (id 0): lock; while !flag wait; unlock.
        struct Waiter {
            stage: usize,
        }
        impl ModelThread<S> for Waiter {
            fn ready(&self, s: &S) -> bool {
                match self.stage {
                    0 => s.lock.free(),            // first acquisition
                    1 => true,                     // holds the lock
                    _ => !s.cv.is_parked(0) && s.lock.free(), // re-acquire after wake
                }
            }
            fn step(&mut self, s: &mut S) -> Step {
                match self.stage {
                    0 => {
                        s.lock.lock(0);
                        self.stage = 1;
                        Step::Ran
                    }
                    1 => {
                        if s.flag {
                            s.lock.unlock(0);
                            Step::Done
                        } else {
                            s.cv.wait(0, &mut s.lock);
                            self.stage = 2;
                            Step::Ran
                        }
                    }
                    _ => {
                        // Woken: re-acquire then re-check the predicate.
                        if s.lock.held_by(0) {
                            unreachable!()
                        }
                        s.lock.lock(0);
                        self.stage = 1;
                        Step::Ran
                    }
                }
            }
        }
        /// Notifier (id 1): lock; flag = true; notify; unlock.
        struct Notifier {
            stage: usize,
        }
        impl ModelThread<S> for Notifier {
            fn ready(&self, s: &S) -> bool {
                self.stage != 0 || s.lock.free()
            }
            fn step(&mut self, s: &mut S) -> Step {
                match self.stage {
                    0 => {
                        s.lock.lock(1);
                        s.flag = true;
                        self.stage = 1;
                        Step::Ran
                    }
                    _ => {
                        s.cv.notify_all();
                        s.lock.unlock(1);
                        Step::Done
                    }
                }
            }
        }
        let stats = explore(
            &mut || {
                (
                    S { lock: ModelMutex::new(), cv: ModelCondvar::new(), flag: false },
                    vec![
                        Box::new(Waiter { stage: 0 }) as Box<dyn ModelThread<S>>,
                        Box::new(Notifier { stage: 0 }),
                    ],
                )
            },
            &mut |s| assert!(s.flag && s.lock.free()),
        );
        assert!(stats.executions >= 2);
    }
}
