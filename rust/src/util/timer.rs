//! Wall-clock timing helpers and a tiny statistics toolkit used by the
//! bench harness (criterion is unavailable offline, so `benches/` are
//! `harness = false` binaries built on these primitives).

use std::time::{Duration, Instant};

/// A simple monotonic stopwatch.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Summary statistics over a sample of measurements.
#[derive(Debug, Clone, PartialEq)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub max: f64,
}

impl Stats {
    pub fn from(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "Stats::from(empty)");
        let n = samples.len();
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let pct = |q: f64| -> f64 {
            let pos = q * (n - 1) as f64;
            let lo = pos.floor() as usize;
            let hi = pos.ceil() as usize;
            if lo == hi {
                sorted[lo]
            } else {
                let frac = pos - lo as f64;
                sorted[lo] * (1.0 - frac) + sorted[hi] * frac
            }
        };
        Stats {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: pct(0.5),
            p90: pct(0.9),
            max: sorted[n - 1],
        }
    }
}

/// Measure a closure `iters` times after `warmup` unmeasured calls.
/// Returns per-call seconds.
pub fn measure<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        out.push(t.elapsed().as_secs_f64());
    }
    out
}

/// Human-readable duration formatting for bench tables.
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_known_values() {
        let s = Stats::from(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        assert!((s.std - 2f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn stats_percentile_interpolates() {
        let s = Stats::from(&[0.0, 10.0]);
        assert_eq!(s.p50, 5.0);
        assert!((s.p90 - 9.0).abs() < 1e-12);
    }

    #[test]
    fn measure_counts() {
        let mut calls = 0usize;
        let samples = measure(2, 5, || calls += 1);
        assert_eq!(samples.len(), 5);
        assert_eq!(calls, 7);
        assert!(samples.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn fmt_duration_ranges() {
        assert_eq!(fmt_duration(2.5), "2.500 s");
        assert_eq!(fmt_duration(0.0025), "2.500 ms");
        assert_eq!(fmt_duration(2.5e-6), "2.500 µs");
        assert_eq!(fmt_duration(2.5e-9), "2.5 ns");
    }

    #[test]
    fn stopwatch_monotonic() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        let e = sw.restart();
        assert!(e.as_secs_f64() > 0.0);
        assert!(sw.elapsed_secs() < e.as_secs_f64() + 1.0);
    }
}
