//! Foundation utilities built from scratch for the offline environment:
//! deterministic RNG, lock-free atomic f64 vectors, timers/statistics,
//! a leveled logger, and a miniature property-testing framework.

pub mod atomic_vec;
pub mod json;
pub mod logging;
pub mod model;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod sync;
pub mod timer;

pub use atomic_vec::AtomicF64Vec;
pub use pool::WorkPool;
pub use rng::Rng;
pub use timer::{measure, Stats, Stopwatch};

/// Dense dot product (used on snapshots / dense vectors).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Squared L2 norm.
#[inline]
pub fn norm_sq(a: &[f64]) -> f64 {
    a.iter().map(|x| x * x).sum()
}

/// `y += a * x` over dense slices.
#[inline]
pub fn axpy(y: &mut [f64], a: f64, x: &[f64]) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// Clamp helper matching the paper's projection `clip(a, 0, 1)`.
#[inline]
pub fn clip(x: f64, lo: f64, hi: f64) -> f64 {
    x.max(lo).min(hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_linalg() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        assert_eq!(dot(&a, &b), 32.0);
        assert_eq!(norm_sq(&a), 14.0);
        let mut y = [1.0, 1.0, 1.0];
        axpy(&mut y, 2.0, &a);
        assert_eq!(y, [3.0, 5.0, 7.0]);
    }

    #[test]
    fn clip_bounds() {
        assert_eq!(clip(-0.5, 0.0, 1.0), 0.0);
        assert_eq!(clip(0.5, 0.0, 1.0), 0.5);
        assert_eq!(clip(1.5, 0.0, 1.0), 1.0);
    }
}
