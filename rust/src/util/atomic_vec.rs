//! Lock-free shared `f64` vector — the heart of the PassCoDe-style local
//! solver (paper §3.1, Algorithm 1 line 9).
//!
//! The paper maintains the shared primal estimate `v ∈ R^d` in each node's
//! shared memory and has every core-thread apply
//! `v ← v + (1/λn) ε x_i` with *atomic memory operations instead of
//! costly locks* (Hsieh et al. 2015). Rust has no `AtomicF64`; we store
//! the bits in `AtomicU64` and implement `fetch_add` as a CAS loop.
//!
//! Two write modes mirror the paper's discussion:
//!
//! * [`AtomicF64Vec::add`] — the lock-free *atomic* mode: a
//!   compare-exchange loop that never loses an update (PassCoDe-Atomic).
//! * [`AtomicF64Vec::add_wild`] — the *wild* mode (PassCoDe-Wild): a
//!   racy read-modify-write expressed as relaxed load + relaxed store.
//!   Concurrent writers may overwrite each other; the paper shows the
//!   algorithm still converges to a nearby solution. (In Rust we must
//!   still use atomic instructions to avoid UB — what is "wild" is the
//!   loss of read-modify-write atomicity, which is exactly the race the
//!   paper describes.)

use std::sync::atomic::{AtomicU64, Ordering};

/// A fixed-size vector of `f64` supporting concurrent lock-free updates.
pub struct AtomicF64Vec {
    data: Vec<AtomicU64>,
}

impl AtomicF64Vec {
    /// Zero-initialized vector of length `len`.
    pub fn zeros(len: usize) -> Self {
        let mut data = Vec::with_capacity(len);
        for _ in 0..len {
            data.push(AtomicU64::new(0f64.to_bits()));
        }
        Self { data }
    }

    /// Build from an existing slice.
    pub fn from_slice(xs: &[f64]) -> Self {
        Self {
            data: xs.iter().map(|&x| AtomicU64::new(x.to_bits())).collect(),
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Relaxed atomic load of one element. Relaxed is sufficient: the
    /// algorithm tolerates bounded-staleness reads by design
    /// (Assumption 1, bounded delay γ).
    #[inline(always)]
    pub fn load(&self, i: usize) -> f64 {
        f64::from_bits(self.data[i].load(Ordering::Relaxed))
    }

    /// Lock-free `v[i] += delta` via CAS loop (never loses an update).
    #[inline(always)]
    pub fn add(&self, i: usize, delta: f64) {
        let cell = &self.data[i];
        let mut cur = cell.load(Ordering::Relaxed);
        loop {
            let new = (f64::from_bits(cur) + delta).to_bits();
            match cell.compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return,
                Err(actual) => cur = actual,
            }
        }
    }

    /// Racy "wild" add: relaxed load + independent relaxed store.
    /// Concurrent adds to the same index may be lost (but never torn).
    #[inline(always)]
    pub fn add_wild(&self, i: usize, delta: f64) {
        let cell = &self.data[i];
        let cur = f64::from_bits(cell.load(Ordering::Relaxed));
        cell.store((cur + delta).to_bits(), Ordering::Relaxed);
    }

    /// Atomic store.
    #[inline]
    pub fn store(&self, i: usize, value: f64) {
        self.data[i].store(value.to_bits(), Ordering::Relaxed);
    }

    /// Snapshot the whole vector into a `Vec<f64>`. Not linearizable
    /// across elements — callers use this only at quiescent points
    /// (between rounds), matching the algorithm's barrier semantics.
    pub fn snapshot(&self) -> Vec<f64> {
        self.data
            .iter()
            .map(|c| f64::from_bits(c.load(Ordering::Relaxed)))
            .collect()
    }

    /// Overwrite the whole vector from a slice (quiescent points only).
    pub fn copy_from(&self, xs: &[f64]) {
        assert_eq!(xs.len(), self.data.len());
        for (c, &x) in self.data.iter().zip(xs) {
            c.store(x.to_bits(), Ordering::Relaxed);
        }
    }

    /// Set every element to zero.
    pub fn fill_zero(&self) {
        let z = 0f64.to_bits();
        for c in &self.data {
            c.store(z, Ordering::Relaxed);
        }
    }

    /// Sparse dot product `Σ_j vals[j] * v[idx[j]]` with relaxed loads.
    /// This is the hot read in the coordinate step: `x_iᵀ v`.
    #[inline]
    pub fn sparse_dot(&self, idx: &[u32], vals: &[f64]) -> f64 {
        debug_assert_eq!(idx.len(), vals.len());
        let mut acc = 0.0;
        for (&j, &x) in idx.iter().zip(vals.iter()) {
            acc += x * self.load(j as usize);
        }
        acc
    }

    /// Sparse axpy `v[idx[j]] += a * vals[j]` using the CAS add.
    #[inline]
    pub fn sparse_axpy(&self, a: f64, idx: &[u32], vals: &[f64]) {
        debug_assert_eq!(idx.len(), vals.len());
        for (&j, &x) in idx.iter().zip(vals.iter()) {
            self.add(j as usize, a * x);
        }
    }

    /// Sparse axpy in wild (racy) mode.
    #[inline]
    pub fn sparse_axpy_wild(&self, a: f64, idx: &[u32], vals: &[f64]) {
        debug_assert_eq!(idx.len(), vals.len());
        for (&j, &x) in idx.iter().zip(vals.iter()) {
            self.add_wild(j as usize, a * x);
        }
    }
}

impl std::fmt::Debug for AtomicF64Vec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "AtomicF64Vec(len={})", self.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn basic_ops() {
        let v = AtomicF64Vec::zeros(4);
        v.add(0, 1.5);
        v.add(0, 2.5);
        v.store(1, -3.0);
        assert_eq!(v.load(0), 4.0);
        assert_eq!(v.load(1), -3.0);
        assert_eq!(v.snapshot(), vec![4.0, -3.0, 0.0, 0.0]);
    }

    #[test]
    fn from_slice_and_copy_from() {
        let v = AtomicF64Vec::from_slice(&[1.0, 2.0]);
        assert_eq!(v.snapshot(), vec![1.0, 2.0]);
        v.copy_from(&[5.0, 6.0]);
        assert_eq!(v.snapshot(), vec![5.0, 6.0]);
        v.fill_zero();
        assert_eq!(v.snapshot(), vec![0.0, 0.0]);
    }

    #[test]
    fn sparse_ops() {
        let v = AtomicF64Vec::from_slice(&[1.0, 2.0, 3.0, 4.0]);
        let idx = [0u32, 2];
        let vals = [10.0, 100.0];
        assert_eq!(v.sparse_dot(&idx, &vals), 10.0 + 300.0);
        v.sparse_axpy(2.0, &idx, &vals);
        assert_eq!(v.snapshot(), vec![21.0, 2.0, 203.0, 4.0]);
    }

    /// The core guarantee: concurrent CAS adds lose nothing, matching the
    /// serial sum exactly in the absence of rounding ambiguity (we use
    /// integers stored as f64 so fp addition is exact).
    #[test]
    fn concurrent_adds_sum_exactly() {
        let v = Arc::new(AtomicF64Vec::zeros(8));
        let threads = 4;
        let per_thread = 10_000;
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let v = Arc::clone(&v);
                std::thread::spawn(move || {
                    for k in 0..per_thread {
                        v.add(k % 8, 1.0);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let total: f64 = v.snapshot().iter().sum();
        assert_eq!(total, (threads * per_thread) as f64);
    }

    /// Wild mode may lose updates under contention but must never tear:
    /// every observed value is a valid partial sum (an integer here).
    #[test]
    fn wild_adds_no_tearing() {
        let v = Arc::new(AtomicF64Vec::zeros(1));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let v = Arc::clone(&v);
                std::thread::spawn(move || {
                    for _ in 0..5_000 {
                        v.add_wild(0, 1.0);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let x = v.load(0);
        assert!(x > 0.0 && x <= 20_000.0 && x.fract() == 0.0, "x={x}");
    }
}
