//! Lock-free shared `f64` vector — the heart of the PassCoDe-style local
//! solver (paper §3.1, Algorithm 1 line 9).
//!
//! The paper maintains the shared primal estimate `v ∈ R^d` in each node's
//! shared memory and has every core-thread apply
//! `v ← v + (1/λn) ε x_i` with *atomic memory operations instead of
//! costly locks* (Hsieh et al. 2015). Rust has no `AtomicF64`; we store
//! the bits in `AtomicU64` and implement `fetch_add` as a CAS loop.
//!
//! Two write modes mirror the paper's discussion:
//!
//! * [`AtomicF64Vec::add`] — the lock-free *atomic* mode: a
//!   compare-exchange loop that never loses an update (PassCoDe-Atomic).
//! * [`AtomicF64Vec::add_wild`] — the *wild* mode (PassCoDe-Wild): a
//!   racy read-modify-write expressed as relaxed load + relaxed store.
//!   Concurrent writers may overwrite each other; the paper shows the
//!   algorithm still converges to a nearby solution. (In Rust we must
//!   still use atomic instructions to avoid UB — what is "wild" is the
//!   loss of read-modify-write atomicity, which is exactly the race the
//!   paper describes.)

use crate::util::sync::{AtomicU64, Ordering};

// ORDERING: every operation in this file is `Relaxed`, deliberately.
// The solver's correctness argument (paper Assumption 1: bounded-delay
// reads; PassCoDe's atomic/wild analysis) only needs per-cell coherence
// — each `v[i]` cell's modification order — never cross-location
// ordering. Readers tolerate stale values by design, and the quiescent
// points where exact snapshots matter (between rounds) are separated by
// thread joins / the WorkPool completion barrier, whose mutex provides
// the happens-before edge. Anything stronger than `Relaxed` here would
// fence the hottest loop in the crate (18M updates/s, BENCH_hot_loop)
// for no algorithmic benefit. `tests/loom_atomic_vec.rs` model-checks
// the CAS and wild protocols under every 2-thread interleaving.

/// A fixed-size vector of `f64` supporting concurrent lock-free updates.
pub struct AtomicF64Vec {
    data: Vec<AtomicU64>,
}

impl AtomicF64Vec {
    /// Zero-initialized vector of length `len`.
    pub fn zeros(len: usize) -> Self {
        let mut data = Vec::with_capacity(len);
        for _ in 0..len {
            data.push(AtomicU64::new(0f64.to_bits()));
        }
        Self { data }
    }

    /// Build from an existing slice.
    pub fn from_slice(xs: &[f64]) -> Self {
        Self {
            data: xs.iter().map(|&x| AtomicU64::new(x.to_bits())).collect(),
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Relaxed atomic load of one element. Relaxed is sufficient: the
    /// algorithm tolerates bounded-staleness reads by design
    /// (Assumption 1, bounded delay γ).
    #[inline(always)]
    pub fn load(&self, i: usize) -> f64 {
        f64::from_bits(self.data[i].load(Ordering::Relaxed))
    }

    /// Relaxed load without the bounds check.
    ///
    /// # Safety
    /// `i < self.len()` must hold.
    #[inline(always)]
    pub unsafe fn load_unchecked(&self, i: usize) -> f64 {
        debug_assert!(i < self.len());
        f64::from_bits(self.data.get_unchecked(i).load(Ordering::Relaxed))
    }

    /// Lock-free `v[i] += delta` via CAS loop (never loses an update).
    #[inline(always)]
    pub fn add(&self, i: usize, delta: f64) {
        let cell = &self.data[i];
        let mut cur = cell.load(Ordering::Relaxed);
        loop {
            let new = (f64::from_bits(cur) + delta).to_bits();
            match cell.compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return,
                Err(actual) => cur = actual,
            }
        }
    }

    /// Racy "wild" add: relaxed load + independent relaxed store.
    /// Concurrent adds to the same index may be lost (but never torn).
    #[inline(always)]
    pub fn add_wild(&self, i: usize, delta: f64) {
        let cell = &self.data[i];
        let cur = f64::from_bits(cell.load(Ordering::Relaxed));
        cell.store((cur + delta).to_bits(), Ordering::Relaxed);
    }

    /// Atomic store.
    #[inline]
    pub fn store(&self, i: usize, value: f64) {
        self.data[i].store(value.to_bits(), Ordering::Relaxed);
    }

    /// Snapshot the whole vector into a `Vec<f64>`. Not linearizable
    /// across elements — callers use this only at quiescent points
    /// (between rounds), matching the algorithm's barrier semantics.
    pub fn snapshot(&self) -> Vec<f64> {
        self.data
            .iter()
            .map(|c| f64::from_bits(c.load(Ordering::Relaxed)))
            .collect()
    }

    /// [`Self::snapshot`] into a caller-owned buffer, so eval loops
    /// reuse one allocation across rounds (quiescent points only).
    pub fn snapshot_into(&self, out: &mut [f64]) {
        assert_eq!(out.len(), self.data.len());
        for (o, c) in out.iter_mut().zip(&self.data) {
            *o = f64::from_bits(c.load(Ordering::Relaxed));
        }
    }

    /// Overwrite the whole vector from a slice (quiescent points only).
    pub fn copy_from(&self, xs: &[f64]) {
        assert_eq!(xs.len(), self.data.len());
        for (c, &x) in self.data.iter().zip(xs) {
            c.store(x.to_bits(), Ordering::Relaxed);
        }
    }

    /// Set every element to zero.
    pub fn fill_zero(&self) {
        let z = 0f64.to_bits();
        for c in &self.data {
            c.store(z, Ordering::Relaxed);
        }
    }

    /// Sparse dot product `Σ_j vals[j] * v[idx[j]]` with relaxed loads.
    /// This is the hot read in the coordinate step: `x_iᵀ v`.
    #[inline]
    pub fn sparse_dot(&self, idx: &[u32], vals: &[f64]) -> f64 {
        debug_assert_eq!(idx.len(), vals.len());
        let mut acc = 0.0;
        for (&j, &x) in idx.iter().zip(vals.iter()) {
            acc += x * self.load(j as usize);
        }
        acc
    }

    /// Sparse axpy `v[idx[j]] += a * vals[j]` using the CAS add.
    #[inline]
    pub fn sparse_axpy(&self, a: f64, idx: &[u32], vals: &[f64]) {
        debug_assert_eq!(idx.len(), vals.len());
        for (&j, &x) in idx.iter().zip(vals.iter()) {
            self.add(j as usize, a * x);
        }
    }

    /// Sparse axpy in wild (racy) mode.
    #[inline]
    pub fn sparse_axpy_wild(&self, a: f64, idx: &[u32], vals: &[f64]) {
        debug_assert_eq!(idx.len(), vals.len());
        for (&j, &x) in idx.iter().zip(vals.iter()) {
            self.add_wild(j as usize, a * x);
        }
    }

    // ---- unchecked, 4-way-unrolled hot-path kernels (§Perf) ----
    //
    // The coordinate step touches every nonzero of `x_i` twice (dot +
    // axpy); with bounds-checked element access each touch pays an
    // index compare and branch. The `*_unchecked` variants drop those
    // and unroll 4× so the loop overhead amortizes across iterations.
    // Accumulation order is kept identical to the scalar references
    // above, so for quiescent vectors the results are bitwise equal —
    // `tests/prop_kernels.rs` pins that equivalence.

    /// Unchecked, unrolled sparse dot `Σ_j vals[j] · v[idx[j]]` with
    /// relaxed loads. Bitwise-identical to [`Self::sparse_dot`] (single
    /// accumulator, same add order).
    ///
    /// # Safety
    /// Every index in `idx` must be `< self.len()`, and
    /// `idx.len() == vals.len()` must hold.
    #[inline]
    pub unsafe fn sparse_dot_unchecked(&self, idx: &[u32], vals: &[f64]) -> f64 {
        debug_assert_eq!(idx.len(), vals.len());
        debug_assert!(idx.iter().all(|&j| (j as usize) < self.len()));
        let n = idx.len();
        let mut acc = 0.0;
        let mut k = 0;
        while k + 4 <= n {
            let v0 = self.load_unchecked(*idx.get_unchecked(k) as usize);
            let v1 = self.load_unchecked(*idx.get_unchecked(k + 1) as usize);
            let v2 = self.load_unchecked(*idx.get_unchecked(k + 2) as usize);
            let v3 = self.load_unchecked(*idx.get_unchecked(k + 3) as usize);
            acc += *vals.get_unchecked(k) * v0;
            acc += *vals.get_unchecked(k + 1) * v1;
            acc += *vals.get_unchecked(k + 2) * v2;
            acc += *vals.get_unchecked(k + 3) * v3;
            k += 4;
        }
        while k < n {
            acc += *vals.get_unchecked(k) * self.load_unchecked(*idx.get_unchecked(k) as usize);
            k += 1;
        }
        acc
    }

    /// Unchecked CAS add of one element (see [`Self::add`]).
    ///
    /// # Safety
    /// `i < self.len()` must hold.
    #[inline(always)]
    pub unsafe fn add_unchecked(&self, i: usize, delta: f64) {
        debug_assert!(i < self.len());
        let cell = self.data.get_unchecked(i);
        let mut cur = cell.load(Ordering::Relaxed);
        loop {
            let new = (f64::from_bits(cur) + delta).to_bits();
            match cell.compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return,
                Err(actual) => cur = actual,
            }
        }
    }

    /// Unchecked racy add of one element (see [`Self::add_wild`]).
    ///
    /// # Safety
    /// `i < self.len()` must hold.
    #[inline(always)]
    pub unsafe fn add_wild_unchecked(&self, i: usize, delta: f64) {
        debug_assert!(i < self.len());
        let cell = self.data.get_unchecked(i);
        let cur = f64::from_bits(cell.load(Ordering::Relaxed));
        cell.store((cur + delta).to_bits(), Ordering::Relaxed);
    }

    /// Unchecked, unrolled sparse axpy `v[idx[j]] += a · vals[j]` via
    /// CAS adds. Element-wise identical to [`Self::sparse_axpy`].
    ///
    /// # Safety
    /// Every index in `idx` must be `< self.len()`, and
    /// `idx.len() == vals.len()` must hold.
    #[inline]
    pub unsafe fn sparse_axpy_unchecked(&self, a: f64, idx: &[u32], vals: &[f64]) {
        debug_assert_eq!(idx.len(), vals.len());
        debug_assert!(idx.iter().all(|&j| (j as usize) < self.len()));
        let n = idx.len();
        let mut k = 0;
        while k + 4 <= n {
            self.add_unchecked(*idx.get_unchecked(k) as usize, a * *vals.get_unchecked(k));
            self.add_unchecked(*idx.get_unchecked(k + 1) as usize, a * *vals.get_unchecked(k + 1));
            self.add_unchecked(*idx.get_unchecked(k + 2) as usize, a * *vals.get_unchecked(k + 2));
            self.add_unchecked(*idx.get_unchecked(k + 3) as usize, a * *vals.get_unchecked(k + 3));
            k += 4;
        }
        while k < n {
            self.add_unchecked(*idx.get_unchecked(k) as usize, a * *vals.get_unchecked(k));
            k += 1;
        }
    }

    /// Unchecked, unrolled sparse axpy in wild (racy) mode.
    ///
    /// # Safety
    /// Every index in `idx` must be `< self.len()`, and
    /// `idx.len() == vals.len()` must hold.
    #[inline]
    pub unsafe fn sparse_axpy_wild_unchecked(&self, a: f64, idx: &[u32], vals: &[f64]) {
        debug_assert_eq!(idx.len(), vals.len());
        debug_assert!(idx.iter().all(|&j| (j as usize) < self.len()));
        let n = idx.len();
        let mut k = 0;
        while k + 4 <= n {
            let (j0, j1) = (*idx.get_unchecked(k) as usize, *idx.get_unchecked(k + 1) as usize);
            let (j2, j3) =
                (*idx.get_unchecked(k + 2) as usize, *idx.get_unchecked(k + 3) as usize);
            self.add_wild_unchecked(j0, a * *vals.get_unchecked(k));
            self.add_wild_unchecked(j1, a * *vals.get_unchecked(k + 1));
            self.add_wild_unchecked(j2, a * *vals.get_unchecked(k + 2));
            self.add_wild_unchecked(j3, a * *vals.get_unchecked(k + 3));
            k += 4;
        }
        while k < n {
            self.add_wild_unchecked(*idx.get_unchecked(k) as usize, a * *vals.get_unchecked(k));
            k += 1;
        }
    }
}

impl std::fmt::Debug for AtomicF64Vec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "AtomicF64Vec(len={})", self.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn basic_ops() {
        let v = AtomicF64Vec::zeros(4);
        v.add(0, 1.5);
        v.add(0, 2.5);
        v.store(1, -3.0);
        assert_eq!(v.load(0), 4.0);
        assert_eq!(v.load(1), -3.0);
        assert_eq!(v.snapshot(), vec![4.0, -3.0, 0.0, 0.0]);
    }

    #[test]
    fn from_slice_and_copy_from() {
        let v = AtomicF64Vec::from_slice(&[1.0, 2.0]);
        assert_eq!(v.snapshot(), vec![1.0, 2.0]);
        v.copy_from(&[5.0, 6.0]);
        assert_eq!(v.snapshot(), vec![5.0, 6.0]);
        v.fill_zero();
        assert_eq!(v.snapshot(), vec![0.0, 0.0]);
    }

    #[test]
    fn sparse_ops() {
        let v = AtomicF64Vec::from_slice(&[1.0, 2.0, 3.0, 4.0]);
        let idx = [0u32, 2];
        let vals = [10.0, 100.0];
        assert_eq!(v.sparse_dot(&idx, &vals), 10.0 + 300.0);
        v.sparse_axpy(2.0, &idx, &vals);
        assert_eq!(v.snapshot(), vec![21.0, 2.0, 203.0, 4.0]);
    }

    /// The core guarantee: concurrent CAS adds lose nothing, matching the
    /// serial sum exactly in the absence of rounding ambiguity (we use
    /// integers stored as f64 so fp addition is exact).
    #[test]
    fn concurrent_adds_sum_exactly() {
        let v = Arc::new(AtomicF64Vec::zeros(8));
        let threads = 4;
        // Miri interprets ~1000× slower; fewer iterations still drive
        // every CAS path under the UB detector.
        let per_thread = if cfg!(miri) { 50 } else { 10_000 };
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let v = Arc::clone(&v);
                std::thread::spawn(move || {
                    for k in 0..per_thread {
                        v.add(k % 8, 1.0);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let total: f64 = v.snapshot().iter().sum();
        assert_eq!(total, (threads * per_thread) as f64);
    }

    /// The unrolled/unchecked kernels are bitwise-faithful to their
    /// scalar references on quiescent vectors, across remainder lengths
    /// 0–3 of the 4-way unroll.
    #[test]
    fn unchecked_kernels_match_scalar_reference() {
        let mut rng = crate::util::Rng::new(77);
        for nnz in [0usize, 1, 2, 3, 4, 5, 7, 8, 13, 64, 65] {
            let dim = 128;
            let base: Vec<f64> = (0..dim).map(|_| rng.next_gaussian()).collect();
            let mut idx: Vec<u32> = crate::util::Rng::new(nnz as u64 + 1)
                .sample_indices(dim, nnz.min(dim))
                .into_iter()
                .map(|j| j as u32)
                .collect();
            idx.sort_unstable();
            let vals: Vec<f64> = idx.iter().map(|_| rng.next_gaussian()).collect();
            let a = rng.next_gaussian();

            let v = AtomicF64Vec::from_slice(&base);
            let dot_ref = v.sparse_dot(&idx, &vals);
            // SAFETY: `idx` was sampled from 0..dim = v.len() and
            // `vals` was built index-by-index from `idx` (equal len).
            let dot_fast = unsafe { v.sparse_dot_unchecked(&idx, &vals) };
            assert_eq!(dot_ref.to_bits(), dot_fast.to_bits(), "dot nnz={nnz}");

            let v_ref = AtomicF64Vec::from_slice(&base);
            let v_fast = AtomicF64Vec::from_slice(&base);
            v_ref.sparse_axpy(a, &idx, &vals);
            // SAFETY: same `idx`/`vals` bounds proof as the dot above.
            unsafe { v_fast.sparse_axpy_unchecked(a, &idx, &vals) };
            assert_eq!(v_ref.snapshot(), v_fast.snapshot(), "axpy nnz={nnz}");

            let w_ref = AtomicF64Vec::from_slice(&base);
            let w_fast = AtomicF64Vec::from_slice(&base);
            w_ref.sparse_axpy_wild(a, &idx, &vals);
            // SAFETY: same `idx`/`vals` bounds proof as the dot above.
            unsafe { w_fast.sparse_axpy_wild_unchecked(a, &idx, &vals) };
            assert_eq!(w_ref.snapshot(), w_fast.snapshot(), "wild axpy nnz={nnz}");
        }
    }

    /// Unchecked CAS adds keep the lock-free no-lost-update guarantee.
    #[test]
    fn concurrent_unchecked_adds_sum_exactly() {
        let v = Arc::new(AtomicF64Vec::zeros(4));
        let per_thread = if cfg!(miri) { 50 } else { 5_000 };
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let v = Arc::clone(&v);
                std::thread::spawn(move || {
                    for k in 0..per_thread {
                        // SAFETY: k % 4 < 4 = v.len().
                        unsafe { v.add_unchecked(k % 4, 1.0) };
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let total: f64 = v.snapshot().iter().sum();
        assert_eq!(total, (4 * per_thread) as f64);
    }

    /// Wild mode may lose updates under contention but must never tear:
    /// every observed value is a valid partial sum (an integer here).
    #[test]
    fn wild_adds_no_tearing() {
        let v = Arc::new(AtomicF64Vec::zeros(1));
        let per_thread = if cfg!(miri) { 50 } else { 5_000 };
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let v = Arc::clone(&v);
                std::thread::spawn(move || {
                    for _ in 0..per_thread {
                        v.add_wild(0, 1.0);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let x = v.load(0);
        assert!(x > 0.0 && x <= (4 * per_thread) as f64 && x.fract() == 0.0, "x={x}");
    }
}
