//! A miniature property-based testing framework (the `proptest` crate is
//! unavailable offline). It supports:
//!
//! * random case generation from a deterministic [`Rng`](super::rng::Rng),
//! * configurable case counts via `HYBRID_DCA_PROPTEST_CASES`,
//! * greedy shrinking of failing inputs through a user-supplied shrinker,
//! * replayable failures (the failing seed is printed).
//!
//! Usage:
//! ```ignore
//! check("partition covers", 256, gen, shrink, |case| { ...; Ok(()) });
//! ```

use super::rng::Rng;

/// Outcome of a single property evaluation.
pub type PropResult = Result<(), String>;

/// Number of cases to run (env-overridable). Under Miri the interpreter
/// runs ~3 orders of magnitude slower than native code, so the default
/// shrinks to a handful of cases — enough for the UB detector to walk
/// every code path (unsafe kernels, codec round trips) without timing
/// out CI. The env override still wins for targeted deep runs.
pub fn default_cases(fallback: usize) -> usize {
    let fallback = if cfg!(miri) { fallback.clamp(1, 4) } else { fallback };
    std::env::var("HYBRID_DCA_PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(fallback)
}

/// Run `prop` against `cases` random inputs drawn by `gen`. On failure,
/// repeatedly apply `shrink` (which proposes a list of smaller candidate
/// inputs) keeping any candidate that still fails, then panic with the
/// minimal reproduction.
pub fn check<T, G, S, P>(name: &str, cases: usize, mut gen: G, shrink: S, prop: P)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> PropResult,
{
    let seed = std::env::var("HYBRID_DCA_PROPTEST_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xC0FFEEu64);
    let mut rng = Rng::new(seed);
    for case_idx in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // Shrink.
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut improved = true;
            let mut rounds = 0;
            while improved && rounds < 200 {
                improved = false;
                rounds += 1;
                for cand in shrink(&best) {
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        improved = true;
                        break;
                    }
                }
            }
            panic!(
                "property '{name}' failed (seed={seed}, case {case_idx}/{cases}):\n  \
                 minimal input: {best:?}\n  error: {best_msg}"
            );
        }
    }
}

/// Convenience shrinker for a `Vec<T>`: tries removing halves, then
/// single elements, then shrinking individual elements.
pub fn shrink_vec<T: Clone>(xs: &[T], shrink_elem: impl Fn(&T) -> Vec<T>) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    let n = xs.len();
    if n > 0 {
        out.push(xs[..n / 2].to_vec());
        out.push(xs[n / 2..].to_vec());
        if n > 1 {
            for i in 0..n.min(8) {
                let mut v = xs.to_vec();
                v.remove(i);
                out.push(v);
            }
        }
        for i in 0..n.min(8) {
            for e in shrink_elem(&xs[i]) {
                let mut v = xs.to_vec();
                v[i] = e;
                out.push(v);
            }
        }
    }
    out
}

/// Shrink a usize towards zero.
pub fn shrink_usize(x: usize) -> Vec<usize> {
    let mut out = Vec::new();
    if x > 0 {
        out.push(0);
        out.push(x / 2);
        out.push(x - 1);
        out.dedup();
    }
    out
}

/// Shrink an f64 towards 0 and ±1.
pub fn shrink_f64(x: f64) -> Vec<f64> {
    let mut out = Vec::new();
    if x != 0.0 {
        out.push(0.0);
        out.push(x / 2.0);
        if x.abs() > 1.0 {
            out.push(x.signum());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::RefCell::new(&mut count);
        check(
            "always true",
            64,
            |r| r.next_below(100),
            |_| vec![],
            |_| {
                **counter.borrow_mut() += 1;
                Ok(())
            },
        );
        assert_eq!(count, 64);
    }

    #[test]
    #[should_panic(expected = "minimal input: 0")]
    fn failing_property_shrinks_to_minimum() {
        // Property "x > 0" fails for any x; shrinker drives it to 0.
        check(
            "x > 0",
            16,
            |r| r.next_below(1000) + 1,
            |&x| shrink_usize(x),
            |&x| if x > usize::MAX - 1 { Ok(()) } else { Err(format!("x={x} not huge")) },
        );
    }

    #[test]
    fn shrink_helpers() {
        assert!(shrink_usize(0).is_empty());
        assert_eq!(shrink_usize(10)[0], 0);
        assert!(shrink_f64(0.0).is_empty());
        assert!(shrink_f64(8.0).contains(&4.0));
        let v = shrink_vec(&[1, 2, 3, 4], |&e| shrink_usize(e));
        assert!(v.iter().any(|c| c.len() == 2));
    }
}
