//! Minimal leveled logger writing to stderr.
//!
//! The `log` crate is present in the vendor tree but a facade without an
//! implementation is useless; this 100-line logger gives us levels, a
//! global verbosity switch (env `HYBRID_DCA_LOG` or CLI `--log-level`),
//! and per-line timestamps — everything the coordinator needs to trace
//! its event flow without pulling in a heavyweight stack.

use std::time::Instant;

use crate::util::sync::{AtomicU8, Ordering};

// ORDERING: the max-level switch is an advisory flag — a logger racing
// a `set_level` call may print (or drop) one borderline line, which is
// harmless, so `Relaxed` load/store suffice.

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

/// Set the global maximum level.
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Initialize from the `HYBRID_DCA_LOG` environment variable if present.
pub fn init_from_env() {
    START.get_or_init(Instant::now);
    if let Ok(v) = std::env::var("HYBRID_DCA_LOG") {
        if let Some(l) = Level::parse(&v) {
            set_level(l);
        }
    }
}

#[inline]
pub fn enabled(level: Level) -> bool {
    (level as u8) <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Core log function; prefer the macros.
pub fn log(level: Level, module: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    eprintln!("[{t:10.4}] {} {module}: {args}", level.tag());
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_trace { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Trace, module_path!(), format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse("bogus"), None);
    }

    #[test]
    fn level_order() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }
}
