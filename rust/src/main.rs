//! `hybrid-dca` — command-line launcher for the Hybrid-DCA system.
//!
//! Subcommands:
//!
//! * `train`     — run one algorithm on a dataset, print the trace.
//!   With `--distributed --listen <addr>` it becomes the master of a
//!   multi-process cluster (workers join via the `node` subcommand).
//! * `node`      — worker role: join a distributed master and train
//!   this process's shard range until the shutdown broadcast.
//! * `gen-data`  — write a synthetic preset as a LIBSVM file.
//! * `data`      — shard store: `pack` LIBSVM text into binary CSR
//!   shards, `inspect` a packed store.
//! * `stats`     — dataset statistics (Table 1 columns).
//! * `bench`     — regenerate a paper table/figure (table1, fig3…fig7),
//!   or `bench report`: latest-vs-previous deltas over the committed
//!   `BENCH_*.json` perf trajectories.
//! * `artifacts` — list/verify the AOT artifacts.

use hybrid_dca::cli::{self, FlagSpec};
use hybrid_dca::config::{Algorithm, ExpConfig, SigmaPolicy};
use hybrid_dca::coordinator::{distributed, RunReport};
use hybrid_dca::data::{libsvm, DatasetStats, Preset, Strategy};
use hybrid_dca::harness;
use hybrid_dca::loss::LossKind;
use hybrid_dca::obs::report::kv_line;
use hybrid_dca::session::{
    self, Chain, CsvStreamObserver, DataSource, Observer, ObserverHandle, PrintObserver, Session,
};
use hybrid_dca::transport::{SocketListener, TransportBackend, TransportCfg};
use hybrid_dca::util::json::Json;
use hybrid_dca::util::{logging, Rng};

fn main() {
    logging::init_from_env();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match real_main(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn real_main(argv: &[String]) -> anyhow::Result<()> {
    let Some(cmd) = argv.first() else {
        print_usage();
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "train" => cmd_train(rest),
        "node" => cmd_node(rest),
        "gen-data" => cmd_gen_data(rest),
        "data" => cmd_data(rest),
        "stats" => cmd_stats(rest),
        "bench" => cmd_bench(rest),
        "artifacts" => cmd_artifacts(rest),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand '{other}' (try 'help')"),
    }
}

fn print_usage() {
    println!(
        "hybrid-dca — double asynchronous stochastic dual coordinate ascent\n\n\
         Subcommands:\n\
         \x20 train      run one solver (Baseline | CoCoA+ | PassCoDe | Hybrid-DCA)\n\
         \x20 node       worker role: join a distributed master (see train --distributed)\n\
         \x20 gen-data   write a synthetic preset as a LIBSVM file\n\
         \x20 data       shard store: pack LIBSVM → binary CSR shards, inspect a store\n\
         \x20 stats      dataset statistics (Table 1)\n\
         \x20 bench      regenerate a paper table/figure (table1, fig3..fig7) or 'report'\n\
         \x20 artifacts  list/verify the AOT artifacts\n\n\
         Use '<subcommand> --help' for flags."
    );
}

fn train_specs() -> Vec<FlagSpec> {
    vec![
        FlagSpec::value("config", "", "TOML config file (flags override it)"),
        FlagSpec::value("algo", "hybrid", "baseline|cocoa+|passcode|hybrid"),
        FlagSpec::value(
            "dataset",
            "tiny",
            "preset name (tiny|rcv1-s|webspam-s|kddb-s|splicesite-s)",
        ),
        FlagSpec::value("data", "", "LIBSVM file path (overrides --dataset)"),
        FlagSpec::value("store", "", "shard-store directory (see 'data pack'; overrides --data)"),
        FlagSpec::value("loss", "hinge", "hinge|squared_hinge|logistic"),
        FlagSpec::value("lambda", "1e-4", "regularization λ"),
        FlagSpec::value("nodes", "4", "worker nodes K"),
        FlagSpec::value("cores", "2", "cores per node R"),
        FlagSpec::value("h", "512", "local iterations per core per round H"),
        FlagSpec::value("s", "0", "bounded barrier S (0 = K)"),
        FlagSpec::value("gamma", "1", "bounded delay Γ"),
        FlagSpec::value("nu", "1.0", "aggregation parameter ν"),
        FlagSpec::value("sigma", "auto", "sigma policy: auto(νS)|k(νK)|<number>"),
        FlagSpec::value("rounds", "100", "max global rounds"),
        FlagSpec::value("threshold", "1e-6", "stop when duality gap below"),
        FlagSpec::value("eval-every", "1", "evaluate gap every N rounds"),
        FlagSpec::value("seed", "42", "root RNG seed"),
        FlagSpec::value("partition", "shuffled", "contiguous|striped|shuffled"),
        FlagSpec::value("stragglers", "", "profile: none|one-slow|ramp|half-slow"),
        FlagSpec::value("csv", "", "write trace CSV to this path"),
        FlagSpec::value("dump", "", "write final state (α, v, trace) as bit-exact JSON"),
        FlagSpec::switch("wild", "use racy (PassCoDe-Wild) updates"),
        FlagSpec::switch("distributed", "run as cluster master over real sockets"),
        FlagSpec::value("listen", "", "master bind address (host:port for tcp, path for uds)"),
        FlagSpec::value("transport", "tcp", "socket backend for --distributed: tcp|uds"),
        FlagSpec::value("accept-timeout", "30", "seconds to wait for all workers to join"),
        FlagSpec::value("read-timeout", "30", "seconds of peer silence before giving up"),
        FlagSpec::value(
            "suspicion",
            "4",
            "silent read-timeout ticks before a worker is declared dead (0 = never)",
        ),
        FlagSpec::value(
            "chaos",
            "",
            "fault-injection plan, e.g. \"seed=7;kill:worker=1,round=2\" (see README)",
        ),
        FlagSpec::value(
            "metrics-out",
            "",
            "write the run's metrics snapshot here (.json, else Prometheus text)",
        ),
        FlagSpec::value(
            "trace-out",
            "",
            "write a Chrome-trace timeline here (open in Perfetto / chrome://tracing)",
        ),
        FlagSpec::switch("help", "show help"),
    ]
}

/// Fold the `--distributed` socket flags into `cfg.transport`.
fn apply_transport_flags(cfg: &mut ExpConfig, args: &cli::Args) -> anyhow::Result<()> {
    let backend = args.get("transport").unwrap();
    cfg.transport.backend = TransportBackend::parse(backend)
        .ok_or_else(|| anyhow::anyhow!("unknown --transport '{backend}' (tcp|uds)"))?;
    anyhow::ensure!(
        cfg.transport.backend != TransportBackend::InProcess,
        "--distributed needs a socket backend (tcp|uds); drop --distributed to run in-process"
    );
    let listen = args.get("listen").unwrap();
    if !listen.is_empty() {
        cfg.transport.listen = listen.to_string();
    }
    anyhow::ensure!(
        !cfg.transport.listen.is_empty(),
        "--distributed requires --listen (host:port for tcp, a socket path for uds)"
    );
    cfg.transport.accept_timeout_secs = args.get_parse("accept-timeout")?;
    cfg.transport.read_timeout_secs = args.get_parse("read-timeout")?;
    cfg.transport.suspicion_timeouts = args.get_parse("suspicion")?;
    cfg.validate()
}

fn parse_train_cfg(args: &cli::Args) -> anyhow::Result<(Algorithm, ExpConfig)> {
    let algo = Algorithm::parse(args.get("algo").unwrap())
        .ok_or_else(|| anyhow::anyhow!("unknown --algo"))?;
    // Contract: with --config, the file is the single source of the
    // experiment parameters (only --algo and --csv still apply); without
    // it, the flags below define everything.
    let config_path = args.get("config").unwrap();
    if !config_path.is_empty() {
        let cfg = ExpConfig::from_file(config_path)?;
        return Ok((algo, cfg));
    }
    let mut cfg = ExpConfig::default();
    cfg.dataset = args.get("dataset").unwrap().to_string();
    let data = args.get("data").unwrap();
    let store = args.get("store").unwrap();
    anyhow::ensure!(
        data.is_empty() || store.is_empty(),
        "--data and --store are mutually exclusive"
    );
    if !data.is_empty() {
        cfg.data_path = Some(data.to_string());
    }
    if !store.is_empty() {
        cfg.store_path = Some(store.to_string());
    }
    cfg.loss = LossKind::parse(args.get("loss").unwrap())
        .ok_or_else(|| anyhow::anyhow!("unknown --loss"))?;
    cfg.lambda = args.get_parse("lambda")?;
    cfg.k_nodes = args.get_parse("nodes")?;
    cfg.r_cores = args.get_parse("cores")?;
    cfg.h_local = args.get_parse("h")?;
    let s: usize = args.get_parse("s")?;
    cfg.s_barrier = if s == 0 { cfg.k_nodes } else { s };
    cfg.gamma = args.get_parse("gamma")?;
    cfg.nu = args.get_parse("nu")?;
    cfg.sigma = SigmaPolicy::parse(args.get("sigma").unwrap())
        .ok_or_else(|| anyhow::anyhow!("bad --sigma"))?;
    cfg.max_rounds = args.get_parse("rounds")?;
    cfg.gap_threshold = args.get_parse("threshold")?;
    cfg.eval_every = args.get_parse("eval-every")?;
    cfg.seed = args.get_parse("seed")?;
    cfg.partition = Strategy::parse(args.get("partition").unwrap())
        .ok_or_else(|| anyhow::anyhow!("bad --partition"))?;
    let straggler = args.get("stragglers").unwrap();
    if !straggler.is_empty() {
        let profile = hybrid_dca::sim::StragglerProfile::parse(straggler)
            .ok_or_else(|| anyhow::anyhow!("unknown straggler profile '{straggler}'"))?;
        cfg.stragglers = profile.multipliers(cfg.k_nodes);
    }
    cfg.wild = args.flag("wild");
    cfg.validate()?;
    Ok((algo, cfg))
}

fn cmd_train(argv: &[String]) -> anyhow::Result<()> {
    let specs = train_specs();
    let args = cli::parse(&specs, argv)?;
    if args.flag("help") {
        print!("{}", cli::help("train", "run one solver", &specs));
        return Ok(());
    }
    let (algo, mut cfg) = parse_train_cfg(&args)?;
    let is_distributed = args.flag("distributed");
    if is_distributed {
        apply_transport_flags(&mut cfg, &args)?;
    }
    // Like --csv, --chaos applies even over --config: it perturbs a run,
    // it does not define the experiment.
    let chaos = args.get("chaos").unwrap();
    if !chaos.is_empty() {
        cfg.chaos_plan = chaos.to_string();
        cfg.validate()?;
    }
    // Same contract for the observability outputs: they watch a run,
    // they do not define the experiment. --trace-out implies the
    // timeline tracer; either flag implies the metrics registry.
    let metrics_out = args.get("metrics-out").unwrap().to_string();
    let trace_out = args.get("trace-out").unwrap().to_string();
    if !metrics_out.is_empty() || !trace_out.is_empty() {
        cfg.obs.enabled = true;
    }
    if !trace_out.is_empty() {
        cfg.obs.trace = true;
    }
    // The typed session API is the execution path; the flat config is
    // only the CLI-flag surface.
    let session = Session::from_exp_config(&cfg)?;
    let engine_name = session::canonical_name(algo);
    // A shard store stays a streamed source end to end: multi-node
    // engines partition on its shard boundaries, train per-node slabs,
    // and evaluate over streamed shards — the flat dataset is never
    // assembled here. Presets/files load flat.
    let source = session.load_source()?;
    let sharded_note = match source.shard_spans() {
        Some(s) => format!(" [{} shards]", s.len()),
        None => String::new(),
    };
    println!(
        "# {} on {}{} (n={}, d={}, nnz={}) λ={} K={} R={} S={} Γ={} H={}",
        algo.name(),
        source.name(),
        sharded_note,
        source.n(),
        source.d(),
        source.nnz(),
        cfg.lambda,
        cfg.k_nodes,
        cfg.r_cores,
        cfg.s_barrier,
        cfg.gamma,
        cfg.h_local
    );
    // Stream the trace live (and incrementally to CSV when requested)
    // instead of dumping it after the run.
    let csv = args.get("csv").unwrap().to_string();
    let report = if csv.is_empty() {
        let mut obs = PrintObserver::new();
        run_train(is_distributed, algo, &cfg, &session, engine_name, &source, &mut obs)?
    } else {
        let file = std::io::BufWriter::new(
            std::fs::File::create(&csv)
                .map_err(|e| anyhow::anyhow!("create {csv}: {e}"))?,
        );
        // Same label the driver will put on the trace (PassCoDe is the
        // only engine whose label varies, on the wild switch).
        let label = if algo == Algorithm::PassCoDe && cfg.wild {
            "PassCoDe-Wild"
        } else {
            algo.name()
        };
        let mut obs = Chain(PrintObserver::new(), CsvStreamObserver::new(file, label)?);
        let report =
            run_train(is_distributed, algo, &cfg, &session, engine_name, &source, &mut obs)?;
        if let Some(e) = obs.1.error.take() {
            anyhow::bail!("writing trace CSV {csv}: {e}");
        }
        println!("# trace streamed to {csv}");
        report
    };
    if is_distributed {
        print_transport_report(&report);
    }
    if !report.faults.is_clean() {
        print_fault_report(&report);
    }
    if let Some(snap) = &report.obs {
        for line in hybrid_dca::obs::report::obs_lines(snap) {
            println!("{line}");
        }
        if !metrics_out.is_empty() {
            hybrid_dca::obs::export::write_metrics(&metrics_out, snap)?;
            println!("# obs: metrics written to {metrics_out}");
        }
        if !trace_out.is_empty() {
            hybrid_dca::obs::export::write_trace(&trace_out, snap)?;
            println!("# obs: trace written to {trace_out}");
        }
    }
    let dump = args.get("dump").unwrap();
    if !dump.is_empty() {
        dump_state(dump, &report)?;
        println!("# state dumped to {dump}");
    }
    println!(
        "# finished: rounds={} updates={} vtime={:.6}s cert-gap={:.4e}",
        report.rounds,
        report.total_updates,
        report.vtime,
        report.certificate_gap_source(&source, &cfg)
    );
    Ok(())
}

/// Run the solver: in-process through the session engine, or as the
/// master of a real socket cluster when `--distributed` is set.
fn run_train(
    is_distributed: bool,
    algo: Algorithm,
    cfg: &ExpConfig,
    session: &Session,
    engine_name: &str,
    source: &DataSource,
    obs: &mut dyn Observer,
) -> anyhow::Result<RunReport> {
    if !is_distributed {
        return session.run_source_observed(engine_name, source, obs);
    }
    let listener = SocketListener::bind(&cfg.transport)?;
    // Parsed by the distributed smoke tests to learn a port-0 bind.
    println!(
        "# listening on {} — waiting for {} worker processes",
        listener.local_desc(),
        cfg.k_nodes
    );
    let handle = ObserverHandle::new(obs);
    distributed::run_master_with_listener(algo, cfg, listener, &handle)
}

/// Per-peer wire traffic, as seen from the master. `sent` is
/// master→worker (v broadcasts), `recv` is worker→master (Δv updates) —
/// sparse rounds show up directly as smaller `recv` byte counts.
/// Formatting goes through [`kv_line`] so all `# <channel>:` report
/// lines share one shape; the exact strings are grepped by CI.
fn print_transport_report(report: &RunReport) {
    for (w, p) in report.net.per_peer.iter().enumerate() {
        println!(
            "{}",
            kv_line(
                "transport",
                &format!("worker {w}"),
                &[
                    ("sent", format!("{}B/{} frames", p.sent_bytes, p.sent_frames)),
                    ("recv", format!("{}B/{} frames", p.recv_bytes, p.recv_frames)),
                ]
            )
        );
    }
    println!(
        "{}",
        kv_line(
            "transport",
            "total",
            &[
                ("sent", format!("{}B", report.net.sent_bytes())),
                ("recv", format!("{}B", report.net.recv_bytes())),
            ]
        )
    );
}

/// The run's fault record: per-peer counters, the ordered event log,
/// and the surviving cluster size. Printed only when something
/// fault-related actually happened — clean runs stay clean on stdout.
fn print_fault_report(report: &RunReport) {
    let f = &report.faults;
    for (w, p) in f.per_peer.iter().enumerate() {
        if p.stalls == 0 && p.retransmits == 0 && p.rejoins == 0 && p.declared_dead == 0 {
            continue;
        }
        println!(
            "{}",
            kv_line(
                "faults",
                &format!("worker {w}"),
                &[
                    ("stalls", p.stalls.to_string()),
                    ("retransmits", p.retransmits.to_string()),
                    ("rejoins", p.rejoins.to_string()),
                    ("declared-dead", p.declared_dead.to_string()),
                    ("last-acked-round", p.last_acked_round.to_string()),
                ]
            )
        );
    }
    for e in &f.events {
        println!(
            "{}",
            kv_line(
                "faults",
                &format!("[vtime {:.3} round {}] worker {}: {}", e.vtime, e.round, e.peer, e.what),
                &[]
            )
        );
    }
    println!(
        "{}",
        kv_line(
            "faults",
            "",
            &[
                ("k_live", f.k_live.to_string()),
                ("deaths", f.total_deaths().to_string()),
                ("rejoins", f.total_rejoins().to_string()),
            ]
        )
    );
}

/// Write the run's final state as JSON with every f64 spelled as its
/// IEEE-754 bit pattern, so two runs can be compared for *bitwise*
/// equality with `cmp`. Wall-clock fields are excluded — everything
/// kept is deterministic for a fixed store, seed, and config.
fn dump_state(path: &str, report: &RunReport) -> anyhow::Result<()> {
    let bits = |x: f64| Json::Str(format!("{:016x}", x.to_bits()));
    let vec_bits = |xs: &[f64]| Json::Arr(xs.iter().map(|&x| bits(x)).collect());
    let trace = Json::Arr(
        report
            .trace
            .points
            .iter()
            .map(|p| {
                Json::Obj(vec![
                    ("round".into(), Json::Num(p.round as f64)),
                    ("virt_secs".into(), bits(p.virt_secs)),
                    ("gap".into(), bits(p.gap)),
                    ("primal".into(), bits(p.primal)),
                    ("dual".into(), bits(p.dual)),
                    ("updates".into(), Json::Num(p.updates as f64)),
                ])
            })
            .collect(),
    );
    let doc = Json::Obj(vec![
        ("label".into(), Json::Str(report.label.clone())),
        ("rounds".into(), Json::Num(report.rounds as f64)),
        ("updates".into(), Json::Num(report.total_updates as f64)),
        ("vtime".into(), bits(report.vtime)),
        ("alpha".into(), vec_bits(&report.alpha)),
        ("v".into(), vec_bits(&report.v)),
        ("trace".into(), trace),
    ]);
    std::fs::write(path, doc.to_pretty()).map_err(|e| anyhow::anyhow!("write {path}: {e}"))
}

fn cmd_node(argv: &[String]) -> anyhow::Result<()> {
    let specs = vec![
        FlagSpec::required("join", "master address (host:port for tcp, socket path for uds)"),
        FlagSpec::value("transport", "tcp", "socket backend: tcp|uds"),
        FlagSpec::value("store", "", "shard-store directory (default: the master's store path)"),
        FlagSpec::value("connect-timeout", "10", "seconds to keep retrying the connect"),
        FlagSpec::value("read-timeout", "30", "seconds of master silence before giving up"),
        FlagSpec::value(
            "metrics-out",
            "",
            "write this node's metrics snapshot here (.json, else Prometheus text)",
        ),
        FlagSpec::value(
            "trace-out",
            "",
            "write this node's Chrome-trace timeline here (open in Perfetto)",
        ),
        FlagSpec::switch("help", "show help"),
    ];
    let args = cli::parse(&specs, argv)?;
    if args.flag("help") {
        print!("{}", cli::help("node", "worker role: join a distributed master", &specs));
        return Ok(());
    }
    let backend = args.get("transport").unwrap();
    let mut tcfg = TransportCfg::default();
    tcfg.backend = TransportBackend::parse(backend)
        .ok_or_else(|| anyhow::anyhow!("unknown --transport '{backend}' (tcp|uds)"))?;
    anyhow::ensure!(
        tcfg.backend != TransportBackend::InProcess,
        "a worker node needs a socket backend (tcp|uds)"
    );
    tcfg.join = args.get("join").unwrap().to_string();
    tcfg.connect_timeout_secs = args.get_parse("connect-timeout")?;
    tcfg.read_timeout_secs = args.get_parse("read-timeout")?;
    tcfg.validate()?;
    let store = args.get("store").unwrap();
    let store_override = if store.is_empty() { None } else { Some(store) };
    // Either output flag turns recording on for this node even when
    // the master's config runs dark; the master's `[obs]` table (riding
    // in on the Assign frame) also turns it on cluster-wide.
    let metrics_out = args.get("metrics-out").unwrap();
    let trace_out = args.get("trace-out").unwrap();
    let obs_override = hybrid_dca::obs::ObsCfg {
        enabled: !metrics_out.is_empty() || !trace_out.is_empty(),
        trace: !trace_out.is_empty(),
    };
    let summary = distributed::run_worker_node(&tcfg, store_override, obs_override)?;
    println!(
        "# worker {} done: rounds={} updates={} sent={}B recv={}B (master at {})",
        summary.worker_id,
        summary.local_rounds,
        summary.updates,
        summary.net.sent_bytes(),
        summary.net.recv_bytes(),
        summary.master_addr
    );
    if let Some(snap) = &summary.obs {
        if !metrics_out.is_empty() {
            hybrid_dca::obs::export::write_metrics(metrics_out, snap)?;
            println!("# obs: metrics written to {metrics_out}");
        }
        if !trace_out.is_empty() {
            hybrid_dca::obs::export::write_trace(trace_out, snap)?;
            println!("# obs: trace written to {trace_out}");
        }
    }
    Ok(())
}

fn cmd_gen_data(argv: &[String]) -> anyhow::Result<()> {
    let specs = vec![
        FlagSpec::value("preset", "tiny", "synthetic preset name"),
        FlagSpec::value("seed", "42", "RNG seed"),
        FlagSpec::required("out", "output LIBSVM path"),
        FlagSpec::switch("help", "show help"),
    ];
    let args = cli::parse(&specs, argv)?;
    if args.flag("help") {
        print!("{}", cli::help("gen-data", "write a synthetic preset", &specs));
        return Ok(());
    }
    let preset = Preset::parse(args.get("preset").unwrap())
        .ok_or_else(|| anyhow::anyhow!("unknown preset"))?;
    let seed: u64 = args.get_parse("seed")?;
    let ds = preset.generate(&mut Rng::new(seed ^ 0xDA7A));
    let out = args.get("out").unwrap();
    libsvm::write_file(out, &ds)?;
    println!("wrote {} ({} rows, {} nnz)", out, ds.n(), ds.x.nnz());
    Ok(())
}

fn cmd_data(argv: &[String]) -> anyhow::Result<()> {
    let usage = "data — shard store tools\n\nSubcommands:\n\
                 \x20 pack     LIBSVM text (or a preset) → binary CSR shards + manifest\n\
                 \x20 inspect  print a store's manifest; --verify decodes every shard\n\n\
                 Use 'data <subcommand> --help' for flags.";
    let Some(sub) = argv.first() else {
        println!("{usage}");
        return Ok(());
    };
    let rest = &argv[1..];
    match sub.as_str() {
        "pack" => cmd_data_pack(rest),
        "inspect" => cmd_data_inspect(rest),
        "help" | "--help" | "-h" => {
            println!("{usage}");
            Ok(())
        }
        other => anyhow::bail!("unknown data subcommand '{other}' (try 'data help')"),
    }
}

fn cmd_data_pack(argv: &[String]) -> anyhow::Result<()> {
    let specs = vec![
        FlagSpec::value("in", "", "input LIBSVM file (streamed, constant memory)"),
        FlagSpec::value("preset", "", "synthetic preset instead of --in"),
        FlagSpec::required("out", "output store directory"),
        FlagSpec::value("shard-rows", "4096", "rows per shard (0 = no row budget)"),
        FlagSpec::value("shard-bytes", "0", "encoded bytes per shard (0 = no byte budget)"),
        FlagSpec::value("align", "1", "cut shards only at row multiples of this (use K*R)"),
        FlagSpec::value("name", "", "dataset name in the manifest (default: input stem)"),
        FlagSpec::value("seed", "42", "RNG seed (preset generation / --shuffle order)"),
        FlagSpec::switch("shuffle", "permute rows at pack time (presets only)"),
        FlagSpec::switch("help", "show help"),
    ];
    let args = cli::parse(&specs, argv)?;
    if args.flag("help") {
        print!("{}", cli::help("data pack", "pack LIBSVM text into CSR shards", &specs));
        return Ok(());
    }
    let input = args.get("in").unwrap();
    let preset_name = args.get("preset").unwrap();
    anyhow::ensure!(
        input.is_empty() != preset_name.is_empty(),
        "exactly one of --in or --preset is required"
    );
    let seed: u64 = args.get_parse("seed")?;
    let out = std::path::PathBuf::from(args.get("out").unwrap());
    let mut opts = hybrid_dca::store::PackOptions {
        shard_rows: args.get_parse("shard-rows")?,
        shard_bytes: args.get_parse("shard-bytes")?,
        align: args.get_parse::<usize>("align")?.max(1),
        seed,
        ..Default::default()
    };
    anyhow::ensure!(
        opts.shard_rows > 0 || opts.shard_bytes > 0,
        "set --shard-rows and/or --shard-bytes (both 0 would make one giant shard)"
    );
    let named = args.get("name").unwrap();
    let (manifest, report) = if !input.is_empty() {
        anyhow::ensure!(
            !args.flag("shuffle"),
            "--shuffle needs the rows in memory; a streaming pack keeps file order \
             (pack a --preset, or pre-shuffle the text)"
        );
        opts.name = if named.is_empty() {
            std::path::Path::new(input)
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_else(|| "dataset".into())
        } else {
            named.to_string()
        };
        hybrid_dca::store::pack_file(std::path::Path::new(input), &out, &opts)?
    } else {
        let preset = Preset::parse(preset_name)
            .ok_or_else(|| anyhow::anyhow!("unknown preset '{preset_name}'"))?;
        let ds = harness::gen_preset(preset, seed);
        opts.name = if named.is_empty() { ds.name.clone() } else { named.to_string() };
        let strategy = if args.flag("shuffle") {
            Strategy::Shuffled
        } else {
            Strategy::Contiguous
        };
        hybrid_dca::store::pack_dataset(&ds, &out, &opts, strategy)?
    };
    println!(
        "packed {} → {}: {} shards, {} rows, {} nnz, {} bytes (peak buffer {} rows)",
        manifest.name,
        out.display(),
        report.shards,
        report.rows,
        report.nnz,
        report.bytes_written,
        report.peak_buffered_rows
    );
    println!("# manifest at {}", hybrid_dca::store::Manifest::path_in(&out).display());
    Ok(())
}

fn cmd_data_inspect(argv: &[String]) -> anyhow::Result<()> {
    let specs = vec![
        FlagSpec::required("store", "store directory to inspect"),
        FlagSpec::switch("verify", "decode every shard (CRC + CSR + label checks)"),
        FlagSpec::switch("help", "show help"),
    ];
    let args = cli::parse(&specs, argv)?;
    if args.flag("help") {
        print!("{}", cli::help("data inspect", "print a store's manifest", &specs));
        return Ok(());
    }
    let store = hybrid_dca::store::open(args.get("store").unwrap())?;
    let m = store.manifest();
    println!(
        "store {} — n={} d={} nnz={} order={} seed={} ({} shards)",
        store.dir().display(),
        m.n,
        m.d,
        m.nnz,
        m.strategy.name(),
        m.seed,
        m.shards.len()
    );
    println!(
        "{:<6} {:<18} {:>12} {:>10} {:>10} {:>9} {:>8} {:>10}",
        "shard", "file", "rows", "nnz", "bytes", "density", "nnz/row", "crc32"
    );
    for (i, s) in m.shards.iter().enumerate() {
        println!(
            "{:<6} {:<18} {:>12} {:>10} {:>10} {:>9.5} {:>8.1} {:>10}",
            i,
            s.path,
            format!("[{},{})", s.row_start, s.row_end),
            s.nnz,
            s.bytes,
            s.stats.density,
            s.stats.nnz_per_row_mean,
            format!("{:08x}", s.crc32)
        );
    }
    if args.flag("verify") {
        store.verify()?;
        println!("verify: all {} shards decode clean (CRC + CSR + labels)", m.shards.len());
    }
    Ok(())
}

fn cmd_stats(argv: &[String]) -> anyhow::Result<()> {
    let specs = vec![
        FlagSpec::value("preset", "", "one preset (default: all)"),
        FlagSpec::value("data", "", "LIBSVM file instead of presets"),
        FlagSpec::value("seed", "42", "RNG seed"),
        FlagSpec::switch("help", "show help"),
    ];
    let args = cli::parse(&specs, argv)?;
    if args.flag("help") {
        print!("{}", cli::help("stats", "dataset statistics (Table 1)", &specs));
        return Ok(());
    }
    let seed: u64 = args.get_parse("seed")?;
    println!("{}", DatasetStats::table_header());
    let file = args.get("data").unwrap();
    if !file.is_empty() {
        let ds = libsvm::read_file(file, 0)?;
        println!("{}", DatasetStats::compute(&ds).table_row());
        return Ok(());
    }
    let one = args.get("preset").unwrap();
    let presets: Vec<Preset> = if one.is_empty() {
        hybrid_dca::data::synth::ALL_PRESETS.to_vec()
    } else {
        vec![Preset::parse(one).ok_or_else(|| anyhow::anyhow!("unknown preset"))?]
    };
    for p in presets {
        let ds = p.generate(&mut Rng::new(seed ^ 0xDA7A));
        println!("{}", DatasetStats::compute(&ds).table_row());
    }
    Ok(())
}

fn cmd_bench(argv: &[String]) -> anyhow::Result<()> {
    let which = argv.first().map(|s| s.as_str()).unwrap_or("");
    match which {
        "table1" => harness::table1::run_and_print(),
        "fig3" => harness::fig3::run_and_print(harness::QuickFull::Quick),
        "fig4" => harness::fig4::run_and_print(harness::QuickFull::Quick),
        "fig5" => harness::fig5::run_and_print(harness::QuickFull::Quick),
        "fig6" => harness::fig6::run_and_print(harness::QuickFull::Quick),
        "fig7" => harness::fig7::run_and_print(harness::QuickFull::Quick),
        "report" => cmd_bench_report(&argv[1..]),
        other => anyhow::bail!(
            "unknown bench '{other}'; expected table1|fig3|fig4|fig5|fig6|fig7|report \
             (full sweeps: cargo bench --bench <name>)"
        ),
    }
}

/// The perf trajectories `cargo bench` appends to (committed at the
/// repo root).
const BENCH_TRAJECTORIES: [&str; 3] =
    ["BENCH_hot_loop.json", "BENCH_data_io.json", "BENCH_transport.json"];

/// `bench report` — compare the latest run in each committed
/// `BENCH_*.json` trajectory against the previous one, per benched
/// path, on `p50_secs`. Advisory (always exits 0): the first step
/// toward the ROADMAP's CI perf-regression gate.
fn cmd_bench_report(argv: &[String]) -> anyhow::Result<()> {
    let specs = vec![
        FlagSpec::value("dir", ".", "directory holding the BENCH_*.json trajectories"),
        FlagSpec::value("band", "5", "noise band in percent; |Δp50| inside it prints as '~'"),
        FlagSpec::switch("help", "show help"),
    ];
    let args = cli::parse(&specs, argv)?;
    if args.flag("help") {
        print!("{}", cli::help("bench report", "latest-vs-previous perf deltas", &specs));
        return Ok(());
    }
    let dir = std::path::PathBuf::from(args.get("dir").unwrap());
    let band: f64 = args.get_parse("band")?;
    anyhow::ensure!(band.is_finite() && band >= 0.0, "--band must be a percentage ≥ 0");
    for name in BENCH_TRAJECTORIES {
        let path = dir.join(name);
        let Ok(text) = std::fs::read_to_string(&path) else {
            println!("# {name}: missing (skipped)");
            continue;
        };
        let doc = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
        print_trajectory_deltas(name, &doc, band)?;
    }
    Ok(())
}

/// One trajectory's latest-vs-previous comparison. Rows are matched by
/// their `path` name, so a reordered or extended bench still lines up.
fn print_trajectory_deltas(name: &str, doc: &Json, band_pct: f64) -> anyhow::Result<()> {
    let runs = doc
        .get("runs")
        .and_then(|r| r.as_arr())
        .ok_or_else(|| anyhow::anyhow!("{name}: no 'runs' array"))?;
    let label = |run: &Json| -> String {
        run.get("label").and_then(|l| l.as_str()).unwrap_or("?").to_string()
    };
    let rows = |run: &Json| -> Vec<(String, f64)> {
        run.get("rows")
            .and_then(|r| r.as_arr())
            .map(|arr| {
                arr.iter()
                    .filter_map(|row| {
                        let p = row.get("path")?.as_str()?;
                        let p50 = row.get("p50_secs")?.as_f64()?;
                        Some((p.to_string(), p50))
                    })
                    .collect()
            })
            .unwrap_or_default()
    };
    let Some(latest) = runs.last() else {
        println!("# {name}: no runs recorded");
        return Ok(());
    };
    if runs.len() < 2 {
        println!("# {name}: one run ('{}') — nothing to compare yet", label(latest));
        return Ok(());
    }
    let prev = &runs[runs.len() - 2];
    println!(
        "# {name}: latest '{}' vs previous '{}' (noise band ±{band_pct}%)",
        label(latest),
        label(prev)
    );
    let prev_rows = rows(prev);
    for (p, p50) in rows(latest) {
        match prev_rows.iter().find(|(q, _)| *q == p) {
            Some(&(_, prev_p50)) if prev_p50 > 0.0 => {
                let delta_pct = (p50 - prev_p50) / prev_p50 * 100.0;
                let verdict = if delta_pct.abs() <= band_pct {
                    "~ within band"
                } else if delta_pct > 0.0 {
                    "SLOWER"
                } else {
                    "faster"
                };
                println!(
                    "    {p:<28} p50 {prev_p50:.3e}s → {p50:.3e}s  {delta_pct:+.1}%  {verdict}"
                );
            }
            _ => println!("    {p:<28} p50 {p50:.3e}s  (new path)"),
        }
    }
    Ok(())
}

#[cfg(not(feature = "xla-runtime"))]
fn cmd_artifacts(_argv: &[String]) -> anyhow::Result<()> {
    anyhow::bail!(
        "this binary was built without the `xla-runtime` feature; \
         rebuild with `cargo build --release --features xla-runtime`"
    )
}

#[cfg(feature = "xla-runtime")]
fn cmd_artifacts(argv: &[String]) -> anyhow::Result<()> {
    let specs = vec![
        FlagSpec::value("dir", "", "artifacts directory (default: ./artifacts)"),
        FlagSpec::switch("help", "show help"),
    ];
    let args = cli::parse(&specs, argv)?;
    if args.flag("help") {
        print!("{}", cli::help("artifacts", "list/verify AOT artifacts", &specs));
        return Ok(());
    }
    let dir = {
        let d = args.get("dir").unwrap();
        if d.is_empty() {
            hybrid_dca::runtime::default_artifacts_dir()
        } else {
            std::path::PathBuf::from(d)
        }
    };
    if !hybrid_dca::runtime::Runtime::available(&dir) {
        anyhow::bail!(
            "no manifest at {} — run `make artifacts` first",
            dir.join("manifest.toml").display()
        );
    }
    let rt = hybrid_dca::runtime::Runtime::load(&dir)?;
    println!("artifacts in {} (compiled OK):", dir.display());
    for name in rt.names() {
        let a = rt.get(name).unwrap();
        println!(
            "  {:<28} kind={:<10} B={:<4} D={:<6} dtype={}",
            name,
            a.meta.kind.as_str(),
            a.meta.b,
            a.meta.d,
            a.meta.dtype
        );
    }
    Ok(())
}
