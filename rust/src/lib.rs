//! # Hybrid-DCA
//!
//! A full reproduction of *“Hybrid-DCA: A Double Asynchronous Approach
//! for Stochastic Dual Coordinate Ascent”* (Pal, Xu, Yang, Rajasekaran,
//! Bi; 2016) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the paper's coordination contribution:
//!   a master with a bounded barrier (`S`) and bounded delay (`Γ`)
//!   merging asynchronous updates from `K` worker nodes, each of which
//!   runs `R` lock-free core-threads of stochastic dual coordinate
//!   ascent (Algorithms 1–2), plus every substrate the experiments
//!   need (sparse data, losses, baselines, metrics, simulation, CLI).
//! * **Layer 2 (python/compile/model.py)** — the block dual-step and
//!   objective computation written in JAX, AOT-lowered to HLO text.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels (Gram tile,
//!   matvec, objective tile) called from Layer 2.
//!
//! Rust executes the AOT artifacts through the PJRT CPU client
//! ([`runtime`]); Python never runs on the solve path.
//!
//! ## Quickstart
//!
//! ```no_run
//! use hybrid_dca::prelude::*;
//!
//! let mut rng = Rng::new(42);
//! let data = Preset::Tiny.generate(&mut rng);
//! let mut cfg = ExpConfig::default();
//! cfg.k_nodes = 4;
//! cfg.r_cores = 2;
//! cfg.s_barrier = 3;
//! cfg.gamma = 2;
//! let report = coordinator::hybrid::run(&data, &cfg).unwrap();
//! println!("final gap = {:?}", report.trace.final_gap());
//! ```

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod harness;
pub mod loss;
pub mod metrics;
pub mod runtime;
pub mod sim;
pub mod solver;
pub mod util;

/// Most-used items in one import.
pub mod prelude {
    pub use crate::config::{Algorithm, ExpConfig, SigmaPolicy};
    pub use crate::coordinator;
    pub use crate::data::{CsrMatrix, Dataset, Partition, Preset, Strategy};
    pub use crate::loss::{Hinge, Logistic, Loss, LossKind, SquaredHinge};
    pub use crate::metrics::{objectives, Objectives, Trace, TracePoint};
    pub use crate::util::Rng;
}
