//! # Hybrid-DCA
//!
//! A full reproduction of *“Hybrid-DCA: A Double Asynchronous Approach
//! for Stochastic Dual Coordinate Ascent”* (Pal, Xu, Yang, Rajasekaran,
//! Bi; 2016) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the paper's coordination contribution:
//!   a master with a bounded barrier (`S`) and bounded delay (`Γ`)
//!   merging asynchronous updates from `K` worker nodes, each of which
//!   runs `R` lock-free core-threads of stochastic dual coordinate
//!   ascent (Algorithms 1–2), plus every substrate the experiments
//!   need (sparse data, losses, baselines, metrics, simulation, CLI).
//! * **Layer 2 (python/compile/model.py)** — the block dual-step and
//!   objective computation written in JAX, AOT-lowered to HLO text.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels (Gram tile,
//!   matvec, objective tile) called from Layer 2.
//!
//! With the `xla-runtime` feature, Rust executes the AOT artifacts
//! through the PJRT CPU client ([`runtime`]); Python never runs on the
//! solve path.
//!
//! ## Quickstart
//!
//! The public API is the [`session`] layer: a typed [`session::Session`]
//! built from validated sub-configs, run through a pluggable
//! [`session::SolverEngine`] registry, streaming progress to a
//! [`session::Observer`].
//!
//! ```no_run
//! use hybrid_dca::prelude::*;
//!
//! let mut rng = Rng::new(42);
//! let data = Preset::Tiny.generate(&mut rng);
//! let session = Session::builder()
//!     .lambda(1e-2)
//!     .cluster(4, 2) // K nodes × R cores
//!     .barrier(3)    // merge as soon as S = 3 workers report
//!     .delay(2)      // but never let anyone lag more than Γ = 2 rounds
//!     .rounds(50)
//!     .gap_threshold(1e-5)
//!     .build()
//!     .unwrap();
//! let report = session.run("hybrid-dca", &data).unwrap();
//! println!("final gap = {:?}", report.trace.final_gap());
//! ```

// The clippy style baseline lives in [workspace.lints.clippy]
// (Cargo.toml) so every crate in the workspace — bin, tests, benches,
// xtask — shares it, not just this lib.

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod harness;
pub mod loss;
pub mod metrics;
pub mod obs;
#[cfg(feature = "xla-runtime")]
pub mod runtime;
pub mod session;
pub mod sim;
pub mod solver;
pub mod store;
pub mod transport;
pub mod util;

/// Most-used items in one import.
pub mod prelude {
    pub use crate::config::{Algorithm, ExpConfig, SigmaPolicy};
    pub use crate::coordinator;
    pub use crate::coordinator::{MergePolicy, RunReport};
    pub use crate::data::{CsrMatrix, Dataset, Partition, Preset, Strategy};
    pub use crate::loss::{Hinge, Logistic, Loss, LossKind, SquaredHinge};
    pub use crate::metrics::{objectives, Objectives, Trace, TracePoint};
    pub use crate::session::{
        DataSource, EvalEvent, Observer, ObserverHandle, RoundEvent, RunCtx, Session,
        SessionBuilder, SolverEngine,
    };
    pub use crate::store::ShardedDataset;
    pub use crate::util::Rng;
}
