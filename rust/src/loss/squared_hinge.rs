//! Squared hinge loss `φ(z; y) = max(0, 1 − yz)²` (L2-SVM).
//!
//! Conjugate: with `a = α·y ≥ 0`, `φ*(−α) = −a + a²/4`, so the dual
//! contribution is `a − a²/4`. The loss is 2-smooth (φ″ ≤ 2, μ = 1/2),
//! so Theorem 6's linear rate applies.
//!
//! Coordinate step (closed form): maximize
//! `f(δ) = (a+δ) − (a+δ)²/4 − y·m·δ − (q/2)δ²` over `a+δ ≥ 0` →
//! `a_new = max(0, (a/2 + q·a + 1 − y·m) / (q + 1/2))`, derived from
//! `f′(δ) = 1 − (a+δ)/2 − y·m − q·δ = 0`.

use super::Loss;

#[derive(Debug, Clone, Copy, Default)]
pub struct SquaredHinge;

impl Loss for SquaredHinge {
    #[inline]
    fn primal(&self, z: f64, y: f64) -> f64 {
        let t = (1.0 - y * z).max(0.0);
        t * t
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    #[inline]
    fn dual_value(&self, alpha: f64, y: f64) -> f64 {
        let a = alpha * y;
        if a >= 0.0 {
            a - 0.25 * a * a
        } else {
            f64::NEG_INFINITY
        }
    }

    #[inline]
    fn feasible(&self, alpha: f64, y: f64) -> bool {
        alpha * y >= 0.0
    }

    #[inline]
    fn coordinate_step(&self, alpha: f64, y: f64, margin: f64, q: f64) -> f64 {
        debug_assert!(q > 0.0);
        let a = alpha * y;
        // Solve 1 − (a+δ)/2 − y·m − qδ = 0 for δ, then a_new = a + δ.
        let delta = (1.0 - y * margin - 0.5 * a) / (q + 0.5);
        let a_new = (a + delta).max(0.0);
        a_new * y
    }

    fn smoothness(&self) -> Option<f64> {
        Some(2.0) // (1/μ)-smooth with 1/μ = 2.
    }

    fn lipschitz(&self) -> f64 {
        // Not globally Lipschitz; on the unit-margin ball |φ'| ≤ 2(1+|z|).
        // Solvers never use this for squared hinge (smooth path taken);
        // return the local bound at |z| ≤ 1 for completeness.
        4.0
    }

    #[inline]
    fn primal_subgradient_dual(&self, z: f64, y: f64) -> f64 {
        // φ'(z) = −2y·max(0, 1−yz); −u = φ' → u = 2y·max(0, 1−yz).
        2.0 * y * (1.0 - y * z).max(0.0)
    }

    fn name(&self) -> &'static str {
        "squared_hinge"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::brute_force_step;
    use crate::util::Rng;

    #[test]
    fn primal_values() {
        let h = SquaredHinge;
        assert_eq!(h.primal(1.0, 1.0), 0.0);
        assert_eq!(h.primal(0.0, 1.0), 1.0);
        assert_eq!(h.primal(-1.0, 1.0), 4.0);
    }

    #[test]
    fn dual_values_and_domain() {
        let h = SquaredHinge;
        assert_eq!(h.dual_value(0.0, 1.0), 0.0);
        assert_eq!(h.dual_value(2.0, 1.0), 1.0); // a=2: 2 − 1 = 1 (max)
        assert!(h.feasible(5.0, 1.0));
        assert!(!h.feasible(-0.1, 1.0));
        assert_eq!(h.dual_value(-1.0, 1.0), f64::NEG_INFINITY);
    }

    #[test]
    fn step_matches_brute_force() {
        let h = SquaredHinge;
        let mut rng = Rng::new(41);
        for _ in 0..300 {
            let y = if rng.next_bool(0.5) { 1.0 } else { -1.0 };
            let a0 = rng.next_f64() * 3.0;
            let alpha = a0 * y;
            let m = rng.next_gaussian() * 2.0;
            let q = 0.1 + rng.next_f64() * 5.0;
            let exact = h.coordinate_step(alpha, y, m, q);
            // Grid-search the signed dual a = α·y over a range wide
            // enough to contain the unconstrained optimum.
            let a_cap = 8.0 + 2.0 * (exact * y).abs();
            let f = |a: f64| {
                h.dual_value(a * y, y) - m * (a * y - alpha) - 0.5 * q * (a * y - alpha).powi(2)
            };
            let mut best = 0.0;
            let mut bestv = f64::NEG_INFINITY;
            for k in 0..=80_000 {
                let a = a_cap * k as f64 / 80_000.0;
                let v = f(a);
                if v > bestv {
                    bestv = v;
                    best = a;
                }
            }
            let brute = best * y;
            let _ = brute_force_step; // generic oracle unused here (domain is one-sided)
            assert!(
                (exact - brute).abs() < 2e-3 * (1.0 + exact.abs()),
                "exact {exact} vs brute {brute} (α={alpha}, y={y}, m={m}, q={q})"
            );
        }
    }

    #[test]
    fn step_never_decreases_subobjective() {
        let h = SquaredHinge;
        let mut rng = Rng::new(43);
        for _ in 0..500 {
            let y = if rng.next_bool(0.5) { 1.0 } else { -1.0 };
            let alpha = rng.next_f64() * 2.0 * y;
            let m = rng.next_gaussian() * 2.0;
            let q = 0.1 + rng.next_f64() * 5.0;
            let f = |a: f64| h.dual_value(a, y) - m * (a - alpha) - 0.5 * q * (a - alpha).powi(2);
            let a_new = h.coordinate_step(alpha, y, m, q);
            assert!(h.feasible(a_new, y));
            assert!(f(a_new) >= f(alpha) - 1e-12, "f({a_new}) < f({alpha})");
        }
    }

    #[test]
    fn smooth_constants() {
        assert_eq!(SquaredHinge.smoothness(), Some(2.0));
    }

    #[test]
    fn subgradient_feasible() {
        let h = SquaredHinge;
        for &(z, y) in &[(0.0, 1.0), (2.0, 1.0), (0.5, -1.0)] {
            let u = h.primal_subgradient_dual(z, y);
            assert!(h.feasible(u, y));
        }
    }
}
