//! Loss functions and their dual (conjugate) machinery.
//!
//! The paper solves the RRM problem (1) in its dual (2). Everything a
//! solver needs from a loss is captured by the [`Loss`] trait:
//!
//! * the primal value `φ(z; y)`,
//! * the dual contribution `−φ*(−α_i)` (so the dual objective is
//!   `D(α) = (1/n) Σ_i dual_value(α_i, y_i) − (λ/2)‖v‖²` with
//!   `v = (1/λn) X α`),
//! * the **single-coordinate maximizer** of the perturbed subproblem
//!   `Q_k^σ` (paper Eq. 6): given current `α_i`, margin `m = x_iᵀu`, and
//!   curvature `q = σ‖x_i‖²/(λn)`, return the new `α_i` maximizing
//!
//!   ```text
//!   f(ε) = −φ*(−(α_i+ε)) − m·ε − (q/2)·ε²  .
//!   ```
//!
//!   Hinge and squared hinge have closed forms (Fan et al. 2008); the
//!   logistic step uses a guarded Newton iteration (Yu et al. 2011),
//!   exactly the split the paper describes in §3.1.
//!
//! All formulas use the substitution `a = α_i·y_i` (the "signed dual"),
//! whose feasible set is `[0,1]` for hinge, `[0,∞)` for squared hinge
//! and `(0,1)` for logistic.

pub mod hinge;
pub mod logistic;
pub mod squared_hinge;

pub use hinge::Hinge;
pub use logistic::Logistic;
pub use squared_hinge::SquaredHinge;

/// A convex classification loss with the dual interface used by every
/// solver in this library.
pub trait Loss: Send + Sync + std::fmt::Debug {
    /// Primal loss `φ(z; y)` at margin `z = x_iᵀw`.
    fn primal(&self, z: f64, y: f64) -> f64;

    /// Concrete-type escape hatch for the hot-path monomorphization in
    /// [`crate::solver::kernels`]: the update kernels downcast to the
    /// builtin losses once per round and run a fully static inner loop,
    /// falling back to virtual dispatch for plugin losses.
    fn as_any(&self) -> &dyn std::any::Any;

    /// Dual contribution `−φ*(−α)` (so larger is better). Returns
    /// `f64::NEG_INFINITY` outside the feasible domain.
    fn dual_value(&self, alpha: f64, y: f64) -> f64;

    /// Is `α` dual-feasible for label `y`?
    fn feasible(&self, alpha: f64, y: f64) -> bool;

    /// Exact (or high-precision iterative) maximizer of the 1-D
    /// subproblem; returns the **new** `α_i`.
    fn coordinate_step(&self, alpha: f64, y: f64, margin: f64, q: f64) -> f64;

    /// `Some(1/μ)` if the loss is `(1/μ)`-smooth (⇒ linear convergence,
    /// Theorem 6), `None` if only Lipschitz (Theorem 7).
    fn smoothness(&self) -> Option<f64>;

    /// Lipschitz constant `L` of `φ(·; y)`.
    fn lipschitz(&self) -> f64;

    /// A dual-feasible subgradient mapping for the duality-gap
    /// certificate: returns some `u` with `−u ∈ ∂φ(z; y)`… in practice we
    /// only need `P(w) − D(α)` which uses `primal` and `dual_value`, but
    /// Theorem 7's analysis uses this; exposed for tests.
    fn primal_subgradient_dual(&self, z: f64, y: f64) -> f64;

    fn name(&self) -> &'static str;
}

/// Loss selection by name (CLI / config).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LossKind {
    Hinge,
    SquaredHinge,
    Logistic,
}

impl LossKind {
    pub fn parse(s: &str) -> Option<LossKind> {
        match s.to_ascii_lowercase().as_str() {
            "hinge" | "svm" => Some(LossKind::Hinge),
            "squared_hinge" | "squared-hinge" | "l2svm" => Some(LossKind::SquaredHinge),
            "logistic" | "logreg" => Some(LossKind::Logistic),
            _ => None,
        }
    }

    pub fn build(self) -> Box<dyn Loss> {
        match self {
            LossKind::Hinge => Box::new(Hinge),
            LossKind::SquaredHinge => Box::new(SquaredHinge),
            LossKind::Logistic => Box::new(Logistic::default()),
        }
    }
}

/// Numerically maximize `f(ε) = dual_value(α+ε) − m·ε − (q/2)ε²` by a
/// fine grid + golden-section refinement. Test oracle for the
/// closed-form steps (never used by solvers).
#[cfg(test)]
pub(crate) fn brute_force_step(
    loss: &dyn Loss,
    alpha: f64,
    y: f64,
    m: f64,
    q: f64,
    lo: f64,
    hi: f64,
) -> f64 {
    let f = |a: f64| loss.dual_value(a, y) - m * (a - alpha) - 0.5 * q * (a - alpha) * (a - alpha);
    let mut best_a = alpha;
    let mut best = f64::NEG_INFINITY;
    let steps = 20_000;
    for k in 0..=steps {
        let a = lo + (hi - lo) * (k as f64 / steps as f64);
        let v = f(a);
        if v > best {
            best = v;
            best_a = a;
        }
    }
    // Golden-section refinement around the best grid point.
    let span = (hi - lo) / steps as f64;
    let (mut a_lo, mut a_hi) = ((best_a - 2.0 * span).max(lo), (best_a + 2.0 * span).min(hi));
    let phi = (5f64.sqrt() - 1.0) / 2.0;
    for _ in 0..200 {
        let x1 = a_hi - phi * (a_hi - a_lo);
        let x2 = a_lo + phi * (a_hi - a_lo);
        if f(x1) < f(x2) {
            a_lo = x1;
        } else {
            a_hi = x2;
        }
    }
    0.5 * (a_lo + a_hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parsing() {
        assert_eq!(LossKind::parse("hinge"), Some(LossKind::Hinge));
        assert_eq!(LossKind::parse("L2SVM"), Some(LossKind::SquaredHinge));
        assert_eq!(LossKind::parse("logreg"), Some(LossKind::Logistic));
        assert_eq!(LossKind::parse("huber"), None);
    }

    #[test]
    fn build_matches_name() {
        assert_eq!(LossKind::Hinge.build().name(), "hinge");
        assert_eq!(LossKind::SquaredHinge.build().name(), "squared_hinge");
        assert_eq!(LossKind::Logistic.build().name(), "logistic");
    }

    /// Fenchel–Young: for any feasible α and any z,
    /// φ(z) + φ*(−α) ≥ −α·z  ⇔  φ(z) − dual_value(α) + α·z ≥ 0.
    #[test]
    fn fenchel_young_inequality() {
        let losses: Vec<Box<dyn Loss>> =
            vec![Box::new(Hinge), Box::new(SquaredHinge), Box::new(Logistic::default())];
        let mut rng = crate::util::Rng::new(99);
        for loss in &losses {
            for _ in 0..2000 {
                let y = if rng.next_bool(0.5) { 1.0 } else { -1.0 };
                let z = rng.next_gaussian() * 3.0;
                // Sample a feasible alpha: a = αy in a loss-appropriate range.
                let a_signed = match loss.name() {
                    "hinge" => rng.next_f64(),
                    "squared_hinge" => rng.next_f64() * 4.0,
                    _ => 0.001 + 0.998 * rng.next_f64(),
                };
                let alpha = a_signed * y;
                assert!(loss.feasible(alpha, y), "{} α={alpha} y={y}", loss.name());
                let lhs = loss.primal(z, y) - loss.dual_value(alpha, y) + alpha * z;
                assert!(
                    lhs >= -1e-9,
                    "Fenchel-Young violated for {}: lhs={lhs} z={z} α={alpha} y={y}",
                    loss.name()
                );
            }
        }
    }
}
