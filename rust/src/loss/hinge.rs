//! Hinge loss `φ(z; y) = max(0, 1 − yz)` — the loss the paper's
//! experiments use (§6: "We evaluated for hinge loss").
//!
//! Conjugate: with the signed dual `a = α·y`,
//! `φ*(−α) = −a` for `a ∈ [0, 1]`, `+∞` otherwise, so the dual
//! contribution is `−φ*(−α) = a`.
//!
//! Coordinate step (closed form, Fan et al. 2008): maximizing
//! `f(ε) = (a+δ) − m·ε − (q/2)ε²` with `ε = y·δ` gives
//! `a_new = clip(a + (1 − y·m)/q, 0, 1)`.

use super::Loss;
use crate::util::clip;

#[derive(Debug, Clone, Copy, Default)]
pub struct Hinge;

impl Loss for Hinge {
    #[inline]
    fn primal(&self, z: f64, y: f64) -> f64 {
        (1.0 - y * z).max(0.0)
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    #[inline]
    fn dual_value(&self, alpha: f64, y: f64) -> f64 {
        let a = alpha * y;
        if (0.0..=1.0).contains(&a) {
            a
        } else {
            f64::NEG_INFINITY
        }
    }

    #[inline]
    fn feasible(&self, alpha: f64, y: f64) -> bool {
        let a = alpha * y;
        (0.0..=1.0).contains(&a)
    }

    #[inline]
    fn coordinate_step(&self, alpha: f64, y: f64, margin: f64, q: f64) -> f64 {
        debug_assert!(q > 0.0);
        let a = alpha * y;
        let a_new = clip(a + (1.0 - y * margin) / q, 0.0, 1.0);
        a_new * y
    }

    fn smoothness(&self) -> Option<f64> {
        None // hinge is not smooth; Theorem 7 applies (L-Lipschitz).
    }

    fn lipschitz(&self) -> f64 {
        1.0
    }

    #[inline]
    fn primal_subgradient_dual(&self, z: f64, y: f64) -> f64 {
        // −u ∈ ∂φ(z): ∂φ = −y on the active branch, 0 otherwise, any
        // point of [−y·1, 0] at the kink. Return the standard choice.
        if y * z < 1.0 {
            y
        } else {
            0.0
        }
    }

    fn name(&self) -> &'static str {
        "hinge"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::brute_force_step;
    use crate::util::Rng;

    #[test]
    fn primal_values() {
        let h = Hinge;
        assert_eq!(h.primal(2.0, 1.0), 0.0);
        assert_eq!(h.primal(0.0, 1.0), 1.0);
        assert_eq!(h.primal(-1.0, 1.0), 2.0);
        assert_eq!(h.primal(-2.0, -1.0), 0.0);
        assert_eq!(h.primal(1.0, -1.0), 2.0);
    }

    #[test]
    fn dual_domain() {
        let h = Hinge;
        assert_eq!(h.dual_value(0.5, 1.0), 0.5);
        assert_eq!(h.dual_value(-0.5, -1.0), 0.5);
        assert!(h.feasible(0.0, 1.0));
        assert!(h.feasible(1.0, 1.0));
        assert!(!h.feasible(1.1, 1.0));
        assert!(!h.feasible(-0.1, 1.0));
        assert_eq!(h.dual_value(2.0, 1.0), f64::NEG_INFINITY);
    }

    #[test]
    fn step_closed_form_simple() {
        let h = Hinge;
        // α=0, y=1, margin 0, q=1 → a_new = clip(0 + 1/1) = 1.
        assert_eq!(h.coordinate_step(0.0, 1.0, 0.0, 1.0), 1.0);
        // Saturation at 0: margin large.
        assert_eq!(h.coordinate_step(0.0, 1.0, 10.0, 1.0), 0.0);
        // Negative label mirrors.
        assert_eq!(h.coordinate_step(0.0, -1.0, 0.0, 1.0), -1.0);
    }

    #[test]
    fn step_matches_brute_force() {
        let h = Hinge;
        let mut rng = Rng::new(31);
        for _ in 0..300 {
            let y = if rng.next_bool(0.5) { 1.0 } else { -1.0 };
            let a0 = rng.next_f64();
            let alpha = a0 * y;
            let m = rng.next_gaussian() * 2.0;
            let q = 0.1 + rng.next_f64() * 5.0;
            let exact = h.coordinate_step(alpha, y, m, q);
            let brute = brute_force_step(&h, alpha, y, m, q, -1.0, 1.0);
            assert!(
                (exact - brute).abs() < 1e-3,
                "exact {exact} vs brute {brute} (α={alpha}, y={y}, m={m}, q={q})"
            );
        }
    }

    #[test]
    fn step_never_decreases_subobjective() {
        let h = Hinge;
        let mut rng = Rng::new(33);
        for _ in 0..500 {
            let y = if rng.next_bool(0.5) { 1.0 } else { -1.0 };
            let alpha = rng.next_f64() * y;
            let m = rng.next_gaussian() * 2.0;
            let q = 0.1 + rng.next_f64() * 5.0;
            let f = |a: f64| h.dual_value(a, y) - m * (a - alpha) - 0.5 * q * (a - alpha).powi(2);
            let a_new = h.coordinate_step(alpha, y, m, q);
            assert!(h.feasible(a_new, y));
            assert!(f(a_new) >= f(alpha) - 1e-12);
        }
    }

    #[test]
    fn subgradient_is_dual_feasible() {
        let h = Hinge;
        for &(z, y) in &[(0.0, 1.0), (2.0, 1.0), (0.5, -1.0), (-3.0, -1.0)] {
            let u = h.primal_subgradient_dual(z, y);
            assert!(h.feasible(u, y), "u={u} infeasible for y={y}");
        }
    }

    #[test]
    fn constants() {
        assert_eq!(Hinge.lipschitz(), 1.0);
        assert!(Hinge.smoothness().is_none());
    }
}
