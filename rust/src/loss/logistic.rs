//! Logistic loss `φ(z; y) = log(1 + e^{−yz})`.
//!
//! Conjugate: with `a = α·y ∈ (0, 1)`,
//! `φ*(−α) = a·log a + (1−a)·log(1−a)` (negative binary entropy), so the
//! dual contribution is the entropy `H(a)`. The loss is 4-smooth
//! (φ″ ≤ 1/4 ⇒ 1/μ = 1/4… careful: φ is (1/4)-smooth, i.e. μ = 4);
//! we report `smoothness() = 1/4` as the `1/μ` constant used by
//! Theorem 6 with μ = 4.
//!
//! The coordinate step has no closed form; we run a guarded Newton
//! iteration on the signed dual `t = a + δ ∈ (0,1)` maximizing
//! `f(t) = H(t) − y·m·(t−a) − (q/2)(t−a)²` (Yu, Huang & Lin, 2011,
//! the method the paper cites for logistic subproblems).

use super::Loss;

#[derive(Debug, Clone, Copy)]
pub struct Logistic {
    /// Newton iteration cap.
    pub max_iters: usize,
    /// Gradient tolerance for early exit.
    pub tol: f64,
}

impl Default for Logistic {
    fn default() -> Self {
        Self { max_iters: 50, tol: 1e-12 }
    }
}

const EPS: f64 = 1e-12;

#[inline]
fn entropy(t: f64) -> f64 {
    // −t·ln t − (1−t)·ln(1−t), continuous extension at 0/1.
    let h = |x: f64| if x <= 0.0 { 0.0 } else { -x * x.ln() };
    h(t) + h(1.0 - t)
}

impl Loss for Logistic {
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    #[inline]
    fn primal(&self, z: f64, y: f64) -> f64 {
        // Numerically stable log(1 + e^{−yz}).
        let t = -y * z;
        if t > 35.0 {
            t
        } else if t < -35.0 {
            0.0
        } else {
            (1.0 + t.exp()).ln()
        }
    }

    #[inline]
    fn dual_value(&self, alpha: f64, y: f64) -> f64 {
        let a = alpha * y;
        if (0.0..=1.0).contains(&a) {
            entropy(a)
        } else {
            f64::NEG_INFINITY
        }
    }

    #[inline]
    fn feasible(&self, alpha: f64, y: f64) -> bool {
        let a = alpha * y;
        (0.0..=1.0).contains(&a)
    }

    fn coordinate_step(&self, alpha: f64, y: f64, margin: f64, q: f64) -> f64 {
        debug_assert!(q > 0.0);
        let a = (alpha * y).clamp(EPS, 1.0 - EPS);
        let ym = y * margin;
        // Maximize f(t) = H(t) − ym(t−a) − q/2 (t−a)².
        // f'(t) = ln((1−t)/t) − ym − q(t−a);  f''(t) = −1/(t(1−t)) − q.
        let mut t = a;
        for _ in 0..self.max_iters {
            let g = ((1.0 - t) / t).ln() - ym - q * (t - a);
            if g.abs() < self.tol {
                break;
            }
            let h = -1.0 / (t * (1.0 - t)) - q;
            let mut step = -g / h;
            // Guard: keep t strictly inside (0,1); damp if overshooting.
            let mut t_new = t + step;
            let mut guard = 0;
            while (t_new <= EPS || t_new >= 1.0 - EPS) && guard < 60 {
                step *= 0.5;
                t_new = t + step;
                guard += 1;
            }
            if guard >= 60 {
                t_new = t_new.clamp(EPS, 1.0 - EPS);
            }
            if (t_new - t).abs() < 1e-16 {
                t = t_new;
                break;
            }
            t = t_new;
        }
        t * y
    }

    fn smoothness(&self) -> Option<f64> {
        Some(0.25) // φ is (1/4)-smooth.
    }

    fn lipschitz(&self) -> f64 {
        1.0 // |φ'| = |−y·s(−yz)| ≤ 1.
    }

    #[inline]
    fn primal_subgradient_dual(&self, z: f64, y: f64) -> f64 {
        // φ'(z) = −y·σ(−yz); u = y·σ(−yz) ∈ y·(0,1).
        let t = -y * z;
        let s = if t > 35.0 {
            1.0
        } else if t < -35.0 {
            0.0
        } else {
            1.0 / (1.0 + (-t).exp())
        };
        y * s
    }

    fn name(&self) -> &'static str {
        "logistic"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn primal_stable_extremes() {
        let l = Logistic::default();
        assert!((l.primal(0.0, 1.0) - 2f64.ln()).abs() < 1e-12);
        assert!((l.primal(100.0, 1.0) - 0.0).abs() < 1e-12);
        assert!((l.primal(-100.0, 1.0) - 100.0).abs() < 1e-9);
        assert!(l.primal(50.0, -1.0) >= 49.0);
    }

    #[test]
    fn dual_is_entropy() {
        let l = Logistic::default();
        assert!((l.dual_value(0.5, 1.0) - 2f64.ln()).abs() < 1e-12);
        assert_eq!(l.dual_value(0.0, 1.0), 0.0);
        assert_eq!(l.dual_value(1.0, 1.0), 0.0);
        assert_eq!(l.dual_value(1.5, 1.0), f64::NEG_INFINITY);
    }

    #[test]
    fn newton_step_maximizes() {
        let l = Logistic::default();
        let mut rng = Rng::new(51);
        for _ in 0..300 {
            let y = if rng.next_bool(0.5) { 1.0 } else { -1.0 };
            let a0 = 0.01 + 0.98 * rng.next_f64();
            let alpha = a0 * y;
            let m = rng.next_gaussian() * 2.0;
            let q = 0.1 + rng.next_f64() * 5.0;
            let a_new = l.coordinate_step(alpha, y, m, q);
            assert!(l.feasible(a_new, y));
            let f = |a: f64| l.dual_value(a, y) - m * (a - alpha) - 0.5 * q * (a - alpha).powi(2);
            // Newton result must beat a fine grid to tolerance.
            let mut best = f64::NEG_INFINITY;
            for k in 1..2000 {
                let t = k as f64 / 2000.0;
                best = best.max(f(t * y));
            }
            assert!(
                f(a_new) >= best - 1e-6,
                "Newton f={} vs grid best {best} (α={alpha}, y={y}, m={m}, q={q})",
                f(a_new)
            );
        }
    }

    #[test]
    fn newton_stationarity() {
        let l = Logistic::default();
        let mut rng = Rng::new(53);
        for _ in 0..200 {
            let y = if rng.next_bool(0.5) { 1.0 } else { -1.0 };
            let alpha = (0.01 + 0.98 * rng.next_f64()) * y;
            let m = rng.next_gaussian();
            let q = 0.5 + rng.next_f64();
            let a_new = l.coordinate_step(alpha, y, m, q) * y;
            let a0 = alpha * y;
            let g = ((1.0 - a_new) / a_new).ln() - y * m - q * (a_new - a0);
            assert!(g.abs() < 1e-6, "gradient at solution = {g}");
        }
    }

    #[test]
    fn subgradient_feasible_and_sigmoid() {
        let l = Logistic::default();
        for &(z, y) in &[(0.0, 1.0), (3.0, 1.0), (-3.0, -1.0), (100.0, 1.0)] {
            let u = l.primal_subgradient_dual(z, y);
            assert!(l.feasible(u, y), "u={u}");
        }
        assert!((l.primal_subgradient_dual(0.0, 1.0) - 0.5).abs() < 1e-12);
    }
}
