//! Declarative command-line parsing (no `clap` offline).
//!
//! Supports subcommands, `--flag value`, `--flag=value`, boolean
//! switches, defaults, required flags, and auto-generated help text.

use std::collections::BTreeMap;

/// Specification of one flag.
#[derive(Debug, Clone)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// None ⇒ boolean switch; Some(default) ⇒ takes a value.
    pub default: Option<&'static str>,
    pub required: bool,
}

impl FlagSpec {
    pub fn value(name: &'static str, default: &'static str, help: &'static str) -> Self {
        Self { name, help, default: Some(default), required: false }
    }

    pub fn required(name: &'static str, help: &'static str) -> Self {
        Self { name, help, default: None, required: true }
    }

    pub fn switch(name: &'static str, help: &'static str) -> Self {
        Self { name, help, default: None, required: false }
    }
}

/// Parsed arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    switches: BTreeMap<String, bool>,
    /// Positional arguments after the flags.
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        let raw = self
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing --{name}"))?;
        raw.parse::<T>()
            .map_err(|e| anyhow::anyhow!("--{name} '{raw}': {e}"))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.switches.get(name).copied().unwrap_or(false)
    }
}

/// Parse `argv` (excluding program name and subcommand) against specs.
pub fn parse(specs: &[FlagSpec], argv: &[String]) -> anyhow::Result<Args> {
    let mut args = Args::default();
    // Seed defaults.
    for spec in specs {
        if let Some(d) = spec.default {
            args.values.insert(spec.name.to_string(), d.to_string());
        }
    }
    let find = |name: &str| specs.iter().find(|s| s.name == name);
    let mut i = 0;
    while i < argv.len() {
        let tok = &argv[i];
        if let Some(body) = tok.strip_prefix("--") {
            let (name, inline_val) = match body.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (body, None),
            };
            let spec = find(name).ok_or_else(|| anyhow::anyhow!("unknown flag --{name}"))?;
            let is_switch = spec.default.is_none() && !spec.required;
            if is_switch {
                anyhow::ensure!(inline_val.is_none(), "switch --{name} takes no value");
                args.switches.insert(name.to_string(), true);
            } else {
                let val = match inline_val {
                    Some(v) => v,
                    None => {
                        i += 1;
                        anyhow::ensure!(i < argv.len(), "--{name} needs a value");
                        argv[i].clone()
                    }
                };
                args.values.insert(name.to_string(), val);
            }
        } else {
            args.positional.push(tok.clone());
        }
        i += 1;
    }
    for spec in specs {
        if spec.required && !args.values.contains_key(spec.name) {
            anyhow::bail!("missing required flag --{}", spec.name);
        }
    }
    Ok(args)
}

/// Render help text for a subcommand.
pub fn help(command: &str, about: &str, specs: &[FlagSpec]) -> String {
    let mut s = format!("{command} — {about}\n\nFlags:\n");
    for spec in specs {
        let kind = if spec.required {
            " (required)".to_string()
        } else if let Some(d) = spec.default {
            format!(" [default: {d}]")
        } else {
            " (switch)".to_string()
        };
        s.push_str(&format!("  --{:<18} {}{}\n", spec.name, spec.help, kind));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<FlagSpec> {
        vec![
            FlagSpec::value("dataset", "tiny", "dataset preset"),
            FlagSpec::value("rounds", "10", "max rounds"),
            FlagSpec::switch("verbose", "chatty output"),
            FlagSpec::required("out", "output path"),
        ]
    }

    fn sv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = parse(&specs(), &sv(&["--out", "x.csv"])).unwrap();
        assert_eq!(a.get("dataset"), Some("tiny"));
        assert_eq!(a.get_parse::<usize>("rounds").unwrap(), 10);
        assert!(!a.flag("verbose"));
        let a = parse(&specs(), &sv(&["--dataset=rcv1-s", "--rounds", "5", "--verbose", "--out=o"]))
            .unwrap();
        assert_eq!(a.get("dataset"), Some("rcv1-s"));
        assert_eq!(a.get_parse::<usize>("rounds").unwrap(), 5);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn required_enforced() {
        assert!(parse(&specs(), &sv(&[])).is_err());
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(parse(&specs(), &sv(&["--out", "x", "--bogus", "1"])).is_err());
    }

    #[test]
    fn switch_with_value_rejected() {
        assert!(parse(&specs(), &sv(&["--out", "x", "--verbose=yes"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(parse(&specs(), &sv(&["--out"])).is_err());
    }

    #[test]
    fn positional_collected() {
        let a = parse(&specs(), &sv(&["--out", "x", "pos1", "pos2"])).unwrap();
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn parse_errors_typed() {
        let a = parse(&specs(), &sv(&["--out", "x", "--rounds", "abc"])).unwrap();
        assert!(a.get_parse::<usize>("rounds").is_err());
    }

    #[test]
    fn help_renders() {
        let h = help("train", "train a model", &specs());
        assert!(h.contains("--dataset"));
        assert!(h.contains("[default: tiny]"));
        assert!(h.contains("(required)"));
        assert!(h.contains("(switch)"));
    }
}
