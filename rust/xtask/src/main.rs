//! Repo-specific lint wall: `cargo xtask lint`.
//!
//! Four textual rules the compiler and clippy cannot enforce, run over
//! `src/`, `tests/`, and `benches/` of the solver crate:
//!
//! 1. **safety-comments** — every `unsafe {` block and `unsafe impl`
//!    must be preceded by a `// SAFETY:` comment (within a few lines);
//!    every `unsafe fn` must document its contract with a `# Safety`
//!    doc section (or a `SAFETY` comment) in the block right above it.
//! 2. **decode-no-panic** — the wire-decode path
//!    (`src/transport/frame.rs`, `src/transport/socket.rs`, non-test
//!    code) must not contain `.unwrap()`, `.expect(`, `panic!(`,
//!    `unreachable!(` or `todo!(`: a hostile or corrupt peer must
//!    surface as a named `WireError`, never a process abort.
//! 3. **atomics-via-facade** — no file other than `src/util/sync.rs`
//!    may mention `std::sync::atomic`; all atomics flow through the
//!    façade so the ordering audit stays complete.
//! 4. **seqcst-justified** — any `SeqCst` use must carry an
//!    `// ORDERING:` justification within the preceding lines. (The
//!    tree is currently SeqCst-free; this keeps it honest if one
//!    returns.)
//!
//! Exit status: 0 clean, 1 with findings (one `file:line:` per line),
//! 2 on usage/IO errors. No dependencies, so the lint wall builds
//! anywhere the toolchain does.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => lint(),
        _ => {
            eprintln!("usage: cargo xtask lint");
            ExitCode::from(2)
        }
    }
}

/// Workspace root = parent of xtask's own manifest dir, so the lint
/// works from any cwd (`cargo xtask` runs it from wherever you are).
fn workspace_root() -> PathBuf {
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    manifest.parent().expect("xtask has a parent dir").to_path_buf()
}

fn lint() -> ExitCode {
    let root = workspace_root();
    let mut files = Vec::new();
    for dir in ["src", "tests", "benches"] {
        collect_rs(&root.join(dir), &mut files);
    }
    files.sort();

    let mut findings = Vec::new();
    for path in &files {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("xtask: cannot read {}: {e}", path.display());
                return ExitCode::from(2);
            }
        };
        let rel = path.strip_prefix(&root).unwrap_or(path).to_path_buf();
        let lines: Vec<&str> = text.lines().collect();
        check_safety_comments(&rel, &lines, &mut findings);
        check_decode_no_panic(&rel, &lines, &mut findings);
        check_atomics_via_facade(&rel, &lines, &mut findings);
        check_seqcst_justified(&rel, &lines, &mut findings);
    }

    if findings.is_empty() {
        println!("xtask lint: {} files clean", files.len());
        ExitCode::SUCCESS
    } else {
        let mut out = String::new();
        for f in &findings {
            let _ = writeln!(out, "{f}");
        }
        eprint!("{out}");
        eprintln!("xtask lint: {} finding(s) in {} files", findings.len(), files.len());
        ExitCode::FAILURE
    }
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            if path.file_name().is_some_and(|n| n == "target") {
                continue;
            }
            collect_rs(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

/// Is the line (sans leading whitespace) a comment line?
fn is_comment(line: &str) -> bool {
    let t = line.trim_start();
    t.starts_with("//") || t.starts_with("/*") || t.starts_with('*')
}

/// The code portion of a line: everything before a trailing `//`
/// comment. (A `//` inside a string literal is miscounted, but none of
/// the trigger patterns below appear in strings in this tree, and a
/// false find is a loud, fixable event — the lint prefers simplicity.)
fn code_part(line: &str) -> &str {
    match line.find("//") {
        Some(i) => &line[..i],
        None => line,
    }
}

/// Does `hay` contain `needle` as a whole word (no `[A-Za-z0-9_]` on
/// either side)?
fn has_word(hay: &str, needle: &str) -> bool {
    let bytes = hay.as_bytes();
    let mut from = 0;
    while let Some(i) = hay[from..].find(needle) {
        let start = from + i;
        let end = start + needle.len();
        let pre_ok = start == 0 || !is_word_byte(bytes[start - 1]);
        let post_ok = end == bytes.len() || !is_word_byte(bytes[end]);
        if pre_ok && post_ok {
            return true;
        }
        from = end;
    }
    false
}

fn is_word_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// `unsafe` followed by one of `{`, `fn`, `impl` on the same line.
fn unsafe_kind(code: &str) -> Option<&'static str> {
    let mut from = 0;
    while let Some(i) = code[from..].find("unsafe") {
        let start = from + i;
        let end = start + "unsafe".len();
        let pre_ok = start == 0 || !is_word_byte(code.as_bytes()[start - 1]);
        if pre_ok {
            let rest = code[end..].trim_start();
            if rest.starts_with('{') {
                return Some("block");
            }
            if rest.starts_with("fn") {
                return Some("fn");
            }
            if rest.starts_with("impl") {
                return Some("impl");
            }
        }
        from = end;
    }
    None
}

/// Rule 1: SAFETY comments on unsafe blocks/impls, `# Safety` docs on
/// unsafe fns.
fn check_safety_comments(rel: &Path, lines: &[&str], findings: &mut Vec<String>) {
    for (idx, line) in lines.iter().enumerate() {
        if is_comment(line) {
            continue;
        }
        let code = code_part(line);
        let Some(kind) = unsafe_kind(code) else { continue };
        let ok = match kind {
            // Contract lives in the doc block directly above the item.
            "fn" => doc_block_has(lines, idx, &["# Safety", "SAFETY"]),
            // Proof lives in a comment just above (or trailing).
            _ => line.contains("SAFETY:") || preceding_comment_has(lines, idx, 6, "SAFETY:"),
        };
        if !ok {
            let what = match kind {
                "fn" => "unsafe fn without a `# Safety` doc section",
                "impl" => "unsafe impl without a preceding `// SAFETY:` comment",
                _ => "unsafe block without a preceding `// SAFETY:` comment",
            };
            findings.push(format!("{}:{}: {what}", rel.display(), idx + 1));
        }
    }
}

/// Scan the contiguous doc/attr/comment block above `idx` for any of
/// `needles` (up to 30 lines).
fn doc_block_has(lines: &[&str], idx: usize, needles: &[&str]) -> bool {
    let mut i = idx;
    let mut budget = 30;
    while i > 0 && budget > 0 {
        i -= 1;
        budget -= 1;
        let t = lines[i].trim_start();
        let part_of_block = t.starts_with("///")
            || t.starts_with("//!")
            || t.starts_with("//")
            || t.starts_with("#[")
            || t.starts_with("#!")
            || (t.is_empty() && budget == 29); // allow one blank right above
        if !part_of_block {
            return false;
        }
        if needles.iter().any(|n| lines[i].contains(n)) {
            return true;
        }
    }
    false
}

/// Is there a comment containing `needle` within the `window` lines
/// above `idx` (scanning only comment/attribute lines)?
fn preceding_comment_has(lines: &[&str], idx: usize, window: usize, needle: &str) -> bool {
    let lo = idx.saturating_sub(window);
    for i in (lo..idx).rev() {
        if lines[i].contains(needle) {
            return true;
        }
    }
    false
}

const DECODE_FILES: [&str; 2] = ["src/transport/frame.rs", "src/transport/socket.rs"];
const PANICKY: [&str; 5] = [".unwrap()", ".expect(", "panic!(", "unreachable!(", "todo!("];

/// Rule 2: no panicking calls in the wire-decode path (non-test code).
fn check_decode_no_panic(rel: &Path, lines: &[&str], findings: &mut Vec<String>) {
    let rel_s = rel.to_string_lossy().replace('\\', "/");
    if !DECODE_FILES.contains(&rel_s.as_str()) {
        return;
    }
    for (idx, line) in lines.iter().enumerate() {
        if line.contains("#[cfg(test)]") {
            break; // tests sit at the bottom of both files
        }
        if is_comment(line) {
            continue;
        }
        let code = code_part(line);
        for pat in PANICKY {
            if code.contains(pat) {
                findings.push(format!(
                    "{}:{}: `{pat}` in the wire-decode path (must return a WireError)",
                    rel.display(),
                    idx + 1
                ));
            }
        }
    }
}

/// Rule 3: atomics only through the `util::sync` façade.
fn check_atomics_via_facade(rel: &Path, lines: &[&str], findings: &mut Vec<String>) {
    let rel_s = rel.to_string_lossy().replace('\\', "/");
    if rel_s == "src/util/sync.rs" {
        return;
    }
    for (idx, line) in lines.iter().enumerate() {
        if line.contains("std::sync::atomic") {
            findings.push(format!(
                "{}:{}: raw `std::sync::atomic` outside the `util::sync` façade",
                rel.display(),
                idx + 1
            ));
        }
    }
}

/// Rule 4: every SeqCst carries an ORDERING justification.
fn check_seqcst_justified(rel: &Path, lines: &[&str], findings: &mut Vec<String>) {
    for (idx, line) in lines.iter().enumerate() {
        if !has_word(code_part(line), "SeqCst") {
            continue;
        }
        if line.contains("ORDERING:") || preceding_comment_has(lines, idx, 5, "ORDERING:") {
            continue;
        }
        findings.push(format!(
            "{}:{}: `SeqCst` without an `// ORDERING:` justification",
            rel.display(),
            idx + 1
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_matching() {
        assert!(has_word("a SeqCst b", "SeqCst"));
        assert!(!has_word("NotSeqCst", "SeqCst"));
        assert!(!has_word("SeqCst_ish", "SeqCst"));
    }

    #[test]
    fn unsafe_kinds() {
        assert_eq!(unsafe_kind("let x = unsafe { y };"), Some("block"));
        assert_eq!(unsafe_kind("pub unsafe fn f()"), Some("fn"));
        assert_eq!(unsafe_kind("unsafe impl Send for T {}"), Some("impl"));
        assert_eq!(unsafe_kind("\"sigma=0.25(unsafe)\""), None);
        assert_eq!(unsafe_kind("says unsafe) =="), None);
        assert_eq!(unsafe_kind("allow_unsafe_sigma"), None);
    }

    #[test]
    fn safety_rule_flags_and_accepts() {
        let bad = ["fn f() {", "    let x = unsafe { g() };", "}"];
        let mut out = Vec::new();
        check_safety_comments(Path::new("x.rs"), &bad, &mut out);
        assert_eq!(out.len(), 1);

        let good = ["fn f() {", "    // SAFETY: g's contract holds.", "    let x = unsafe { g() };", "}"];
        let mut out = Vec::new();
        check_safety_comments(Path::new("x.rs"), &good, &mut out);
        assert!(out.is_empty());

        let doc = ["/// Does things.", "///", "/// # Safety", "/// i < len.", "pub unsafe fn g(i: usize) {}"];
        let mut out = Vec::new();
        check_safety_comments(Path::new("x.rs"), &doc, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn seqcst_rule() {
        let bad = ["x.load(Ordering::SeqCst);"];
        let mut out = Vec::new();
        check_seqcst_justified(Path::new("x.rs"), &bad, &mut out);
        assert_eq!(out.len(), 1);

        let good = ["// ORDERING: fence needed for X.", "x.load(Ordering::SeqCst);"];
        let mut out = Vec::new();
        check_seqcst_justified(Path::new("x.rs"), &good, &mut out);
        assert!(out.is_empty());
    }
}
