//! Exhaustive interleaving checks for the `AtomicF64Vec` protocols
//! (`src/util/atomic_vec.rs`): the CAS add (`add`, lines 88–98), the
//! wild add (`add_wild`, lines 103–107), and a reader racing either.
//!
//! Built only with `--features modelcheck` (see `[[test]]` in
//! Cargo.toml). Each model thread transcribes the real protocol
//! line-by-line: one explorer step per atomic instruction (load, CAS,
//! store). The shared state holds the cell's *value*; tearing is
//! impossible by construction because every model store writes the
//! whole value — which is exactly the guarantee the real code gets from
//! `AtomicU64` (the paper's "wild" mode loses read-modify-write
//! atomicity, never store atomicity).

use hybrid_dca::util::model::{explore, ModelThread, Step};

/// Transcription of `AtomicF64Vec::add` (CAS retry loop): one step for
/// the initial relaxed load, one step per `compare_exchange_weak`
/// attempt (failure reloads, exactly like `Err(actual) => cur = actual`).
struct CasAdd {
    delta: f64,
    seen: Option<f64>,
}

impl CasAdd {
    fn new(delta: f64) -> Self {
        CasAdd { delta, seen: None }
    }
}

impl ModelThread<f64> for CasAdd {
    fn step(&mut self, cell: &mut f64) -> Step {
        match self.seen {
            None => {
                self.seen = Some(*cell); // cell.load(Relaxed)
                Step::Ran
            }
            Some(cur) => {
                if *cell == cur {
                    *cell = cur + self.delta; // CAS success
                    Step::Done
                } else {
                    self.seen = Some(*cell); // CAS failure: cur = actual
                    Step::Ran
                }
            }
        }
    }
}

/// Transcription of `AtomicF64Vec::add_wild`: relaxed load, then an
/// independent relaxed store of `loaded + delta`.
struct WildAdd {
    delta: f64,
    seen: Option<f64>,
}

impl WildAdd {
    fn new(delta: f64) -> Self {
        WildAdd { delta, seen: None }
    }
}

impl ModelThread<f64> for WildAdd {
    fn step(&mut self, cell: &mut f64) -> Step {
        match self.seen {
            None => {
                self.seen = Some(*cell); // cell.load(Relaxed)
                Step::Ran
            }
            Some(cur) => {
                *cell = cur + self.delta; // cell.store(cur + delta)
                Step::Done
            }
        }
    }
}

/// PassCoDe-Atomic invariant: two concurrent CAS adds to one cell
/// commit both deltas in *every* interleaving — no lost Δα.
#[test]
fn cas_add_never_loses_an_update() {
    let stats = explore(
        &mut || {
            (
                0.0f64,
                vec![
                    Box::new(CasAdd::new(1.0)) as Box<dyn ModelThread<f64>>,
                    Box::new(CasAdd::new(2.0)),
                ],
            )
        },
        &mut |&v| assert_eq!(v, 3.0, "CAS add lost an update"),
    );
    // At least the C(4,2) = 6 schedules of two 2-step threads, plus
    // retry branches where a CAS observes the other thread's commit.
    assert!(stats.executions >= 6, "explored only {} executions", stats.executions);
}

/// PassCoDe-Wild invariant: concurrent wild adds may lose an update —
/// but the result is always some *valid* partial sum, never a torn
/// value. Exploration must also prove both the lossy and the clean
/// outcome are reachable (the race is real, not hypothetical).
#[test]
fn wild_add_loses_updates_but_never_tears() {
    let mut outcomes = std::collections::BTreeSet::new();
    explore(
        &mut || {
            (
                0.0f64,
                vec![
                    Box::new(WildAdd::new(1.0)) as Box<dyn ModelThread<f64>>,
                    Box::new(WildAdd::new(2.0)),
                ],
            )
        },
        &mut |&v| {
            assert!(
                v == 1.0 || v == 2.0 || v == 3.0,
                "torn/invalid value {v} observed"
            );
            outcomes.insert(v.to_bits());
        },
    );
    let outcomes: Vec<f64> = outcomes.into_iter().map(f64::from_bits).collect();
    assert_eq!(outcomes, vec![1.0, 2.0, 3.0], "missing reachable outcome");
}

/// Wild-vs-CAS: a wild store may erase a concurrent CAS commit (final
/// 2.0), but can never produce anything outside the valid-sum set, and
/// the clean outcome (3.0) stays reachable. This is the exact risk the
/// ν-damped aggregation in the paper compensates for.
#[test]
fn wild_store_may_erase_cas_commit_but_never_tears() {
    let mut outcomes = std::collections::BTreeSet::new();
    explore(
        &mut || {
            (
                0.0f64,
                vec![
                    Box::new(CasAdd::new(1.0)) as Box<dyn ModelThread<f64>>,
                    Box::new(WildAdd::new(2.0)),
                ],
            )
        },
        &mut |&v| {
            outcomes.insert(v.to_bits());
        },
    );
    let outcomes: Vec<f64> = outcomes.into_iter().map(f64::from_bits).collect();
    // 2.0 = wild overwrote the CAS commit; 3.0 = both landed. The CAS
    // retry loop makes 1.0 (CAS erasing the wild store) unreachable:
    // a CAS that observed pre-store state fails and reloads.
    assert_eq!(outcomes, vec![2.0, 3.0]);
}

/// Reader invariant ("dual sum never observes torn α"): a reader racing
/// a CAS writer that commits two increments observes only valid partial
/// sums, in monotone order — each observation is one of the writer's
/// committed states, never an intermediate bit pattern.
#[test]
fn reader_observes_only_committed_partial_sums() {
    /// Writer: two sequential CAS adds of 0.5 each (same cell).
    struct TwoAdds {
        inner: CasAdd,
        left: usize,
    }
    impl ModelThread<(f64, Vec<u64>)> for TwoAdds {
        fn step(&mut self, s: &mut (f64, Vec<u64>)) -> Step {
            match self.inner.step(&mut s.0) {
                Step::Done if self.left > 1 => {
                    self.left -= 1;
                    self.inner = CasAdd::new(0.5);
                    Step::Ran
                }
                done_or_ran => done_or_ran,
            }
        }
    }
    /// Reader: two relaxed loads, recorded for the final assertion.
    struct Reader {
        loads: usize,
    }
    impl ModelThread<(f64, Vec<u64>)> for Reader {
        fn step(&mut self, s: &mut (f64, Vec<u64>)) -> Step {
            s.1.push(s.0.to_bits());
            self.loads -= 1;
            if self.loads == 0 {
                Step::Done
            } else {
                Step::Ran
            }
        }
    }
    explore(
        &mut || {
            (
                (0.0f64, Vec::new()),
                vec![
                    Box::new(TwoAdds { inner: CasAdd::new(0.5), left: 2 })
                        as Box<dyn ModelThread<(f64, Vec<u64>)>>,
                    Box::new(Reader { loads: 2 }),
                ],
            )
        },
        &mut |(final_v, observed)| {
            assert_eq!(*final_v, 1.0);
            let mut prev = f64::NEG_INFINITY;
            for &bits in observed {
                let v = f64::from_bits(bits);
                assert!(
                    v == 0.0 || v == 0.5 || v == 1.0,
                    "reader saw non-committed value {v}"
                );
                assert!(v >= prev, "reader saw non-monotone sequence");
                prev = v;
            }
        },
    );
}
